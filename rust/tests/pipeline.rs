// Shared lint policy with the library crate (rust/src/lib.rs): these
// allows cover numeric-harness idioms (indexed loops, config structs
// mutated after Default::default(), positional format args).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

//! Cross-layer pipeline tests: trained weights + AOT artifacts + native
//! model must agree. Skips gracefully when `make artifacts` has not run.

use std::path::PathBuf;

use mustafar::config::{Backend, EngineConfig, SparsityConfig};
use mustafar::coordinator::pjrt_backend::PjrtBackend;
use mustafar::coordinator::{Engine, Request};
use mustafar::model::{NativeModel, Weights};
use mustafar::util::Pcg32;
use mustafar::workload::lang;

fn artifacts() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
}

fn have(name: &str) -> bool {
    artifacts().join(format!("weights_{name}.json")).exists()
        && artifacts().join("artifacts.json").exists()
}

#[test]
fn native_vs_pjrt_dense_logits_agree() {
    if !have("tiny") {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let w = Weights::load(&artifacts(), "tiny").unwrap();
    let model = NativeModel::new(w.clone());
    let plen = w.cfg.max_seq / 2; // AOT prefill length
    let prompt = lang::gen_document(&mut Pcg32::seeded(3), plen);

    // native greedy tokens
    let mut ec = EngineConfig::default();
    ec.backend = Backend::NativeDense;
    ec.max_new_tokens = 8;
    let mut en = Engine::new_native(NativeModel::new(w.clone()), ec.clone());
    let native = en.run_trace(vec![Request::new(0, prompt.clone(), 8)]).unwrap();

    // pjrt-dense greedy tokens
    let mut ec2 = EngineConfig::default();
    ec2.backend = Backend::PjrtDense;
    ec2.max_new_tokens = 8;
    let pj = PjrtBackend::new(&artifacts(), &w, Backend::PjrtDense, SparsityConfig::dense())
        .unwrap();
    let mut ep = Engine::new_pjrt(model, ec2, pj);
    let pjrt = ep.run_trace(vec![Request::new(0, prompt, 8)]).unwrap();

    assert_eq!(
        native[0].tokens, pjrt[0].tokens,
        "greedy decode must agree across native and XLA backends"
    );
}

#[test]
fn pjrt_sparse_backend_runs_and_compresses() {
    if !have("tiny") {
        return;
    }
    let w = Weights::load(&artifacts(), "tiny").unwrap();
    let plen = w.cfg.max_seq / 2;
    let prompt = lang::gen_document(&mut Pcg32::seeded(5), plen);
    let mut ec = EngineConfig::default();
    ec.backend = Backend::PjrtSparse;
    ec.sparsity = SparsityConfig::mustafar(0.7, 0.7);
    ec.max_new_tokens = 6;
    let pj = PjrtBackend::new(&artifacts(), &w, Backend::PjrtSparse, ec.sparsity).unwrap();
    let mut e = Engine::new_pjrt(NativeModel::new(w), ec, pj);
    let out = e.run_trace(vec![Request::new(0, prompt, 6)]).unwrap();
    assert_eq!(out[0].tokens.len(), 6);
    assert!(
        out[0].kv_bytes < out[0].kv_dense_bytes,
        "sparse pjrt path must report compressed KV"
    );
}

#[test]
fn native_sparse_70_mechanics_on_tiny() {
    if !have("tiny") {
        return;
    }
    let w = Weights::load(&artifacts(), "tiny").unwrap();
    let prompt = lang::gen_document(&mut Pcg32::seeded(7), 200);
    let gen = 12;
    let mk = |backend, s, w: &Weights| {
        let mut ec = EngineConfig::default();
        ec.backend = backend;
        ec.sparsity = SparsityConfig::mustafar(s, s);
        ec.max_new_tokens = gen;
        Engine::new_native(NativeModel::new(w.clone()), ec)
    };
    let a = mk(Backend::NativeDense, 0.0, &w)
        .run_trace(vec![Request::new(0, prompt.clone(), gen)])
        .unwrap();
    let b = mk(Backend::NativeSparse, 0.7, &w)
        .run_trace(vec![Request::new(0, prompt, gen)])
        .unwrap();
    assert_eq!(a[0].tokens.len(), b[0].tokens.len());
}
