// Shared lint policy with the library crate (rust/src/lib.rs): these
// allows cover numeric-harness idioms (indexed loops, config structs
// mutated after Default::default(), positional format args).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

//! Telemetry acceptance: the histogram registry fills from a real run,
//! `{"trace"}` output is schema-valid chrome://tracing JSON with
//! monotone span nesting, the Prometheus exposition parses back, and —
//! the determinism contract — two pinned-seed chaos runs dump
//! byte-identical flight-recorder sequences.

use mustafar::config::{Backend, EngineConfig, ModelConfig, SparsityConfig};
use mustafar::coordinator::{estimate_seq_bytes, Engine, Request};
use mustafar::faults::Injector;
use mustafar::fmt::Json;
use mustafar::kvcache::KvPolicy;
use mustafar::model::{NativeModel, Weights};
use mustafar::telemetry::prometheus;
use mustafar::workload::trace::chaos_trace;

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        ff: 128,
        vocab: 512,
        rope_theta: 10000.0,
        max_seq: 512,
        norm_eps: 1e-5,
    }
}

fn tiny_engine(telemetry: bool) -> Engine {
    let cfg = tiny_cfg();
    let mut ec = EngineConfig::default();
    ec.backend = Backend::NativeSparse;
    ec.sparsity = SparsityConfig::mustafar(0.5, 0.5);
    ec.max_batch = 4;
    ec.max_new_tokens = 64;
    ec.telemetry = telemetry;
    Engine::new_native(NativeModel::new(Weights::random_for_tests(cfg, 7)), ec)
}

fn small_requests(n: u64) -> Vec<Request> {
    (0..n)
        .map(|id| {
            let prompt: Vec<u16> =
                (0..24).map(|j| ((id as usize * 37 + j) % 400 + 16) as u16).collect();
            Request::new(id, prompt, 6)
        })
        .collect()
}

#[test]
fn histograms_fill_from_a_live_run_and_quantiles_are_monotone() {
    let mut e = tiny_engine(true);
    let n = 4u64;
    let out = e.run_trace(small_requests(n)).unwrap();
    assert_eq!(out.len(), n as usize);

    let hists: std::collections::BTreeMap<&str, _> =
        e.telemetry.hist_snapshots().into_iter().collect();
    // one TTFT / queue-wait / prefill sample per request
    for key in ["ttft_us", "queue_wait_us", "prefill_us"] {
        assert_eq!(hists[key].count(), n, "{key} should have one sample per request");
    }
    // 6 tokens each: the first is TTFT, the rest are inter-token gaps
    assert!(hists["inter_token_us"].count() >= n * 4, "inter-token gaps under-recorded");
    assert!(hists["decode_round_us"].count() >= 6, "decode rounds under-recorded");
    // prune_us times the pressure ladder's re-prune; a clean unpressured
    // run records nothing there, so only assert it exists in the registry
    assert!(hists.contains_key("prune_us"));
    assert!(hists["pool_occupancy_bytes"].count() > 0);
    assert!(hists["worker_task_us"].count() > 0, "decode workers must be timed");
    assert!(hists["ttft_us"].max() > 0, "TTFT of a real prefill cannot be zero µs");

    // quantile surface: present for the three request-latency axes,
    // ms-scaled, and monotone in q
    let q: std::collections::BTreeMap<&str, f64> =
        e.telemetry.quantile_fields().into_iter().collect();
    for axis in ["ttft_ms", "inter_token_ms", "queue_wait_ms"] {
        let (p50, p99, p999) = (
            q[format!("{axis}_p50").as_str()],
            q[format!("{axis}_p99").as_str()],
            q[format!("{axis}_p999").as_str()],
        );
        assert!(p50 <= p99 && p99 <= p999, "{axis}: {p50} / {p99} / {p999} not monotone");
    }
    assert!(q["ttft_ms_p50"] > 0.0);
}

#[test]
fn disabled_telemetry_records_no_histograms_but_recorder_stays_on() {
    let mut e = tiny_engine(false);
    e.run_trace(small_requests(3)).unwrap();
    assert!(!e.telemetry.on());
    for (name, h) in e.telemetry.hist_snapshots() {
        assert!(h.is_empty(), "{name} recorded despite --no-telemetry");
    }
    assert!(e.spans().is_empty(), "spans recorded despite --no-telemetry");
    // the flight recorder is a debugging aid, not a metric: it stays on
    assert!(!e.recorder().is_empty(), "flight recorder must survive --no-telemetry");
    for q in e.telemetry.quantile_fields() {
        assert_eq!(q.1, 0.0, "{} nonzero on an empty histogram", q.0);
    }
}

/// `{"trace": n}` output loads in chrome://tracing: every event is an
/// "X" complete event with pid/tid/ts/dur, and each request's child
/// spans (`queued` → `prefill` → `decode`) tile its `request` span
/// exactly, in order, with no overlap and no excursion.
#[test]
fn trace_json_is_chrome_schema_with_monotone_span_nesting() {
    let mut e = tiny_engine(true);
    let n = 4u64;
    e.run_trace(small_requests(n)).unwrap();

    let line = e.trace_json(0).to_string();
    let v = Json::parse(&line).expect("trace output must be valid JSON");
    assert_eq!(v.get("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
    assert_eq!(v.get("droppedSpans").unwrap().as_usize().unwrap(), 0);
    let events = v.get("traceEvents").unwrap().as_arr().unwrap();
    // 4 lifecycle spans per request, plus engine-wide decode_round spans
    assert!(events.len() >= n as usize * 4, "only {} trace events", events.len());

    // (tid, id) -> name -> (ts, end)
    let mut per_req: std::collections::BTreeMap<(u64, u64), Vec<(String, u64, u64)>> =
        std::collections::BTreeMap::new();
    let mut decode_rounds = 0usize;
    for ev in events {
        assert_eq!(ev.get("ph").unwrap().as_str().unwrap(), "X", "only complete events");
        assert_eq!(ev.get("pid").unwrap().as_usize().unwrap(), 1);
        let name = ev.get("name").unwrap().as_str().unwrap().to_string();
        let tid = ev.get("tid").unwrap().as_usize().unwrap() as u64;
        let ts = ev.get("ts").unwrap().as_usize().unwrap() as u64;
        let dur = ev.get("dur").unwrap().as_usize().unwrap() as u64;
        if name == "decode_round" {
            assert_eq!(tid, 0, "engine-wide spans render on lane 0");
            decode_rounds += 1;
            continue;
        }
        let id = ev.get("args").unwrap().get("id").unwrap().as_usize().unwrap() as u64;
        per_req.entry((tid, id)).or_default().push((name, ts, ts + dur));
    }
    assert!(decode_rounds >= 6, "decode_round spans missing from the trace");
    assert_eq!(per_req.len(), n as usize, "every request gets a span group");

    for ((tid, id), spans) in per_req {
        let get = |want: &str| {
            spans
                .iter()
                .find(|(name, _, _)| name == want)
                .unwrap_or_else(|| panic!("request {id} (lane {tid}) missing {want} span"))
        };
        let &(_, r0, r1) = get("request");
        let &(_, q0, q1) = get("queued");
        let &(_, p0, p1) = get("prefill");
        let &(_, d0, d1) = get("decode");
        assert_eq!(q0, r0, "request {id}: queued must start the request span");
        assert_eq!(p0, q1, "request {id}: prefill must start where queued ends");
        assert_eq!(d0, p1, "request {id}: decode must start where prefill ends");
        assert_eq!(d1, r1, "request {id}: decode must end the request span");
        for (name, s0, s1) in &spans {
            assert!(
                *s0 >= r0 && *s1 <= r1,
                "request {id}: {name} span [{s0}, {s1}] escapes parent [{r0}, {r1}]"
            );
        }
    }
}

/// Minimal parse-back of the Prometheus text exposition built from live
/// engine data: every line is a comment or `name value`, histogram
/// bucket series are cumulative and agree with `_count`, and explicit
/// quantile lines are present.
#[test]
fn prometheus_exposition_from_live_run_parses_back() {
    let mut e = tiny_engine(true);
    e.run_trace(small_requests(3)).unwrap();
    let scalars = vec![("completions", 3.0), ("queue_peak_pending", 3.0)];
    let text = prometheus::render(&scalars, &e.telemetry.hist_snapshots());

    let mut values: std::collections::BTreeMap<String, f64> = std::collections::BTreeMap::new();
    let mut buckets: std::collections::BTreeMap<String, Vec<(f64, f64)>> = Default::default();
    for line in text.lines() {
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name, value) = line.rsplit_once(' ').unwrap_or_else(|| panic!("bad line {line:?}"));
        let value: f64 =
            value.parse().unwrap_or_else(|_| panic!("unparseable value in {line:?}"));
        assert!(name.starts_with("mustafar_"), "unprefixed metric line {line:?}");
        if let Some((base, rest)) = name.split_once("_bucket{le=\"") {
            let le = rest.trim_end_matches("\"}");
            let le = if le == "+Inf" { f64::INFINITY } else { le.parse().unwrap() };
            buckets.entry(base.to_string()).or_default().push((le, value));
        } else {
            values.insert(name.to_string(), value);
        }
    }

    assert_eq!(values["mustafar_completions"], 3.0);
    assert_eq!(values["mustafar_queue_peak_pending"], 3.0);
    for (base, series) in &buckets {
        // le thresholds strictly increasing, counts cumulative
        for w in series.windows(2) {
            assert!(w[0].0 < w[1].0, "{base}: le thresholds out of order");
            assert!(w[0].1 <= w[1].1, "{base}: bucket counts not cumulative");
        }
        let (last_le, last_count) = *series.last().unwrap();
        assert!(last_le.is_infinite(), "{base}: missing +Inf bucket");
        assert_eq!(last_count, values[&format!("{base}_count")], "{base}: +Inf != _count");
        assert!(values.contains_key(&format!("{base}_sum")), "{base}: missing _sum");
        for q in ["p50", "p99", "p999"] {
            assert!(values.contains_key(&format!("{base}_{q}")), "{base}: missing {q}");
        }
    }
    let ttft = buckets.get("mustafar_ttft_us").expect("ttft histogram missing");
    assert_eq!(ttft.last().unwrap().1, 3.0, "three requests, three TTFT samples");
}

/// The determinism contract from the flight-recorder design: events
/// carry no timestamps and are recorded (or folded in) only on the
/// engine thread, so two chaos runs with the same pinned seed dump
/// identical event sequences.
#[test]
fn pinned_seed_chaos_runs_dump_identical_flight_recorder_sequences() {
    // Engine-thread-sequenced fault points only (worker.task/seq.decode
    // fire on pool threads whose interleaving is scheduler-dependent);
    // 0.25 on prefill makes a fault event a near-certainty per run.
    const SPEC: &str = "seq.prefill:0.25,kvpool.alloc:0.05,prefix.insert:0.1";
    let seed: u64 = std::env::var("MUSTAFAR_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20260807);

    let run = |seed: u64| {
        let cfg = tiny_cfg();
        let policy = KvPolicy::mustafar(0.7, 0.7);
        let per_seq = estimate_seq_bytes(&policy, &cfg, 48 + 48);
        let mut ec = EngineConfig::default();
        ec.backend = Backend::NativeSparse;
        ec.sparsity = SparsityConfig::mustafar(0.7, 0.7);
        ec.max_batch = 4;
        ec.max_new_tokens = 64;
        ec.kv_budget_bytes = per_seq * 2;
        ec.kv_page_bytes = 1024;
        let mut e =
            Engine::new_native(NativeModel::new(Weights::random_for_tests(cfg, seed)), ec);
        e.set_fault_injector(Injector::parse(SPEC, seed).unwrap());
        for t in chaos_trace(seed, 24, 48, 16) {
            let _ = e.submit_full(Request::new(t.id, t.prompt, t.max_new_tokens));
        }
        let mut steps = 0usize;
        while !e.idle() {
            if let Err(err) = e.step() {
                e.fail_inflight(&err.to_string());
            }
            let _ = e.take_completions();
            steps += 1;
            assert!(steps < 20_000, "engine failed to quiesce");
        }
        let events: Vec<_> = e.recorder().events().cloned().collect();
        (events, e.dump_json().to_string())
    };

    let (ev1, dump1) = run(seed);
    let (ev2, dump2) = run(seed);
    assert!(!ev1.is_empty());
    assert!(
        ev1.iter().any(|e| e.kind.starts_with("fault:")),
        "chaos run recorded no fault events — the spec/seed no longer bites"
    );
    assert_eq!(ev1, ev2, "pinned-seed chaos runs diverged in the flight recorder");
    assert_eq!(dump1, dump2, "dump_json must render identically for identical rings");
}
