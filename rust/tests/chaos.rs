// Shared lint policy with the library crate (rust/src/lib.rs): these
// allows cover numeric-harness idioms (indexed loops, config structs
// mutated after Default::default(), positional format args).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

//! Chaos acceptance: replay realistic traces while a fault injector
//! randomly breaks kvpool allocation/release, worker tasks, per-sequence
//! prefill/decode, and prefix-cache inserts. Whatever fires, the engine
//! must answer every request exactly once (some with `error`/`rejected`
//! finishes — never silently lost, never twice), keep pool accounting
//! exact at every step, and never deadlock.
//!
//! The trace and the injector are both deterministic in
//! `MUSTAFAR_FAULT_SEED` (default 20260807), so a failing run replays
//! exactly: `MUSTAFAR_FAULT_SEED=<seed> cargo test --test chaos`.

use std::collections::{BTreeMap, HashSet};
use std::time::Duration;

use mustafar::config::{Backend, EngineConfig, SparsityConfig};
use mustafar::coordinator::{
    estimate_seq_bytes, Completion, Engine, FinishReason, Request, SubmitOutcome,
};
use mustafar::faults::Injector;
use mustafar::kvcache::KvPolicy;
use mustafar::model::{NativeModel, Weights};
use mustafar::workload::trace::{
    bursty_monster_trace, chaos_trace, disconnect_trace, TraceRequest,
};

/// Every request-reachable fault point, armed with low per-call
/// probabilities so runs see a mix of clean and broken behavior.
const SPEC: &str = "kvpool.alloc:0.02,kvpool.release:0.02,worker.task:0.01,\
                    seq.decode:0.02,seq.prefill:0.02,seq.prefill_chunk:0.02,\
                    seq.compress:0.02,prefix.insert:0.05";

fn base_seed() -> u64 {
    std::env::var("MUSTAFAR_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20260807)
}

fn tiny_cfg() -> mustafar::config::ModelConfig {
    mustafar::config::ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        ff: 128,
        vocab: 512,
        rope_theta: 10000.0,
        max_seq: 512,
        norm_eps: 1e-5,
    }
}

/// A pressured engine: sparse backend, small pool budget (two full
/// sequences out of a four-slot batch), prefix cache on — so alloc
/// faults land on real reclaim paths, not an uncontended pool. Prefill
/// is chunked under a round budget so `seq.prefill_chunk` faults and
/// mid-prefill cuts have live-but-not-yet-decodable sequences to land
/// on.
fn pressured_engine(seed: u64) -> Engine {
    let cfg = tiny_cfg();
    let policy = KvPolicy::mustafar(0.7, 0.7);
    let per_seq = estimate_seq_bytes(&policy, &cfg, 48 + 48);
    let mut ec = EngineConfig::default();
    ec.backend = Backend::NativeSparse;
    ec.sparsity = SparsityConfig::mustafar(0.7, 0.7);
    ec.max_batch = 4;
    ec.max_new_tokens = 64;
    ec.kv_budget_bytes = per_seq * 2;
    ec.kv_page_bytes = 1024;
    ec.prefill_chunk_tokens = 16;
    ec.round_token_budget = 32;
    Engine::new_native(NativeModel::new(Weights::random_for_tests(cfg, seed)), ec)
}

/// Like [`pressured_engine`] but sized for the bursty-monster trace: the
/// pool holds the monster plus a couple of shorts, so the monster's
/// chunked prefill runs for many rounds while shorts churn around it.
fn monster_engine(seed: u64) -> Engine {
    let cfg = tiny_cfg();
    let policy = KvPolicy::mustafar(0.7, 0.7);
    let per_monster = estimate_seq_bytes(&policy, &cfg, 256 + 8);
    let mut ec = EngineConfig::default();
    ec.backend = Backend::NativeSparse;
    ec.sparsity = SparsityConfig::mustafar(0.7, 0.7);
    ec.max_batch = 6;
    ec.max_new_tokens = 8;
    ec.kv_budget_bytes = per_monster * 2;
    ec.kv_page_bytes = 1024;
    ec.prefill_chunk_tokens = 16;
    ec.round_token_budget = 32;
    Engine::new_native(NativeModel::new(Weights::random_for_tests(cfg, seed)), ec)
}

/// Drive one trace to quiescence under whatever injector the engine
/// carries: submit everything, honor `cancel_after` thresholds between
/// steps, convert step-level errors into failed-inflight completions
/// (what the server does), and assert exact pool accounting after every
/// step. Returns (completions, refused ids, steps taken).
fn drive(e: &mut Engine, trace: Vec<TraceRequest>) -> (Vec<Completion>, Vec<u64>, usize) {
    let mut cancels: Vec<(u64, usize)> = trace
        .iter()
        .filter_map(|t| t.cancel_after.map(|k| (t.id, k)))
        .collect();
    let mut refused = Vec::new();
    for t in trace {
        match e.submit_full(Request::new(t.id, t.prompt, t.max_new_tokens)) {
            SubmitOutcome::Queued => {}
            SubmitOutcome::Rejected | SubmitOutcome::Shed { .. } => refused.push(t.id),
        }
    }
    let mut out = Vec::new();
    let mut steps = 0usize;
    while !e.idle() {
        cancels.retain(|&(id, k)| match e.progress(id) {
            Some(g) if g >= k => {
                // may race a fault-induced finish; either way the
                // request is answered exactly once
                let _ = e.cancel(id);
                false
            }
            Some(_) => true,
            None => false,
        });
        if e.idle() {
            break;
        }
        if let Err(err) = e.step() {
            // the server's recovery: fail everything in flight back to
            // its client rather than stranding waiters
            e.fail_inflight(&err.to_string());
        }
        assert_eq!(
            e.pool_stats().live_bytes,
            e.measured_live_bytes(),
            "pool accounting diverged at step {steps}"
        );
        out.extend(e.take_completions());
        steps += 1;
        assert!(steps < 20_000, "engine failed to quiesce (deadlock/livelock)");
    }
    out.extend(e.take_completions());
    (out, refused, steps)
}

/// Exactly-once check: completions + refusals cover every trace id,
/// no id twice.
fn assert_exactly_once(n: usize, out: &[Completion], refused: &[u64], ctx: &str) {
    let mut answered: Vec<u64> =
        out.iter().map(|c| c.id).chain(refused.iter().copied()).collect();
    answered.sort_unstable();
    let dup = answered.windows(2).find(|w| w[0] == w[1]);
    assert!(dup.is_none(), "{ctx}: request {} answered twice", dup.unwrap()[0]);
    let want: Vec<u64> = (0..n as u64).collect();
    assert_eq!(answered, want, "{ctx}: lost requests");
}

#[test]
fn chaos_trace_exactly_once_under_randomized_faults() {
    let seed0 = base_seed();
    let mut fired: BTreeMap<String, (u64, u64)> = BTreeMap::new();
    let mut total_steps = 0usize;
    let mut finishes: BTreeMap<String, usize> = BTreeMap::new();
    let mut run = 0u64;
    while total_steps < 2000 {
        assert!(run < 30, "chaos runs are not accumulating steps ({total_steps})");
        let seed = seed0.wrapping_add(run);
        let mut e = pressured_engine(seed);
        e.set_fault_injector(Injector::parse(SPEC, seed).unwrap());
        let trace = chaos_trace(seed, 32, 48, 24);
        let n = trace.len();
        let (out, refused, steps) = drive(&mut e, trace);
        total_steps += steps;
        assert_exactly_once(n, &out, &refused, &format!("seed {seed}"));
        assert_eq!(e.active_count(), 0, "seed {seed}: sequences left active");
        assert_eq!(e.queued_count(), 0, "seed {seed}: requests left queued");
        for c in &out {
            *finishes.entry(format!("{:?}", c.finish)).or_default() += 1;
        }
        for (name, hits, fires) in e.fault_injector().fired() {
            let ent = fired.entry(name).or_default();
            ent.0 += hits;
            ent.1 += fires;
        }
        run += 1;
    }

    // the paper-style fault matrix for EXPERIMENTS §9 (shows up in CI
    // logs; `--nocapture` locally)
    eprintln!("\n| fault point | evaluations | injected | outcome |");
    eprintln!("|---|---|---|---|");
    for (name, (hits, fires)) in &fired {
        eprintln!("| `{name}` | {hits} | {fires} | survived, exactly-once |");
    }
    eprintln!("runs: {run}, steps: {total_steps}, finishes: {finishes:?}\n");

    let distinct_fired: HashSet<&String> =
        fired.iter().filter(|(_, v)| v.1 > 0).map(|(k, _)| k).collect();
    assert!(
        distinct_fired.len() >= 5,
        "expected >= 5 distinct fault points to fire, got {distinct_fired:?}"
    );
    assert!(total_steps >= 2000, "acceptance requires >= 2000 steps, got {total_steps}");
}

#[test]
fn disconnect_trace_survives_faults() {
    // the PR-5 cancellation workload with the injector armed on top:
    // hangs-up and injected faults interleave, everything still answers
    let seed = base_seed().wrapping_mul(31).wrapping_add(7);
    let mut e = pressured_engine(seed);
    e.set_fault_injector(Injector::parse(SPEC, seed).unwrap());
    let trace = disconnect_trace(seed, 16, 48, 32);
    let n = trace.len();
    let (out, refused, _) = drive(&mut e, trace);
    assert_exactly_once(n, &out, &refused, "disconnect+faults");
    assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
}

#[test]
fn unarmed_injector_changes_nothing() {
    // with no faults armed the chaos driver is a plain replay: two
    // engines over the same trace produce identical token streams
    // (determinism is what makes a failing chaos seed replayable)
    let run = |seed: u64| {
        let mut e = pressured_engine(seed);
        let trace: Vec<TraceRequest> = chaos_trace(seed, 12, 48, 16)
            .into_iter()
            .map(|mut t| {
                t.cancel_after = None; // pure decode determinism
                t
            })
            .collect();
        let (mut out, refused, _) = drive(&mut e, trace);
        assert!(refused.is_empty(), "nothing should be refused unfaulted");
        assert!(e.fault_injector().fired().is_empty(), "disabled injector must not tally");
        out.sort_by_key(|c| c.id);
        out.iter().map(|c| c.tokens.clone()).collect::<Vec<_>>()
    };
    let seed = base_seed();
    assert_eq!(run(seed), run(seed));
}

/// Deterministic synthetic prompt in-vocab for [`tiny_cfg`] (vocab 512).
fn cut_prompt(seed: u64, len: usize) -> Vec<u16> {
    (0..len)
        .map(|i| (((seed as usize).wrapping_mul(131) + i * 7) % 500 + 5) as u16)
        .collect()
}

#[test]
fn mid_prefill_cuts_release_partial_pages_under_faults() {
    // A sequence cut between chunks — client cancel or blown deadline —
    // must release every partial pool page immediately, with the
    // injector firing around it. Prompts are long relative to the chunk
    // size and round budget, so after one step every admitted sequence
    // is still mid-prefill; the cuts all land on live-but-not-yet-
    // decodable state.
    let seed = base_seed().wrapping_mul(17).wrapping_add(3);
    let mut e = pressured_engine(seed);
    e.set_fault_injector(Injector::parse(SPEC, seed).unwrap());

    let n = 10u64;
    let mut refused = Vec::new();
    for i in 0..n {
        let mut r = Request::new(i, cut_prompt(seed.wrapping_add(i), 96), 8);
        if i % 2 == 0 {
            // expires long before a 96-token prompt can clear 16-token
            // chunks under a 32-token round budget
            r.deadline_ms = Some(5);
        }
        match e.submit_full(r) {
            SubmitOutcome::Queued => {}
            SubmitOutcome::Rejected | SubmitOutcome::Shed { .. } => refused.push(i),
        }
    }

    // one step admits the head of the queue and feeds first chunks
    if let Err(err) = e.step() {
        e.fail_inflight(&err.to_string());
    }
    let mut out: Vec<Completion> = e.take_completions();

    // every odd id hangs up — queued or mid-prefill, the pages (and the
    // accounting) must be back before the next step runs
    for i in (1..n).step_by(2) {
        let _ = e.cancel(i);
        assert_eq!(
            e.pool_stats().live_bytes,
            e.measured_live_bytes(),
            "accounting diverged right after cancelling {i}"
        );
    }
    out.extend(e.take_completions());

    // ...and the even cohort blows through its 5 ms deadline
    std::thread::sleep(Duration::from_millis(10));
    let mut steps = 0usize;
    while !e.idle() {
        if let Err(err) = e.step() {
            e.fail_inflight(&err.to_string());
        }
        assert_eq!(
            e.pool_stats().live_bytes,
            e.measured_live_bytes(),
            "pool accounting diverged at step {steps}"
        );
        out.extend(e.take_completions());
        steps += 1;
        assert!(steps < 20_000, "engine failed to quiesce after mid-prefill cuts");
    }
    out.extend(e.take_completions());

    assert_exactly_once(n as usize, &out, &refused, "mid-prefill cuts");
    assert_eq!(e.pool_stats().live_bytes, 0, "cut sequences left pages live");
    for c in &out {
        assert!(
            c.tokens.is_empty(),
            "id {} was cut pre-decode but carries tokens {:?}",
            c.id,
            c.tokens
        );
        assert_eq!(c.decode_ms, 0.0, "id {} never started decoding", c.id);
    }
    let timeouts = out.iter().filter(|c| c.finish == FinishReason::Timeout).count();
    let cancels = out.iter().filter(|c| c.finish == FinishReason::Cancelled).count();
    assert!(timeouts >= 1, "no deadline cut landed mid-prefill");
    assert!(cancels >= 1, "no cancel cut landed mid-prefill");
}

#[test]
fn monster_prompt_under_faults_answers_exactly_once_and_replays() {
    // The issue's starvation scenario with the injector armed on top:
    // one 256-token monster prefilling in 16-token chunks for many
    // rounds while 16 shorts churn around it under pool pressure.
    // Whatever fires, every request answers exactly once, accounting
    // stays exact at every step, and — because the trace and the
    // injector are both seed-deterministic — the whole run replays
    // bit-identically, which is what makes a failing chaos seed
    // debuggable.
    let run = |seed: u64| -> Vec<(u64, String, Vec<u16>)> {
        let mut e = monster_engine(seed);
        e.set_fault_injector(Injector::parse(SPEC, seed).unwrap());
        let trace = bursty_monster_trace(seed, 256, 16, 24, 4);
        let n = trace.len();
        let (out, refused, _) = drive(&mut e, trace);
        assert_exactly_once(n, &out, &refused, &format!("monster seed {seed}"));
        assert_eq!(e.active_count(), 0, "sequences left active");
        assert_eq!(e.queued_count(), 0, "requests left queued");
        assert_eq!(e.pool_stats().live_bytes, 0, "pages left live after quiescence");
        let mut key: Vec<(u64, String, Vec<u16>)> = out
            .iter()
            .map(|c| (c.id, format!("{:?}", c.finish), c.tokens.clone()))
            .collect();
        key.sort();
        key
    };
    let seed = base_seed().wrapping_mul(13).wrapping_add(1);
    assert_eq!(
        run(seed),
        run(seed),
        "armed chaos run must replay identically under a pinned seed"
    );
}
