// Shared lint policy with the library crate (rust/src/lib.rs): these
// allows cover numeric-harness idioms (indexed loops, config structs
// mutated after Default::default(), positional format args).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

//! End-to-end TCP server tests: bind an ephemeral port, drive
//! pipelined and concurrent connections through `serve_listener`, and
//! assert completions route back to the right connection — including
//! the cancellation paths (explicit `{"cancel": id}` lines, dropped
//! connections freeing pool pages, cancel racing completion) and the
//! engine-failure path (waiters get an error finish, never a hang).
//! Every stream carries a read timeout so a hung-waiter regression
//! fails fast instead of wedging the job (CI additionally wraps this
//! test binary in a hard `timeout`).

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Duration;

use mustafar::config::{Backend, EngineConfig, ModelConfig, ServerConfig, SparsityConfig};
use mustafar::coordinator::Engine;
use mustafar::fmt::Json;
use mustafar::model::{NativeModel, Weights};
use mustafar::server;

fn tiny_engine_with_backend(backend: Backend) -> Engine {
    let cfg = ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        ff: 128,
        vocab: 512,
        rope_theta: 10000.0,
        max_seq: 512,
        norm_eps: 1e-5,
    };
    let mut ec = EngineConfig::default();
    ec.backend = backend;
    ec.sparsity = SparsityConfig::mustafar(0.5, 0.5);
    ec.max_batch = 4;
    // several tests rely on deliberately huge generation lengths (5000,
    // 1000) staying in flight long enough to cancel/disconnect; the
    // submit-time clamp must not shorten them
    ec.max_new_tokens = 8192;
    Engine::new_native(NativeModel::new(Weights::random_for_tests(cfg, 7)), ec)
}

fn tiny_engine() -> Engine {
    tiny_engine_with_backend(Backend::NativeSparse)
}

/// Spawn the server on an ephemeral listener, return the address.
fn spawn_server_with(engine: Engine) -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        let _ = server::serve_listener(engine, listener);
    });
    addr
}

/// Bind 127.0.0.1:0, spawn the server on the ephemeral listener, return
/// the address to connect to.
fn spawn_server() -> std::net::SocketAddr {
    spawn_server_with(tiny_engine())
}

/// Spawn the server with explicit limits, returning the address, the
/// shutdown handle, and a channel that fires when `serve_listener_cfg`
/// returns (drain tests bound quiescence on it).
fn spawn_server_cfg(
    engine: Engine,
    cfg: ServerConfig,
) -> (std::net::SocketAddr, server::ShutdownHandle, mpsc::Receiver<()>) {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().unwrap();
    let shutdown = server::ShutdownHandle::new();
    let handle = shutdown.clone();
    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = server::serve_listener_cfg(engine, listener, cfg, handle);
        let _ = done_tx.send(());
    });
    (addr, shutdown, done_rx)
}

/// Connect with the anti-wedge read timeout applied.
fn connect(addr: std::net::SocketAddr) -> TcpStream {
    let stream = TcpStream::connect(addr).expect("connect");
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    stream
}

/// Read one line and parse it (panics — failing the test — on timeout).
fn read_json(reader: &mut BufReader<TcpStream>) -> Json {
    let mut line = String::new();
    reader.read_line(&mut line).expect("response before read timeout");
    Json::parse(&line).unwrap_or_else(|e| panic!("malformed response line {line:?}: {e}"))
}

fn req_line(id: u64, prompt_len: usize, gen: usize) -> String {
    let prompt: Vec<String> =
        (0..prompt_len).map(|j| ((id as usize * 37 + j) % 400 + 16).to_string()).collect();
    format!(
        "{{\"id\": {id}, \"prompt\": [{}], \"max_new_tokens\": {gen}}}",
        prompt.join(", ")
    )
}

#[test]
fn pipelined_requests_on_one_connection_route_by_id() {
    let addr = spawn_server();
    let mut stream = connect(addr);
    // write three requests back-to-back before reading anything
    for id in [10u64, 11, 12] {
        writeln!(stream, "{}", req_line(id, 48, 4)).unwrap();
    }
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut seen = HashSet::new();
    for _ in 0..3 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        let id = v.get("id").unwrap().as_usize().unwrap() as u64;
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 4, "id {id}");
        assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "length");
        assert!(v.get("queue_ms").unwrap().as_f64().unwrap() >= 0.0);
        seen.insert(id);
    }
    assert_eq!(seen, HashSet::from([10, 11, 12]), "a completion was lost or misrouted");
}

#[test]
fn concurrent_connections_each_get_only_their_completions() {
    let addr = spawn_server();
    let mut handles = Vec::new();
    for conn in 0..3u64 {
        handles.push(std::thread::spawn(move || {
            let mut stream = connect(addr);
            let ids: Vec<u64> = (0..3).map(|k| 100 + conn * 10 + k).collect();
            for &id in &ids {
                writeln!(stream, "{}", req_line(id, 40, 3)).unwrap();
            }
            let mut reader = BufReader::new(stream);
            let mut got = HashSet::new();
            for _ in 0..ids.len() {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let v = Json::parse(&line).unwrap();
                got.insert(v.get("id").unwrap().as_usize().unwrap() as u64);
            }
            (ids.into_iter().collect::<HashSet<u64>>(), got)
        }));
    }
    for h in handles {
        let (want, got) = h.join().unwrap();
        assert_eq!(want, got, "a connection received someone else's completion");
    }
}

#[test]
fn stats_and_error_lines_interleave_with_completions() {
    let addr = spawn_server();
    let mut stream = connect(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();

    // malformed request: error object, not a hang
    writeln!(stream, "not json").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");

    // a real request...
    writeln!(stream, "{}", req_line(1, 160, 4)).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(Json::parse(&line).unwrap().get("id").unwrap().as_usize().unwrap(), 1);

    // ...then the same prompt again: the prefix cache serves it, and the
    // stats endpoint reports the hit and live pool bytes
    writeln!(stream, "{}", req_line(1, 160, 4)).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();

    writeln!(stream, "{{\"stats\": true}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert_eq!(v.get("completions").unwrap().as_usize().unwrap(), 2);
    assert_eq!(v.get("prefix_full_hits").unwrap().as_usize().unwrap(), 1);
    assert!(v.get("pool_live_bytes").unwrap().as_f64().unwrap() > 0.0);
    assert!(v.get("prefix_hit_rate").unwrap().as_f64().unwrap() > 0.0);
    // the robustness counters parse back and are quiet on a healthy run
    for key in ["shed", "timed_out_queued", "deadline_exceeded", "isolated_panics"] {
        assert_eq!(v.get(key).unwrap().as_usize().unwrap(), 0, "{key} on a clean run");
    }
    assert!(v.get("queue_depth_ms_estimate").unwrap().as_f64().unwrap() >= 0.0);

    // duplicate in-flight id: error line instead of a clobbered waiter
    writeln!(stream, "{}", req_line(500, 400, 64)).unwrap();
    writeln!(stream, "{}", req_line(500, 8, 1)).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let first = line.clone();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let both = format!("{first}{line}");
    assert!(both.contains("duplicate"), "expected a duplicate-id error, got: {both}");
}

#[test]
fn explicit_cancel_yields_cancelled_finish_line() {
    let addr = spawn_server();
    let mut stream = connect(addr);
    // A long-running request, then an explicit cancel line behind it.
    // Generation length is deliberately huge (seconds of decode on the
    // tiny model) so the cancel always lands while the request is in
    // flight, even with the reader thread preempted on a loaded runner
    // — the cancel stops it long before the length limit.
    writeln!(stream, "{}", req_line(1, 48, 5000)).unwrap();
    writeln!(stream, "{{\"cancel\": 1}}").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let v = read_json(&mut reader);
    assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "cancelled");
    assert!(
        v.get("tokens").unwrap().as_arr().unwrap().len() < 5000,
        "a cancelled request must not decode to completion"
    );
    // the connection (and the id) keep working after a cancel
    writeln!(stream, "{}", req_line(1, 32, 3)).unwrap();
    let v = read_json(&mut reader);
    assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "length");
    assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 3);
}

#[test]
fn dropped_connection_frees_pool_pages() {
    let addr = spawn_server();
    let probe = connect(addr); // stats side-channel on its own conn
    let mut probe_w = probe.try_clone().unwrap();
    let mut probe_r = BufReader::new(probe);
    let mut stats = move || -> Json {
        writeln!(probe_w, "{{\"stats\": true}}").unwrap();
        read_json(&mut probe_r)
    };

    let mut victim = connect(addr);
    for id in 0..2u64 {
        writeln!(victim, "{}", req_line(100 + id, 64, 1000)).unwrap();
    }
    // wait until both sequences are decoding and holding pool pages
    let mut live_before = 0.0;
    for i in 0.. {
        let v = stats();
        if v.get("active").unwrap().as_usize().unwrap() == 2 {
            live_before = v.get("pool_live_bytes").unwrap().as_f64().unwrap();
            break;
        }
        assert!(i < 3000, "requests never became active");
        std::thread::sleep(Duration::from_millis(10));
    }
    assert!(live_before > 0.0);

    // the client vanishes mid-decode: the reader sees EOF and aborts
    // everything the connection had in flight
    drop(victim);
    for i in 0.. {
        let v = stats();
        if v.get("cancelled").unwrap().as_usize().unwrap() == 2 {
            assert_eq!(v.get("active").unwrap().as_usize().unwrap(), 0);
            assert_eq!(v.get("completions").unwrap().as_usize().unwrap(), 0);
            assert!(v.get("cancelled_freed_bytes").unwrap().as_f64().unwrap() > 0.0);
            let live = v.get("pool_live_bytes").unwrap().as_f64().unwrap();
            assert!(
                live < live_before,
                "disconnect must free the sequences' pages ({live} vs {live_before})"
            );
            break;
        }
        assert!(i < 3000, "disconnect never cancelled the in-flight work");
        std::thread::sleep(Duration::from_millis(10));
    }
}

#[test]
fn cancel_racing_completion_is_answered_exactly_once() {
    let addr = spawn_server();
    let mut stream = connect(addr);
    // a tiny request that may well complete before the cancel lands:
    // whichever side wins, exactly one line answers id 7
    writeln!(stream, "{}", req_line(7, 16, 1)).unwrap();
    writeln!(stream, "{{\"cancel\": 7}}").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let v = read_json(&mut reader);
    assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 7);
    let finish = v.get("finish").unwrap().as_str().unwrap().to_string();
    assert!(finish == "length" || finish == "cancelled", "unexpected finish {finish}");
    // no stray second answer: the next line on the wire belongs to the
    // next request
    writeln!(stream, "{}", req_line(8, 16, 2)).unwrap();
    let v = read_json(&mut reader);
    assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 8, "duplicate answer for id 7");
}

#[test]
fn same_request_id_on_two_connections_does_not_collide() {
    let addr = spawn_server();
    let mut a = connect(addr);
    let mut b = connect(addr);
    // both connections use id 5; distinct generation lengths prove the
    // completions route back to their own socket
    writeln!(a, "{}", req_line(5, 40, 3)).unwrap();
    writeln!(b, "{}", req_line(5, 40, 6)).unwrap();
    let mut ra = BufReader::new(a.try_clone().unwrap());
    let mut rb = BufReader::new(b.try_clone().unwrap());
    let va = read_json(&mut ra);
    let vb = read_json(&mut rb);
    assert_eq!(va.get("id").unwrap().as_usize().unwrap(), 5);
    assert_eq!(va.get("finish").unwrap().as_str().unwrap(), "length");
    assert_eq!(va.get("tokens").unwrap().as_arr().unwrap().len(), 3, "conn A got B's answer");
    assert_eq!(vb.get("id").unwrap().as_usize().unwrap(), 5);
    assert_eq!(vb.get("tokens").unwrap().as_arr().unwrap().len(), 6, "conn B got A's answer");
}

#[test]
fn engine_step_failure_fails_inflight_requests_with_error_finish() {
    // A PJRT backend selected but never constructed makes the first
    // admission error out of step(). Every waiter must get an "error"
    // finish line — previously the engine thread just eprintln!'d and
    // looped, leaving the clients blocked on read_line forever.
    let addr = spawn_server_with(tiny_engine_with_backend(Backend::PjrtSparse));
    let mut stream = connect(addr);
    writeln!(stream, "{}", req_line(1, 32, 4)).unwrap();
    writeln!(stream, "{}", req_line(2, 32, 4)).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut ids = HashSet::new();
    for _ in 0..2 {
        let v = read_json(&mut reader);
        assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "error");
        assert!(
            v.get("error").unwrap().as_str().unwrap().contains("pjrt"),
            "error line should carry the engine message"
        );
        ids.insert(v.get("id").unwrap().as_usize().unwrap() as u64);
    }
    assert_eq!(ids, HashSet::from([1, 2]));
}

#[test]
fn malformed_lines_get_json_safe_error_responses() {
    // `{"id" "x"}` produces a parse error whose message contains a `"`
    // — raw interpolation used to emit a malformed error line; every
    // error response must parse back as JSON
    let addr = spawn_server();
    let mut stream = connect(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "{{\"id\" \"x\"}}").unwrap();
    let v = read_json(&mut reader);
    let msg = v.get("error").unwrap().as_str().unwrap();
    assert!(msg.contains('"'), "this probe needs a quote-bearing message, got {msg:?}");

    // a well-formed line that fails request validation also answers
    // with a parseable error object
    writeln!(stream, "{{\"id\": 1, \"prompt\": \"nope\", \"max_new_tokens\": 1}}").unwrap();
    let v = read_json(&mut reader);
    assert!(!v.get("error").unwrap().as_str().unwrap().is_empty());

    // a cancel line with a non-numeric id is answered as a malformed
    // cancel, not misreported as a request missing prompt/id fields
    writeln!(stream, "{{\"cancel\": \"7\"}}").unwrap();
    let v = read_json(&mut reader);
    assert!(
        v.get("error").unwrap().as_str().unwrap().contains("cancel"),
        "malformed cancel should say so"
    );

    // an out-of-vocab token id (vocab is 512 here) must be rejected at
    // the engine boundary, not panic the engine thread mid-forward and
    // hang every waiter forever
    writeln!(stream, "{{\"id\": 3, \"prompt\": [65535], \"max_new_tokens\": 1}}").unwrap();
    let v = read_json(&mut reader);
    assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "rejected");

    // same class: an empty prompt would panic prefill's slicing
    writeln!(stream, "{{\"id\": 5, \"prompt\": [], \"max_new_tokens\": 1}}").unwrap();
    let v = read_json(&mut reader);
    assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "rejected");

    // ... and the server is still alive for well-formed work
    writeln!(stream, "{}", req_line(4, 16, 2)).unwrap();
    let v = read_json(&mut reader);
    assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 4);
    assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "length");

    // a request carrying a stray "cancel" field is still a request —
    // submitted and answered, not swallowed as a cancel message
    writeln!(stream, "{{\"id\": 9, \"prompt\": [5, 6], \"max_new_tokens\": 1, \"cancel\": 0}}")
        .unwrap();
    let v = read_json(&mut reader);
    assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 9);
    assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "length");
}

#[test]
fn oversized_line_gets_one_error_and_the_connection_survives() {
    let mut cfg = ServerConfig::default();
    cfg.max_line_bytes = 4096;
    let (addr, _shutdown, _done) = spawn_server_cfg(tiny_engine(), cfg);
    let mut stream = connect(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());

    // 12 KiB of unterminated junk: more than one 8 KiB read chunk, so
    // the bound trips on a partial line no matter how the reads batch
    let junk = [b'x'; 12288];
    stream.write_all(&junk).unwrap();
    stream.write_all(b"\n").unwrap();
    let v = read_json(&mut reader);
    assert!(
        v.get("error").unwrap().as_str().unwrap().contains("max_line_bytes"),
        "oversize reply should name the bound"
    );

    // same connection, normal request: the line was dropped, not the conn
    writeln!(stream, "{}", req_line(1, 16, 2)).unwrap();
    let v = read_json(&mut reader);
    assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 1);
    assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "length");

    writeln!(stream, "{{\"stats\": true}}").unwrap();
    let v = read_json(&mut reader);
    assert!(v.get("oversize_lines").unwrap().as_usize().unwrap() >= 1);
}

#[test]
fn slowloris_partial_line_is_cut_at_the_read_deadline() {
    let mut cfg = ServerConfig::default();
    cfg.read_deadline_ms = 400;
    let (addr, _shutdown, _done) = spawn_server_cfg(tiny_engine(), cfg);

    let slow = connect(addr);
    let mut slow_r = BufReader::new(slow.try_clone().unwrap());
    // dribble bytes of one never-terminated line: each write is fresh
    // socket activity, but the deadline is keyed to the line's first
    // byte, so activity alone must not keep the connection alive
    let mut slow_w = slow.try_clone().unwrap();
    let dribbler = std::thread::spawn(move || {
        for _ in 0..200 {
            if slow_w.write_all(b"\"").is_err() {
                return; // server already cut us
            }
            std::thread::sleep(Duration::from_millis(25));
        }
    });

    // a well-behaved client on another connection is unaffected
    let fast = std::thread::spawn(move || {
        let mut s = connect(addr);
        let mut r = BufReader::new(s.try_clone().unwrap());
        writeln!(s, "{}", req_line(1, 32, 2)).unwrap();
        let v = read_json(&mut r);
        assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "length");
    });

    let mut line = String::new();
    match slow_r.read_line(&mut line) {
        Ok(0) | Err(_) => {} // clean EOF, or RST from writing past the close
        Ok(n) => panic!("server should cut the slowloris, got {n} bytes: {line:?}"),
    }
    dribbler.join().unwrap();
    fast.join().unwrap();

    let mut probe = connect(addr);
    let mut pr = BufReader::new(probe.try_clone().unwrap());
    writeln!(probe, "{{\"stats\": true}}").unwrap();
    let v = read_json(&mut pr);
    assert!(v.get("read_deadline_closes").unwrap().as_usize().unwrap() >= 1);
}

/// Linux-gated: pins kernel socket buffers so the write path backs up
/// deterministically instead of vanishing into loopback autotuning.
#[cfg(target_os = "linux")]
#[test]
fn stalled_reader_is_cut_at_the_write_high_water_mark() {
    let mut cfg = ServerConfig::default();
    cfg.write_hwm_bytes = 16 * 1024;
    cfg.sock_sndbuf_bytes = 8 * 1024;
    let (addr, _shutdown, _done) = spawn_server_cfg(tiny_engine(), cfg);

    // the staller: a small receive window, a pile of long completions
    // headed its way, and it never reads a byte
    let staller = connect(addr);
    server::set_stream_buffers(&staller, None, Some(4096)).unwrap();
    let mut sw = staller.try_clone().unwrap();
    for id in 0..24u64 {
        writeln!(sw, "{}", req_line(id, 32, 512)).unwrap();
    }

    // a fast client shares the server: its small requests complete even
    // while the staller's replies back up (FIFO admission means it
    // waits its turn in the queue, but never on the stalled socket)
    let t0 = std::time::Instant::now();
    let mut fastc = connect(addr);
    let mut fr = BufReader::new(fastc.try_clone().unwrap());
    for id in 100..104u64 {
        writeln!(fastc, "{}", req_line(id, 24, 2)).unwrap();
        let v = read_json(&mut fr);
        assert_eq!(v.get("id").unwrap().as_usize().unwrap() as u64, id);
        assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "length");
    }
    assert!(t0.elapsed() < Duration::from_secs(60), "fast client starved by stalled reader");

    // the staller eventually trips the high-water mark and is torn down
    for i in 0.. {
        writeln!(fastc, "{{\"stats\": true}}").unwrap();
        let v = read_json(&mut fr);
        if v.get("write_backpressure_closes").unwrap().as_usize().unwrap() >= 1 {
            break;
        }
        assert!(i < 3000, "staller never hit the write high-water mark");
        std::thread::sleep(Duration::from_millis(10));
    }
    drop(staller);
}

#[test]
fn graceful_drain_finishes_inflight_sheds_late_and_returns() {
    let mut cfg = ServerConfig::default();
    cfg.drain_deadline_ms = 5000;
    let (addr, shutdown, done_rx) = spawn_server_cfg(tiny_engine(), cfg);

    let mut a = connect(addr);
    let mut ra = BufReader::new(a.try_clone().unwrap());
    // id 1 runs far past the drain window (deadline-cancelled unless
    // the host is fast enough to finish it); id 3 finishes inside it
    writeln!(a, "{}", req_line(1, 48, 8000)).unwrap();
    writeln!(a, "{}", req_line(3, 32, 30)).unwrap();
    // let both reach the engine before draining starts
    std::thread::sleep(Duration::from_millis(300));

    shutdown.shutdown();
    std::thread::sleep(Duration::from_millis(300));
    // a late submit on the surviving connection: shed with a retry hint
    writeln!(a, "{}", req_line(2, 16, 4)).unwrap();

    let mut finishes = std::collections::HashMap::new();
    for _ in 0..3 {
        let v = read_json(&mut ra);
        let id = v.get("id").unwrap().as_usize().unwrap() as u64;
        let f = v.get("finish").unwrap().as_str().unwrap().to_string();
        if f == "shed" {
            assert!(v.get("retry_after_ms").unwrap().as_usize().unwrap() > 0);
        }
        finishes.insert(id, f);
    }
    assert_eq!(finishes.get(&2).map(String::as_str), Some("shed"));
    assert_eq!(finishes.get(&3).map(String::as_str), Some("length"));
    let f1 = finishes.get(&1).map(String::as_str).unwrap();
    assert!(f1 == "timeout" || f1 == "length", "id 1 finished {f1}");

    // once everything it is owed has been flushed, the drained server
    // closes the connection...
    let mut line = String::new();
    match ra.read_line(&mut line) {
        Ok(0) | Err(_) => {}
        Ok(n) => panic!("drained server should close, got {n} bytes: {line:?}"),
    }
    // ...refuses (or sheds) fresh connections...
    match TcpStream::connect(addr) {
        Err(_) => {}
        Ok(s) => {
            s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
            let mut r = BufReader::new(s);
            let mut l = String::new();
            let n = r.read_line(&mut l).unwrap_or(0);
            assert!(n == 0 || l.contains("error"), "unexpected greeting {l:?}");
        }
    }
    // ...and serve_listener_cfg returns within the quiescence bound
    done_rx.recv_timeout(Duration::from_secs(20)).expect("server failed to quiesce");
}

#[test]
fn metrics_trace_and_dump_lines_round_trip() {
    let addr = spawn_server();
    let mut stream = connect(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    // real work first so latency histograms/spans have samples
    for id in [1u64, 2] {
        writeln!(stream, "{}", req_line(id, 32, 4)).unwrap();
    }
    for _ in 0..2 {
        let _ = read_json(&mut reader);
    }

    // the stats line grew the quantile surface
    writeln!(stream, "{{\"stats\": true}}").unwrap();
    let stats = read_json(&mut reader);
    for key in ["ttft_ms", "inter_token_ms", "queue_wait_ms"] {
        let p50 = stats.get(&format!("{key}_p50")).unwrap().as_f64().unwrap();
        let p99 = stats.get(&format!("{key}_p99")).unwrap().as_f64().unwrap();
        let p999 = stats.get(&format!("{key}_p999")).unwrap().as_f64().unwrap();
        assert!(p50 <= p99 && p99 <= p999, "{key} quantiles not monotone");
    }
    assert!(stats.get("ttft_ms_p50").unwrap().as_f64().unwrap() > 0.0);
    assert!(stats.get("queue_peak_pending").unwrap().as_usize().unwrap() >= 1);
    // the deferred-compression scalars parse back as numbers (this tiny
    // workload never exits a group, so they are present-but-zero here;
    // the engine tests drive them nonzero)
    for key in ["compress_jobs", "compress_stalls", "compress_backlog"] {
        assert!(
            stats.get(key).unwrap().as_f64().unwrap() >= 0.0,
            "stats key {key} missing or non-numeric"
        );
    }

    // metrics-scrape smoke: every scalar the stats line reports must
    // appear in the Prometheus exposition under the mustafar_ prefix
    // (both render from one stats_scalars() list — this pins it)
    writeln!(stream, "{{\"metrics\": true}}").unwrap();
    let v = read_json(&mut reader);
    let text = v.get("metrics").unwrap().as_str().unwrap().to_string();
    for (key, _) in stats.as_obj().unwrap() {
        assert!(
            text.contains(&format!("mustafar_{key} ")),
            "stats key {key} missing from the metrics exposition"
        );
    }
    assert!(text.contains("mustafar_ttft_us_bucket{le=\"+Inf\"}"), "histograms missing");

    // trace line: valid chrome://tracing JSON, bounded by the argument
    writeln!(stream, "{{\"trace\": 4}}").unwrap();
    let v = read_json(&mut reader);
    let events = v.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!events.is_empty() && events.len() <= 4, "got {} events", events.len());
    assert_eq!(events[0].get("ph").unwrap().as_str().unwrap(), "X");

    // dump line: the flight recorder saw the finishes
    writeln!(stream, "{{\"dump\": true}}").unwrap();
    let v = read_json(&mut reader);
    let kinds: Vec<String> = v
        .get("events")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|e| e.get("kind").unwrap().as_str().unwrap().to_string())
        .collect();
    assert!(kinds.iter().any(|k| k == "finish"), "no finish events in {kinds:?}");

    // the three telemetry queries count themselves
    writeln!(stream, "{{\"stats\": true}}").unwrap();
    let stats = read_json(&mut reader);
    assert_eq!(stats.get("trace_queries").unwrap().as_usize().unwrap(), 1);
    assert_eq!(stats.get("dump_queries").unwrap().as_usize().unwrap(), 1);
    assert!(stats.get("metrics_queries").unwrap().as_usize().unwrap() >= 1);
}

#[test]
fn metrics_addr_listener_serves_http_scrapes() {
    let mut cfg = ServerConfig::default();
    let scrape_listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let scrape_addr = scrape_listener.local_addr().unwrap();
    drop(scrape_listener); // rebind inside the server (racy but local-only)
    cfg.metrics_addr = Some(scrape_addr.to_string());
    let (addr, shutdown, done_rx) = spawn_server_cfg(tiny_engine(), cfg);

    let mut stream = connect(addr);
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    writeln!(stream, "{}", req_line(1, 32, 3)).unwrap();
    let _ = read_json(&mut reader);

    // plain HTTP GET against the scrape port
    let mut scrape = None;
    for i in 0.. {
        match TcpStream::connect(scrape_addr) {
            Ok(s) => {
                scrape = Some(s);
                break;
            }
            Err(_) if i < 100 => std::thread::sleep(Duration::from_millis(20)),
            Err(e) => panic!("scrape listener never came up: {e}"),
        }
    }
    let mut scrape = scrape.unwrap();
    scrape.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    scrape.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut body = String::new();
    use std::io::Read as _;
    scrape.read_to_string(&mut body).unwrap();
    assert!(body.starts_with("HTTP/1.0 200 OK"), "bad scrape response: {body:.80}");
    assert!(body.contains("text/plain; version=0.0.4"));
    assert!(body.contains("mustafar_completions 1"));
    assert!(body.contains("mustafar_ttft_us_count 1"));
    drop(scrape);

    shutdown.shutdown();
    drop(stream);
    drop(reader);
    done_rx.recv_timeout(Duration::from_secs(20)).expect("server failed to quiesce");
}

#[test]
fn connection_cap_sheds_excess_with_retry_hint() {
    let mut cfg = ServerConfig::default();
    cfg.max_conns = 2;
    let (addr, _shutdown, _done) = spawn_server_cfg(tiny_engine(), cfg);
    let a = connect(addr);
    let b = connect(addr);
    // both slots held: the third connection gets one shed line, then EOF
    let c = connect(addr);
    let mut rc = BufReader::new(c);
    let v = read_json(&mut rc);
    assert!(v.get("error").unwrap().as_str().unwrap().contains("capacity"));
    assert!(v.get("retry_after_ms").unwrap().as_usize().unwrap() > 0);
    let mut line = String::new();
    match rc.read_line(&mut line) {
        Ok(0) | Err(_) => {}
        Ok(n) => panic!("shed conn should close, got {n} bytes: {line:?}"),
    }

    // the held connections still work, and the gauges say so
    let mut aw = a.try_clone().unwrap();
    let mut ar = BufReader::new(a);
    writeln!(aw, "{{\"stats\": true}}").unwrap();
    let v = read_json(&mut ar);
    assert_eq!(v.get("open_conns").unwrap().as_usize().unwrap(), 2);
    assert_eq!(v.get("conns_shed").unwrap().as_usize().unwrap(), 1);
    drop(b);
}
