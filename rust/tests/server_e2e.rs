// Shared lint policy with the library crate (rust/src/lib.rs): these
// allows cover numeric-harness idioms (indexed loops, config structs
// mutated after Default::default(), positional format args).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

//! End-to-end TCP server tests (satellite of the kvpool PR): bind an
//! ephemeral port, drive pipelined and concurrent connections through
//! `serve_listener`, and assert completions route back to the right
//! connection. The older tests only covered parse/render.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};

use mustafar::config::{Backend, EngineConfig, ModelConfig, SparsityConfig};
use mustafar::coordinator::Engine;
use mustafar::fmt::Json;
use mustafar::model::{NativeModel, Weights};
use mustafar::server;

fn tiny_engine() -> Engine {
    let cfg = ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        ff: 128,
        vocab: 512,
        rope_theta: 10000.0,
        max_seq: 512,
        norm_eps: 1e-5,
    };
    let mut ec = EngineConfig::default();
    ec.backend = Backend::NativeSparse;
    ec.sparsity = SparsityConfig::mustafar(0.5, 0.5);
    ec.max_batch = 4;
    Engine::new_native(NativeModel::new(Weights::random_for_tests(cfg, 7)), ec)
}

/// Bind 127.0.0.1:0, spawn the server on the ephemeral listener, return
/// the address to connect to.
fn spawn_server() -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().unwrap();
    let engine = tiny_engine();
    std::thread::spawn(move || {
        let _ = server::serve_listener(engine, listener);
    });
    addr
}

fn req_line(id: u64, prompt_len: usize, gen: usize) -> String {
    let prompt: Vec<String> =
        (0..prompt_len).map(|j| ((id as usize * 37 + j) % 400 + 16).to_string()).collect();
    format!(
        "{{\"id\": {id}, \"prompt\": [{}], \"max_new_tokens\": {gen}}}",
        prompt.join(", ")
    )
}

#[test]
fn pipelined_requests_on_one_connection_route_by_id() {
    let addr = spawn_server();
    let mut stream = TcpStream::connect(addr).expect("connect");
    // write three requests back-to-back before reading anything
    for id in [10u64, 11, 12] {
        writeln!(stream, "{}", req_line(id, 48, 4)).unwrap();
    }
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut seen = HashSet::new();
    for _ in 0..3 {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let v = Json::parse(&line).unwrap();
        let id = v.get("id").unwrap().as_usize().unwrap() as u64;
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 4, "id {id}");
        assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "length");
        assert!(v.get("queue_ms").unwrap().as_f64().unwrap() >= 0.0);
        seen.insert(id);
    }
    assert_eq!(seen, HashSet::from([10, 11, 12]), "a completion was lost or misrouted");
}

#[test]
fn concurrent_connections_each_get_only_their_completions() {
    let addr = spawn_server();
    let mut handles = Vec::new();
    for conn in 0..3u64 {
        handles.push(std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).expect("connect");
            let ids: Vec<u64> = (0..3).map(|k| 100 + conn * 10 + k).collect();
            for &id in &ids {
                writeln!(stream, "{}", req_line(id, 40, 3)).unwrap();
            }
            let mut reader = BufReader::new(stream);
            let mut got = HashSet::new();
            for _ in 0..ids.len() {
                let mut line = String::new();
                reader.read_line(&mut line).unwrap();
                let v = Json::parse(&line).unwrap();
                got.insert(v.get("id").unwrap().as_usize().unwrap() as u64);
            }
            (ids.into_iter().collect::<HashSet<u64>>(), got)
        }));
    }
    for h in handles {
        let (want, got) = h.join().unwrap();
        assert_eq!(want, got, "a connection received someone else's completion");
    }
}

#[test]
fn stats_and_error_lines_interleave_with_completions() {
    let addr = spawn_server();
    let mut stream = TcpStream::connect(addr).expect("connect");
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut line = String::new();

    // malformed request: error object, not a hang
    writeln!(stream, "not json").unwrap();
    reader.read_line(&mut line).unwrap();
    assert!(line.contains("error"), "{line}");

    // a real request...
    writeln!(stream, "{}", req_line(1, 160, 4)).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    assert_eq!(Json::parse(&line).unwrap().get("id").unwrap().as_usize().unwrap(), 1);

    // ...then the same prompt again: the prefix cache serves it, and the
    // stats endpoint reports the hit and live pool bytes
    writeln!(stream, "{}", req_line(1, 160, 4)).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();

    writeln!(stream, "{{\"stats\": true}}").unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let v = Json::parse(&line).unwrap();
    assert_eq!(v.get("completions").unwrap().as_usize().unwrap(), 2);
    assert_eq!(v.get("prefix_full_hits").unwrap().as_usize().unwrap(), 1);
    assert!(v.get("pool_live_bytes").unwrap().as_f64().unwrap() > 0.0);
    assert!(v.get("prefix_hit_rate").unwrap().as_f64().unwrap() > 0.0);

    // duplicate in-flight id: error line instead of a clobbered waiter
    writeln!(stream, "{}", req_line(500, 400, 64)).unwrap();
    writeln!(stream, "{}", req_line(500, 8, 1)).unwrap();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let first = line.clone();
    line.clear();
    reader.read_line(&mut line).unwrap();
    let both = format!("{first}{line}");
    assert!(both.contains("duplicate"), "expected a duplicate-id error, got: {both}");
}
