// Shared lint policy with the library crate (rust/src/lib.rs): these
// allows cover numeric-harness idioms (indexed loops, config structs
// mutated after Default::default(), positional format args).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

//! Socket-level chaos acceptance: drive the real TCP reactor with a
//! storm of pipelined connections while the fault injector breaks
//! reactor reads/writes (`server.io`) *and* the engine underneath it
//! (alloc, worker, prefill, decode). Whatever fires, every client must
//! observe each of its requests answered at most once — a missing
//! answer is legal only on a connection the server visibly cut — and
//! once the storm drains, the kvpool must account to exactly zero live
//! bytes (the prefix cache is off here so nothing is parked on
//! purpose).
//!
//! Deterministic replay: the trace and the injector both derive from
//! `MUSTAFAR_FAULT_SEED` (default 20260807); `MUSTAFAR_FAULTS`
//! overrides the armed spec.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Duration;

use mustafar::config::{Backend, EngineConfig, ModelConfig, ServerConfig, SparsityConfig};
use mustafar::coordinator::Engine;
use mustafar::faults::Injector;
use mustafar::fmt::Json;
use mustafar::model::{NativeModel, Weights};
use mustafar::server;
use mustafar::workload::trace::{storm_trace, TraceRequest};

const CONNS: usize = 24;
const PER_CONN: usize = 8;

/// Every fault point between the socket and the decode kernels, armed
/// with low per-call probabilities so runs mix clean completions,
/// engine-side failures, and reactor-side connection cuts.
const SPEC: &str = "server.io:0.05,kvpool.alloc:0.02,worker.task:0.01,\
                    seq.decode:0.02,seq.prefill:0.02";

fn base_seed() -> u64 {
    std::env::var("MUSTAFAR_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(20260807)
}

fn spec() -> String {
    std::env::var("MUSTAFAR_FAULTS").unwrap_or_else(|_| SPEC.to_string())
}

fn chaos_engine(seed: u64) -> Engine {
    let cfg = ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        ff: 128,
        vocab: 512,
        rope_theta: 10000.0,
        max_seq: 512,
        norm_eps: 1e-5,
    };
    let mut ec = EngineConfig::default();
    ec.backend = Backend::NativeSparse;
    ec.sparsity = SparsityConfig::mustafar(0.5, 0.5);
    ec.max_batch = 4;
    ec.max_new_tokens = 64;
    // The quiescence invariant below is *exactly zero* live pool
    // bytes; the prefix cache parks bytes by design, so it stays off.
    ec.prefix_cache = false;
    let mut e = Engine::new_native(NativeModel::new(Weights::random_for_tests(cfg, seed)), ec);
    e.set_fault_injector(Injector::parse(&spec(), seed).unwrap());
    e
}

fn req_json(r: &TraceRequest) -> String {
    let prompt: Vec<String> = r.prompt.iter().map(|t| t.to_string()).collect();
    format!(
        "{{\"id\": {}, \"prompt\": [{}], \"max_new_tokens\": {}}}",
        r.id,
        prompt.join(", "),
        r.max_new_tokens
    )
}

/// One client connection: pipeline its trace slice, then read until
/// every id is answered or the server cuts the socket. Returns
/// (answered ids, whether the connection was cut).
fn drive_conn(addr: std::net::SocketAddr, slice: &[TraceRequest]) -> (HashSet<u64>, bool) {
    let want: HashSet<u64> = slice.iter().map(|r| r.id).collect();
    let stream = match TcpStream::connect(addr) {
        Ok(s) => s,
        Err(_) => return (HashSet::new(), true),
    };
    stream.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
    let mut w = stream.try_clone().unwrap();
    let mut cut = false;
    for r in slice {
        if writeln!(w, "{}", req_json(r)).is_err() {
            cut = true; // server.io killed us before the pipeline landed
            break;
        }
    }
    let mut reader = BufReader::new(stream);
    let mut got = HashSet::new();
    while got.len() < want.len() {
        let mut line = String::new();
        match reader.read_line(&mut line) {
            Ok(0) | Err(_) => {
                cut = true;
                break;
            }
            Ok(_) => {}
        }
        let v = Json::parse(&line).unwrap_or_else(|e| panic!("bad line {line:?}: {e}"));
        let Some(id) = v.opt("id").and_then(|x| x.as_usize().ok()) else {
            continue; // id-less error line (never expected here, never fatal)
        };
        let id = id as u64;
        assert!(want.contains(&id), "answer {id} does not belong to this connection");
        assert!(got.insert(id), "request {id} answered twice");
    }
    (got, cut)
}

#[test]
fn server_chaos_exactly_once_or_clean_disconnect() {
    let seed = base_seed();
    let trace = storm_trace(seed, CONNS, PER_CONN, 32, 12);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().unwrap();
    let shutdown = server::ShutdownHandle::new();
    let handle = shutdown.clone();
    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let cfg = ServerConfig::default();
        let _ = server::serve_listener_cfg(chaos_engine(seed), listener, cfg, handle);
        let _ = done_tx.send(());
    });

    let mut clients = Vec::new();
    for c in 0..CONNS {
        let slice: Vec<TraceRequest> = trace[c * PER_CONN..(c + 1) * PER_CONN].to_vec();
        clients.push(std::thread::spawn(move || drive_conn(addr, &slice)));
    }
    let mut answered = 0usize;
    for (c, h) in clients.into_iter().enumerate() {
        let (got, cut) = h.join().unwrap();
        answered += got.len();
        assert!(
            got.len() == PER_CONN || cut,
            "conn {c}: {}/{PER_CONN} answers on a connection the server never cut \
             (seed {seed}; replay with MUSTAFAR_FAULT_SEED={seed})",
            got.len()
        );
    }
    // vacuous-pass guard: the armed probabilities are low enough that
    // plenty of requests must still be answered outright
    assert!(answered > 0, "chaos killed every single request (seed {seed})");

    // Quiescence: with every client gone, the engine must answer or
    // abort everything in flight and the pool must drain to exactly
    // zero live bytes. Probe connections can themselves be chaos-cut,
    // so retry with fresh sockets against a wall-clock bound.
    let deadline = std::time::Instant::now() + Duration::from_secs(60);
    let mut last = String::new();
    loop {
        assert!(
            std::time::Instant::now() < deadline,
            "pool never drained to zero (seed {seed}); last stats: {last}"
        );
        std::thread::sleep(Duration::from_millis(50));
        let Ok(probe) = TcpStream::connect(addr) else { continue };
        probe.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
        let mut pw = probe.try_clone().unwrap();
        if writeln!(pw, "{{\"stats\": true}}").is_err() {
            continue;
        }
        let mut pr = BufReader::new(probe);
        let mut line = String::new();
        match pr.read_line(&mut line) {
            Ok(n) if n > 0 => {}
            _ => continue, // probe conn chaos-cut; try again
        }
        let Ok(v) = Json::parse(&line) else { continue };
        last = line.clone();
        let active = v.get("active").unwrap().as_usize().unwrap();
        let queued = v.get("queued").unwrap().as_usize().unwrap();
        let live = v.get("pool_live_bytes").unwrap().as_f64().unwrap();
        if active == 0 && queued == 0 && live == 0.0 {
            // the reactor-side fault point must actually have been
            // exercised on this pinned seed
            let cuts = v.get("io_fault_closes").unwrap().as_usize().unwrap();
            assert!(cuts >= 1, "server.io never fired (seed {seed}); stats: {line}");
            break;
        }
    }

    // Bounded drain even after a chaotic run: every connection still
    // owed bytes was cut or flushed, and the server thread exits.
    shutdown.shutdown();
    done_rx.recv_timeout(Duration::from_secs(30)).expect("drain after chaos never completed");
}
