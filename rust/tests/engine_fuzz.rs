// Shared lint policy with the library crate (rust/src/lib.rs): these
// allows cover numeric-harness idioms (indexed loops, config structs
// mutated after Default::default(), positional format args).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

//! Property-style fuzz of the coordinator over random traces: whatever
//! the trace shape, no request is lost or duplicated, batch bounds hold,
//! KV accounting is exact, and generation lengths are respected.
//! (proptest is not in the offline vendor set — generators run on the
//! project's deterministic PCG.)

use mustafar::config::{Backend, EngineConfig, ModelConfig, SparsityConfig};
use mustafar::coordinator::{Engine, FinishReason, Request};
use mustafar::model::{NativeModel, Weights};
use mustafar::util::Pcg32;

fn tiny_model(seed: u64) -> NativeModel {
    let cfg = ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        ff: 128,
        vocab: 512,
        rope_theta: 10000.0,
        max_seq: 512,
        norm_eps: 1e-5,
    };
    NativeModel::new(Weights::random_for_tests(cfg, seed))
}

#[test]
fn random_traces_preserve_all_invariants() {
    for case in 0..6u64 {
        let mut rng = Pcg32::seeded(1000 + case);
        let n_reqs = 1 + rng.below(10) as usize;
        let max_batch = 1 + rng.below(5) as usize;
        let sparsity = [0.0, 0.5, 0.7][rng.below(3) as usize];

        let mut ec = EngineConfig::default();
        ec.backend = if sparsity > 0.0 { Backend::NativeSparse } else { Backend::NativeDense };
        ec.sparsity = SparsityConfig::mustafar(sparsity, sparsity);
        ec.max_batch = max_batch;
        let mut engine = Engine::new_native(tiny_model(case), ec);

        let reqs: Vec<Request> = (0..n_reqs as u64)
            .map(|i| {
                let plen = 8 + rng.below(150) as usize;
                let gen = 1 + rng.below(12) as usize;
                let prompt: Vec<u16> =
                    (0..plen).map(|_| 16 + rng.below(400) as u16).collect();
                Request::new(i, prompt, gen)
            })
            .collect();
        let want: Vec<(u64, usize)> =
            reqs.iter().map(|r| (r.id, r.max_new_tokens)).collect();

        let out = engine.run_trace(reqs).unwrap();

        // every request completes exactly once
        let mut got: Vec<u64> = out.iter().map(|c| c.id).collect();
        got.sort_unstable();
        let mut want_ids: Vec<u64> = want.iter().map(|(i, _)| *i).collect();
        want_ids.sort_unstable();
        assert_eq!(got, want_ids, "case {case}: lost/duplicated requests");

        for c in &out {
            let (_, gen) = want.iter().find(|(i, _)| *i == c.id).unwrap();
            assert_eq!(c.tokens.len(), *gen, "case {case}: wrong gen length");
            assert_eq!(c.finish, FinishReason::Length);
            assert!(c.kv_bytes <= c.kv_dense_bytes, "case {case}: kv accounting");
            if sparsity > 0.0 {
                // sequences long enough to compress must actually shrink
                let total = c.tokens.len()
                    + want.iter().find(|(i, _)| *i == c.id).map(|_| 0).unwrap();
                let _ = total;
            }
        }

        // batch bound respected in every decode round
        let bh = &engine.metrics.batch_hist;
        assert!(
            bh.is_empty() || (bh.min() >= 1 && bh.max() <= max_batch as u64),
            "case {case}: batch bound violated"
        );
        // token accounting is exact
        let total_gen: usize = out.iter().map(|c| c.tokens.len()).sum();
        assert_eq!(engine.metrics.generated_tokens, total_gen, "case {case}");
    }
}

#[test]
fn fault_seeded_random_traces_still_answer_exactly_once() {
    // the fuzz above, with a randomly-armed fault injector layered in:
    // some requests now finish `Error`/`Rejected`, but every id is
    // still answered exactly once and pool accounting never drifts
    use mustafar::coordinator::SubmitOutcome;
    use mustafar::faults::Injector;

    for case in 0..4u64 {
        let mut rng = Pcg32::seeded(3000 + case);
        // five probabilities in [0, 0.04), rendered into a spec string
        // exactly like an operator's MUSTAFAR_FAULTS value
        let ps: Vec<String> =
            (0..5).map(|_| format!("{:.3}", rng.below(40) as f64 / 1000.0)).collect();
        let spec = format!(
            "kvpool.alloc:{},seq.decode:{},seq.prefill:{},worker.task:{},prefix.insert:{}",
            ps[0], ps[1], ps[2], ps[3], ps[4]
        );

        let mut ec = EngineConfig::default();
        ec.backend = Backend::NativeSparse;
        ec.sparsity = SparsityConfig::mustafar(0.5, 0.5);
        ec.max_batch = 1 + rng.below(4) as usize;
        let mut engine = Engine::new_native(tiny_model(case), ec);
        engine.set_fault_injector(Injector::parse(&spec, 9000 + case).unwrap());

        let n_reqs = 4 + rng.below(8) as usize;
        let mut refused: Vec<u64> = Vec::new();
        for i in 0..n_reqs as u64 {
            let plen = 8 + rng.below(100) as usize;
            let gen = 1 + rng.below(12) as usize;
            let prompt: Vec<u16> = (0..plen).map(|_| 16 + rng.below(400) as u16).collect();
            match engine.submit_full(Request::new(i, prompt, gen)) {
                SubmitOutcome::Queued => {}
                SubmitOutcome::Rejected | SubmitOutcome::Shed { .. } => refused.push(i),
            }
        }

        let mut out = Vec::new();
        let mut steps = 0usize;
        while !engine.idle() {
            if let Err(e) = engine.step() {
                engine.fail_inflight(&e.to_string());
            }
            assert_eq!(
                engine.pool_stats().live_bytes,
                engine.measured_live_bytes(),
                "case {case}: accounting drifted under faults"
            );
            out.extend(engine.take_completions());
            steps += 1;
            assert!(steps < 10_000, "case {case}: failed to quiesce under faults");
        }
        out.extend(engine.take_completions());

        let mut answered: Vec<u64> = out.iter().map(|c| c.id).chain(refused).collect();
        answered.sort_unstable();
        let expect: Vec<u64> = (0..n_reqs as u64).collect();
        assert_eq!(answered, expect, "case {case}: exactly-once violated");
        for c in &out {
            if c.finish == FinishReason::Error {
                assert!(c.error.is_some(), "case {case}: error finish without a message");
            }
        }
    }
}

#[test]
fn sparse_and_dense_engines_equal_within_window() {
    // prompts short enough that nothing exits the local window must give
    // IDENTICAL generations regardless of sparsity config
    for seed in 0..4u64 {
        let mut rng = Pcg32::seeded(2000 + seed);
        let prompt: Vec<u16> = (0..40).map(|_| 16 + rng.below(400) as u16).collect();
        let gen = 5;
        let outs: Vec<Vec<u16>> = [0.0, 0.7, 0.9]
            .iter()
            .map(|&s| {
                let mut ec = EngineConfig::default();
                ec.backend = if s > 0.0 { Backend::NativeSparse } else { Backend::NativeDense };
                ec.sparsity = SparsityConfig::mustafar(s, s);
                ec.max_new_tokens = gen;
                let mut e = Engine::new_native(tiny_model(seed), ec);
                e.run_trace(vec![Request::new(0, prompt.clone(), gen)]).unwrap()[0]
                    .tokens
                    .clone()
            })
            .collect();
        assert_eq!(outs[0], outs[1], "seed {seed}");
        assert_eq!(outs[0], outs[2], "seed {seed}");
    }
}
