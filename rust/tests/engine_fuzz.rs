// Shared lint policy with the library crate (rust/src/lib.rs): these
// allows cover numeric-harness idioms (indexed loops, config structs
// mutated after Default::default(), positional format args).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

//! Property-style fuzz of the coordinator over random traces: whatever
//! the trace shape, no request is lost or duplicated, batch bounds hold,
//! KV accounting is exact, and generation lengths are respected.
//! (proptest is not in the offline vendor set — generators run on the
//! project's deterministic PCG.)

use mustafar::config::{Backend, EngineConfig, ModelConfig, SparsityConfig};
use mustafar::coordinator::{Engine, FinishReason, Request};
use mustafar::model::{NativeModel, Weights};
use mustafar::util::Pcg32;

fn tiny_model(seed: u64) -> NativeModel {
    let cfg = ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        ff: 128,
        vocab: 512,
        rope_theta: 10000.0,
        max_seq: 512,
        norm_eps: 1e-5,
    };
    NativeModel::new(Weights::random_for_tests(cfg, seed))
}

#[test]
fn random_traces_preserve_all_invariants() {
    for case in 0..6u64 {
        let mut rng = Pcg32::seeded(1000 + case);
        let n_reqs = 1 + rng.below(10) as usize;
        let max_batch = 1 + rng.below(5) as usize;
        let sparsity = [0.0, 0.5, 0.7][rng.below(3) as usize];

        let mut ec = EngineConfig::default();
        ec.backend = if sparsity > 0.0 { Backend::NativeSparse } else { Backend::NativeDense };
        ec.sparsity = SparsityConfig::mustafar(sparsity, sparsity);
        ec.max_batch = max_batch;
        let mut engine = Engine::new_native(tiny_model(case), ec);

        let reqs: Vec<Request> = (0..n_reqs as u64)
            .map(|i| {
                let plen = 8 + rng.below(150) as usize;
                let gen = 1 + rng.below(12) as usize;
                let prompt: Vec<u16> =
                    (0..plen).map(|_| 16 + rng.below(400) as u16).collect();
                Request::new(i, prompt, gen)
            })
            .collect();
        let want: Vec<(u64, usize)> =
            reqs.iter().map(|r| (r.id, r.max_new_tokens)).collect();

        let out = engine.run_trace(reqs).unwrap();

        // every request completes exactly once
        let mut got: Vec<u64> = out.iter().map(|c| c.id).collect();
        got.sort_unstable();
        let mut want_ids: Vec<u64> = want.iter().map(|(i, _)| *i).collect();
        want_ids.sort_unstable();
        assert_eq!(got, want_ids, "case {case}: lost/duplicated requests");

        for c in &out {
            let (_, gen) = want.iter().find(|(i, _)| *i == c.id).unwrap();
            assert_eq!(c.tokens.len(), *gen, "case {case}: wrong gen length");
            assert_eq!(c.finish, FinishReason::Length);
            assert!(c.kv_bytes <= c.kv_dense_bytes, "case {case}: kv accounting");
            if sparsity > 0.0 {
                // sequences long enough to compress must actually shrink
                let total = c.tokens.len()
                    + want.iter().find(|(i, _)| *i == c.id).map(|_| 0).unwrap();
                let _ = total;
            }
        }

        // batch bound respected in every decode round
        assert!(
            engine.metrics.batch_sizes.iter().all(|&b| b >= 1 && b <= max_batch),
            "case {case}: batch bound violated"
        );
        // token accounting is exact
        let total_gen: usize = out.iter().map(|c| c.tokens.len()).sum();
        assert_eq!(engine.metrics.generated_tokens, total_gen, "case {case}");
    }
}

#[test]
fn sparse_and_dense_engines_equal_within_window() {
    // prompts short enough that nothing exits the local window must give
    // IDENTICAL generations regardless of sparsity config
    for seed in 0..4u64 {
        let mut rng = Pcg32::seeded(2000 + seed);
        let prompt: Vec<u16> = (0..40).map(|_| 16 + rng.below(400) as u16).collect();
        let gen = 5;
        let outs: Vec<Vec<u16>> = [0.0, 0.7, 0.9]
            .iter()
            .map(|&s| {
                let mut ec = EngineConfig::default();
                ec.backend = if s > 0.0 { Backend::NativeSparse } else { Backend::NativeDense };
                ec.sparsity = SparsityConfig::mustafar(s, s);
                ec.max_new_tokens = gen;
                let mut e = Engine::new_native(tiny_model(seed), ec);
                e.run_trace(vec![Request::new(0, prompt.clone(), gen)]).unwrap()[0]
                    .tokens
                    .clone()
            })
            .collect();
        assert_eq!(outs[0], outs[1], "seed {seed}");
        assert_eq!(outs[0], outs[2], "seed {seed}");
    }
}
