// Shared lint policy with the library crate (rust/src/lib.rs): these
// allows cover numeric-harness idioms (indexed loops, config structs
// mutated after Default::default(), positional format args).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

//! Engine + server integration over the real trained model (random
//! weights fallback keeps the test meaningful without artifacts).

use mustafar::config::{Backend, EngineConfig, ModelConfig, SparsityConfig};
use mustafar::coordinator::{Engine, Request};
use mustafar::model::{NativeModel, Weights};
use mustafar::server;

fn tiny_weights() -> Weights {
    let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    Weights::load(dir, "tiny").unwrap_or_else(|_| {
        let cfg = ModelConfig {
            name: "tiny".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 32,
            ff: 128,
            vocab: 512,
            rope_theta: 10000.0,
            max_seq: 256,
            norm_eps: 1e-5,
        };
        Weights::random_for_tests(cfg, 1)
    })
}

#[test]
fn continuous_batching_interleaves_admissions() {
    let mut ec = EngineConfig::default();
    ec.backend = Backend::NativeSparse;
    ec.sparsity = SparsityConfig::mustafar(0.5, 0.5);
    ec.max_batch = 3;
    let mut e = Engine::new_native(NativeModel::new(tiny_weights()), ec);
    // 7 requests through a 3-wide batch: later requests must be admitted
    // as earlier ones retire.
    let reqs: Vec<Request> = (0..7)
        .map(|i| Request::new(i, vec![16 + (i as u16 % 100); 80], 6))
        .collect();
    let out = e.run_trace(reqs).unwrap();
    assert_eq!(out.len(), 7);
    assert_eq!(e.metrics.batch_hist.max(), 3, "full batch width was never reached");
    assert_eq!(e.metrics.generated_tokens, 7 * 6);
}

#[test]
fn server_round_trip_over_tcp() {
    use std::io::{BufRead, BufReader, Write};

    let mut ec = EngineConfig::default();
    ec.backend = Backend::NativeDense;
    ec.max_new_tokens = 4;
    let engine = Engine::new_native(NativeModel::new(tiny_weights()), ec);

    let addr = "127.0.0.1:17771";
    std::thread::spawn(move || {
        let _ = server::serve(engine, addr);
    });
    std::thread::sleep(std::time::Duration::from_millis(300));

    let mut stream = std::net::TcpStream::connect(addr).expect("connect");
    writeln!(stream, r#"{{"id": 42, "prompt": [1, 20, 30, 40], "max_new_tokens": 4}}"#).unwrap();
    let mut line = String::new();
    BufReader::new(stream.try_clone().unwrap()).read_line(&mut line).unwrap();
    let v = mustafar::fmt::Json::parse(&line).unwrap();
    assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 42);
    assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 4);

    // malformed request gets an error object, not a hang
    writeln!(stream, "not json").unwrap();
    line.clear();
    BufReader::new(stream).read_line(&mut line).unwrap();
    assert!(line.contains("error"));
}
