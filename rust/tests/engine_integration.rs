// Shared lint policy with the library crate (rust/src/lib.rs): these
// allows cover numeric-harness idioms (indexed loops, config structs
// mutated after Default::default(), positional format args).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

//! Engine + server integration over the real trained model (random
//! weights fallback keeps the test meaningful without artifacts).

use std::collections::BTreeMap;
use std::time::Duration;

use mustafar::config::{Backend, EngineConfig, ModelConfig, SparsityConfig};
use mustafar::coordinator::{estimate_seq_bytes, Engine, FinishReason, Request};
use mustafar::kvcache::KvPolicy;
use mustafar::model::{NativeModel, Weights};
use mustafar::server;
use mustafar::workload::trace::bursty_monster_trace;

fn tiny_weights() -> Weights {
    let dir = std::path::Path::new(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"));
    Weights::load(dir, "tiny").unwrap_or_else(|_| {
        let cfg = ModelConfig {
            name: "tiny".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 32,
            ff: 128,
            vocab: 512,
            rope_theta: 10000.0,
            max_seq: 256,
            norm_eps: 1e-5,
        };
        Weights::random_for_tests(cfg, 1)
    })
}

#[test]
fn continuous_batching_interleaves_admissions() {
    let mut ec = EngineConfig::default();
    ec.backend = Backend::NativeSparse;
    ec.sparsity = SparsityConfig::mustafar(0.5, 0.5);
    ec.max_batch = 3;
    let mut e = Engine::new_native(NativeModel::new(tiny_weights()), ec);
    // 7 requests through a 3-wide batch: later requests must be admitted
    // as earlier ones retire.
    let reqs: Vec<Request> = (0..7)
        .map(|i| Request::new(i, vec![16 + (i as u16 % 100); 80], 6))
        .collect();
    let out = e.run_trace(reqs).unwrap();
    assert_eq!(out.len(), 7);
    assert_eq!(e.metrics.batch_hist.max(), 3, "full batch width was never reached");
    assert_eq!(e.metrics.generated_tokens, 7 * 6);
}

#[test]
fn server_round_trip_over_tcp() {
    use std::io::{BufRead, BufReader, Write};

    let mut ec = EngineConfig::default();
    ec.backend = Backend::NativeDense;
    ec.max_new_tokens = 4;
    let engine = Engine::new_native(NativeModel::new(tiny_weights()), ec);

    let addr = "127.0.0.1:17771";
    std::thread::spawn(move || {
        let _ = server::serve(engine, addr);
    });
    std::thread::sleep(std::time::Duration::from_millis(300));

    let mut stream = std::net::TcpStream::connect(addr).expect("connect");
    writeln!(stream, r#"{{"id": 42, "prompt": [1, 20, 30, 40], "max_new_tokens": 4}}"#).unwrap();
    let mut line = String::new();
    BufReader::new(stream.try_clone().unwrap()).read_line(&mut line).unwrap();
    let v = mustafar::fmt::Json::parse(&line).unwrap();
    assert_eq!(v.get("id").unwrap().as_usize().unwrap(), 42);
    assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 4);

    // malformed request gets an error object, not a hang
    writeln!(stream, "not json").unwrap();
    line.clear();
    BufReader::new(stream).read_line(&mut line).unwrap();
    assert!(line.contains("error"));
}

/// A 512-position model config for tests whose prompts outgrow the
/// tiny artifact's 256-token window (monster prompts, overcommit runs).
fn wide_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        ff: 128,
        vocab: 512,
        rope_theta: 10000.0,
        max_seq: 512,
        norm_eps: 1e-5,
    }
}

#[test]
fn full_prefix_hit_reports_restore_cost_in_prefill_ms() {
    let mut ec = EngineConfig::default();
    ec.backend = Backend::NativeSparse;
    ec.sparsity = SparsityConfig::mustafar(0.5, 0.5);
    ec.max_new_tokens = 4;
    let mut e = Engine::new_native(NativeModel::new(tiny_weights()), ec);

    let prompt: Vec<u16> = (0..224).map(|i| ((17 + i * 5) % 400 + 20) as u16).collect();
    let cold = e.run_trace(vec![Request::new(0, prompt.clone(), 4)]).unwrap();
    let hit = e.run_trace(vec![Request::new(1, prompt, 4)]).unwrap();

    assert_eq!(e.metrics.prefix_full_hits, 1, "second submission must fully hit the cache");
    assert_eq!(cold[0].tokens, hit[0].tokens, "a cache hit must not change the output");
    // the fix under test: a full hit skips the forward pass but still
    // pays to restore the cached pages into a live sequence — that cost
    // is the hit's prefill, not zero
    assert!(
        hit[0].prefill_ms > 0.0,
        "full-prefix-hit prefill_ms must report the restore cost, got {}",
        hit[0].prefill_ms
    );
}

#[test]
fn pool_overcommit_bounces_a_sequence_and_queue_wait_spans_both_stays() {
    // Two sequences whose combined steady-state footprint exceeds the
    // pool are both admitted early (admission reserves per chunk, not
    // the whole estimate up front); growth later forces the pressure
    // ladder to requeue one of them. With the prefix cache and the
    // re-prune ladder off, preemption is the only reclaim left, so the
    // bounce is guaranteed. The bounced request must (a) still produce
    // exactly the tokens an unpressured engine produces and (b) report
    // a queue_ms that spans both queue stays, not just the last one.
    let cfg = wide_cfg();
    let policy = KvPolicy::mustafar(0.5, 0.5);
    let mk = |budget: usize| {
        let mut ec = EngineConfig::default();
        ec.backend = Backend::NativeSparse;
        ec.sparsity = SparsityConfig::mustafar(0.5, 0.5);
        ec.max_batch = 2;
        ec.max_new_tokens = 64;
        ec.kv_budget_bytes = budget;
        ec.kv_page_bytes = 1024;
        ec.prefix_cache = false;
        ec.reprune_tiers = vec![];
        ec.prefill_chunk_tokens = 16;
        ec.round_token_budget = 16;
        Engine::new_native(NativeModel::new(Weights::random_for_tests(cfg.clone(), 11)), ec)
    };
    let reqs = || {
        let decoder: Vec<u16> = (0..48).map(|i| ((i * 13) % 460 + 30) as u16).collect();
        let monster: Vec<u16> = (0..160).map(|i| ((i * 7) % 460 + 25) as u16).collect();
        vec![Request::new(0, decoder, 64), Request::new(1, monster, 4)]
    };

    // control: unbounded pool, no pressure, same chunking
    let control = mk(0).run_trace(reqs()).unwrap();
    let expect: BTreeMap<u64, (Vec<u16>, FinishReason)> =
        control.iter().map(|c| (c.id, (c.tokens.clone(), c.finish))).collect();

    // pressured: room for one monster plus a small margin — both admit
    // while small, the combined 112 + 164 token footprint cannot fit
    let mut e = mk(estimate_seq_bytes(&policy, &cfg, 180));
    for r in reqs() {
        use mustafar::coordinator::SubmitOutcome;
        assert!(matches!(e.submit_full(r), SubmitOutcome::Queued));
    }
    // first queue stay: both requests wait measurably before admission
    std::thread::sleep(Duration::from_millis(25));
    let mut out = Vec::new();
    let mut steps = 0usize;
    let mut slept_requeued = false;
    while !e.idle() {
        e.step().unwrap();
        out.extend(e.take_completions());
        steps += 1;
        assert!(steps < 5000, "overcommit run failed to quiesce");
        if !slept_requeued && e.metrics.preempted >= 1 {
            // second stay: the victim is back in the queue and cannot
            // re-admit while the survivor holds the pool — this wait
            // must land in its final queue_ms on top of the first stay
            std::thread::sleep(Duration::from_millis(25));
            slept_requeued = true;
        }
    }
    out.extend(e.take_completions());

    assert!(e.metrics.preempted >= 1, "overcommit never bounced a sequence");
    assert_eq!(out.len(), 2);
    for c in &out {
        let (tokens, finish) = &expect[&c.id];
        assert_eq!(&c.tokens, tokens, "id {}: bounce changed the output", c.id);
        assert_eq!(&c.finish, finish, "id {}: bounce changed the finish", c.id);
        // both waited out the pre-admission sleep
        assert!(c.queue_ms >= 24.0, "id {}: queue_ms {} lost its first stay", c.id, c.queue_ms);
    }
    let qmax = out.iter().map(|c| c.queue_ms).fold(0.0, f64::max);
    assert!(
        qmax >= 48.0,
        "bounced request's queue_ms ({qmax:.1}) does not span both queue stays"
    );
}

#[test]
fn decoders_inter_token_latency_is_bounded_while_a_monster_prefills() {
    // The issue's fairness SLO, scaled to the test model: one monster
    // prompt prefilling in chunks under a round budget must not starve
    // 16 short decoders. Solo run (shorts only) sets the baseline
    // inter-token p99 from the PR-8 histograms; the mixed run must stay
    // within a fixed factor (plus a small absolute allowance for shared
    // CI machines). Starvation-freedom itself is asserted on round
    // counts, which are scheduling-deterministic.
    const MONSTER: usize = 384; // tokens, 12 chunks of 32
    const N_SHORT: usize = 16;
    const SHORT: usize = 24;
    const GEN: usize = 8;
    const BUDGET: usize = 48;
    let mk = || {
        let mut ec = EngineConfig::default();
        ec.backend = Backend::NativeSparse;
        ec.sparsity = SparsityConfig::mustafar(0.5, 0.5);
        ec.max_batch = 20;
        ec.max_new_tokens = GEN;
        ec.prefill_chunk_tokens = 32;
        ec.round_token_budget = BUDGET;
        Engine::new_native(NativeModel::new(Weights::random_for_tests(wide_cfg(), 5)), ec)
    };
    let trace = bursty_monster_trace(3, MONSTER, N_SHORT, SHORT, GEN);

    // baseline: the 16 shorts with the monster filtered out
    let mut solo = mk();
    let shorts_only: Vec<Request> = trace
        .iter()
        .filter(|t| t.id != 0)
        .map(|t| Request::new(t.id, t.prompt.clone(), t.max_new_tokens))
        .collect();
    solo.run_trace(shorts_only).unwrap();
    let p99_solo = solo.telemetry.inter_token_us.snapshot().quantile(0.99);

    // mixed: same shorts with the monster submitted first
    let mut e = mk();
    for t in &trace {
        use mustafar::coordinator::SubmitOutcome;
        let r = Request::new(t.id, t.prompt.clone(), t.max_new_tokens);
        assert!(matches!(e.submit_full(r), SubmitOutcome::Queued));
    }
    let mut shorts_done = 0usize;
    let mut shorts_done_at = 0usize;
    let mut monster_done_at = 0usize;
    let mut steps = 0usize;
    while !e.idle() {
        e.step().unwrap();
        steps += 1;
        assert!(steps < 2000, "mixed run failed to quiesce");
        for c in e.take_completions() {
            assert_eq!(c.tokens.len(), GEN, "id {} starved of decode tokens", c.id);
            if c.id == 0 {
                monster_done_at = steps;
            } else {
                shorts_done += 1;
                shorts_done_at = steps;
            }
        }
    }
    assert_eq!(shorts_done, N_SHORT);

    // budget-derived starvation bound: every round feeds at least
    // (budget - decodables) prefill tokens (floor: one chunk), and the
    // monster's rotation share is at most one chunk per cycle — double
    // it all for slack and the shorts must still be done
    let per_round = BUDGET - (N_SHORT + 1);
    let bound = 2 * ((N_SHORT * SHORT + MONSTER) / per_round + GEN + N_SHORT + 1);
    assert!(
        shorts_done_at <= bound,
        "shorts finished at round {shorts_done_at}, budget bound is {bound}"
    );
    assert!(monster_done_at > 0, "monster never completed");

    let p99_mixed = e.telemetry.inter_token_us.snapshot().quantile(0.99);
    assert!(
        p99_mixed <= 50.0 * p99_solo + 5_000.0,
        "decoder inter-token p99 {p99_mixed:.0}us vs solo {p99_solo:.0}us — \
         chunked prefill is starving decoders"
    );
}
