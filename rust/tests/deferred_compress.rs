// Shared lint policy with the library crate (rust/src/lib.rs): these
// allows cover numeric-harness idioms (indexed loops, config structs
// mutated after Default::default(), positional format args).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

//! Acceptance for the deferred compression pipeline: the async
//! harvest/settle path must be *token-identical* to synchronous
//! prune-on-commit across local-window sizes and in-flight budgets —
//! including chunked-prefill resume and partial prefix-hit suffix
//! rebuilds — a `seq.compress` fault must poison exactly one sequence
//! with exact live-byte accounting throughout, and the steady-state
//! deferred commit must be allocation-free (the hot path only appends
//! to the dense ring tail).

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use mustafar::config::{Backend, EngineConfig, ModelConfig, SparsityConfig};
use mustafar::coordinator::{Completion, Engine, FinishReason, Request, SubmitOutcome};
use mustafar::faults::Injector;
use mustafar::kvcache::{KvPolicy, SequenceKV};
use mustafar::model::{NativeModel, Weights};
use mustafar::prune::LOCAL_WINDOW;
use mustafar::sparse::TILE;
use mustafar::util::Pcg32;

// ---------------------------------------------------------------------
// Thread-local allocation counter: a global allocator that tallies this
// thread's heap operations, so one test can assert the deferred decode
// hot path allocates nothing without being perturbed by parallel tests.

struct CountingAlloc;

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

fn thread_allocs() -> u64 {
    THREAD_ALLOCS.with(|c| c.get())
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        // try_with: TLS may be mid-teardown during thread exit
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|c| c.set(c.get() + 1));
        System.realloc(ptr, layout, new_size)
    }
}

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

// ---------------------------------------------------------------------

fn tiny_cfg() -> ModelConfig {
    ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        ff: 128,
        vocab: 512,
        rope_theta: 10000.0,
        max_seq: 512,
        norm_eps: 1e-5,
    }
}

/// A sparse native engine with an unconstrained pool (identity runs must
/// not diverge through reclaim timing, which legitimately shifts by one
/// step between modes).
fn engine(deferred: bool, window: usize, budget: usize, seed: u64) -> Engine {
    let mut ec = EngineConfig::default();
    ec.backend = Backend::NativeSparse;
    ec.sparsity = SparsityConfig::mustafar(0.6, 0.6);
    ec.max_batch = 4;
    ec.max_new_tokens = 256;
    ec.deferred_compress = deferred;
    ec.compress_inflight_groups = budget;
    ec.local_window = window;
    Engine::new_native(NativeModel::new(Weights::random_for_tests(tiny_cfg(), seed)), ec)
}

fn prompts(seed: u64, lens: &[usize]) -> Vec<Vec<u16>> {
    let mut rng = Pcg32::seeded(seed);
    lens.iter()
        .map(|&n| (0..n).map(|_| 16 + rng.below(400) as u16).collect())
        .collect()
}

fn by_id(out: Vec<Completion>) -> Vec<(u64, Vec<u16>)> {
    let mut v: Vec<(u64, Vec<u16>)> = out.into_iter().map(|c| (c.id, c.tokens)).collect();
    v.sort_by_key(|(id, _)| *id);
    v
}

/// Tentpole acceptance: for every local-window size and in-flight-group
/// budget, a multi-sequence deferred run generates the exact token
/// streams of the synchronous engine. Prompts are long enough that
/// several groups exit during both prefill and decode, so the harvest →
/// overlap → settle schedule is genuinely exercised.
#[test]
fn deferred_is_token_identical_to_sync_across_windows_and_budgets() {
    for &window in &[8usize, LOCAL_WINDOW, 64] {
        let lens = [2 * TILE + 11, 90, 3 * TILE];
        let gen = TILE + 17; // enough decode commits to exit groups mid-decode
        let mk_reqs = || -> Vec<Request> {
            prompts(40 + window as u64, &lens)
                .into_iter()
                .enumerate()
                .map(|(i, p)| Request::new(i as u64, p, gen))
                .collect()
        };
        let baseline = by_id(engine(false, window, 1, 7).run_trace(mk_reqs()).unwrap());
        for &budget in &[1usize, 2, 8] {
            let mut e = engine(true, window, budget, 7);
            let got = by_id(e.run_trace(mk_reqs()).unwrap());
            assert_eq!(
                got, baseline,
                "window {window} budget {budget}: deferred diverged from sync"
            );
            assert!(
                e.telemetry.compress_jobs.get() > 0,
                "window {window} budget {budget}: no deferred jobs ran — \
                 the pipeline was not exercised"
            );
            assert_eq!(
                e.telemetry.compress_backlog.get(),
                0,
                "window {window} budget {budget}: backlog gauge nonzero at idle"
            );
        }
    }
}

/// Chunked prefill stays synchronous (no overlap window exists inside a
/// chunk's token loop), so a monster prompt resuming across many rounds
/// while shorts decode around it must be bit-identical between modes.
#[test]
fn deferred_is_identical_through_chunked_prefill_resume() {
    let mk = |deferred: bool| -> Engine {
        let mut ec = EngineConfig::default();
        ec.backend = Backend::NativeSparse;
        ec.sparsity = SparsityConfig::mustafar(0.7, 0.7);
        ec.max_batch = 4;
        ec.max_new_tokens = 64;
        ec.prefill_chunk_tokens = 16;
        ec.round_token_budget = 32;
        ec.deferred_compress = deferred;
        ec.compress_inflight_groups = 2;
        Engine::new_native(NativeModel::new(Weights::random_for_tests(tiny_cfg(), 11)), ec)
    };
    let mk_reqs = || -> Vec<Request> {
        let ps = prompts(55, &[250, 40, 48]);
        ps.into_iter()
            .enumerate()
            .map(|(i, p)| Request::new(i as u64, p, 24))
            .collect()
    };
    let sync = by_id(mk(false).run_trace(mk_reqs()).unwrap());
    let def = by_id(mk(true).run_trace(mk_reqs()).unwrap());
    assert_eq!(def, sync, "deferred diverged across chunked-prefill resume");
}

/// A partial prefix hit seeds the new sequence from the cache and
/// rebuilds only the unshared suffix. The shareable snapshot is taken
/// before the ring goes deferred, so the lineage must stay identical —
/// and the hit must actually occur in both modes.
#[test]
fn deferred_is_identical_across_partial_prefix_hit_suffix_rebuild() {
    let run = |deferred: bool| -> (Vec<(u64, Vec<u16>)>, u64) {
        let mut ec = EngineConfig::default();
        ec.backend = Backend::NativeSparse;
        ec.sparsity = SparsityConfig::mustafar(0.6, 0.6);
        ec.max_batch = 2;
        ec.max_new_tokens = 64;
        ec.prefix_cache_bytes = 16 << 20;
        ec.deferred_compress = deferred;
        ec.compress_inflight_groups = 2;
        let mut e =
            Engine::new_native(NativeModel::new(Weights::random_for_tests(tiny_cfg(), 13)), ec);
        let base = prompts(77, &[3 * TILE])[0].clone();
        let mut longer = base.clone();
        longer.extend(prompts(78, &[TILE + 9])[0].iter().copied());
        // first request populates the cache...
        let mut out = e.run_trace(vec![Request::new(0, base, 16)]).unwrap();
        // ...second gets a partial hit and rebuilds only its suffix
        out.extend(e.run_trace(vec![Request::new(1, longer, 16)]).unwrap());
        let hits = e.metrics.prefix_partial_hits;
        (by_id(out), hits)
    };
    let (sync, sync_hits) = run(false);
    let (def, def_hits) = run(true);
    assert!(sync_hits >= 1, "sync run saw no partial prefix hit");
    assert!(def_hits >= 1, "deferred run saw no partial prefix hit");
    assert_eq!(def, sync, "deferred diverged after a partial prefix hit");
}

/// An armed `seq.compress` fault fails compression jobs: each poisoned
/// sequence gets exactly one `error` finish naming the deferred
/// pipeline, its pages come back, live-byte accounting is exact after
/// every step with jobs in flight, and the engine itself survives to
/// quiescence.
#[test]
fn compress_fault_poisons_sequences_with_exact_accounting() {
    let mut e = engine(true, LOCAL_WINDOW, 2, 21);
    e.set_fault_injector(Injector::parse("seq.compress:1.0", 4242).unwrap());
    let lens = [2 * TILE + 20, 2 * TILE + 33, 40];
    let n = lens.len() as u64;
    for (i, p) in prompts(99, &lens).into_iter().enumerate() {
        assert!(matches!(
            e.submit_full(Request::new(i as u64, p, TILE)),
            SubmitOutcome::Queued
        ));
    }
    let mut out = Vec::new();
    let mut steps = 0usize;
    while !e.idle() {
        if let Err(err) = e.step() {
            e.fail_inflight(&err.to_string());
        }
        assert_eq!(
            e.pool_stats().live_bytes,
            e.measured_live_bytes(),
            "accounting drifted with compression jobs in flight"
        );
        out.extend(e.take_completions());
        steps += 1;
        assert!(steps < 10_000, "engine failed to quiesce under seq.compress faults");
    }
    out.extend(e.take_completions());

    let mut ids: Vec<u64> = out.iter().map(|c| c.id).collect();
    ids.sort_unstable();
    assert_eq!(ids, (0..n).collect::<Vec<_>>(), "exactly-once violated");
    let errors: Vec<&Completion> =
        out.iter().filter(|c| c.finish == FinishReason::Error).collect();
    assert!(
        !errors.is_empty(),
        "a p=1.0 seq.compress fault with group exits must poison something"
    );
    for c in &errors {
        let msg = c.error.as_deref().unwrap_or("");
        assert!(
            msg.contains("deferred compression failed"),
            "error finish not attributed to the compression pipeline: {msg:?}"
        );
    }
    // every page is back once the batch drains
    assert_eq!(e.pool_stats().live_bytes, 0, "pages leaked after poisoned finishes");
    assert!(e.telemetry.compress_jobs.get() > 0, "no jobs were ever submitted");
}

/// The decode hot path in deferred mode only appends fp16 to the ring
/// tail and bumps a pending counter: once the ring has reached its
/// steady-state extent, a full budget's worth of commits — group exits
/// included — performs zero heap allocations on this thread.
#[test]
fn steady_state_deferred_commit_allocates_nothing() {
    let (l, kvh, hd) = (1usize, 1usize, 32usize);
    let policy = KvPolicy::mustafar(0.6, 0.6);
    let mut kv = SequenceKV::new(policy, l, kvh, hd).unwrap();
    kv.set_deferred(true, 8).unwrap();
    let mut rng = Pcg32::seeded(17);
    let mut kr = vec![0.0f32; hd];
    let mut vr = vec![0.0f32; hd];

    let mut climb = |kv: &mut SequenceKV, rng: &mut Pcg32, kr: &mut [f32], vr: &mut [f32]| {
        while kv.pending_groups() < 8 {
            for x in kr.iter_mut() {
                *x = rng.normal_f32();
            }
            for x in vr.iter_mut() {
                *x = rng.normal_f32();
            }
            kv.append(0, 0, kr, vr);
            kv.commit_token().unwrap();
        }
    };

    // two warm-up cycles: the ring tail reaches its steady-state extent
    // (Vec capacity retained across the flush's advance/compact) and the
    // shared compression scratch is grown once
    for _ in 0..2 {
        climb(&mut kv, &mut rng, &mut kr, &mut vr);
        kv.flush_queued().unwrap();
    }

    let before = thread_allocs();
    climb(&mut kv, &mut rng, &mut kr, &mut vr);
    let allocs = thread_allocs() - before;
    assert_eq!(
        allocs, 0,
        "steady-state deferred commits must be allocation-free \
         (ring append + pending bookkeeping only), saw {allocs} allocations"
    );
    kv.flush_queued().unwrap(); // leave the sequence consistent
}
