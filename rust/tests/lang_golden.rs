// Shared lint policy with the library crate (rust/src/lib.rs): these
// allows cover numeric-harness idioms (indexed loops, config structs
// mutated after Default::default(), positional format args).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

//! Golden-file lock between the Rust and Python synthetic-language
//! implementations. The golden file is produced by the python side
//! (python/tests/golden_lang.json); if this test fails the two mirrors
//! have drifted and the trained models no longer match the serving
//! workloads.

use mustafar::fmt::Json;
use mustafar::util::Pcg32;
use mustafar::workload::lang;

fn golden() -> Json {
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/python/tests/golden_lang.json");
    let text = std::fs::read_to_string(path)
        .expect("golden_lang.json missing — run python goldens first");
    Json::parse(&text).unwrap()
}

fn u16vec(v: &Json) -> Vec<u16> {
    v.as_arr().unwrap().iter().map(|x| x.as_usize().unwrap() as u16).collect()
}

#[test]
fn pcg32_stream_matches_python() {
    let g = golden();
    let want: Vec<u32> = g
        .get("pcg32_42_54")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as u32)
        .collect();
    let mut rng = Pcg32::new(42, 54);
    let got: Vec<u32> = (0..want.len()).map(|_| rng.next_u32()).collect();
    assert_eq!(got, want);
}

#[test]
fn documents_match_python() {
    let g = golden();
    let want = u16vec(g.get("doc_seed42_len256").unwrap());
    let got = lang::gen_document(&mut Pcg32::new(42, 54), 256);
    assert_eq!(got, want);

    let want = u16vec(g.get("doc_seed7_len512").unwrap());
    let got = lang::gen_document(&mut Pcg32::new(7, 54), 512);
    assert_eq!(got, want);
}

#[test]
fn segments_match_python() {
    let g = golden();
    type SegFn = fn(&mut Pcg32) -> Vec<u16>;
    let fns: [(&str, SegFn); 7] = [
        ("seg0_seg_kv_facts_seed100", lang::seg_kv_facts),
        ("seg1_seg_doc_facts_seed101", lang::seg_doc_facts),
        ("seg2_seg_recap_seed102", lang::seg_recap),
        ("seg3_seg_fewshot_seed103", lang::seg_fewshot),
        ("seg4_seg_count_seed104", lang::seg_count),
        ("seg5_seg_code_seed105", lang::seg_code),
        ("seg6_seg_filler_seed106", lang::seg_filler),
    ];
    for (i, (key, f)) in fns.iter().enumerate() {
        let want = u16vec(g.get(key).unwrap());
        let got = f(&mut Pcg32::new(100 + i as u64, 54));
        assert_eq!(&got, &want, "{key} drifted");
    }
}
