// Shared lint policy with the library crate (rust/src/lib.rs): these
// allows cover numeric-harness idioms (indexed loops, config structs
// mutated after Default::default(), positional format args).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::type_complexity
)]

//! Connection-storm smoke (EXPERIMENTS §10): 256 concurrent pipelined
//! connections — plus one deliberately stalled reader — served from a
//! fixed reactor thread set. The old thread-per-connection front-end
//! spent two threads per socket (513+ threads for this storm); the
//! reactor must hold the process to `reactor_threads` + one engine
//! thread + the engine's bounded worker pool, verified against
//! `/proc/self/status` on linux. Every request must come back on its
//! own connection with a `length` finish, and shutdown must drain the
//! whole storm within the quiescence bound.

use std::collections::HashSet;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::mpsc;
use std::time::Duration;

use mustafar::config::{Backend, EngineConfig, ModelConfig, ServerConfig, SparsityConfig};
use mustafar::coordinator::Engine;
use mustafar::fmt::Json;
use mustafar::model::{NativeModel, Weights};
use mustafar::server;
use mustafar::workload::trace::{storm_trace, TraceRequest};

const CONNS: usize = 256;
const PER_CONN: usize = 2;

fn storm_engine() -> Engine {
    let cfg = ModelConfig {
        name: "tiny".into(),
        d_model: 64,
        n_layers: 2,
        n_heads: 2,
        n_kv_heads: 1,
        head_dim: 32,
        ff: 128,
        vocab: 512,
        rope_theta: 10000.0,
        max_seq: 512,
        norm_eps: 1e-5,
    };
    let mut ec = EngineConfig::default();
    ec.backend = Backend::NativeSparse;
    ec.sparsity = SparsityConfig::mustafar(0.5, 0.5);
    ec.max_batch = 8;
    // the whole storm (512 requests) pipelines in before the first
    // completion; nothing may be shed for queue depth
    ec.queue_cap = 1024;
    ec.max_new_tokens = 512;
    Engine::new_native(NativeModel::new(Weights::random_for_tests(cfg, 7)), ec)
}

fn req_json(r: &TraceRequest) -> String {
    let prompt: Vec<String> = r.prompt.iter().map(|t| t.to_string()).collect();
    format!(
        "{{\"id\": {}, \"prompt\": [{}], \"max_new_tokens\": {}}}",
        r.id,
        prompt.join(", "),
        r.max_new_tokens
    )
}

/// Thread count of this process from `/proc/self/status` (linux-only;
/// `None` elsewhere, which skips the thread-budget assertions).
fn process_threads() -> Option<usize> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status.lines().find_map(|l| l.strip_prefix("Threads:").and_then(|v| v.trim().parse().ok()))
}

/// Read `PER_CONN` completion lines off one storm socket and check
/// they are exactly the connection's own ids, each a `length` finish
/// of the expected token count.
fn read_conn(sock: &TcpStream, c: usize) {
    let want: HashSet<u64> = (0..PER_CONN).map(|k| (c * PER_CONN + k) as u64).collect();
    let mut reader = BufReader::new(sock.try_clone().unwrap());
    let mut got = HashSet::new();
    for _ in 0..PER_CONN {
        let mut line = String::new();
        reader.read_line(&mut line).expect("completion before read timeout");
        let v = Json::parse(&line).unwrap_or_else(|e| panic!("bad line {line:?}: {e}"));
        let id = v.get("id").unwrap().as_usize().unwrap() as u64;
        assert!(want.contains(&id), "conn {c} got id {id}, not its own");
        assert!(got.insert(id), "conn {c}: id {id} answered twice");
        assert_eq!(v.get("finish").unwrap().as_str().unwrap(), "length", "conn {c} id {id}");
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 3, "conn {c} id {id}");
    }
    assert_eq!(got, want, "conn {c} lost a completion");
}

#[test]
fn storm_of_pipelined_connections_on_a_fixed_thread_set() {
    let trace = storm_trace(20260807, CONNS, PER_CONN, 24, 3);
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind ephemeral");
    let addr = listener.local_addr().unwrap();
    let shutdown = server::ShutdownHandle::new();
    let handle = shutdown.clone();
    let scfg = ServerConfig { reactor_threads: 2, max_conns: 2048, ..ServerConfig::default() };
    let reactors = scfg.reactor_threads;

    let before = process_threads();
    let (done_tx, done_rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = server::serve_listener_cfg(storm_engine(), listener, scfg, handle);
        let _ = done_tx.send(());
    });

    // one hostile stalled reader amid the storm: it submits work whose
    // reply it never reads, and must not slow anyone else down
    let staller = TcpStream::connect(addr).expect("connect staller");
    let mut stw = staller.try_clone().unwrap();
    writeln!(stw, "{{\"id\": 999, \"prompt\": [20, 21, 22], \"max_new_tokens\": 256}}").unwrap();

    // the storm: every connection opened and fully pipelined from this
    // one thread, so client threads never pollute the process's thread
    // count
    let mut socks = Vec::with_capacity(CONNS);
    for c in 0..CONNS {
        let sock = TcpStream::connect(addr).expect("connect storm conn");
        sock.set_read_timeout(Some(Duration::from_secs(120))).unwrap();
        let mut w = sock.try_clone().unwrap();
        for r in &trace[c * PER_CONN..(c + 1) * PER_CONN] {
            writeln!(w, "{}", req_json(r)).unwrap();
        }
        socks.push(sock);
    }

    // after the first connection's answers, the engine's lazy worker
    // pool exists: measure the steady-state thread count under load
    read_conn(&socks[0], 0);
    if let (Some(b), Some(d)) = (before, process_threads()) {
        // serve thread = reactor 0, peers, engine thread, worker pool;
        // +2 slack for the runtime's own bookkeeping threads
        let workers = mustafar::util::threads().min(8);
        let allowed = reactors + 1 + workers + 2;
        assert!(
            d.saturating_sub(b) <= allowed,
            "serving 257 sockets grew the process by {} threads (allowed {allowed}): \
             the reactor is not multiplexing",
            d.saturating_sub(b)
        );
        assert!(d < 50, "absolute thread count {d} is thread-per-connection territory");
    }

    for (c, sock) in socks.iter().enumerate().skip(1) {
        read_conn(sock, c);
    }

    // drain the storm: the staller still holds an unread reply, but the
    // kernel absorbs it, so the whole server quiesces within the bound
    shutdown.shutdown();
    done_rx.recv_timeout(Duration::from_secs(30)).expect("storm drain never completed");
    drop(staller);
}
