//! Offline stub of the `xla` (xla_extension 0.5.x) bindings used by
//! `mustafar::runtime`. The image that bakes in libxla links the real
//! crate; everywhere else this stub keeps the crate compiling and lets
//! the native backend run, while any attempt to actually compile or
//! execute an HLO artifact fails with a clear runtime error.
//!
//! Only the API surface `runtime/mod.rs` touches is mirrored — keep the
//! two in sync when extending the runtime.

use std::fmt;

pub type Result<T> = std::result::Result<T, Error>;

/// Error type matching the real bindings' `xla::Error` usage
/// (`Display` + `Debug`; converted to `mustafar::Error::Xla` via
/// `to_string`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: xla_extension is not linked in this build (offline stub); \
         use the native backend or build against the real xla crate"
    ))
}

/// PJRT client handle. Construction succeeds (so artifact-index errors
/// surface first, matching the real flow); device work fails.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient(()))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("compile"))
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        Err(unavailable("buffer_from_host_buffer"))
    }
}

/// Device buffer handle (never constructed by the stub).
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("to_literal_sync"))
    }
}

/// Compiled executable handle (never constructed by the stub).
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("execute_b"))
    }
}

/// Host literal (never constructed by the stub).
pub struct Literal(());

impl Literal {
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("to_tuple"))
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Err(unavailable("array_shape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("to_vec"))
    }
}

/// Array shape of a literal.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_constructs_but_device_ops_fail() {
        let c = PjRtClient::cpu().unwrap();
        assert_eq!(c.platform_name(), "stub");
        let err = c.buffer_from_host_buffer::<f32>(&[1.0], &[1], None).unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
