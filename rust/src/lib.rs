// Portable SIMD (sparse::f16::simd) is nightly-only; the `simd` cargo
// feature opts in and folds into the runtime dispatch table
// (sparse::dispatch) as just another tier. The default stable build
// dispatches to std::arch AVX2/FMA/F16C kernels at runtime when the CPU
// has them, with the bit-identical scalar oracle as the fallback.
#![cfg_attr(feature = "simd", feature(portable_simd))]
// Lint policy for the CI `cargo clippy -- -D warnings` gate. The allowed
// lints are idioms this codebase uses on purpose: indexed loops mirror
// the paper's tile math, kernel signatures carry the full attention
// tuple, and single-letter names are the paper's notation (q, k, v, t,
// d). Everything else clippy flags is a hard CI failure.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::many_single_char_names,
    clippy::needless_lifetimes,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args,
    clippy::manual_div_ceil,
    clippy::manual_memcpy,
    clippy::type_complexity,
    clippy::collapsible_if,
    clippy::collapsible_else_if
)]

//! # Mustafar-RS
//!
//! Reproduction of *"MUSTAFAR: Promoting Unstructured Sparsity for KV
//! Cache Pruning in LLM Inference"* (NeurIPS 2025) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! * **L3 (this crate)** — serving coordinator: request router, continuous
//!   batcher, compressed KV-cache manager built on the paper's bitmap
//!   sparse format, runtime pruning + compression, and the SpMV attention
//!   hot path.
//! * **L2 (python/compile/model.py)** — JAX transformer, AOT-lowered to
//!   HLO text artifacts executed through `runtime` (PJRT).
//! * **L1 (python/compile/kernels/)** — Pallas sparse-attention and prune
//!   kernels (interpret-mode validated; TPU-shaped).
//!
//! See DESIGN.md for the system inventory and EXPERIMENTS.md for the
//! paper-vs-measured record.

pub mod attention;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod error;
pub mod eval;
pub mod evict;
pub mod faults;
pub mod fmt;
pub mod kvcache;
pub mod kvpool;
pub mod model;
pub mod prune;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod sparse;
pub mod telemetry;
pub mod tensor;
pub mod util;
pub mod workload;

pub use error::{Error, Result};
