//! Deferred KV-group compression: the engine-side coordinator that
//! turns exited 64-token groups into fire-and-forget jobs on the shared
//! [`WorkerPool`] and settles the results back into their sequences in
//! exit order.
//!
//! The decode hot path only ever appends fp16 to a sequence's dense
//! ring tail ([`SequenceKV::commit_token`] in deferred mode is O(1)
//! bookkeeping); the prune → bitmap-pack work runs here, overlapped
//! with subsequent engine rounds. The schedule that keeps this
//! bit-identical to the synchronous path is *settle-before-read*: the
//! engine settles every completed wave at the top of its round (before
//! admission decisions and before any attention walk), and decode adds
//! exactly one token per sequence per round, so a group exiting in
//! round `t` is compressed and visible by the first attention of round
//! `t + 1` — precisely when the synchronous path would have compressed
//! it.
//!
//! Jobs operate on *copied* rows (recycled `Vec<u16>` buffers, so the
//! steady state allocates nothing) and hold no pool pages: cancelling,
//! preempting, or failing a sequence with jobs in flight is pure
//! bookkeeping here ([`Compressor::abandon`]) while the pages are
//! released exactly once through the engine's existing paths. Every job
//! runs under its own `catch_unwind` and *always* sends a result — an
//! injected `seq.compress` fault or a real panic comes back as a typed
//! `Err` that poisons only the owning sequence.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::time::Instant;

use crate::coordinator::engine::panic_message;
use crate::coordinator::pool::WorkerPool;
use crate::error::{Error, Result};
use crate::kvcache::{compress_group, SequenceKV};
use crate::kvpool::OwnerId;
use crate::sparse::BitmapMatrix;
use crate::telemetry::{self, Telemetry};

/// Recycled-input free-list cap: beyond this, returned job buffers are
/// simply dropped (bounds idle memory after a burst of deep sequences).
const MAX_FREE_BUFFERS: usize = 64;

/// One completed per-head compression job, routed back over the result
/// channel. Carries its input buffers home for recycling.
struct GroupResult {
    owner: OwnerId,
    head: usize,
    wave: u64,
    out: Result<(BitmapMatrix, BitmapMatrix)>,
    k_in: Vec<u16>,
    v_in: Vec<u16>,
}

/// In-flight state for one sequence (pool owner).
struct Flight {
    /// Per-head jobs submitted but not yet received back.
    outstanding: usize,
    /// Results received and awaiting settle (sorted by wave at settle).
    ready: Vec<GroupResult>,
    /// Monotonic wave id: one wave per harvested group, settled in
    /// submission order.
    next_wave: u64,
    /// Heads per wave (`n_layers * n_kv`, fixed per sequence).
    heads: usize,
    /// Owner left the engine: results are recycled as they arrive and
    /// the flight is dropped once drained, never settled.
    abandoned: bool,
}

/// Engine-owned coordinator for deferred group compression. Not a
/// thread: submission happens on the engine thread, the prune/pack work
/// on the worker pool, and settling back on the engine thread — so
/// `SequenceKV` needs no locking and live-byte accounting stays an
/// engine-thread-exact figure.
pub struct Compressor {
    tx: Sender<GroupResult>,
    rx: Receiver<GroupResult>,
    flights: HashMap<OwnerId, Flight>,
    /// Recycled job-input buffers.
    free: Vec<(Vec<u16>, Vec<u16>)>,
    telemetry: Arc<Telemetry>,
}

impl Compressor {
    pub fn new(telemetry: Arc<Telemetry>) -> Compressor {
        let (tx, rx) = channel();
        Compressor { tx, rx, flights: HashMap::new(), free: Vec::new(), telemetry }
    }

    /// True when no sequence has anything submitted or buffered.
    pub fn is_idle(&self) -> bool {
        self.flights.is_empty()
    }

    /// Owners with live (non-abandoned) flights, for the engine's settle
    /// loop.
    pub fn owners(&self) -> Vec<OwnerId> {
        self.flights.iter().filter(|(_, f)| !f.abandoned).map(|(&o, _)| o).collect()
    }

    /// Groups submitted but not yet settled, across all sequences (the
    /// `compress_backlog` gauge's in-flight half).
    pub fn backlog_groups(&self) -> usize {
        self.flights
            .values()
            .map(|f| (f.outstanding + f.ready.len()).div_ceil(f.heads.max(1)))
            .sum()
    }

    /// Harvest every pending group of `kv` into per-head worker jobs.
    /// `fails[g]` marks group `g`'s jobs for an injected `seq.compress`
    /// failure (the fault is *consulted* on the engine thread for
    /// deterministic replay; it *fires* inside the job as a panic so the
    /// isolation path is the one a real kernel bug would take). Returns
    /// the number of per-head jobs submitted.
    pub fn submit_pending(
        &mut self,
        pool: &WorkerPool,
        owner: OwnerId,
        kv: &mut SequenceKV,
        fails: &[bool],
    ) -> u64 {
        let groups = fails.len();
        debug_assert_eq!(groups, kv.pending_groups());
        if groups == 0 {
            return 0;
        }
        let heads = kv.n_layers * kv.n_kv;
        let hd = kv.hd;
        let policy = kv.policy;
        let flight = self.flights.entry(owner).or_insert(Flight {
            outstanding: 0,
            ready: Vec::new(),
            next_wave: 0,
            heads,
            abandoned: false,
        });
        let mut submitted = 0u64;
        for (slot, &fail) in fails.iter().enumerate() {
            let wave = flight.next_wave;
            flight.next_wave += 1;
            for head in 0..heads {
                let (mut k_in, mut v_in) = self.free.pop().unwrap_or_default();
                {
                    let (kr, vr) = kv.pending_group_rows(head, slot);
                    k_in.clear();
                    k_in.extend_from_slice(kr);
                    v_in.clear();
                    v_in.extend_from_slice(vr);
                }
                let tx = self.tx.clone();
                let tel = Arc::clone(&self.telemetry);
                let job = move || {
                    let t0 = Instant::now();
                    let out = catch_unwind(AssertUnwindSafe(|| {
                        if fail {
                            panic!("injected fault: seq.compress");
                        }
                        compress_group(&policy, hd, &k_in, &v_in)
                    }))
                    .unwrap_or_else(|payload| {
                        Err(Error::Engine(format!(
                            "isolated panic in compression job: {}",
                            panic_message(payload.as_ref())
                        )))
                    });
                    if tel.on() {
                        tel.compress_us.record(telemetry::us(t0.elapsed()));
                    }
                    // the engine may already have dropped (shutdown);
                    // a dead receiver is fine
                    let _ = tx.send(GroupResult { owner, head, wave, out, k_in, v_in });
                };
                // a shutting-down pool degrades to inline execution so
                // the settle loop still sees every result
                if let Err(job) = pool.submit_detached(Box::new(job)) {
                    job();
                }
                flight.outstanding += 1;
                submitted += 1;
            }
        }
        kv.mark_harvested(groups);
        submitted
    }

    /// Absorb any results that have already arrived without blocking
    /// (keeps abandoned flights draining and the ready queues warm).
    pub fn drain_idle(&mut self) {
        while let Ok(r) = self.rx.try_recv() {
            self.route(r);
        }
    }

    /// Mark every flight whose owner is not in `live` as abandoned: its
    /// buffered results are recycled now, stragglers recycle on arrival,
    /// and the flight is dropped once drained. The compressor holds no
    /// pool pages, so this is pure bookkeeping — page release stays with
    /// the engine's existing (exactly-once) retirement paths.
    pub fn sweep_abandoned(&mut self, live: &[OwnerId]) {
        let dead: Vec<OwnerId> =
            self.flights.keys().filter(|o| !live.contains(o)).copied().collect();
        for owner in dead {
            self.abandon(owner);
        }
    }

    /// Abandon one owner's flight (cancel/deadline/preempt/poison).
    pub fn abandon(&mut self, owner: OwnerId) {
        let Some(flight) = self.flights.get_mut(&owner) else {
            return;
        };
        flight.abandoned = true;
        let drained = std::mem::take(&mut flight.ready);
        let done = flight.outstanding == 0;
        for r in drained {
            self.recycle(r.k_in, r.v_in);
        }
        if done {
            self.flights.remove(&owner);
        }
    }

    /// Block until every outstanding job for `owner` has reported, then
    /// settle the completed waves into `kv` in exit order. Returns
    /// `Ok(true)` if anything settled, `Ok(false)` for no flight, and
    /// `Err` when any job failed (injected fault or isolated panic) —
    /// the sequence's earlier waves are still settled exactly, so
    /// live-byte accounting stays truthful while the engine poisons it.
    pub fn settle_owner(&mut self, owner: OwnerId, kv: &mut SequenceKV) -> Result<bool> {
        if !self.flights.contains_key(&owner) {
            return Ok(false);
        }
        while self.flights.get(&owner).is_some_and(|f| f.outstanding > 0) {
            match self.rx.recv() {
                Ok(r) => self.route(r),
                // unreachable: we hold a sender clone for the channel's
                // whole lifetime
                Err(_) => return Err(Error::Engine("compressor result channel closed".into())),
            }
        }
        let Some(flight) = self.flights.remove(&owner) else {
            return Ok(false);
        };
        let heads = flight.heads;
        let mut ready = flight.ready;
        ready.sort_by_key(|r| (r.wave, r.head));
        let mut results = ready.into_iter();
        let mut failure: Option<Error> = None;
        loop {
            let wave: Vec<GroupResult> = results.by_ref().take(heads).collect();
            if wave.is_empty() {
                break;
            }
            let mut parts = Vec::with_capacity(heads);
            for r in wave {
                let GroupResult { out, k_in, v_in, .. } = r;
                match out {
                    Ok(pair) => parts.push(pair),
                    Err(e) => {
                        if failure.is_none() {
                            failure = Some(e);
                        }
                    }
                }
                self.recycle(k_in, v_in);
            }
            // a failed wave (and, for ordering, everything after it)
            // never settles; the sequence is poisoned by the caller
            if failure.is_none() {
                kv.settle_group(parts)?;
            }
        }
        match failure {
            Some(e) => Err(e),
            None => Ok(true),
        }
    }

    fn route(&mut self, r: GroupResult) {
        let Some(flight) = self.flights.get_mut(&r.owner) else {
            // flight already dropped (abandoned + fully drained before
            // this straggler): just reclaim the buffers
            let GroupResult { k_in, v_in, .. } = r;
            self.recycle(k_in, v_in);
            return;
        };
        flight.outstanding = flight.outstanding.saturating_sub(1);
        if flight.abandoned {
            let done = flight.outstanding == 0;
            let owner = r.owner;
            let GroupResult { k_in, v_in, .. } = r;
            self.recycle(k_in, v_in);
            if done {
                self.flights.remove(&owner);
            }
        } else {
            flight.ready.push(r);
        }
    }

    fn recycle(&mut self, k_in: Vec<u16>, v_in: Vec<u16>) {
        if self.free.len() < MAX_FREE_BUFFERS {
            self.free.push((k_in, v_in));
        }
    }
}
