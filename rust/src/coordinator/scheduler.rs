//! Admission control + continuous-batching scheduler.
//!
//! The scheduler decides which waiting requests join the running batch.
//! Its KV-budget model is where Mustafar's compression pays off at the
//! system level: compressed sequences reserve fewer bytes, so more of
//! them fit in the same budget — the mechanism behind Fig 7's "larger
//! batch at the same memory" result.
//!
//! Admission no longer implies "fully prefilled": with chunked prefill
//! (`EngineConfig::prefill_chunk_tokens`) a popped request activates
//! mid-prefill and the engine's round planner feeds it prompt chunks
//! across steps. The queue still only holds *unadmitted* requests — a
//! mid-prefill sequence bounced by pool pressure re-enters through
//! `requeue_front` like any preemption victim.

use std::collections::VecDeque;

use crate::config::{EngineConfig, ModelConfig};
use crate::coordinator::request::Request;
use crate::kvcache::KvPolicy;
use crate::sparse::bitmap::{BITMAP_BYTES, OFFSET_BYTES, PAD, TILE, VALUE_BYTES};
use crate::sparse::PackAxis;

/// Estimate the steady-state KV bytes a sequence of `tokens` total tokens
/// (prompt + generation) will hold under `policy` — the planning model
/// used for admission. Matches `SequenceKV::memory_bytes`, which since
/// the f16 storage refactor reports *actually stored* bytes
/// (`VALUE_BYTES = 2` is the real per-value footprint, not an accounting
/// fiction), so admission reserves what sequences genuinely occupy.
pub fn estimate_seq_bytes(policy: &KvPolicy, cfg: &ModelConfig, tokens: usize) -> usize {
    let heads = cfg.n_layers * cfg.n_kv_heads;
    let hd = cfg.head_dim;
    let dense_per_tok = 2 * hd * VALUE_BYTES; // K and V
    if !policy.compress {
        return heads * tokens * dense_per_tok;
    }
    let window = policy.local_window + TILE / 2; // average in-flight tail
    let comp_tokens = tokens.saturating_sub(window);
    let tail_tokens = tokens - comp_tokens;

    // Axis-aware tile model. Key tiles span 64 tokens at a fixed channel
    // (always full); Value tiles span up to 64 channels of one token, and
    // the trailing block is *partial* when hd % 64 != 0 — each partial
    // tile still pays its full bitmap + offset overhead, so the count
    // must be ceil-based or hd < 64 sequences get under-reserved.
    let per_cache = |sparsity: f64, prune: bool, axis: PackAxis| -> usize {
        // An unpruned-but-compressed cache (Method::None under a
        // compressing policy) still lives in the bitmap format — fully
        // dense tiles that pay value padding and per-tile bitmap+offset
        // overhead — so it is the kept = hd case of the same model.
        let kept = if prune { crate::prune::keep_count(hd, sparsity) } else { hd };
        match axis {
            PackAxis::Token => {
                let tiles = comp_tokens * hd / TILE;
                let vals_per_tile = (kept * TILE / hd).div_ceil(PAD) * PAD; // avg nnz padded
                tiles * (vals_per_tile * VALUE_BYTES + BITMAP_BYTES + OFFSET_BYTES)
            }
            PackAxis::Channel => {
                let mut per_tok = 0usize;
                let mut c = 0;
                while c < hd {
                    let width = TILE.min(hd - c);
                    let nnz = (kept * width).div_ceil(hd); // avg nnz in this block
                    per_tok += nnz.div_ceil(PAD) * PAD * VALUE_BYTES + BITMAP_BYTES + OFFSET_BYTES;
                    c += width;
                }
                comp_tokens * per_tok
            }
        }
    };

    let sp = &policy.sparsity;
    let k_bytes =
        per_cache(sp.key_sparsity, sp.key_method != crate::prune::Method::None, PackAxis::Token);
    let v_bytes = per_cache(
        sp.value_sparsity,
        sp.value_method != crate::prune::Method::None,
        PackAxis::Channel,
    );
    heads * (k_bytes + v_bytes + tail_tokens * dense_per_tok)
}

/// FIFO admission queue.
///
/// Byte gating moved to the `kvpool` with the paged-pool refactor: the
/// engine admits against *real pool occupancy* (free pages plus what
/// the pressure ladder can reclaim), not a reserved-estimate model. The
/// scheduler keeps the estimate only for (a) rejecting requests that
/// could never fit the budget even alone and (b) `peek_need`, the
/// prefill-footprint hint the engine checks headroom against before
/// popping the head.
pub struct Scheduler {
    pub cfg: EngineConfig,
    model_cfg: ModelConfig,
    policy: KvPolicy,
    queue: VecDeque<Request>,
    /// High-water mark of `pending()` across the scheduler's lifetime
    /// (telemetry: how deep did the admission queue ever get).
    peak_pending: usize,
}

impl Scheduler {
    pub fn new(cfg: EngineConfig, model_cfg: ModelConfig, policy: KvPolicy) -> Scheduler {
        Scheduler { cfg, model_cfg, policy, queue: VecDeque::new(), peak_pending: 0 }
    }

    /// Enqueue a request; returns false when the queue is full or the
    /// request can never fit the budget even with the whole pool to
    /// itself. The refusal is *only* signalled through the return
    /// value: the caller (`Engine::submit`) owns the rejection counter
    /// (`Metrics::rejected`), and a rejected request must not be
    /// retained — that would be an unbounded, client-drivable memory
    /// leak in a long-running server.
    pub fn submit(&mut self, req: Request) -> bool {
        if self.queue.len() >= self.cfg.queue_cap {
            return false;
        }
        let need = self.estimate(&req);
        if self.cfg.kv_budget_bytes > 0 && need > self.cfg.kv_budget_bytes {
            return false;
        }
        self.queue.push_back(req);
        self.peak_pending = self.peak_pending.max(self.queue.len());
        true
    }

    fn estimate(&self, req: &Request) -> usize {
        estimate_seq_bytes(
            &self.policy,
            &self.model_cfg,
            req.prompt.len() + req.max_new_tokens,
        )
    }

    /// Estimated *post-prefill* pool footprint of the head request (the
    /// admission headroom check; decode growth is paged in on demand
    /// and handled by the pressure ladder). None when the queue is
    /// empty.
    pub fn peek_need(&self) -> Option<usize> {
        self.queue
            .front()
            .map(|r| estimate_seq_bytes(&self.policy, &self.model_cfg, r.prompt.len() + 1))
    }

    /// Head of the queue (admission gating inspects its prompt).
    pub fn peek(&self) -> Option<&Request> {
        self.queue.front()
    }

    /// Pop the head request for admission.
    pub fn pop_front(&mut self) -> Option<Request> {
        self.queue.pop_front()
    }

    /// Re-enqueue a preempted request at the *front* of the queue (it
    /// was admitted once; FIFO fairness says it goes next). Bypasses
    /// `queue_cap` — a preempted request must never be dropped. The
    /// engine cancels a request *before* this can resurrect it
    /// (`Engine::cancel` removes queued requests via `remove_by_id`,
    /// and cancellation is only processed between steps, so a cancelled
    /// request is never in the active set when preemption runs).
    ///
    /// Since chunked prefill, the bounced request may have been cut
    /// *mid-prefill* (its partial `SequenceKV` dropped with the pages
    /// released): the engine re-stamps `Request::enqueued` and banks the
    /// prior stay into `queue_ms_acc` before calling this, so the new
    /// queue stay is measured from the bounce while the reported
    /// `queue_ms` keeps accumulating across stays.
    pub fn requeue_front(&mut self, req: Request) {
        self.queue.push_front(req);
        self.peak_pending = self.peak_pending.max(self.queue.len());
    }

    /// Remove a queued request by its routing key (client cancellation
    /// of a request that has not been admitted yet — including one
    /// preemption put back at the head). Preserves the order of the
    /// remaining queue. `None` when no queued request has that key.
    pub fn remove_by_id(&mut self, route: u64) -> Option<Request> {
        let i = self.queue.iter().position(|r| r.route == route)?;
        self.queue.remove(i)
    }

    /// Is a request with this routing key waiting in the queue?
    pub fn contains(&self, route: u64) -> bool {
        self.queue.iter().any(|r| r.route == route)
    }

    /// Remove and return every queued request matching `pred`,
    /// preserving the order of both the removed set and the remainder
    /// (deadline sweep: the engine answers each removed request with a
    /// `Timeout` completion).
    pub fn remove_where<F: FnMut(&Request) -> bool>(&mut self, mut pred: F) -> Vec<Request> {
        let mut removed = Vec::new();
        let mut kept = VecDeque::with_capacity(self.queue.len());
        for r in self.queue.drain(..) {
            if pred(&r) {
                removed.push(r);
            } else {
                kept.push_back(r);
            }
        }
        self.queue = kept;
        removed
    }

    /// Capacity-only admission (`running` = current batch size): pops up
    /// to `max_batch` requests without byte gating. Callers holding a
    /// `KvPool` (the engine) admit one at a time through `peek_need` /
    /// `pop_front` instead, so reservations check real occupancy.
    pub fn admit(&mut self, running: usize) -> Vec<Request> {
        let mut out = Vec::new();
        while running + out.len() < self.cfg.max_batch {
            let Some(req) = self.queue.pop_front() else { break };
            out.push(req);
        }
        out
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Deepest the queue has ever been (monotone high-water mark).
    pub fn peak_pending(&self) -> usize {
        self.peak_pending
    }

    /// Visit every queued request mutably, in queue order. Used by the
    /// drain path to clamp deadlines on work that has not been admitted
    /// yet.
    pub fn for_each_mut<F: FnMut(&mut Request)>(&mut self, mut f: F) {
        for r in self.queue.iter_mut() {
            f(r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    fn mc() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            d_model: 256,
            n_layers: 6,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 64,
            ff: 512,
            vocab: 512,
            rope_theta: 1e4,
            max_seq: 1024,
            norm_eps: 1e-5,
        }
    }

    #[test]
    fn estimate_compression_orders() {
        let cfg = mc();
        let dense = estimate_seq_bytes(&KvPolicy::dense(), &cfg, 1024);
        let m50 = estimate_seq_bytes(&KvPolicy::mustafar(0.5, 0.5), &cfg, 1024);
        let m70 = estimate_seq_bytes(&KvPolicy::mustafar(0.7, 0.7), &cfg, 1024);
        assert!(dense > m50 && m50 > m70, "{dense} {m50} {m70}");
        // Fig 6b ballpark: 50% -> ~0.65x dense, 70% -> ~0.45x dense
        let r50 = m50 as f64 / dense as f64;
        let r70 = m70 as f64 / dense as f64;
        assert!((0.55..0.75).contains(&r50), "{r50}");
        assert!((0.38..0.55).contains(&r70), "{r70}");
    }

    #[test]
    fn estimate_tracks_actual_bytes_incl_partial_tile_heads() {
        // Regression for the partial-channel-tile shapes (hd % 64 != 0):
        // every partial tile pays full bitmap+offset overhead, and the
        // planning model must reserve for it, or hd < 64 workloads
        // over-admit against kv_budget_bytes.
        use crate::kvcache::SequenceKV;
        use crate::util::Pcg32;
        // second policy: unpruned-but-compressed V (Method::None) still
        // pays bitmap-format overhead and must be priced as such
        for policy in [KvPolicy::mustafar(0.5, 0.5), KvPolicy::mustafar(0.5, 0.0)] {
            for hd in [32usize, 64, 96] {
                let mut cfg = mc();
                cfg.head_dim = hd;
                let tokens = 1024usize;
                let est = estimate_seq_bytes(&policy, &cfg, tokens);

                let heads = cfg.n_layers * cfg.n_kv_heads;
                let mut rng = Pcg32::seeded(900 + hd as u64);
                let mk = |rng: &mut Pcg32| -> Vec<Vec<f32>> {
                    (0..heads)
                        .map(|_| (0..tokens * hd).map(|_| rng.normal_f32()).collect())
                        .collect()
                };
                let (k, v) = (mk(&mut rng), mk(&mut rng));
                let mut kv = SequenceKV::new(policy, cfg.n_layers, cfg.n_kv_heads, hd).unwrap();
                kv.ingest_prefill(&k, &v, tokens, None).unwrap();
                let (actual, _) = kv.memory_bytes();

                let ratio = est as f64 / actual as f64;
                assert!(
                    (0.8..1.3).contains(&ratio),
                    "hd={hd} policy {policy:?}: est {est} vs actual {actual} (ratio {ratio:.3})"
                );
            }
        }
    }

    #[test]
    fn peek_need_reflects_compression() {
        // The admission hint is the prefill footprint, and compressed
        // policies need fewer bytes for the same prompt — the mechanism
        // that lets the engine pack more sequences into one pool.
        let cfg = mc();
        let mk = |policy: KvPolicy| {
            let mut s = Scheduler::new(EngineConfig::default(), cfg.clone(), policy);
            assert!(s.peek_need().is_none());
            s.submit(Request::new(0, vec![0; 896], 128));
            s.peek_need().unwrap()
        };
        let dense = mk(KvPolicy::dense());
        let sparse = mk(KvPolicy::mustafar(0.7, 0.7));
        assert!(sparse < dense, "{sparse} vs {dense}");
        // prefill-only: far below the whole-lifetime estimate
        assert!(dense <= estimate_seq_bytes(&KvPolicy::dense(), &cfg, 896 + 128));
    }

    #[test]
    fn submit_rejects_impossible_requests() {
        // A request whose whole-lifetime KV exceeds the entire pool can
        // never complete; it is rejected at submit instead of cycling
        // through the pressure ladder forever.
        let cfg = mc();
        let mut ec = EngineConfig::default();
        ec.kv_budget_bytes = estimate_seq_bytes(&KvPolicy::dense(), &cfg, 64);
        let mut s = Scheduler::new(ec, cfg, KvPolicy::dense());
        assert!(s.submit(Request::new(0, vec![0; 32], 8)));
        assert!(!s.submit(Request::new(1, vec![0; 512], 128)));
        assert_eq!(s.pending(), 1, "rejected request must not be retained");
    }

    #[test]
    fn queue_capacity_rejects() {
        let cfg = mc();
        let mut ec = EngineConfig::default();
        ec.queue_cap = 2;
        let mut s = Scheduler::new(ec, cfg, KvPolicy::dense());
        assert!(s.submit(Request::new(0, vec![0; 8], 4)));
        assert!(s.submit(Request::new(1, vec![0; 8], 4)));
        assert!(!s.submit(Request::new(2, vec![0; 8], 4)));
        assert_eq!(s.pending(), 2, "rejected request must not be retained");
    }

    #[test]
    fn requeue_front_takes_priority_and_bypasses_cap() {
        let cfg = mc();
        let mut ec = EngineConfig::default();
        ec.queue_cap = 2;
        let mut s = Scheduler::new(ec, cfg, KvPolicy::dense());
        s.submit(Request::new(0, vec![0; 8], 4));
        s.submit(Request::new(1, vec![0; 8], 4));
        // a preempted request re-enters at the head even when the queue
        // is at capacity
        s.requeue_front(Request::new(7, vec![0; 8], 4));
        assert_eq!(s.pending(), 3);
        assert_eq!(s.pop_front().unwrap().id, 7);
        assert_eq!(s.pop_front().unwrap().id, 0);
    }

    #[test]
    fn remove_by_id_preserves_order_of_the_rest() {
        let cfg = mc();
        let mut s = Scheduler::new(EngineConfig::default(), cfg, KvPolicy::dense());
        for i in 0..4 {
            s.submit(Request::new(i, vec![0; 8], 4));
        }
        assert!(s.contains(2));
        let r = s.remove_by_id(2).expect("queued request");
        assert_eq!(r.id, 2);
        assert!(!s.contains(2));
        assert!(s.remove_by_id(2).is_none(), "second removal finds nothing");
        let ids: Vec<u64> = std::iter::from_fn(|| s.pop_front()).map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 3]);
    }

    #[test]
    fn remove_by_id_reaches_a_requeued_head() {
        // a preempted request re-queued at the head must still be
        // cancellable — this is the "cancelled sequence must not be
        // resurrected by requeue_front" guarantee at the queue level
        let cfg = mc();
        let mut s = Scheduler::new(EngineConfig::default(), cfg, KvPolicy::dense());
        s.submit(Request::new(0, vec![0; 8], 4));
        s.requeue_front(Request::new(9, vec![0; 8], 4));
        assert_eq!(s.remove_by_id(9).unwrap().id, 9);
        assert_eq!(s.pop_front().unwrap().id, 0);
        assert_eq!(s.pending(), 0);
    }

    #[test]
    fn remove_where_splits_queue_in_order() {
        let cfg = mc();
        let mut s = Scheduler::new(EngineConfig::default(), cfg, KvPolicy::dense());
        for i in 0..6 {
            s.submit(Request::new(i, vec![0; 8], 4));
        }
        let removed = s.remove_where(|r| r.id % 2 == 0);
        assert_eq!(removed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 2, 4]);
        let rest: Vec<u64> = std::iter::from_fn(|| s.pop_front()).map(|r| r.id).collect();
        assert_eq!(rest, vec![1, 3, 5]);
    }

    #[test]
    fn peak_pending_is_a_high_water_mark() {
        let cfg = mc();
        let mut s = Scheduler::new(EngineConfig::default(), cfg, KvPolicy::dense());
        assert_eq!(s.peak_pending(), 0);
        for i in 0..3 {
            s.submit(Request::new(i, vec![0; 8], 4));
        }
        assert_eq!(s.peak_pending(), 3);
        // draining does not lower the mark
        while s.pop_front().is_some() {}
        assert_eq!(s.pending(), 0);
        assert_eq!(s.peak_pending(), 3);
        // requeue_front past the old peak raises it
        for i in 0..4 {
            s.requeue_front(Request::new(10 + i, vec![0; 8], 4));
        }
        assert_eq!(s.peak_pending(), 4);
    }

    #[test]
    fn fifo_order_preserved() {
        let cfg = mc();
        let mut s = Scheduler::new(EngineConfig::default(), cfg, KvPolicy::dense());
        for i in 0..5 {
            s.submit(Request::new(i, vec![0; 4], 1));
        }
        let adm = s.admit(0);
        let ids: Vec<u64> = adm.iter().map(|r| r.id).collect();
        assert_eq!(ids, vec![0, 1, 2, 3, 4]);
    }
}
