//! Persistent decode worker pool.
//!
//! `Engine::decode_round` previously spawned a fresh `std::thread::scope`
//! every round, paying thread creation + teardown for every generated
//! token. Decode steps are short (especially for small batches and short
//! contexts), so that fixed cost is a real fraction of the round. This
//! pool keeps workers parked on a shared queue and re-dispatches borrowed
//! closures each round, with a completion barrier standing in for the
//! scope's implicit join.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use crate::telemetry::{self, Telemetry};

type Job = Box<dyn FnOnce() + Send + 'static>;
/// Panic payload carried back from a worker (`None` = job completed).
type PanicPayload = Box<dyn std::any::Any + Send + 'static>;

/// One queued unit of work. Scoped tasks participate in `run_scoped`'s
/// completion barrier (one done-channel message each, panic payloads
/// re-raised on the caller); detached tasks do not — they report their
/// outcome through whatever channel the job itself carries (the deferred
/// compressor's result queue), so a detached panic is swallowed here
/// after the job's own `catch_unwind` has already converted it.
enum Task {
    Scoped(Job),
    Detached(Job),
}

/// Fixed-size pool of parked worker threads executing borrowed jobs with
/// a scoped-join guarantee (`run_scoped` blocks until every submitted
/// job has finished). Detached fire-and-forget jobs (`submit_detached`)
/// share the same workers and queue but skip the barrier.
pub struct WorkerPool {
    tx: Option<Sender<Task>>,
    done_rx: Receiver<Option<PanicPayload>>,
    handles: Vec<JoinHandle<()>>,
    size: usize,
}

impl WorkerPool {
    /// Spawn `size` parked workers (at least 1).
    pub fn new(size: usize) -> WorkerPool {
        WorkerPool::spawn(size, None)
    }

    /// Like [`WorkerPool::new`], but each completed job's wall time is
    /// recorded into `telemetry.worker_task_us` (sharded atomic
    /// histogram — one relaxed record per job, no locking on the decode
    /// hot path). A disabled registry short-circuits to plain
    /// execution.
    pub fn new_with_telemetry(size: usize, telemetry: Arc<Telemetry>) -> WorkerPool {
        WorkerPool::spawn(size, Some(telemetry))
    }

    fn spawn(size: usize, tel: Option<Arc<Telemetry>>) -> WorkerPool {
        let size = size.max(1);
        let (tx, rx) = channel::<Task>();
        let rx = Arc::new(Mutex::new(rx));
        let (done_tx, done_rx) = channel::<Option<PanicPayload>>();
        let tel = tel.filter(|t| t.on());
        let handles = (0..size)
            .map(|i| {
                let rx = Arc::clone(&rx);
                let done = done_tx.clone();
                let tel = tel.clone();
                std::thread::Builder::new()
                    .name(format!("decode-worker-{i}"))
                    .spawn(move || loop {
                        // hold the lock only while dequeueing
                        let task = match rx.lock() {
                            Ok(guard) => guard.recv(),
                            Err(_) => break,
                        };
                        match task {
                            Ok(task) => {
                                let (job, scoped) = match task {
                                    Task::Scoped(job) => (job, true),
                                    Task::Detached(job) => (job, false),
                                };
                                // carry the payload back so run_scoped can
                                // resume_unwind with the original message
                                let t0 = tel.as_ref().map(|_| Instant::now());
                                let payload = std::panic::catch_unwind(
                                    std::panic::AssertUnwindSafe(job),
                                )
                                .err();
                                if let (Some(tel), Some(t0)) = (tel.as_ref(), t0) {
                                    tel.worker_task_us.record(telemetry::us(t0.elapsed()));
                                }
                                // detached tasks never touch the barrier
                                // channel: run_scoped counts exactly its
                                // own submissions
                                if scoped && done.send(payload).is_err() {
                                    break;
                                }
                            }
                            Err(_) => break, // sender dropped: shut down
                        }
                    })
                    .expect("spawn decode worker")
            })
            .collect();
        WorkerPool { tx: Some(tx), done_rx, handles, size }
    }

    /// Queue a fire-and-forget job on the pool. If the pool is shutting
    /// down the job is handed back unrun so the caller can execute it
    /// inline. The job is responsible for reporting its own outcome
    /// (including catching its own panics); `run_scoped`'s barrier is
    /// unaffected.
    pub fn submit_detached(&self, job: Job) -> std::result::Result<(), Job> {
        match &self.tx {
            Some(tx) => tx.send(Task::Detached(job)).map_err(|e| match e.0 {
                Task::Detached(job) | Task::Scoped(job) => job,
            }),
            None => Err(job),
        }
    }

    pub fn size(&self) -> usize {
        self.size
    }

    /// Execute all jobs on the pool and block until every one completes.
    ///
    /// Jobs may borrow from the caller's stack: the completion barrier
    /// below is what makes the lifetime extension sound, exactly like the
    /// implicit join of `std::thread::scope`.
    pub fn run_scoped<'s>(&self, jobs: Vec<Box<dyn FnOnce() + Send + 's>>) {
        let n = jobs.len();
        for job in jobs {
            // SAFETY: `Job` erases the `'s` lifetime. We do not return (or
            // unwind) from this frame until all `n` jobs have signalled
            // completion (panics inside a job are caught by the worker's
            // `catch_unwind` and still signal), so every borrow captured
            // by a job strictly outlives its execution. The two
            // cannot-happen channel failures below therefore must ABORT,
            // not unwind: unwinding past this point with jobs still
            // queued/running would free borrowed stack data under them.
            let job: Job = unsafe {
                std::mem::transmute::<Box<dyn FnOnce() + Send + 's>, Job>(job)
            };
            let alive = self
                .tx
                .as_ref()
                .map(|tx| tx.send(Task::Scoped(job)).is_ok())
                .unwrap_or(false);
            if !alive {
                eprintln!("fatal: decode worker pool unavailable mid-dispatch");
                std::process::abort();
            }
        }
        let mut first_panic: Option<PanicPayload> = None;
        for _ in 0..n {
            match self.done_rx.recv() {
                Ok(payload) => {
                    if first_panic.is_none() {
                        first_panic = payload;
                    }
                }
                Err(_) => {
                    eprintln!("fatal: decode worker pool died mid-round");
                    std::process::abort();
                }
            }
        }
        // All jobs have finished executing; unwinding is safe from here.
        // Re-raise the first job panic with its original payload.
        if let Some(payload) = first_panic {
            std::panic::resume_unwind(payload);
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        // closing the queue wakes every worker out of recv()
        drop(self.tx.take());
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_borrowed_jobs_to_completion() {
        let pool = WorkerPool::new(4);
        let mut results = vec![0usize; 16];
        for round in 0..3usize {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = results
                .iter_mut()
                .enumerate()
                .map(|(i, slot)| {
                    let job: Box<dyn FnOnce() + Send + '_> =
                        Box::new(move || *slot = i * 10 + round);
                    job
                })
                .collect();
            pool.run_scoped(jobs);
            for (i, r) in results.iter().enumerate() {
                assert_eq!(*r, i * 10 + round);
            }
        }
    }

    #[test]
    fn reuses_threads_across_rounds() {
        let pool = WorkerPool::new(2);
        let count = AtomicUsize::new(0);
        for _ in 0..50 {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| {
                    let c = &count;
                    let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                        c.fetch_add(1, Ordering::Relaxed);
                    });
                    job
                })
                .collect();
            pool.run_scoped(jobs);
        }
        assert_eq!(count.load(Ordering::Relaxed), 200);
    }

    #[test]
    fn empty_round_is_noop() {
        let pool = WorkerPool::new(1);
        pool.run_scoped(Vec::new());
        assert_eq!(pool.size(), 1);
    }

    #[test]
    fn telemetry_pool_times_every_job() {
        let tel = Arc::new(Telemetry::new(true));
        let pool = WorkerPool::new_with_telemetry(2, Arc::clone(&tel));
        for _ in 0..3 {
            let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|_| {
                    let job: Box<dyn FnOnce() + Send + '_> = Box::new(|| {
                        std::hint::black_box(0u64);
                    });
                    job
                })
                .collect();
            pool.run_scoped(jobs);
        }
        assert_eq!(tel.worker_task_us.snapshot().count(), 12);
    }

    #[test]
    fn detached_jobs_share_workers_without_touching_the_barrier() {
        let pool = WorkerPool::new(2);
        let (tx, rx) = std::sync::mpsc::channel::<usize>();
        for i in 0..8 {
            let tx = tx.clone();
            assert!(pool
                .submit_detached(Box::new(move || {
                    let _ = tx.send(i);
                }))
                .is_ok());
        }
        // a scoped round interleaved with the detached stream still
        // counts exactly its own jobs at the barrier
        let count = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                let c = &count;
                Box::new(move || {
                    c.fetch_add(1, Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.run_scoped(jobs);
        assert_eq!(count.load(Ordering::Relaxed), 4);
        let mut got: Vec<usize> = (0..8).map(|_| rx.recv().unwrap()).collect();
        got.sort_unstable();
        assert_eq!(got, (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn detached_panic_is_contained_and_pool_survives() {
        let pool = WorkerPool::new(1);
        assert!(pool.submit_detached(Box::new(|| panic!("detached boom"))).is_ok());
        let done = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = vec![Box::new(|| {
            done.fetch_add(1, Ordering::Relaxed);
        })];
        pool.run_scoped(jobs);
        assert_eq!(done.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn disabled_telemetry_pool_records_nothing() {
        let tel = Arc::new(Telemetry::new(false));
        let pool = WorkerPool::new_with_telemetry(2, Arc::clone(&tel));
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> =
            (0..4).map(|_| Box::new(|| {}) as Box<dyn FnOnce() + Send + '_>).collect();
        pool.run_scoped(jobs);
        assert!(tel.worker_task_us.snapshot().is_empty());
    }
}
