//! The serving engine: continuous batching over the native or PJRT
//! backends, with the Mustafar compressed-KV lifecycle owned by the
//! coordinator (prune + compress on local-window exit).
//!
//! All compressed-KV storage reserves pages from one `kvpool::KvPool`
//! under a global byte budget. Admission checks *real pool occupancy*
//! (head-of-line estimate against free pages, then an exact post-prefill
//! reservation); prefill work is shared through the kvpool prefix cache
//! (full hits skip prefill entirely and decode token-identically to the
//! cold path); and when a reservation cannot be satisfied the pressure
//! ladder runs — evict idle prefix pages, re-prune the coldest resident
//! sequences to a higher sparsity tier, preempt the youngest sequence
//! back onto the queue — before anything is rejected.
//!
//! Prefill is chunked, resumable, and fairly scheduled (Sarathi-style):
//! admission builds an *empty* (or prefix-cache-seeded) `SequenceKV`
//! and hands the sequence to the round planner, which feeds it prompt
//! chunks of `prefill_chunk_tokens` through the decode path —
//! interleaved with decode rounds under `round_token_budget`, so a
//! monster prompt no longer head-of-line-blocks every decoding user.
//! Sequences are therefore live-but-not-yet-decodable while
//! `ActiveSeq::prefill` is `Some`: decode rounds skip them, pool
//! reservations settle exactly per chunk, and cancellation, deadlines,
//! and preemption all cut *between* chunks with immediate page release.
//! Because chunks run token-by-token through the same `decode_into`
//! kernel regardless of chunk size, chunked prefill is bit-identical to
//! run-to-completion prefill — the property tests assert it.
//!
//! Request lifetime is cancellable end to end: `cancel` removes a
//! request from the queue or drops its sequence from the active batch
//! and releases its pool pages immediately (shared prefixes decref
//! without freeing cache-charged pages), so a disconnected client stops
//! costing the pool the moment the server notices — instead of decoding
//! to completion while the pressure ladder re-prunes or preempts *live*
//! requests to make room. `fail_inflight` is the companion for engine
//! errors: every waiter is answered, none hang.
//!
//! Failure behavior is part of the engine's contract: per-sequence
//! prefill and decode run under `catch_unwind`, so a panic (or an
//! injected fault — see `crate::faults`) poisons exactly one request,
//! which finishes `Error` with its pages released, instead of killing
//! the engine thread and hanging every waiter. Deadline admission
//! (`max_queue_ms` TTL + per-request `deadline_ms`) self-cancels
//! requests nobody is waiting on, and a saturated queue sheds new
//! arrivals immediately with a `retry_after_ms` hint instead of
//! queueing unboundedly.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Instant;

use crate::config::{Backend, EngineConfig};
use crate::coordinator::compress::Compressor;
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pjrt_backend::{PjrtBackend, PjrtSeq};
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::request::{ActiveSeq, Completion, FinishReason, PrefillCursor, Request};
use crate::coordinator::scheduler::Scheduler;
use crate::error::Result;
use crate::faults::Injector;
use crate::kvcache::{KvPolicy, SequenceKV};
use crate::kvpool::{self, KvPool, OwnerId, PoolConfig, PoolStats, PrefixCache, PrefixHit};
use crate::model::{argmax, DecodeScratch, NativeModel};
use crate::telemetry::{self, FlightRecorder, Span, SpanRing, Telemetry};

/// Per-sequence backend state.
pub enum SeqState {
    Native(Box<SequenceKV>),
    Pjrt(Box<PjrtSeq>),
}

/// What admission built for a request.
enum Admission {
    /// Fully prefilled at admission: a full prefix-cache hit's restored
    /// state, or a PJRT device-side prefill. First token included.
    Ready(SeqState, u16),
    /// Native chunked path: a `SequenceKV` holding prompt tokens
    /// `[0, cursor)` (empty on a cold miss, prefix-seeded on a partial
    /// hit); the round planner feeds the rest chunk by chunk.
    Prefilling(Box<SequenceKV>, usize),
}

/// Synchronous continuous-batching engine.
///
/// `run_trace` drives a whole request trace to completion; `submit` +
/// `step` expose the same loop incrementally for the TCP server.
pub struct Engine {
    pub cfg: EngineConfig,
    pub model: Arc<NativeModel>,
    policy: KvPolicy,
    scheduler: Scheduler,
    active: Vec<ActiveSeq>,
    completions: Vec<Completion>,
    pub metrics: Metrics,
    pjrt: Option<PjrtBackend>,
    /// Persistent decode workers (lazily created on the first batched
    /// round) — replaces per-round `std::thread::scope` spawning.
    pool: Option<WorkerPool>,
    /// The paged compressed-KV pool every byte of KV state reserves
    /// against.
    kvpool: KvPool,
    prefix_cache: PrefixCache,
    /// Monotone admission counter (pressure-controller coldness order).
    admit_stamp: u64,
    /// Round-robin cursor for the prefill planner: admission stamp of
    /// the last sequence served a chunk. Each round starts serving from
    /// the next stamp after it (wrapping), so a monster prompt that
    /// exhausts the round budget cannot shut out later-admitted prompts
    /// round after round — every mid-prefill sequence is served within
    /// one full rotation.
    prefill_rr: u64,
    /// Fault injection (disabled unless `MUSTAFAR_FAULTS` is set or a
    /// test installs an injector). The kvpool shares the same handle.
    faults: Injector,
    /// Shared cross-thread metrics registry (latency histograms; the
    /// Prometheus surface). Worker and reactor threads record into
    /// their own shards; reads merge.
    pub telemetry: Arc<Telemetry>,
    /// Trace-span ring (engine-thread owned; rendered for
    /// `{"trace": n}` and `--trace-out`).
    spans: SpanRing,
    /// Flight recorder (engine-thread owned; deterministic event ring
    /// dumped on panics/faults and `{"dump"}`).
    recorder: FlightRecorder,
    /// Injector fire tallies as of the previous step end, for folding
    /// worker-thread fault fires into recorder events deterministically
    /// (diffed and sorted on the engine thread).
    fault_fires: Vec<(String, u64)>,
    /// Deferred group-compression coordinator: exited groups harvested
    /// after each decode round into detached worker jobs, settled at the
    /// top of the next step (before admission and any attention read).
    compressor: Compressor,
}

/// What `Engine::submit_full` did with a request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitOutcome {
    /// Accepted into the admission queue.
    Queued,
    /// Permanently refused (empty/out-of-vocab prompt, or a KV
    /// footprint that could never fit the budget). Retrying the same
    /// request cannot succeed.
    Rejected,
    /// Shed under overload: the queue is saturated. Retryable — the
    /// hint estimates when a slot should open.
    Shed { retry_after_ms: u64 },
}

impl Engine {
    /// Native-backend engine (pure Rust forward).
    pub fn new_native(model: NativeModel, cfg: EngineConfig) -> Engine {
        let policy = match cfg.backend {
            Backend::NativeDense | Backend::PjrtDense => KvPolicy::dense(),
            _ => KvPolicy {
                sparsity: cfg.sparsity,
                quant: None,
                compress: true,
                local_window: cfg.local_window.max(1),
            },
        };
        let scheduler = Scheduler::new(cfg.clone(), model.cfg().clone(), policy);
        let faults = Injector::from_env();
        let mut kvpool = KvPool::new(PoolConfig {
            budget_bytes: cfg.kv_budget_bytes,
            page_bytes: cfg.kv_page_bytes,
        });
        kvpool.set_fault_injector(faults.clone());
        let prefix_cache =
            PrefixCache::with_limits(cfg.prefix_cache, cfg.prefix_cache_bytes, cfg.prefix_ttl_ms);
        let tel = Arc::new(Telemetry::new(cfg.telemetry));
        kvpool.set_telemetry(Arc::clone(&tel));
        let spans = SpanRing::new(cfg.trace_ring);
        let recorder = FlightRecorder::new(cfg.recorder_ring);
        Engine {
            compressor: Compressor::new(Arc::clone(&tel)),
            telemetry: tel,
            spans,
            recorder,
            fault_fires: Vec::new(),
            cfg,
            model: Arc::new(model),
            policy,
            scheduler,
            active: Vec::new(),
            completions: Vec::new(),
            metrics: Metrics::default(),
            pjrt: None,
            pool: None,
            kvpool,
            prefix_cache,
            admit_stamp: 0,
            prefill_rr: 0,
            faults,
        }
    }

    /// Install a fault injector programmatically (tests and the chaos
    /// harness; servers arm theirs from `MUSTAFAR_FAULTS` at
    /// construction). The kvpool shares the same handle so every fault
    /// point draws from one deterministic stream.
    pub fn set_fault_injector(&mut self, inj: Injector) {
        self.kvpool.set_fault_injector(inj.clone());
        self.faults = inj;
        // fresh injector, fresh tallies: recorder fault diffs restart
        self.fault_fires.clear();
    }

    /// The engine's fault-injector handle (the server clones it so its
    /// `server.io` point shares the same deterministic stream).
    pub fn fault_injector(&self) -> &Injector {
        &self.faults
    }

    /// PJRT-backend engine (XLA artifacts on the hot path).
    pub fn new_pjrt(model: NativeModel, cfg: EngineConfig, backend: PjrtBackend) -> Engine {
        let mut e = Engine::new_native(model, cfg);
        e.pjrt = Some(backend);
        e
    }

    pub fn policy(&self) -> &KvPolicy {
        &self.policy
    }

    /// Pool occupancy snapshot (served by the TCP stats endpoint).
    pub fn pool_stats(&self) -> PoolStats {
        self.kvpool.stats()
    }

    pub fn prefix_cache(&self) -> &PrefixCache {
        &self.prefix_cache
    }

    /// Recompute the pool's live bytes from the actual buffers (active
    /// sequences' private state + prefix-cache entries). The pool's own
    /// `stats().live_bytes` must equal this exactly at step boundaries —
    /// asserted by the accounting tests.
    pub fn measured_live_bytes(&self) -> usize {
        let seqs: usize =
            self.active.iter().map(|s| Self::state_bytes(&s.state, self.pjrt.as_ref())).sum();
        seqs + self.prefix_cache.measured_bytes()
    }

    /// Submit a request to the admission queue; `true` = queued. The
    /// boolean view of [`Engine::submit_full`] for callers that treat
    /// shed and rejected alike.
    pub fn submit(&mut self, req: Request) -> bool {
        matches!(self.submit_full(req), SubmitOutcome::Queued)
    }

    /// Submit a request, distinguishing overload shedding from
    /// permanent rejection (stamping the submission time, the base of
    /// `Completion::queue_ms`).
    ///
    /// Rejects empty prompts and out-of-vocab token ids here, at the
    /// boundary: either would otherwise panic the engine thread inside
    /// the forward pass (`prefill` slices `(t - 1) * d..`; `Tensor::row`
    /// asserts the embedding index) — remotely triggerable hangs of
    /// every waiter that the `fail_inflight` error path cannot catch,
    /// since they are panics rather than `Err`s.
    ///
    /// A saturated queue *sheds* instead of rejecting: the refusal is
    /// immediate and retryable, with a backoff hint derived from
    /// observed throughput — bounded queueing beats letting clients
    /// wait on a queue that cannot drain in time.
    ///
    /// `max_new_tokens` over the config cap is clamped, not rejected:
    /// the cap is a deployment-advertised ceiling, and a truncated
    /// `Length` answer at the cap serves the client strictly better
    /// than a hard error for asking optimistically.
    pub fn submit_full(&mut self, req: Request) -> SubmitOutcome {
        let vocab = self.model.cfg().vocab;
        if req.prompt.is_empty() || req.prompt.iter().any(|&t| t as usize >= vocab) {
            self.metrics.rejected += 1;
            self.recorder.note("reject", req.id, 0);
            return SubmitOutcome::Rejected;
        }
        if self.scheduler.pending() >= self.cfg.queue_cap {
            self.metrics.shed += 1;
            self.recorder.note("shed", req.id, self.scheduler.pending() as u64);
            return SubmitOutcome::Shed { retry_after_ms: self.retry_after_hint_ms() };
        }
        let mut req = req;
        req.max_new_tokens = req.max_new_tokens.min(self.cfg.max_new_tokens.max(1));
        req.submitted = Instant::now();
        // a fresh submission starts a fresh queue history (requeues go
        // through the scheduler directly and keep theirs)
        req.enqueued = req.submitted;
        req.queue_ms_acc = 0.0;
        let (id, plen) = (req.id, req.prompt.len());
        if self.scheduler.submit(req) {
            self.recorder.note("queued", id, plen as u64);
            SubmitOutcome::Queued
        } else {
            // queue_cap was checked above, so this is the scheduler's
            // impossible-budget refusal: permanent, not retryable
            self.metrics.rejected += 1;
            self.recorder.note("reject", id, plen as u64);
            SubmitOutcome::Rejected
        }
    }

    /// Milliseconds a shed client should wait before retrying, from
    /// observed service time: the queue drains roughly one request per
    /// `recent request latency / max_batch`. Uses the decaying EWMA,
    /// not the lifetime mean — one slow cold-start request must not
    /// skew hints for the rest of the process lifetime. Falls back to
    /// a small constant before any request has completed.
    pub fn retry_after_hint_ms(&self) -> u64 {
        if self.metrics.request_latency.is_empty() {
            return 50;
        }
        let per_slot = self.metrics.request_ms_ewma / self.cfg.max_batch.max(1) as f64;
        per_slot.clamp(10.0, 60_000.0) as u64
    }

    /// Estimated milliseconds of work queued ahead of a new arrival
    /// (stats endpoint): pending requests times *recent* (EWMA) service
    /// time, divided by the batch width draining them. 0.0 before any
    /// request has completed.
    pub fn queue_depth_ms_estimate(&self) -> f64 {
        if self.metrics.request_latency.is_empty() {
            return 0.0;
        }
        self.scheduler.pending() as f64 * self.metrics.request_ms_ewma
            / self.cfg.max_batch.max(1) as f64
    }

    /// True when nothing is queued or running.
    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.scheduler.pending() == 0
    }

    /// One engine round: admit new sequences, run the round planner's
    /// prefill half (chunks for mid-prefill sequences under the token
    /// budget), run one decode round over the decodable set, then
    /// settle every sequence's pool reservation against its actual
    /// growth. Deadlines are enforced first, so a stale queued request
    /// never spends prefill compute and an expired active one frees its
    /// pages before the round.
    pub fn step(&mut self) -> Result<()> {
        let t0 = Instant::now();
        self.enforce_deadlines();
        // TTL decay for idle prefix-cache entries (no-op unless
        // `prefix_ttl_ms` is set) — before admission so the freed pages
        // are available to this step's arrivals.
        self.metrics.prefix_ttl_evictions += self.prefix_cache.expire_idle(&mut self.kvpool);
        // Settle last round's deferred compression jobs before admission
        // decisions (live-byte accounting must reflect the settled
        // layout) and before any attention read (bit-exactness: an
        // exited group is compressed by the first attention after its
        // exit, exactly like the synchronous path).
        self.settle_compressions();
        self.admit_new()?;
        let work_t0 = Instant::now();
        self.prefill_round();
        let landed = self.decode_round()?;
        if self.telemetry.on() && landed > 0 {
            // Inter-token latency spans the whole round: a decoder's
            // next token waited out any prefill chunks scheduled ahead
            // of the decode too, so chunked-prefill head-of-line
            // interference shows up in this histogram — which is what
            // the round budget exists to bound.
            let gap_us = telemetry::us(work_t0.elapsed());
            for _ in 0..landed {
                self.telemetry.inter_token_us.record(gap_us);
            }
        }
        // Harvest the groups this round's commits pushed out of the
        // window into detached worker jobs — they compress overlapped
        // with everything the engine does until the next settle.
        self.harvest_compressions();
        self.sync_pool();
        if self.telemetry.on() {
            self.telemetry.pool_occupancy_bytes.record(self.kvpool.stats().live_bytes as u64);
        }
        self.absorb_fault_fires();
        self.metrics.wall_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Fold injector fires since the last step into flight-recorder
    /// events. Runs on the engine thread over the injector's own
    /// tallies, sorted by point name — so worker-thread interleaving
    /// within a round can never change the recorded event sequence
    /// (per-point fire *counts* per step are deterministic under a
    /// pinned seed; which worker observed them is not).
    fn absorb_fault_fires(&mut self) {
        if !self.faults.enabled() {
            return;
        }
        let mut cur: Vec<(String, u64)> =
            self.faults.fired().into_iter().map(|(name, _hits, fires)| (name, fires)).collect();
        cur.sort();
        let mut fired_now = false;
        for (name, fires) in &cur {
            let prev = self
                .fault_fires
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, f)| *f)
                .unwrap_or(0);
            if *fires > prev {
                self.recorder.note_owned(format!("fault:{name}"), *fires - prev, *fires);
                fired_now = true;
            }
        }
        self.fault_fires = cur;
        if fired_now {
            self.recorder.trigger_auto_dump("chaos fault fired");
        }
    }

    /// Timeout sweep, run at the top of every step.
    ///
    /// Queued requests past the `max_queue_ms` TTL or their own
    /// `deadline_ms` self-cancel with a `Timeout` finish — a client
    /// that bounded its wait has stopped listening, and holding its
    /// queue slot only delays requests that are still live. Active
    /// sequences are cut only by their *own* deadline (the TTL governs
    /// queue wait, not service time); the completion carries whatever
    /// tokens were generated before the cut and the pages come back
    /// immediately.
    fn enforce_deadlines(&mut self) {
        let ttl = self.cfg.max_queue_ms;
        let stale = self.scheduler.remove_where(|r| {
            let waited = r.submitted.elapsed().as_millis() as u64;
            (ttl > 0 && waited > ttl) || r.deadline_ms.is_some_and(|d| waited > d)
        });
        for req in stale {
            let waited = req.submitted.elapsed().as_millis() as u64;
            if req.deadline_ms.is_some_and(|d| waited > d) {
                self.metrics.deadline_exceeded += 1;
            } else {
                self.metrics.timed_out_queued += 1;
            }
            self.recorder.note("timeout", req.id, 0);
            self.completions.push(Completion::queued(
                req.id,
                req.route,
                req.submitted,
                FinishReason::Timeout,
                None,
            ));
        }

        let mut i = 0;
        while i < self.active.len() {
            let s = &self.active[i];
            let expired = s
                .req
                .deadline_ms
                .is_some_and(|d| s.req.submitted.elapsed().as_millis() as u64 > d);
            if !expired {
                i += 1;
                continue;
            }
            let s = self.active.swap_remove(i);
            let kv = self.seq_kv_bytes(&s.state);
            self.note_kv_peaks(kv);
            self.kvpool.release(s.owner);
            self.metrics.deadline_exceeded += 1;
            self.recorder.note("timeout", s.req.id, s.generated.len() as u64);
            self.completions.push(s.into_completion(FinishReason::Timeout, None, kv));
        }
    }

    /// Clamp every in-flight request (queued and active) to finish
    /// within `ms` from now: each deadline becomes the *minimum* of its
    /// existing value and `elapsed + ms`, so a tighter client deadline
    /// is never loosened. The next `enforce_deadlines` sweep then cuts
    /// whatever outlives the clamp with the ordinary `Timeout` finish —
    /// this is how graceful drain guarantees a bounded quiescence time
    /// without inventing a second cancellation path.
    pub fn impose_deadline(&mut self, ms: u64) {
        let inflight = (self.active.len() + self.scheduler.pending()) as u64;
        self.recorder.note("impose_deadline", ms, inflight);
        let clamp = |req: &mut Request| {
            let elapsed = req.submitted.elapsed().as_millis() as u64;
            let nd = elapsed + ms;
            req.deadline_ms = Some(req.deadline_ms.map_or(nd, |d| d.min(nd)));
        };
        self.scheduler.for_each_mut(clamp);
        for s in self.active.iter_mut() {
            let elapsed = s.req.submitted.elapsed().as_millis() as u64;
            let nd = elapsed + ms;
            s.req.deadline_ms = Some(s.req.deadline_ms.map_or(nd, |d| d.min(nd)));
        }
    }

    /// Drive a whole trace to completion and return the completions.
    /// A request `submit_full` refuses (shed under queue saturation,
    /// impossible budget, out-of-vocab tokens) still gets a terminal
    /// completion — the same answer the server gives — so callers'
    /// completion counts keep the full trace as their denominator
    /// instead of requests silently vanishing.
    pub fn run_trace(&mut self, reqs: Vec<Request>) -> Result<Vec<Completion>> {
        for r in reqs {
            let (id, route) = (r.id, r.route);
            // stamp now, not the request's construction time: the
            // refusal was instant, and accepted requests have their
            // `submitted` reset by submit_full() the same way
            match self.submit_full(r) {
                SubmitOutcome::Queued => {}
                SubmitOutcome::Rejected => {
                    self.completions.push(Completion::queued(
                        id,
                        route,
                        Instant::now(),
                        FinishReason::Rejected,
                        None,
                    ));
                }
                SubmitOutcome::Shed { retry_after_ms } => {
                    let mut c = Completion::queued(
                        id,
                        route,
                        Instant::now(),
                        FinishReason::Shed,
                        None,
                    );
                    c.retry_after_ms = Some(retry_after_ms);
                    self.completions.push(c);
                }
            }
        }
        while !self.idle() {
            self.step()?;
        }
        Ok(std::mem::take(&mut self.completions))
    }

    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Estimated pool footprint of a full prefix-cache hit: private
    /// dense tails only (the shared compressed pages are already
    /// charged to the cache).
    fn full_hit_need(&self) -> usize {
        let window = self.policy.local_window + crate::sparse::TILE;
        crate::coordinator::scheduler::estimate_seq_bytes(&self.policy, self.model.cfg(), window)
    }

    /// Admit new sequences into the batch (up to `max_batch`). A full
    /// prefix-cache hit (and the PJRT backend) activates fully built;
    /// the native cold/partial paths activate *mid-prefill* — the round
    /// planner feeds them prompt chunks on subsequent `prefill_round`s.
    fn admit_new(&mut self) -> Result<()> {
        while self.active.len() < self.cfg.max_batch {
            let Some(mut need) = self.scheduler.peek_need() else { break };
            // a fully-cached head only charges its tails — don't evict
            // or re-prune residents against the whole-prompt estimate
            if self.scheduler.peek().is_some_and(|r| self.prefix_cache.has_full(&r.prompt)) {
                need = need.min(self.full_hit_need());
            }
            if !self.kvpool.fits_extra(need) && !self.reclaim(need, None, false) {
                // Head-of-line wait while anything is running (retiring
                // sequences will free pages). With an empty batch the
                // head is admitted anyway: the exact reservation below
                // — which may preempt nothing — decides for real, so an
                // oversized request rejects instead of stalling the
                // queue forever.
                if !self.active.is_empty() {
                    break;
                }
            }
            // peek_need was Some above, but prefer a graceful stop over
            // trusting that nothing drained the queue in between
            let Some(req) = self.scheduler.pop_front() else { break };
            let (id, route, submitted) = (req.id, req.route, req.submitted);
            if let Err(e) = self.start_request(req) {
                // The popped request must not vanish into the error: its
                // waiter gets an Error finish (nobody hangs), then the
                // step error still propagates so the server can fail the
                // rest of the batch too.
                self.metrics.failed += 1;
                self.completions.push(Completion::queued(
                    id,
                    route,
                    submitted,
                    FinishReason::Error,
                    Some(e.to_string()),
                ));
                return Err(e);
            }
        }
        Ok(())
    }

    /// Begin serving one admitted request: resolve the prefix cache,
    /// build the admission-time state, and either activate it fully
    /// prefilled (full hit / PJRT) or hand it to the round planner
    /// mid-prefill (native cold and partial-hit paths).
    ///
    /// The admission build runs under `catch_unwind`: a panic anywhere
    /// in it (kernel stack, cache restore, or an injected `seq.prefill`
    /// fault) is isolated to this request — its waiter gets an `Error`
    /// completion and the engine keeps serving. Genuine `Err` returns
    /// keep their old semantics (the completion is pushed by
    /// `admit_new` and the step error propagates): an `Err` is the
    /// engine *reporting* a failure it understands, a panic is the
    /// failure escaping it.
    fn start_request(&mut self, req: Request) -> Result<()> {
        let admitted = Instant::now();
        // queue wait accumulates across mid-prefill requeues: prior
        // stays are banked in `queue_ms_acc`, this stay ran from the
        // most recent `enqueued` stamp (satellite: stamp once per stay,
        // never reset, so requeues don't erase real waiting)
        let queue_ms =
            req.queue_ms_acc + admitted.duration_since(req.enqueued).as_secs_f64() * 1e3;
        let mut req = req;
        req.queue_ms_acc = queue_ms;
        let t0 = Instant::now();
        let built = catch_unwind(AssertUnwindSafe(|| self.admission_build(&req)));
        let admission = match built {
            Ok(Ok(built)) => built,
            Ok(Err(e)) => return Err(e),
            Err(payload) => {
                // No pool owner is registered until after the build, and
                // any prefix-cache insert that completed before the
                // panic left the cache internally consistent (it owns
                // its charge) — so accounting stays exact.
                self.metrics.isolated_panics += 1;
                self.metrics.failed += 1;
                self.recorder.note("prefill_panic", req.id, 0);
                self.recorder.trigger_auto_dump("panic isolated in prefill");
                let mut c = Completion::queued(
                    req.id,
                    req.route,
                    req.submitted,
                    FinishReason::Error,
                    Some(format!(
                        "isolated panic during prefill: {}",
                        panic_message(payload.as_ref())
                    )),
                );
                c.queue_ms = queue_ms;
                c.prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
                self.completions.push(c);
                return Ok(());
            }
        };
        let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
        match admission {
            Admission::Ready(state, first) => {
                self.activate(req, state, first, queue_ms, prefill_ms)
            }
            Admission::Prefilling(kv, cursor) => {
                self.begin_prefill(req, kv, cursor, queue_ms, prefill_ms)
            }
        }
    }

    /// The admission-time state build — prefix-cache resolution plus
    /// whatever can be constructed without running prompt compute.
    /// Extracted from `start_request` so it can run under
    /// `catch_unwind`; the injected `seq.prefill` fault fires before
    /// any state is touched, so an injected panic never leaves partial
    /// mutations behind.
    ///
    /// Native cold and partial-hit paths return `Prefilling`: an empty
    /// (or prefix-seeded) `SequenceKV` plus the prompt cursor the round
    /// planner resumes from. All prompt compute then runs token-by-token
    /// through `decode_into` in `prefill_round` — one chunked-prefill
    /// code path, bit-identical for every chunk size because the chunk
    /// boundary is not visible to the kernel. A full cache hit restores
    /// the exact post-prefill state (`Ready`); PJRT keeps its
    /// device-side run-to-completion prefill (`Ready`).
    fn admission_build(&mut self, req: &Request) -> Result<Admission> {
        if self.faults.fire("seq.prefill") {
            panic!("injected fault: seq.prefill");
        }
        let cacheable = self.prefix_cache.enabled()
            && self.policy.prefix_shareable()
            && matches!(self.cfg.backend, Backend::NativeDense | Backend::NativeSparse);

        let out = match (self.cfg.backend, &mut self.pjrt) {
            (Backend::NativeDense | Backend::NativeSparse, _) => {
                let hit = if cacheable {
                    self.prefix_cache.lookup(&req.prompt, self.policy.local_window)
                } else {
                    None
                };
                let mcfg = self.model.cfg();
                let (l, nkv, hd) = (mcfg.n_layers, mcfg.n_kv_heads, mcfg.head_dim);
                match hit {
                    Some(PrefixHit::Full { prefix, tail_k, tail_v, first_token }) => {
                        // the whole prefill is cached: reconstruct the
                        // exact post-prefill state and skip the forward
                        // (`Completion::prefill_ms` reports this restore
                        // cost — it is the hit's real prefill work)
                        self.metrics.prefix_full_hits += 1;
                        self.metrics.prefix_tokens_reused += req.prompt.len();
                        let kv = SequenceKV::restore_full(
                            self.policy,
                            prefix,
                            tail_k,
                            tail_v,
                            req.prompt.len(),
                        )?;
                        Admission::Ready(SeqState::Native(Box::new(kv)), first_token)
                    }
                    Some(PrefixHit::Partial { prefix }) => {
                        // shared pages cover [0, b); the round planner
                        // runs only the prompt suffix, resuming at b
                        let b = prefix.tokens;
                        self.metrics.prefix_partial_hits += 1;
                        self.metrics.prefix_tokens_reused += b;
                        self.metrics.prefill_tokens += req.prompt.len() - b;
                        let kv = SequenceKV::with_prefix(self.policy, prefix)?;
                        Admission::Prefilling(Box::new(kv), b)
                    }
                    None => {
                        if cacheable {
                            self.metrics.prefix_misses += 1;
                        }
                        self.metrics.prefill_tokens += req.prompt.len();
                        let kv = SequenceKV::new(self.policy, l, nkv, hd)?;
                        Admission::Prefilling(Box::new(kv), 0)
                    }
                }
            }
            (Backend::PjrtDense | Backend::PjrtSparse, Some(pj)) => {
                self.metrics.prefill_tokens += req.prompt.len();
                let (seq, logits) = pj.prefill(&req.prompt, self.cfg.backend)?;
                Admission::Ready(SeqState::Pjrt(Box::new(seq)), argmax(&logits))
            }
            (_, None) => {
                return Err(crate::Error::Engine(
                    "pjrt backend selected but not constructed".into(),
                ))
            }
        };
        Ok(out)
    }

    /// Reserve exact pool bytes for a freshly built sequence state and
    /// activate it (the second half of `start_request`).
    fn activate(
        &mut self,
        req: Request,
        state: SeqState,
        first: u16,
        queue_ms: f64,
        prefill_ms: f64,
    ) -> Result<()> {
        // Exact reservation against the pool. This is the issue's
        // "reservation would exceed the budget" moment: the full ladder
        // (evict → re-prune → preempt) may run; only a request that
        // cannot fit even with the pool to itself is rejected.
        let owner = self.kvpool.register();
        let bytes = Self::state_bytes(&state, self.pjrt.as_ref());
        if let Err(sf) = self.kvpool.set_live_bytes(owner, bytes) {
            let ok = self.reclaim(sf.bytes, None, true)
                && self.kvpool.set_live_bytes(owner, bytes).is_ok();
            if !ok {
                self.kvpool.release(owner);
                self.metrics.rejected += 1;
                self.metrics.rejected_capacity += 1;
                self.recorder.note("reject_capacity", req.id, bytes as u64);
                // shared constructor, with the two timings this path
                // knows more precisely (admission-stamped queue time
                // and the prefill that ran before the reject)
                let mut c = Completion::queued(
                    req.id,
                    req.route,
                    req.submitted,
                    FinishReason::Rejected,
                    None,
                );
                c.queue_ms = queue_ms;
                c.prefill_ms = prefill_ms;
                self.completions.push(c);
                return Ok(());
            }
        }

        if self.telemetry.on() {
            self.telemetry.queue_wait_us.record((queue_ms * 1e3).max(0.0) as u64);
            self.telemetry.prefill_us.record((prefill_ms * 1e3).max(0.0) as u64);
            // TTFT: the first token exists as soon as prefill finishes
            self.telemetry.ttft_us.record(((queue_ms + prefill_ms) * 1e3).max(0.0) as u64);
        }
        self.recorder.note("admit", req.id, req.prompt.len() as u64);
        let pos = req.prompt.len();
        self.admit_stamp += 1;
        let mut seq = ActiveSeq {
            req,
            generated: vec![first],
            pos,
            prefill: None,
            prefill_ms,
            queue_ms,
            decode_start: Instant::now(),
            state,
            owner,
            admitted_seq: self.admit_stamp,
            reprune_tier: 0,
            scratch: DecodeScratch::new(),
        };
        self.metrics.generated_tokens += 1;
        if self.seq_finished(&seq) {
            self.finish(seq);
        } else {
            // Decode from here on: switch the KV write path to the
            // append-only ring tail. Prefill (above) always ran
            // synchronously — its per-chunk token loop reads attention
            // between commits, so there is no overlap window to exploit
            // and the sync path keeps prefix snapshots and mid-prefill
            // resume structurally identical.
            if self.deferred_on() {
                if let SeqState::Native(kv) = &mut seq.state {
                    // enabling never flushes, so this cannot fail
                    let _ = kv.set_deferred(true, self.cfg.compress_inflight_groups);
                }
            }
            seq.decode_start = Instant::now();
            self.active.push(seq);
        }
        Ok(())
    }

    /// Activate an admitted-but-unprefilled sequence: register its pool
    /// owner, reserve what it holds so far (a reused prefix is charged
    /// to the cache, a cold start holds almost nothing — the exact
    /// per-chunk settle happens as chunks land), and hand it to the
    /// round planner.
    fn begin_prefill(
        &mut self,
        req: Request,
        kv: Box<SequenceKV>,
        cursor: usize,
        queue_ms: f64,
        prefill_ms: f64,
    ) -> Result<()> {
        let state = SeqState::Native(kv);
        let owner = self.kvpool.register();
        let bytes = Self::state_bytes(&state, self.pjrt.as_ref());
        if let Err(sf) = self.kvpool.set_live_bytes(owner, bytes) {
            let ok = self.reclaim(sf.bytes, None, true)
                && self.kvpool.set_live_bytes(owner, bytes).is_ok();
            if !ok {
                self.kvpool.release(owner);
                self.metrics.rejected += 1;
                self.metrics.rejected_capacity += 1;
                self.recorder.note("reject_capacity", req.id, bytes as u64);
                let mut c = Completion::queued(
                    req.id,
                    req.route,
                    req.submitted,
                    FinishReason::Rejected,
                    None,
                );
                c.queue_ms = queue_ms;
                c.prefill_ms = prefill_ms;
                self.completions.push(c);
                return Ok(());
            }
        }
        if self.telemetry.on() {
            self.telemetry.queue_wait_us.record((queue_ms * 1e3).max(0.0) as u64);
        }
        self.recorder.note("admit", req.id, req.prompt.len() as u64);
        self.admit_stamp += 1;
        let seq = ActiveSeq {
            req,
            generated: Vec::new(),
            pos: cursor,
            prefill: Some(PrefillCursor { cursor, chunks: 0 }),
            prefill_ms,
            queue_ms,
            // re-stamped when the first token lands; until then the
            // sequence has no decode phase
            decode_start: Instant::now(),
            state,
            owner,
            admitted_seq: self.admit_stamp,
            reprune_tier: 0,
            scratch: DecodeScratch::new(),
        };
        self.active.push(seq);
        Ok(())
    }

    /// The round planner's prefill half: feed prompt chunks to every
    /// mid-prefill sequence, round-robin in admission order, under the
    /// round token budget. Every decodable sequence's next token is
    /// charged against the budget first; prefill gets the leftover —
    /// floored at one chunk, so a fully decode-loaded engine still
    /// advances prefill (neither side can starve the other).
    /// Round-robin *across rounds* (the `prefill_rr` cursor, rather
    /// than oldest-runs-dry) lets short prompts admitted behind a
    /// monster finish in a handful of rounds even when the monster
    /// exhausts each round's budget by itself, which is where the TTFT
    /// fairness comes from.
    fn prefill_round(&mut self) {
        if !self.active.iter().any(|s| s.prefill.is_some()) {
            self.telemetry.round_budget_tokens.set(0);
            return;
        }
        let chunk = if self.cfg.prefill_chunk_tokens == 0 {
            usize::MAX
        } else {
            self.cfg.prefill_chunk_tokens
        };
        let mut budget = if self.cfg.round_token_budget == 0 {
            usize::MAX
        } else {
            let decodable = self.active.iter().filter(|s| s.prefill.is_none()).count();
            let leftover = self.cfg.round_token_budget.saturating_sub(decodable);
            if leftover == 0 {
                chunk
            } else {
                leftover
            }
        };
        let mut fed = 0usize;
        loop {
            let mut waiting: Vec<(u64, OwnerId)> = self
                .active
                .iter()
                .filter(|s| s.prefill.is_some())
                .map(|s| (s.admitted_seq, s.owner))
                .collect();
            if waiting.is_empty() || budget == 0 {
                break;
            }
            waiting.sort_by_key(|&(stamp, _)| stamp);
            // resume the rotation after the last-served stamp (wrap to
            // the oldest when the cursor is past everyone)
            let pivot =
                waiting.iter().position(|&(stamp, _)| stamp > self.prefill_rr).unwrap_or(0);
            waiting.rotate_left(pivot);
            let mut progressed = false;
            for (stamp, owner) in waiting {
                if budget == 0 {
                    break;
                }
                let n = self.prefill_chunk_for(owner, chunk.min(budget));
                self.prefill_rr = stamp;
                budget = budget.saturating_sub(n);
                fed += n;
                progressed |= n > 0;
            }
            if !progressed {
                break;
            }
        }
        self.telemetry.round_budget_tokens.set(fed as u64);
    }

    /// Feed one prompt chunk (≤ `take` tokens) to the mid-prefill
    /// sequence owned by `owner`, through the decode path — the same
    /// `decode_into` kernel every token goes through regardless of
    /// chunk size, which is what makes chunked prefill bit-identical to
    /// run-to-completion. Settles the sequence's exact pool reservation
    /// afterwards (pressure ladder → requeue → reject), and completes
    /// the prefill when the final chunk lands. Returns the prompt
    /// tokens consumed (0 when the sequence vanished, was cut by its
    /// deadline, or died).
    fn prefill_chunk_for(&mut self, owner: OwnerId, take: usize) -> usize {
        let Some(idx) = self.active.iter().position(|s| s.owner == owner) else {
            return 0;
        };
        // deadline cut *between chunks*: a monster prompt past its
        // deadline stops burning compute now, not at the next sweep,
        // and its partial pages come back immediately
        let expired = self.active[idx]
            .req
            .deadline_ms
            .is_some_and(|d| self.active[idx].req.submitted.elapsed().as_millis() as u64 > d);
        if expired {
            let s = self.active.swap_remove(idx);
            let kv = self.seq_kv_bytes(&s.state);
            self.note_kv_peaks(kv);
            self.kvpool.release(s.owner);
            self.metrics.deadline_exceeded += 1;
            self.recorder.note("timeout", s.req.id, 0);
            self.completions.push(s.into_completion(FinishReason::Timeout, None, kv));
            return 0;
        }
        let t0 = Instant::now();
        let model = Arc::clone(&self.model);
        let faults = self.faults.clone();
        let (cur, end, outcome) = {
            let s = &mut self.active[idx];
            let cur = s.prefill.as_ref().map_or(s.pos, |p| p.cursor);
            let end = (cur + take).min(s.req.prompt.len());
            let ActiveSeq { req, state, scratch, .. } = s;
            let SeqState::Native(kv) = state else {
                // PJRT prefills run-to-completion at admission; a
                // non-native state is never mid-prefill
                return 0;
            };
            let outcome = catch_unwind(AssertUnwindSafe(|| {
                if faults.fire("seq.prefill_chunk") {
                    panic!("injected fault: seq.prefill_chunk");
                }
                for j in cur..end {
                    model.decode_into(req.prompt[j], j, kv, scratch)?;
                }
                Ok::<(), crate::Error>(())
            }));
            (cur, end, outcome)
        };
        match outcome {
            Err(payload) => {
                // same isolation contract as admission-time prefill:
                // the panic poisons exactly this request — pages
                // released, waiter answered, engine keeps serving
                let s = self.active.swap_remove(idx);
                let kv = self.seq_kv_bytes(&s.state);
                self.note_kv_peaks(kv);
                self.kvpool.release(s.owner);
                self.metrics.isolated_panics += 1;
                self.metrics.failed += 1;
                self.recorder.note("prefill_panic", s.req.id, cur as u64);
                self.recorder.trigger_auto_dump("panic isolated in prefill chunk");
                let msg = format!(
                    "isolated panic during prefill chunk: {}",
                    panic_message(payload.as_ref())
                );
                self.completions.push(s.into_completion(FinishReason::Error, Some(msg), kv));
                0
            }
            Ok(Err(e)) => {
                let s = self.active.swap_remove(idx);
                let kv = self.seq_kv_bytes(&s.state);
                self.note_kv_peaks(kv);
                self.kvpool.release(s.owner);
                self.metrics.failed += 1;
                self.recorder.note("prefill_fail", s.req.id, cur as u64);
                self.completions
                    .push(s.into_completion(FinishReason::Error, Some(e.to_string()), kv));
                0
            }
            Ok(Ok(())) => {
                if self.telemetry.on() {
                    self.telemetry.prefill_chunk_us.record(telemetry::us(t0.elapsed()));
                }
                self.telemetry.prefill_chunks.inc();
                {
                    let s = &mut self.active[idx];
                    s.pos = end;
                    s.prefill_ms += t0.elapsed().as_secs_f64() * 1e3;
                    if let Some(p) = s.prefill.as_mut() {
                        p.cursor = end;
                        p.chunks += 1;
                    }
                }
                // settle the exact reservation for this chunk's growth;
                // the ladder may run, with the same bounded retries as
                // `sync_pool` (this sequence protected as the victim)
                let bytes = Self::state_bytes(&self.active[idx].state, self.pjrt.as_ref());
                let stamp = self.active[idx].admitted_seq;
                let mut attempts = 0;
                loop {
                    match self.kvpool.set_live_bytes(owner, bytes) {
                        Ok(()) => break,
                        Err(sf) => {
                            attempts += 1;
                            if attempts <= 3 && self.reclaim(sf.bytes, Some(stamp), true) {
                                continue;
                            }
                            // cannot hold this chunk: bounce back to the
                            // queue if peers may free room later (their
                            // retirement is the only thing that will),
                            // reject if it has the pool to itself
                            let Some(idx) =
                                self.active.iter().position(|s| s.owner == owner)
                            else {
                                break;
                            };
                            if self.active.len() > 1 {
                                self.requeue_prefill(idx);
                            } else {
                                let s = self.active.swap_remove(idx);
                                self.kvpool.release(s.owner);
                                self.reject_finish(s);
                            }
                            return end - cur;
                        }
                    }
                }
                // the reclaim above can reorder `active`: re-find before
                // completing
                if let Some(idx) = self.active.iter().position(|s| s.owner == owner) {
                    if self.active[idx].pos == self.active[idx].req.prompt.len() {
                        self.complete_prefill(idx);
                    }
                }
                end - cur
            }
        }
    }

    /// The final chunk landed: derive the first token from the last
    /// chunk's logits, share the built prefix through the cache (the
    /// cold and partial-hit paths converge here), and flip the sequence
    /// decodable.
    fn complete_prefill(&mut self, idx: usize) {
        let first = argmax(&self.active[idx].scratch.logits);
        let cacheable = self.prefix_cache.enabled()
            && self.policy.prefix_shareable()
            && matches!(self.cfg.backend, Backend::NativeDense | Backend::NativeSparse);
        if cacheable {
            // Insert the built state: prefill compressed fresh groups
            // past any hit boundary, so a lineage of ever-longer shared
            // prompts gets an ever-longer partial hit (plus a full
            // entry for exact repeats) instead of re-prefilling its new
            // tail forever. On success the sequence is promoted onto
            // the canonical (cache-charged) prefix and its private
            // group copies are dropped.
            let snap = {
                let SeqState::Native(kv) = &mut self.active[idx].state else {
                    return;
                };
                kv.shareable_snapshot()
            };
            if let Ok((snap, tk, tv)) = snap {
                let ev0 = self.prefix_cache.evictions;
                // an injected insert fault models the cache declining
                // (its no-room path) — the sequence keeps its private
                // state, accounting exact
                let canonical = if self.faults.fire("prefix.insert") {
                    None
                } else {
                    self.prefix_cache.insert(
                        &self.active[idx].req.prompt,
                        snap,
                        &tk,
                        &tv,
                        first,
                        &mut self.kvpool,
                    )
                };
                self.metrics.prefix_evictions += self.prefix_cache.evictions - ev0;
                if let Some(p) = canonical {
                    let promoted = {
                        let SeqState::Native(kv) = &mut self.active[idx].state else {
                            return;
                        };
                        kv.promote_prefix(p).is_ok()
                    };
                    if promoted {
                        // promotion dropped private copies — a shrink,
                        // so the settle cannot fail
                        let owner = self.active[idx].owner;
                        let bytes =
                            Self::state_bytes(&self.active[idx].state, self.pjrt.as_ref());
                        let _ = self.kvpool.set_live_bytes(owner, bytes);
                    }
                }
            }
        }
        let ttft_us = telemetry::us(self.active[idx].req.submitted.elapsed());
        {
            let s = &mut self.active[idx];
            s.generated.push(first);
            s.prefill = None;
            s.decode_start = Instant::now();
        }
        // Prefill done (and any prefix snapshot taken above, while the
        // ring was clean): decode commits from here on go through the
        // deferred append-only tail.
        if self.deferred_on() {
            let budget = self.cfg.compress_inflight_groups;
            if let SeqState::Native(kv) = &mut self.active[idx].state {
                let _ = kv.set_deferred(true, budget);
            }
        }
        self.metrics.generated_tokens += 1;
        if self.telemetry.on() {
            let prefill_ms = self.active[idx].prefill_ms;
            self.telemetry.prefill_us.record((prefill_ms * 1e3).max(0.0) as u64);
            // TTFT: the first token exists the moment the final chunk
            // lands, measured from the client's submission
            self.telemetry.ttft_us.record(ttft_us);
        }
        self.recorder.note("first_token", self.active[idx].req.id, self.active[idx].pos as u64);
        if self.seq_finished(&self.active[idx]) {
            let s = self.active.swap_remove(idx);
            self.finish(s);
        }
    }

    /// Bounce a mid-prefill sequence back to the admission queue under
    /// pool pressure: recompute-style (the partial KV is dropped with
    /// its pages released *now*), the queue stay restarts so `queue_ms`
    /// keeps accumulating, and it re-enters at the head so it re-admits
    /// before newer arrivals.
    fn requeue_prefill(&mut self, idx: usize) {
        let mut s = self.active.swap_remove(idx);
        self.kvpool.release(s.owner);
        self.telemetry.prefill_preempted.inc();
        let at = s.prefill.as_ref().map_or(0, |p| p.cursor);
        self.recorder.note("prefill_preempt", s.req.id, at as u64);
        s.req.queue_ms_acc = s.queue_ms;
        s.req.enqueued = Instant::now();
        self.scheduler.requeue_front(s.req);
        self.metrics.preempted += 1;
    }

    fn state_bytes(state: &SeqState, pjrt: Option<&PjrtBackend>) -> usize {
        match state {
            SeqState::Native(kv) => kv.private_bytes(),
            SeqState::Pjrt(seq) => pjrt.map(|p| p.seq_memory_bytes(seq).0).unwrap_or(0),
        }
    }

    /// The pressure ladder: make `need` extra pool bytes fit. Steps, in
    /// order: (1) evict idle LRU prefix-cache entries; (2) re-prune the
    /// coldest resident sequence's compressed regions to the next
    /// sparsity tier (pages shrink in place); (3) if allowed, preempt
    /// the youngest sequence back onto the admission queue
    /// (recompute-style, FIFO re-entry; `protect` is never the victim).
    /// Returns true once the reservation fits.
    fn reclaim(&mut self, need: usize, protect: Option<u64>, allow_preempt: bool) -> bool {
        loop {
            if self.kvpool.fits_extra(need) {
                return true;
            }
            if self.prefix_cache.evict_lru(&mut self.kvpool) {
                self.metrics.prefix_evictions += 1;
                self.recorder.note("prefix_evict", need as u64, 0);
                continue;
            }
            if self.reprune_one() {
                continue;
            }
            if allow_preempt {
                let cands = self.reclaim_candidates();
                if let Some(i) = kvpool::pick_preempt_victim(&cands, protect) {
                    self.preempt_at(i);
                    continue;
                }
            }
            return false;
        }
    }

    fn reclaim_candidates(&self) -> Vec<kvpool::ReclaimCandidate> {
        self.active
            .iter()
            .map(|s| kvpool::ReclaimCandidate {
                admitted_seq: s.admitted_seq,
                tier: s.reprune_tier,
                compressed_bytes: match &s.state {
                    SeqState::Native(kv) => kv.compressed_region_bytes(),
                    SeqState::Pjrt(_) => 0,
                },
                reprunable: matches!(&s.state, SeqState::Native(kv) if kv.policy.compress),
            })
            .collect()
    }

    /// Re-prune one resident sequence to its next sparsity tier.
    /// Returns true when it made progress (freed bytes or retired a
    /// candidate), false when no sequence has tiers left.
    fn reprune_one(&mut self) -> bool {
        let tiers = self.cfg.reprune_tiers.clone();
        let cands = self.reclaim_candidates();
        let Some(i) = kvpool::pick_reprune_victim(&cands, tiers.len()) else {
            return false;
        };
        let s = &mut self.active[i];
        let SeqState::Native(kv) = &mut s.state else {
            s.reprune_tier = tiers.len();
            return true;
        };
        // Gate the ladder on the *less* sparse side: as long as either
        // cache still sits below a remaining tier there are bytes to
        // reclaim (`reprune` raises each side independently and never
        // lowers one already above the tier).
        let cur = kv.policy.sparsity.key_sparsity.min(kv.policy.sparsity.value_sparsity);
        let Some((next_tier, sparsity)) = kvpool::next_reprune_tier(&tiers, s.reprune_tier, cur)
        else {
            // already sparser than every remaining tier
            s.reprune_tier = tiers.len();
            return true;
        };
        s.reprune_tier = next_tier;
        let owner = s.owner;
        let id = s.req.id;
        let t0 = Instant::now();
        if self.reprune_heads_parallel(i, sparsity).is_err() {
            return false;
        }
        let SeqState::Native(kv) = &self.active[i].state else {
            return false; // unreachable: matched Native above
        };
        let bytes = kv.private_bytes();
        if self.telemetry.on() {
            self.telemetry.prune_us.record(telemetry::us(t0.elapsed()));
        }
        // a re-prune only shrinks, so this reservation cannot fail
        let _ = self.kvpool.set_live_bytes(owner, bytes);
        self.metrics.repruned += 1;
        self.recorder.note("reprune", id, next_tier as u64);
        true
    }

    /// Raise one native sequence's sparsity in place, fanning the
    /// per-head re-prune across the worker pool (heads are independent —
    /// the same batch parallelism decode uses). Each head job catches
    /// its own panics so a bad head fails the re-prune, not the engine
    /// thread. The deferred pipeline needs no special casing: queued and
    /// in-flight groups are still dense tail bytes, and only the
    /// already-compressed region is repruned.
    fn reprune_heads_parallel(&mut self, idx: usize, sparsity: f64) -> Result<()> {
        self.ensure_pool();
        let Engine { active, pool, .. } = self;
        let SeqState::Native(kv) = &mut active[idx].state else {
            return Ok(());
        };
        let (raise_k, raise_v, kk_k, kk_v) = kv.reprune_plan(sparsity, sparsity);
        if !raise_k && !raise_v {
            kv.apply_reprune_policy(sparsity, sparsity);
            return Ok(());
        }
        let hd = kv.hd;
        let pool = pool.as_ref().expect("ensure_pool");
        let heads = kv.heads_mut();
        let n = heads.len();
        let mut slots: Vec<Option<Result<()>>> = (0..n).map(|_| None).collect();
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = heads
            .iter_mut()
            .zip(slots.iter_mut())
            .map(|(h, slot)| {
                let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    *slot = Some(
                        catch_unwind(AssertUnwindSafe(|| {
                            crate::kvcache::reprune_head_inplace(
                                h, hd, raise_k, raise_v, kk_k, kk_v,
                            )
                        }))
                        .unwrap_or_else(|payload| {
                            Err(crate::Error::Engine(format!(
                                "isolated panic during reprune: {}",
                                panic_message(payload.as_ref())
                            )))
                        }),
                    );
                });
                job
            })
            .collect();
        pool.run_scoped(jobs);
        for r in slots {
            r.unwrap_or(Err(crate::Error::Engine("reprune job dropped".into())))?;
        }
        kv.apply_reprune_policy(sparsity, sparsity);
        Ok(())
    }

    /// Recompute-style preemption: drop the sequence's state (pages and
    /// generated tokens) and put its request back at the queue head.
    /// The discarded tokens leave `generated_tokens` too — the re-run
    /// counts them again, so keeping them would double-count throughput
    /// exactly in the pressure regimes being measured (the invariant
    /// `generated_tokens == Σ completion lengths` holds regardless of
    /// preemptions).
    fn preempt_at(&mut self, idx: usize) {
        let mut s = self.active.swap_remove(idx);
        self.kvpool.release(s.owner);
        self.metrics.generated_tokens -= s.generated.len();
        if s.prefill.is_some() {
            self.telemetry.prefill_preempted.inc();
        }
        self.recorder.note("preempt", s.req.id, s.generated.len() as u64);
        // restart the queue stay (the accumulator keeps the wait so far)
        // — deadlines still anchor to the original `submitted`
        s.req.queue_ms_acc = s.queue_ms;
        s.req.enqueued = Instant::now();
        self.scheduler.requeue_front(s.req);
        self.metrics.preempted += 1;
    }

    /// Settle every active sequence's reservation against its actual
    /// post-round footprint, running the pressure ladder on growth that
    /// no longer fits. A sequence that cannot fit even after the full
    /// ladder is preempted (peers remain) or reject-finished (it has the
    /// pool to itself and still cannot grow).
    fn sync_pool(&mut self) {
        let owners: Vec<(OwnerId, u64)> =
            self.active.iter().map(|s| (s.owner, s.admitted_seq)).collect();
        self.resettle_owner_bytes(owners);
    }

    /// Re-settle the given owners' reservations against their actual
    /// footprints, with the bounded reclaim ladder. Shared by the
    /// post-round `sync_pool` and the compression settle (whose settled
    /// sequences just swapped dense tail bytes for compressed bytes and
    /// must be re-accounted before admission reads the pool).
    fn resettle_owner_bytes(&mut self, owners: Vec<(OwnerId, u64)>) {
        for (owner, stamp) in owners {
            let mut attempts = 0;
            loop {
                let Some(idx) = self.active.iter().position(|s| s.owner == owner) else {
                    break; // preempted by an earlier sequence's reclaim
                };
                let bytes = Self::state_bytes(&self.active[idx].state, self.pjrt.as_ref());
                match self.kvpool.set_live_bytes(owner, bytes) {
                    Ok(()) => break,
                    Err(sf) => {
                        // Bounded retries: under fault injection the
                        // pool can keep refusing a reservation that
                        // headroom says fits, and an unbounded
                        // reclaim-retry cycle would never terminate.
                        attempts += 1;
                        if attempts <= 3 && self.reclaim(sf.bytes, Some(stamp), true) {
                            continue; // retry the reservation
                        }
                        let Some(idx) = self.active.iter().position(|s| s.owner == owner) else {
                            break;
                        };
                        if self.active.len() > 1 {
                            self.preempt_at(idx);
                        } else {
                            let s = self.active.swap_remove(idx);
                            self.kvpool.release(s.owner);
                            self.reject_finish(s);
                        }
                        break;
                    }
                }
            }
        }
    }

    /// Whether this engine runs the deferred compression pipeline: a
    /// native backend with compression on and the config knob set. The
    /// dense baseline never compresses, and PJRT sequences own no
    /// engine-side tail, so both stay on their existing paths.
    fn deferred_on(&self) -> bool {
        self.cfg.deferred_compress
            && self.policy.compress
            && matches!(self.cfg.backend, Backend::NativeDense | Backend::NativeSparse)
    }

    /// Create the worker pool if it does not exist yet. Decode creates
    /// it lazily on the first batched round; the deferred compressor and
    /// the parallel re-prune need it even for single-sequence workloads.
    fn ensure_pool(&mut self) {
        if self.pool.is_none() {
            let workers = crate::util::threads().min(self.cfg.max_batch.max(1));
            let tel = Arc::clone(&self.telemetry);
            self.pool = Some(WorkerPool::new_with_telemetry(workers, tel));
        }
    }

    /// Top-of-step settle: absorb every completed compression job, apply
    /// the waves to their sequences in exit order, poison any sequence
    /// whose job failed (injected `seq.compress` fault or an isolated
    /// worker panic), and re-settle the settled owners' reservations so
    /// this step's admission decisions see exact live bytes. Runs before
    /// any attention read, which is what keeps the deferred pipeline
    /// bit-identical to synchronous compression (see
    /// `coordinator::compress`).
    fn settle_compressions(&mut self) {
        if self.compressor.is_idle() {
            return;
        }
        self.compressor.drain_idle();
        // owners that left the engine since submitting (finish, cancel,
        // deadline, preempt, decode casualty) drop their flights here —
        // their pages were already released exactly once on those paths,
        // and the compressor holds only copied rows
        let live: Vec<OwnerId> = self.active.iter().map(|s| s.owner).collect();
        self.compressor.sweep_abandoned(&live);
        let mut settled: Vec<(OwnerId, u64)> = Vec::new();
        for owner in self.compressor.owners() {
            let Some(idx) = self.active.iter().position(|s| s.owner == owner) else {
                continue; // unreachable after the sweep
            };
            let stamp = self.active[idx].admitted_seq;
            let SeqState::Native(kv) = &mut self.active[idx].state else {
                continue;
            };
            match self.compressor.settle_owner(owner, kv) {
                Ok(true) => settled.push((owner, stamp)),
                Ok(false) => {}
                Err(e) => {
                    // poison exactly this sequence: its earlier waves
                    // settled exactly (accounting stays truthful), the
                    // waiter gets one Error finish, the pages come back
                    // now, and the batch keeps going
                    let s = self.active.swap_remove(idx);
                    let kvb = self.seq_kv_bytes(&s.state);
                    self.note_kv_peaks(kvb);
                    self.kvpool.release(s.owner);
                    self.compressor.abandon(owner);
                    self.metrics.failed += 1;
                    self.metrics.isolated_panics += 1;
                    self.recorder.note("compress_fail", s.req.id, s.generated.len() as u64);
                    self.recorder.trigger_auto_dump("compression job failed");
                    self.completions.push(s.into_completion(
                        FinishReason::Error,
                        Some(format!("deferred compression failed: {e}")),
                        kvb,
                    ));
                }
            }
        }
        // settled sequences swapped dense tail bytes for compressed
        // bytes: re-account them (ladder included) before admission
        self.resettle_owner_bytes(settled);
        if self.telemetry.on() {
            self.telemetry.compress_backlog.set(self.compressor.backlog_groups() as u64);
        }
    }

    /// Post-round harvest: hand every sequence's newly exited groups to
    /// the worker pool as detached jobs, overlapped with everything the
    /// engine does until the next settle. The `seq.compress` fault is
    /// *consulted* here, on the engine thread, once per harvested group
    /// — deterministic under a pinned seed regardless of worker
    /// interleaving — and *fires* inside the job.
    fn harvest_compressions(&mut self) {
        if !self.deferred_on() {
            return;
        }
        let mut stalls = 0u64;
        let mut any = false;
        for s in &mut self.active {
            if let SeqState::Native(kv) = &mut s.state {
                stalls += kv.take_stalls();
                any |= kv.pending_groups() > 0;
            }
        }
        if stalls > 0 {
            self.telemetry.compress_stalls.add(stalls);
        }
        if any {
            self.ensure_pool();
            let Engine { active, pool, compressor, faults, .. } = self;
            let pool = pool.as_ref().expect("ensure_pool");
            let mut jobs = 0u64;
            for s in active.iter_mut() {
                let SeqState::Native(kv) = &mut s.state else {
                    continue;
                };
                let groups = kv.pending_groups();
                if groups == 0 {
                    continue;
                }
                let fails: Vec<bool> = (0..groups).map(|_| faults.fire("seq.compress")).collect();
                jobs += compressor.submit_pending(pool, s.owner, kv, &fails);
            }
            if jobs > 0 {
                self.telemetry.compress_jobs.add(jobs);
            }
        }
        if self.telemetry.on() {
            self.telemetry.compress_backlog.set(self.compressor.backlog_groups() as u64);
        }
    }

    /// Finish a sequence that ran out of pool even with the whole budget
    /// to itself (nothing reclaimable remains).
    fn reject_finish(&mut self, s: ActiveSeq) {
        self.metrics.rejected += 1;
        self.metrics.rejected_capacity += 1;
        self.completions.push(s.into_completion(FinishReason::Rejected, None, (0, 0)));
    }

    /// (compressed, dense-equivalent) KV bytes a sequence state holds.
    fn seq_kv_bytes(&self, state: &SeqState) -> (usize, usize) {
        match state {
            SeqState::Native(kv) => kv.memory_bytes(),
            SeqState::Pjrt(seq) => {
                self.pjrt.as_ref().map(|p| p.seq_memory_bytes(seq)).unwrap_or((0, 0))
            }
        }
    }

    /// Fold a retiring sequence's footprint into the peak metrics —
    /// every exit path (finish, cancel, fail) must do this, or
    /// cancel-heavy runs under-report the memory the pool really held.
    fn note_kv_peaks(&mut self, kv: (usize, usize)) {
        self.metrics.peak_kv_bytes = self.metrics.peak_kv_bytes.max(kv.0);
        self.metrics.peak_kv_dense_bytes = self.metrics.peak_kv_dense_bytes.max(kv.1);
    }

    fn seq_finished(&self, s: &ActiveSeq) -> bool {
        // a mid-prefill sequence has produced nothing yet — even a
        // degenerate `max_new_tokens == 0` request must land its first
        // token before the length check can fire
        if s.prefill.is_some() {
            return false;
        }
        if s.generated.len() >= s.req.max_new_tokens {
            return true;
        }
        if let (Some(stop), Some(&last)) = (s.req.stop_token, s.generated.last()) {
            if last == stop {
                return true;
            }
        }
        false
    }

    /// One decode round over the decodable sequences (mid-prefill ones
    /// are skipped — they have no token to extend yet). Returns how
    /// many tokens landed, for the step-level inter-token histogram.
    fn decode_round(&mut self) -> Result<usize> {
        let n_decodable = self.active.iter().filter(|s| s.prefill.is_none()).count();
        if n_decodable == 0 {
            return Ok(0);
        }
        self.metrics.decode_rounds += 1;
        self.metrics.note_batch(n_decodable);
        let batch = n_decodable;
        let round_t0 = Instant::now();
        let mut landed = 0usize;

        match self.cfg.backend {
            Backend::NativeDense | Backend::NativeSparse => {
                // Sequences are independent: decode them in parallel
                // (the CPU analogue of GPU batch parallelism) on the
                // persistent worker pool — no per-round thread spawning.
                // Each sequence's step runs under catch_unwind, so a
                // panic or decode error poisons only that sequence.
                let n = n_decodable;
                let outcomes: Vec<DecodeOutcome> = if n > 1 {
                    let workers = crate::util::threads().min(self.cfg.max_batch.max(1));
                    let tel = Arc::clone(&self.telemetry);
                    let pool =
                        self.pool.get_or_insert_with(|| WorkerPool::new_with_telemetry(workers, tel));
                    let model: &NativeModel = &self.model;
                    let faults = &self.faults;
                    let mut slots: Vec<Option<DecodeOutcome>> = (0..n).map(|_| None).collect();
                    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = self
                        .active
                        .iter_mut()
                        .filter(|s| s.prefill.is_none())
                        .zip(slots.iter_mut())
                        .map(|(s, slot)| {
                            let job: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                                *slot = Some(decode_step_isolated(model, faults, s, true))
                            });
                            job
                        })
                        .collect();
                    pool.run_scoped(jobs);
                    slots
                        .into_iter()
                        .map(|r| {
                            // a dropped job (worker died before writing
                            // its slot) fails one sequence, not the batch
                            r.unwrap_or_else(|| {
                                DecodeOutcome::Failed(crate::Error::Engine(
                                    "decode job dropped".into(),
                                ))
                            })
                        })
                        .collect()
                } else {
                    let model = Arc::clone(&self.model);
                    let faults = self.faults.clone();
                    self.active
                        .iter_mut()
                        .filter(|s| s.prefill.is_none())
                        .map(|s| decode_step_isolated(&model, &faults, s, false))
                        .collect()
                };
                // count each token as it lands: failed sequences leave
                // their earlier tokens in `generated`, and their Error
                // completions carry them — the `generated_tokens ==
                // Σ completion lengths` invariant must include them
                let mut casualties: Vec<(OwnerId, String, bool)> = Vec::new();
                let decodable = self.active.iter_mut().filter(|s| s.prefill.is_none());
                for (s, o) in decodable.zip(outcomes) {
                    match o {
                        DecodeOutcome::Token(tok) => {
                            s.generated.push(tok);
                            s.pos += 1;
                            self.metrics.generated_tokens += 1;
                            landed += 1;
                        }
                        DecodeOutcome::Failed(e) => {
                            casualties.push((s.owner, e.to_string(), false));
                        }
                        DecodeOutcome::Panicked(msg) => {
                            let msg = format!("isolated panic during decode: {msg}");
                            casualties.push((s.owner, msg, true));
                        }
                    }
                }
                // retire poisoned sequences: pages released, waiter
                // answered with an Error finish, the batch keeps going
                for (owner, msg, panicked) in casualties {
                    let Some(idx) = self.active.iter().position(|s| s.owner == owner) else {
                        continue;
                    };
                    let s = self.active.swap_remove(idx);
                    let kv = self.seq_kv_bytes(&s.state);
                    self.note_kv_peaks(kv);
                    self.kvpool.release(s.owner);
                    self.metrics.failed += 1;
                    let kind = if panicked { "decode_panic" } else { "decode_fail" };
                    self.recorder.note(kind, s.req.id, s.generated.len() as u64);
                    if panicked {
                        self.metrics.isolated_panics += 1;
                        self.recorder.trigger_auto_dump("panic isolated in decode");
                    }
                    self.completions.push(s.into_completion(
                        FinishReason::Error,
                        Some(msg),
                        kv,
                    ));
                }
            }
            Backend::PjrtDense | Backend::PjrtSparse => {
                let Some(pj) = self.pjrt.as_ref() else {
                    return Err(crate::Error::Engine(
                        "pjrt backend selected but not constructed".into(),
                    ));
                };
                for s in self.active.iter_mut() {
                    let Some(&last) = s.generated.last() else {
                        return Err(crate::Error::Engine(
                            "active sequence has no seed token".into(),
                        ));
                    };
                    let SeqState::Pjrt(seq) = &mut s.state else {
                        return Err(crate::Error::Engine(
                            "pjrt decode on a non-pjrt sequence state".into(),
                        ));
                    };
                    let logits = pj.decode(seq, last, s.pos)?;
                    s.generated.push(argmax(&logits));
                    s.pos += 1;
                    self.metrics.generated_tokens += 1;
                    landed += 1;
                }
            }
        }

        if self.telemetry.on() {
            let round_us = telemetry::us(round_t0.elapsed());
            self.telemetry.decode_round_us.record(round_us);
            // (inter-token latency is recorded by `step` over the whole
            // round — prefill chunks included — so chunked-prefill
            // interference is visible in that histogram)
            let end_us = self.telemetry.now_us();
            self.spans.push(Span {
                name: "decode_round",
                tid: 0,
                ts_us: end_us.saturating_sub(round_us),
                dur_us: round_us,
                args: vec![("batch", batch as u64), ("landed", landed as u64)],
            });
        }

        // retire finished sequences
        let mut i = 0;
        while i < self.active.len() {
            if self.seq_finished(&self.active[i]) {
                let s = self.active.swap_remove(i);
                self.finish(s);
            } else {
                i += 1;
            }
        }
        Ok(landed)
    }

    fn finish(&mut self, s: ActiveSeq) {
        self.kvpool.release(s.owner);
        let kv = self.seq_kv_bytes(&s.state);
        self.note_kv_peaks(kv);
        // end-to-end latency from submission (includes queue time)
        let total_ms = s.req.submitted.elapsed().as_secs_f64() * 1e3;
        self.metrics.note_request_ms(total_ms);
        self.metrics.completions += 1;
        self.recorder.note("finish", s.req.id, s.generated.len() as u64);
        if self.telemetry.on() {
            self.push_request_spans(&s, total_ms);
        }

        let finish = if s
            .req
            .stop_token
            .map(|st| s.generated.last() == Some(&st))
            .unwrap_or(false)
        {
            FinishReason::Stop
        } else {
            FinishReason::Length
        };
        self.completions.push(s.into_completion(finish, None, kv));
    }

    /// Stamp one finished request's lifecycle onto the span ring:
    /// `request` ⊇ `queued` → `prefill` → `decode`, all on the
    /// request's route lane. Child boundaries are clamped inside the
    /// parent so nesting is monotone even when the rounded phase
    /// timings disagree by a microsecond.
    fn push_request_spans(&mut self, s: &ActiveSeq, total_ms: f64) {
        let end_us = self.telemetry.now_us();
        let total_us = (total_ms * 1e3).max(0.0) as u64;
        let start_us = end_us.saturating_sub(total_us);
        let tid = s.req.route;
        let id = s.req.id;
        let q_end = (start_us + (s.queue_ms * 1e3).max(0.0) as u64).min(end_us);
        let p_end = (q_end + (s.prefill_ms * 1e3).max(0.0) as u64).min(end_us);
        let tokens = s.generated.len() as u64;
        self.spans.push(Span {
            name: "request",
            tid,
            ts_us: start_us,
            dur_us: total_us,
            args: vec![("id", id), ("tokens", tokens)],
        });
        self.spans.push(Span {
            name: "queued",
            tid,
            ts_us: start_us,
            dur_us: q_end - start_us,
            args: vec![("id", id)],
        });
        self.spans.push(Span {
            name: "prefill",
            tid,
            ts_us: q_end,
            dur_us: p_end - q_end,
            args: vec![("id", id)],
        });
        self.spans.push(Span {
            name: "decode",
            tid,
            ts_us: p_end,
            dur_us: end_us - p_end,
            args: vec![("id", id), ("tokens", tokens)],
        });
    }

    /// Cancel a request anywhere in its lifetime, keyed by
    /// `Request::route`. A queued request (including one a preemption
    /// put back at the head — it must not be resurrected by
    /// `requeue_front`) is removed from the scheduler; an active
    /// sequence is dropped from the batch mid-round and its pool pages
    /// are released *immediately* — private compressed regions and
    /// dense tails are freed, while a refcounted shared prefix is only
    /// decref'd (dropping the `Arc`), leaving the cache-charged pages
    /// resident for other sequences but unpinned for LRU eviction.
    ///
    /// Emits a `FinishReason::Cancelled` completion carrying whatever
    /// tokens were generated (keeping the `generated_tokens == Σ
    /// completion lengths` invariant). Returns false when the request
    /// is not in flight — a cancel racing the natural completion is a
    /// no-op, so the client is answered exactly once.
    pub fn cancel(&mut self, route: u64) -> bool {
        if let Some(req) = self.scheduler.remove_by_id(route) {
            self.metrics.cancelled += 1;
            self.recorder.note("cancel", req.id, 0);
            self.completions.push(Completion::queued(
                req.id,
                req.route,
                req.submitted,
                FinishReason::Cancelled,
                None,
            ));
            return true;
        }
        let Some(idx) = self.active.iter().position(|s| s.req.route == route) else {
            return false;
        };
        let s = self.active.swap_remove(idx);
        let kv = self.seq_kv_bytes(&s.state);
        self.note_kv_peaks(kv);
        let freed = self.kvpool.release(s.owner);
        self.metrics.cancelled += 1;
        self.metrics.cancelled_freed_bytes += freed;
        self.recorder.note("cancel", s.req.id, s.generated.len() as u64);
        // s.state drops inside into_completion: private buffers are
        // gone (their pool charge was released above) and any shared
        // prefix decrefs without freeing the cache-charged pages
        self.completions.push(s.into_completion(FinishReason::Cancelled, None, kv));
        true
    }

    /// Fail every in-flight request — queued and active — back to its
    /// waiter with a `FinishReason::Error` completion carrying `err`,
    /// releasing all held pool pages. The server calls this when
    /// `step()` errors so no client hangs forever on a wedged batch;
    /// the engine itself is left empty and can keep serving. Returns
    /// how many requests were failed.
    pub fn fail_inflight(&mut self, err: &str) -> usize {
        let mut n = 0;
        while let Some(req) = self.scheduler.pop_front() {
            self.completions.push(Completion::queued(
                req.id,
                req.route,
                req.submitted,
                FinishReason::Error,
                Some(err.to_string()),
            ));
            n += 1;
        }
        for s in std::mem::take(&mut self.active) {
            let kv = self.seq_kv_bytes(&s.state);
            self.note_kv_peaks(kv);
            self.kvpool.release(s.owner);
            self.completions
                .push(s.into_completion(FinishReason::Error, Some(err.to_string()), kv));
            n += 1;
        }
        self.metrics.failed += n;
        if n > 0 {
            self.recorder.note("fail_inflight", n as u64, 0);
        }
        n
    }

    /// chrome://tracing JSON of the most recent `n` spans (0 = all
    /// retained). Serves the `{"trace": n}` line and `--trace-out`.
    pub fn trace_json(&self, n: usize) -> crate::fmt::Json {
        self.telemetry.trace_queries.inc();
        self.spans.chrome_json(n)
    }

    /// Flight-recorder dump (the `{"dump"}` line).
    pub fn dump_json(&self) -> crate::fmt::Json {
        self.telemetry.dump_queries.inc();
        self.recorder.dump_json()
    }

    /// The retained trace-span ring (tests/introspection).
    pub fn spans(&self) -> &SpanRing {
        &self.spans
    }

    /// The flight recorder (tests/introspection).
    pub fn recorder(&self) -> &FlightRecorder {
        &self.recorder
    }

    /// High-water mark of the admission queue since startup.
    pub fn peak_queued(&self) -> usize {
        self.scheduler.peak_pending()
    }

    /// Generated-token count of an in-flight request by routing key:
    /// `Some(0)` while queued, `Some(n)` while active, `None` once
    /// finished/cancelled (or never submitted). Drives disconnect
    /// traces ("cancel after k tokens") and cancellation tests.
    pub fn progress(&self, route: u64) -> Option<usize> {
        if self.scheduler.contains(route) {
            return Some(0);
        }
        self.active.iter().find(|s| s.req.route == route).map(|s| s.generated.len())
    }

    /// Number of sequences currently decoding (stats endpoint).
    pub fn active_count(&self) -> usize {
        self.active.len()
    }

    /// Number of requests waiting in the admission queue.
    pub fn queued_count(&self) -> usize {
        self.scheduler.pending()
    }
}

/// One sequence's decode step, every failure as data.
enum DecodeOutcome {
    Token(u16),
    Failed(crate::Error),
    Panicked(String),
}

/// Best-effort text of a caught panic payload.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s
    } else {
        "opaque panic payload"
    }
}

/// Run one sequence's decode step under `catch_unwind`: panics (from
/// the kernel stack or an injected `worker.task` fault on the pooled
/// path) and `Err`s (including injected `seq.decode` faults) come back
/// as data for per-sequence retirement instead of unwinding the engine
/// or a worker thread.
fn decode_step_isolated(
    model: &NativeModel,
    faults: &Injector,
    s: &mut ActiveSeq,
    pooled: bool,
) -> DecodeOutcome {
    let out = catch_unwind(AssertUnwindSafe(|| {
        if pooled && faults.fire("worker.task") {
            panic!("injected fault: worker.task");
        }
        if faults.fire("seq.decode") {
            return Err(crate::Error::Engine("injected fault: seq.decode".into()));
        }
        decode_one_native(model, s)
    }));
    match out {
        Ok(Ok(tok)) => DecodeOutcome::Token(tok),
        Ok(Err(e)) => DecodeOutcome::Failed(e),
        Err(payload) => DecodeOutcome::Panicked(panic_message(payload.as_ref()).to_string()),
    }
}

fn decode_one_native(model: &NativeModel, s: &mut ActiveSeq) -> Result<u16> {
    let Some(&last) = s.generated.last() else {
        return Err(crate::Error::Engine("active sequence has no seed token".into()));
    };
    let pos = s.pos;
    let ActiveSeq { state, scratch, .. } = s;
    let SeqState::Native(kv) = state else {
        return Err(crate::Error::Engine("native decode on a non-native sequence state".into()));
    };
    model.decode_into(last, pos, kv, scratch)?;
    Ok(argmax(&scratch.logits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Backend, ModelConfig};
    use crate::coordinator::scheduler::estimate_seq_bytes;
    use crate::model::Weights;

    fn tiny_model_cfg(n_heads: usize, n_kv_heads: usize, head_dim: usize) -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            d_model: 64,
            n_layers: 2,
            n_heads,
            n_kv_heads,
            head_dim,
            ff: 128,
            vocab: 512,
            rope_theta: 10000.0,
            max_seq: 1024,
            norm_eps: 1e-5,
        }
    }

    fn tiny_engine_gqa(
        backend: Backend,
        sparsity: (f64, f64),
        n_heads: usize,
        n_kv_heads: usize,
        head_dim: usize,
    ) -> Engine {
        let cfg = tiny_model_cfg(n_heads, n_kv_heads, head_dim);
        let model = NativeModel::new(Weights::random_for_tests(cfg, 42));
        let mut ec = EngineConfig::default();
        ec.backend = backend;
        ec.sparsity = crate::config::SparsityConfig::mustafar(sparsity.0, sparsity.1);
        ec.max_batch = 4;
        ec.max_new_tokens = 8;
        Engine::new_native(model, ec)
    }

    fn tiny_engine(backend: Backend, sparsity: (f64, f64)) -> Engine {
        tiny_engine_gqa(backend, sparsity, 2, 1, 32)
    }

    fn reqs(n: u64, prompt_len: usize, gen: usize) -> Vec<Request> {
        (0..n)
            .map(|i| {
                let prompt: Vec<u16> =
                    (0..prompt_len).map(|j| ((i as usize * 31 + j) % 400 + 16) as u16).collect();
                Request::new(i, prompt, gen)
            })
            .collect()
    }

    #[test]
    fn trace_completes_all_requests() {
        let mut e = tiny_engine(Backend::NativeDense, (0.0, 0.0));
        let out = e.run_trace(reqs(6, 40, 5)).unwrap();
        assert_eq!(out.len(), 6);
        for c in &out {
            assert_eq!(c.tokens.len(), 5);
            assert_eq!(c.finish, FinishReason::Length);
        }
        assert_eq!(e.metrics.completions, 6);
        assert_eq!(e.metrics.generated_tokens, 30);
        // continuous batching: max 4 at a time
        assert!(e.metrics.batch_hist.max() <= 4);
        assert!(e.metrics.batch_hist.count() > 0);
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.5, 0.5));
        let out = e.run_trace(reqs(9, 80, 4)).unwrap();
        let mut ids: Vec<u64> = out.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn sparse_backend_compresses_kv() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.7, 0.7));
        let out = e.run_trace(reqs(2, 160, 4)).unwrap();
        for c in &out {
            assert!(c.kv_bytes < c.kv_dense_bytes, "{} vs {}", c.kv_bytes, c.kv_dense_bytes);
        }
        assert!(e.metrics.kv_compression_rate() < 0.8);
    }

    #[test]
    fn stop_token_halts_generation() {
        let mut e = tiny_engine(Backend::NativeDense, (0.0, 0.0));
        let mut rs = reqs(1, 24, 8);
        // stop on whatever token the model produces first
        let probe = e.run_trace(rs.clone()).unwrap();
        let first = probe[0].tokens[0];
        rs[0].stop_token = Some(first);
        rs[0].id = 77;
        let mut e2 = tiny_engine(Backend::NativeDense, (0.0, 0.0));
        let out = e2.run_trace(rs).unwrap();
        assert_eq!(out[0].tokens.len(), 1);
        assert_eq!(out[0].finish, FinishReason::Stop);
    }

    #[test]
    fn dense_and_sparse_agree_on_short_context() {
        // With only ~60 tokens everything stays in the local window+group,
        // so sparse output must equal dense output exactly.
        let r = reqs(1, 60, 6);
        let mut ed = tiny_engine(Backend::NativeDense, (0.0, 0.0));
        let mut es = tiny_engine(Backend::NativeSparse, (0.7, 0.7));
        let a = ed.run_trace(r.clone()).unwrap();
        let b = es.run_trace(r).unwrap();
        assert_eq!(a[0].tokens, b[0].tokens);
    }

    #[test]
    fn gqa_dense_and_sparse_agree_on_short_context() {
        // n_heads > n_kv_heads exercises the fused multi-query decode
        // path (one compressed-stream walk per KV head for the whole
        // query group); short-context parity must survive the refactor.
        for (nh, nkv) in [(4, 2), (4, 1), (8, 2)] {
            let r = reqs(2, 60, 6);
            let mut ed = tiny_engine_gqa(Backend::NativeDense, (0.0, 0.0), nh, nkv, 32);
            let mut es = tiny_engine_gqa(Backend::NativeSparse, (0.7, 0.7), nh, nkv, 32);
            let a = ed.run_trace(r.clone()).unwrap();
            let b = es.run_trace(r).unwrap();
            for (ca, cb) in a.iter().zip(&b) {
                assert_eq!(ca.tokens, cb.tokens, "nh={nh} nkv={nkv}");
            }
        }
    }

    #[test]
    fn gqa_long_context_sparse_decode_completes() {
        // Long enough to push groups through compression during decode
        // with group > 1 (fused path over a non-empty compressed region).
        // head_dim = 32 exercises the partial channel tiles of the
        // value cache (the former seed bug left hd < 64 silently empty);
        // head_dim = 64 covers the full-tile path.
        for hd in [32usize, 64] {
            let mut e = tiny_engine_gqa(Backend::NativeSparse, (0.6, 0.6), 4, 2, hd);
            let out = e.run_trace(reqs(2, 160, 8)).unwrap();
            assert_eq!(out.len(), 2);
            for c in &out {
                assert_eq!(c.tokens.len(), 8, "hd={hd}");
                assert!(c.kv_bytes < c.kv_dense_bytes, "hd={hd}");
            }
        }
    }

    #[test]
    fn prefix_cache_full_hit_is_token_identical_and_skips_prefill() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.5, 0.5));
        let r = reqs(1, 160, 8);
        let cold = e.run_trace(r.clone()).unwrap();
        assert_eq!(e.metrics.prefix_misses, 1);
        assert_eq!(e.metrics.prefix_full_hits, 0);
        let prefill_after_cold = e.metrics.prefill_tokens;

        // same prompt again: full hit, no prefill work, identical tokens
        let mut again = r.clone();
        again[0].id = 1;
        let hot = e.run_trace(again).unwrap();
        assert_eq!(e.metrics.prefix_full_hits, 1);
        assert_eq!(e.metrics.prefill_tokens, prefill_after_cold, "prefill was not skipped");
        assert_eq!(e.metrics.prefix_tokens_reused, 160);
        assert_eq!(hot[0].tokens, cold[0].tokens, "full hit must be token-identical");
        assert!(e.metrics.prefix_hit_rate() > 0.4);
    }

    #[test]
    fn prefix_cache_partial_hit_reuses_shared_pages() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.5, 0.5));
        let base = reqs(1, 224, 4);
        e.run_trace(base.clone()).unwrap();
        // (224 - 32) -> prefix boundary at 192 tokens

        // an extending prompt: shares the first 224 tokens, adds 64 more
        let mut longer = base[0].prompt.clone();
        longer.extend((0..64).map(|i| (i * 3 % 300 + 20) as u16));
        let out = e.run_trace(vec![Request::new(9, longer, 4)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].tokens.len(), 4);
        assert_eq!(out[0].finish, FinishReason::Length);
        assert_eq!(e.metrics.prefix_partial_hits, 1);
        assert_eq!(e.metrics.prefix_tokens_reused, 192);
        // only the suffix beyond the shared boundary was prefilled
        assert_eq!(e.metrics.prefill_tokens, 224 + (288 - 192));
    }

    #[test]
    fn prefix_cache_partial_hits_extend_down_a_lineage() {
        // Satellite acceptance: partial-hit sequences populate the cache
        // too, so the *second* partial hit on an extended prompt reuses
        // a longer prefix (previously only cold misses inserted, and a
        // lineage of ever-longer prompts re-prefilled its new tail every
        // time against the original boundary).
        let mut e = tiny_engine(Backend::NativeSparse, (0.5, 0.5));
        let base = reqs(1, 224, 4); // cold: boundary at 192
        e.run_trace(base.clone()).unwrap();

        let mut p2 = base[0].prompt.clone();
        p2.extend((0..64).map(|i| (i * 3 % 300 + 20) as u16)); // 288 tokens
        let run2 = e.run_trace(vec![Request::new(1, p2.clone(), 4)]).unwrap();
        assert_eq!(e.metrics.prefix_partial_hits, 1);
        assert_eq!(e.metrics.prefix_tokens_reused, 192);

        // the partial-hit rebuild extends coverage to the 256 boundary
        // ((288 - 32) rounded down to a group); the next prompt in the
        // lineage must hit *that*, not the original 192.
        let mut p3 = p2.clone();
        p3.extend((0..64).map(|i| (i * 7 % 300 + 20) as u16)); // 352 tokens
        e.run_trace(vec![Request::new(2, p3, 4)]).unwrap();
        assert_eq!(e.metrics.prefix_partial_hits, 2);
        assert_eq!(
            e.metrics.prefix_tokens_reused,
            192 + 256,
            "second partial hit should cover the extended boundary"
        );

        // and an exact repeat of the partial-hit prompt is now a *full*
        // hit that decodes token-identically to its first run
        let again = e.run_trace(vec![Request::new(3, p2, 4)]).unwrap();
        assert_eq!(e.metrics.prefix_full_hits, 1);
        assert_eq!(again[0].tokens, run2[0].tokens, "full hit must be token-identical");

        // accounting stays exact with promoted sequences in play
        assert_eq!(e.pool_stats().live_bytes, e.prefix_cache().measured_bytes());
    }

    #[test]
    fn pool_accounting_is_exact_at_every_step() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.5, 0.5));
        for r in reqs(5, 128, 6) {
            e.submit(r);
        }
        while !e.idle() {
            e.step().unwrap();
            assert_eq!(
                e.pool_stats().live_bytes,
                e.measured_live_bytes(),
                "pool charge drifted from measured bytes"
            );
        }
        // all sequences retired: whatever remains is the prefix cache
        assert_eq!(e.pool_stats().live_bytes, e.prefix_cache().measured_bytes());
        assert_eq!(e.pool_stats().live_bytes, e.prefix_cache().charged_bytes(&e.kvpool));
    }

    #[test]
    fn over_budget_trace_completes_via_reprune_and_preempt() {
        // Acceptance: aggregate KV far exceeds the pool budget, yet every
        // request completes — the pressure ladder degrades and reorders
        // instead of rejecting.
        let cfg = tiny_model_cfg(2, 1, 32);
        let policy = crate::kvcache::KvPolicy::mustafar(0.5, 0.5);
        let per_seq = estimate_seq_bytes(&policy, &cfg, 96 + 160);
        let model = NativeModel::new(Weights::random_for_tests(cfg, 42));
        let mut ec = EngineConfig::default();
        ec.backend = Backend::NativeSparse;
        ec.sparsity = crate::config::SparsityConfig::mustafar(0.5, 0.5);
        ec.max_batch = 3;
        ec.max_new_tokens = 256;
        ec.kv_budget_bytes = per_seq * 2; // 3 full sequences cannot coexist
        ec.kv_page_bytes = 1024;
        let mut e = Engine::new_native(model, ec);

        for r in reqs(3, 96, 160) {
            assert!(e.submit(r), "submit-time rejection defeats the test");
        }
        while !e.idle() {
            e.step().unwrap();
            assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
            assert!(
                e.pool_stats().reserved_bytes <= e.pool_stats().budget_bytes + 1024,
                "budget exceeded: {} > {}",
                e.pool_stats().reserved_bytes,
                e.pool_stats().budget_bytes
            );
        }
        let out = e.take_completions();
        assert_eq!(out.len(), 3);
        for c in &out {
            assert_eq!(c.finish, FinishReason::Length, "id {} finished {:?}", c.id, c.finish);
            assert_eq!(c.tokens.len(), 160, "id {}", c.id);
        }
        assert_eq!(e.metrics.rejected, 0);
        assert!(
            e.metrics.repruned + e.metrics.preempted > 0,
            "pressure ladder never ran (repruned {}, preempted {})",
            e.metrics.repruned,
            e.metrics.preempted
        );
    }

    /// Drive a disconnect trace: submit everything, then between steps
    /// cancel each request whose `cancel_after` threshold its progress
    /// has reached (`honor = false` replays the identical trace with
    /// clients that never hang up — the baseline). Asserts exact pool
    /// accounting around every step, so a cancel that failed to release
    /// its pages (or released shared pages it didn't own) fails here.
    fn run_with_disconnects(
        e: &mut Engine,
        trace: Vec<crate::workload::trace::TraceRequest>,
        honor: bool,
    ) -> Vec<Completion> {
        let mut cancels: Vec<(u64, usize)> = trace
            .iter()
            .filter_map(|t| t.cancel_after.filter(|_| honor).map(|k| (t.id, k)))
            .collect();
        for t in trace {
            assert!(e.submit(Request::new(t.id, t.prompt, t.max_new_tokens)), "submit rejected");
        }
        loop {
            cancels.retain(|&(id, k)| match e.progress(id) {
                Some(g) if g >= k => {
                    assert!(e.cancel(id));
                    false
                }
                Some(_) => true,
                None => false, // finished before the client hung up
            });
            assert_eq!(
                e.pool_stats().live_bytes,
                e.measured_live_bytes(),
                "cancel left the pool charge out of sync"
            );
            if e.idle() {
                break;
            }
            e.step().unwrap();
            assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
        }
        e.take_completions()
    }

    #[test]
    fn cancel_queued_and_active_requests_end_to_end() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.5, 0.5));
        // the tiny-engine cap (8) would clamp these 64-token requests
        // before the mid-decode cancel below could land; raise it
        e.cfg.max_new_tokens = 64;
        // max_batch = 4: four go active, the fifth waits in the queue
        for r in reqs(5, 64, 64) {
            assert!(e.submit(r));
        }
        e.step().unwrap();
        assert_eq!(e.active_count(), 4);
        assert_eq!(e.queued_count(), 1);
        assert_eq!(e.progress(4), Some(0), "queued request reports zero progress");

        // cancel the queued request: removed before it ever prefills
        assert!(e.cancel(4));
        assert_eq!(e.progress(4), None);
        assert_eq!(e.queued_count(), 0);

        // cancel an active request: its pages come back immediately
        let live_before = e.pool_stats().live_bytes;
        assert!(e.cancel(2));
        assert!(e.pool_stats().live_bytes < live_before, "pages not released");
        assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
        assert!(!e.cancel(2), "double cancel is a no-op");
        assert!(e.metrics.cancelled_freed_bytes > 0);

        let out = e.run_trace(Vec::new()).unwrap(); // drain the rest
        assert_eq!(out.len(), 5, "every request answered exactly once");
        for c in &out {
            match c.id {
                4 => {
                    assert_eq!(c.finish, FinishReason::Cancelled);
                    assert!(c.tokens.is_empty(), "queued cancel generated nothing");
                }
                2 => {
                    assert_eq!(c.finish, FinishReason::Cancelled);
                    assert!(!c.tokens.is_empty(), "active cancel keeps partial tokens");
                    assert!(c.tokens.len() < 64);
                }
                _ => {
                    assert_eq!(c.finish, FinishReason::Length);
                    assert_eq!(c.tokens.len(), 64);
                }
            }
        }
        assert_eq!(e.metrics.cancelled, 2);
        // invariant: generated tokens == Σ completion lengths, with
        // cancelled completions carrying their partial output
        let total: usize = out.iter().map(|c| c.tokens.len()).sum();
        assert_eq!(e.metrics.generated_tokens, total);
    }

    #[test]
    fn cancel_racing_completion_is_a_silent_noop() {
        let mut e = tiny_engine(Backend::NativeDense, (0.0, 0.0));
        let out = e.run_trace(reqs(1, 24, 4)).unwrap();
        assert_eq!(out.len(), 1);
        assert!(!e.cancel(0), "already answered");
        assert!(e.take_completions().is_empty(), "no second completion");
        assert_eq!(e.metrics.cancelled, 0);
    }

    #[test]
    fn cancel_decrefs_shared_prefix_without_freeing_it() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.5, 0.5));
        let r = reqs(1, 160, 48);
        e.run_trace(r.clone()).unwrap(); // cold run populates the cache
        let entries = e.prefix_cache().len();
        let cache_bytes = e.prefix_cache().measured_bytes();
        assert_eq!(e.prefix_cache().pinned_partial_entries(), 0);

        // an identical prompt: full hit, the live sequence pins the
        // shared prefix pages
        assert!(e.submit(Request::new(9, r[0].prompt.clone(), 48)));
        e.step().unwrap();
        assert_eq!(e.metrics.prefix_full_hits, 1);
        assert_eq!(e.prefix_cache().pinned_partial_entries(), 1);

        // cancel mid-decode: the shared prefix must decref (unpin) but
        // keep its cache-charged pages; only private state is freed
        assert!(e.cancel(9));
        assert_eq!(e.prefix_cache().pinned_partial_entries(), 0, "prefix not decref'd");
        assert_eq!(e.prefix_cache().len(), entries, "cache entries must survive the cancel");
        assert_eq!(e.prefix_cache().measured_bytes(), cache_bytes);
        assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
        assert_eq!(
            e.pool_stats().live_bytes,
            cache_bytes,
            "after the cancel only the cache is charged"
        );
        assert_eq!(e.metrics.cancelled, 1);
    }

    #[test]
    fn cancelled_request_is_not_resurrected_by_preemption_requeue() {
        // Over-budget setup forces preemption (the youngest goes back
        // to the queue head); cancelling the re-queued victim must
        // remove it for good — requeue_front never resurrects it.
        let cfg = tiny_model_cfg(2, 1, 32);
        let policy = crate::kvcache::KvPolicy::mustafar(0.5, 0.5);
        let per_seq = estimate_seq_bytes(&policy, &cfg, 96 + 160);
        let model = NativeModel::new(Weights::random_for_tests(cfg, 42));
        let mut ec = EngineConfig::default();
        ec.backend = Backend::NativeSparse;
        ec.sparsity = crate::config::SparsityConfig::mustafar(0.5, 0.5);
        ec.max_batch = 3;
        ec.max_new_tokens = 256;
        ec.kv_budget_bytes = per_seq * 2;
        ec.kv_page_bytes = 1024;
        let mut e = Engine::new_native(model, ec);
        for r in reqs(3, 96, 160) {
            assert!(e.submit(r));
        }
        // step until a preemption leaves its victim waiting in the queue
        let mut victim = None;
        for _ in 0..2000 {
            if e.idle() {
                break;
            }
            e.step().unwrap();
            if e.metrics.preempted > 0 {
                // progress == Some(0) can only mean "queued" (an active
                // sequence always has its first token already)
                if let Some(id) = (0..3u64).find(|&id| e.progress(id) == Some(0)) {
                    victim = Some(id);
                    break;
                }
            }
        }
        let victim = victim.expect("pressure never left a preempted request queued");
        assert!(e.cancel(victim));
        while !e.idle() {
            e.step().unwrap();
            assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
        }
        let out = e.take_completions();
        assert_eq!(out.iter().filter(|c| c.id == victim).count(), 1, "answered exactly once");
        for c in &out {
            if c.id == victim {
                assert_eq!(c.finish, FinishReason::Cancelled);
            } else {
                assert_eq!(c.finish, FinishReason::Length, "id {}", c.id);
                assert_eq!(c.tokens.len(), 160);
            }
        }
        assert_eq!(e.metrics.cancelled, 1);
    }

    #[test]
    fn disconnect_trace_frees_pages_and_reduces_pressure_events() {
        // EXPERIMENTS §8 / acceptance: under the same over-budget
        // disconnect-heavy trace, honoring cancellation must strictly
        // reduce repruned + preempted — dead requests release their
        // pages instead of forcing the ladder to degrade live ones.
        let mk = || {
            let cfg = tiny_model_cfg(2, 1, 32);
            let policy = crate::kvcache::KvPolicy::mustafar(0.5, 0.5);
            let per_seq = estimate_seq_bytes(&policy, &cfg, 96 + 160);
            let model = NativeModel::new(Weights::random_for_tests(cfg, 42));
            let mut ec = EngineConfig::default();
            ec.backend = Backend::NativeSparse;
            ec.sparsity = crate::config::SparsityConfig::mustafar(0.5, 0.5);
            ec.max_batch = 4;
            ec.max_new_tokens = 256;
            ec.kv_budget_bytes = per_seq * 2;
            ec.kv_page_bytes = 1024;
            Engine::new_native(model, ec)
        };
        let trace = crate::workload::trace::disconnect_trace(3, 8, 96, 160);
        let n_cancel = trace.iter().filter(|t| t.cancel_after.is_some()).count();
        assert_eq!(n_cancel, 6);

        let mut base_engine = mk();
        let base = run_with_disconnects(&mut base_engine, trace.clone(), false);
        assert_eq!(base.len(), 8);
        assert!(base.iter().all(|c| c.finish == FinishReason::Length));
        let base_pressure = base_engine.metrics.repruned + base_engine.metrics.preempted;
        assert!(base_pressure > 0, "baseline never hit the pressure ladder");

        let mut e = mk();
        let out = run_with_disconnects(&mut e, trace, true);
        assert_eq!(out.len(), 8, "every request answered exactly once");
        assert_eq!(e.metrics.cancelled, 6);
        assert!(e.metrics.cancelled_freed_bytes > 0, "active cancels must free pages");
        assert_eq!(
            out.iter().filter(|c| c.finish == FinishReason::Cancelled).count(),
            6,
            "every disconnect yields a cancelled completion"
        );
        assert_eq!(out.iter().filter(|c| c.finish == FinishReason::Length).count(), 2);
        let pressure = e.metrics.repruned + e.metrics.preempted;
        assert!(
            pressure < base_pressure,
            "cancellation must strictly reduce pressure events ({pressure} vs {base_pressure})"
        );
        // generated == Σ completion lengths even across cancels
        let total: usize = out.iter().map(|c| c.tokens.len()).sum();
        assert_eq!(e.metrics.generated_tokens, total);
    }

    #[test]
    fn fail_inflight_answers_every_waiter_and_drains_the_pool() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.5, 0.5));
        for r in reqs(5, 64, 32) {
            assert!(e.submit(r));
        }
        e.step().unwrap(); // 4 active (max_batch), 1 queued
        assert!(e.active_count() > 0 && e.queued_count() > 0);
        let n = e.fail_inflight("engine step failed: test");
        assert_eq!(n, 5);
        assert_eq!(e.metrics.failed, 5);
        assert!(e.idle(), "engine drained");
        let out = e.take_completions();
        assert_eq!(out.len(), 5);
        for c in &out {
            assert_eq!(c.finish, FinishReason::Error);
            assert_eq!(c.error.as_deref(), Some("engine step failed: test"));
        }
        // every sequence's pages came back; only the prefix cache remains
        assert_eq!(e.pool_stats().live_bytes, e.prefix_cache().measured_bytes());
        assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
    }

    #[test]
    fn out_of_vocab_prompt_is_rejected_at_submit_not_panicking_the_forward() {
        // One bad token id would assert inside the embedding lookup
        // and panic the engine thread — a remotely triggerable hang of
        // every waiter (a panic, not the Err that fail_inflight
        // handles). The boundary check must refuse it instead.
        let mut e = tiny_engine(Backend::NativeDense, (0.0, 0.0));
        let vocab = e.model.cfg().vocab as u16;
        assert!(!e.submit(Request::new(1, vec![1, 2, vocab], 4)));
        assert!(!e.submit(Request::new(2, vec![u16::MAX], 4)));
        // an empty prompt would slice (t - 1) * d in prefill — same
        // panic class, same boundary rejection
        assert!(!e.submit(Request::new(3, Vec::new(), 4)));
        assert_eq!(e.metrics.rejected, 3);
        assert!(e.idle(), "rejected requests must not enter the queue");
        // a valid request still runs on the same engine
        let out = e.run_trace(reqs(1, 16, 3)).unwrap();
        assert_eq!(out[0].finish, FinishReason::Length);
        // trace mode answers the rejection instead of dropping it
        let out = e.run_trace(vec![Request::new(9, vec![u16::MAX], 2)]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 9);
        assert_eq!(out[0].finish, FinishReason::Rejected);
    }

    #[test]
    fn step_error_fails_the_popped_request_instead_of_losing_it() {
        // A PJRT backend selected but never constructed makes
        // start_request fail — the canonical reachable step() error.
        // The popped request must get an Error completion (its waiter
        // is answered), not silently vanish into the propagated error.
        let cfg = tiny_model_cfg(2, 1, 32);
        let model = NativeModel::new(Weights::random_for_tests(cfg, 42));
        let mut ec = EngineConfig::default();
        ec.backend = Backend::PjrtSparse;
        let mut e = Engine::new_native(model, ec);
        assert!(e.submit(Request::new(1, vec![5; 32], 4)));
        assert!(e.step().is_err());
        let out = e.take_completions();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].id, 1);
        assert_eq!(out[0].finish, FinishReason::Error);
        assert!(out[0].error.as_deref().unwrap_or("").contains("pjrt"));
        assert_eq!(e.metrics.failed, 1);
        assert!(e.idle(), "the failed request is not stuck in the engine");
    }

    #[test]
    fn max_new_tokens_is_clamped_to_the_config_cap() {
        // tiny_engine caps max_new_tokens at 8: a request asking for 64
        // is clamped (finishes Length at the cap), not rejected
        let mut e = tiny_engine(Backend::NativeDense, (0.0, 0.0));
        let out = e.run_trace(reqs(1, 24, 64)).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].finish, FinishReason::Length);
        assert_eq!(out[0].tokens.len(), 8, "clamped to the cap, not the request");
        assert_eq!(e.metrics.rejected, 0);
    }

    #[test]
    fn stale_queued_requests_time_out_via_ttl() {
        let cfg = tiny_model_cfg(2, 1, 32);
        let model = NativeModel::new(Weights::random_for_tests(cfg, 42));
        let mut ec = EngineConfig::default();
        ec.backend = Backend::NativeDense;
        ec.max_batch = 1; // the second request waits in the queue
        ec.max_queue_ms = 1;
        let mut e = Engine::new_native(model, ec);
        for r in reqs(2, 48, 8) {
            assert!(e.submit(r));
        }
        e.step().unwrap(); // admits request 0 before any wait accrues
        std::thread::sleep(std::time::Duration::from_millis(20));
        while !e.idle() {
            e.step().unwrap();
            assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
        }
        let out = e.take_completions();
        assert_eq!(out.len(), 2, "every request answered exactly once");
        let c0 = out.iter().find(|c| c.id == 0).unwrap();
        let c1 = out.iter().find(|c| c.id == 1).unwrap();
        assert_eq!(c0.finish, FinishReason::Length, "the running request is untouched");
        assert_eq!(c1.finish, FinishReason::Timeout);
        assert!(c1.tokens.is_empty(), "timed out while queued: nothing generated");
        assert_eq!(e.metrics.timed_out_queued, 1);
        assert_eq!(e.metrics.deadline_exceeded, 0);
    }

    #[test]
    fn per_request_deadline_cuts_an_active_sequence() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.5, 0.5));
        e.cfg.max_new_tokens = 10_000; // decode long enough to expire
        let mut r = reqs(1, 64, 10_000).remove(0);
        r.deadline_ms = Some(30);
        assert!(e.submit(r));
        let deadline = Instant::now() + std::time::Duration::from_secs(60);
        while !e.idle() {
            assert!(Instant::now() < deadline, "deadline never enforced");
            e.step().unwrap();
            assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
        }
        let out = e.take_completions();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].finish, FinishReason::Timeout);
        assert!(!out[0].tokens.is_empty(), "partial tokens ride the timeout completion");
        assert!(out[0].tokens.len() < 10_000);
        assert_eq!(e.metrics.deadline_exceeded, 1);
        // the partial tokens keep the throughput invariant exact
        assert_eq!(e.metrics.generated_tokens, out[0].tokens.len());
        assert_eq!(e.pool_stats().live_bytes, e.prefix_cache().measured_bytes());
    }

    #[test]
    fn saturated_queue_sheds_with_a_retry_hint() {
        let cfg = tiny_model_cfg(2, 1, 32);
        let model = NativeModel::new(Weights::random_for_tests(cfg, 42));
        let mut ec = EngineConfig::default();
        ec.backend = Backend::NativeDense;
        ec.queue_cap = 1;
        let mut e = Engine::new_native(model, ec);
        let mut rs = reqs(3, 16, 2).into_iter();
        assert_eq!(e.submit_full(rs.next().unwrap()), SubmitOutcome::Queued);
        match e.submit_full(rs.next().unwrap()) {
            SubmitOutcome::Shed { retry_after_ms } => {
                assert!(retry_after_ms > 0, "hint must be actionable");
            }
            other => panic!("expected Shed, got {other:?}"),
        }
        assert_eq!(e.metrics.shed, 1);
        assert_eq!(e.metrics.rejected, 0, "shed is retryable, not a rejection");
        // trace mode answers a shed request with a Shed completion
        let out = e.run_trace(vec![rs.next().unwrap()]).unwrap();
        let shed: Vec<_> =
            out.iter().filter(|c| c.finish == FinishReason::Shed).collect();
        assert_eq!(shed.len(), 1);
        assert!(shed[0].retry_after_ms.is_some());
        assert_eq!(e.metrics.shed, 2);
    }

    #[test]
    fn injected_decode_fault_isolates_one_round_not_the_engine() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.5, 0.5));
        // every seq.decode consult after the 4th fails: the first round
        // of a 2-sequence batch passes, later rounds poison sequences
        e.set_fault_injector(
            crate::faults::Injector::parse("seq.decode:after=4", 7).unwrap(),
        );
        let out = e.run_trace(reqs(2, 40, 8)).unwrap();
        assert_eq!(out.len(), 2, "every request answered exactly once");
        for c in &out {
            assert_eq!(c.finish, FinishReason::Error);
            assert!(c.error.as_deref().unwrap_or("").contains("seq.decode"));
            assert!(!c.tokens.is_empty(), "pre-fault tokens ride the Error completion");
        }
        assert_eq!(e.metrics.failed, 2);
        assert_eq!(e.metrics.isolated_panics, 0, "an Err is not a panic");
        let total: usize = out.iter().map(|c| c.tokens.len()).sum();
        assert_eq!(e.metrics.generated_tokens, total);
        assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
        // the engine survives: a fresh fault-free run still completes
        e.set_fault_injector(crate::faults::Injector::disabled());
        let ok = e.run_trace(reqs(1, 24, 3)).unwrap();
        assert_eq!(ok[0].finish, FinishReason::Length);
    }

    #[test]
    fn injected_worker_panic_is_contained_to_its_sequence() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.5, 0.5));
        // 4 sequences/round on the pooled path: hits 1-6 pass, so the
        // first rounds are clean, then panics start landing mid-batch
        e.set_fault_injector(
            crate::faults::Injector::parse("worker.task:after=6", 11).unwrap(),
        );
        let out = e.run_trace(reqs(4, 40, 8)).unwrap();
        assert_eq!(out.len(), 4, "every request answered exactly once");
        let errs = out.iter().filter(|c| c.finish == FinishReason::Error).count();
        assert!(errs >= 1, "injected panics must surface as Error completions");
        for c in out.iter().filter(|c| c.finish == FinishReason::Error) {
            assert!(c.error.as_deref().unwrap_or("").contains("isolated panic"));
        }
        assert_eq!(e.metrics.isolated_panics, errs);
        let total: usize = out.iter().map(|c| c.tokens.len()).sum();
        assert_eq!(e.metrics.generated_tokens, total);
        assert_eq!(
            e.pool_stats().live_bytes,
            e.prefix_cache().measured_bytes(),
            "poisoned sequences released their pages"
        );
    }

    #[test]
    fn injected_prefill_panic_is_contained_to_its_request() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.5, 0.5));
        // first prefill passes, every later one panics
        e.set_fault_injector(
            crate::faults::Injector::parse("seq.prefill:after=1", 5).unwrap(),
        );
        let out = e.run_trace(reqs(3, 40, 4)).unwrap();
        assert_eq!(out.len(), 3, "every request answered exactly once");
        let mut ok = 0;
        for c in &out {
            match c.finish {
                FinishReason::Length => ok += 1,
                FinishReason::Error => {
                    assert!(c
                        .error
                        .as_deref()
                        .unwrap_or("")
                        .contains("isolated panic during prefill"));
                    assert!(c.tokens.is_empty());
                }
                other => panic!("unexpected finish {other:?}"),
            }
        }
        assert_eq!(ok, 1);
        assert_eq!(e.metrics.isolated_panics, 2);
        assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
    }

    #[test]
    fn queue_ms_reports_admission_minus_submission() {
        let cfg = tiny_model_cfg(2, 1, 32);
        let model = NativeModel::new(Weights::random_for_tests(cfg, 42));
        let mut ec = EngineConfig::default();
        ec.backend = Backend::NativeDense;
        ec.max_batch = 1; // the second request must wait for the first
        let mut e = Engine::new_native(model, ec);
        let out = e.run_trace(reqs(2, 64, 8)).unwrap();
        let c0 = out.iter().find(|c| c.id == 0).unwrap();
        let c1 = out.iter().find(|c| c.id == 1).unwrap();
        assert!(c1.queue_ms > 0.0, "queued request reports zero queue time");
        assert!(
            c1.queue_ms > c0.queue_ms,
            "request 1 waited a full request ({} vs {})",
            c1.queue_ms,
            c0.queue_ms
        );
    }

    /// Engine with explicit chunk/budget knobs — the chunked-prefill
    /// test harness (sparse backend, same weights/seed as tiny_engine).
    fn chunked_engine(chunk: usize, budget: usize) -> Engine {
        let cfg = tiny_model_cfg(2, 1, 32);
        let model = NativeModel::new(Weights::random_for_tests(cfg, 42));
        let mut ec = EngineConfig::default();
        ec.backend = Backend::NativeSparse;
        ec.sparsity = crate::config::SparsityConfig::mustafar(0.5, 0.5);
        ec.max_batch = 4;
        ec.max_new_tokens = 8;
        ec.prefill_chunk_tokens = chunk;
        ec.round_token_budget = budget;
        Engine::new_native(model, ec)
    }

    #[test]
    fn chunked_prefill_is_token_identical_across_chunk_sizes_and_budgets() {
        // Acceptance: chunk boundaries are invisible to the kernel —
        // every (chunk, budget) combination must produce bit-identical
        // token streams vs run-to-completion, including chunk sizes
        // that are not group-aligned and prompt lengths that are not
        // chunk multiples (137, 200).
        let trace = || {
            vec![
                Request::new(0, (0..137).map(|j| ((j * 11) % 400 + 16) as u16).collect(), 8),
                Request::new(1, (0..200).map(|j| ((j * 5 + 3) % 400 + 16) as u16).collect(), 8),
                Request::new(2, (0..64).map(|j| ((j * 17 + 9) % 400 + 16) as u16).collect(), 8),
            ]
        };
        let collect = |mut e: Engine| {
            let mut out = e.run_trace(trace()).unwrap();
            out.sort_by_key(|c| c.id);
            assert!(out.iter().all(|c| c.finish == FinishReason::Length));
            (out.into_iter().map(|c| c.tokens).collect::<Vec<_>>(), e)
        };
        let (baseline, _) = collect(chunked_engine(0, 0)); // run-to-completion
        for (chunk, budget) in [(16, 0), (64, 0), (100, 0), (0, 48), (16, 48), (64, 24)] {
            let (tokens, e) = collect(chunked_engine(chunk, budget));
            assert_eq!(
                tokens, baseline,
                "chunk={chunk} budget={budget} diverged from run-to-completion"
            );
            if chunk == 16 {
                // 137 + 200 + 64 prompt tokens at 16/chunk really split
                assert!(e.telemetry.prefill_chunks.get() > 3, "prefill never actually chunked");
            }
        }
    }

    #[test]
    fn partial_hit_resumed_across_rounds_matches_unchunked_cold_prefill() {
        // Satellite: the partial-hit suffix rebuild rides the same
        // resumable chunk API as cold prefill. Resume a hit across
        // several budgeted rounds and compare against an unchunked cold
        // prefill of the same prompt on a fresh (unprimed) engine.
        let base = reqs(1, 224, 4);
        let mut longer = base[0].prompt.clone();
        longer.extend((0..64).map(|i| (i * 3 % 300 + 20) as u16)); // 288 tokens

        let mut cold = chunked_engine(0, 0);
        let want = cold.run_trace(vec![Request::new(9, longer.clone(), 4)]).unwrap();

        // primed cache + tiny chunks under a small round budget: the
        // 96-token suffix rebuild spans multiple engine steps
        let mut e = chunked_engine(16, 24);
        e.run_trace(base).unwrap();
        let chunks0 = e.telemetry.prefill_chunks.get();
        let got = e.run_trace(vec![Request::new(9, longer, 4)]).unwrap();
        assert_eq!(e.metrics.prefix_partial_hits, 1);
        assert_eq!(e.metrics.prefix_tokens_reused, 192);
        assert!(
            e.telemetry.prefill_chunks.get() - chunks0 >= 4,
            "the suffix rebuild must have resumed across chunks"
        );
        assert_eq!(got[0].tokens, want[0].tokens, "resumed partial hit diverged");
        assert_eq!(got[0].finish, FinishReason::Length);
    }

    #[test]
    fn cancel_mid_prefill_releases_partial_pages_immediately() {
        let mut e = chunked_engine(16, 16);
        assert!(e.submit(reqs(1, 96, 4).remove(0)));
        e.step().unwrap();
        // one budgeted chunk in: live but not yet decodable
        assert_eq!(e.active_count(), 1);
        assert_eq!(e.progress(0), Some(0), "no token yet mid-prefill");
        assert!(e.pool_stats().live_bytes > 0, "partial KV must be charged");
        assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
        assert!(e.cancel(0));
        assert_eq!(e.pool_stats().live_bytes, 0, "partial pages must come back at cancel");
        assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
        assert!(e.idle());
        let out = e.take_completions();
        assert_eq!(out.len(), 1, "answered exactly once");
        assert_eq!(out[0].finish, FinishReason::Cancelled);
        assert!(out[0].tokens.is_empty());
        assert_eq!(out[0].decode_ms, 0.0, "never decoded");
        assert_eq!(e.metrics.cancelled, 1);
        assert_eq!(e.metrics.generated_tokens, 0);
    }

    #[test]
    fn deadline_cuts_a_mid_prefill_sequence_and_frees_its_pages() {
        let mut e = chunked_engine(16, 16);
        let mut r = reqs(1, 96, 8).remove(0);
        r.deadline_ms = Some(50);
        assert!(e.submit(r));
        e.step().unwrap(); // admits; the first chunk lands
        assert_eq!(e.progress(0), Some(0), "still mid-prefill");
        assert_eq!(e.active_count(), 1);
        std::thread::sleep(std::time::Duration::from_millis(60));
        let bound = Instant::now() + std::time::Duration::from_secs(60);
        while !e.idle() {
            assert!(Instant::now() < bound, "deadline never enforced");
            e.step().unwrap();
            assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
        }
        let out = e.take_completions();
        assert_eq!(out.len(), 1, "answered exactly once");
        assert_eq!(out[0].finish, FinishReason::Timeout);
        assert!(out[0].tokens.is_empty(), "cut before its first token");
        assert_eq!(out[0].decode_ms, 0.0);
        assert_eq!(e.metrics.deadline_exceeded, 1);
        assert_eq!(e.pool_stats().live_bytes, 0, "partial pages released at the cut");
    }

    #[test]
    fn injected_prefill_chunk_panic_is_contained_to_its_sequence() {
        let mut e = chunked_engine(16, 0);
        // the short prompt (1 chunk) takes the first consult; the long
        // one (3 chunks) takes the rest and panics on its final chunk
        e.set_fault_injector(
            crate::faults::Injector::parse("seq.prefill_chunk:after=3", 5).unwrap(),
        );
        let short = Request::new(0, (0..16).map(|j| ((j * 13) % 400 + 16) as u16).collect(), 4);
        let long =
            Request::new(1, (0..48).map(|j| ((j * 29 + 7) % 400 + 16) as u16).collect(), 4);
        let out = e.run_trace(vec![short, long]).unwrap();
        assert_eq!(out.len(), 2, "every request answered exactly once");
        let c0 = out.iter().find(|c| c.id == 0).unwrap();
        let c1 = out.iter().find(|c| c.id == 1).unwrap();
        assert_eq!(c0.finish, FinishReason::Length, "survivor must finish normally");
        assert_eq!(c0.tokens.len(), 4);
        assert_eq!(c1.finish, FinishReason::Error);
        assert!(c1
            .error
            .as_deref()
            .unwrap_or("")
            .contains("isolated panic during prefill chunk"));
        assert!(c1.tokens.is_empty());
        assert_eq!(e.metrics.isolated_panics, 1);
        assert_eq!(e.metrics.failed, 1);
        assert_eq!(e.pool_stats().live_bytes, e.measured_live_bytes());
        assert_eq!(e.pool_stats().live_bytes, e.prefix_cache().measured_bytes());
    }

    #[test]
    fn round_budget_rotation_prevents_prefill_starvation_behind_a_monster() {
        // budget 8 < chunk: each round grants one 8-token slice to one
        // sequence. Without the `prefill_rr` rotation cursor the
        // monster (admitted first) would win every round and the short
        // prompts behind it would never reach their first token.
        let mut e = chunked_engine(64, 8);
        assert!(e.submit(Request::new(0, reqs(1, 512, 4).remove(0).prompt, 4)));
        for mut r in reqs(2, 24, 4) {
            r.id += 1;
            r.route = r.id;
            assert!(e.submit(r));
        }
        let mut steps = 0;
        while e.progress(1).is_some() || e.progress(2).is_some() {
            e.step().unwrap();
            assert!(e.telemetry.round_budget_tokens.get() <= 8, "planner overspent the budget");
            steps += 1;
            assert!(steps < 40, "short decoders starved behind the monster prefill");
        }
        assert_eq!(e.progress(0), Some(0), "monster still mid-prefill");
        // and the monster itself is never starved either: it completes
        let bound = 2000;
        let mut n = 0;
        while !e.idle() {
            e.step().unwrap();
            n += 1;
            assert!(n < bound, "monster prefill never completed");
        }
        let mut out = e.take_completions();
        out.sort_by_key(|c| c.id);
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|c| c.finish == FinishReason::Length), "{out:?}");
        assert_eq!(out[0].tokens.len(), 4);
    }

    #[test]
    fn queue_wait_accumulates_across_a_mid_prefill_requeue() {
        let mut e = chunked_engine(16, 16);
        assert!(e.submit(reqs(1, 96, 4).remove(0)));
        e.step().unwrap(); // admitted, one chunk in
        assert!(e.active[0].prefill.is_some());
        let q0 = e.active[0].queue_ms;
        // pressure-bounce the mid-prefill sequence back to the queue
        e.requeue_prefill(0);
        assert_eq!(e.active_count(), 0);
        assert_eq!(e.queued_count(), 1);
        assert_eq!(e.telemetry.prefill_preempted.get(), 1);
        assert_eq!(e.metrics.preempted, 1);
        assert_eq!(e.pool_stats().live_bytes, 0, "bounced pages released immediately");
        std::thread::sleep(std::time::Duration::from_millis(15));
        let mut n = 0;
        while !e.idle() {
            e.step().unwrap();
            n += 1;
            assert!(n < 2000, "requeued request never finished");
        }
        let out = e.take_completions();
        assert_eq!(out.len(), 1, "answered exactly once across the requeue");
        assert_eq!(out[0].finish, FinishReason::Length);
        // the second stay adds >= the 15 ms sleep on top of the banked
        // first stay — a per-stay restamp would have erased q0
        assert!(
            out[0].queue_ms >= q0 + 15.0,
            "queue wait erased by the requeue: {} vs banked {q0}",
            out[0].queue_ms
        );
    }
}
