//! The serving engine: continuous batching over the native or PJRT
//! backends, with the Mustafar compressed-KV lifecycle owned by the
//! coordinator (prune + compress on local-window exit).

use std::sync::Arc;
use std::time::Instant;

use crate::config::{Backend, EngineConfig};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::pjrt_backend::{PjrtBackend, PjrtSeq};
use crate::coordinator::pool::WorkerPool;
use crate::coordinator::request::{ActiveSeq, Completion, FinishReason, Request};
use crate::coordinator::scheduler::Scheduler;
use crate::error::Result;
use crate::kvcache::{KvPolicy, SequenceKV};
use crate::model::{argmax, DecodeScratch, NativeModel};

/// Per-sequence backend state.
pub enum SeqState {
    Native(Box<SequenceKV>),
    Pjrt(Box<PjrtSeq>),
}

/// Synchronous continuous-batching engine.
///
/// `run_trace` drives a whole request trace to completion; `submit` +
/// `step` expose the same loop incrementally for the TCP server.
pub struct Engine {
    pub cfg: EngineConfig,
    pub model: Arc<NativeModel>,
    policy: KvPolicy,
    scheduler: Scheduler,
    active: Vec<ActiveSeq>,
    completions: Vec<Completion>,
    pub metrics: Metrics,
    pjrt: Option<PjrtBackend>,
    /// Persistent decode workers (lazily created on the first batched
    /// round) — replaces per-round `std::thread::scope` spawning.
    pool: Option<WorkerPool>,
}

impl Engine {
    /// Native-backend engine (pure Rust forward).
    pub fn new_native(model: NativeModel, cfg: EngineConfig) -> Engine {
        let policy = match cfg.backend {
            Backend::NativeDense | Backend::PjrtDense => KvPolicy::dense(),
            _ => KvPolicy {
                sparsity: cfg.sparsity,
                quant: None,
                compress: true,
                local_window: crate::prune::LOCAL_WINDOW,
            },
        };
        let scheduler = Scheduler::new(cfg.clone(), model.cfg().clone(), policy);
        Engine {
            cfg,
            model: Arc::new(model),
            policy,
            scheduler,
            active: Vec::new(),
            completions: Vec::new(),
            metrics: Metrics::default(),
            pjrt: None,
            pool: None,
        }
    }

    /// PJRT-backend engine (XLA artifacts on the hot path).
    pub fn new_pjrt(model: NativeModel, cfg: EngineConfig, backend: PjrtBackend) -> Engine {
        let mut e = Engine::new_native(model, cfg);
        e.pjrt = Some(backend);
        e
    }

    pub fn policy(&self) -> &KvPolicy {
        &self.policy
    }

    /// Submit a request to the admission queue.
    pub fn submit(&mut self, req: Request) -> bool {
        let ok = self.scheduler.submit(req);
        if !ok {
            self.metrics.rejected += 1;
        }
        ok
    }

    /// True when nothing is queued or running.
    pub fn idle(&self) -> bool {
        self.active.is_empty() && self.scheduler.pending() == 0
    }

    /// Admit + prefill new sequences, then run one decode round.
    pub fn step(&mut self) -> Result<()> {
        let t0 = Instant::now();
        self.admit_and_prefill()?;
        self.decode_round()?;
        self.metrics.wall_s += t0.elapsed().as_secs_f64();
        Ok(())
    }

    /// Drive a whole trace to completion and return the completions.
    pub fn run_trace(&mut self, reqs: Vec<Request>) -> Result<Vec<Completion>> {
        for r in reqs {
            self.submit(r);
        }
        while !self.idle() {
            self.step()?;
        }
        Ok(std::mem::take(&mut self.completions))
    }

    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    fn admit_and_prefill(&mut self) -> Result<()> {
        let admitted = self.scheduler.admit(self.active.len());
        for req in admitted {
            let enqueue = Instant::now(); // queue time measured from admission call in server mode
            let t0 = Instant::now();
            let (state, first_logits) = match (self.cfg.backend, &mut self.pjrt) {
                (Backend::NativeDense | Backend::NativeSparse, _) => {
                    let r = self.model.prefill(&req.prompt, false);
                    let mut kv = SequenceKV::new(
                        self.policy,
                        self.model.cfg().n_layers,
                        self.model.cfg().n_kv_heads,
                        self.model.cfg().head_dim,
                    )?;
                    kv.ingest_prefill(&r.k, &r.v, r.t, None)?;
                    (SeqState::Native(Box::new(kv)), r.logits_last)
                }
                (Backend::PjrtDense | Backend::PjrtSparse, Some(pj)) => {
                    let (seq, logits) = pj.prefill(&req.prompt, self.cfg.backend)?;
                    (SeqState::Pjrt(Box::new(seq)), logits)
                }
                (_, None) => {
                    return Err(crate::Error::Engine(
                        "pjrt backend selected but not constructed".into(),
                    ))
                }
            };
            let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
            self.metrics.prefill_tokens += req.prompt.len();

            let first = argmax(&first_logits);
            let pos = req.prompt.len();
            let mut seq = ActiveSeq {
                req,
                generated: vec![first],
                pos,
                enqueue,
                prefill_ms,
                queue_ms: 0.0,
                decode_start: Instant::now(),
                state,
                scratch: DecodeScratch::new(),
            };
            self.metrics.generated_tokens += 1;
            if self.seq_finished(&seq) {
                self.finish(seq);
            } else {
                seq.decode_start = Instant::now();
                self.active.push(seq);
            }
        }
        Ok(())
    }

    fn seq_finished(&self, s: &ActiveSeq) -> bool {
        if s.generated.len() >= s.req.max_new_tokens {
            return true;
        }
        if let (Some(stop), Some(&last)) = (s.req.stop_token, s.generated.last()) {
            if last == stop {
                return true;
            }
        }
        false
    }

    fn decode_round(&mut self) -> Result<()> {
        if self.active.is_empty() {
            return Ok(());
        }
        self.metrics.decode_rounds += 1;
        self.metrics.batch_sizes.push(self.active.len());

        match self.cfg.backend {
            Backend::NativeDense | Backend::NativeSparse => {
                // Sequences are independent: decode them in parallel
                // (the CPU analogue of GPU batch parallelism) on the
                // persistent worker pool — no per-round thread spawning.
                let n = self.active.len();
                let results: Vec<Result<u16>> = if n > 1 {
                    let workers = crate::util::threads().min(self.cfg.max_batch.max(1));
                    let pool = self.pool.get_or_insert_with(|| WorkerPool::new(workers));
                    let model: &NativeModel = &self.model;
                    let mut slots: Vec<Option<Result<u16>>> = (0..n).map(|_| None).collect();
                    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = self
                        .active
                        .iter_mut()
                        .zip(slots.iter_mut())
                        .map(|(s, slot)| {
                            let job: Box<dyn FnOnce() + Send + '_> =
                                Box::new(move || *slot = Some(decode_one_native(model, s)));
                            job
                        })
                        .collect();
                    pool.run_scoped(jobs);
                    slots.into_iter().map(|r| r.expect("decode job dropped")).collect()
                } else {
                    let model = Arc::clone(&self.model);
                    self.active.iter_mut().map(|s| decode_one_native(&model, s)).collect()
                };
                for (s, r) in self.active.iter_mut().zip(results) {
                    let tok = r?;
                    s.generated.push(tok);
                    s.pos += 1;
                }
            }
            Backend::PjrtDense | Backend::PjrtSparse => {
                let pj = self.pjrt.as_ref().unwrap();
                for s in self.active.iter_mut() {
                    let last = *s.generated.last().unwrap();
                    let SeqState::Pjrt(seq) = &mut s.state else { unreachable!() };
                    let logits = pj.decode(seq, last, s.pos)?;
                    s.generated.push(argmax(&logits));
                    s.pos += 1;
                }
            }
        }
        self.metrics.generated_tokens += self.active.len();

        // retire finished sequences
        let mut i = 0;
        while i < self.active.len() {
            if self.seq_finished(&self.active[i]) {
                let s = self.active.swap_remove(i);
                self.finish(s);
            } else {
                i += 1;
            }
        }
        Ok(())
    }

    fn finish(&mut self, s: ActiveSeq) {
        self.scheduler.release(&s.req);
        let (kv_bytes, kv_dense) = match &s.state {
            SeqState::Native(kv) => kv.memory_bytes(),
            SeqState::Pjrt(seq) => self
                .pjrt
                .as_ref()
                .map(|p| p.seq_memory_bytes(seq))
                .unwrap_or((0, 0)),
        };
        self.metrics.peak_kv_bytes = self.metrics.peak_kv_bytes.max(kv_bytes);
        self.metrics.peak_kv_dense_bytes = self.metrics.peak_kv_dense_bytes.max(kv_dense);
        let decode_ms = s.decode_start.elapsed().as_secs_f64() * 1e3;
        let total_ms = s.enqueue.elapsed().as_secs_f64() * 1e3;
        self.metrics.request_ms.push(total_ms);
        self.metrics.completions += 1;

        let finish = if s
            .req
            .stop_token
            .map(|st| s.generated.last() == Some(&st))
            .unwrap_or(false)
        {
            FinishReason::Stop
        } else {
            FinishReason::Length
        };
        self.completions.push(Completion {
            id: s.req.id,
            tokens: s.generated,
            finish,
            queue_ms: s.queue_ms,
            prefill_ms: s.prefill_ms,
            decode_ms,
            kv_bytes,
            kv_dense_bytes: kv_dense,
        });
    }
}

fn decode_one_native(model: &NativeModel, s: &mut ActiveSeq) -> Result<u16> {
    let last = *s.generated.last().unwrap();
    let pos = s.pos;
    let ActiveSeq { state, scratch, .. } = s;
    let SeqState::Native(kv) = state else { unreachable!() };
    model.decode_into(last, pos, kv, scratch)?;
    Ok(argmax(&scratch.logits))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Backend, ModelConfig};
    use crate::model::Weights;

    fn tiny_engine_gqa(
        backend: Backend,
        sparsity: (f64, f64),
        n_heads: usize,
        n_kv_heads: usize,
        head_dim: usize,
    ) -> Engine {
        let cfg = ModelConfig {
            name: "tiny".into(),
            d_model: 64,
            n_layers: 2,
            n_heads,
            n_kv_heads,
            head_dim,
            ff: 128,
            vocab: 512,
            rope_theta: 10000.0,
            max_seq: 256,
            norm_eps: 1e-5,
        };
        let model = NativeModel::new(Weights::random_for_tests(cfg, 42));
        let mut ec = EngineConfig::default();
        ec.backend = backend;
        ec.sparsity = crate::config::SparsityConfig::mustafar(sparsity.0, sparsity.1);
        ec.max_batch = 4;
        ec.max_new_tokens = 8;
        Engine::new_native(model, ec)
    }

    fn tiny_engine(backend: Backend, sparsity: (f64, f64)) -> Engine {
        tiny_engine_gqa(backend, sparsity, 2, 1, 32)
    }

    fn reqs(n: u64, prompt_len: usize, gen: usize) -> Vec<Request> {
        (0..n)
            .map(|i| {
                let prompt: Vec<u16> = (0..prompt_len).map(|j| ((i as usize * 31 + j) % 400 + 16) as u16).collect();
                Request::new(i, prompt, gen)
            })
            .collect()
    }

    #[test]
    fn trace_completes_all_requests() {
        let mut e = tiny_engine(Backend::NativeDense, (0.0, 0.0));
        let out = e.run_trace(reqs(6, 40, 5)).unwrap();
        assert_eq!(out.len(), 6);
        for c in &out {
            assert_eq!(c.tokens.len(), 5);
            assert_eq!(c.finish, FinishReason::Length);
        }
        assert_eq!(e.metrics.completions, 6);
        assert_eq!(e.metrics.generated_tokens, 30);
        // continuous batching: max 4 at a time
        assert!(e.metrics.batch_sizes.iter().all(|&b| b <= 4));
    }

    #[test]
    fn no_request_lost_or_duplicated() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.5, 0.5));
        let out = e.run_trace(reqs(9, 80, 4)).unwrap();
        let mut ids: Vec<u64> = out.iter().map(|c| c.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..9).collect::<Vec<_>>());
    }

    #[test]
    fn sparse_backend_compresses_kv() {
        let mut e = tiny_engine(Backend::NativeSparse, (0.7, 0.7));
        let out = e.run_trace(reqs(2, 160, 4)).unwrap();
        for c in &out {
            assert!(c.kv_bytes < c.kv_dense_bytes, "{} vs {}", c.kv_bytes, c.kv_dense_bytes);
        }
        assert!(e.metrics.kv_compression_rate() < 0.8);
    }

    #[test]
    fn stop_token_halts_generation() {
        let mut e = tiny_engine(Backend::NativeDense, (0.0, 0.0));
        let mut rs = reqs(1, 24, 8);
        // stop on whatever token the model produces first
        let probe = e.run_trace(rs.clone()).unwrap();
        let first = probe[0].tokens[0];
        rs[0].stop_token = Some(first);
        rs[0].id = 77;
        let mut e2 = tiny_engine(Backend::NativeDense, (0.0, 0.0));
        let out = e2.run_trace(rs).unwrap();
        assert_eq!(out[0].tokens.len(), 1);
        assert_eq!(out[0].finish, FinishReason::Stop);
    }

    #[test]
    fn dense_and_sparse_agree_on_short_context() {
        // With only ~60 tokens everything stays in the local window+group,
        // so sparse output must equal dense output exactly.
        let r = reqs(1, 60, 6);
        let mut ed = tiny_engine(Backend::NativeDense, (0.0, 0.0));
        let mut es = tiny_engine(Backend::NativeSparse, (0.7, 0.7));
        let a = ed.run_trace(r.clone()).unwrap();
        let b = es.run_trace(r).unwrap();
        assert_eq!(a[0].tokens, b[0].tokens);
    }

    #[test]
    fn gqa_dense_and_sparse_agree_on_short_context() {
        // n_heads > n_kv_heads exercises the fused multi-query decode
        // path (one compressed-stream walk per KV head for the whole
        // query group); short-context parity must survive the refactor.
        for (nh, nkv) in [(4, 2), (4, 1), (8, 2)] {
            let r = reqs(2, 60, 6);
            let mut ed = tiny_engine_gqa(Backend::NativeDense, (0.0, 0.0), nh, nkv, 32);
            let mut es = tiny_engine_gqa(Backend::NativeSparse, (0.7, 0.7), nh, nkv, 32);
            let a = ed.run_trace(r.clone()).unwrap();
            let b = es.run_trace(r).unwrap();
            for (ca, cb) in a.iter().zip(&b) {
                assert_eq!(ca.tokens, cb.tokens, "nh={nh} nkv={nkv}");
            }
        }
    }

    #[test]
    fn gqa_long_context_sparse_decode_completes() {
        // Long enough to push groups through compression during decode
        // with group > 1 (fused path over a non-empty compressed region).
        // head_dim = 32 exercises the partial channel tiles of the
        // value cache (the former seed bug left hd < 64 silently empty);
        // head_dim = 64 covers the full-tile path.
        for hd in [32usize, 64] {
            let mut e = tiny_engine_gqa(Backend::NativeSparse, (0.6, 0.6), 4, 2, hd);
            let out = e.run_trace(reqs(2, 160, 8)).unwrap();
            assert_eq!(out.len(), 2);
            for c in &out {
                assert_eq!(c.tokens.len(), 8, "hd={hd}");
                assert!(c.kv_bytes < c.kv_dense_bytes, "hd={hd}");
            }
        }
    }
}
