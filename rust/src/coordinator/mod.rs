//! L3 serving coordinator — the paper's system layer: request admission,
//! continuous batching, prefill/decode scheduling, and the compressed
//! KV-cache lifecycle (prune + compress on local-window exit).

pub mod compress;
pub mod engine;
pub mod metrics;
pub mod pjrt_backend;
pub mod pool;
pub mod request;
pub mod scheduler;

pub use engine::{Engine, SubmitOutcome};
pub use metrics::Metrics;
pub use pool::WorkerPool;
pub use request::{Completion, FinishReason, Request};
pub use scheduler::{estimate_seq_bytes, Scheduler};
