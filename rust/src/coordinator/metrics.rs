//! Serving metrics: token throughput, latency percentiles, KV memory.
//!
//! Latency and batch-size distributions are bounded log₂ histograms
//! ([`Hist`]) — a long-lived server accumulates them in O(1) memory.
//! (They used to be ever-growing `Vec`s, which leaked linearly in
//! request count; `latency_summary()` / `mean_batch()` keep their old
//! signatures on top of the histograms for the eval harness callers.)

use crate::telemetry::Hist;
use crate::util::Summary;

/// EWMA smoothing for the per-request latency estimate that drives
/// `retry_after_ms` hints and queue-depth estimates: 0.2 weights the
/// last ~10 completions, so one slow cold-start request stops skewing
/// hints after a handful of normal ones.
const LATENCY_EWMA_ALPHA: f64 = 0.2;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Wall-clock seconds spent inside engine stepping.
    pub wall_s: f64,
    pub prefill_tokens: usize,
    pub generated_tokens: usize,
    pub decode_rounds: usize,
    pub completions: usize,
    pub rejected: usize,
    /// Per-decode-round batch sizes (bounded histogram; for
    /// utilization analysis).
    pub batch_hist: Hist,
    /// Per-request end-to-end latencies, recorded in µs (bounded
    /// histogram; summarized in ms).
    pub request_latency: Hist,
    /// Exponentially-weighted mean of recent end-to-end latencies (ms).
    /// Unlike the histogram mean this *decays*, so admission hints
    /// track current conditions instead of process-lifetime history.
    pub request_ms_ewma: f64,
    /// Peak KV bytes across the run (compressed accounting).
    pub peak_kv_bytes: usize,
    /// Peak dense-equivalent KV bytes.
    pub peak_kv_dense_bytes: usize,
    /// Prefix-cache outcomes among cache-eligible admissions.
    pub prefix_full_hits: usize,
    pub prefix_partial_hits: usize,
    pub prefix_misses: usize,
    /// Entries dropped by the pressure controller / insert path.
    pub prefix_evictions: usize,
    /// Prefix-cache entries dropped by TTL decay (idle longer than
    /// `prefix_ttl_ms`), counted apart from pressure evictions.
    pub prefix_ttl_evictions: usize,
    /// Prompt tokens whose prefill was skipped via shared pages.
    pub prefix_tokens_reused: usize,
    /// Pressure-controller actions: compressed regions re-pruned to a
    /// higher sparsity tier, and sequences preempted back to the queue.
    pub repruned: usize,
    pub preempted: usize,
    /// Requests that reached admission but could not fit the pool even
    /// after the full reclaim ladder (subset of `rejected`).
    pub rejected_capacity: usize,
    /// Requests cancelled by the client (explicit cancel line or a
    /// dropped connection) while queued or decoding.
    pub cancelled: usize,
    /// Live pool bytes released by cancellations of *active* sequences
    /// — memory that would otherwise have been reclaimed from live
    /// requests via re-prune/preempt or held to completion.
    pub cancelled_freed_bytes: usize,
    /// Requests failed back to their clients because the engine errored
    /// while they were in flight (`Engine::fail_inflight`), or because
    /// their own prefill/decode failed and was isolated.
    pub failed: usize,
    /// Queued requests self-cancelled by the `max_queue_ms` TTL before
    /// admission.
    pub timed_out_queued: usize,
    /// Requests (queued or active) cut by their own `deadline_ms`.
    pub deadline_exceeded: usize,
    /// Requests shed at admission under overload (queue saturated);
    /// answered immediately with a retryable `Shed` completion.
    pub shed: usize,
    /// Panics caught and contained to a single sequence (prefill or
    /// decode) instead of killing the engine thread.
    pub isolated_panics: usize,
}

impl Metrics {
    /// Generated tokens per second (the Fig 7 metric).
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_s
        }
    }

    /// Record one decode round's batch size.
    pub fn note_batch(&mut self, n: usize) {
        self.batch_hist.record(n as u64);
    }

    /// Record one request's end-to-end latency: into the bounded
    /// histogram (for percentiles) and the decaying EWMA (for
    /// admission hints).
    pub fn note_request_ms(&mut self, ms: f64) {
        if self.request_latency.is_empty() {
            self.request_ms_ewma = ms;
        } else {
            self.request_ms_ewma += LATENCY_EWMA_ALPHA * (ms - self.request_ms_ewma);
        }
        self.request_latency.record((ms * 1e3).max(0.0) as u64);
    }

    pub fn mean_batch(&self) -> f64 {
        self.batch_hist.mean()
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        self.request_latency.summary(1e-3)
    }

    pub fn kv_compression_rate(&self) -> f64 {
        if self.peak_kv_dense_bytes == 0 {
            1.0
        } else {
            self.peak_kv_bytes as f64 / self.peak_kv_dense_bytes as f64
        }
    }

    /// Fraction of cache-eligible admissions that hit the prefix cache
    /// (full or partial).
    pub fn prefix_hit_rate(&self) -> f64 {
        let hits = self.prefix_full_hits + self.prefix_partial_hits;
        let total = hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = Metrics { wall_s: 2.0, generated_tokens: 100, ..Default::default() };
        assert!((m.tokens_per_sec() - 50.0).abs() < 1e-9);
        assert_eq!(Metrics::default().tokens_per_sec(), 0.0);
    }

    #[test]
    fn latency_summary_empty() {
        assert!(Metrics::default().latency_summary().is_none());
    }

    #[test]
    fn latency_summary_from_histogram_is_ms() {
        let mut m = Metrics::default();
        for ms in [10.0, 20.0, 30.0, 40.0] {
            m.note_request_ms(ms);
        }
        let s = m.latency_summary().unwrap();
        assert_eq!(s.n, 4);
        assert!((s.mean - 25.0).abs() < 1e-6);
        assert!((s.min - 10.0).abs() < 1e-6);
        assert!((s.max - 40.0).abs() < 1e-6);
        assert!(s.p50 >= s.min && s.p50 <= s.max);
    }

    #[test]
    fn mean_batch_from_histogram() {
        let mut m = Metrics::default();
        for b in [2usize, 4, 4, 6] {
            m.note_batch(b);
        }
        assert!((m.mean_batch() - 4.0).abs() < 1e-9);
        assert_eq!(m.batch_hist.max(), 6);
        assert_eq!(m.batch_hist.min(), 2);
    }

    #[test]
    fn ewma_forgets_cold_start() {
        let mut m = Metrics::default();
        // one pathological cold-start completion...
        m.note_request_ms(10_000.0);
        assert!((m.request_ms_ewma - 10_000.0).abs() < 1e-9);
        // ...decays toward steady state after a burst of normal ones
        for _ in 0..30 {
            m.note_request_ms(20.0);
        }
        assert!(m.request_ms_ewma < 40.0, "ewma stuck at {}", m.request_ms_ewma);
        // while the histogram still remembers the outlier exactly
        assert!(m.latency_summary().unwrap().max >= 9_999.0);
    }
}
