//! Serving metrics: token throughput, latency percentiles, KV memory.

use crate::util::Summary;

#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Wall-clock seconds spent inside engine stepping.
    pub wall_s: f64,
    pub prefill_tokens: usize,
    pub generated_tokens: usize,
    pub decode_rounds: usize,
    pub completions: usize,
    pub rejected: usize,
    /// Per-decode-round batch sizes (for utilization analysis).
    pub batch_sizes: Vec<usize>,
    /// Per-request end-to-end latencies (ms).
    pub request_ms: Vec<f64>,
    /// Peak KV bytes across the run (compressed accounting).
    pub peak_kv_bytes: usize,
    /// Peak dense-equivalent KV bytes.
    pub peak_kv_dense_bytes: usize,
    /// Prefix-cache outcomes among cache-eligible admissions.
    pub prefix_full_hits: usize,
    pub prefix_partial_hits: usize,
    pub prefix_misses: usize,
    /// Entries dropped by the pressure controller / insert path.
    pub prefix_evictions: usize,
    /// Prefix-cache entries dropped by TTL decay (idle longer than
    /// `prefix_ttl_ms`), counted apart from pressure evictions.
    pub prefix_ttl_evictions: usize,
    /// Prompt tokens whose prefill was skipped via shared pages.
    pub prefix_tokens_reused: usize,
    /// Pressure-controller actions: compressed regions re-pruned to a
    /// higher sparsity tier, and sequences preempted back to the queue.
    pub repruned: usize,
    pub preempted: usize,
    /// Requests that reached admission but could not fit the pool even
    /// after the full reclaim ladder (subset of `rejected`).
    pub rejected_capacity: usize,
    /// Requests cancelled by the client (explicit cancel line or a
    /// dropped connection) while queued or decoding.
    pub cancelled: usize,
    /// Live pool bytes released by cancellations of *active* sequences
    /// — memory that would otherwise have been reclaimed from live
    /// requests via re-prune/preempt or held to completion.
    pub cancelled_freed_bytes: usize,
    /// Requests failed back to their clients because the engine errored
    /// while they were in flight (`Engine::fail_inflight`), or because
    /// their own prefill/decode failed and was isolated.
    pub failed: usize,
    /// Queued requests self-cancelled by the `max_queue_ms` TTL before
    /// admission.
    pub timed_out_queued: usize,
    /// Requests (queued or active) cut by their own `deadline_ms`.
    pub deadline_exceeded: usize,
    /// Requests shed at admission under overload (queue saturated);
    /// answered immediately with a retryable `Shed` completion.
    pub shed: usize,
    /// Panics caught and contained to a single sequence (prefill or
    /// decode) instead of killing the engine thread.
    pub isolated_panics: usize,
}

impl Metrics {
    /// Generated tokens per second (the Fig 7 metric).
    pub fn tokens_per_sec(&self) -> f64 {
        if self.wall_s == 0.0 {
            0.0
        } else {
            self.generated_tokens as f64 / self.wall_s
        }
    }

    pub fn mean_batch(&self) -> f64 {
        crate::util::stats::mean(
            &self.batch_sizes.iter().map(|&b| b as f64).collect::<Vec<_>>(),
        )
    }

    pub fn latency_summary(&self) -> Option<Summary> {
        if self.request_ms.is_empty() {
            None
        } else {
            Some(Summary::of(&self.request_ms))
        }
    }

    pub fn kv_compression_rate(&self) -> f64 {
        if self.peak_kv_dense_bytes == 0 {
            1.0
        } else {
            self.peak_kv_bytes as f64 / self.peak_kv_dense_bytes as f64
        }
    }

    /// Fraction of cache-eligible admissions that hit the prefix cache
    /// (full or partial).
    pub fn prefix_hit_rate(&self) -> f64 {
        let hits = self.prefix_full_hits + self.prefix_partial_hits;
        let total = hits + self.prefix_misses;
        if total == 0 {
            0.0
        } else {
            hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throughput_math() {
        let m = Metrics { wall_s: 2.0, generated_tokens: 100, ..Default::default() };
        assert!((m.tokens_per_sec() - 50.0).abs() < 1e-9);
        assert_eq!(Metrics::default().tokens_per_sec(), 0.0);
    }

    #[test]
    fn latency_summary_empty() {
        assert!(Metrics::default().latency_summary().is_none());
    }
}
