//! Request/sequence lifecycle types for the serving coordinator.

use std::time::Instant;

/// Inference request as submitted by a client (router or trace).
#[derive(Clone, Debug)]
pub struct Request {
    /// Client-chosen id, echoed back on the completion line. Only
    /// meaningful within one connection — different connections may
    /// reuse the same id freely.
    pub id: u64,
    /// Engine-wide routing key. Defaults to `id` (trace harnesses
    /// address requests directly); the TCP server overwrites it with a
    /// server-assigned unique value so same-id requests from different
    /// connections never collide in the waiter map, and cancellation
    /// (`Engine::cancel`) targets exactly one request.
    pub route: u64,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    /// Stop generation at this token (the language's SEP by default).
    pub stop_token: Option<u16>,
    /// When the request entered the system. Stamped at construction and
    /// re-stamped by `Engine::submit`; `Completion::queue_ms` reports
    /// admission − submission against it. Preserved across preemption
    /// so re-queued requests report their full queue time.
    pub submitted: Instant,
    /// Optional end-to-end deadline in milliseconds from submission.
    /// Checked every engine round: a request past its deadline finishes
    /// `Timeout` (with whatever tokens it generated) instead of holding
    /// pool pages for an answer the client has stopped waiting for.
    pub deadline_ms: Option<u64>,
    /// When the request last entered the admission queue. Equals
    /// `submitted` on first submit; re-stamped on every requeue
    /// (preemption, mid-prefill pressure bounce) so each queue stay is
    /// measured from the right origin while `submitted` keeps anchoring
    /// deadlines to the client's original send.
    pub enqueued: Instant,
    /// Queue wait accumulated over *previous* queue stays, ms. A
    /// request preempted or bounced mid-prefill goes back to the queue;
    /// its eventual `Completion::queue_ms` is this accumulator plus the
    /// current stay — stamped once per stay at admission, never reset,
    /// so requeues don't erase waiting the client actually experienced.
    pub queue_ms_acc: f64,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u16>, max_new_tokens: usize) -> Request {
        let now = Instant::now();
        Request {
            id,
            route: id,
            prompt,
            max_new_tokens,
            stop_token: None,
            submitted: now,
            deadline_ms: None,
            enqueued: now,
            queue_ms_acc: 0.0,
        }
    }
}

/// Why a sequence finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit max_new_tokens.
    Length,
    /// Produced the stop token.
    Stop,
    /// Rejected at admission (prompt too long / over budget / token
    /// ids outside the model vocab).
    Rejected,
    /// Cancelled by the client (explicit `{"cancel": id}` line or a
    /// dropped connection) before finishing; pool pages were released
    /// at cancel time.
    Cancelled,
    /// The engine failed while this request was in flight (`step()`
    /// errored); the request was failed back instead of hanging its
    /// waiter. `Completion::error` carries the message.
    Error,
    /// The request outlived its time allowance: either it sat queued
    /// past `EngineConfig::max_queue_ms`, or it blew through its own
    /// `Request::deadline_ms` (queued or mid-decode — the completion
    /// carries any tokens generated before the cut).
    Timeout,
    /// Shed at admission under overload (queue saturated). Unlike
    /// `Rejected` this is retryable: `Completion::retry_after_ms`
    /// carries a backoff hint derived from observed throughput.
    Shed,
}

/// Completed request with timing breakdown.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    /// Routing key copied from `Request::route` (see there).
    pub route: u64,
    pub tokens: Vec<u16>,
    pub finish: FinishReason,
    /// Engine error message for `FinishReason::Error` completions.
    pub error: Option<String>,
    pub queue_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// KV bytes (compressed accounting) held at completion.
    pub kv_bytes: usize,
    /// Dense-equivalent KV bytes at completion.
    pub kv_dense_bytes: usize,
    /// For `FinishReason::Shed`: how long the client should wait before
    /// retrying, derived from current decode throughput and queue depth.
    pub retry_after_ms: Option<u64>,
}

impl Completion {
    /// Terminal answer for a request that never became (or no longer
    /// is) an active sequence — rejection at submit, cancel while
    /// queued, engine error before activation. No tokens, no KV, no
    /// prefill/decode time; `queue_ms` runs from submission to now.
    pub fn queued(
        id: u64,
        route: u64,
        submitted: Instant,
        finish: FinishReason,
        error: Option<String>,
    ) -> Completion {
        Completion {
            id,
            route,
            tokens: Vec::new(),
            finish,
            error,
            queue_ms: submitted.elapsed().as_secs_f64() * 1e3,
            prefill_ms: 0.0,
            decode_ms: 0.0,
            kv_bytes: 0,
            kv_dense_bytes: 0,
            retry_after_ms: None,
        }
    }
}

/// Chunked-prefill progress carried on a live sequence that is not yet
/// decodable: the partially built `SequenceKV` lives in
/// `ActiveSeq::state` as usual, this records how far into the prompt it
/// has been fed. Dropped (set to `None`) the moment the final chunk
/// lands the first token.
pub(crate) struct PrefillCursor {
    /// Next prompt index to feed (prompt tokens `[0, cursor)` are
    /// already in the KV state; for a prefix-cache partial hit the
    /// cursor starts at the reused boundary, not 0).
    pub cursor: usize,
    /// Chunks executed so far for this admission (diagnostics).
    pub chunks: u64,
}

/// Internal per-sequence decode state.
pub(crate) struct ActiveSeq {
    pub req: Request,
    pub generated: Vec<u16>,
    /// Next RoPE position (= tokens processed so far).
    pub pos: usize,
    /// `Some` while the sequence is admitted but still mid-prefill
    /// (live-but-not-yet-decodable): decode rounds skip it, the round
    /// planner feeds it prompt chunks, and any terminal cut (cancel,
    /// deadline, preempt, pressure) releases its partial pages exactly
    /// like a decodable sequence's.
    pub prefill: Option<PrefillCursor>,
    pub prefill_ms: f64,
    pub queue_ms: f64,
    pub decode_start: Instant,
    pub state: crate::coordinator::engine::SeqState,
    /// This sequence's page-table owner in the kvpool.
    pub owner: crate::kvpool::OwnerId,
    /// Monotone admission stamp (pressure-controller coldness order).
    pub admitted_seq: u64,
    /// Next re-prune tier index into `EngineConfig::reprune_tiers`.
    pub reprune_tier: usize,
    /// Per-sequence decode workspace: buffers persist across tokens so
    /// the native decode hot path allocates nothing in steady state.
    pub scratch: crate::model::DecodeScratch,
}

impl ActiveSeq {
    /// Terminal completion for this sequence, carrying whatever tokens
    /// it generated (finish, cancel, error, and reject paths all build
    /// through here so the field set cannot drift between them). `kv`
    /// is the (compressed, dense-equivalent) byte pair the caller
    /// measured from the state — zero where the footprint is moot.
    pub(crate) fn into_completion(
        self,
        finish: FinishReason,
        error: Option<String>,
        kv: (usize, usize),
    ) -> Completion {
        Completion {
            id: self.req.id,
            route: self.req.route,
            tokens: self.generated,
            finish,
            error,
            queue_ms: self.queue_ms,
            prefill_ms: self.prefill_ms,
            // a sequence cut mid-prefill never started decoding;
            // `decode_start` is only stamped when the first token lands
            decode_ms: if self.prefill.is_some() {
                0.0
            } else {
                self.decode_start.elapsed().as_secs_f64() * 1e3
            },
            kv_bytes: kv.0,
            kv_dense_bytes: kv.1,
            retry_after_ms: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_defaults() {
        let r = Request::new(7, vec![1, 2, 3], 16);
        assert_eq!(r.id, 7);
        assert_eq!(r.route, 7, "route defaults to the client id");
        assert_eq!(r.stop_token, None);
    }
}
