//! Request/sequence lifecycle types for the serving coordinator.

use std::time::Instant;

/// Inference request as submitted by a client (router or trace).
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    /// Stop generation at this token (the language's SEP by default).
    pub stop_token: Option<u16>,
    /// When the request entered the system. Stamped at construction and
    /// re-stamped by `Engine::submit`; `Completion::queue_ms` reports
    /// admission − submission against it. Preserved across preemption
    /// so re-queued requests report their full queue time.
    pub submitted: Instant,
}

impl Request {
    pub fn new(id: u64, prompt: Vec<u16>, max_new_tokens: usize) -> Request {
        Request { id, prompt, max_new_tokens, stop_token: None, submitted: Instant::now() }
    }
}

/// Why a sequence finished.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit max_new_tokens.
    Length,
    /// Produced the stop token.
    Stop,
    /// Rejected at admission (prompt too long / over budget).
    Rejected,
}

/// Completed request with timing breakdown.
#[derive(Clone, Debug)]
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<u16>,
    pub finish: FinishReason,
    pub queue_ms: f64,
    pub prefill_ms: f64,
    pub decode_ms: f64,
    /// KV bytes (compressed accounting) held at completion.
    pub kv_bytes: usize,
    /// Dense-equivalent KV bytes at completion.
    pub kv_dense_bytes: usize,
}

/// Internal per-sequence decode state.
pub(crate) struct ActiveSeq {
    pub req: Request,
    pub generated: Vec<u16>,
    /// Next RoPE position (= tokens processed so far).
    pub pos: usize,
    pub prefill_ms: f64,
    pub queue_ms: f64,
    pub decode_start: Instant,
    pub state: crate::coordinator::engine::SeqState,
    /// This sequence's page-table owner in the kvpool.
    pub owner: crate::kvpool::OwnerId,
    /// Monotone admission stamp (pressure-controller coldness order).
    pub admitted_seq: u64,
    /// Next re-prune tier index into `EngineConfig::reprune_tiers`.
    pub reprune_tier: usize,
    /// Per-sequence decode workspace: buffers persist across tokens so
    /// the native decode hot path allocates nothing in steady state.
    pub scratch: crate::model::DecodeScratch,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_defaults() {
        let r = Request::new(7, vec![1, 2, 3], 16);
        assert_eq!(r.id, 7);
        assert_eq!(r.stop_token, None);
    }
}
