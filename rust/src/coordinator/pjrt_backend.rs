//! PJRT execution backend for the engine: the three-layer hot path.
//! The XLA artifacts (lowered from the JAX model, with the L1 Pallas
//! sparse-attention kernel inside `decode_sparse_*`) do the model math;
//! this module owns the host-side compressed KV state and the
//! prune+compress lifecycle, mirroring `SequenceKV` semantics.
//!
//! Shape discipline: artifacts are compiled for fixed shapes (one serving
//! "bucket" per config — prompt length S, cache capacity Tc/Tmax). The
//! engine enforces prompt length == S for PJRT backends; the native
//! backend has no such restriction.

use std::path::Path;

use crate::config::{Backend, ModelConfig, SparsityConfig};
use crate::error::{Error, Result};
use crate::kvcache::TAIL_CAP;
use crate::model::Weights;
use crate::prune::{self, keep_count, LOCAL_WINDOW};
use crate::runtime::{literal_f32, DeviceWeights, HostArg, Runtime};
use crate::sparse::{TokenPairs, TILE};

/// Per-sequence state for the PJRT backends.
pub enum PjrtSeq {
    Dense {
        /// Host-side caches `[L,1,KV,Tmax,hd]` (round-trip each step).
        k: Vec<f32>,
        v: Vec<f32>,
        cur_len: usize,
    },
    Sparse {
        /// Compressed region `[L,KV,Tc,kk]` in (values, indices) form.
        k_vals: Vec<f32>,
        k_idx: Vec<i32>,
        v_vals: Vec<f32>,
        v_idx: Vec<i32>,
        /// Valid compressed tokens.
        nc: usize,
        /// Dense tail `[L,KV,TAIL_CAP,hd]`, `tail_len` valid rows each.
        tail_k: Vec<f32>,
        tail_v: Vec<f32>,
        tail_len: usize,
        tokens: usize,
    },
}

/// The PJRT backend: runtime + device weights + artifact bookkeeping.
pub struct PjrtBackend {
    rt: Runtime,
    dw: DeviceWeights,
    pub cfg: ModelConfig,
    prefill_name: String,
    decode_dense_name: String,
    decode_sparse_name: Option<String>,
    /// Prefill prompt length the artifact was compiled for.
    pub s: usize,
    pub tmax: usize,
    pub tc: usize,
    pub kk: usize,
    sparsity: SparsityConfig,
}

// SAFETY: a PjrtBackend is owned by exactly one Engine and is only used
// from whichever single thread currently owns that Engine (the server
// moves the whole Engine onto its engine thread; nothing is shared).
// The inner Rc/raw pointers therefore never experience concurrent access.
unsafe impl Send for PjrtBackend {}

impl PjrtBackend {
    /// Load artifacts + weights for `cfg_name` and pick the sparse-decode
    /// variant matching the sparsity config (pjrt-sparse supports the
    /// AOT'd keep-counts only; the native backend covers the full grid).
    pub fn new(
        artifact_dir: &Path,
        weights: &Weights,
        backend: Backend,
        sparsity: SparsityConfig,
    ) -> Result<PjrtBackend> {
        let cfg = weights.cfg.clone();
        let mut rt = Runtime::new(artifact_dir)?;
        let prefill_name = format!("prefill_{}", cfg.name);
        let decode_dense_name = format!("decode_dense_{}", cfg.name);
        rt.load(&prefill_name)?;
        let meta = rt.index.entries.get(&prefill_name).unwrap().clone();
        let s = meta.input_shapes.last().unwrap().0[1];

        let dmeta = rt
            .index
            .entries
            .get(&decode_dense_name)
            .ok_or_else(|| Error::Runtime(format!("missing {decode_dense_name}")))?;
        let tmax = dmeta.input_shapes[dmeta.n_weights + 2].0[3];

        let (decode_sparse_name, tc, kk) = if backend == Backend::PjrtSparse {
            let kk_k = keep_count(cfg.head_dim, sparsity.key_sparsity);
            let kk_v = keep_count(cfg.head_dim, sparsity.value_sparsity);
            if kk_k != kk_v {
                return Err(Error::Engine(
                    "pjrt-sparse requires symmetric K/V sparsity (AOT'd variants)".into(),
                ));
            }
            let name = format!("decode_sparse_{}_k{}", cfg.name, kk_k);
            let smeta = rt.index.entries.get(&name).ok_or_else(|| {
                Error::Engine(format!(
                    "no AOT variant '{name}' — pjrt-sparse supports the pre-compiled keep-counts"
                ))
            })?;
            let tc = smeta.input_shapes[smeta.n_weights + 2].0[2];
            rt.load(&name)?;
            (Some(name), tc, kk_k)
        } else {
            rt.load(&decode_dense_name)?;
            (None, 0, 0)
        };
        if backend == Backend::PjrtDense {
            rt.load(&decode_dense_name)?;
        }

        let dw = rt.upload_weights(weights)?;
        Ok(PjrtBackend {
            rt,
            dw,
            cfg,
            prefill_name,
            decode_dense_name,
            decode_sparse_name,
            s,
            tmax,
            tc,
            kk,
            sparsity,
        })
    }

    /// Run the prefill artifact and build the per-sequence state.
    pub fn prefill(&self, prompt: &[u16], backend: Backend) -> Result<(PjrtSeq, Vec<f32>)> {
        if prompt.len() != self.s {
            return Err(Error::Engine(format!(
                "pjrt backends are compiled for prompt length {} (got {}) — \
                 use the native backend for arbitrary lengths",
                self.s,
                prompt.len()
            )));
        }
        let toks: Vec<i32> = prompt.iter().map(|&t| t as i32).collect();
        let out = self.rt.run(
            &self.prefill_name,
            Some(&self.dw),
            &[HostArg::I32(&toks, vec![1, self.s])],
        )?;
        let (logits, ldims) = literal_f32(&out[0])?;
        let (kflat, _) = literal_f32(&out[1])?;
        let (vflat, _) = literal_f32(&out[2])?;
        let vocab = ldims[2];
        let last_logits = logits[(self.s - 1) * vocab..].to_vec();

        let (l, kv, hd) = (self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim);
        let seq = match backend {
            Backend::PjrtDense => {
                // place [L,1,KV,S,hd] into [L,1,KV,Tmax,hd]
                let mut k = vec![0.0f32; l * kv * self.tmax * hd];
                let mut v = vec![0.0f32; l * kv * self.tmax * hd];
                for li in 0..l {
                    for h in 0..kv {
                        let src = (li * kv + h) * self.s * hd;
                        let dst = (li * kv + h) * self.tmax * hd;
                        k[dst..dst + self.s * hd]
                            .copy_from_slice(&kflat[src..src + self.s * hd]);
                        v[dst..dst + self.s * hd]
                            .copy_from_slice(&vflat[src..src + self.s * hd]);
                    }
                }
                PjrtSeq::Dense { k, v, cur_len: self.s }
            }
            Backend::PjrtSparse => {
                let n_comp = ((self.s - LOCAL_WINDOW) / TILE) * TILE;
                let tail = self.s - n_comp;
                let mut st = self.empty_sparse_seq();
                let PjrtSeq::Sparse {
                    k_vals, k_idx, v_vals, v_idx, nc, tail_k, tail_v, tail_len, tokens,
                } = &mut st
                else {
                    unreachable!()
                };
                for li in 0..l {
                    for h in 0..kv {
                        let src = (li * kv + h) * self.s * hd;
                        let krows = &kflat[src..src + self.s * hd];
                        let vrows = &vflat[src..src + self.s * hd];
                        // prune + pack the compressed region
                        let kc = &krows[..n_comp * hd];
                        let vc = &vrows[..n_comp * hd];
                        let kp = prune::per_token_magnitude(kc, n_comp, hd, self.kk);
                        let vp = prune::per_token_magnitude(vc, n_comp, hd, self.kk);
                        let kpair = TokenPairs::from_dense(&kp, n_comp, hd, self.kk)?;
                        let vpair = TokenPairs::from_dense(&vp, n_comp, hd, self.kk)?;
                        let base = (li * kv + h) * self.tc * self.kk;
                        k_vals[base..base + n_comp * self.kk].copy_from_slice(&kpair.values);
                        k_idx[base..base + n_comp * self.kk].copy_from_slice(&kpair.indices);
                        v_vals[base..base + n_comp * self.kk].copy_from_slice(&vpair.values);
                        v_idx[base..base + n_comp * self.kk].copy_from_slice(&vpair.indices);
                        // dense tail
                        let tb = (li * kv + h) * TAIL_CAP * hd;
                        tail_k[tb..tb + tail * hd].copy_from_slice(&krows[n_comp * hd..]);
                        tail_v[tb..tb + tail * hd].copy_from_slice(&vrows[n_comp * hd..]);
                    }
                }
                *nc = n_comp;
                *tail_len = tail;
                *tokens = self.s;
                st
            }
            _ => unreachable!("native backends never call PjrtBackend::prefill"),
        };
        Ok((seq, last_logits))
    }

    fn empty_sparse_seq(&self) -> PjrtSeq {
        let (l, kv, hd) = (self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim);
        PjrtSeq::Sparse {
            k_vals: vec![0.0; l * kv * self.tc * self.kk],
            k_idx: vec![0; l * kv * self.tc * self.kk],
            v_vals: vec![0.0; l * kv * self.tc * self.kk],
            v_idx: vec![0; l * kv * self.tc * self.kk],
            nc: 0,
            tail_k: vec![0.0; l * kv * TAIL_CAP * hd],
            tail_v: vec![0.0; l * kv * TAIL_CAP * hd],
            tail_len: 0,
            tokens: 0,
        }
    }

    /// One decode step; returns logits.
    pub fn decode(&self, seq: &mut PjrtSeq, token: u16, pos: usize) -> Result<Vec<f32>> {
        let (l, kvh, hd) = (self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim);
        match seq {
            PjrtSeq::Dense { k, v, cur_len } => {
                if *cur_len >= self.tmax {
                    return Err(Error::Engine("dense cache capacity exceeded".into()));
                }
                let tok = [token as i32];
                let out = self.rt.run(
                    &self.decode_dense_name,
                    Some(&self.dw),
                    &[
                        HostArg::I32(&tok, vec![1]),
                        HostArg::ScalarI32(*cur_len as i32),
                        HostArg::F32(k, vec![l, 1, kvh, self.tmax, hd]),
                        HostArg::F32(v, vec![l, 1, kvh, self.tmax, hd]),
                    ],
                )?;
                let (logits, _) = literal_f32(&out[0])?;
                let (knew, _) = literal_f32(&out[1])?;
                let (vnew, _) = literal_f32(&out[2])?;
                *k = knew;
                *v = vnew;
                *cur_len += 1;
                let _ = pos;
                Ok(logits)
            }
            PjrtSeq::Sparse {
                k_vals, k_idx, v_vals, v_idx, nc, tail_k, tail_v, tail_len, tokens,
            } => {
                let name = self.decode_sparse_name.as_ref().unwrap();
                let out = self.rt.run(
                    name,
                    Some(&self.dw),
                    &[
                        HostArg::ScalarI32(token as i32),
                        HostArg::ScalarI32(pos as i32),
                        HostArg::F32(k_vals, vec![l, kvh, self.tc, self.kk]),
                        HostArg::I32(k_idx, vec![l, kvh, self.tc, self.kk]),
                        HostArg::F32(v_vals, vec![l, kvh, self.tc, self.kk]),
                        HostArg::I32(v_idx, vec![l, kvh, self.tc, self.kk]),
                        HostArg::ScalarI32(*nc as i32),
                        HostArg::F32(tail_k, vec![l, kvh, TAIL_CAP, hd]),
                        HostArg::F32(tail_v, vec![l, kvh, TAIL_CAP, hd]),
                        HostArg::ScalarI32(*tail_len as i32),
                    ],
                )?;
                let (logits, _) = literal_f32(&out[0])?;
                let (new_k, _) = literal_f32(&out[1])?; // [L,KV,hd]
                let (new_v, _) = literal_f32(&out[2])?;

                // append the new token's K/V to every head's tail
                for li in 0..l {
                    for h in 0..kvh {
                        let src = (li * kvh + h) * hd;
                        let dst = (li * kvh + h) * TAIL_CAP * hd + *tail_len * hd;
                        tail_k[dst..dst + hd].copy_from_slice(&new_k[src..src + hd]);
                        tail_v[dst..dst + hd].copy_from_slice(&new_v[src..src + hd]);
                    }
                }
                *tail_len += 1;
                *tokens += 1;

                // compression trigger: a full group has exited the window
                if *tail_len == TAIL_CAP {
                    if *nc + TILE > self.tc {
                        return Err(Error::Engine("compressed region capacity exceeded".into()));
                    }
                    for li in 0..l {
                        for h in 0..kvh {
                            let tb = (li * kvh + h) * TAIL_CAP * hd;
                            let kg = tail_k[tb..tb + TILE * hd].to_vec();
                            let vg = tail_v[tb..tb + TILE * hd].to_vec();
                            let kp = prune::per_token_magnitude(&kg, TILE, hd, self.kk);
                            let vp = prune::per_token_magnitude(&vg, TILE, hd, self.kk);
                            let kpair = TokenPairs::from_dense(&kp, TILE, hd, self.kk)?;
                            let vpair = TokenPairs::from_dense(&vp, TILE, hd, self.kk)?;
                            let base = ((li * kvh + h) * self.tc + *nc) * self.kk;
                            k_vals[base..base + TILE * self.kk].copy_from_slice(&kpair.values);
                            k_idx[base..base + TILE * self.kk].copy_from_slice(&kpair.indices);
                            v_vals[base..base + TILE * self.kk].copy_from_slice(&vpair.values);
                            v_idx[base..base + TILE * self.kk].copy_from_slice(&vpair.indices);
                            // slide the tail down by one group
                            tail_k.copy_within(tb + TILE * hd..tb + TAIL_CAP * hd, tb);
                            tail_v.copy_within(tb + TILE * hd..tb + TAIL_CAP * hd, tb);
                        }
                    }
                    *nc += TILE;
                    *tail_len -= TILE;
                }
                let _ = self.sparsity;
                Ok(logits)
            }
        }
    }

    /// fp16-accounting memory for a PJRT sequence (engine metrics).
    ///
    /// Unlike the native backend — whose `SequenceKV` stores real
    /// binary16, making its figures actual bytes — the PJRT host buffers
    /// stay `f32` because the AOT'd XLA artifacts take F32 literals at
    /// the FFI boundary; this figure remains the paper's fp16 *model*
    /// of the same state so both backends report comparable numbers.
    pub fn seq_memory_bytes(&self, seq: &PjrtSeq) -> (usize, usize) {
        use crate::sparse::bitmap::{BITMAP_BYTES, OFFSET_BYTES, PAD, VALUE_BYTES};
        let (l, kv, hd) = (self.cfg.n_layers, self.cfg.n_kv_heads, self.cfg.head_dim);
        let heads = l * kv;
        match seq {
            PjrtSeq::Dense { cur_len, .. } => {
                let d = heads * 2 * cur_len * hd * VALUE_BYTES;
                (d, d)
            }
            PjrtSeq::Sparse { nc, tail_len, tokens, .. } => {
                // bitmap-equivalent accounting of the (vals, idx) region
                let tiles_per_cache = nc * hd / TILE;
                let vals_per_tile = (self.kk * TILE / hd).div_ceil(PAD) * PAD;
                let per_cache =
                    tiles_per_cache * (vals_per_tile * VALUE_BYTES + BITMAP_BYTES + OFFSET_BYTES);
                let comp = heads * (2 * per_cache + 2 * tail_len * hd * VALUE_BYTES);
                let dense = heads * 2 * tokens * hd * VALUE_BYTES;
                (comp, dense)
            }
        }
    }
}
