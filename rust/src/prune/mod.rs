//! Pruning algorithms explored in §2 of the paper plus baselines:
//! per-token magnitude (the verdict method), per-token output-aware (Key),
//! per-channel magnitude / output-aware (Value), ThinK-style structured
//! channel removal, and 2:4 semi-structured.

pub mod per_channel;
pub mod per_token;
pub mod semi;
pub mod think;

pub use per_channel::{per_channel_magnitude, per_channel_output_aware, CHANNEL_GROUP};
pub use per_token::{
    per_token_magnitude, per_token_magnitude_inplace, per_token_output_aware, select_top_per_row,
};
pub use semi::semi_24;
pub use think::{structured_compression_rate, think_key, think_value};

/// Recent-token dense window: the paper keeps the most recent 32 tokens
/// untouched during decode (§2, "local dense window").
pub const LOCAL_WINDOW: usize = 32;

/// Kept elements per token for a target sparsity over `d` channels:
/// round-half-up of d·(1−s), floored at 1. Mirrors
/// `python/compile/kernels/prune.py::keep_count`.
pub fn keep_count(d: usize, sparsity: f64) -> usize {
    (((d as f64) * (1.0 - sparsity) + 0.5).floor() as usize).clamp(1, d)
}

/// Pruning method selector used by configs and the experiment harness.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Method {
    /// No pruning.
    None,
    /// Per-token magnitude (the paper's verdict method).
    TokenMagnitude,
    /// Per-token output-aware (Key cache; needs query window).
    TokenOutputAware,
    /// Per-channel magnitude in 32-token groups (Value cache study).
    ChannelMagnitude,
    /// Per-channel output-aware (Value cache; needs attention window).
    ChannelOutputAware,
    /// ThinK-style structured channel removal.
    ThinkStructured,
    /// 2:4 semi-structured (sparsity fixed at 0.5).
    Semi24,
}

impl Method {
    pub fn parse(s: &str) -> Option<Method> {
        Some(match s {
            "none" => Method::None,
            "token-magnitude" | "magnitude" => Method::TokenMagnitude,
            "token-output-aware" | "output-aware" => Method::TokenOutputAware,
            "channel-magnitude" => Method::ChannelMagnitude,
            "channel-output-aware" => Method::ChannelOutputAware,
            "think" | "structured" => Method::ThinkStructured,
            "2:4" | "semi24" => Method::Semi24,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::None => "none",
            Method::TokenMagnitude => "token-magnitude",
            Method::TokenOutputAware => "token-output-aware",
            Method::ChannelMagnitude => "channel-magnitude",
            Method::ChannelOutputAware => "channel-output-aware",
            Method::ThinkStructured => "think",
            Method::Semi24 => "2:4",
        }
    }
}

/// Side information some methods need (computed by the harness/engine
/// from the prompt's trailing query window, paper Fig 3 / §2.2).
pub struct OutputAwareCtx<'a> {
    /// Σ_w |Q_w| per channel (GQA: summed over the queries of the group).
    pub q_abs_sum: Option<&'a [f32]>,
    /// Σ_w α_w per token (attention mass received over the window).
    pub att_sum: Option<&'a [f32]>,
}

impl<'a> OutputAwareCtx<'a> {
    pub fn none() -> OutputAwareCtx<'static> {
        OutputAwareCtx { q_abs_sum: None, att_sum: None }
    }
}

/// Apply `method` at `sparsity` to a `[tokens x channels]` cache matrix.
/// Panics if a required output-aware context is missing (programmer error
/// — the harness wires these explicitly).
pub fn apply(
    method: Method,
    x: &[f32],
    tokens: usize,
    channels: usize,
    sparsity: f64,
    ctx: &OutputAwareCtx,
) -> Vec<f32> {
    if tokens == 0 {
        return Vec::new();
    }
    match method {
        Method::None => x.to_vec(),
        Method::TokenMagnitude => {
            per_token_magnitude(x, tokens, channels, keep_count(channels, sparsity))
        }
        Method::TokenOutputAware => per_token_output_aware(
            x,
            tokens,
            channels,
            ctx.q_abs_sum.expect("TokenOutputAware needs q_abs_sum"),
            keep_count(channels, sparsity),
        ),
        Method::ChannelMagnitude => per_channel_magnitude(x, tokens, channels, sparsity),
        Method::ChannelOutputAware => per_channel_output_aware(
            x,
            tokens,
            channels,
            ctx.att_sum.expect("ChannelOutputAware needs att_sum"),
            sparsity,
        ),
        Method::ThinkStructured => {
            // For the Key cache ThinK is query-driven; for Value the
            // magnitude variant is used. The harness passes q_abs_sum for
            // K and leaves it None for V.
            match ctx.q_abs_sum {
                Some(q) => think_key(x, tokens, channels, q, sparsity).0,
                None => think_value(x, tokens, channels, sparsity).0,
            }
        }
        Method::Semi24 => semi_24(x, tokens, channels),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn keep_count_rounding() {
        assert_eq!(keep_count(64, 0.5), 32);
        assert_eq!(keep_count(64, 0.7), 19); // 64*0.3 = 19.2 -> 19
        assert_eq!(keep_count(128, 0.7), 38); // 128*0.3 = 38.4 -> 38
        assert_eq!(keep_count(64, 0.0), 64);
        assert_eq!(keep_count(64, 0.99), 1);
        assert_eq!(keep_count(10, 0.75), 3); // 2.5 rounds half-up to 3
    }

    #[test]
    fn method_parse_roundtrip() {
        for m in [
            Method::None,
            Method::TokenMagnitude,
            Method::TokenOutputAware,
            Method::ChannelMagnitude,
            Method::ChannelOutputAware,
            Method::ThinkStructured,
            Method::Semi24,
        ] {
            assert_eq!(Method::parse(m.name()), Some(m));
        }
        assert_eq!(Method::parse("bogus"), None);
    }

    #[test]
    fn apply_dispatch_sparsity() {
        let mut rng = Pcg32::seeded(10);
        let (t, d) = (64, 64);
        let x: Vec<f32> = (0..t * d).map(|_| rng.normal_f32()).collect();
        let ctx = OutputAwareCtx::none();
        for (m, s) in [
            (Method::TokenMagnitude, 0.5),
            (Method::ChannelMagnitude, 0.5),
            (Method::Semi24, 0.5),
        ] {
            let p = apply(m, &x, t, d, s, &ctx);
            let nnz = p.iter().filter(|v| **v != 0.0).count() as f64;
            let rate = nnz / (t * d) as f64;
            assert!((rate - 0.5).abs() < 0.02, "{m:?}: kept {rate}");
        }
        let p = apply(Method::None, &x, t, d, 0.5, &ctx);
        assert_eq!(p, x);
    }

    #[test]
    fn apply_empty_input() {
        let ctx = OutputAwareCtx::none();
        assert!(apply(Method::TokenMagnitude, &[], 0, 64, 0.5, &ctx).is_empty());
    }
}
