//! Per-token pruning (§2): sparsity is induced across each token's vector
//! (over channels). The paper's headline method is per-token *magnitude*
//! pruning; the output-aware variant weights each Key element by the
//! L1-accumulated query magnitudes (Fig 3).
//!
//! Tie-break convention (shared with the L1 kernel and ref.py): among
//! equal scores the lower channel index wins.

/// Select, per row, the `kk` largest entries of `score` and copy the
/// corresponding `x` entries into the output (everything else zero).
///
/// `x` and `score` are row-major `[tokens x channels]`.
pub fn select_top_per_row(
    x: &[f32],
    score: &[f32],
    tokens: usize,
    channels: usize,
    kk: usize,
) -> Vec<f32> {
    assert_eq!(x.len(), tokens * channels);
    assert_eq!(score.len(), tokens * channels);
    assert!(kk >= 1 && kk <= channels);
    let mut out = vec![0.0f32; tokens * channels];
    let mut idx: Vec<u32> = Vec::with_capacity(channels);
    for t in 0..tokens {
        let s = &score[t * channels..(t + 1) * channels];
        idx.clear();
        idx.extend(0..channels as u32);
        if kk < channels {
            // Partial selection: kk largest by (score desc, index asc).
            idx.select_nth_unstable_by(kk - 1, |&a, &b| {
                s[b as usize]
                    .partial_cmp(&s[a as usize])
                    .unwrap()
                    .then(a.cmp(&b))
            });
            idx.truncate(kk);
        }
        let xr = &x[t * channels..(t + 1) * channels];
        let or = &mut out[t * channels..(t + 1) * channels];
        for &c in idx.iter() {
            or[c as usize] = xr[c as usize];
        }
    }
    out
}

/// Per-token magnitude pruning: keep the `kk` largest-|.| elements of each
/// token's vector. The paper's verdict method for both K and V caches.
pub fn per_token_magnitude(x: &[f32], tokens: usize, channels: usize, kk: usize) -> Vec<f32> {
    let score: Vec<f32> = x.iter().map(|v| v.abs()).collect();
    select_top_per_row(x, &score, tokens, channels, kk)
}

/// In-place `per_token_magnitude`: zero everything but the `kk`
/// largest-|.| elements of each row. Bit-identical to the copying
/// variant (same selection comparator incl. the lower-index tie-break),
/// but allocation-free apart from one index scratch — the decode
/// group-commit hot path (`SequenceKV::commit_token`) prunes its
/// widened scratch directly instead of materializing a pruned copy per
/// head every 64 tokens.
pub fn per_token_magnitude_inplace(x: &mut [f32], tokens: usize, channels: usize, kk: usize) {
    assert_eq!(x.len(), tokens * channels);
    assert!(kk >= 1 && kk <= channels);
    if kk == channels {
        return;
    }
    let mut idx: Vec<u32> = Vec::with_capacity(channels);
    for t in 0..tokens {
        let r = &mut x[t * channels..(t + 1) * channels];
        idx.clear();
        idx.extend(0..channels as u32);
        // same ordering as `select_top_per_row`: |x| desc, index asc
        idx.select_nth_unstable_by(kk - 1, |&a, &b| {
            r[b as usize]
                .abs()
                .partial_cmp(&r[a as usize].abs())
                .unwrap()
                .then(a.cmp(&b))
        });
        for &c in &idx[kk..] {
            r[c as usize] = 0.0;
        }
    }
}

/// Per-token *output-aware* Key pruning (Fig 3):
/// `S = |K| ⊙ broadcast(Σ_w |Q_w|)`; keep the per-token top-kk by S.
///
/// `q_abs_sum` is the element-wise L1 accumulation of the query window
/// (the harness sums the last 32 prompt queries; for GQA the scores of all
/// queries mapped to a KV head are summed — the caller does that fold).
pub fn per_token_output_aware(
    k: &[f32],
    tokens: usize,
    channels: usize,
    q_abs_sum: &[f32],
    kk: usize,
) -> Vec<f32> {
    assert_eq!(q_abs_sum.len(), channels);
    let mut score = vec![0.0f32; tokens * channels];
    for t in 0..tokens {
        for c in 0..channels {
            score[t * channels + c] = k[t * channels + c].abs() * q_abs_sum[c];
        }
    }
    select_top_per_row(k, &score, tokens, channels, kk)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn keeps_exactly_kk_per_row() {
        let mut rng = Pcg32::seeded(1);
        let (t, d, kk) = (16, 64, 20);
        let x: Vec<f32> = (0..t * d).map(|_| rng.normal_f32()).collect();
        let p = per_token_magnitude(&x, t, d, kk);
        for tt in 0..t {
            let n = p[tt * d..(tt + 1) * d].iter().filter(|v| **v != 0.0).count();
            assert_eq!(n, kk);
        }
    }

    #[test]
    fn keeps_largest_magnitudes() {
        let x = vec![0.1, -5.0, 0.2, 3.0, -0.3, 0.05, 1.0, -2.0];
        let p = per_token_magnitude(&x, 1, 8, 3);
        assert_eq!(p, vec![0.0, -5.0, 0.0, 3.0, 0.0, 0.0, 0.0, -2.0]);
    }

    #[test]
    fn tie_break_prefers_lower_index() {
        let x = vec![1.0, -1.0, 1.0, 1.0];
        let p = per_token_magnitude(&x, 1, 4, 2);
        assert_eq!(p, vec![1.0, -1.0, 0.0, 0.0]);
    }

    #[test]
    fn output_aware_reweights() {
        // |K| equal everywhere; q weights pick channels 2 and 0.
        let k = vec![1.0f32; 4];
        let q = vec![0.5, 0.1, 0.9, 0.2];
        let p = per_token_output_aware(&k, 1, 4, &q, 2);
        assert_eq!(p, vec![1.0, 0.0, 1.0, 0.0]);
    }

    #[test]
    fn inplace_matches_copying_variant_bitexact() {
        let mut rng = Pcg32::seeded(5);
        for &(t, d, kk) in &[(8, 32, 10), (3, 7, 1), (16, 64, 64), (1, 4, 2), (5, 100, 31)] {
            let x: Vec<f32> = (0..t * d).map(|_| rng.normal_f32()).collect();
            let want = per_token_magnitude(&x, t, d, kk);
            let mut got = x.clone();
            per_token_magnitude_inplace(&mut got, t, d, kk);
            assert_eq!(got, want, "t={t} d={d} kk={kk}");
        }
        // ties resolve identically too
        let x = vec![1.0f32, -1.0, 1.0, 1.0];
        let mut got = x.clone();
        per_token_magnitude_inplace(&mut got, 1, 4, 2);
        assert_eq!(got, per_token_magnitude(&x, 1, 4, 2));
    }

    #[test]
    fn kk_equals_channels_is_identity() {
        let mut rng = Pcg32::seeded(2);
        let x: Vec<f32> = (0..4 * 8).map(|_| rng.normal_f32()).collect();
        assert_eq!(per_token_magnitude(&x, 4, 8, 8), x);
    }

    #[test]
    fn preserved_values_are_unmodified() {
        let mut rng = Pcg32::seeded(3);
        let (t, d, kk) = (8, 32, 10);
        let x: Vec<f32> = (0..t * d).map(|_| rng.normal_f32()).collect();
        let p = per_token_magnitude(&x, t, d, kk);
        for (orig, kept) in x.iter().zip(&p) {
            assert!(*kept == 0.0 || kept == orig);
        }
    }
}
