//! ThinK-style structured (per-channel removal) pruning baseline [38].
//!
//! ThinK drops entire Key-cache channels using a query-driven score
//! accumulated over the last 32 queries. We reproduce it in spirit:
//! channel score = (Σ_w |Q_w[c]|) · ‖K[:,c]‖₂, keep the top ⌈(1-s)·D⌉
//! channels, zero the rest. For the Value cache (paper Tables 2/8) the
//! same structured scheme with a pure magnitude score ‖V[:,c]‖₂ is used.

/// Structured Key-cache pruning: drop whole channels by query-driven score.
/// Returns the pruned matrix and the kept-channel mask.
pub fn think_key(
    k: &[f32],
    tokens: usize,
    channels: usize,
    q_abs_sum: &[f32],
    sparsity: f64,
) -> (Vec<f32>, Vec<bool>) {
    assert_eq!(k.len(), tokens * channels);
    assert_eq!(q_abs_sum.len(), channels);
    let mut score = vec![0.0f64; channels];
    for c in 0..channels {
        let mut norm2 = 0.0f64;
        for t in 0..tokens {
            let x = k[t * channels + c] as f64;
            norm2 += x * x;
        }
        score[c] = q_abs_sum[c] as f64 * norm2.sqrt();
    }
    apply_channel_mask(k, tokens, channels, &score, sparsity)
}

/// Structured Value-cache pruning: drop whole channels by L2 magnitude.
pub fn think_value(
    v: &[f32],
    tokens: usize,
    channels: usize,
    sparsity: f64,
) -> (Vec<f32>, Vec<bool>) {
    assert_eq!(v.len(), tokens * channels);
    let mut score = vec![0.0f64; channels];
    for c in 0..channels {
        let mut norm2 = 0.0f64;
        for t in 0..tokens {
            let x = v[t * channels + c] as f64;
            norm2 += x * x;
        }
        score[c] = norm2.sqrt();
    }
    apply_channel_mask(v, tokens, channels, &score, sparsity)
}

fn apply_channel_mask(
    x: &[f32],
    tokens: usize,
    channels: usize,
    score: &[f64],
    sparsity: f64,
) -> (Vec<f32>, Vec<bool>) {
    let keep = (((channels as f64) * (1.0 - sparsity) + 0.5).floor() as usize)
        .clamp(1, channels);
    let mut order: Vec<usize> = (0..channels).collect();
    order.sort_by(|&a, &b| score[b].partial_cmp(&score[a]).unwrap().then(a.cmp(&b)));
    let mut mask = vec![false; channels];
    for &c in order.iter().take(keep) {
        mask[c] = true;
    }
    let mut out = vec![0.0f32; tokens * channels];
    for t in 0..tokens {
        for c in 0..channels {
            if mask[c] {
                out[t * channels + c] = x[t * channels + c];
            }
        }
    }
    (out, mask)
}

/// Structured pruning memory accounting: kept channels remain dense, so
/// the compressed size is simply the kept fraction (no bitmap needed).
/// The paper's Fig 6b: K-only 50% ThinK => 75% of the *full KV* footprint.
pub fn structured_compression_rate(mask: &[bool]) -> f64 {
    mask.iter().filter(|m| **m).count() as f64 / mask.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn drops_whole_channels() {
        let mut rng = Pcg32::seeded(6);
        let (t, d) = (50, 16);
        let k: Vec<f32> = (0..t * d).map(|_| rng.normal_f32()).collect();
        let q = vec![1.0f32; d];
        let (p, mask) = think_key(&k, t, d, &q, 0.5);
        assert_eq!(mask.iter().filter(|m| **m).count(), 8);
        for c in 0..d {
            let any = (0..t).any(|tt| p[tt * d + c] != 0.0);
            if mask[c] {
                assert!(any || k.iter().skip(c).step_by(d).all(|x| *x == 0.0));
            } else {
                assert!(!any, "dropped channel {c} has survivors");
            }
        }
    }

    #[test]
    fn query_weighting_changes_selection() {
        // channel 0 large K but zero query weight; channel 1 small K but
        // large weight -> ThinK keeps channel 1.
        let k = vec![10.0, 0.1, 10.0, 0.1];
        let q = vec![0.0, 5.0];
        let (_, mask) = think_key(&k, 2, 2, &q, 0.5);
        assert_eq!(mask, vec![false, true]);
    }

    #[test]
    fn value_variant_magnitude_only() {
        let v = vec![3.0, 0.1, 3.0, 0.2];
        let (_, mask) = think_value(&v, 2, 2, 0.5);
        assert_eq!(mask, vec![true, false]);
    }

    #[test]
    fn compression_rate_is_kept_fraction() {
        assert_eq!(structured_compression_rate(&[true, false, true, false]), 0.5);
    }
}
