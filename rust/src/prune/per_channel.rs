//! Per-channel pruning (§2.2): sparsity is induced across tokens for each
//! channel. The paper prunes each channel within *32-token groups* (for
//! compatibility with the local-window size) and explores magnitude and
//! output-aware scores for the Value cache.

/// Token-group size used by per-channel pruning (paper §2.2).
pub const CHANNEL_GROUP: usize = 32;

/// Number of kept tokens for a group of `glen` tokens at target sparsity.
fn group_keep(glen: usize, sparsity: f64) -> usize {
    ((glen as f64 * (1.0 - sparsity) + 0.5).floor() as usize).max(1)
}

/// Shared scaffolding: per (channel, 32-token group), keep the `keep`
/// highest-scored tokens. `score` has the same layout as `x`.
fn select_per_channel(
    x: &[f32],
    score: &[f32],
    tokens: usize,
    channels: usize,
    sparsity: f64,
) -> Vec<f32> {
    assert_eq!(x.len(), tokens * channels);
    let mut out = vec![0.0f32; tokens * channels];
    let mut order: Vec<u32> = Vec::with_capacity(CHANNEL_GROUP);
    let mut g0 = 0usize;
    while g0 < tokens {
        let glen = CHANNEL_GROUP.min(tokens - g0);
        let keep = group_keep(glen, sparsity).min(glen);
        for c in 0..channels {
            order.clear();
            order.extend(0..glen as u32);
            if keep < glen {
                order.select_nth_unstable_by(keep - 1, |&a, &b| {
                    let sa = score[(g0 + a as usize) * channels + c];
                    let sb = score[(g0 + b as usize) * channels + c];
                    sb.partial_cmp(&sa).unwrap().then(a.cmp(&b))
                });
                order.truncate(keep);
            }
            for &r in order.iter() {
                let t = g0 + r as usize;
                out[t * channels + c] = x[t * channels + c];
            }
        }
        g0 += glen;
    }
    out
}

/// Per-channel magnitude pruning of the Value cache.
pub fn per_channel_magnitude(v: &[f32], tokens: usize, channels: usize, sparsity: f64) -> Vec<f32> {
    let score: Vec<f32> = v.iter().map(|x| x.abs()).collect();
    select_per_channel(v, &score, tokens, channels, sparsity)
}

/// Per-channel *output-aware* Value pruning (§2.2):
/// `S = |V| ⊙ broadcast(Σ_w |α_w|)` where `att_sum[t]` is the accumulated
/// attention mass token t receives over the query window.
pub fn per_channel_output_aware(
    v: &[f32],
    tokens: usize,
    channels: usize,
    att_sum: &[f32],
    sparsity: f64,
) -> Vec<f32> {
    assert_eq!(att_sum.len(), tokens);
    let mut score = vec![0.0f32; tokens * channels];
    for t in 0..tokens {
        let a = att_sum[t];
        for c in 0..channels {
            score[t * channels + c] = v[t * channels + c].abs() * a;
        }
    }
    select_per_channel(v, &score, tokens, channels, sparsity)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn per_channel_sparsity_within_groups() {
        let mut rng = Pcg32::seeded(4);
        let (t, d) = (96, 16); // three full groups
        let v: Vec<f32> = (0..t * d).map(|_| rng.normal_f32() + 0.01).collect();
        let p = per_channel_magnitude(&v, t, d, 0.5);
        for g in 0..3 {
            for c in 0..d {
                let kept = (0..CHANNEL_GROUP)
                    .filter(|r| p[(g * CHANNEL_GROUP + r) * d + c] != 0.0)
                    .count();
                assert_eq!(kept, 16, "group {g} channel {c}");
            }
        }
    }

    #[test]
    fn ragged_tail_group() {
        let mut rng = Pcg32::seeded(5);
        let (t, d) = (40, 4); // 32 + 8 tail
        let v: Vec<f32> = (0..t * d).map(|_| rng.normal_f32() + 0.01).collect();
        let p = per_channel_magnitude(&v, t, d, 0.7);
        // tail group of 8 tokens at 70% -> keep round(8*0.3)=2
        for c in 0..d {
            let kept = (32..40).filter(|&tt| p[tt * d + c] != 0.0).count();
            assert_eq!(kept, 2, "channel {c}");
        }
    }

    #[test]
    fn output_aware_prefers_attended_tokens() {
        // Uniform |V|, attention mass concentrated on token 3 -> token 3's
        // elements survive in every channel.
        let (t, d) = (32, 2);
        let v = vec![1.0f32; t * d];
        let mut att = vec![0.01f32; t];
        att[3] = 5.0;
        let p = per_channel_output_aware(&v, t, d, &att, 0.9);
        for c in 0..d {
            assert!(p[3 * d + c] != 0.0);
        }
    }

    #[test]
    fn keeps_at_least_one_per_group() {
        let v = vec![1.0f32; 32 * 2];
        let p = per_channel_magnitude(&v, 32, 2, 0.99);
        for c in 0..2 {
            let kept = (0..32).filter(|&t| p[t * 2 + c] != 0.0).count();
            assert_eq!(kept, 1);
        }
    }
}
