//! 2:4 semi-structured pruning (App. B): within every 4 consecutive
//! channels of a token's vector, keep the 2 largest-magnitude elements —
//! a global 50% sparsity with the pattern NVIDIA sparse tensor cores
//! support. Used only for the accuracy comparison of Table 12.

/// Apply 2:4 semi-structured magnitude pruning along each row.
/// `channels` must be a multiple of 4.
pub fn semi_24(x: &[f32], tokens: usize, channels: usize) -> Vec<f32> {
    assert_eq!(x.len(), tokens * channels);
    assert_eq!(channels % 4, 0, "2:4 needs channels % 4 == 0");
    let mut out = vec![0.0f32; x.len()];
    for t in 0..tokens {
        for g in 0..channels / 4 {
            let base = t * channels + g * 4;
            let grp = &x[base..base + 4];
            // indices of the two largest |.| (ties -> lower index)
            let mut idx = [0usize, 1, 2, 3];
            idx.sort_by(|&a, &b| {
                grp[b].abs().partial_cmp(&grp[a].abs()).unwrap().then(a.cmp(&b))
            });
            out[base + idx[0]] = grp[idx[0]];
            out[base + idx[1]] = grp[idx[1]];
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    #[test]
    fn exactly_two_of_four_survive() {
        let mut rng = Pcg32::seeded(8);
        let (t, d) = (8, 64);
        let x: Vec<f32> = (0..t * d).map(|_| rng.normal_f32()).collect();
        let p = semi_24(&x, t, d);
        for tt in 0..t {
            for g in 0..d / 4 {
                let grp = &p[tt * d + g * 4..tt * d + g * 4 + 4];
                assert_eq!(grp.iter().filter(|v| **v != 0.0).count(), 2);
            }
        }
    }

    #[test]
    fn keeps_the_largest() {
        let x = vec![0.1, -3.0, 2.0, 0.5];
        assert_eq!(semi_24(&x, 1, 4), vec![0.0, -3.0, 2.0, 0.0]);
    }

    #[test]
    fn global_sparsity_is_half() {
        let mut rng = Pcg32::seeded(9);
        let x: Vec<f32> = (0..64 * 64).map(|_| rng.normal_f32()).collect();
        let p = semi_24(&x, 64, 64);
        let nnz = p.iter().filter(|v| **v != 0.0).count();
        assert_eq!(nnz, 64 * 64 / 2);
    }
}
