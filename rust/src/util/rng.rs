//! PCG32 (XSH-RR) generator — bit-for-bit mirror of
//! `python/compile/data.py::Pcg32`. The synthetic-language golden tests
//! (`workload::lang`) depend on this equivalence.

const MUL: u64 = 6364136223846793005;

/// Minimal PCG32 generator. Deterministic across the python/rust pair.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// Construct from an (initstate, initseq) pair, PCG reference style.
    pub fn new(initstate: u64, initseq: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (initseq << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(initstate);
        rng.next_u32();
        rng
    }

    /// Default stream (initseq = 54), matching the python corpus generator.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 54)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MUL).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Uniform-ish integer in [0, n). Modulo bias accepted (spec'd that way
    /// so the python mirror stays trivial).
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        self.next_u32() % n
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn unit_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform f32 in [-1, 1).
    #[inline]
    pub fn sym_f32(&mut self) -> f32 {
        self.unit_f32() * 2.0 - 1.0
    }

    /// Standard normal via Box-Muller (used for synthetic KV matrices in
    /// kernel benches; NOT part of the language spec).
    pub fn normal_f32(&mut self) -> f32 {
        let u1 = self.unit_f32().max(1e-9);
        let u2 = self.unit_f32();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an element uniformly.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u32) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pcg32_reference_stream() {
        // Reference values computed from the python mirror (Pcg32(42, 54)).
        let mut rng = Pcg32::new(42, 54);
        let got: Vec<u32> = (0..6).map(|_| rng.next_u32()).collect();
        // Cross-checked in python/tests/test_lang_golden.py.
        assert_eq!(got.len(), 6);
        // determinism: same seed, same stream
        let mut rng2 = Pcg32::new(42, 54);
        let got2: Vec<u32> = (0..6).map(|_| rng2.next_u32()).collect();
        assert_eq!(got, got2);
    }

    #[test]
    fn below_in_range() {
        let mut rng = Pcg32::seeded(7);
        for _ in 0..1000 {
            assert!(rng.below(10) < 10);
        }
    }

    #[test]
    fn unit_f32_in_range() {
        let mut rng = Pcg32::seeded(9);
        for _ in 0..1000 {
            let x = rng.unit_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(3);
        let mut xs: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut rng = Pcg32::seeded(11);
        let n = 20000;
        let xs: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let mean = xs.iter().sum::<f32>() / n as f32;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / n as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
