//! Small statistics helpers shared by the bench harness and metrics.

/// Summary statistics over a sample of f64 measurements.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p10: f64,
    pub p50: f64,
    pub p90: f64,
    pub p95: f64,
    pub max: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let mut xs = samples.to_vec();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: xs[0],
            p10: percentile_sorted(&xs, 0.10),
            p50: percentile_sorted(&xs, 0.50),
            p90: percentile_sorted(&xs, 0.90),
            p95: percentile_sorted(&xs, 0.95),
            max: xs[n - 1],
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=1.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let pos = p * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Mean of a slice (0.0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() { 0.0 } else { xs.iter().sum::<f64>() / xs.len() as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!((percentile_sorted(&xs, 0.5) - 5.0).abs() < 1e-12);
        assert_eq!(percentile_sorted(&xs, 0.0), 0.0);
        assert_eq!(percentile_sorted(&xs, 1.0), 10.0);
    }

    #[test]
    fn single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.p90, 7.0);
        assert_eq!(s.std, 0.0);
    }

    #[test]
    #[should_panic]
    fn empty_sample_panics() {
        let _ = Summary::of(&[]);
    }
}
