//! Shared utilities: deterministic RNG (python-mirrored), statistics,
//! timing, and a minimal logger.

pub mod rng;
pub mod stats;

pub use rng::Pcg32;
pub use stats::Summary;

use std::time::Instant;

/// Wall-clock stopwatch with ms/us readouts.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }
    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
    pub fn elapsed_us(&self) -> f64 {
        self.elapsed_s() * 1e6
    }
}

/// Log level for the tiny env-controlled logger (`MUSTAFAR_LOG=debug`).
#[derive(Clone, Copy, PartialEq, PartialOrd)]
pub enum Level {
    Error = 0,
    Info = 1,
    Debug = 2,
}

pub fn log_level() -> Level {
    match std::env::var("MUSTAFAR_LOG").as_deref() {
        Ok("debug") => Level::Debug,
        Ok("error") => Level::Error,
        _ => Level::Info,
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= $crate::util::Level::Info {
            eprintln!("[info] {}", format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {
        if $crate::util::log_level() >= $crate::util::Level::Debug {
            eprintln!("[debug] {}", format!($($arg)*));
        }
    };
}

/// Worker thread count: `MUSTAFAR_THREADS` env override, else the
/// machine's available parallelism.
pub fn threads() -> usize {
    static THREADS: std::sync::OnceLock<usize> = std::sync::OnceLock::new();
    *THREADS.get_or_init(|| {
        std::env::var("MUSTAFAR_THREADS")
            .ok()
            .and_then(|s| s.parse().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
            })
    })
}

/// Round `x` up to the next multiple of `m` (m > 0).
#[inline]
pub fn round_up(x: usize, m: usize) -> usize {
    x.div_ceil(m) * m
}

/// Integer ceil-div.
#[inline]
pub fn ceil_div(x: usize, m: usize) -> usize {
    x.div_ceil(m)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rounding() {
        assert_eq!(round_up(0, 8), 0);
        assert_eq!(round_up(1, 8), 8);
        assert_eq!(round_up(8, 8), 8);
        assert_eq!(round_up(9, 8), 16);
        assert_eq!(ceil_div(9, 8), 2);
    }

    #[test]
    fn stopwatch_monotonic() {
        let sw = Stopwatch::start();
        std::thread::sleep(std::time::Duration::from_millis(2));
        assert!(sw.elapsed_ms() >= 1.0);
    }
}
