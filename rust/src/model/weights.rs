//! Weight loading: reads the `weights_{cfg}.bin` + `weights_{cfg}.json`
//! pair exported by `python/compile/train.py`. The flat-list manifest
//! order is the python↔rust ABI (model.py::param_manifest).

use std::collections::HashMap;
use std::io::Read;
use std::path::Path;

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::fmt::Json;
use crate::tensor::Tensor;

/// Loaded model weights plus config.
#[derive(Clone)]
pub struct Weights {
    pub cfg: ModelConfig,
    pub params: Vec<Tensor>,
    pub names: Vec<String>,
    index: HashMap<String, usize>,
    /// Final training loss recorded by the exporter (provenance).
    pub final_loss: f64,
}

impl Weights {
    /// Load `weights_{name}.{bin,json}` from `dir`.
    pub fn load(dir: &Path, name: &str) -> Result<Weights> {
        let meta_path = dir.join(format!("weights_{name}.json"));
        let bin_path = dir.join(format!("weights_{name}.bin"));
        let meta = Json::parse(&std::fs::read_to_string(&meta_path).map_err(|e| {
            let p = meta_path.display();
            Error::Runtime(format!("cannot read {p} ({e}) — run `make artifacts`"))
        })?)?;
        let cfg = ModelConfig::from_json(&meta)?;
        cfg.validate()?;

        let mut blob = Vec::new();
        std::fs::File::open(&bin_path)?.read_to_end(&mut blob)?;
        let total = meta.get("total_bytes")?.as_usize()?;
        if blob.len() != total {
            return Err(Error::Runtime(format!(
                "{}: {} bytes, manifest says {total}",
                bin_path.display(),
                blob.len()
            )));
        }

        let mut params = Vec::new();
        let mut names = Vec::new();
        let mut index = HashMap::new();
        for p in meta.get("params")?.as_arr()? {
            let pname = p.get("name")?.as_str()?.to_string();
            let shape = p.get("shape")?.as_usize_vec()?;
            let offset = p.get("offset")?.as_usize()?;
            let nbytes = p.get("nbytes")?.as_usize()?;
            let n: usize = shape.iter().product();
            if nbytes != n * 4 || offset + nbytes > blob.len() {
                return Err(Error::Runtime(format!("bad manifest entry for {pname}")));
            }
            let mut data = vec![0.0f32; n];
            for (i, chunk) in blob[offset..offset + nbytes].chunks_exact(4).enumerate() {
                data[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
            }
            index.insert(pname.clone(), params.len());
            names.push(pname);
            params.push(Tensor::new(shape, data)?);
        }

        let final_loss = meta.opt("final_loss").and_then(|v| v.as_f64().ok()).unwrap_or(f64::NAN);
        Ok(Weights { cfg, params, names, index, final_loss })
    }

    /// Named parameter access ("layer0.wq", "tok_emb", ...).
    pub fn get(&self, name: &str) -> &Tensor {
        let i = *self
            .index
            .get(name)
            .unwrap_or_else(|| panic!("missing weight '{name}'"));
        &self.params[i]
    }

    pub fn layer(&self, l: usize, part: &str) -> &Tensor {
        self.get(&format!("layer{l}.{part}"))
    }

    /// Total parameter count.
    pub fn n_params(&self) -> usize {
        self.params.iter().map(|t| t.len()).sum()
    }

    /// Synthesize random weights for unit tests (bypasses disk).
    pub fn random_for_tests(cfg: ModelConfig, seed: u64) -> Weights {
        let mut rng = crate::util::Pcg32::seeded(seed);
        let mut params = Vec::new();
        let mut names = Vec::new();
        let mut index = HashMap::new();
        let manifest = manifest_for(&cfg);
        for (name, shape) in manifest {
            let n: usize = shape.iter().product();
            let std = 1.0 / (shape[0] as f32).sqrt();
            let data: Vec<f32> = if name.ends_with("norm") {
                vec![1.0; n]
            } else {
                (0..n).map(|_| rng.normal_f32() * std).collect()
            };
            index.insert(name.clone(), params.len());
            names.push(name);
            params.push(Tensor::new(shape, data).unwrap());
        }
        Weights { cfg, params, names, index, final_loss: f64::NAN }
    }
}

/// The parameter manifest (name, shape) in ABI order — mirror of
/// python/compile/model.py::param_manifest.
pub fn manifest_for(cfg: &ModelConfig) -> Vec<(String, Vec<usize>)> {
    let mut out = vec![("tok_emb".to_string(), vec![cfg.vocab, cfg.d_model])];
    for l in 0..cfg.n_layers {
        let p = format!("layer{l}.");
        out.push((format!("{p}attn_norm"), vec![cfg.d_model]));
        out.push((format!("{p}wq"), vec![cfg.d_model, cfg.q_dim()]));
        out.push((format!("{p}wk"), vec![cfg.d_model, cfg.kv_dim()]));
        out.push((format!("{p}wv"), vec![cfg.d_model, cfg.kv_dim()]));
        out.push((format!("{p}wo"), vec![cfg.q_dim(), cfg.d_model]));
        out.push((format!("{p}mlp_norm"), vec![cfg.d_model]));
        out.push((format!("{p}w_gate"), vec![cfg.d_model, cfg.ff]));
        out.push((format!("{p}w_up"), vec![cfg.d_model, cfg.ff]));
        out.push((format!("{p}w_down"), vec![cfg.ff, cfg.d_model]));
    }
    out.push(("final_norm".to_string(), vec![cfg.d_model]));
    out.push(("lm_head".to_string(), vec![cfg.d_model, cfg.vocab]));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ModelConfig {
        ModelConfig {
            name: "tiny".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 32,
            ff: 128,
            vocab: 512,
            rope_theta: 10000.0,
            max_seq: 256,
            norm_eps: 1e-5,
        }
    }

    #[test]
    fn manifest_matches_python_layout() {
        let m = manifest_for(&tiny_cfg());
        assert_eq!(m.len(), 1 + 2 * 9 + 2);
        assert_eq!(m[0].0, "tok_emb");
        assert_eq!(m[1].0, "layer0.attn_norm");
        assert_eq!(m[10].0, "layer1.attn_norm");
        assert_eq!(m.last().unwrap().0, "lm_head");
        assert_eq!(m[2].1, vec![64, 64]); // wq [d, H*hd]
        assert_eq!(m[3].1, vec![64, 32]); // wk [d, KV*hd]
    }

    #[test]
    fn random_weights_consistent() {
        let w = Weights::random_for_tests(tiny_cfg(), 1);
        assert_eq!(w.get("tok_emb").shape(), &[512, 64]);
        assert_eq!(w.layer(1, "w_down").shape(), &[128, 64]);
        let n = w.n_params();
        assert!(n > 100_000, "{n}");
    }

    #[test]
    #[should_panic(expected = "missing weight")]
    fn missing_weight_panics() {
        let w = Weights::random_for_tests(tiny_cfg(), 1);
        let _ = w.get("layer9.wq");
    }
}
