//! Dense math for the native transformer: threaded blocked matmul,
//! RMSNorm, SiLU. The native path exists for fast accuracy sweeps and as
//! a numerics cross-check against the PJRT artifacts; the serving hot
//! path's sparse attention lives in `sparse::spmv`.
//!
//! The matmul inner sweeps route through the runtime SIMD dispatch table
//! (`sparse::dispatch`): the 4-way-unrolled axpy row update is one
//! `axpy4` call per k-block, so the prefill hot loop reaches AVX2 on the
//! default stable build. Per output element the dispatched sweep performs
//! the identical operation order to the scalar oracle, so results are
//! bit-for-bit independent of the selected tier.

use crate::sparse::dispatch::{kernels, KernelTable};

/// out[m x n] = x[m x k] @ w[k x n], row-major. Accumulates into zeroed
/// output. Parallelizes over row blocks when the work is large enough.
pub fn matmul(x: &[f32], m: usize, k: usize, w: &[f32], n: usize, out: &mut [f32]) {
    matmul_with(kernels(), x, m, k, w, n, out)
}

/// `matmul` through an explicit kernel table (dispatch parity tests).
pub fn matmul_with(
    kt: &KernelTable,
    x: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    n: usize,
    out: &mut [f32],
) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    assert_eq!(out.len(), m * n);

    let flops = 2 * m * k * n;
    let threads = crate::util::threads();
    if flops < 4_000_000 || threads <= 1 || m == 1 {
        matmul_rows(kt, x, m, k, w, n, out);
        return;
    }

    let rows_per = m.div_ceil(threads).max(8);
    std::thread::scope(|scope| {
        let mut out_rest = &mut out[..];
        let mut r0 = 0usize;
        while r0 < m {
            let rows = rows_per.min(m - r0);
            let (chunk, rest) = out_rest.split_at_mut(rows * n);
            out_rest = rest;
            let xs = &x[r0 * k..(r0 + rows) * k];
            scope.spawn(move || {
                matmul_rows(kt, xs, rows, k, w, n, chunk);
            });
            r0 += rows;
        }
    });
}

/// Single-threaded kernel: axpy form (sequential access on both w rows
/// and the output row), 4-way unrolled over k via the dispatched `axpy4`
/// sweep.
fn matmul_rows(
    kt: &KernelTable,
    x: &[f32],
    m: usize,
    k: usize,
    w: &[f32],
    n: usize,
    out: &mut [f32],
) {
    for r in 0..m {
        let xr = &x[r * k..(r + 1) * k];
        let or = &mut out[r * n..(r + 1) * n];
        or.iter_mut().for_each(|v| *v = 0.0);
        let mut kk = 0;
        while kk + 4 <= k {
            let a = [xr[kk], xr[kk + 1], xr[kk + 2], xr[kk + 3]];
            let w0 = &w[kk * n..(kk + 1) * n];
            let w1 = &w[(kk + 1) * n..(kk + 2) * n];
            let w2 = &w[(kk + 2) * n..(kk + 3) * n];
            let w3 = &w[(kk + 3) * n..(kk + 4) * n];
            (kt.axpy4)(or, w0, w1, w2, w3, a);
            kk += 4;
        }
        while kk < k {
            let a = xr[kk];
            if a != 0.0 {
                let wr = &w[kk * n..(kk + 1) * n];
                (kt.fma_f32)(or, wr, a);
            }
            kk += 1;
        }
    }
}

/// RMSNorm over the last axis: y = x / rms(x) * g, row-major `[m x d]`.
pub fn rmsnorm(x: &[f32], m: usize, d: usize, g: &[f32], eps: f32, out: &mut [f32]) {
    assert_eq!(g.len(), d);
    assert_eq!(x.len(), m * d);
    for r in 0..m {
        let xr = &x[r * d..(r + 1) * d];
        let ms: f32 = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let inv = 1.0 / (ms + eps).sqrt();
        let or = &mut out[r * d..(r + 1) * d];
        for c in 0..d {
            or[c] = xr[c] * inv * g[c];
        }
    }
}

/// SiLU (swish) activation.
#[inline]
pub fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn naive_matmul(x: &[f32], m: usize, k: usize, w: &[f32], n: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for r in 0..m {
            for kk in 0..k {
                for c in 0..n {
                    out[r * n + c] += x[r * k + kk] * w[kk * n + c];
                }
            }
        }
        out
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Pcg32::seeded(21);
        for &(m, k, n) in &[(1, 8, 8), (3, 7, 5), (17, 33, 9), (64, 64, 64), (130, 70, 90)] {
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
            let mut got = vec![0.0f32; m * n];
            matmul(&x, m, k, &w, n, &mut got);
            let want = naive_matmul(&x, m, k, &w, n);
            for (g, wv) in got.iter().zip(&want) {
                assert!((g - wv).abs() < 1e-3, "({m},{k},{n}): {g} vs {wv}");
            }
        }
    }

    #[test]
    fn matmul_parallel_path_matches() {
        let mut rng = Pcg32::seeded(22);
        let (m, k, n) = (256, 128, 128); // big enough to trigger threading
        let x: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
        let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
        let mut got = vec![0.0f32; m * n];
        matmul(&x, m, k, &w, n, &mut got);
        let want = naive_matmul(&x, m, k, &w, n);
        for (g, wv) in got.iter().zip(&want) {
            assert!((g - wv).abs() < 1e-2);
        }
    }

    #[test]
    fn matmul_dispatch_parity_all_backends() {
        // Every dispatch tier must produce bit-identical matmul output
        // (the axpy sweeps are element-wise, so vectorization cannot
        // change per-element operation order). Covers the single- and
        // multi-threaded paths plus ragged k/n remainders.
        let sc = crate::sparse::dispatch::KernelTable::scalar();
        let mut rng = Pcg32::seeded(23);
        for kt in crate::sparse::dispatch::available() {
            for &(m, k, n) in &[(1, 8, 8), (3, 7, 5), (17, 33, 9), (64, 64, 64), (256, 128, 128)] {
                let x: Vec<f32> = (0..m * k).map(|_| rng.normal_f32()).collect();
                let w: Vec<f32> = (0..k * n).map(|_| rng.normal_f32()).collect();
                let mut a = vec![0.0f32; m * n];
                let mut b = vec![0.0f32; m * n];
                matmul_with(&kt, &x, m, k, &w, n, &mut a);
                matmul_with(&sc, &x, m, k, &w, n, &mut b);
                assert_eq!(a, b, "{:?} ({m},{k},{n})", kt.backend);
            }
        }
    }

    #[test]
    fn rmsnorm_unit_scale() {
        let x = vec![3.0f32, 4.0]; // rms = sqrt(12.5)
        let g = vec![1.0f32, 1.0];
        let mut out = vec![0.0f32; 2];
        rmsnorm(&x, 1, 2, &g, 0.0, &mut out);
        let rms = (12.5f32).sqrt();
        assert!((out[0] - 3.0 / rms).abs() < 1e-6);
        assert!((out[1] - 4.0 / rms).abs() < 1e-6);
    }

    #[test]
    fn silu_known_values() {
        assert!((silu(0.0)).abs() < 1e-9);
        assert!((silu(10.0) - 10.0).abs() < 1e-3);
        assert!(silu(-10.0).abs() < 1e-3);
    }
}
