//! Model layer: weight loading (python-exported), native transformer
//! forward (prefill + decode over SequenceKV).

pub mod math;
pub mod native;
pub mod weights;

pub use native::{argmax, DecodeScratch, NativeModel, PrefillResult};
pub use weights::Weights;
