//! Native (pure-Rust) transformer forward — prefill and single-token
//! decode over the `SequenceKV` cache. Exists for fast accuracy sweeps
//! (hundreds of LongBench-sim samples across the sparsity grid) and as a
//! numerics cross-check of the PJRT backends; it is bit-architecture
//! identical to `python/compile/model.py` and validated against
//! python-generated goldens in `rust/tests/pipeline.rs`.

use crate::attention;
use crate::config::ModelConfig;
use crate::error::Result;
use crate::kvcache::{PruneAux, SequenceKV};
use crate::model::math::{matmul, rmsnorm, silu};
use crate::model::weights::Weights;
use crate::prune::LOCAL_WINDOW;

/// Everything the eval pipeline needs from a prefill pass.
pub struct PrefillResult {
    /// Logits of the final position `[vocab]`.
    pub logits_last: Vec<f32>,
    /// Post-RoPE key cache per (layer*kv_head), each `[t x hd]`.
    pub k: Vec<Vec<f32>>,
    /// Value cache per (layer*kv_head), each `[t x hd]`.
    pub v: Vec<Vec<f32>>,
    /// Output-aware pruning context (query window / attention window).
    pub aux: PruneAux,
    /// Accumulated attention mass per token over *all* query positions,
    /// per (layer*kv_head) — the H2O heavy-hitter score at prefill end.
    pub att_total: Vec<Vec<f32>>,
    pub t: usize,
}

/// Reusable decode workspace: every temporary the single-token forward
/// needs, owned by the caller (one per active sequence) so that
/// steady-state decode performs no heap allocations. Buffers are sized
/// lazily on first use and reused verbatim afterwards — `matmul` and
/// `rmsnorm` fully overwrite their outputs, and the attention score
/// lanes are cleared/resized in `decode_sparse_group`.
#[derive(Clone, Debug, Default)]
pub struct DecodeScratch {
    x: Vec<f32>,
    hn: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    o: Vec<f32>,
    attn_out: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    down: Vec<f32>,
    last: Vec<f32>,
    /// Logits of the decoded position `[vocab]` (the forward's output).
    pub logits: Vec<f32>,
    cos: Vec<f32>,
    sin: Vec<f32>,
    /// Multi-query attention score lanes over the compressed region.
    s_comp: Vec<f32>,
    /// Multi-query attention score lanes over the dense tail.
    s_tail: Vec<f32>,
}

impl DecodeScratch {
    pub fn new() -> DecodeScratch {
        DecodeScratch::default()
    }

    /// Size all fixed-shape buffers for `cfg` (no-op once sized).
    fn prepare(&mut self, cfg: &ModelConfig) {
        let d = cfg.d_model;
        self.x.resize(d, 0.0);
        self.hn.resize(d, 0.0);
        self.q.resize(cfg.q_dim(), 0.0);
        self.k.resize(cfg.kv_dim(), 0.0);
        self.v.resize(cfg.kv_dim(), 0.0);
        self.o.resize(cfg.q_dim(), 0.0);
        self.attn_out.resize(d, 0.0);
        self.gate.resize(cfg.ff, 0.0);
        self.up.resize(cfg.ff, 0.0);
        self.down.resize(d, 0.0);
        self.last.resize(d, 0.0);
        self.logits.resize(cfg.vocab, 0.0);
    }
}

/// Native model: config + weights.
pub struct NativeModel {
    pub w: Weights,
}

impl NativeModel {
    pub fn new(w: Weights) -> NativeModel {
        NativeModel { w }
    }

    pub fn cfg(&self) -> &ModelConfig {
        &self.w.cfg
    }

    /// Full-context forward. `capture_aux` additionally materializes the
    /// per-head attention matrices to build output-aware scores (slower;
    /// only the pruning-method studies need it).
    pub fn prefill(&self, tokens: &[u16], capture_aux: bool) -> PrefillResult {
        let cfg = self.cfg().clone();
        let t = tokens.len();
        let (d, hd) = (cfg.d_model, cfg.head_dim);
        let (nh, nkv, group) = (cfg.n_heads, cfg.n_kv_heads, cfg.group());
        let scale = 1.0 / (hd as f32).sqrt();
        let win = LOCAL_WINDOW.min(t);

        // token embeddings
        let emb = self.w.get("tok_emb");
        let mut x = vec![0.0f32; t * d];
        for (i, &tok) in tokens.iter().enumerate() {
            x[i * d..(i + 1) * d].copy_from_slice(emb.row(tok as usize));
        }

        // rope tables per position
        let ropes: Vec<(Vec<f32>, Vec<f32>)> =
            (0..t).map(|p| attention::rope_cos_sin(p, hd, cfg.rope_theta)).collect();

        let mut k_out: Vec<Vec<f32>> = Vec::with_capacity(cfg.n_layers * nkv);
        let mut v_out: Vec<Vec<f32>> = Vec::with_capacity(cfg.n_layers * nkv);
        let mut aux = PruneAux::default();
        let mut att_total: Vec<Vec<f32>> = Vec::new();

        let mut hn = vec![0.0f32; t * d];
        let mut probs_buf = Vec::new();

        for l in 0..cfg.n_layers {
            rmsnorm(&x, t, d, self.w.layer(l, "attn_norm").data(), cfg.norm_eps as f32, &mut hn);

            let mut q = vec![0.0f32; t * cfg.q_dim()];
            let mut k = vec![0.0f32; t * cfg.kv_dim()];
            let mut v = vec![0.0f32; t * cfg.kv_dim()];
            matmul(&hn, t, d, self.w.layer(l, "wq").data(), cfg.q_dim(), &mut q);
            matmul(&hn, t, d, self.w.layer(l, "wk").data(), cfg.kv_dim(), &mut k);
            matmul(&hn, t, d, self.w.layer(l, "wv").data(), cfg.kv_dim(), &mut v);

            // rope on q and k, per head
            for i in 0..t {
                let (cos, sin) = &ropes[i];
                for h in 0..nh {
                    let span = i * cfg.q_dim() + h * hd..i * cfg.q_dim() + (h + 1) * hd;
                    attention::apply_rope(&mut q[span], cos, sin);
                }
                for h in 0..nkv {
                    let span = i * cfg.kv_dim() + h * hd..i * cfg.kv_dim() + (h + 1) * hd;
                    attention::apply_rope(&mut k[span], cos, sin);
                }
            }

            // contiguous per-kv-head K/V
            let mut k_heads: Vec<Vec<f32>> = vec![vec![0.0; t * hd]; nkv];
            let mut v_heads: Vec<Vec<f32>> = vec![vec![0.0; t * hd]; nkv];
            for i in 0..t {
                for h in 0..nkv {
                    let span = i * cfg.kv_dim() + h * hd..i * cfg.kv_dim() + (h + 1) * hd;
                    k_heads[h][i * hd..(i + 1) * hd].copy_from_slice(&k[span.clone()]);
                    v_heads[h][i * hd..(i + 1) * hd].copy_from_slice(&v[span]);
                }
            }

            // aux accumulators for this layer
            let mut q_abs_l: Vec<Vec<f32>> = vec![vec![0.0; hd]; nkv];
            let mut att_win_l: Vec<Vec<f32>> = vec![vec![0.0; t]; nkv];
            let mut att_tot_l: Vec<Vec<f32>> = vec![vec![0.0; t]; nkv];

            // attention per query head
            let mut o = vec![0.0f32; t * cfg.q_dim()];
            let mut q_head = vec![0.0f32; t * hd];
            let mut o_head = vec![0.0f32; t * hd];
            for h in 0..nh {
                let kvh = h / group;
                for i in 0..t {
                    let span = i * cfg.q_dim() + h * hd..i * cfg.q_dim() + (h + 1) * hd;
                    q_head[i * hd..(i + 1) * hd].copy_from_slice(&q[span]);
                }
                let probs_opt = if capture_aux { Some(&mut probs_buf) } else { None };
                attention::causal_prefill(
                    &q_head, &k_heads[kvh], &v_heads[kvh], t, hd, scale, &mut o_head, probs_opt,
                );
                for i in 0..t {
                    o[i * cfg.q_dim() + h * hd..i * cfg.q_dim() + (h + 1) * hd]
                        .copy_from_slice(&o_head[i * hd..(i + 1) * hd]);
                }
                if capture_aux {
                    // Σ|Q| over the trailing query window (GQA: summed over
                    // the group's query heads — Fig 3 / §2 GQA note)
                    for i in t - win..t {
                        for c in 0..hd {
                            q_abs_l[kvh][c] += q_head[i * hd + c].abs();
                        }
                    }
                    // attention mass per key-token over the window / total
                    for i in 0..t {
                        let row = &probs_buf[i * t..i * t + i + 1];
                        let target = &mut att_tot_l[kvh];
                        for (j, &p) in row.iter().enumerate() {
                            target[j] += p;
                        }
                        if i >= t - win {
                            let tw = &mut att_win_l[kvh];
                            for (j, &p) in row.iter().enumerate() {
                                tw[j] += p;
                            }
                        }
                    }
                }
            }

            let mut attn_out = vec![0.0f32; t * d];
            matmul(&o, t, cfg.q_dim(), self.w.layer(l, "wo").data(), d, &mut attn_out);
            for (xi, ai) in x.iter_mut().zip(&attn_out) {
                *xi += ai;
            }

            // MLP
            rmsnorm(&x, t, d, self.w.layer(l, "mlp_norm").data(), cfg.norm_eps as f32, &mut hn);
            let mut g = vec![0.0f32; t * cfg.ff];
            let mut u = vec![0.0f32; t * cfg.ff];
            matmul(&hn, t, d, self.w.layer(l, "w_gate").data(), cfg.ff, &mut g);
            matmul(&hn, t, d, self.w.layer(l, "w_up").data(), cfg.ff, &mut u);
            for (gi, ui) in g.iter_mut().zip(&u) {
                *gi = silu(*gi) * ui;
            }
            let mut down = vec![0.0f32; t * d];
            matmul(&g, t, cfg.ff, self.w.layer(l, "w_down").data(), d, &mut down);
            for (xi, di) in x.iter_mut().zip(&down) {
                *xi += di;
            }

            k_out.append(&mut k_heads);
            v_out.append(&mut v_heads);
            aux.q_abs_win.append(&mut q_abs_l);
            aux.att_win.append(&mut att_win_l);
            att_total.append(&mut att_tot_l);
        }

        // final norm + lm head on the last position only
        let mut last = vec![0.0f32; d];
        let fnorm = self.w.get("final_norm");
        rmsnorm(&x[(t - 1) * d..], 1, d, fnorm.data(), cfg.norm_eps as f32, &mut last);
        let mut logits = vec![0.0f32; cfg.vocab];
        matmul(&last, 1, d, self.w.get("lm_head").data(), cfg.vocab, &mut logits);

        PrefillResult { logits_last: logits, k: k_out, v: v_out, aux, att_total, t }
    }

    /// One decode step: appends the token's K/V into `kv` (dense tail),
    /// runs attention over compressed + tail per head, returns logits.
    /// `pos` is the RoPE position of `token` (= tokens so far).
    ///
    /// Convenience wrapper over `decode_into` that allocates a throwaway
    /// workspace; hot loops (the engine) hold a `DecodeScratch` per
    /// sequence and call `decode_into` directly.
    pub fn decode(&self, token: u16, pos: usize, kv: &mut SequenceKV) -> Result<Vec<f32>> {
        let mut scratch = DecodeScratch::new();
        self.decode_into(token, pos, kv, &mut scratch)?;
        Ok(std::mem::take(&mut scratch.logits))
    }

    /// One decode step into a caller-owned workspace; logits land in
    /// `scratch.logits`. The attention hot path walks each KV head's
    /// compressed stream once for the whole GQA query group
    /// (`decode_sparse_group`) and performs no heap allocations in
    /// steady state — every temporary lives in `scratch`.
    pub fn decode_into(
        &self,
        token: u16,
        pos: usize,
        kv: &mut SequenceKV,
        scratch: &mut DecodeScratch,
    ) -> Result<()> {
        let cfg = &self.w.cfg;
        let (d, hd) = (cfg.d_model, cfg.head_dim);
        let (nh, nkv, group) = (cfg.n_heads, cfg.n_kv_heads, cfg.group());
        let scale = 1.0 / (hd as f32).sqrt();
        scratch.prepare(cfg);

        scratch.x.copy_from_slice(self.w.get("tok_emb").row(token as usize));
        attention::rope_cos_sin_into(pos, hd, cfg.rope_theta, &mut scratch.cos, &mut scratch.sin);

        for l in 0..cfg.n_layers {
            rmsnorm(
                &scratch.x, 1, d,
                self.w.layer(l, "attn_norm").data(),
                cfg.norm_eps as f32,
                &mut scratch.hn,
            );
            matmul(&scratch.hn, 1, d, self.w.layer(l, "wq").data(), cfg.q_dim(), &mut scratch.q);
            matmul(&scratch.hn, 1, d, self.w.layer(l, "wk").data(), cfg.kv_dim(), &mut scratch.k);
            matmul(&scratch.hn, 1, d, self.w.layer(l, "wv").data(), cfg.kv_dim(), &mut scratch.v);
            let (cos, sin) = (&scratch.cos, &scratch.sin);
            for h in 0..nh {
                attention::apply_rope(&mut scratch.q[h * hd..(h + 1) * hd], cos, sin);
            }
            for h in 0..nkv {
                attention::apply_rope(&mut scratch.k[h * hd..(h + 1) * hd], cos, sin);
            }
            for h in 0..nkv {
                kv.append(l, h, &scratch.k[h * hd..(h + 1) * hd], &scratch.v[h * hd..(h + 1) * hd]);
            }

            // Fused GQA attention: iterate KV heads, not query heads.
            // The `group` query lanes sharing KV head `kvh` are contiguous
            // in `q` (heads kvh*group .. (kvh+1)*group), so each group is
            // one flat [group x hd] slab — one multi-query call per KV
            // head walks its compressed stream exactly once. The
            // compressed region may span two segments in token order: a
            // shared prefill prefix (prefix-cache hit, refcounted pages)
            // followed by the sequence's own groups. Groups wider than
            // the kernels' MAX_GROUP lane cap (extreme MQA) are chunked;
            // each chunk still amortizes the stream walk over up to
            // MAX_GROUP lanes.
            for kvh in 0..nkv {
                let head = kv.head(l, kvh);
                let tail_len = head.tail_len(hd);
                let own = (&head.k_comp, &head.v_comp);
                let (segs_buf, n_segs) = match kv.prefix() {
                    Some(p) => ([p.head(l, kvh), own], 2),
                    None => ([own, own], 1),
                };
                let segs = &segs_buf[..n_segs];
                let mut lane0 = 0;
                while lane0 < group {
                    let lanes = (group - lane0).min(crate::sparse::MAX_GROUP);
                    let start = (kvh * group + lane0) * hd;
                    let span = start..start + lanes * hd;
                    attention::decode_sparse_group_segments(
                        &scratch.q[span.clone()],
                        lanes,
                        segs,
                        head.tail_k(),
                        head.tail_v(),
                        tail_len,
                        scale,
                        &mut scratch.o[span],
                        &mut scratch.s_comp,
                        &mut scratch.s_tail,
                    );
                    lane0 += lanes;
                }
            }

            let wo = self.w.layer(l, "wo");
            matmul(&scratch.o, 1, cfg.q_dim(), wo.data(), d, &mut scratch.attn_out);
            for (xi, ai) in scratch.x.iter_mut().zip(&scratch.attn_out) {
                *xi += ai;
            }

            rmsnorm(
                &scratch.x, 1, d,
                self.w.layer(l, "mlp_norm").data(),
                cfg.norm_eps as f32,
                &mut scratch.hn,
            );
            matmul(&scratch.hn, 1, d, self.w.layer(l, "w_gate").data(), cfg.ff, &mut scratch.gate);
            matmul(&scratch.hn, 1, d, self.w.layer(l, "w_up").data(), cfg.ff, &mut scratch.up);
            for (gi, ui) in scratch.gate.iter_mut().zip(&scratch.up) {
                *gi = silu(*gi) * ui;
            }
            let wd = self.w.layer(l, "w_down");
            matmul(&scratch.gate, 1, cfg.ff, wd.data(), d, &mut scratch.down);
            for (xi, di) in scratch.x.iter_mut().zip(&scratch.down) {
                *xi += di;
            }
        }
        kv.commit_token()?;

        rmsnorm(
            &scratch.x, 1, d,
            self.w.get("final_norm").data(),
            cfg.norm_eps as f32,
            &mut scratch.last,
        );
        matmul(&scratch.last, 1, d, self.w.get("lm_head").data(), cfg.vocab, &mut scratch.logits);
        Ok(())
    }
}

/// Greedy argmax over logits.
pub fn argmax(logits: &[f32]) -> u16 {
    let mut best = 0usize;
    let mut bv = f32::NEG_INFINITY;
    for (i, &x) in logits.iter().enumerate() {
        if x > bv {
            bv = x;
            best = i;
        }
    }
    best as u16
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::KvPolicy;
    use crate::model::weights::Weights;

    fn tiny_model() -> NativeModel {
        let cfg = ModelConfig {
            name: "tiny".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 32,
            ff: 128,
            vocab: 512,
            rope_theta: 10000.0,
            max_seq: 256,
            norm_eps: 1e-5,
        };
        NativeModel::new(Weights::random_for_tests(cfg, 99))
    }

    #[test]
    fn prefill_shapes() {
        let m = tiny_model();
        let tokens: Vec<u16> = (0..80).map(|i| (i % 400 + 16) as u16).collect();
        let r = m.prefill(&tokens, true);
        assert_eq!(r.logits_last.len(), 512);
        assert_eq!(r.k.len(), 2); // L*KV = 2*1
        assert_eq!(r.k[0].len(), 80 * 32);
        assert_eq!(r.aux.q_abs_win.len(), 2);
        assert_eq!(r.aux.att_win[0].len(), 80);
        assert_eq!(r.att_total[1].len(), 80);
    }

    /// Max-abs deviation scaled by the reference's own magnitude. The
    /// cached path stores K/V as binary16 (≤2^-11 relative rounding per
    /// element) while prefill computes in f32, so decode-vs-prefill
    /// parity holds to a small *relative* bound rather than exactly.
    fn rel_mad(got: &[f32], want: &[f32]) -> f32 {
        let mad = got.iter().zip(want).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        let scale = want.iter().map(|x| x.abs()).fold(f32::MIN_POSITIVE, f32::max);
        mad / scale
    }

    #[test]
    fn decode_after_prefill_matches_full_prefill() {
        // prefill(n) then decode(token n) must equal prefill(n+1)'s last
        // logits when the cache is dense (no pruning), up to the f16
        // rounding of the cached K/V.
        let m = tiny_model();
        let tokens: Vec<u16> = (0..65).map(|i| (i * 7 % 400 + 16) as u16).collect();
        let full = m.prefill(&tokens, false);

        let r = m.prefill(&tokens[..64], false);
        let mut kv = SequenceKV::new(KvPolicy::dense(), 2, 1, 32).unwrap();
        kv.ingest_prefill(&r.k, &r.v, 64, None).unwrap();
        let logits = m.decode(tokens[64], 64, &mut kv).unwrap();

        let rel = rel_mad(&logits, &full.logits_last);
        assert!(rel < 2e-2, "decode vs prefill mismatch: rel {rel}");
    }

    #[test]
    fn decode_with_pruned_cache_runs_and_differs() {
        let m = tiny_model();
        let tokens: Vec<u16> = (0..96).map(|i| (i * 11 % 400 + 16) as u16).collect();
        let r = m.prefill(&tokens, false);

        let mut kv_dense = SequenceKV::new(KvPolicy::dense(), 2, 1, 32).unwrap();
        kv_dense.ingest_prefill(&r.k, &r.v, 96, None).unwrap();
        let ld = m.decode(300, 96, &mut kv_dense).unwrap();

        let mut kv_sparse = SequenceKV::new(KvPolicy::mustafar(0.7, 0.7), 2, 1, 32).unwrap();
        kv_sparse.ingest_prefill(&r.k, &r.v, 96, None).unwrap();
        let ls = m.decode(300, 96, &mut kv_sparse).unwrap();

        let mad: f32 = ld.iter().zip(&ls).map(|(a, b)| (a - b).abs()).fold(0.0, f32::max);
        assert!(mad > 0.0, "pruning should perturb logits");
        // ... but not catastrophically (70% per-token magnitude is benign)
        let denom: f32 = ld.iter().map(|x| x.abs()).fold(0.0, f32::max);
        assert!(mad / denom < 1.0, "pruned logits unreasonably far: {mad} vs {denom}");
    }

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax(&[0.0, 3.0, -1.0, 3.0]), 1);
    }

    #[test]
    fn scratch_reuse_matches_fresh_scratch() {
        // Decoding with one persistent workspace must be bit-identical to
        // decoding with a fresh workspace every token (no state leaks
        // between tokens through reused buffers).
        let m = tiny_model();
        let tokens: Vec<u16> = (0..120).map(|i| (i * 13 % 400 + 16) as u16).collect();
        let r = m.prefill(&tokens, false);

        let mut kv_a = SequenceKV::new(KvPolicy::mustafar(0.6, 0.6), 2, 1, 32).unwrap();
        kv_a.ingest_prefill(&r.k, &r.v, 120, None).unwrap();
        let mut kv_b = kv_a.clone();

        let mut persistent = DecodeScratch::new();
        let mut tok_a = 77u16;
        let mut tok_b = 77u16;
        for i in 0..40 {
            m.decode_into(tok_a, 120 + i, &mut kv_a, &mut persistent).unwrap();
            let la = persistent.logits.clone();
            let mut fresh = DecodeScratch::new();
            m.decode_into(tok_b, 120 + i, &mut kv_b, &mut fresh).unwrap();
            assert_eq!(la, fresh.logits, "token {i}");
            tok_a = argmax(&la);
            tok_b = argmax(&fresh.logits);
        }
    }

    #[test]
    fn decode_over_shared_prefix_is_bit_identical_to_private_cache() {
        // A prefix-cache full hit (shared compressed prefix + restored
        // tails) must decode bit-identically to the cold-path private
        // cache — the engine's token-identity guarantee rests on this.
        use crate::kvcache::build_shared_prefill;
        use std::sync::Arc;

        let m = tiny_model();
        let t = 160;
        let tokens: Vec<u16> = (0..t).map(|i| (i * 17 % 400 + 16) as u16).collect();
        let r = m.prefill(&tokens, false);
        let policy = KvPolicy::mustafar(0.6, 0.6);

        let mut cold = SequenceKV::new(policy, 2, 1, 32).unwrap();
        cold.ingest_prefill(&r.k, &r.v, t, None).unwrap();

        let (prefix, tk, tv) = build_shared_prefill(&policy, 2, 1, 32, &r.k, &r.v, t).unwrap();
        assert!(prefix.tokens > 0, "test needs a non-empty shared prefix");
        let mut hot = SequenceKV::restore_full(policy, Arc::new(prefix), tk, tv, t).unwrap();

        let mut sc = DecodeScratch::new();
        let mut sh = DecodeScratch::new();
        let (mut tok_c, mut tok_h) = (99u16, 99u16);
        // 80 decode steps push a 64-token group through compression
        // (tail 32 + 80 > TAIL_CAP), so the hot path also exercises the
        // [shared prefix | private groups] two-segment walk.
        for i in 0..80 {
            m.decode_into(tok_c, t + i, &mut cold, &mut sc).unwrap();
            m.decode_into(tok_h, t + i, &mut hot, &mut sh).unwrap();
            assert_eq!(sc.logits, sh.logits, "token {i}");
            tok_c = argmax(&sc.logits);
            tok_h = argmax(&sh.logits);
        }
        assert!(hot.head(0, 0).k_comp.tokens > 0, "private groups never compressed");
    }

    #[test]
    fn wide_gqa_decode_matches_prefill() {
        // group = 4 (n_heads=4, n_kv_heads=1): the fused multi-query path
        // must still reproduce full-prefill logits on a dense cache.
        let cfg = ModelConfig {
            name: "tiny-gqa4".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 1,
            head_dim: 16,
            ff: 128,
            vocab: 512,
            rope_theta: 10000.0,
            max_seq: 256,
            norm_eps: 1e-5,
        };
        let m = NativeModel::new(Weights::random_for_tests(cfg, 123));
        let tokens: Vec<u16> = (0..49).map(|i| (i * 5 % 400 + 16) as u16).collect();
        let full = m.prefill(&tokens, false);

        let r = m.prefill(&tokens[..48], false);
        let mut kv = SequenceKV::new(KvPolicy::dense(), 2, 1, 16).unwrap();
        kv.ingest_prefill(&r.k, &r.v, 48, None).unwrap();
        let logits = m.decode(tokens[48], 48, &mut kv).unwrap();

        let rel = rel_mad(&logits, &full.logits_last);
        assert!(rel < 2e-2, "wide-GQA decode vs prefill mismatch: rel {rel}");
    }

    #[test]
    fn mqa_group_wider_than_max_group_is_chunked() {
        // group = 32 > sparse::MAX_GROUP = 16: decode must chunk the
        // query group across fused calls rather than panic.
        let cfg = ModelConfig {
            name: "tiny-mqa32".into(),
            d_model: 64,
            n_layers: 1,
            n_heads: 32,
            n_kv_heads: 1,
            head_dim: 8,
            ff: 64,
            vocab: 256,
            rope_theta: 10000.0,
            max_seq: 128,
            norm_eps: 1e-5,
        };
        let m = NativeModel::new(Weights::random_for_tests(cfg, 321));
        let tokens: Vec<u16> = (0..41).map(|i| (i * 3 % 200 + 16) as u16).collect();
        let full = m.prefill(&tokens, false);

        let r = m.prefill(&tokens[..40], false);
        let mut kv = SequenceKV::new(KvPolicy::dense(), 1, 1, 8).unwrap();
        kv.ingest_prefill(&r.k, &r.v, 40, None).unwrap();
        let logits = m.decode(tokens[40], 40, &mut kv).unwrap();

        let rel = rel_mad(&logits, &full.logits_last);
        assert!(rel < 2e-2, "chunked MQA decode vs prefill mismatch: rel {rel}");
    }
}
