//! XLA/PJRT runtime: loads the HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them on the PJRT CPU client, and
//! executes them from the serving hot path. Weights are uploaded once as
//! device-resident buffers and reused via `execute_b`.
//!
//! Interchange is HLO *text* — the image's xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos (64-bit instruction ids); the text parser
//! reassigns ids (see /opt/xla-example/README.md).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use crate::config::ModelConfig;
use crate::error::{Error, Result};
use crate::fmt::Json;
use crate::model::Weights;

/// Parsed `artifacts.json` + artifact directory.
pub struct ArtifactIndex {
    pub dir: PathBuf,
    pub local_window: usize,
    pub tail_cap: usize,
    /// name -> IO metadata
    pub entries: HashMap<String, ArtifactMeta>,
}

#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    pub n_weights: usize,
    pub input_shapes: Vec<(Vec<usize>, String)>,
    pub outputs: Vec<String>,
}

impl ArtifactIndex {
    pub fn load(dir: &Path) -> Result<ArtifactIndex> {
        let path = dir.join("artifacts.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} ({e}) — run `make artifacts`",
                path.display()
            ))
        })?;
        let v = Json::parse(&text)?;
        let mut entries = HashMap::new();
        for a in v.get("artifacts")?.as_arr()? {
            let name = a.get("name")?.as_str()?.to_string();
            let inputs = a
                .get("inputs")?
                .as_arr()?
                .iter()
                .map(|x| {
                    Ok((
                        x.get("shape")?.as_usize_vec()?,
                        x.get("dtype")?.as_str()?.to_string(),
                    ))
                })
                .collect::<Result<Vec<_>>>()?;
            let outputs = a
                .get("outputs")?
                .as_arr()?
                .iter()
                .map(|x| Ok(x.as_str()?.to_string()))
                .collect::<Result<Vec<_>>>()?;
            entries.insert(
                name.clone(),
                ArtifactMeta {
                    name,
                    n_weights: a.get("n_weights")?.as_usize()?,
                    input_shapes: inputs,
                    outputs,
                },
            );
        }
        Ok(ArtifactIndex {
            dir: dir.to_path_buf(),
            local_window: v.get("local_window")?.as_usize()?,
            tail_cap: v.get("tail_cap")?.as_usize()?,
            entries,
        })
    }
}

/// A host-side input value for an executable call.
pub enum HostArg<'a> {
    F32(&'a [f32], Vec<usize>),
    I32(&'a [i32], Vec<usize>),
    ScalarI32(i32),
}

/// PJRT client + compiled-executable cache.
pub struct Runtime {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    pub index: ArtifactIndex,
}

impl Runtime {
    /// Create the CPU PJRT client and load the artifact index.
    pub fn new(artifact_dir: &Path) -> Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        let index = ArtifactIndex::load(artifact_dir)?;
        Ok(Runtime { client, exes: HashMap::new(), index })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (and cache) the named artifact.
    pub fn load(&mut self, name: &str) -> Result<()> {
        if self.exes.contains_key(name) {
            return Ok(());
        }
        let path = self.index.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| Error::Runtime("bad path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.exes.insert(name.to_string(), exe);
        Ok(())
    }

    /// Upload model weights as device-resident buffers (manifest order).
    pub fn upload_weights(&self, w: &Weights) -> Result<DeviceWeights> {
        let mut bufs = Vec::with_capacity(w.params.len());
        for t in &w.params {
            bufs.push(self.client.buffer_from_host_buffer::<f32>(t.data(), t.shape(), None)?);
        }
        Ok(DeviceWeights { bufs, cfg: w.cfg.clone() })
    }

    /// Upload one host argument.
    pub fn upload(&self, arg: &HostArg) -> Result<xla::PjRtBuffer> {
        Ok(match arg {
            HostArg::F32(data, dims) => {
                self.client.buffer_from_host_buffer::<f32>(data, dims, None)?
            }
            HostArg::I32(data, dims) => {
                self.client.buffer_from_host_buffer::<i32>(data, dims, None)?
            }
            HostArg::ScalarI32(x) => self.client.buffer_from_host_buffer::<i32>(&[*x], &[], None)?,
        })
    }

    /// Execute artifact `name` with device-resident weights followed by
    /// the given host args; returns the flattened output literals.
    pub fn run(
        &self,
        name: &str,
        weights: Option<&DeviceWeights>,
        args: &[HostArg],
    ) -> Result<Vec<xla::Literal>> {
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("artifact '{name}' not loaded")))?;
        let meta = self
            .index
            .entries
            .get(name)
            .ok_or_else(|| Error::Runtime(format!("artifact '{name}' not in index")))?;

        let weight_refs: Vec<&xla::PjRtBuffer> = match weights {
            Some(dw) => {
                if dw.bufs.len() != meta.n_weights {
                    return Err(Error::Runtime(format!(
                        "{name}: weight count {} != manifest {}",
                        dw.bufs.len(),
                        meta.n_weights
                    )));
                }
                dw.bufs.iter().collect()
            }
            None => {
                if meta.n_weights != 0 {
                    return Err(Error::Runtime(format!("{name}: weights required")));
                }
                Vec::new()
            }
        };
        if meta.input_shapes.len() != meta.n_weights + args.len() {
            return Err(Error::Runtime(format!(
                "{name}: expected {} inputs, got {} weights + {} args",
                meta.input_shapes.len(),
                meta.n_weights,
                args.len()
            )));
        }
        let arg_bufs: Vec<xla::PjRtBuffer> =
            args.iter().map(|a| self.upload(a)).collect::<Result<Vec<_>>>()?;
        let mut all: Vec<&xla::PjRtBuffer> = weight_refs;
        all.extend(arg_bufs.iter());

        let out = exe.execute_b(&all)?;
        let lit = out[0][0].to_literal_sync()?;
        // AOT lowers with return_tuple=True: decompose.
        Ok(lit.to_tuple()?)
    }
}

/// Device-resident weight buffers (uploaded once, reused every step).
pub struct DeviceWeights {
    bufs: Vec<xla::PjRtBuffer>,
    pub cfg: ModelConfig,
}

/// Pull an f32 literal out as (data, shape).
pub fn literal_f32(lit: &xla::Literal) -> Result<(Vec<f32>, Vec<usize>)> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    Ok((lit.to_vec::<f32>()?, dims))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts"))
    }

    fn have_artifacts() -> bool {
        artifacts_dir().join("artifacts.json").exists()
    }

    #[test]
    fn smoke_artifact_roundtrip() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let mut rt = Runtime::new(&artifacts_dir()).unwrap();
        rt.load("smoke").unwrap();
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y = [1.0f32, 1.0, 1.0, 1.0];
        let out = rt
            .run(
                "smoke",
                None,
                &[HostArg::F32(&x, vec![2, 2]), HostArg::F32(&y, vec![2, 2])],
            )
            .unwrap();
        let (vals, dims) = literal_f32(&out[0]).unwrap();
        assert_eq!(dims, vec![2, 2]);
        // pallas kernel computes x@y + 2
        assert_eq!(vals, vec![5.0, 5.0, 9.0, 9.0]);
    }

    #[test]
    fn index_parses() {
        if !have_artifacts() {
            return;
        }
        let idx = ArtifactIndex::load(&artifacts_dir()).unwrap();
        assert!(idx.entries.contains_key("smoke"));
        assert_eq!(idx.local_window, 32);
    }

    #[test]
    fn missing_artifact_dir_is_clear_error() {
        let err = Runtime::new(Path::new("/nonexistent-dir")).err();
        // Either client creation or index load fails with a useful message.
        assert!(err.is_some());
        let msg = format!("{}", err.unwrap());
        assert!(msg.contains("artifacts") || msg.contains("nonexistent"), "{msg}");
    }
}
