//! `mustafar` — CLI for the Mustafar serving coordinator and the paper's
//! experiment harness.
//!
//! Subcommands:
//!   exp <id|all>       regenerate a paper table/figure (reports/<id>.md)
//!   serve              start the TCP serving front-end
//!   generate           one-shot generation (any backend)
//!   info               inventory of artifacts/weights/configs
//!
//! Arg parsing is hand-rolled (clap is not in the offline vendor set).

use std::collections::HashMap;
use std::path::PathBuf;
use std::process::ExitCode;

use mustafar::config::{Backend, EngineConfig, SparsityConfig};
use mustafar::coordinator::pjrt_backend::PjrtBackend;
use mustafar::coordinator::{Engine, Request};
use mustafar::eval::experiments::{self, ExpCtx};
use mustafar::model::{NativeModel, Weights};
use mustafar::util::Pcg32;
use mustafar::workload::lang;

struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Args {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(name.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    fn get_usize(&self, name: &str, default: usize) -> usize {
        self.flags.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.flags.get(name).and_then(|s| s.parse().ok()).unwrap_or(default)
    }
}

fn usage() -> &'static str {
    "mustafar — unstructured-sparsity KV cache pruning (NeurIPS'25 reproduction)

USAGE:
  mustafar exp <table1..table12|fig2|fig6b|all> [--samples N] [--ctx N]
           [--artifacts DIR] [--report-dir DIR]
  mustafar serve    [--model M] [--backend B] [--ks S] [--vs S]
           [--addr HOST:PORT] [--max-batch N] [--max-queue-ms N] [--artifacts DIR]
           [--reactor-threads N] [--max-conns N] [--max-line-bytes N]
           [--write-hwm N] [--idle-timeout-ms N] [--read-deadline-ms N]
           [--drain-deadline-ms N] [--prefix-cache-bytes N] [--prefix-ttl-ms N]
           [--prefill-chunk TOKENS] [--round-budget TOKENS]
           [--sync-compress] [--compress-inflight GROUPS] [--local-window TOKENS]
           [--no-telemetry] [--trace-out FILE] [--metrics-addr HOST:PORT]
  mustafar generate [--model M] [--backend B] [--ks S] [--vs S]
           [--prompt-seed N] [--prompt-len N] [--max-new N] [--artifacts DIR]
  mustafar info     [--artifacts DIR]

BACKENDS: native-dense | native-sparse | pjrt-dense | pjrt-sparse
MODELS:   tiny | gqa-small | mha-small | gqa-medium
"
}

fn main() -> ExitCode {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        eprintln!("{}", usage());
        return ExitCode::FAILURE;
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    let res = match cmd.as_str() {
        "exp" => cmd_exp(&args),
        "serve" => cmd_serve(&args),
        "generate" => cmd_generate(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => {
            eprintln!("unknown command '{other}'\n{}", usage());
            return ExitCode::FAILURE;
        }
    };
    match res {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e}");
            ExitCode::FAILURE
        }
    }
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get("artifacts", "artifacts"))
}

fn cmd_exp(args: &Args) -> mustafar::Result<()> {
    let id = args
        .positional
        .first()
        .cloned()
        .ok_or_else(|| mustafar::Error::Invalid("exp: missing experiment id".into()))?;
    let report_dir = PathBuf::from(args.get("report-dir", "reports"));
    let mut ctx = ExpCtx::new(artifacts_dir(args), report_dir);
    ctx.n_samples = args.get_usize("samples", 20);
    ctx.ctx_len = args.get_usize("ctx", 448);
    // Sweeps parallelize across samples; keep per-matmul threading off to
    // avoid oversubscription (see DESIGN.md §Perf).
    if std::env::var("MUSTAFAR_THREADS").is_err() {
        std::env::set_var("MUSTAFAR_THREADS", "1");
    }
    experiments::run(&id, &ctx)
}

fn build_engine(args: &Args) -> mustafar::Result<Engine> {
    let model_name = args.get("model", "gqa-small");
    let backend = Backend::parse(&args.get("backend", "native-sparse"))
        .ok_or_else(|| mustafar::Error::Invalid("bad --backend".into()))?;
    let ks = args.get_f64("ks", 0.5);
    let vs = args.get_f64("vs", 0.5);
    let dir = artifacts_dir(args);
    let weights = Weights::load(&dir, &model_name)?;

    let mut ec = EngineConfig::default();
    ec.backend = backend;
    ec.sparsity = SparsityConfig::mustafar(ks, vs);
    ec.max_batch = args.get_usize("max-batch", 8);
    ec.max_new_tokens = args.get_usize("max-new", 64);
    ec.max_queue_ms = args.get_usize("max-queue-ms", 0) as u64;
    ec.kv_budget_bytes = args.get_usize("kv-budget", 0);
    ec.prefill_chunk_tokens = args.get_usize("prefill-chunk", ec.prefill_chunk_tokens);
    ec.round_token_budget = args.get_usize("round-budget", ec.round_token_budget);
    ec.prefix_cache_bytes = args.get_usize("prefix-cache-bytes", 0);
    ec.prefix_ttl_ms = args.get_usize("prefix-ttl-ms", 0) as u64;
    // deferred group compression is the default; --sync-compress restores
    // the synchronous prune-on-commit path (the bench baseline)
    ec.deferred_compress = !args.flags.contains_key("sync-compress");
    ec.compress_inflight_groups =
        args.get_usize("compress-inflight", ec.compress_inflight_groups);
    ec.local_window = args.get_usize("local-window", ec.local_window);
    ec.telemetry = !args.flags.contains_key("no-telemetry");

    let model = NativeModel::new(weights.clone());
    match backend {
        Backend::PjrtDense | Backend::PjrtSparse => {
            let pj = PjrtBackend::new(&dir, &weights, backend, ec.sparsity)?;
            Ok(Engine::new_pjrt(model, ec, pj))
        }
        _ => Ok(Engine::new_native(model, ec)),
    }
}

fn cmd_serve(args: &Args) -> mustafar::Result<()> {
    let engine = build_engine(args)?;
    let addr = args.get("addr", "127.0.0.1:7777");
    let d = mustafar::config::ServerConfig::default();
    let sc = mustafar::config::ServerConfig {
        reactor_threads: args.get_usize("reactor-threads", d.reactor_threads),
        max_conns: args.get_usize("max-conns", d.max_conns),
        max_line_bytes: args.get_usize("max-line-bytes", d.max_line_bytes),
        write_hwm_bytes: args.get_usize("write-hwm", d.write_hwm_bytes),
        idle_timeout_ms: args.get_usize("idle-timeout-ms", d.idle_timeout_ms as usize) as u64,
        read_deadline_ms: args.get_usize("read-deadline-ms", d.read_deadline_ms as usize) as u64,
        drain_deadline_ms: args.get_usize("drain-deadline-ms", d.drain_deadline_ms as usize)
            as u64,
        metrics_addr: args.flags.get("metrics-addr").cloned(),
        trace_out: args.flags.get("trace-out").cloned(),
        ..d
    };
    mustafar::server::serve_with(engine, &addr, sc)
}

fn cmd_generate(args: &Args) -> mustafar::Result<()> {
    let mut engine = build_engine(args)?;
    let seed = args.get_usize("prompt-seed", 7) as u64;
    // pjrt backends are compiled for a fixed prompt length (= max_seq/2)
    let default_len = match engine.cfg.backend {
        Backend::PjrtDense | Backend::PjrtSparse => engine.model.cfg().max_seq / 2,
        _ => 256,
    };
    let plen = args.get_usize("prompt-len", default_len);
    let max_new = args.get_usize("max-new", 32);

    let prompt = lang::gen_document(&mut Pcg32::seeded(seed), plen);
    println!(
        "model={} backend={} prompt_len={} max_new={}",
        engine.model.cfg().name,
        engine.cfg.backend.name(),
        plen,
        max_new
    );
    let out = engine.run_trace(vec![Request::new(0, prompt, max_new)])?;
    let c = &out[0];
    println!("generated: {:?}", c.tokens);
    println!(
        "prefill {:.1} ms | decode {:.1} ms | {:.1} tok/s | kv {:.1} KiB ({:.0}% of dense)",
        c.prefill_ms,
        c.decode_ms,
        c.tokens.len() as f64 / ((c.prefill_ms + c.decode_ms) / 1e3),
        c.kv_bytes as f64 / 1024.0,
        c.kv_bytes as f64 / c.kv_dense_bytes.max(1) as f64 * 100.0
    );
    Ok(())
}

fn cmd_info(args: &Args) -> mustafar::Result<()> {
    let dir = artifacts_dir(args);
    println!("artifact dir: {}", dir.display());
    match mustafar::runtime::ArtifactIndex::load(&dir) {
        Ok(idx) => {
            println!("local_window={} tail_cap={}", idx.local_window, idx.tail_cap);
            let mut names: Vec<&String> = idx.entries.keys().collect();
            names.sort();
            for n in names {
                let m = &idx.entries[n];
                println!("  {n}: {} inputs ({} weights)", m.input_shapes.len(), m.n_weights);
            }
        }
        Err(e) => println!("  (no artifact index: {e})"),
    }
    for name in ["tiny", "gqa-small", "mha-small", "gqa-medium"] {
        match Weights::load(&dir, name) {
            Ok(w) => println!(
                "  weights_{name}: {:.2}M params, final_loss={:.3}",
                w.n_params() as f64 / 1e6,
                w.final_loss
            ),
            Err(_) => println!("  weights_{name}: (missing)"),
        }
    }
    Ok(())
}
