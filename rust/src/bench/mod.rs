//! Hand-rolled micro-benchmark harness (criterion is not available in
//! the offline vendor set). Warmup + timed iterations + summary stats;
//! used by every `benches/*.rs` target (`harness = false`).

use crate::util::{Stopwatch, Summary};

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Minimum total measurement time; iters are extended to reach it.
    pub min_time_s: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup_iters: 3, iters: 20, min_time_s: 0.25 }
    }
}

impl BenchOpts {
    /// Tiny iteration counts for CI smoke runs (`smoke_mode()`).
    pub fn smoke() -> BenchOpts {
        BenchOpts { warmup_iters: 1, iters: 3, min_time_s: 0.0 }
    }
}

/// True when `MUSTAFAR_BENCH_SMOKE` is set non-empty and not "0" — the
/// CI bench mode that exercises both kernel code paths without real
/// measurement time. Shared by every bench target so the env contract
/// cannot drift between them.
pub fn smoke_mode() -> bool {
    std::env::var("MUSTAFAR_BENCH_SMOKE").map_or(false, |v| !v.is_empty() && v != "0")
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in microseconds.
    pub us: Summary,
}

impl BenchResult {
    pub fn median_us(&self) -> f64 {
        self.us.p50
    }
}

/// Time `f` under `opts`; the closure must perform one full iteration.
pub fn bench<F: FnMut()>(name: &str, opts: BenchOpts, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(opts.iters);
    let total = Stopwatch::start();
    loop {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed_us());
        if samples.len() >= opts.iters && total.elapsed_s() >= opts.min_time_s {
            break;
        }
        if samples.len() > 100_000 {
            break; // safety valve for pathologically fast closures
        }
    }
    BenchResult { name: name.to_string(), us: Summary::of(&samples) }
}

/// Pretty-print a set of results normalized against a baseline (the
/// paper's Fig 6a style: components as % of the dense baseline).
pub fn print_normalized(title: &str, baseline: &BenchResult, components: &[&BenchResult]) {
    println!("\n## {title}");
    println!(
        "{:<28} {:>12} {:>10}",
        "component", "median (us)", "% of base"
    );
    println!("{:<28} {:>12.1} {:>9.1}%", baseline.name, baseline.median_us(), 100.0);
    for c in components {
        println!(
            "{:<28} {:>12.1} {:>9.1}%",
            c.name,
            c.median_us(),
            c.median_us() / baseline.median_us() * 100.0
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let r = bench(
            "sleep",
            BenchOpts { warmup_iters: 0, iters: 3, min_time_s: 0.0 },
            || std::thread::sleep(std::time::Duration::from_millis(2)),
        );
        assert!(r.median_us() >= 1500.0, "{}", r.median_us());
        assert_eq!(r.us.n, 3);
    }

    #[test]
    fn extends_to_min_time() {
        let r = bench(
            "fast",
            BenchOpts { warmup_iters: 0, iters: 1, min_time_s: 0.05 },
            || {
                std::hint::black_box(1 + 1);
            },
        );
        assert!(r.us.n > 100);
    }
}
