//! Hand-rolled micro-benchmark harness (criterion is not available in
//! the offline vendor set). Warmup + timed iterations + summary stats;
//! used by every `benches/*.rs` target (`harness = false`).
//!
//! Besides the human-readable table each harness prints, `BenchReport`
//! writes a machine-readable `BENCH_<name>.json` (median ns, bytes
//! touched, speedup vs the forced-scalar oracle, kernel backend) so the
//! perf trajectory is tracked across PRs as data, not EXPERIMENTS.md
//! prose. CI currently runs (and archives the JSON of) the spmv_micro
//! and fused_gqa harnesses; the rest emit the same files on local runs.

use crate::fmt::Json;
use crate::util::{Stopwatch, Summary};

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchOpts {
    pub warmup_iters: usize,
    pub iters: usize,
    /// Minimum total measurement time; iters are extended to reach it.
    pub min_time_s: f64,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { warmup_iters: 3, iters: 20, min_time_s: 0.25 }
    }
}

impl BenchOpts {
    /// Tiny iteration counts for CI smoke runs (`smoke_mode()`).
    pub fn smoke() -> BenchOpts {
        BenchOpts { warmup_iters: 1, iters: 3, min_time_s: 0.0 }
    }
}

/// True when `MUSTAFAR_BENCH_SMOKE` is set non-empty and not "0" — the
/// CI bench mode that exercises both kernel code paths without real
/// measurement time. Shared by every bench target so the env contract
/// cannot drift between them.
pub fn smoke_mode() -> bool {
    std::env::var("MUSTAFAR_BENCH_SMOKE").map_or(false, |v| !v.is_empty() && v != "0")
}

/// Result of one benchmark case.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Per-iteration wall time in microseconds.
    pub us: Summary,
}

impl BenchResult {
    pub fn median_us(&self) -> f64 {
        self.us.p50
    }
}

/// Time `f` under `opts`; the closure must perform one full iteration.
pub fn bench<F: FnMut()>(name: &str, opts: BenchOpts, mut f: F) -> BenchResult {
    for _ in 0..opts.warmup_iters {
        f();
    }
    let mut samples = Vec::with_capacity(opts.iters);
    let total = Stopwatch::start();
    loop {
        let sw = Stopwatch::start();
        f();
        samples.push(sw.elapsed_us());
        if samples.len() >= opts.iters && total.elapsed_s() >= opts.min_time_s {
            break;
        }
        if samples.len() > 100_000 {
            break; // safety valve for pathologically fast closures
        }
    }
    BenchResult { name: name.to_string(), us: Summary::of(&samples) }
}

/// Pretty-print a set of results normalized against a baseline (the
/// paper's Fig 6a style: components as % of the dense baseline).
pub fn print_normalized(title: &str, baseline: &BenchResult, components: &[&BenchResult]) {
    println!("\n## {title}");
    println!(
        "{:<28} {:>12} {:>10}",
        "component", "median (us)", "% of base"
    );
    println!("{:<28} {:>12.1} {:>9.1}%", baseline.name, baseline.median_us(), 100.0);
    for c in components {
        println!(
            "{:<28} {:>12.1} {:>9.1}%",
            c.name,
            c.median_us(),
            c.median_us() / baseline.median_us() * 100.0
        );
    }
}

/// Machine-readable summary for one bench target: a flat list of cases,
/// each a small map of metric name → number/string. Written as
/// `BENCH_<name>.json` into `MUSTAFAR_BENCH_JSON_DIR` (default: the
/// working directory) so CI can archive the perf trajectory across PRs.
pub struct BenchReport {
    bench: String,
    meta: Vec<(String, Json)>,
    cases: Vec<Json>,
}

impl BenchReport {
    /// Start a report for bench target `bench`. Records the selected
    /// kernel backend and the smoke flag automatically — every consumer
    /// of these files needs both to interpret the numbers.
    pub fn new(bench: &str) -> BenchReport {
        BenchReport {
            bench: bench.to_string(),
            meta: vec![
                (
                    "backend".to_string(),
                    Json::str(crate::sparse::kernels().backend.name()),
                ),
                ("smoke".to_string(), Json::Bool(smoke_mode())),
            ],
            cases: Vec::new(),
        }
    }

    /// Attach a report-level metadata field.
    pub fn meta(&mut self, key: &str, value: Json) {
        self.meta.push((key.to_string(), value));
    }

    /// Record one case as (field, value) pairs. Conventional fields:
    /// `name`, `median_ns`, `bytes`, `speedup_vs_scalar`.
    pub fn case(&mut self, fields: Vec<(&str, Json)>) {
        self.cases.push(Json::obj(fields));
    }

    /// Shorthand for the common shape: a named timing with optional
    /// bytes-touched and speedup-vs-scalar columns.
    pub fn timing(
        &mut self,
        name: &str,
        r: &BenchResult,
        bytes: Option<usize>,
        speedup: Option<f64>,
    ) {
        let mut fields = vec![
            ("name", Json::str(name)),
            ("median_ns", Json::num(r.median_us() * 1e3)),
            ("iters", Json::num(r.us.n as f64)),
        ];
        if let Some(b) = bytes {
            fields.push(("bytes", Json::num(b as f64)));
        }
        if let Some(s) = speedup {
            fields.push(("speedup_vs_scalar", Json::num(s)));
        }
        self.case(fields);
    }

    /// Serialize to `BENCH_<name>.json` in `MUSTAFAR_BENCH_JSON_DIR`
    /// (default: the working directory); returns the path written.
    pub fn write(&self) -> std::io::Result<String> {
        let dir = std::env::var("MUSTAFAR_BENCH_JSON_DIR").unwrap_or_else(|_| ".".to_string());
        self.write_to(&dir)
    }

    /// Serialize to `<dir>/BENCH_<name>.json`; returns the path written.
    pub fn write_to(&self, dir: &str) -> std::io::Result<String> {
        let path = format!("{dir}/BENCH_{}.json", self.bench);
        let mut top = vec![("bench", Json::str(self.bench.as_str()))];
        for (k, v) in &self.meta {
            top.push((k.as_str(), v.clone()));
        }
        top.push(("cases", Json::Arr(self.cases.clone())));
        std::fs::write(&path, Json::obj(top).to_pretty())?;
        Ok(path)
    }

    /// `write`, logging the outcome instead of failing the bench run
    /// (an unwritable directory should not kill a measurement).
    pub fn write_or_warn(&self) {
        match self.write() {
            Ok(path) => println!("[bench-json] wrote {path}"),
            Err(e) => eprintln!("[bench-json] could not write BENCH_{}.json: {e}", self.bench),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_sleep() {
        let r = bench(
            "sleep",
            BenchOpts { warmup_iters: 0, iters: 3, min_time_s: 0.0 },
            || std::thread::sleep(std::time::Duration::from_millis(2)),
        );
        assert!(r.median_us() >= 1500.0, "{}", r.median_us());
        assert_eq!(r.us.n, 3);
    }

    #[test]
    fn bench_report_roundtrips_through_json() {
        let r = bench("fast", BenchOpts { warmup_iters: 0, iters: 2, min_time_s: 0.0 }, || {
            std::hint::black_box(1 + 1);
        });
        let mut rep = BenchReport::new("unit_test");
        rep.meta("sparsity", Json::num(0.5));
        rep.timing("case_a", &r, Some(4096), Some(1.25));
        let dir = std::env::temp_dir();
        let path = rep.write_to(dir.to_str().unwrap()).unwrap();
        assert!(path.ends_with("BENCH_unit_test.json"));

        let parsed = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(parsed.get("bench").unwrap().as_str().unwrap(), "unit_test");
        // backend name recorded for every report
        let backend = parsed.get("backend").unwrap().as_str().unwrap().to_string();
        assert_eq!(backend, crate::sparse::kernels().backend.name());
        let cases = parsed.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("name").unwrap().as_str().unwrap(), "case_a");
        assert!(cases[0].get("median_ns").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(cases[0].get("bytes").unwrap().as_usize().unwrap(), 4096);
        assert!(
            (cases[0].get("speedup_vs_scalar").unwrap().as_f64().unwrap() - 1.25).abs() < 1e-9
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn extends_to_min_time() {
        let r = bench(
            "fast",
            BenchOpts { warmup_iters: 0, iters: 1, min_time_s: 0.05 },
            || {
                std::hint::black_box(1 + 1);
            },
        );
        assert!(r.us.n > 100);
    }
}
