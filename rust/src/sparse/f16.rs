//! IEEE 754 binary16 storage type for the compressed KV cache.
//!
//! The paper stores packed values in fp16; until this module existed the
//! repo only *accounted* bytes as fp16 (`VALUE_BYTES = 2`) while storing
//! `f32`, so the measured bandwidth win was half of what the format can
//! deliver. `BitmapMatrix::values` and the `SequenceKV` dense tails now
//! hold real binary16 bit patterns (`u16`), converted once at
//! compress/append time and widened back to `f32` in-register inside the
//! SpMV kernels.
//!
//! Hand-rolled conversions (no external crate — the build is offline):
//!
//! * `f32_to_f16` — narrowing with round-to-nearest-even, the IEEE
//!   default rounding mode, including subnormal and overflow handling.
//! * `f16_to_f32` — widening via the branch-light "multiply trick": shift
//!   the half's exponent/mantissa into f32 position and scale by 2^112.
//!   Both the normal and subnormal cases are *exact* power-of-two
//!   rescalings, so no double rounding occurs.
//!
//! The feature-gated `simd` submodule provides an 8-lane widening used by
//! the tile kernels; it applies the identical multiply trick, so SIMD and
//! scalar decode are bit-for-bit interchangeable (the kernels' parity
//! tests rely on this).

use crate::sparse::dispatch::KernelTable;

/// 2^112 as f32 bits: rescales a half's exponent field, pre-shifted into
/// f32 position, onto the f32 bias (`(254 - 15) << 23`).
const WIDEN_SCALE_BITS: u32 = (254 - 15) << 23;

/// 2^16 as f32 bits: the smallest magnitude the multiply trick produces
/// for an Inf/NaN half (finite halves top out at 65504 < 2^16).
const WIDEN_INFNAN_BITS: u32 = (127 + 16) << 23;

/// Widen one binary16 bit pattern to f32.
///
/// Exact for every finite half (normal and subnormal); Inf maps to Inf
/// and NaN to NaN (top mantissa bits preserved).
#[inline(always)]
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = (h as u32 & 0x8000) << 16;
    let om = (h as u32 & 0x7fff) << 13;
    let f = f32::from_bits(om) * f32::from_bits(WIDEN_SCALE_BITS);
    let mut bits = f.to_bits();
    if f >= f32::from_bits(WIDEN_INFNAN_BITS) {
        bits |= 0x7f80_0000; // restore the Inf/NaN exponent
    }
    f32::from_bits(bits | sign)
}

/// Narrow an f32 to a binary16 bit pattern with round-to-nearest-even.
///
/// Overflow rounds to ±Inf, underflow to signed zero, and every NaN
/// canonicalizes to a quiet f16 NaN.
#[inline]
pub fn f32_to_f16(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let abs = bits & 0x7fff_ffff;

    if abs >= 0x7f80_0000 {
        return if abs == 0x7f80_0000 { sign | 0x7c00 } else { sign | 0x7e00 };
    }

    let exp = ((abs >> 23) as i32) - 127; // unbiased exponent
    let man = abs & 0x007f_ffff;

    if exp >= 16 {
        return sign | 0x7c00; // |x| >= 2^16: beyond the f16 range
    }
    if exp >= -14 {
        // Normal half: drop 13 mantissa bits with RNE. A carry propagates
        // into the exponent (and, at the top of the range, on to Inf —
        // exactly the IEEE overflow behaviour for values >= 65520).
        let mut h = (((exp + 15) as u32) << 10) | (man >> 13);
        let rem = man & 0x1fff;
        if rem > 0x1000 || (rem == 0x1000 && (h & 1) == 1) {
            h += 1;
        }
        return sign | h as u16;
    }
    if exp >= -25 {
        // Subnormal half: shift the 24-bit significand (implicit bit made
        // explicit) into the 10-bit field, RNE on the shifted-out bits.
        let sig = man | 0x0080_0000;
        let shift = (-(exp + 1)) as u32; // 14..=24
        let mut h = sig >> shift;
        let rem = sig & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        if rem > half || (rem == half && (h & 1) == 1) {
            h += 1;
        }
        return sign | h as u16;
    }
    sign // underflows to signed zero
}

/// f32 → f16 → f32 round trip: the value a stored f32 comes back as.
/// Identity for every value exactly representable in binary16.
#[inline]
pub fn f16_round(x: f32) -> f32 {
    f16_to_f32(f32_to_f16(x))
}

/// Narrow a whole f32 slice into a fresh f16 buffer.
pub fn to_f16_vec(xs: &[f32]) -> Vec<u16> {
    xs.iter().map(|&x| f32_to_f16(x)).collect()
}

/// Widen a whole f16 buffer into a fresh f32 vector.
pub fn to_f32_vec(hs: &[u16]) -> Vec<f32> {
    hs.iter().map(|&h| f16_to_f32(h)).collect()
}

/// Widen `src` into a caller-owned buffer (no allocation; lengths must
/// match). The group-compression path reuses one scratch across heads.
/// Routed through the runtime dispatch table (`sparse::dispatch`): on
/// AVX2+F16C hardware this is one `_mm256_cvtph_ps` per 8 elements,
/// bit-identical to the scalar multiply trick.
pub fn widen_into(dst: &mut [f32], src: &[u16]) {
    assert_eq!(dst.len(), src.len());
    (crate::sparse::dispatch::kernels().widen)(dst, src);
}

/// Round every element of `xs` through binary16 — the reference
/// transform every "stored and widened" test compares against.
pub fn f16_round_vec(xs: &[f32]) -> Vec<f32> {
    xs.iter().map(|&x| f16_round(x)).collect()
}

/// Append the f16 narrowing of `xs` onto `dst` (the tail-buffer push path).
#[inline]
pub fn extend_f16(dst: &mut Vec<u16>, xs: &[f32]) {
    dst.extend(xs.iter().map(|&x| f32_to_f16(x)));
}

/// Element type a KV buffer can hold: `f32` (activations, dense
/// baselines) or binary16 bits in a `u16` (the compressed region and the
/// dense-tail storage). The dense MV kernels are generic over this so the
/// same code serves full-precision prefill buffers and the f16 tail.
///
/// `dot` and `fma_row` pick the element type's entry in a dispatch
/// `KernelTable`, so the generic dense kernels reach the runtime-selected
/// SIMD tier without monomorphizing over the backend.
pub trait KvElem: Copy {
    /// Widen to f32 (identity for f32, f16 decode for u16).
    fn widen(self) -> f32;

    /// Dispatched Σ_i row[i]·q[i] (the dense-Key hot loop).
    fn dot(kt: &KernelTable, row: &[Self], q: &[f32]) -> f32;

    /// Dispatched out[i] += row[i]·w (the dense-Value hot loop).
    fn fma_row(kt: &KernelTable, out: &mut [f32], row: &[Self], w: f32);
}

impl KvElem for f32 {
    #[inline(always)]
    fn widen(self) -> f32 {
        self
    }

    #[inline(always)]
    fn dot(kt: &KernelTable, row: &[f32], q: &[f32]) -> f32 {
        (kt.dot_f32)(row, q)
    }

    #[inline(always)]
    fn fma_row(kt: &KernelTable, out: &mut [f32], row: &[f32], w: f32) {
        (kt.fma_f32)(out, row, w)
    }
}

impl KvElem for u16 {
    #[inline(always)]
    fn widen(self) -> f32 {
        f16_to_f32(self)
    }

    #[inline(always)]
    fn dot(kt: &KernelTable, row: &[u16], q: &[f32]) -> f32 {
        (kt.dot_f16)(row, q)
    }

    #[inline(always)]
    fn fma_row(kt: &KernelTable, out: &mut [f32], row: &[u16], w: f32) {
        (kt.fma_f16)(out, row, w)
    }
}

/// Portable-SIMD widening (nightly `portable_simd`, behind the `simd`
/// cargo feature). Lane-for-lane bit-identical to the scalar
/// `f16_to_f32`: same multiply trick, and both the subnormal and normal
/// rescalings are exact, so there is no rounding to diverge on.
#[cfg(feature = "simd")]
pub mod simd {
    use core::simd::cmp::SimdPartialOrd;
    use core::simd::num::SimdFloat;
    use core::simd::Simd;

    /// Lane count for the tile kernels (one AVX2 register of f32).
    pub const LANES: usize = 8;
    pub type F32S = Simd<f32, LANES>;
    pub type U32S = Simd<u32, LANES>;
    pub type U16S = Simd<u16, LANES>;

    /// Widen 8 packed binary16 values to f32.
    #[inline]
    pub fn widen(h: U16S) -> F32S {
        let h: U32S = h.cast();
        let sign = (h & U32S::splat(0x8000)) << U32S::splat(16);
        let om = (h & U32S::splat(0x7fff)) << U32S::splat(13);
        let f = F32S::from_bits(om) * F32S::splat(f32::from_bits(super::WIDEN_SCALE_BITS));
        let bits = f.to_bits();
        let infnan = f.simd_ge(F32S::splat(f32::from_bits(super::WIDEN_INFNAN_BITS)));
        let bits = infnan.select(bits | U32S::splat(0x7f80_0000), bits);
        F32S::from_bits(bits | sign)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Straightforward (slow, obviously-correct) widening used as the
    /// oracle for the exhaustive cross-check. All arithmetic is exact in
    /// f32: `man / 1024` and `2^k` scalings introduce no rounding.
    fn f16_to_f32_reference(h: u16) -> f32 {
        let sign = if h & 0x8000 != 0 { -1.0f32 } else { 1.0 };
        let exp = ((h >> 10) & 0x1f) as i32;
        let man = (h & 0x3ff) as f32;
        match exp {
            0 => sign * man * (2.0f32).powi(-24),
            31 => {
                if man == 0.0 {
                    sign * f32::INFINITY
                } else {
                    f32::NAN
                }
            }
            e => sign * (1.0 + man / 1024.0) * (2.0f32).powi(e - 15),
        }
    }

    #[test]
    fn widen_matches_reference_exhaustively() {
        for h in 0..=u16::MAX {
            let got = f16_to_f32(h);
            let want = f16_to_f32_reference(h);
            if want.is_nan() {
                assert!(got.is_nan(), "h={h:#06x}: {got} should be NaN");
            } else {
                assert_eq!(got.to_bits(), want.to_bits(), "h={h:#06x}: {got} vs {want}");
            }
        }
    }

    #[test]
    fn roundtrip_is_identity_for_every_finite_half() {
        // Includes ±0 and every subnormal; NaN payloads canonicalize and
        // are excluded.
        for h in 0..=u16::MAX {
            if (h >> 10) & 0x1f == 31 && h & 0x3ff != 0 {
                continue; // NaN
            }
            assert_eq!(f32_to_f16(f16_to_f32(h)), h, "h={h:#06x}");
        }
    }

    #[test]
    fn exact_for_representable_values() {
        for x in [
            0.0f32, -0.0, 1.0, -1.0, 0.5, 1.5, -0.375, 2048.0, 65504.0, -65504.0, 6.103515625e-5,
            5.960464477539063e-8, // smallest subnormal, 2^-24
        ] {
            assert_eq!(f16_round(x).to_bits(), x.to_bits(), "{x}");
        }
        for k in -24..=15 {
            let x = (2.0f32).powi(k);
            assert_eq!(f16_round(x), x, "2^{k}");
        }
    }

    #[test]
    fn rounds_to_nearest_even() {
        // 1 + 2^-11 is exactly halfway between 1.0 and 1.0 + 2^-10:
        // ties go to the even mantissa (1.0).
        assert_eq!(f32_to_f16(1.0 + (2.0f32).powi(-11)), f32_to_f16(1.0));
        // 1 + 3·2^-11 is halfway between 1 + 2^-10 (odd) and 1 + 2^-9
        // (even): ties to even rounds *up* here.
        let x = 1.0 + 3.0 * (2.0f32).powi(-11);
        assert_eq!(f16_to_f32(f32_to_f16(x)), 1.0 + (2.0f32).powi(-9));
        // just above/below the tie round to the nearer neighbour
        assert_eq!(f16_round(1.0 + 1.1 * (2.0f32).powi(-11)), 1.0 + (2.0f32).powi(-10));
        assert_eq!(f16_round(1.0 + 0.9 * (2.0f32).powi(-11)), 1.0);
    }

    #[test]
    fn overflow_underflow_and_specials() {
        assert_eq!(f32_to_f16(65519.0), f32_to_f16(65504.0)); // below the tie: max normal
        assert_eq!(f32_to_f16(65520.0), 0x7c00); // tie rounds up to Inf
        assert_eq!(f32_to_f16(1e6), 0x7c00);
        assert_eq!(f32_to_f16(-1e6), 0xfc00);
        assert_eq!(f32_to_f16(f32::INFINITY), 0x7c00);
        assert_eq!(f32_to_f16(f32::NEG_INFINITY), 0xfc00);
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        // sub-subnormal magnitudes underflow to signed zero
        assert_eq!(f32_to_f16(1e-10), 0x0000);
        assert_eq!(f32_to_f16(-1e-10), 0x8000);
        // 2^-25 is exactly halfway between 0 and the smallest subnormal:
        // ties to even -> 0; anything above it rounds to the subnormal.
        assert_eq!(f32_to_f16((2.0f32).powi(-25)), 0x0000);
        assert_eq!(f32_to_f16(1.5 * (2.0f32).powi(-25)), 0x0001);
    }

    #[test]
    fn relative_error_bounded_for_normals() {
        let mut rng = crate::util::Pcg32::seeded(404);
        for _ in 0..20_000 {
            let x = rng.normal_f32() * 10.0;
            let r = f16_round(x);
            let rel = (r - x).abs() / x.abs().max(6.2e-5);
            assert!(rel <= (2.0f32).powi(-11), "{x} -> {r} (rel {rel})");
        }
    }

    #[cfg(feature = "simd")]
    #[test]
    fn simd_widen_matches_scalar_exhaustively() {
        use super::simd::{widen, U16S, LANES};
        let mut h: u32 = 0;
        while h <= u16::MAX as u32 {
            let lane: [u16; LANES] = std::array::from_fn(|i| (h as u16).wrapping_add(i as u16));
            let got = widen(U16S::from_array(lane));
            for i in 0..LANES {
                assert_eq!(
                    got[i].to_bits(),
                    f16_to_f32(lane[i]).to_bits(),
                    "h={:#06x}",
                    lane[i]
                );
            }
            h += LANES as u32;
        }
    }
}
