//! Runtime SIMD dispatch for the decode and prefill hot kernels.
//!
//! The paper's throughput claim rests on the decode SpMV being
//! memory-bound — which only holds if the compute side keeps up. Until
//! this module existed the *default stable build* ran every tile FMA and
//! every f16→f32 widening as scalar code: explicit SIMD lived exclusively
//! behind the nightly-only `portable_simd` feature, so CI's stable gate
//! and any stable-toolchain deployment shipped the slow path.
//!
//! This module detects CPU features **once at runtime**
//! (`is_x86_feature_detected!`) and caches a table of kernel function
//! pointers in a `OnceLock`. The surface is backend-shaped, not
//! x86-shaped — every tier fills the same `KernelTable`:
//!
//! * `Backend::Scalar` — always compiled; the bit-exact parity oracle
//!   every other tier is property-tested against.
//! * `Backend::Avx2` — stable-Rust `std::arch` implementations behind
//!   `#[target_feature(enable = "avx2,fma,f16c")]`, selected at runtime.
//!   The f16→f32 widening uses hardware `_mm256_cvtph_ps` (one
//!   instruction; bit-identical to the scalar multiply trick since both
//!   are exact).
//! * `Backend::Portable` — the nightly `std::simd` kernels (cargo
//!   feature `simd`), folded into the same table as just another tier.
//! * `Backend::Neon` — reserved aarch64 tier: the slot exists so NEON
//!   kernels drop into the same table; until they land aarch64 serves
//!   the scalar oracle.
//!
//! **Bit-exactness contract.** Every non-scalar kernel preserves the
//! scalar oracle's *per-lane floating-point operation order*: tile FMAs
//! stay separate mul-then-add (Rust never contracts, and the intrinsic
//! paths use `_mm256_mul_ps` + `_mm256_add_ps`, not `_mm256_fmadd_ps`);
//! dot products accumulate 8 stride-8 partial sums and combine them in
//! one fixed order (`combine8`, shared by every tier). The dispatch
//! parity tests therefore assert `==` on bits, not tolerance.
//!
//! Env overrides (testing / benchmarking):
//! * `MUSTAFAR_FORCE_SCALAR=1` — pin the scalar oracle regardless of CPU.
//! * `MUSTAFAR_SIMD=scalar|avx2|portable` — request one tier; a tier the
//!   build or CPU cannot serve falls back to the scalar oracle (never
//!   silently to a different SIMD tier).

use std::sync::OnceLock;

/// Which kernel tier a `KernelTable` routes to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Plain Rust loops — the bit-exact parity oracle.
    Scalar,
    /// Nightly `std::simd` (cargo feature `simd`).
    Portable,
    /// Stable `std::arch` AVX2 + FMA + F16C, runtime-detected.
    Avx2,
    /// Reserved aarch64 tier (kernels not yet implemented).
    Neon,
}

impl Backend {
    pub fn name(self) -> &'static str {
        match self {
            Backend::Scalar => "scalar",
            Backend::Portable => "portable-simd",
            Backend::Avx2 => "avx2+fma+f16c",
            Backend::Neon => "neon",
        }
    }
}

/// Function-pointer table of the hot kernels. All entries obey the
/// bit-exactness contract in the module docs; callers pick one table and
/// thread it through a whole kernel invocation (`*_with` variants), so a
/// single computation never mixes tiers.
#[derive(Clone, Copy)]
pub struct KernelTable {
    pub backend: Backend,
    /// `out[i] += widen(vals[i]) * w` — the 64-wide dense-tile sweep.
    pub fma_f16: fn(&mut [f32], &[u16], f32),
    /// `out[i] += buf[i] * w` — the expand-then-FMA sweep.
    pub fma_f32: fn(&mut [f32], &[f32], f32),
    /// `dst[i] = widen(src[i])` — bulk f16→f32 widening.
    pub widen: fn(&mut [f32], &[u16]),
    /// Stride-8 eight-accumulator dot product (combine order fixed by
    /// `combine8`).
    pub dot_f32: fn(&[f32], &[f32]) -> f32,
    /// Same dot with an f16 row widened in-register.
    pub dot_f16: fn(&[u16], &[f32]) -> f32,
    /// `out[c] += a[0]*w0[c] + a[1]*w1[c] + a[2]*w2[c] + a[3]*w3[c]` —
    /// the 4-way-unrolled matmul axpy sweep.
    pub axpy4: fn(&mut [f32], &[f32], &[f32], &[f32], &[f32], [f32; 4]),
}

impl KernelTable {
    /// The scalar oracle tier (always available).
    pub fn scalar() -> KernelTable {
        KernelTable {
            backend: Backend::Scalar,
            fma_f16: scalar::fma_f16,
            fma_f32: scalar::fma_f32,
            widen: scalar::widen,
            dot_f32: scalar::dot_f32,
            dot_f16: scalar::dot_f16,
            axpy4: scalar::axpy4,
        }
    }

    /// The AVX2+FMA+F16C tier, if this build targets x86-64 and the CPU
    /// has the features.
    #[cfg(target_arch = "x86_64")]
    pub fn avx2() -> Option<KernelTable> {
        x86::table()
    }

    /// The AVX2+FMA+F16C tier (never available off x86-64).
    #[cfg(not(target_arch = "x86_64"))]
    pub fn avx2() -> Option<KernelTable> {
        None
    }

    /// The nightly portable-SIMD tier (cargo feature `simd`).
    #[cfg(feature = "simd")]
    pub fn portable() -> KernelTable {
        portable::table()
    }

    /// The aarch64 NEON tier, once its kernels exist.
    #[cfg(target_arch = "aarch64")]
    pub fn neon() -> Option<KernelTable> {
        neon::table()
    }

    /// The NEON tier (never available off aarch64).
    #[cfg(not(target_arch = "aarch64"))]
    pub fn neon() -> Option<KernelTable> {
        None
    }
}

/// Every tier available in this build on this CPU (scalar first). The
/// dispatch parity tests run each kernel through all of these and assert
/// bit-identical outputs.
pub fn available() -> Vec<KernelTable> {
    let mut v = vec![KernelTable::scalar()];
    #[cfg(feature = "simd")]
    v.push(KernelTable::portable());
    if let Some(t) = KernelTable::avx2() {
        v.push(t);
    }
    if let Some(t) = KernelTable::neon() {
        v.push(t);
    }
    v
}

/// The process-wide dispatched table: detected once, cached forever.
pub fn kernels() -> &'static KernelTable {
    static TABLE: OnceLock<KernelTable> = OnceLock::new();
    TABLE.get_or_init(|| {
        select(
            std::env::var("MUSTAFAR_FORCE_SCALAR").ok().as_deref(),
            std::env::var("MUSTAFAR_SIMD").ok().as_deref(),
        )
    })
}

/// Resolve the env overrides into a table (factored out of `kernels` so
/// the override logic is testable without mutating process env).
fn select(force_scalar: Option<&str>, request: Option<&str>) -> KernelTable {
    if force_scalar.is_some_and(|v| !v.is_empty() && v != "0") {
        return KernelTable::scalar();
    }
    match request {
        Some("avx2") => KernelTable::avx2().unwrap_or_else(KernelTable::scalar),
        Some("portable") => portable_or_scalar(),
        Some("neon") => KernelTable::neon().unwrap_or_else(KernelTable::scalar),
        Some("scalar") => KernelTable::scalar(),
        Some(other) => {
            // A typo'd tier silently running everything scalar would be
            // the exact slowdown this module removes — say so once.
            eprintln!(
                "[mustafar] unknown MUSTAFAR_SIMD value {other:?}; \
                 falling back to the scalar oracle"
            );
            KernelTable::scalar()
        }
        None => auto(),
    }
}

/// Auto-detection order: hardware intrinsics first (F16C widening beats
/// the portable multiply trick), then the portable tier if compiled in,
/// then scalar.
fn auto() -> KernelTable {
    if let Some(t) = KernelTable::avx2() {
        return t;
    }
    if let Some(t) = KernelTable::neon() {
        return t;
    }
    portable_or_scalar()
}

#[cfg(feature = "simd")]
fn portable_or_scalar() -> KernelTable {
    KernelTable::portable()
}

#[cfg(not(feature = "simd"))]
fn portable_or_scalar() -> KernelTable {
    KernelTable::scalar()
}

/// The one fixed reduction order every tier's dot product ends with:
/// eight stride-8 partial sums combined left to right, then the scalar
/// remainder. Shared so the order cannot drift between tiers.
#[inline(always)]
pub(crate) fn combine8(l: [f32; 8], tail: f32) -> f32 {
    ((((((l[0] + l[1]) + l[2]) + l[3]) + l[4]) + l[5]) + l[6]) + l[7] + tail
}

// ---------------------------------------------------------------------------
// Scalar oracle tier.
// ---------------------------------------------------------------------------

pub mod scalar {
    use super::combine8;
    use crate::sparse::f16::f16_to_f32;

    /// out[i] += widen(vals[i]) * w
    pub fn fma_f16(out: &mut [f32], vals: &[u16], w: f32) {
        debug_assert_eq!(out.len(), vals.len());
        for (o, &v) in out.iter_mut().zip(vals) {
            *o += f16_to_f32(v) * w;
        }
    }

    /// out[i] += buf[i] * w
    pub fn fma_f32(out: &mut [f32], buf: &[f32], w: f32) {
        debug_assert_eq!(out.len(), buf.len());
        for (o, &x) in out.iter_mut().zip(buf) {
            *o += x * w;
        }
    }

    /// dst[i] = widen(src[i])
    pub fn widen(dst: &mut [f32], src: &[u16]) {
        debug_assert_eq!(dst.len(), src.len());
        for (d, &h) in dst.iter_mut().zip(src) {
            *d = f16_to_f32(h);
        }
    }

    #[inline]
    fn dot8(widen_at: impl Fn(usize) -> f32, q: &[f32], n: usize) -> f32 {
        let lim = n & !7;
        let mut l = [0.0f32; 8];
        let mut c = 0;
        while c < lim {
            for (i, li) in l.iter_mut().enumerate() {
                *li += widen_at(c + i) * q[c + i];
            }
            c += 8;
        }
        let mut tail = 0.0f32;
        while c < n {
            tail += widen_at(c) * q[c];
            c += 1;
        }
        combine8(l, tail)
    }

    /// Σ_i row[i]·q[i], eight stride-8 accumulators.
    pub fn dot_f32(row: &[f32], q: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), q.len());
        dot8(|i| row[i], q, row.len())
    }

    /// Σ_i widen(row[i])·q[i], eight stride-8 accumulators.
    pub fn dot_f16(row: &[u16], q: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), q.len());
        dot8(|i| f16_to_f32(row[i]), q, row.len())
    }

    /// out[c] += a[0]*w0[c] + a[1]*w1[c] + a[2]*w2[c] + a[3]*w3[c]
    pub fn axpy4(out: &mut [f32], w0: &[f32], w1: &[f32], w2: &[f32], w3: &[f32], a: [f32; 4]) {
        let n = out.len();
        debug_assert!(w0.len() >= n && w1.len() >= n && w2.len() >= n && w3.len() >= n);
        for c in 0..n {
            out[c] += a[0] * w0[c] + a[1] * w1[c] + a[2] * w2[c] + a[3] * w3[c];
        }
    }
}

// ---------------------------------------------------------------------------
// Portable-SIMD tier (nightly `std::simd`, cargo feature `simd`).
// ---------------------------------------------------------------------------

#[cfg(feature = "simd")]
mod portable {
    use super::{combine8, scalar, Backend, KernelTable};
    use crate::sparse::f16::simd::{widen as widen8, F32S, U16S, LANES};

    pub fn table() -> KernelTable {
        KernelTable {
            backend: Backend::Portable,
            fma_f16,
            fma_f32,
            widen,
            dot_f32,
            dot_f16,
            axpy4,
        }
    }

    fn fma_f16(out: &mut [f32], vals: &[u16], w: f32) {
        debug_assert_eq!(out.len(), vals.len());
        let wv = F32S::splat(w);
        let mut oc = out.chunks_exact_mut(LANES);
        let mut vc = vals.chunks_exact(LANES);
        for (o, v) in (&mut oc).zip(&mut vc) {
            let acc = F32S::from_slice(o) + widen8(U16S::from_slice(v)) * wv;
            acc.copy_to_slice(o);
        }
        scalar::fma_f16(oc.into_remainder(), vc.remainder(), w);
    }

    fn fma_f32(out: &mut [f32], buf: &[f32], w: f32) {
        debug_assert_eq!(out.len(), buf.len());
        let wv = F32S::splat(w);
        let mut oc = out.chunks_exact_mut(LANES);
        let mut bc = buf.chunks_exact(LANES);
        for (o, b) in (&mut oc).zip(&mut bc) {
            let acc = F32S::from_slice(o) + F32S::from_slice(b) * wv;
            acc.copy_to_slice(o);
        }
        scalar::fma_f32(oc.into_remainder(), bc.remainder(), w);
    }

    fn widen(dst: &mut [f32], src: &[u16]) {
        debug_assert_eq!(dst.len(), src.len());
        let mut dc = dst.chunks_exact_mut(LANES);
        let mut sc = src.chunks_exact(LANES);
        for (d, s) in (&mut dc).zip(&mut sc) {
            widen8(U16S::from_slice(s)).copy_to_slice(d);
        }
        scalar::widen(dc.into_remainder(), sc.remainder());
    }

    fn dot_f32(row: &[f32], q: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), q.len());
        let n = row.len();
        let lim = n & !(LANES - 1);
        let mut vacc = F32S::splat(0.0);
        let mut c = 0;
        while c < lim {
            vacc += F32S::from_slice(&row[c..c + LANES]) * F32S::from_slice(&q[c..c + LANES]);
            c += LANES;
        }
        let mut tail = 0.0f32;
        while c < n {
            tail += row[c] * q[c];
            c += 1;
        }
        combine8(vacc.to_array(), tail)
    }

    fn dot_f16(row: &[u16], q: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), q.len());
        let n = row.len();
        let lim = n & !(LANES - 1);
        let mut vacc = F32S::splat(0.0);
        let mut c = 0;
        while c < lim {
            let r = widen8(U16S::from_slice(&row[c..c + LANES]));
            vacc += r * F32S::from_slice(&q[c..c + LANES]);
            c += LANES;
        }
        let mut tail = 0.0f32;
        while c < n {
            tail += crate::sparse::f16::f16_to_f32(row[c]) * q[c];
            c += 1;
        }
        combine8(vacc.to_array(), tail)
    }

    fn axpy4(out: &mut [f32], w0: &[f32], w1: &[f32], w2: &[f32], w3: &[f32], a: [f32; 4]) {
        let n = out.len();
        debug_assert!(w0.len() >= n && w1.len() >= n && w2.len() >= n && w3.len() >= n);
        let (a0, a1, a2) = (F32S::splat(a[0]), F32S::splat(a[1]), F32S::splat(a[2]));
        let a3 = F32S::splat(a[3]);
        let lim = n & !(LANES - 1);
        let mut c = 0;
        while c < lim {
            let mut t = a0 * F32S::from_slice(&w0[c..c + LANES]);
            t += a1 * F32S::from_slice(&w1[c..c + LANES]);
            t += a2 * F32S::from_slice(&w2[c..c + LANES]);
            t += a3 * F32S::from_slice(&w3[c..c + LANES]);
            let acc = F32S::from_slice(&out[c..c + LANES]) + t;
            acc.copy_to_slice(&mut out[c..c + LANES]);
            c += LANES;
        }
        scalar::axpy4(&mut out[c..], &w0[c..n], &w1[c..n], &w2[c..n], &w3[c..n], a);
    }
}

// ---------------------------------------------------------------------------
// Stable x86-64 tier: AVX2 + FMA + F16C, runtime-detected.
//
// Every `unsafe fn` below is sound to call only on a CPU with those
// features; the safe wrappers are placed into a table exclusively by
// `table()`, which verifies them with `is_x86_feature_detected!` first.
// The mul/add pairs are deliberately NOT fused into `_mm256_fmadd_ps`:
// the scalar oracle rounds the product and the sum separately, and the
// bit-exactness contract wins over the last ~10% of FLOPs (the kernels
// are memory-bound regardless — that is the paper's whole argument).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::{combine8, scalar, Backend, KernelTable};
    use core::arch::x86_64::*;

    pub fn table() -> Option<KernelTable> {
        if is_x86_feature_detected!("avx2")
            && is_x86_feature_detected!("fma")
            && is_x86_feature_detected!("f16c")
        {
            Some(KernelTable {
                backend: Backend::Avx2,
                fma_f16,
                fma_f32,
                widen,
                dot_f32,
                dot_f16,
                axpy4,
            })
        } else {
            None
        }
    }

    // Safe wrappers: sound because `table()` gated on runtime detection.

    fn fma_f16(out: &mut [f32], vals: &[u16], w: f32) {
        unsafe { fma_f16_impl(out, vals, w) }
    }

    fn fma_f32(out: &mut [f32], buf: &[f32], w: f32) {
        unsafe { fma_f32_impl(out, buf, w) }
    }

    fn widen(dst: &mut [f32], src: &[u16]) {
        unsafe { widen_impl(dst, src) }
    }

    fn dot_f32(row: &[f32], q: &[f32]) -> f32 {
        unsafe { dot_f32_impl(row, q) }
    }

    fn dot_f16(row: &[u16], q: &[f32]) -> f32 {
        unsafe { dot_f16_impl(row, q) }
    }

    fn axpy4(out: &mut [f32], w0: &[f32], w1: &[f32], w2: &[f32], w3: &[f32], a: [f32; 4]) {
        unsafe { axpy4_impl(out, w0, w1, w2, w3, a) }
    }

    /// Load 8 packed binary16 and widen to 8 f32 (hardware F16C — exact,
    /// hence bit-identical to the scalar multiply trick).
    #[target_feature(enable = "avx2,fma,f16c")]
    #[inline]
    unsafe fn widen8(p: *const u16) -> __m256 {
        _mm256_cvtph_ps(_mm_loadu_si128(p as *const __m128i))
    }

    #[target_feature(enable = "avx2,fma,f16c")]
    unsafe fn fma_f16_impl(out: &mut [f32], vals: &[u16], w: f32) {
        debug_assert_eq!(out.len(), vals.len());
        let n = out.len();
        let wv = _mm256_set1_ps(w);
        let mut i = 0;
        while i + 8 <= n {
            let v = widen8(vals.as_ptr().add(i));
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            let r = _mm256_add_ps(o, _mm256_mul_ps(v, wv));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += 8;
        }
        scalar::fma_f16(&mut out[i..], &vals[i..], w);
    }

    #[target_feature(enable = "avx2,fma,f16c")]
    unsafe fn fma_f32_impl(out: &mut [f32], buf: &[f32], w: f32) {
        debug_assert_eq!(out.len(), buf.len());
        let n = out.len();
        let wv = _mm256_set1_ps(w);
        let mut i = 0;
        while i + 8 <= n {
            let b = _mm256_loadu_ps(buf.as_ptr().add(i));
            let o = _mm256_loadu_ps(out.as_ptr().add(i));
            let r = _mm256_add_ps(o, _mm256_mul_ps(b, wv));
            _mm256_storeu_ps(out.as_mut_ptr().add(i), r);
            i += 8;
        }
        scalar::fma_f32(&mut out[i..], &buf[i..], w);
    }

    #[target_feature(enable = "avx2,fma,f16c")]
    unsafe fn widen_impl(dst: &mut [f32], src: &[u16]) {
        debug_assert_eq!(dst.len(), src.len());
        let n = dst.len();
        let mut i = 0;
        while i + 8 <= n {
            _mm256_storeu_ps(dst.as_mut_ptr().add(i), widen8(src.as_ptr().add(i)));
            i += 8;
        }
        scalar::widen(&mut dst[i..], &src[i..]);
    }

    #[target_feature(enable = "avx2,fma,f16c")]
    unsafe fn dot_f32_impl(row: &[f32], q: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), q.len());
        let n = row.len();
        let lim = n & !7;
        let mut vacc = _mm256_setzero_ps();
        let mut c = 0;
        while c < lim {
            let r = _mm256_loadu_ps(row.as_ptr().add(c));
            let qq = _mm256_loadu_ps(q.as_ptr().add(c));
            vacc = _mm256_add_ps(vacc, _mm256_mul_ps(r, qq));
            c += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), vacc);
        let mut tail = 0.0f32;
        while c < n {
            tail += row[c] * q[c];
            c += 1;
        }
        combine8(lanes, tail)
    }

    #[target_feature(enable = "avx2,fma,f16c")]
    unsafe fn dot_f16_impl(row: &[u16], q: &[f32]) -> f32 {
        debug_assert_eq!(row.len(), q.len());
        let n = row.len();
        let lim = n & !7;
        let mut vacc = _mm256_setzero_ps();
        let mut c = 0;
        while c < lim {
            let r = widen8(row.as_ptr().add(c));
            let qq = _mm256_loadu_ps(q.as_ptr().add(c));
            vacc = _mm256_add_ps(vacc, _mm256_mul_ps(r, qq));
            c += 8;
        }
        let mut lanes = [0.0f32; 8];
        _mm256_storeu_ps(lanes.as_mut_ptr(), vacc);
        let mut tail = 0.0f32;
        while c < n {
            tail += crate::sparse::f16::f16_to_f32(row[c]) * q[c];
            c += 1;
        }
        combine8(lanes, tail)
    }

    #[target_feature(enable = "avx2,fma,f16c")]
    unsafe fn axpy4_impl(
        out: &mut [f32],
        w0: &[f32],
        w1: &[f32],
        w2: &[f32],
        w3: &[f32],
        a: [f32; 4],
    ) {
        let n = out.len();
        debug_assert!(w0.len() >= n && w1.len() >= n && w2.len() >= n && w3.len() >= n);
        let a0 = _mm256_set1_ps(a[0]);
        let a1 = _mm256_set1_ps(a[1]);
        let a2 = _mm256_set1_ps(a[2]);
        let a3 = _mm256_set1_ps(a[3]);
        let mut c = 0;
        while c + 8 <= n {
            let mut t = _mm256_mul_ps(a0, _mm256_loadu_ps(w0.as_ptr().add(c)));
            t = _mm256_add_ps(t, _mm256_mul_ps(a1, _mm256_loadu_ps(w1.as_ptr().add(c))));
            t = _mm256_add_ps(t, _mm256_mul_ps(a2, _mm256_loadu_ps(w2.as_ptr().add(c))));
            t = _mm256_add_ps(t, _mm256_mul_ps(a3, _mm256_loadu_ps(w3.as_ptr().add(c))));
            let o = _mm256_loadu_ps(out.as_ptr().add(c));
            _mm256_storeu_ps(out.as_mut_ptr().add(c), _mm256_add_ps(o, t));
            c += 8;
        }
        scalar::axpy4(&mut out[c..], &w0[c..n], &w1[c..n], &w2[c..n], &w3[c..n], a);
    }
}

// ---------------------------------------------------------------------------
// aarch64 NEON tier (reserved).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::KernelTable;

    /// NEON kernels have not been written yet; returning `None` routes
    /// aarch64 through the scalar oracle while keeping the tier a
    /// first-class member of the dispatch surface (the table shape and
    /// the `MUSTAFAR_SIMD=neon` override already exist).
    pub fn table() -> Option<KernelTable> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::f16::{f16_to_f32, f32_to_f16};
    use crate::util::Pcg32;

    fn non_scalar() -> Vec<KernelTable> {
        available().into_iter().filter(|t| t.backend != Backend::Scalar).collect()
    }

    #[test]
    fn force_scalar_env_wins() {
        assert_eq!(select(Some("1"), None).backend, Backend::Scalar);
        assert_eq!(select(Some("1"), Some("avx2")).backend, Backend::Scalar);
        // unset / "0" / empty do not force
        assert_eq!(select(Some("0"), Some("scalar")).backend, Backend::Scalar);
        assert_eq!(select(None, Some("scalar")).backend, Backend::Scalar);
    }

    #[test]
    fn unavailable_request_falls_back_to_scalar() {
        // "neon" is never available on x86 builds, and unknown names
        // must not silently pick a SIMD tier.
        #[cfg(not(target_arch = "aarch64"))]
        assert_eq!(select(None, Some("neon")).backend, Backend::Scalar);
        assert_eq!(select(None, Some("bogus")).backend, Backend::Scalar);
    }

    #[test]
    fn auto_selects_an_available_backend() {
        let t = select(None, None);
        assert!(
            available().iter().any(|a| a.backend == t.backend),
            "auto picked {:?} which is not in available()",
            t.backend
        );
    }

    #[test]
    fn widen_parity_exhaustive_every_backend() {
        // All 65536 binary16 patterns through every tier's bulk widen
        // must match the scalar multiply trick bit for bit (NaNs must at
        // least stay NaN — on x86 the payloads also agree, but the
        // contract is only "both NaN").
        for kt in non_scalar() {
            let src: Vec<u16> = (0..=u16::MAX).collect();
            let mut got = vec![0.0f32; src.len()];
            (kt.widen)(&mut got, &src);
            for (&h, &g) in src.iter().zip(&got) {
                let want = f16_to_f32(h);
                if want.is_nan() {
                    assert!(g.is_nan(), "{:?} h={h:#06x}: {g} should be NaN", kt.backend);
                } else {
                    assert_eq!(
                        g.to_bits(),
                        want.to_bits(),
                        "{:?} h={h:#06x}: {g} vs {want}",
                        kt.backend
                    );
                }
            }
        }
    }

    #[test]
    fn tile_primitives_bitexact_every_backend_every_length() {
        // Partial lengths (1..=130) cover the vector body, the scalar
        // remainder, and the empty case for every primitive.
        let sc = KernelTable::scalar();
        let mut rng = Pcg32::seeded(9090);
        for kt in non_scalar() {
            for len in 0..=130usize {
                let vals: Vec<u16> = (0..len).map(|_| f32_to_f16(rng.normal_f32())).collect();
                let buf: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
                let q: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
                let acc0: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
                let w = rng.normal_f32();

                let mut a = acc0.clone();
                let mut b = acc0.clone();
                (kt.fma_f16)(&mut a, &vals, w);
                (sc.fma_f16)(&mut b, &vals, w);
                assert_eq!(a, b, "{:?} fma_f16 len {len}", kt.backend);

                let mut a = acc0.clone();
                let mut b = acc0.clone();
                (kt.fma_f32)(&mut a, &buf, w);
                (sc.fma_f32)(&mut b, &buf, w);
                assert_eq!(a, b, "{:?} fma_f32 len {len}", kt.backend);

                let mut a = vec![0.0f32; len];
                let mut b = vec![0.0f32; len];
                (kt.widen)(&mut a, &vals);
                (sc.widen)(&mut b, &vals);
                assert_eq!(a, b, "{:?} widen len {len}", kt.backend);

                let da = (kt.dot_f32)(&buf, &q);
                let db = (sc.dot_f32)(&buf, &q);
                assert_eq!(da.to_bits(), db.to_bits(), "{:?} dot_f32 len {len}", kt.backend);

                let da = (kt.dot_f16)(&vals, &q);
                let db = (sc.dot_f16)(&vals, &q);
                assert_eq!(da.to_bits(), db.to_bits(), "{:?} dot_f16 len {len}", kt.backend);

                let w0: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
                let w1: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
                let w2: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
                let w3: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
                let ax = [rng.normal_f32(), rng.normal_f32(), rng.normal_f32(), rng.normal_f32()];
                let mut a = acc0.clone();
                let mut b = acc0.clone();
                (kt.axpy4)(&mut a, &w0, &w1, &w2, &w3, ax);
                (sc.axpy4)(&mut b, &w0, &w1, &w2, &w3, ax);
                assert_eq!(a, b, "{:?} axpy4 len {len}", kt.backend);
            }
        }
    }

    #[test]
    fn backend_names_are_stable() {
        // bench JSON and CI logs key on these strings
        assert_eq!(Backend::Scalar.name(), "scalar");
        assert_eq!(Backend::Avx2.name(), "avx2+fma+f16c");
        assert_eq!(Backend::Portable.name(), "portable-simd");
        assert_eq!(Backend::Neon.name(), "neon");
    }
}
