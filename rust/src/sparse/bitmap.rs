//! The paper's bitmap-based sparse format (Fig 5b, App. C).
//!
//! A pruned cache matrix `[tokens x channels]` is stored as 1x64 tiles:
//! each tile covers 64 consecutive elements along the *packing axis*, and
//! carries a 64-bit bitmap marking non-zero positions plus a tile offset
//! addressing its first non-zero in the packed value array. Per-tile value
//! segments are padded to a multiple of 8 ("coalescing" padding — the
//! paper's 15%-overhead source at 50% sparsity).
//!
//! Packing-axis choice follows App. C: the tiling direction must be
//! orthogonal to the dimension being contracted, so
//!   * Key cache (contracted over channels in K·q)   -> `PackAxis::Token`
//!   * Value cache (contracted over tokens in αᵀ·V)  -> `PackAxis::Channel`
//!
//! Tile *ordering* is chosen so that newly compressed 64-token groups
//! append at the end of every array (App. C requirement (2)); see
//! `layout.rs` for the traversal and the append path.

use crate::error::{Error, Result};
use crate::util::round_up;

/// Tile extent along the packing axis (the paper's 1x64 tile).
pub const TILE: usize = 64;
/// Value-segment padding granularity (paper: multiples of 8).
pub const PAD: usize = 8;
/// Bytes per stored value in the *accounting model* (paper stores fp16).
pub const VALUE_BYTES: usize = 2;
/// Bytes per tile bitmap.
pub const BITMAP_BYTES: usize = 8;
/// Bytes per tile offset.
pub const OFFSET_BYTES: usize = 4;

/// Which logical dimension the 1x64 tiles run along.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackAxis {
    /// Tiles span 64 tokens at a fixed channel (Key cache; Fig 9a).
    Token,
    /// Tiles span 64 channels of a fixed token (Value cache; Fig 9b).
    Channel,
}

/// A pruned `[tokens x channels]` matrix in the bitmap format.
#[derive(Clone, Debug, PartialEq)]
pub struct BitmapMatrix {
    pub tokens: usize,
    pub channels: usize,
    pub axis: PackAxis,
    /// Per-tile 64-bit occupancy bitmap, in `layout::tile_order`.
    pub bitmaps: Vec<u64>,
    /// Per-tile start offset into `values` (+ one trailing total-length entry).
    pub offsets: Vec<u32>,
    /// Packed non-zero values; each tile's segment padded to a multiple of 8.
    pub values: Vec<f32>,
}

impl BitmapMatrix {
    /// Number of tiles for a (tokens, channels, axis) geometry.
    pub fn n_tiles(tokens: usize, channels: usize, axis: PackAxis) -> usize {
        match axis {
            PackAxis::Token => tokens.div_ceil(TILE) * channels,
            PackAxis::Channel => channels.div_ceil(TILE) * tokens,
        }
    }

    /// Empty matrix with zero tokens.
    pub fn empty(channels: usize, axis: PackAxis) -> BitmapMatrix {
        BitmapMatrix {
            tokens: 0,
            channels,
            axis,
            bitmaps: Vec::new(),
            offsets: vec![0],
            values: Vec::new(),
        }
    }

    /// Compress a dense (already pruned — zeros are "pruned away") matrix.
    ///
    /// `dense` is row-major `[tokens x channels]`. For `PackAxis::Token`,
    /// `tokens` must be a multiple of 64 (the KV manager only compresses
    /// whole 64-token groups, matching the kernel's warp-tile granularity);
    /// for `PackAxis::Channel`, `channels` must be a multiple of 64.
    pub fn compress(dense: &[f32], tokens: usize, channels: usize, axis: PackAxis) -> Result<BitmapMatrix> {
        if dense.len() != tokens * channels {
            return Err(Error::Shape(format!(
                "dense len {} != {}x{}",
                dense.len(),
                tokens,
                channels
            )));
        }
        match axis {
            PackAxis::Token if tokens % TILE != 0 => {
                return Err(Error::Shape(format!("tokens {tokens} not a multiple of {TILE}")));
            }
            PackAxis::Channel if channels % TILE != 0 => {
                return Err(Error::Shape(format!("channels {channels} not a multiple of {TILE}")));
            }
            _ => {}
        }

        let mut m = BitmapMatrix::empty(channels, axis);
        m.append_groups(dense, tokens)?;
        Ok(m)
    }

    /// Append `new_tokens` (a multiple of the group granularity) worth of
    /// dense rows to the compressed matrix. This is the paper's runtime
    /// compression path: 64-token groups exiting the local window are
    /// compressed and appended (App. C requirement (2)).
    pub fn append_groups(&mut self, dense: &[f32], new_tokens: usize) -> Result<()> {
        if dense.len() != new_tokens * self.channels {
            return Err(Error::Shape(format!(
                "append: dense len {} != {}x{}",
                dense.len(),
                new_tokens,
                self.channels
            )));
        }
        if self.axis == PackAxis::Token && new_tokens % TILE != 0 {
            return Err(Error::Shape(format!(
                "append: new_tokens {new_tokens} not a multiple of {TILE}"
            )));
        }

        let d = self.channels;
        match self.axis {
            PackAxis::Token => {
                // groups of 64 tokens; within a group, one tile per channel
                for g in 0..new_tokens / TILE {
                    for c in 0..d {
                        let mut bm: u64 = 0;
                        let mut vals: Vec<f32> = Vec::with_capacity(TILE);
                        for b in 0..TILE {
                            let x = dense[(g * TILE + b) * d + c];
                            if x != 0.0 {
                                bm |= 1u64 << b;
                                vals.push(x);
                            }
                        }
                        self.push_tile(bm, &vals);
                    }
                }
            }
            PackAxis::Channel => {
                // one tile per (token, 64-channel block); token-major order
                let cblocks = d / TILE;
                for t in 0..new_tokens {
                    for cb in 0..cblocks {
                        let mut bm: u64 = 0;
                        let mut vals: Vec<f32> = Vec::with_capacity(TILE);
                        for b in 0..TILE {
                            let x = dense[t * d + cb * TILE + b];
                            if x != 0.0 {
                                bm |= 1u64 << b;
                                vals.push(x);
                            }
                        }
                        self.push_tile(bm, &vals);
                    }
                }
            }
        }
        self.tokens += new_tokens;
        Ok(())
    }

    fn push_tile(&mut self, bitmap: u64, vals: &[f32]) {
        debug_assert_eq!(bitmap.count_ones() as usize, vals.len());
        self.bitmaps.push(bitmap);
        self.values.extend_from_slice(vals);
        // coalescing padding to a multiple of 8 values
        let padded = round_up(vals.len(), PAD);
        self.values.extend(std::iter::repeat(0.0).take(padded - vals.len()));
        let last = *self.offsets.last().unwrap();
        self.offsets.push(last + padded as u32);
    }

    /// Decompress to a dense row-major `[tokens x channels]` matrix.
    pub fn decompress(&self) -> Vec<f32> {
        let d = self.channels;
        let mut out = vec![0.0f32; self.tokens * d];
        match self.axis {
            PackAxis::Token => {
                for (ti, &bm) in self.bitmaps.iter().enumerate() {
                    let g = ti / d;
                    let c = ti % d;
                    let mut off = self.offsets[ti] as usize;
                    let mut bits = bm;
                    while bits != 0 {
                        let b = bits.trailing_zeros() as usize;
                        out[(g * TILE + b) * d + c] = self.values[off];
                        off += 1;
                        bits &= bits - 1;
                    }
                }
            }
            PackAxis::Channel => {
                let cblocks = d / TILE;
                for (ti, &bm) in self.bitmaps.iter().enumerate() {
                    let t = ti / cblocks;
                    let cb = ti % cblocks;
                    let mut off = self.offsets[ti] as usize;
                    let mut bits = bm;
                    while bits != 0 {
                        let b = bits.trailing_zeros() as usize;
                        out[t * d + cb * TILE + b] = self.values[off];
                        off += 1;
                        bits &= bits - 1;
                    }
                }
            }
        }
        out
    }

    /// Number of stored non-zeros (excluding padding slots).
    pub fn nnz(&self) -> usize {
        self.bitmaps.iter().map(|b| b.count_ones() as usize).sum()
    }

    /// Compressed size in bytes under the paper's accounting model
    /// (fp16 values incl. padding + u64 bitmaps + u32 tile offsets).
    pub fn compressed_bytes(&self) -> usize {
        self.values.len() * VALUE_BYTES
            + self.bitmaps.len() * BITMAP_BYTES
            + (self.offsets.len() - 1) * OFFSET_BYTES
    }

    /// Dense size in bytes of the same matrix (fp16 accounting).
    pub fn dense_bytes(&self) -> usize {
        self.tokens * self.channels * VALUE_BYTES
    }

    /// Compression rate = compressed / dense (the paper's Fig 6b metric;
    /// lower is better, dense = 1.0).
    pub fn compression_rate(&self) -> f64 {
        if self.tokens == 0 {
            return 0.0;
        }
        self.compressed_bytes() as f64 / self.dense_bytes() as f64
    }

    /// Internal consistency check (used by tests and debug assertions).
    pub fn validate(&self) -> Result<()> {
        let want_tiles = Self::n_tiles(self.tokens, self.channels, self.axis);
        if self.bitmaps.len() != want_tiles {
            return Err(Error::Shape(format!(
                "tile count {} != expected {}",
                self.bitmaps.len(),
                want_tiles
            )));
        }
        if self.offsets.len() != want_tiles + 1 {
            return Err(Error::Shape("offsets length mismatch".into()));
        }
        for (i, &bm) in self.bitmaps.iter().enumerate() {
            let seg = (self.offsets[i + 1] - self.offsets[i]) as usize;
            let nnz = bm.count_ones() as usize;
            if seg != round_up(nnz, PAD) {
                return Err(Error::Shape(format!(
                    "tile {i}: segment {seg} != padded nnz {}",
                    round_up(nnz, PAD)
                )));
            }
        }
        if *self.offsets.last().unwrap() as usize != self.values.len() {
            return Err(Error::Shape("values length mismatch".into()));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_pruned(tokens: usize, channels: usize, keep_prob: f32, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..tokens * channels)
            .map(|_| {
                if rng.unit_f32() < keep_prob {
                    rng.normal_f32()
                } else {
                    0.0
                }
            })
            .collect()
    }

    #[test]
    fn roundtrip_token_axis() {
        for &(t, d, p) in &[(64, 64, 0.5), (128, 32, 0.3), (192, 64, 0.05), (64, 8, 1.0)] {
            let dense = random_pruned(t, d, p, 42);
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
            m.validate().unwrap();
            assert_eq!(m.decompress(), dense, "t={t} d={d} p={p}");
        }
    }

    #[test]
    fn roundtrip_channel_axis() {
        for &(t, d, p) in &[(10, 64, 0.5), (100, 128, 0.3), (1, 64, 0.0), (7, 64, 1.0)] {
            let dense = random_pruned(t, d, p, 43);
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Channel).unwrap();
            m.validate().unwrap();
            assert_eq!(m.decompress(), dense, "t={t} d={d} p={p}");
        }
    }

    #[test]
    fn shape_errors() {
        let dense = vec![0.0; 63 * 64];
        assert!(BitmapMatrix::compress(&dense, 63, 64, PackAxis::Token).is_err());
        let dense = vec![0.0; 4 * 63];
        assert!(BitmapMatrix::compress(&dense, 4, 63, PackAxis::Channel).is_err());
        let dense = vec![0.0; 10];
        assert!(BitmapMatrix::compress(&dense, 64, 64, PackAxis::Token).is_err());
    }

    #[test]
    fn nnz_and_padding() {
        // one tile with 3 non-zeros -> padded segment of 8
        let mut dense = vec![0.0f32; 64 * 1];
        dense[0] = 1.0;
        dense[10] = 2.0;
        dense[63] = 3.0;
        let m = BitmapMatrix::compress(&dense, 64, 1, PackAxis::Token).unwrap();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.values.len(), 8);
        assert_eq!(m.offsets, vec![0, 8]);
        assert_eq!(m.bitmaps[0], (1u64 << 0) | (1 << 10) | (1 << 63));
    }

    #[test]
    fn accounting_matches_paper_shape() {
        // 50% sparsity with hd=128-like channels: compression rate should
        // land near the paper's ~0.65 (Fig 6b), 70% near ~0.45.
        let t = 1024;
        let d = 128;
        for &(sparsity, lo, hi) in &[(0.5, 0.60, 0.70), (0.7, 0.40, 0.50)] {
            let dense = random_pruned(t, d, 1.0 - sparsity, 7);
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
            let r = m.compression_rate();
            assert!(r > lo && r < hi, "sparsity {sparsity}: rate {r}");
        }
    }

    #[test]
    fn append_equals_full_compress_token_axis() {
        let d = 32;
        let dense = random_pruned(192, d, 0.4, 11);
        let full = BitmapMatrix::compress(&dense, 192, d, PackAxis::Token).unwrap();
        let mut inc = BitmapMatrix::compress(&dense[..64 * d], 64, d, PackAxis::Token).unwrap();
        inc.append_groups(&dense[64 * d..128 * d], 64).unwrap();
        inc.append_groups(&dense[128 * d..], 64).unwrap();
        assert_eq!(inc, full);
    }

    #[test]
    fn append_equals_full_compress_channel_axis() {
        let d = 64;
        let dense = random_pruned(100, d, 0.4, 12);
        let full = BitmapMatrix::compress(&dense, 100, d, PackAxis::Channel).unwrap();
        let mut inc = BitmapMatrix::compress(&dense[..60 * d], 60, d, PackAxis::Channel).unwrap();
        inc.append_groups(&dense[60 * d..], 40).unwrap();
        assert_eq!(inc, full);
    }

    #[test]
    fn empty_matrix() {
        let m = BitmapMatrix::empty(64, PackAxis::Channel);
        m.validate().unwrap();
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.compression_rate(), 0.0);
        assert!(m.decompress().is_empty());
    }

    #[test]
    fn property_roundtrip_random_patterns() {
        // Arbitrary sparsity patterns — the paper's whole point is that the
        // format supports *unstructured* sparsity, so test random masks.
        for seed in 0..20 {
            let mut rng = Pcg32::seeded(seed);
            let groups = 1 + rng.below(3) as usize;
            let t = groups * TILE;
            let d = [8, 16, 64][rng.below(3) as usize];
            let p = rng.unit_f32();
            let dense = random_pruned(t, d, p, seed + 1000);
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
            m.validate().unwrap();
            assert_eq!(m.decompress(), dense);
            let nnz_expected = dense.iter().filter(|x| **x != 0.0).count();
            assert_eq!(m.nnz(), nnz_expected);
        }
    }
}
