//! The paper's bitmap-based sparse format (Fig 5b, App. C).
//!
//! A pruned cache matrix `[tokens x channels]` is stored as 1x64 tiles:
//! each tile covers 64 consecutive elements along the *packing axis*, and
//! carries a 64-bit bitmap marking non-zero positions plus a tile offset
//! addressing its first non-zero in the packed value array. Per-tile value
//! segments are padded to a multiple of 8 ("coalescing" padding — the
//! paper's 15%-overhead source at 50% sparsity).
//!
//! Values are stored as real IEEE 754 binary16 (`u16` bit patterns,
//! `sparse::f16`), exactly as the paper's kernels do — the compressed
//! byte accounting below *is* the in-memory footprint, and the SpMV
//! kernels widen f16→f32 in-register while walking the stream.
//!
//! Packing-axis choice follows App. C: the tiling direction must be
//! orthogonal to the dimension being contracted, so
//!   * Key cache (contracted over channels in K·q)   -> `PackAxis::Token`
//!   * Value cache (contracted over tokens in αᵀ·V)  -> `PackAxis::Channel`
//!
//! Along the channel axis the trailing tile may be *partial*
//! (`head_dim % 64 != 0`): its bitmap simply never sets bits at or past
//! the block width. (The seed silently produced zero tiles for
//! `head_dim < 64`; see the regression tests below.)
//!
//! Tile *ordering* is chosen so that newly compressed 64-token groups
//! append at the end of every array (App. C requirement (2)); see
//! `layout.rs` for the traversal and the append path.

use crate::error::{Error, Result};
use crate::sparse::f16::{f16_to_f32, f32_to_f16};
use crate::util::round_up;

/// Tile extent along the packing axis (the paper's 1x64 tile).
pub const TILE: usize = 64;
/// Value-segment padding granularity (paper: multiples of 8).
pub const PAD: usize = 8;
/// Bytes per stored value — real binary16 storage, so this is the actual
/// in-memory size, not just the paper's accounting model.
pub const VALUE_BYTES: usize = std::mem::size_of::<u16>();
/// Bytes per tile bitmap.
pub const BITMAP_BYTES: usize = 8;
/// Bytes per tile offset.
pub const OFFSET_BYTES: usize = 4;

/// Which logical dimension the 1x64 tiles run along.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackAxis {
    /// Tiles span 64 tokens at a fixed channel (Key cache; Fig 9a).
    Token,
    /// Tiles span 64 channels of a fixed token (Value cache; Fig 9b).
    Channel,
}

/// A pruned `[tokens x channels]` matrix in the bitmap format.
#[derive(Clone, Debug, PartialEq)]
pub struct BitmapMatrix {
    pub tokens: usize,
    pub channels: usize,
    pub axis: PackAxis,
    /// Per-tile 64-bit occupancy bitmap, in `layout::tile_order`.
    pub bitmaps: Vec<u64>,
    /// Per-tile start offset into `values` (+ one trailing total-length entry).
    pub offsets: Vec<u32>,
    /// Packed non-zero values as binary16 bit patterns; each tile's
    /// segment padded to a multiple of 8.
    pub values: Vec<u16>,
}

impl BitmapMatrix {
    /// Number of tiles for a (tokens, channels, axis) geometry.
    pub fn n_tiles(tokens: usize, channels: usize, axis: PackAxis) -> usize {
        match axis {
            PackAxis::Token => tokens.div_ceil(TILE) * channels,
            PackAxis::Channel => channels.div_ceil(TILE) * tokens,
        }
    }

    /// Empty matrix with zero tokens.
    pub fn empty(channels: usize, axis: PackAxis) -> BitmapMatrix {
        BitmapMatrix {
            tokens: 0,
            channels,
            axis,
            bitmaps: Vec::new(),
            offsets: vec![0],
            values: Vec::new(),
        }
    }

    /// Compress a dense (already pruned — zeros are "pruned away") matrix,
    /// narrowing values to binary16.
    ///
    /// `dense` is row-major `[tokens x channels]`. For `PackAxis::Token`,
    /// `tokens` must be a multiple of 64 (the KV manager only compresses
    /// whole 64-token groups, matching the kernel's warp-tile granularity).
    /// `PackAxis::Channel` accepts any channel count — the trailing
    /// channel tile is partial when `channels % 64 != 0`.
    pub fn compress(
        dense: &[f32],
        tokens: usize,
        channels: usize,
        axis: PackAxis,
    ) -> Result<BitmapMatrix> {
        if dense.len() != tokens * channels {
            return Err(Error::Shape(format!(
                "dense len {} != {}x{}",
                dense.len(),
                tokens,
                channels
            )));
        }
        if axis == PackAxis::Token && tokens % TILE != 0 {
            return Err(Error::Shape(format!("tokens {tokens} not a multiple of {TILE}")));
        }

        let mut m = BitmapMatrix::empty(channels, axis);
        m.append_groups(dense, tokens)?;
        Ok(m)
    }

    /// Append `new_tokens` (a multiple of the group granularity) worth of
    /// dense rows to the compressed matrix. This is the paper's runtime
    /// compression path: 64-token groups exiting the local window are
    /// compressed and appended (App. C requirement (2)).
    ///
    /// A position is considered non-zero iff its binary16 narrowing is
    /// non-zero, so the bitmap always agrees with the stored stream
    /// (magnitudes below ~2^-25 underflow and are treated as pruned).
    pub fn append_groups(&mut self, dense: &[f32], new_tokens: usize) -> Result<()> {
        if dense.len() != new_tokens * self.channels {
            return Err(Error::Shape(format!(
                "append: dense len {} != {}x{}",
                dense.len(),
                new_tokens,
                self.channels
            )));
        }
        if self.axis == PackAxis::Token && new_tokens % TILE != 0 {
            return Err(Error::Shape(format!(
                "append: new_tokens {new_tokens} not a multiple of {TILE}"
            )));
        }

        let d = self.channels;
        let mut vals = [0u16; TILE];
        match self.axis {
            PackAxis::Token => {
                // groups of 64 tokens; within a group, one tile per channel
                for g in 0..new_tokens / TILE {
                    for c in 0..d {
                        let mut bm: u64 = 0;
                        let mut n = 0;
                        for b in 0..TILE {
                            let h = f32_to_f16(dense[(g * TILE + b) * d + c]);
                            if h & 0x7fff != 0 {
                                bm |= 1u64 << b;
                                vals[n] = h;
                                n += 1;
                            }
                        }
                        self.push_tile(bm, &vals[..n]);
                    }
                }
            }
            PackAxis::Channel => {
                // one tile per (token, 64-channel block), token-major; the
                // trailing block is partial when d % 64 != 0 (its bitmap
                // never sets bits at or beyond the block width).
                let cblocks = d.div_ceil(TILE);
                for t in 0..new_tokens {
                    for cb in 0..cblocks {
                        let width = TILE.min(d - cb * TILE);
                        let mut bm: u64 = 0;
                        let mut n = 0;
                        for b in 0..width {
                            let h = f32_to_f16(dense[t * d + cb * TILE + b]);
                            if h & 0x7fff != 0 {
                                bm |= 1u64 << b;
                                vals[n] = h;
                                n += 1;
                            }
                        }
                        self.push_tile(bm, &vals[..n]);
                    }
                }
            }
        }
        self.tokens += new_tokens;
        Ok(())
    }

    /// Structurally append another compressed matrix's tiles (same axis
    /// and channel geometry): bitmaps and padded value segments are
    /// copied verbatim, offsets rebased. Because tile order is
    /// append-friendly on both axes (App. C requirement (2)), the result
    /// is byte-identical to compressing the concatenated dense rows in
    /// one pass — the prefix-promotion path relies on this to merge
    /// `[shared prefix | private groups]` without a decompress round
    /// trip.
    pub fn append_compressed(&mut self, other: &BitmapMatrix) -> Result<()> {
        if other.axis != self.axis || other.channels != self.channels {
            return Err(Error::Shape(format!(
                "append_compressed: geometry mismatch ({:?}/{} vs {:?}/{})",
                self.axis, self.channels, other.axis, other.channels
            )));
        }
        if self.axis == PackAxis::Token && other.tokens % TILE != 0 {
            return Err(Error::Shape(format!(
                "append_compressed: other.tokens {} not a multiple of {TILE}",
                other.tokens
            )));
        }
        let base = *self.offsets.last().unwrap();
        self.bitmaps.extend_from_slice(&other.bitmaps);
        self.values.extend_from_slice(&other.values);
        self.offsets.extend(other.offsets[1..].iter().map(|&o| base + o));
        self.tokens += other.tokens;
        Ok(())
    }

    fn push_tile(&mut self, bitmap: u64, vals: &[u16]) {
        debug_assert_eq!(bitmap.count_ones() as usize, vals.len());
        self.bitmaps.push(bitmap);
        self.values.extend_from_slice(vals);
        // coalescing padding to a multiple of 8 values
        let padded = round_up(vals.len(), PAD);
        self.values.extend(std::iter::repeat(0u16).take(padded - vals.len()));
        let last = *self.offsets.last().unwrap();
        self.offsets.push(last + padded as u32);
    }

    /// Decompress to a dense row-major `[tokens x channels]` f32 matrix
    /// (each value widened from its stored binary16 form).
    pub fn decompress(&self) -> Vec<f32> {
        let d = self.channels;
        let mut out = vec![0.0f32; self.tokens * d];
        match self.axis {
            PackAxis::Token => {
                for (ti, &bm) in self.bitmaps.iter().enumerate() {
                    let g = ti / d;
                    let c = ti % d;
                    let mut off = self.offsets[ti] as usize;
                    let mut bits = bm;
                    while bits != 0 {
                        let b = bits.trailing_zeros() as usize;
                        out[(g * TILE + b) * d + c] = f16_to_f32(self.values[off]);
                        off += 1;
                        bits &= bits - 1;
                    }
                }
            }
            PackAxis::Channel => {
                let cblocks = d.div_ceil(TILE);
                for (ti, &bm) in self.bitmaps.iter().enumerate() {
                    let t = ti / cblocks;
                    let cb = ti % cblocks;
                    let mut off = self.offsets[ti] as usize;
                    let mut bits = bm;
                    while bits != 0 {
                        let b = bits.trailing_zeros() as usize;
                        out[t * d + cb * TILE + b] = f16_to_f32(self.values[off]);
                        off += 1;
                        bits &= bits - 1;
                    }
                }
            }
        }
        out
    }

    /// Number of stored non-zeros (excluding padding slots).
    pub fn nnz(&self) -> usize {
        self.bitmaps.iter().map(|b| b.count_ones() as usize).sum()
    }

    /// Compressed size in bytes. Since values are stored as real binary16
    /// this is the *actual* in-memory footprint (fp16 values incl.
    /// padding + u64 bitmaps + u32 tile offsets), which coincides with
    /// the paper's accounting model.
    pub fn compressed_bytes(&self) -> usize {
        std::mem::size_of_val(self.values.as_slice())
            + std::mem::size_of_val(self.bitmaps.as_slice())
            + (self.offsets.len() - 1) * OFFSET_BYTES
    }

    /// Dense size in bytes of the same matrix (fp16 storage).
    pub fn dense_bytes(&self) -> usize {
        self.tokens * self.channels * VALUE_BYTES
    }

    /// Compression rate = compressed / dense (the paper's Fig 6b metric;
    /// lower is better, dense = 1.0).
    pub fn compression_rate(&self) -> f64 {
        if self.tokens == 0 {
            return 0.0;
        }
        self.compressed_bytes() as f64 / self.dense_bytes() as f64
    }

    /// Internal consistency check (used by tests and debug assertions).
    pub fn validate(&self) -> Result<()> {
        let want_tiles = Self::n_tiles(self.tokens, self.channels, self.axis);
        if self.bitmaps.len() != want_tiles {
            return Err(Error::Shape(format!(
                "tile count {} != expected {}",
                self.bitmaps.len(),
                want_tiles
            )));
        }
        if self.offsets.len() != want_tiles + 1 {
            return Err(Error::Shape("offsets length mismatch".into()));
        }
        for (i, &bm) in self.bitmaps.iter().enumerate() {
            let seg = (self.offsets[i + 1] - self.offsets[i]) as usize;
            let nnz = bm.count_ones() as usize;
            if seg != round_up(nnz, PAD) {
                return Err(Error::Shape(format!(
                    "tile {i}: segment {seg} != padded nnz {}",
                    round_up(nnz, PAD)
                )));
            }
        }
        if *self.offsets.last().unwrap() as usize != self.values.len() {
            return Err(Error::Shape("values length mismatch".into()));
        }
        if self.axis == PackAxis::Channel && self.channels % TILE != 0 {
            // partial trailing tiles must stay within their block width
            let cblocks = self.channels.div_ceil(TILE);
            let width = self.channels - (cblocks - 1) * TILE; // 1..=63 here
            let legal = (1u64 << width) - 1;
            for t in 0..self.tokens {
                let bm = self.bitmaps[t * cblocks + cblocks - 1];
                if bm & !legal != 0 {
                    return Err(Error::Shape(format!(
                        "token {t}: partial tile sets bits beyond width {width}"
                    )));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::f16::f16_round_vec as f16_ref;
    use crate::util::Pcg32;

    fn random_pruned(tokens: usize, channels: usize, keep_prob: f32, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..tokens * channels)
            .map(|_| {
                if rng.unit_f32() < keep_prob {
                    rng.normal_f32()
                } else {
                    0.0
                }
            })
            .collect()
    }

    #[test]
    fn roundtrip_token_axis() {
        for &(t, d, p) in &[(64, 64, 0.5), (128, 32, 0.3), (192, 64, 0.05), (64, 8, 1.0)] {
            let dense = random_pruned(t, d, p, 42);
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
            m.validate().unwrap();
            assert_eq!(m.decompress(), f16_ref(&dense), "t={t} d={d} p={p}");
        }
    }

    #[test]
    fn roundtrip_channel_axis() {
        for &(t, d, p) in &[(10, 64, 0.5), (100, 128, 0.3), (1, 64, 0.0), (7, 64, 1.0)] {
            let dense = random_pruned(t, d, p, 43);
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Channel).unwrap();
            m.validate().unwrap();
            assert_eq!(m.decompress(), f16_ref(&dense), "t={t} d={d} p={p}");
        }
    }

    #[test]
    fn partial_channel_tiles_small_and_ragged_heads() {
        // Seed-bug regression: channel-packed matrices with
        // channels % 64 != 0 (notably head_dim < 64) must carry real
        // partial tiles instead of silently contributing nothing.
        for &(t, d, p) in &[(5, 32, 0.6), (16, 8, 0.5), (3, 96, 0.4), (7, 100, 0.7), (1, 1, 1.0)] {
            let dense = random_pruned(t, d, p, 77);
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Channel).unwrap();
            m.validate().unwrap();
            assert_eq!(m.bitmaps.len(), t * d.div_ceil(TILE), "t={t} d={d}");
            assert_eq!(m.decompress(), f16_ref(&dense), "t={t} d={d}");
            let nnz_expected = dense.iter().filter(|&&x| f32_to_f16(x) & 0x7fff != 0).count();
            assert_eq!(m.nnz(), nnz_expected, "t={t} d={d}");
        }
    }

    #[test]
    fn shape_errors() {
        let dense = vec![0.0; 63 * 64];
        assert!(BitmapMatrix::compress(&dense, 63, 64, PackAxis::Token).is_err());
        let dense = vec![0.0; 10];
        assert!(BitmapMatrix::compress(&dense, 64, 64, PackAxis::Token).is_err());
        // channel axis now accepts any channel count (partial tiles)
        let dense = vec![0.0; 4 * 63];
        assert!(BitmapMatrix::compress(&dense, 4, 63, PackAxis::Channel).is_ok());
    }

    #[test]
    fn nnz_and_padding() {
        // one tile with 3 non-zeros -> padded segment of 8
        let mut dense = vec![0.0f32; 64 * 1];
        dense[0] = 1.0;
        dense[10] = 2.0;
        dense[63] = 3.0;
        let m = BitmapMatrix::compress(&dense, 64, 1, PackAxis::Token).unwrap();
        assert_eq!(m.nnz(), 3);
        assert_eq!(m.values.len(), 8);
        assert_eq!(m.offsets, vec![0, 8]);
        assert_eq!(m.bitmaps[0], (1u64 << 0) | (1 << 10) | (1 << 63));
        // 1.0/2.0/3.0 are exactly representable in binary16
        assert_eq!(&m.values[..3], &[f32_to_f16(1.0), f32_to_f16(2.0), f32_to_f16(3.0)]);
    }

    #[test]
    fn compressed_bytes_is_actual_storage() {
        let dense = random_pruned(128, 48, 0.5, 9);
        let m = BitmapMatrix::compress(&dense, 128, 48, PackAxis::Token).unwrap();
        let actual = std::mem::size_of_val(m.values.as_slice())
            + std::mem::size_of_val(m.bitmaps.as_slice())
            + std::mem::size_of_val(&m.offsets.as_slice()[..m.offsets.len() - 1]);
        assert_eq!(m.compressed_bytes(), actual);
        // the load-bearing half of the claim: a stored value is 2 bytes
        assert_eq!(std::mem::size_of_val(&m.values[0]), 2);
        assert_eq!(m.compressed_bytes() % 2, 0);
    }

    #[test]
    fn accounting_matches_paper_shape() {
        // 50% sparsity with hd=128-like channels: compression rate should
        // land near the paper's ~0.65 (Fig 6b), 70% near ~0.45.
        let t = 1024;
        let d = 128;
        for &(sparsity, lo, hi) in &[(0.5, 0.60, 0.70), (0.7, 0.40, 0.50)] {
            let dense = random_pruned(t, d, 1.0 - sparsity, 7);
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
            let r = m.compression_rate();
            assert!(r > lo && r < hi, "sparsity {sparsity}: rate {r}");
        }
    }

    #[test]
    fn append_equals_full_compress_token_axis() {
        let d = 32;
        let dense = random_pruned(192, d, 0.4, 11);
        let full = BitmapMatrix::compress(&dense, 192, d, PackAxis::Token).unwrap();
        let mut inc = BitmapMatrix::compress(&dense[..64 * d], 64, d, PackAxis::Token).unwrap();
        inc.append_groups(&dense[64 * d..128 * d], 64).unwrap();
        inc.append_groups(&dense[128 * d..], 64).unwrap();
        assert_eq!(inc, full);
    }

    #[test]
    fn append_equals_full_compress_channel_axis() {
        for d in [32usize, 64, 96] {
            let dense = random_pruned(100, d, 0.4, 12);
            let full = BitmapMatrix::compress(&dense, 100, d, PackAxis::Channel).unwrap();
            let mut inc =
                BitmapMatrix::compress(&dense[..60 * d], 60, d, PackAxis::Channel).unwrap();
            inc.append_groups(&dense[60 * d..], 40).unwrap();
            assert_eq!(inc, full, "d={d}");
        }
    }

    #[test]
    fn append_compressed_equals_full_compress() {
        // structural concat == one-pass compression, bit for bit
        for &(axis, d) in &[
            (PackAxis::Token, 32usize),
            (PackAxis::Token, 64),
            (PackAxis::Channel, 32),
            (PackAxis::Channel, 96),
            (PackAxis::Channel, 100),
        ] {
            let (ta, tb) = match axis {
                PackAxis::Token => (128, 64),
                PackAxis::Channel => (37, 21),
            };
            let dense = random_pruned(ta + tb, d, 0.4, 17 + d as u64);
            let full = BitmapMatrix::compress(&dense, ta + tb, d, axis).unwrap();
            let mut a = BitmapMatrix::compress(&dense[..ta * d], ta, d, axis).unwrap();
            let b = BitmapMatrix::compress(&dense[ta * d..], tb, d, axis).unwrap();
            a.append_compressed(&b).unwrap();
            a.validate().unwrap();
            assert_eq!(a, full, "{axis:?} d={d}");
            // and onto an empty matrix it is the identity
            let mut e = BitmapMatrix::empty(d, axis);
            e.append_compressed(&full).unwrap();
            assert_eq!(e, full, "{axis:?} d={d} from empty");
        }
        // geometry mismatches are loud
        let m64 = BitmapMatrix::empty(64, PackAxis::Token);
        let mut m32 = BitmapMatrix::empty(32, PackAxis::Token);
        assert!(m32.append_compressed(&m64).is_err());
        let chan = BitmapMatrix::empty(32, PackAxis::Channel);
        assert!(m32.append_compressed(&chan).is_err());
    }

    #[test]
    fn empty_matrix() {
        let m = BitmapMatrix::empty(64, PackAxis::Channel);
        m.validate().unwrap();
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.compression_rate(), 0.0);
        assert!(m.decompress().is_empty());
    }

    #[test]
    fn property_roundtrip_random_patterns() {
        // Arbitrary sparsity patterns — the paper's whole point is that the
        // format supports *unstructured* sparsity, so test random masks.
        for seed in 0..20 {
            let mut rng = Pcg32::seeded(seed);
            let groups = 1 + rng.below(3) as usize;
            let t = groups * TILE;
            let d = [8, 16, 64][rng.below(3) as usize];
            let p = rng.unit_f32();
            let dense = random_pruned(t, d, p, seed + 1000);
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
            m.validate().unwrap();
            assert_eq!(m.decompress(), f16_ref(&dense));
            let nnz_expected = dense.iter().filter(|&&x| f32_to_f16(x) & 0x7fff != 0).count();
            assert_eq!(m.nnz(), nnz_expected);
        }
    }
}
