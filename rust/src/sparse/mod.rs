//! The paper's bitmap-based sparse format and the SpMV kernels over it
//! (§3, Fig 5, App. C).
//!
//! * `bitmap` — 1x64-tile compressed representation with per-tile u64
//!   bitmaps, tile offsets, and multiples-of-8 value padding; values are
//!   stored as real IEEE binary16 (`u16`).
//! * `f16` — hand-rolled f32↔binary16 conversion (round-to-nearest-even
//!   narrowing, exact multiply-trick widening) plus the feature-gated
//!   SIMD widening used by the tile kernels.
//! * `dispatch` — runtime-detected SIMD kernel table (scalar oracle,
//!   stable AVX2+FMA+F16C, nightly portable-SIMD, reserved NEON tier);
//!   every hot kernel routes through it on the default stable build.
//! * `spmv` — load-as-compressed/compute-as-dense matrix-vector products
//!   for the two decode-phase attention MVs, plus dense baselines generic
//!   over the stored element type (`KvElem`).
//! * `pairs` — the rectangular (values, indices) view used at the
//!   XLA/PJRT boundary (static shapes, f32 at the FFI surface).

pub mod bitmap;
pub mod dispatch;
pub mod f16;
pub mod pairs;
pub mod spmv;

pub use bitmap::{BitmapMatrix, PackAxis, PAD, TILE};
pub use dispatch::{kernels, Backend, KernelTable};
pub use f16::{f16_round, f16_to_f32, f32_to_f16, KvElem};
pub use pairs::TokenPairs;
pub use spmv::{
    dense_key, dense_key_multi, dense_key_multi_with, dense_key_with, dense_value,
    dense_value_multi, dense_value_multi_with, dense_value_with, spmv_key, spmv_key_multi,
    spmv_key_multi_with, spmv_key_with, spmv_value, spmv_value_multi, spmv_value_multi_with,
    spmv_value_with, MAX_GROUP,
};
