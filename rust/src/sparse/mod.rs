//! The paper's bitmap-based sparse format and the SpMV kernels over it
//! (§3, Fig 5, App. C).
//!
//! * `bitmap` — 1x64-tile compressed representation with per-tile u64
//!   bitmaps, tile offsets, and multiples-of-8 value padding.
//! * `spmv` — load-as-compressed/compute-as-dense matrix-vector products
//!   for the two decode-phase attention MVs, plus dense baselines.
//! * `pairs` — the rectangular (values, indices) view used at the
//!   XLA/PJRT boundary (static shapes).

pub mod bitmap;
pub mod pairs;
pub mod spmv;

pub use bitmap::{BitmapMatrix, PackAxis, PAD, TILE};
pub use pairs::TokenPairs;
pub use spmv::{
    dense_key, dense_key_multi, dense_value, dense_value_multi, spmv_key, spmv_key_multi,
    spmv_value, spmv_value_multi, MAX_GROUP,
};
