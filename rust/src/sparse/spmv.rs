//! SpMV over the bitmap format — the Mustafar attention hot path.
//!
//! Two flavors mirror the two decode-phase MVs (Fig 5a):
//!   * `spmv_key`:  scores[t] = Σ_c K[t,c]·q[c]   (Key × Queryᵀ)
//!   * `spmv_value`: out[c]   = Σ_t α[t]·V[t,c]   (AttentionScore × Value)
//!
//! Both follow the paper's *load-as-compressed, compute-as-dense* paradigm:
//! the packed value stream is walked sequentially (that is the bandwidth
//! win — only compressed bytes are touched), with the bitmap steering
//! accumulation into the right output lane. The stream is real binary16
//! (`sparse::f16`), widened to f32 in-register on the fly, so the bytes
//! walked are genuinely half of an f32 stream.
//!
//! Dense reference MVs (`dense_key`, `dense_value`) play the cuBLAS-
//! baseline role of Fig 6a. They are generic over `KvElem`, serving both
//! full-precision prefill buffers (`f32`) and the f16 dense tail (`u16`).
//!
//! The 64-wide dense-tile sweeps, the expand-then-FMA sweeps, and the
//! dense-row dot/FMA loops all route through the **runtime dispatch
//! table** (`sparse::dispatch`): the default stable build reaches
//! AVX2+FMA+F16C intrinsics on hardware that has them, the nightly
//! `simd` feature supplies the portable tier, and the scalar path — the
//! bit-exact parity oracle — always exists. Every kernel has a `*_with`
//! variant taking an explicit `KernelTable` so tests and benches can pin
//! a tier; the plain names use the process-wide detected table.

use super::bitmap::{BitmapMatrix, PackAxis, TILE};
use super::dispatch::{kernels, KernelTable};
use super::f16::{f16_to_f32, KvElem};

// §Perf note: a byte-LUT decode (table of set-bit positions per byte) was
// tried and REGRESSED ~4x vs the tzcnt bit-walk on this CPU (indirect
// table loads + data-dependent inner loops beat by hardware tzcnt);
// recorded in EXPERIMENTS.md §Perf iteration log.

// ---------------------------------------------------------------------------
// Single-query kernels.
// ---------------------------------------------------------------------------

/// scores[t] = Σ_c K[t,c]·q[c] for a Key cache packed along `PackAxis::Token`.
///
/// `scores` must have length `k.tokens` and is *accumulated into* (callers
/// zero it or seed it with the local-window contribution separately).
pub fn spmv_key(k: &BitmapMatrix, q: &[f32], scores: &mut [f32]) {
    spmv_key_with(kernels(), k, q, scores)
}

/// `spmv_key` through an explicit kernel table.
pub fn spmv_key_with(kt: &KernelTable, k: &BitmapMatrix, q: &[f32], scores: &mut [f32]) {
    assert_eq!(k.axis, PackAxis::Token, "key cache must be token-packed");
    assert_eq!(q.len(), k.channels);
    assert_eq!(scores.len(), k.tokens);

    let d = k.channels;
    let values = &k.values[..];
    // Tile order: token-group-major, channel-minor (layout in bitmap.rs).
    // All tiles of group g write into scores[g*64 .. g*64+64].
    for g in 0..k.tokens / TILE {
        let out = &mut scores[g * TILE..(g + 1) * TILE];
        let tile_base = g * d;
        for c in 0..d {
            let ti = tile_base + c;
            let bits = k.bitmaps[ti];
            if bits == 0 {
                continue;
            }
            let qc = q[c];
            let mut off = k.offsets[ti] as usize;
            if bits == u64::MAX {
                // dense tile fast path: one 64-wide widening FMA
                (kt.fma_f16)(out, &values[off..off + TILE], qc);
                continue;
            }
            // bit-walk decode (tzcnt); bounds hoisted — `validate()`
            // guarantees offsets stay within `values`.
            let mut bits = bits;
            unsafe {
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    *out.get_unchecked_mut(b) += f16_to_f32(*values.get_unchecked(off)) * qc;
                    off += 1;
                    bits &= bits - 1;
                }
            }
        }
    }
}

/// out[c] = Σ_t α[t]·V[t,c] for a Value cache packed along `PackAxis::Channel`.
///
/// `out` must have length `v.channels` and is accumulated into. The
/// trailing channel block may be partial (`channels % 64 != 0`).
pub fn spmv_value(v: &BitmapMatrix, att: &[f32], out: &mut [f32]) {
    spmv_value_with(kernels(), v, att, out)
}

/// `spmv_value` through an explicit kernel table.
pub fn spmv_value_with(kt: &KernelTable, v: &BitmapMatrix, att: &[f32], out: &mut [f32]) {
    assert_eq!(v.axis, PackAxis::Channel, "value cache must be channel-packed");
    assert_eq!(att.len(), v.tokens);
    assert_eq!(out.len(), v.channels);

    let d = v.channels;
    let cblocks = d.div_ceil(TILE);
    let values = &v.values[..];
    for t in 0..v.tokens {
        let at = att[t];
        if at == 0.0 {
            continue;
        }
        for cb in 0..cblocks {
            let ti = t * cblocks + cb;
            let bits = v.bitmaps[ti];
            if bits == 0 {
                continue;
            }
            let mut off = v.offsets[ti] as usize;
            let out_block = &mut out[cb * TILE..(cb * TILE + TILE).min(d)];
            if bits == u64::MAX {
                // only possible for full-width blocks
                (kt.fma_f16)(out_block, &values[off..off + TILE], at);
                continue;
            }
            // expand-then-FMA ("compute-as-dense", Fig 8): scatter the
            // compressed tile into a stack buffer with plain stores, then
            // one vectorizable 64-wide FMA — breaks the load-add-store
            // dependency chain of a scattered accumulate.
            let mut buf = [0.0f32; TILE];
            let mut bits = bits;
            unsafe {
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    *buf.get_unchecked_mut(b) = f16_to_f32(*values.get_unchecked(off));
                    off += 1;
                    bits &= bits - 1;
                }
            }
            let w = out_block.len();
            (kt.fma_f32)(out_block, &buf[..w], at);
        }
    }
}

/// Dense MV baseline: scores[t] = Σ_c K[t,c]·q[c] (row-major K [T x D],
/// f32 or stored-f16 elements).
pub fn dense_key<E: KvElem>(
    k: &[E],
    tokens: usize,
    channels: usize,
    q: &[f32],
    scores: &mut [f32],
) {
    dense_key_with(kernels(), k, tokens, channels, q, scores)
}

/// `dense_key` through an explicit kernel table.
pub fn dense_key_with<E: KvElem>(
    kt: &KernelTable,
    k: &[E],
    tokens: usize,
    channels: usize,
    q: &[f32],
    scores: &mut [f32],
) {
    assert_eq!(k.len(), tokens * channels);
    assert_eq!(q.len(), channels);
    assert_eq!(scores.len(), tokens);
    for t in 0..tokens {
        let row = &k[t * channels..(t + 1) * channels];
        scores[t] += E::dot(kt, row, q);
    }
}

/// Dense MV baseline: out[c] = Σ_t α[t]·V[t,c] (row-major V [T x D],
/// f32 or stored-f16 elements).
pub fn dense_value<E: KvElem>(
    v: &[E],
    tokens: usize,
    channels: usize,
    att: &[f32],
    out: &mut [f32],
) {
    dense_value_with(kernels(), v, tokens, channels, att, out)
}

/// `dense_value` through an explicit kernel table.
pub fn dense_value_with<E: KvElem>(
    kt: &KernelTable,
    v: &[E],
    tokens: usize,
    channels: usize,
    att: &[f32],
    out: &mut [f32],
) {
    assert_eq!(v.len(), tokens * channels);
    assert_eq!(att.len(), tokens);
    assert_eq!(out.len(), channels);
    for t in 0..tokens {
        let at = att[t];
        if at == 0.0 {
            continue;
        }
        let row = &v[t * channels..(t + 1) * channels];
        E::fma_row(kt, out, row, at);
    }
}

// ---------------------------------------------------------------------------
// Fused GQA multi-query kernels.
//
// Under grouped-query attention, `G = n_heads / n_kv_heads` query heads
// share one KV head. The single-lane kernels above force the caller to
// re-walk the compressed stream G times per token; since the decode SpMV
// is memory-bound (Fig 5a/6a), that throws away the format's bandwidth
// win. The `_multi` kernels below walk each tile's bitmap + packed
// values exactly once and FMA the decoded tile into all G lanes.
//
// Lane layouts are flat: queries `[G x channels]`, scores `[G x tokens]`,
// outputs `[G x channels]`. Per lane, the floating-point operation order
// is identical to the corresponding single-lane kernel, so results are
// bit-exact against G independent single-lane calls (tested below).
// ---------------------------------------------------------------------------

/// Maximum GQA group size the fused kernels accept (stack-buffer bound;
/// real models use 4–8 queries per KV head).
pub const MAX_GROUP: usize = 16;

/// Multi-query `spmv_key`: scores[l*tokens + t] += Σ_c K[t,c]·q[l*channels + c]
/// for `g` query lanes, walking the compressed Key stream once.
pub fn spmv_key_multi(k: &BitmapMatrix, qs: &[f32], g: usize, scores: &mut [f32]) {
    spmv_key_multi_with(kernels(), k, qs, g, scores)
}

/// `spmv_key_multi` through an explicit kernel table.
pub fn spmv_key_multi_with(
    kt: &KernelTable,
    k: &BitmapMatrix,
    qs: &[f32],
    g: usize,
    scores: &mut [f32],
) {
    assert_eq!(k.axis, PackAxis::Token, "key cache must be token-packed");
    assert!(g >= 1 && g <= MAX_GROUP, "group size {g} out of range");
    assert_eq!(qs.len(), g * k.channels);
    assert_eq!(scores.len(), g * k.tokens);

    let d = k.channels;
    let nt = k.tokens;
    let values = &k.values[..];
    for gt in 0..nt / TILE {
        let base = gt * TILE;
        let tile_base = gt * d;
        for c in 0..d {
            let ti = tile_base + c;
            let bits = k.bitmaps[ti];
            if bits == 0 {
                continue;
            }
            // hoist the G query weights for this channel
            let mut qc = [0.0f32; MAX_GROUP];
            for (l, slot) in qc[..g].iter_mut().enumerate() {
                *slot = qs[l * d + c];
            }
            let mut off = k.offsets[ti] as usize;
            if bits == u64::MAX {
                // dense tile fast path: per lane, one 64-wide widening FMA
                for (l, &w) in qc[..g].iter().enumerate() {
                    let out = &mut scores[l * nt + base..l * nt + base + TILE];
                    (kt.fma_f16)(out, &values[off..off + TILE], w);
                }
                continue;
            }
            // single bit-walk; each decoded value feeds all G lanes
            let mut bits = bits;
            unsafe {
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    let v = f16_to_f32(*values.get_unchecked(off));
                    for (l, &w) in qc[..g].iter().enumerate() {
                        *scores.get_unchecked_mut(l * nt + base + b) += v * w;
                    }
                    off += 1;
                    bits &= bits - 1;
                }
            }
        }
    }
}

/// Multi-query `spmv_value`: out[l*channels + c] += Σ_t α[l*tokens + t]·V[t,c]
/// for `g` attention lanes, walking the compressed Value stream once.
/// Each partial tile is scattered into a stack buffer once and then FMA'd
/// into every lane (amortizing the decode across the GQA group).
pub fn spmv_value_multi(v: &BitmapMatrix, att: &[f32], g: usize, out: &mut [f32]) {
    spmv_value_multi_with(kernels(), v, att, g, out)
}

/// `spmv_value_multi` through an explicit kernel table.
pub fn spmv_value_multi_with(
    kt: &KernelTable,
    v: &BitmapMatrix,
    att: &[f32],
    g: usize,
    out: &mut [f32],
) {
    assert_eq!(v.axis, PackAxis::Channel, "value cache must be channel-packed");
    assert!(g >= 1 && g <= MAX_GROUP, "group size {g} out of range");
    assert_eq!(att.len(), g * v.tokens);
    assert_eq!(out.len(), g * v.channels);

    let d = v.channels;
    let cblocks = d.div_ceil(TILE);
    let nt = v.tokens;
    let values = &v.values[..];
    for t in 0..nt {
        let mut ats = [0.0f32; MAX_GROUP];
        let mut any = false;
        for (l, slot) in ats[..g].iter_mut().enumerate() {
            let a = att[l * nt + t];
            *slot = a;
            any |= a != 0.0;
        }
        if !any {
            continue;
        }
        for cb in 0..cblocks {
            let ti = t * cblocks + cb;
            let bits = v.bitmaps[ti];
            if bits == 0 {
                continue;
            }
            let blk = cb * TILE..(cb * TILE + TILE).min(d);
            let mut off = v.offsets[ti] as usize;
            if bits == u64::MAX {
                let seg = &values[off..off + TILE];
                for (l, &at) in ats[..g].iter().enumerate() {
                    if at == 0.0 {
                        continue;
                    }
                    let ob = &mut out[l * d + blk.start..l * d + blk.end];
                    (kt.fma_f16)(ob, seg, at);
                }
                continue;
            }
            // expand once ("compute-as-dense", Fig 8), FMA per lane
            let mut buf = [0.0f32; TILE];
            let mut bits = bits;
            unsafe {
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    *buf.get_unchecked_mut(b) = f16_to_f32(*values.get_unchecked(off));
                    off += 1;
                    bits &= bits - 1;
                }
            }
            let width = blk.end - blk.start;
            for (l, &at) in ats[..g].iter().enumerate() {
                if at == 0.0 {
                    continue;
                }
                let ob = &mut out[l * d + blk.start..l * d + blk.end];
                (kt.fma_f32)(ob, &buf[..width], at);
            }
        }
    }
}

/// Multi-query dense Key MV for the local-window tail: each K row is read
/// once and dotted against all `g` query lanes.
pub fn dense_key_multi<E: KvElem>(
    k: &[E],
    tokens: usize,
    channels: usize,
    qs: &[f32],
    g: usize,
    scores: &mut [f32],
) {
    dense_key_multi_with(kernels(), k, tokens, channels, qs, g, scores)
}

/// `dense_key_multi` through an explicit kernel table.
pub fn dense_key_multi_with<E: KvElem>(
    kt: &KernelTable,
    k: &[E],
    tokens: usize,
    channels: usize,
    qs: &[f32],
    g: usize,
    scores: &mut [f32],
) {
    assert_eq!(k.len(), tokens * channels);
    assert!(g >= 1 && g <= MAX_GROUP, "group size {g} out of range");
    assert_eq!(qs.len(), g * channels);
    assert_eq!(scores.len(), g * tokens);
    for t in 0..tokens {
        let row = &k[t * channels..(t + 1) * channels];
        for l in 0..g {
            let q = &qs[l * channels..(l + 1) * channels];
            scores[l * tokens + t] += E::dot(kt, row, q);
        }
    }
}

/// Multi-query dense Value MV for the local-window tail: each V row is
/// read once and accumulated into all `g` output lanes.
pub fn dense_value_multi<E: KvElem>(
    v: &[E],
    tokens: usize,
    channels: usize,
    att: &[f32],
    g: usize,
    out: &mut [f32],
) {
    dense_value_multi_with(kernels(), v, tokens, channels, att, g, out)
}

/// `dense_value_multi` through an explicit kernel table.
pub fn dense_value_multi_with<E: KvElem>(
    kt: &KernelTable,
    v: &[E],
    tokens: usize,
    channels: usize,
    att: &[f32],
    g: usize,
    out: &mut [f32],
) {
    assert_eq!(v.len(), tokens * channels);
    assert!(g >= 1 && g <= MAX_GROUP, "group size {g} out of range");
    assert_eq!(att.len(), g * tokens);
    assert_eq!(out.len(), g * channels);
    for t in 0..tokens {
        let row = &v[t * channels..(t + 1) * channels];
        for l in 0..g {
            let at = att[l * tokens + t];
            if at == 0.0 {
                continue;
            }
            let ob = &mut out[l * channels..(l + 1) * channels];
            E::fma_row(kt, ob, row, at);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::dispatch;
    use crate::sparse::f16::f16_round_vec as f16_ref;
    use crate::util::Pcg32;

    fn random_pruned(tokens: usize, channels: usize, keep: f32, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..tokens * channels)
            .map(|_| if rng.unit_f32() < keep { rng.normal_f32() } else { 0.0 })
            .collect()
    }

    #[test]
    fn spmv_key_matches_dense() {
        // dense reference over the f16-rounded matrix: identical stored
        // values, different summation order -> tight tolerance.
        for seed in 0..10 {
            let mut rng = Pcg32::seeded(seed + 500);
            let t = TILE * (1 + rng.below(4) as usize);
            let d = [16, 64, 128][rng.below(3) as usize];
            let dense = random_pruned(t, d, 0.3 + 0.5 * rng.unit_f32(), seed);
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();

            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
            let mut got = vec![0.0f32; t];
            spmv_key(&m, &q, &mut got);

            let mut want = vec![0.0f32; t];
            dense_key(&f16_ref(&dense), t, d, &q, &mut want);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "seed {seed}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn spmv_value_matches_dense() {
        for seed in 0..10 {
            let mut rng = Pcg32::seeded(seed + 900);
            let t = 1 + rng.below(300) as usize;
            let d = TILE * (1 + rng.below(2) as usize);
            let dense = random_pruned(t, d, 0.3 + 0.5 * rng.unit_f32(), seed);
            let att: Vec<f32> = (0..t).map(|_| rng.unit_f32()).collect();

            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Channel).unwrap();
            let mut got = vec![0.0f32; d];
            spmv_value(&m, &att, &mut got);

            let mut want = vec![0.0f32; d];
            dense_value(&f16_ref(&dense), t, d, &att, &mut want);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "seed {seed}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn spmv_value_partial_channel_blocks_match_dense() {
        // channels % 64 != 0 (incl. head_dim < 64) — the seed-bug shapes.
        for &(t, d) in &[(20, 32), (9, 8), (33, 96), (5, 100)] {
            let dense = random_pruned(t, d, 0.6, t as u64 * 131 + d as u64);
            let mut rng = Pcg32::seeded(d as u64);
            let att: Vec<f32> = (0..t).map(|_| rng.unit_f32()).collect();
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Channel).unwrap();
            let mut got = vec![0.0f32; d];
            spmv_value(&m, &att, &mut got);
            let mut want = vec![0.0f32; d];
            dense_value(&f16_ref(&dense), t, d, &att, &mut want);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "t={t} d={d}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn f16_kernels_within_tolerance_of_f32_reference() {
        // Acceptance property: against the *unrounded* f32 reference
        // kernels, the f16 storage path stays within 1e-2 relative error
        // (L2 over the output vector) across sparsity 0.3–0.9.
        let l2 = |xs: &[f32]| xs.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        for (i, &s) in [0.3f32, 0.5, 0.7, 0.9].iter().enumerate() {
            let mut rng = Pcg32::seeded(6000 + i as u64);
            let (t, d) = (4 * TILE, 128);
            let dense = random_pruned(t, d, 1.0 - s, 6100 + i as u64);
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let att: Vec<f32> = (0..t).map(|_| rng.unit_f32()).collect();

            let kc = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
            let mut got_k = vec![0.0f32; t];
            spmv_key(&kc, &q, &mut got_k);
            let mut ref_k = vec![0.0f32; t];
            dense_key(&dense, t, d, &q, &mut ref_k);
            let err: Vec<f32> = got_k.iter().zip(&ref_k).map(|(a, b)| a - b).collect();
            let rel = l2(&err) / l2(&ref_k).max(1e-12);
            assert!(rel <= 1e-2, "key sparsity {s}: rel {rel}");

            let vc = BitmapMatrix::compress(&dense, t, d, PackAxis::Channel).unwrap();
            let mut got_v = vec![0.0f32; d];
            spmv_value(&vc, &att, &mut got_v);
            let mut ref_v = vec![0.0f32; d];
            dense_value(&dense, t, d, &att, &mut ref_v);
            let err: Vec<f32> = got_v.iter().zip(&ref_v).map(|(a, b)| a - b).collect();
            let rel = l2(&err) / l2(&ref_v).max(1e-12);
            assert!(rel <= 1e-2, "value sparsity {s}: rel {rel}");
        }
    }

    #[test]
    fn spmv_accumulates() {
        let d = 64;
        let dense = random_pruned(TILE, d, 0.5, 1);
        let m = BitmapMatrix::compress(&dense, TILE, d, PackAxis::Token).unwrap();
        let q = vec![1.0f32; d];
        let mut scores = vec![10.0f32; TILE];
        spmv_key(&m, &q, &mut scores);
        let mut base = vec![0.0f32; TILE];
        spmv_key(&m, &q, &mut base);
        for (s, b) in scores.iter().zip(&base) {
            assert!((s - (b + 10.0)).abs() < 1e-5);
        }
    }

    #[test]
    fn empty_bitmap_is_noop() {
        let m = BitmapMatrix::compress(&vec![0.0; TILE * 8], TILE, 8, PackAxis::Token).unwrap();
        let mut scores = vec![0.0f32; TILE];
        spmv_key(&m, &[1.0; 8], &mut scores);
        assert!(scores.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn spmv_key_multi_bitexact_vs_single_lane() {
        // property: on random unstructured masks, the fused kernel must be
        // bit-for-bit identical to G independent single-lane calls.
        for seed in 0..20 {
            let mut rng = Pcg32::seeded(seed + 3000);
            let t = TILE * (1 + rng.below(4) as usize);
            let d = [16, 64, 128][rng.below(3) as usize];
            let g = [1, 2, 4, 8][rng.below(4) as usize];
            // include fully-dense tiles sometimes to hit the fast path
            let keep = if seed % 5 == 0 { 1.0 } else { 0.1 + 0.8 * rng.unit_f32() };
            let dense = random_pruned(t, d, keep, seed);
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
            let qs: Vec<f32> = (0..g * d).map(|_| rng.normal_f32()).collect();

            let mut fused = vec![0.0f32; g * t];
            spmv_key_multi(&m, &qs, g, &mut fused);

            for l in 0..g {
                let mut lane = vec![0.0f32; t];
                spmv_key(&m, &qs[l * d..(l + 1) * d], &mut lane);
                assert_eq!(&fused[l * t..(l + 1) * t], &lane[..], "seed {seed} lane {l}");
            }
        }
    }

    #[test]
    fn spmv_value_multi_bitexact_vs_single_lane() {
        for seed in 0..20 {
            let mut rng = Pcg32::seeded(seed + 4000);
            let t = 1 + rng.below(300) as usize;
            // include partial trailing channel blocks (d % 64 != 0)
            let d = [32, 64, 96, 128][rng.below(4) as usize];
            let g = [1, 2, 4, 8][rng.below(4) as usize];
            let keep = if seed % 5 == 0 { 1.0 } else { 0.1 + 0.8 * rng.unit_f32() };
            let dense = random_pruned(t, d, keep, seed);
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Channel).unwrap();
            // include exact zeros in some lanes to hit the skip path
            let att: Vec<f32> = (0..g * t)
                .map(|i| if i % 7 == 0 { 0.0 } else { rng.unit_f32() })
                .collect();

            let mut fused = vec![0.0f32; g * d];
            spmv_value_multi(&m, &att, g, &mut fused);

            for l in 0..g {
                let mut lane = vec![0.0f32; d];
                spmv_value(&m, &att[l * t..(l + 1) * t], &mut lane);
                assert_eq!(&fused[l * d..(l + 1) * d], &lane[..], "seed {seed} lane {l}");
            }
        }
    }

    #[test]
    fn dense_multi_bitexact_vs_single_lane() {
        for seed in 0..10 {
            let mut rng = Pcg32::seeded(seed + 5000);
            let t = 1 + rng.below(100) as usize;
            let d = [16, 32, 64][rng.below(3) as usize];
            let g = [1, 3, 4, 8][rng.below(4) as usize];
            // exercise the E = u16 instantiation (the f16 dense tail)
            let mat: Vec<u16> =
                (0..t * d).map(|_| crate::sparse::f16::f32_to_f16(rng.normal_f32())).collect();
            let qs: Vec<f32> = (0..g * d).map(|_| rng.normal_f32()).collect();
            let att: Vec<f32> = (0..g * t)
                .map(|i| if i % 5 == 0 { 0.0 } else { rng.normal_f32() })
                .collect();

            let mut sk = vec![0.0f32; g * t];
            dense_key_multi(&mat, t, d, &qs, g, &mut sk);
            let mut ov = vec![0.0f32; g * d];
            dense_value_multi(&mat, t, d, &att, g, &mut ov);

            for l in 0..g {
                let mut lane_s = vec![0.0f32; t];
                dense_key(&mat, t, d, &qs[l * d..(l + 1) * d], &mut lane_s);
                assert_eq!(&sk[l * t..(l + 1) * t], &lane_s[..], "key seed {seed} lane {l}");

                let mut lane_o = vec![0.0f32; d];
                dense_value(&mat, t, d, &att[l * t..(l + 1) * t], &mut lane_o);
                assert_eq!(&ov[l * d..(l + 1) * d], &lane_o[..], "val seed {seed} lane {l}");
            }
        }
    }

    #[test]
    fn multi_kernels_accumulate() {
        let d = 64;
        let dense = random_pruned(TILE, d, 0.5, 77);
        let m = BitmapMatrix::compress(&dense, TILE, d, PackAxis::Token).unwrap();
        let qs = vec![1.0f32; 2 * d];
        let mut scores = vec![5.0f32; 2 * TILE];
        spmv_key_multi(&m, &qs, 2, &mut scores);
        let mut base = vec![0.0f32; 2 * TILE];
        spmv_key_multi(&m, &qs, 2, &mut base);
        for (s, b) in scores.iter().zip(&base) {
            assert!((s - (b + 5.0)).abs() < 1e-5);
        }
    }

    /// Satellite acceptance: every kernel through every available
    /// dispatch tier (scalar oracle, portable-SIMD when the feature is
    /// on, AVX2/F16C when the CPU has it) must produce bit-identical
    /// outputs — across partial channel tiles (`head_dim = 32`), ragged
    /// group counts, and `MAX_GROUP` lane chunking. The forced-scalar
    /// env override is exercised by the CI leg that reruns the whole
    /// suite under `MUSTAFAR_FORCE_SCALAR=1` (and by the unit tests on
    /// `dispatch::select`).
    #[test]
    fn dispatch_parity_all_backends_all_kernels() {
        let sc = dispatch::KernelTable::scalar();
        let tiers: Vec<_> = dispatch::available()
            .into_iter()
            .filter(|t| t.backend != dispatch::Backend::Scalar)
            .collect();
        for kt in &tiers {
            for seed in 0..8u64 {
                let mut rng = Pcg32::seeded(seed + 7700);
                // ragged group counts and partial channel tiles
                let groups = 1 + rng.below(4) as usize;
                let t = TILE * groups;
                let d = [32usize, 64, 100, 128][rng.below(4) as usize];
                let g = [1usize, 3, MAX_GROUP][rng.below(3) as usize];
                let keep = if seed % 4 == 0 { 1.0 } else { 0.1 + 0.8 * rng.unit_f32() };
                let dense = random_pruned(t, d, keep, seed + 7800);
                let qs: Vec<f32> = (0..g * d).map(|_| rng.normal_f32()).collect();
                let att: Vec<f32> = (0..g * t)
                    .map(|i| if i % 9 == 0 { 0.0 } else { rng.unit_f32() })
                    .collect();
                let tail: Vec<u16> =
                    (0..t * d).map(|_| crate::sparse::f16::f32_to_f16(rng.normal_f32())).collect();

                let km = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
                let vm = BitmapMatrix::compress(&dense, t, d, PackAxis::Channel).unwrap();
                let ctx = format!("{:?} seed {seed} t={t} d={d} g={g}", kt.backend);

                let mut a = vec![0.0f32; t];
                let mut b = vec![0.0f32; t];
                spmv_key_with(kt, &km, &qs[..d], &mut a);
                spmv_key_with(&sc, &km, &qs[..d], &mut b);
                assert_eq!(a, b, "spmv_key {ctx}");

                let mut a = vec![0.0f32; d];
                let mut b = vec![0.0f32; d];
                spmv_value_with(kt, &vm, &att[..t], &mut a);
                spmv_value_with(&sc, &vm, &att[..t], &mut b);
                assert_eq!(a, b, "spmv_value {ctx}");

                let mut a = vec![0.0f32; g * t];
                let mut b = vec![0.0f32; g * t];
                spmv_key_multi_with(kt, &km, &qs, g, &mut a);
                spmv_key_multi_with(&sc, &km, &qs, g, &mut b);
                assert_eq!(a, b, "spmv_key_multi {ctx}");

                let mut a = vec![0.0f32; g * d];
                let mut b = vec![0.0f32; g * d];
                spmv_value_multi_with(kt, &vm, &att, g, &mut a);
                spmv_value_multi_with(&sc, &vm, &att, g, &mut b);
                assert_eq!(a, b, "spmv_value_multi {ctx}");

                let mut a = vec![0.0f32; t];
                let mut b = vec![0.0f32; t];
                dense_key_with(kt, &tail, t, d, &qs[..d], &mut a);
                dense_key_with(&sc, &tail, t, d, &qs[..d], &mut b);
                assert_eq!(a, b, "dense_key(u16) {ctx}");

                let mut a = vec![0.0f32; d];
                let mut b = vec![0.0f32; d];
                dense_value_with(kt, &dense, t, d, &att[..t], &mut a);
                dense_value_with(&sc, &dense, t, d, &att[..t], &mut b);
                assert_eq!(a, b, "dense_value(f32) {ctx}");

                let mut a = vec![0.0f32; g * t];
                let mut b = vec![0.0f32; g * t];
                dense_key_multi_with(kt, &dense, t, d, &qs, g, &mut a);
                dense_key_multi_with(&sc, &dense, t, d, &qs, g, &mut b);
                assert_eq!(a, b, "dense_key_multi(f32) {ctx}");

                let mut a = vec![0.0f32; g * d];
                let mut b = vec![0.0f32; g * d];
                dense_value_multi_with(kt, &tail, t, d, &att, g, &mut a);
                dense_value_multi_with(&sc, &tail, t, d, &att, g, &mut b);
                assert_eq!(a, b, "dense_value_multi(u16) {ctx}");
            }
        }
    }
}
