//! SpMV over the bitmap format — the Mustafar attention hot path.
//!
//! Two flavors mirror the two decode-phase MVs (Fig 5a):
//!   * `spmv_key`:  scores[t] = Σ_c K[t,c]·q[c]   (Key × Queryᵀ)
//!   * `spmv_value`: out[c]   = Σ_t α[t]·V[t,c]   (AttentionScore × Value)
//!
//! Both follow the paper's *load-as-compressed, compute-as-dense* paradigm:
//! the packed value stream is walked sequentially (that is the bandwidth
//! win — only compressed bytes are touched), with the bitmap steering
//! accumulation into the right output lane. The stream is real binary16
//! (`sparse::f16`), widened to f32 in-register on the fly, so the bytes
//! walked are genuinely half of an f32 stream.
//!
//! Dense reference MVs (`dense_key`, `dense_value`) play the cuBLAS-
//! baseline role of Fig 6a. They are generic over `KvElem`, serving both
//! full-precision prefill buffers (`f32`) and the f16 dense tail (`u16`).
//!
//! The 64-wide dense-tile and expand-then-FMA sweeps have explicit SIMD
//! widening-FMA paths (`std::simd` behind the `simd` cargo feature,
//! nightly only); the scalar fallback is always compiled and doubles as
//! the parity oracle — per output element both paths perform the
//! identical `acc += widen(v) * w`, and the f16 widening itself is exact,
//! so SIMD and scalar results are bit-for-bit equal.

use super::bitmap::{BitmapMatrix, PackAxis, TILE};
use super::f16::{f16_to_f32, KvElem};

// §Perf note: a byte-LUT decode (table of set-bit positions per byte) was
// tried and REGRESSED ~4x vs the tzcnt bit-walk on this CPU (indirect
// table loads + data-dependent inner loops beat by hardware tzcnt);
// recorded in EXPERIMENTS.md §Perf iteration log.

// ---------------------------------------------------------------------------
// Tile sweep primitives (scalar fallback = SIMD parity oracle).
// ---------------------------------------------------------------------------

/// out[i] += widen(vals[i]) * w — the dense-tile fast path sweep.
#[inline]
fn fma_tile_f16_scalar(out: &mut [f32], vals: &[u16], w: f32) {
    for (o, &v) in out.iter_mut().zip(vals) {
        *o += f16_to_f32(v) * w;
    }
}

/// out[i] += buf[i] * w — the expand-then-FMA sweep over a decoded tile.
#[inline]
fn fma_tile_f32_scalar(out: &mut [f32], buf: &[f32], w: f32) {
    for (o, &x) in out.iter_mut().zip(buf) {
        *o += x * w;
    }
}

#[cfg(not(feature = "simd"))]
#[inline(always)]
fn fma_tile_f16(out: &mut [f32], vals: &[u16], w: f32) {
    fma_tile_f16_scalar(out, vals, w)
}

#[cfg(not(feature = "simd"))]
#[inline(always)]
fn fma_tile_f32(out: &mut [f32], buf: &[f32], w: f32) {
    fma_tile_f32_scalar(out, buf, w)
}

#[cfg(feature = "simd")]
#[inline]
fn fma_tile_f16(out: &mut [f32], vals: &[u16], w: f32) {
    use super::f16::simd::{widen, F32S, U16S, LANES};
    debug_assert_eq!(out.len(), vals.len());
    let wv = F32S::splat(w);
    let mut oc = out.chunks_exact_mut(LANES);
    let mut vc = vals.chunks_exact(LANES);
    for (o, v) in (&mut oc).zip(&mut vc) {
        let acc = F32S::from_slice(o) + widen(U16S::from_slice(v)) * wv;
        acc.copy_to_slice(o);
    }
    fma_tile_f16_scalar(oc.into_remainder(), vc.remainder(), w);
}

#[cfg(feature = "simd")]
#[inline]
fn fma_tile_f32(out: &mut [f32], buf: &[f32], w: f32) {
    use super::f16::simd::{F32S, LANES};
    debug_assert_eq!(out.len(), buf.len());
    let wv = F32S::splat(w);
    let mut oc = out.chunks_exact_mut(LANES);
    let mut bc = buf.chunks_exact(LANES);
    for (o, b) in (&mut oc).zip(&mut bc) {
        let acc = F32S::from_slice(o) + F32S::from_slice(b) * wv;
        acc.copy_to_slice(o);
    }
    fma_tile_f32_scalar(oc.into_remainder(), bc.remainder(), w);
}

// ---------------------------------------------------------------------------
// Single-query kernels.
// ---------------------------------------------------------------------------

/// scores[t] = Σ_c K[t,c]·q[c] for a Key cache packed along `PackAxis::Token`.
///
/// `scores` must have length `k.tokens` and is *accumulated into* (callers
/// zero it or seed it with the local-window contribution separately).
pub fn spmv_key(k: &BitmapMatrix, q: &[f32], scores: &mut [f32]) {
    assert_eq!(k.axis, PackAxis::Token, "key cache must be token-packed");
    assert_eq!(q.len(), k.channels);
    assert_eq!(scores.len(), k.tokens);

    let d = k.channels;
    let values = &k.values[..];
    // Tile order: token-group-major, channel-minor (layout in bitmap.rs).
    // All tiles of group g write into scores[g*64 .. g*64+64].
    for g in 0..k.tokens / TILE {
        let out = &mut scores[g * TILE..(g + 1) * TILE];
        let tile_base = g * d;
        for c in 0..d {
            let ti = tile_base + c;
            let bits = k.bitmaps[ti];
            if bits == 0 {
                continue;
            }
            let qc = q[c];
            let mut off = k.offsets[ti] as usize;
            if bits == u64::MAX {
                // dense tile fast path: one 64-wide widening FMA
                fma_tile_f16(out, &values[off..off + TILE], qc);
                continue;
            }
            // bit-walk decode (tzcnt); bounds hoisted — `validate()`
            // guarantees offsets stay within `values`.
            let mut bits = bits;
            unsafe {
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    *out.get_unchecked_mut(b) += f16_to_f32(*values.get_unchecked(off)) * qc;
                    off += 1;
                    bits &= bits - 1;
                }
            }
        }
    }
}

/// out[c] = Σ_t α[t]·V[t,c] for a Value cache packed along `PackAxis::Channel`.
///
/// `out` must have length `v.channels` and is accumulated into. The
/// trailing channel block may be partial (`channels % 64 != 0`).
pub fn spmv_value(v: &BitmapMatrix, att: &[f32], out: &mut [f32]) {
    assert_eq!(v.axis, PackAxis::Channel, "value cache must be channel-packed");
    assert_eq!(att.len(), v.tokens);
    assert_eq!(out.len(), v.channels);

    let d = v.channels;
    let cblocks = d.div_ceil(TILE);
    let values = &v.values[..];
    for t in 0..v.tokens {
        let at = att[t];
        if at == 0.0 {
            continue;
        }
        for cb in 0..cblocks {
            let ti = t * cblocks + cb;
            let bits = v.bitmaps[ti];
            if bits == 0 {
                continue;
            }
            let mut off = v.offsets[ti] as usize;
            let out_block = &mut out[cb * TILE..(cb * TILE + TILE).min(d)];
            if bits == u64::MAX {
                // only possible for full-width blocks
                fma_tile_f16(out_block, &values[off..off + TILE], at);
                continue;
            }
            // expand-then-FMA ("compute-as-dense", Fig 8): scatter the
            // compressed tile into a stack buffer with plain stores, then
            // one vectorizable 64-wide FMA — breaks the load-add-store
            // dependency chain of a scattered accumulate.
            let mut buf = [0.0f32; TILE];
            let mut bits = bits;
            unsafe {
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    *buf.get_unchecked_mut(b) = f16_to_f32(*values.get_unchecked(off));
                    off += 1;
                    bits &= bits - 1;
                }
            }
            let w = out_block.len();
            fma_tile_f32(out_block, &buf[..w], at);
        }
    }
}

/// 4-lane unrolled dot product — shared by the dense single- and
/// multi-query MVs so their per-lane rounding is identical.
#[inline]
fn dot_unrolled<E: KvElem>(row: &[E], q: &[f32], channels: usize) -> f32 {
    let mut acc = 0.0f32;
    let mut c = 0;
    let lim = channels & !3;
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    while c < lim {
        a0 += row[c].widen() * q[c];
        a1 += row[c + 1].widen() * q[c + 1];
        a2 += row[c + 2].widen() * q[c + 2];
        a3 += row[c + 3].widen() * q[c + 3];
        c += 4;
    }
    while c < channels {
        acc += row[c].widen() * q[c];
        c += 1;
    }
    acc + a0 + a1 + a2 + a3
}

/// Dense MV baseline: scores[t] = Σ_c K[t,c]·q[c] (row-major K [T x D],
/// f32 or stored-f16 elements).
pub fn dense_key<E: KvElem>(
    k: &[E],
    tokens: usize,
    channels: usize,
    q: &[f32],
    scores: &mut [f32],
) {
    assert_eq!(k.len(), tokens * channels);
    assert_eq!(q.len(), channels);
    assert_eq!(scores.len(), tokens);
    for t in 0..tokens {
        let row = &k[t * channels..(t + 1) * channels];
        scores[t] += dot_unrolled(row, q, channels);
    }
}

/// Dense MV baseline: out[c] = Σ_t α[t]·V[t,c] (row-major V [T x D],
/// f32 or stored-f16 elements).
pub fn dense_value<E: KvElem>(
    v: &[E],
    tokens: usize,
    channels: usize,
    att: &[f32],
    out: &mut [f32],
) {
    assert_eq!(v.len(), tokens * channels);
    assert_eq!(att.len(), tokens);
    assert_eq!(out.len(), channels);
    for t in 0..tokens {
        let at = att[t];
        if at == 0.0 {
            continue;
        }
        let row = &v[t * channels..(t + 1) * channels];
        for c in 0..channels {
            out[c] += at * row[c].widen();
        }
    }
}

// ---------------------------------------------------------------------------
// Fused GQA multi-query kernels.
//
// Under grouped-query attention, `G = n_heads / n_kv_heads` query heads
// share one KV head. The single-lane kernels above force the caller to
// re-walk the compressed stream G times per token; since the decode SpMV
// is memory-bound (Fig 5a/6a), that throws away the format's bandwidth
// win. The `_multi` kernels below walk each tile's bitmap + packed
// values exactly once and FMA the decoded tile into all G lanes.
//
// Lane layouts are flat: queries `[G x channels]`, scores `[G x tokens]`,
// outputs `[G x channels]`. Per lane, the floating-point operation order
// is identical to the corresponding single-lane kernel, so results are
// bit-exact against G independent single-lane calls (tested below).
// ---------------------------------------------------------------------------

/// Maximum GQA group size the fused kernels accept (stack-buffer bound;
/// real models use 4–8 queries per KV head).
pub const MAX_GROUP: usize = 16;

/// Multi-query `spmv_key`: scores[l*tokens + t] += Σ_c K[t,c]·q[l*channels + c]
/// for `g` query lanes, walking the compressed Key stream once.
pub fn spmv_key_multi(k: &BitmapMatrix, qs: &[f32], g: usize, scores: &mut [f32]) {
    assert_eq!(k.axis, PackAxis::Token, "key cache must be token-packed");
    assert!(g >= 1 && g <= MAX_GROUP, "group size {g} out of range");
    assert_eq!(qs.len(), g * k.channels);
    assert_eq!(scores.len(), g * k.tokens);

    let d = k.channels;
    let nt = k.tokens;
    let values = &k.values[..];
    for gt in 0..nt / TILE {
        let base = gt * TILE;
        let tile_base = gt * d;
        for c in 0..d {
            let ti = tile_base + c;
            let bits = k.bitmaps[ti];
            if bits == 0 {
                continue;
            }
            // hoist the G query weights for this channel
            let mut qc = [0.0f32; MAX_GROUP];
            for (l, slot) in qc[..g].iter_mut().enumerate() {
                *slot = qs[l * d + c];
            }
            let mut off = k.offsets[ti] as usize;
            if bits == u64::MAX {
                // dense tile fast path: per lane, one 64-wide widening FMA
                for (l, &w) in qc[..g].iter().enumerate() {
                    let out = &mut scores[l * nt + base..l * nt + base + TILE];
                    fma_tile_f16(out, &values[off..off + TILE], w);
                }
                continue;
            }
            // single bit-walk; each decoded value feeds all G lanes
            let mut bits = bits;
            unsafe {
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    let v = f16_to_f32(*values.get_unchecked(off));
                    for (l, &w) in qc[..g].iter().enumerate() {
                        *scores.get_unchecked_mut(l * nt + base + b) += v * w;
                    }
                    off += 1;
                    bits &= bits - 1;
                }
            }
        }
    }
}

/// Multi-query `spmv_value`: out[l*channels + c] += Σ_t α[l*tokens + t]·V[t,c]
/// for `g` attention lanes, walking the compressed Value stream once.
/// Each partial tile is scattered into a stack buffer once and then FMA'd
/// into every lane (amortizing the decode across the GQA group).
pub fn spmv_value_multi(v: &BitmapMatrix, att: &[f32], g: usize, out: &mut [f32]) {
    assert_eq!(v.axis, PackAxis::Channel, "value cache must be channel-packed");
    assert!(g >= 1 && g <= MAX_GROUP, "group size {g} out of range");
    assert_eq!(att.len(), g * v.tokens);
    assert_eq!(out.len(), g * v.channels);

    let d = v.channels;
    let cblocks = d.div_ceil(TILE);
    let nt = v.tokens;
    let values = &v.values[..];
    for t in 0..nt {
        let mut ats = [0.0f32; MAX_GROUP];
        let mut any = false;
        for (l, slot) in ats[..g].iter_mut().enumerate() {
            let a = att[l * nt + t];
            *slot = a;
            any |= a != 0.0;
        }
        if !any {
            continue;
        }
        for cb in 0..cblocks {
            let ti = t * cblocks + cb;
            let bits = v.bitmaps[ti];
            if bits == 0 {
                continue;
            }
            let blk = cb * TILE..(cb * TILE + TILE).min(d);
            let mut off = v.offsets[ti] as usize;
            if bits == u64::MAX {
                let seg = &values[off..off + TILE];
                for (l, &at) in ats[..g].iter().enumerate() {
                    if at == 0.0 {
                        continue;
                    }
                    let ob = &mut out[l * d + blk.start..l * d + blk.end];
                    fma_tile_f16(ob, seg, at);
                }
                continue;
            }
            // expand once ("compute-as-dense", Fig 8), FMA per lane
            let mut buf = [0.0f32; TILE];
            let mut bits = bits;
            unsafe {
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    *buf.get_unchecked_mut(b) = f16_to_f32(*values.get_unchecked(off));
                    off += 1;
                    bits &= bits - 1;
                }
            }
            let width = blk.end - blk.start;
            for (l, &at) in ats[..g].iter().enumerate() {
                if at == 0.0 {
                    continue;
                }
                let ob = &mut out[l * d + blk.start..l * d + blk.end];
                fma_tile_f32(ob, &buf[..width], at);
            }
        }
    }
}

/// Multi-query dense Key MV for the local-window tail: each K row is read
/// once and dotted against all `g` query lanes.
pub fn dense_key_multi<E: KvElem>(
    k: &[E],
    tokens: usize,
    channels: usize,
    qs: &[f32],
    g: usize,
    scores: &mut [f32],
) {
    assert_eq!(k.len(), tokens * channels);
    assert!(g >= 1 && g <= MAX_GROUP, "group size {g} out of range");
    assert_eq!(qs.len(), g * channels);
    assert_eq!(scores.len(), g * tokens);
    for t in 0..tokens {
        let row = &k[t * channels..(t + 1) * channels];
        for l in 0..g {
            let q = &qs[l * channels..(l + 1) * channels];
            scores[l * tokens + t] += dot_unrolled(row, q, channels);
        }
    }
}

/// Multi-query dense Value MV for the local-window tail: each V row is
/// read once and accumulated into all `g` output lanes.
pub fn dense_value_multi<E: KvElem>(
    v: &[E],
    tokens: usize,
    channels: usize,
    att: &[f32],
    g: usize,
    out: &mut [f32],
) {
    assert_eq!(v.len(), tokens * channels);
    assert!(g >= 1 && g <= MAX_GROUP, "group size {g} out of range");
    assert_eq!(att.len(), g * tokens);
    assert_eq!(out.len(), g * channels);
    for t in 0..tokens {
        let row = &v[t * channels..(t + 1) * channels];
        for l in 0..g {
            let at = att[l * tokens + t];
            if at == 0.0 {
                continue;
            }
            let ob = &mut out[l * channels..(l + 1) * channels];
            for (o, &x) in ob.iter_mut().zip(row) {
                *o += at * x.widen();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::f16::f16_round_vec as f16_ref;
    use crate::util::Pcg32;

    fn random_pruned(tokens: usize, channels: usize, keep: f32, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..tokens * channels)
            .map(|_| if rng.unit_f32() < keep { rng.normal_f32() } else { 0.0 })
            .collect()
    }

    #[test]
    fn spmv_key_matches_dense() {
        // dense reference over the f16-rounded matrix: identical stored
        // values, different summation order -> tight tolerance.
        for seed in 0..10 {
            let mut rng = Pcg32::seeded(seed + 500);
            let t = TILE * (1 + rng.below(4) as usize);
            let d = [16, 64, 128][rng.below(3) as usize];
            let dense = random_pruned(t, d, 0.3 + 0.5 * rng.unit_f32(), seed);
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();

            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
            let mut got = vec![0.0f32; t];
            spmv_key(&m, &q, &mut got);

            let mut want = vec![0.0f32; t];
            dense_key(&f16_ref(&dense), t, d, &q, &mut want);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "seed {seed}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn spmv_value_matches_dense() {
        for seed in 0..10 {
            let mut rng = Pcg32::seeded(seed + 900);
            let t = 1 + rng.below(300) as usize;
            let d = TILE * (1 + rng.below(2) as usize);
            let dense = random_pruned(t, d, 0.3 + 0.5 * rng.unit_f32(), seed);
            let att: Vec<f32> = (0..t).map(|_| rng.unit_f32()).collect();

            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Channel).unwrap();
            let mut got = vec![0.0f32; d];
            spmv_value(&m, &att, &mut got);

            let mut want = vec![0.0f32; d];
            dense_value(&f16_ref(&dense), t, d, &att, &mut want);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "seed {seed}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn spmv_value_partial_channel_blocks_match_dense() {
        // channels % 64 != 0 (incl. head_dim < 64) — the seed-bug shapes.
        for &(t, d) in &[(20, 32), (9, 8), (33, 96), (5, 100)] {
            let dense = random_pruned(t, d, 0.6, t as u64 * 131 + d as u64);
            let mut rng = Pcg32::seeded(d as u64);
            let att: Vec<f32> = (0..t).map(|_| rng.unit_f32()).collect();
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Channel).unwrap();
            let mut got = vec![0.0f32; d];
            spmv_value(&m, &att, &mut got);
            let mut want = vec![0.0f32; d];
            dense_value(&f16_ref(&dense), t, d, &att, &mut want);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "t={t} d={d}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn f16_kernels_within_tolerance_of_f32_reference() {
        // Acceptance property: against the *unrounded* f32 reference
        // kernels, the f16 storage path stays within 1e-2 relative error
        // (L2 over the output vector) across sparsity 0.3–0.9.
        let l2 = |xs: &[f32]| xs.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        for (i, &s) in [0.3f32, 0.5, 0.7, 0.9].iter().enumerate() {
            let mut rng = Pcg32::seeded(6000 + i as u64);
            let (t, d) = (4 * TILE, 128);
            let dense = random_pruned(t, d, 1.0 - s, 6100 + i as u64);
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let att: Vec<f32> = (0..t).map(|_| rng.unit_f32()).collect();

            let kc = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
            let mut got_k = vec![0.0f32; t];
            spmv_key(&kc, &q, &mut got_k);
            let mut ref_k = vec![0.0f32; t];
            dense_key(&dense, t, d, &q, &mut ref_k);
            let err: Vec<f32> = got_k.iter().zip(&ref_k).map(|(a, b)| a - b).collect();
            let rel = l2(&err) / l2(&ref_k).max(1e-12);
            assert!(rel <= 1e-2, "key sparsity {s}: rel {rel}");

            let vc = BitmapMatrix::compress(&dense, t, d, PackAxis::Channel).unwrap();
            let mut got_v = vec![0.0f32; d];
            spmv_value(&vc, &att, &mut got_v);
            let mut ref_v = vec![0.0f32; d];
            dense_value(&dense, t, d, &att, &mut ref_v);
            let err: Vec<f32> = got_v.iter().zip(&ref_v).map(|(a, b)| a - b).collect();
            let rel = l2(&err) / l2(&ref_v).max(1e-12);
            assert!(rel <= 1e-2, "value sparsity {s}: rel {rel}");
        }
    }

    #[test]
    fn spmv_accumulates() {
        let d = 64;
        let dense = random_pruned(TILE, d, 0.5, 1);
        let m = BitmapMatrix::compress(&dense, TILE, d, PackAxis::Token).unwrap();
        let q = vec![1.0f32; d];
        let mut scores = vec![10.0f32; TILE];
        spmv_key(&m, &q, &mut scores);
        let mut base = vec![0.0f32; TILE];
        spmv_key(&m, &q, &mut base);
        for (s, b) in scores.iter().zip(&base) {
            assert!((s - (b + 10.0)).abs() < 1e-5);
        }
    }

    #[test]
    fn empty_bitmap_is_noop() {
        let m = BitmapMatrix::compress(&vec![0.0; TILE * 8], TILE, 8, PackAxis::Token).unwrap();
        let mut scores = vec![0.0f32; TILE];
        spmv_key(&m, &[1.0; 8], &mut scores);
        assert!(scores.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn spmv_key_multi_bitexact_vs_single_lane() {
        // property: on random unstructured masks, the fused kernel must be
        // bit-for-bit identical to G independent single-lane calls.
        for seed in 0..20 {
            let mut rng = Pcg32::seeded(seed + 3000);
            let t = TILE * (1 + rng.below(4) as usize);
            let d = [16, 64, 128][rng.below(3) as usize];
            let g = [1, 2, 4, 8][rng.below(4) as usize];
            // include fully-dense tiles sometimes to hit the fast path
            let keep = if seed % 5 == 0 { 1.0 } else { 0.1 + 0.8 * rng.unit_f32() };
            let dense = random_pruned(t, d, keep, seed);
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
            let qs: Vec<f32> = (0..g * d).map(|_| rng.normal_f32()).collect();

            let mut fused = vec![0.0f32; g * t];
            spmv_key_multi(&m, &qs, g, &mut fused);

            for l in 0..g {
                let mut lane = vec![0.0f32; t];
                spmv_key(&m, &qs[l * d..(l + 1) * d], &mut lane);
                assert_eq!(&fused[l * t..(l + 1) * t], &lane[..], "seed {seed} lane {l}");
            }
        }
    }

    #[test]
    fn spmv_value_multi_bitexact_vs_single_lane() {
        for seed in 0..20 {
            let mut rng = Pcg32::seeded(seed + 4000);
            let t = 1 + rng.below(300) as usize;
            // include partial trailing channel blocks (d % 64 != 0)
            let d = [32, 64, 96, 128][rng.below(4) as usize];
            let g = [1, 2, 4, 8][rng.below(4) as usize];
            let keep = if seed % 5 == 0 { 1.0 } else { 0.1 + 0.8 * rng.unit_f32() };
            let dense = random_pruned(t, d, keep, seed);
            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Channel).unwrap();
            // include exact zeros in some lanes to hit the skip path
            let att: Vec<f32> = (0..g * t)
                .map(|i| if i % 7 == 0 { 0.0 } else { rng.unit_f32() })
                .collect();

            let mut fused = vec![0.0f32; g * d];
            spmv_value_multi(&m, &att, g, &mut fused);

            for l in 0..g {
                let mut lane = vec![0.0f32; d];
                spmv_value(&m, &att[l * t..(l + 1) * t], &mut lane);
                assert_eq!(&fused[l * d..(l + 1) * d], &lane[..], "seed {seed} lane {l}");
            }
        }
    }

    #[test]
    fn dense_multi_bitexact_vs_single_lane() {
        for seed in 0..10 {
            let mut rng = Pcg32::seeded(seed + 5000);
            let t = 1 + rng.below(100) as usize;
            let d = [16, 32, 64][rng.below(3) as usize];
            let g = [1, 3, 4, 8][rng.below(4) as usize];
            // exercise the E = u16 instantiation (the f16 dense tail)
            let mat: Vec<u16> =
                (0..t * d).map(|_| crate::sparse::f16::f32_to_f16(rng.normal_f32())).collect();
            let qs: Vec<f32> = (0..g * d).map(|_| rng.normal_f32()).collect();
            let att: Vec<f32> = (0..g * t)
                .map(|i| if i % 5 == 0 { 0.0 } else { rng.normal_f32() })
                .collect();

            let mut sk = vec![0.0f32; g * t];
            dense_key_multi(&mat, t, d, &qs, g, &mut sk);
            let mut ov = vec![0.0f32; g * d];
            dense_value_multi(&mat, t, d, &att, g, &mut ov);

            for l in 0..g {
                let mut lane_s = vec![0.0f32; t];
                dense_key(&mat, t, d, &qs[l * d..(l + 1) * d], &mut lane_s);
                assert_eq!(&sk[l * t..(l + 1) * t], &lane_s[..], "key seed {seed} lane {l}");

                let mut lane_o = vec![0.0f32; d];
                dense_value(&mat, t, d, &att[l * t..(l + 1) * t], &mut lane_o);
                assert_eq!(&ov[l * d..(l + 1) * d], &lane_o[..], "val seed {seed} lane {l}");
            }
        }
    }

    #[test]
    fn multi_kernels_accumulate() {
        let d = 64;
        let dense = random_pruned(TILE, d, 0.5, 77);
        let m = BitmapMatrix::compress(&dense, TILE, d, PackAxis::Token).unwrap();
        let qs = vec![1.0f32; 2 * d];
        let mut scores = vec![5.0f32; 2 * TILE];
        spmv_key_multi(&m, &qs, 2, &mut scores);
        let mut base = vec![0.0f32; 2 * TILE];
        spmv_key_multi(&m, &qs, 2, &mut base);
        for (s, b) in scores.iter().zip(&base) {
            assert!((s - (b + 5.0)).abs() < 1e-5);
        }
    }

    #[test]
    fn tile_fma_dispatch_matches_scalar_bitexact() {
        // The dispatched fma_tile_* (SIMD when the `simd` feature is on,
        // scalar otherwise) must be bit-identical to the scalar oracle for
        // every length, including non-multiples of the lane count.
        let mut rng = Pcg32::seeded(8080);
        for len in 1..=TILE {
            let vals: Vec<u16> =
                (0..len).map(|_| crate::sparse::f16::f32_to_f16(rng.normal_f32())).collect();
            let buf: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
            let acc0: Vec<f32> = (0..len).map(|_| rng.normal_f32()).collect();
            let w = rng.normal_f32();

            let mut a = acc0.clone();
            let mut b = acc0.clone();
            fma_tile_f16(&mut a, &vals, w);
            fma_tile_f16_scalar(&mut b, &vals, w);
            assert_eq!(a, b, "f16 len {len}");

            let mut a = acc0.clone();
            let mut b = acc0;
            fma_tile_f32(&mut a, &buf, w);
            fma_tile_f32_scalar(&mut b, &buf, w);
            assert_eq!(a, b, "f32 len {len}");
        }
    }
}
