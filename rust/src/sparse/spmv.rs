//! SpMV over the bitmap format — the Mustafar attention hot path.
//!
//! Two flavors mirror the two decode-phase MVs (Fig 5a):
//!   * `spmv_key`:  scores[t] = Σ_c K[t,c]·q[c]   (Key × Queryᵀ)
//!   * `spmv_value`: out[c]   = Σ_t α[t]·V[t,c]   (AttentionScore × Value)
//!
//! Both follow the paper's *load-as-compressed, compute-as-dense* paradigm:
//! the packed value stream is walked sequentially (that is the bandwidth
//! win — only compressed bytes are touched), with the bitmap steering
//! accumulation into the right output lane.
//!
//! Dense reference MVs (`dense_key`, `dense_value`) play the cuBLAS-
//! baseline role of Fig 6a.

use super::bitmap::{BitmapMatrix, PackAxis, TILE};

// §Perf note: a byte-LUT decode (table of set-bit positions per byte) was
// tried and REGRESSED ~4x vs the tzcnt bit-walk on this CPU (indirect
// table loads + data-dependent inner loops beat by hardware tzcnt);
// recorded in EXPERIMENTS.md §Perf iteration log.

/// scores[t] = Σ_c K[t,c]·q[c] for a Key cache packed along `PackAxis::Token`.
///
/// `scores` must have length `k.tokens` and is *accumulated into* (callers
/// zero it or seed it with the local-window contribution separately).
pub fn spmv_key(k: &BitmapMatrix, q: &[f32], scores: &mut [f32]) {
    assert_eq!(k.axis, PackAxis::Token, "key cache must be token-packed");
    assert_eq!(q.len(), k.channels);
    assert_eq!(scores.len(), k.tokens);

    let d = k.channels;
    let values = &k.values[..];
    // Tile order: token-group-major, channel-minor (layout in bitmap.rs).
    // All tiles of group g write into scores[g*64 .. g*64+64].
    for g in 0..k.tokens / TILE {
        let out = &mut scores[g * TILE..(g + 1) * TILE];
        let tile_base = g * d;
        for c in 0..d {
            let ti = tile_base + c;
            let bits = k.bitmaps[ti];
            if bits == 0 {
                continue;
            }
            let qc = q[c];
            let mut off = k.offsets[ti] as usize;
            if bits == u64::MAX {
                // dense tile fast path: straight vectorizable loop
                for (o, &v) in out.iter_mut().zip(&values[off..off + TILE]) {
                    *o += v * qc;
                }
                continue;
            }
            // bit-walk decode (tzcnt); bounds hoisted — `validate()`
            // guarantees offsets stay within `values`.
            let mut bits = bits;
            unsafe {
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    *out.get_unchecked_mut(b) += values.get_unchecked(off) * qc;
                    off += 1;
                    bits &= bits - 1;
                }
            }
        }
    }
}

/// out[c] = Σ_t α[t]·V[t,c] for a Value cache packed along `PackAxis::Channel`.
///
/// `out` must have length `v.channels` and is accumulated into.
pub fn spmv_value(v: &BitmapMatrix, att: &[f32], out: &mut [f32]) {
    assert_eq!(v.axis, PackAxis::Channel, "value cache must be channel-packed");
    assert_eq!(att.len(), v.tokens);
    assert_eq!(out.len(), v.channels);

    let cblocks = v.channels / TILE;
    let values = &v.values[..];
    for t in 0..v.tokens {
        let at = att[t];
        if at == 0.0 {
            continue;
        }
        for cb in 0..cblocks {
            let ti = t * cblocks + cb;
            let bits = v.bitmaps[ti];
            if bits == 0 {
                continue;
            }
            let mut off = v.offsets[ti] as usize;
            let out_block = &mut out[cb * TILE..(cb + 1) * TILE];
            if bits == u64::MAX {
                for (o, &x) in out_block.iter_mut().zip(&values[off..off + TILE]) {
                    *o += x * at;
                }
                continue;
            }
            // expand-then-FMA ("compute-as-dense", Fig 8): scatter the
            // compressed tile into a stack buffer with plain stores, then
            // one vectorizable 64-wide FMA — breaks the load-add-store
            // dependency chain of a scattered accumulate.
            let mut buf = [0.0f32; TILE];
            let mut bits = bits;
            unsafe {
                while bits != 0 {
                    let b = bits.trailing_zeros() as usize;
                    *buf.get_unchecked_mut(b) = *values.get_unchecked(off);
                    off += 1;
                    bits &= bits - 1;
                }
            }
            for (o, &x) in out_block.iter_mut().zip(buf.iter()) {
                *o += x * at;
            }
        }
    }
}

/// Dense MV baseline: scores[t] = Σ_c K[t,c]·q[c] (row-major K [T x D]).
pub fn dense_key(k: &[f32], tokens: usize, channels: usize, q: &[f32], scores: &mut [f32]) {
    assert_eq!(k.len(), tokens * channels);
    assert_eq!(q.len(), channels);
    assert_eq!(scores.len(), tokens);
    for t in 0..tokens {
        let row = &k[t * channels..(t + 1) * channels];
        let mut acc = 0.0f32;
        // 4-lane unrolled dot product
        let mut c = 0;
        let lim = channels & !3;
        let (mut a0, mut a1, mut a2, mut a3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
        while c < lim {
            a0 += row[c] * q[c];
            a1 += row[c + 1] * q[c + 1];
            a2 += row[c + 2] * q[c + 2];
            a3 += row[c + 3] * q[c + 3];
            c += 4;
        }
        while c < channels {
            acc += row[c] * q[c];
            c += 1;
        }
        scores[t] += acc + a0 + a1 + a2 + a3;
    }
}

/// Dense MV baseline: out[c] = Σ_t α[t]·V[t,c] (row-major V [T x D]).
pub fn dense_value(v: &[f32], tokens: usize, channels: usize, att: &[f32], out: &mut [f32]) {
    assert_eq!(v.len(), tokens * channels);
    assert_eq!(att.len(), tokens);
    assert_eq!(out.len(), channels);
    for t in 0..tokens {
        let at = att[t];
        if at == 0.0 {
            continue;
        }
        let row = &v[t * channels..(t + 1) * channels];
        for c in 0..channels {
            out[c] += at * row[c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn random_pruned(tokens: usize, channels: usize, keep: f32, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..tokens * channels)
            .map(|_| if rng.unit_f32() < keep { rng.normal_f32() } else { 0.0 })
            .collect()
    }

    #[test]
    fn spmv_key_matches_dense() {
        for seed in 0..10 {
            let mut rng = Pcg32::seeded(seed + 500);
            let t = TILE * (1 + rng.below(4) as usize);
            let d = [16, 64, 128][rng.below(3) as usize];
            let dense = random_pruned(t, d, 0.3 + 0.5 * rng.unit_f32(), seed);
            let q: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();

            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Token).unwrap();
            let mut got = vec![0.0f32; t];
            spmv_key(&m, &q, &mut got);

            let mut want = vec![0.0f32; t];
            dense_key(&dense, t, d, &q, &mut want);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-4, "seed {seed}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn spmv_value_matches_dense() {
        for seed in 0..10 {
            let mut rng = Pcg32::seeded(seed + 900);
            let t = 1 + rng.below(300) as usize;
            let d = TILE * (1 + rng.below(2) as usize);
            let dense = random_pruned(t, d, 0.3 + 0.5 * rng.unit_f32(), seed);
            let att: Vec<f32> = (0..t).map(|_| rng.unit_f32()).collect();

            let m = BitmapMatrix::compress(&dense, t, d, PackAxis::Channel).unwrap();
            let mut got = vec![0.0f32; d];
            spmv_value(&m, &att, &mut got);

            let mut want = vec![0.0f32; d];
            dense_value(&dense, t, d, &att, &mut want);
            for (g, w) in got.iter().zip(&want) {
                assert!((g - w).abs() < 1e-3, "seed {seed}: {g} vs {w}");
            }
        }
    }

    #[test]
    fn spmv_accumulates() {
        let d = 64;
        let dense = random_pruned(TILE, d, 0.5, 1);
        let m = BitmapMatrix::compress(&dense, TILE, d, PackAxis::Token).unwrap();
        let q = vec![1.0f32; d];
        let mut scores = vec![10.0f32; TILE];
        spmv_key(&m, &q, &mut scores);
        let mut base = vec![0.0f32; TILE];
        spmv_key(&m, &q, &mut base);
        for (s, b) in scores.iter().zip(&base) {
            assert!((s - (b + 10.0)).abs() < 1e-5);
        }
    }

    #[test]
    fn empty_bitmap_is_noop() {
        let m = BitmapMatrix::compress(&vec![0.0; TILE * 8], TILE, 8, PackAxis::Token).unwrap();
        let mut scores = vec![0.0f32; TILE];
        spmv_key(&m, &[1.0; 8], &mut scores);
        assert!(scores.iter().all(|&x| x == 0.0));
    }
}
