//! (values, indices) rectangular view of a per-token pruned matrix.
//!
//! The L1 Pallas kernel consumes compressed operands as constant-width
//! `[T, kk]` (values, indices) pairs because XLA requires static shapes
//! (DESIGN.md §3). Per-token pruning keeps exactly `kk` elements per
//! token, so this view is lossless; it is derived from / converted to the
//! bitmap format only at the PJRT boundary. Both views are bit-exact
//! representations of the same pruned matrix (round-trip tested).

use super::bitmap::{BitmapMatrix, PackAxis};
use crate::error::{Error, Result};

/// Rectangular compressed view: row t holds the kept elements of token t
/// with their channel indices ascending; rows with fewer than `kk` kept
/// elements are padded with (0.0, 0).
#[derive(Clone, Debug, PartialEq)]
pub struct TokenPairs {
    pub tokens: usize,
    pub channels: usize,
    pub kk: usize,
    /// `[tokens * kk]` values (padding slots are 0.0)
    pub values: Vec<f32>,
    /// `[tokens * kk]` channel indices (padding slots are 0)
    pub indices: Vec<i32>,
}

impl TokenPairs {
    /// Build from a dense (pruned) row-major `[tokens x channels]` matrix.
    /// Errors if any token has more than `kk` non-zeros.
    pub fn from_dense(
        dense: &[f32],
        tokens: usize,
        channels: usize,
        kk: usize,
    ) -> Result<TokenPairs> {
        if dense.len() != tokens * channels {
            return Err(Error::Shape(format!(
                "dense len {} != {tokens}x{channels}",
                dense.len()
            )));
        }
        let mut values = vec![0.0f32; tokens * kk];
        let mut indices = vec![0i32; tokens * kk];
        for t in 0..tokens {
            let row = &dense[t * channels..(t + 1) * channels];
            let mut j = 0usize;
            for (c, &x) in row.iter().enumerate() {
                if x != 0.0 {
                    if j >= kk {
                        return Err(Error::Shape(format!(
                            "token {t} has more than kk={kk} non-zeros"
                        )));
                    }
                    values[t * kk + j] = x;
                    indices[t * kk + j] = c as i32;
                    j += 1;
                }
            }
        }
        Ok(TokenPairs { tokens, channels, kk, values, indices })
    }

    /// Densify back to `[tokens x channels]`.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.tokens * self.channels];
        for t in 0..self.tokens {
            for j in 0..self.kk {
                let v = self.values[t * self.kk + j];
                if v != 0.0 {
                    out[t * self.channels + self.indices[t * self.kk + j] as usize] = v;
                }
            }
        }
        out
    }

    /// Convert a bitmap-format matrix into the pairs view.
    pub fn from_bitmap(m: &BitmapMatrix, kk: usize) -> Result<TokenPairs> {
        Self::from_dense(&m.decompress(), m.tokens, m.channels, kk)
    }

    /// Convert to the bitmap format with the given packing axis (tokens
    /// must satisfy the axis' granularity requirement).
    pub fn to_bitmap(&self, axis: PackAxis) -> Result<BitmapMatrix> {
        BitmapMatrix::compress(&self.to_dense(), self.tokens, self.channels, axis)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::per_token_magnitude;
    use crate::util::Pcg32;

    #[test]
    fn roundtrip_with_pruned_matrix() {
        let mut rng = Pcg32::seeded(77);
        let (t, d, kk) = (128, 64, 20);
        let dense: Vec<f32> = (0..t * d).map(|_| rng.normal_f32()).collect();
        let pruned = per_token_magnitude(&dense, t, d, kk);
        let pairs = TokenPairs::from_dense(&pruned, t, d, kk).unwrap();
        assert_eq!(pairs.to_dense(), pruned);

        // bitmap <-> pairs equivalence
        let bm = pairs.to_bitmap(PackAxis::Token).unwrap();
        let pairs2 = TokenPairs::from_bitmap(&bm, kk).unwrap();
        assert_eq!(pairs, pairs2);
    }

    #[test]
    fn rejects_overfull_rows() {
        let dense = vec![1.0f32; 2 * 8]; // every element non-zero
        assert!(TokenPairs::from_dense(&dense, 2, 8, 4).is_err());
    }

    #[test]
    fn indices_ascending() {
        let mut rng = Pcg32::seeded(5);
        let (t, d, kk) = (64, 64, 16);
        let dense: Vec<f32> = (0..t * d).map(|_| rng.normal_f32()).collect();
        let pruned = per_token_magnitude(&dense, t, d, kk);
        let pairs = TokenPairs::from_dense(&pruned, t, d, kk).unwrap();
        for tt in 0..t {
            let idx = &pairs.indices[tt * kk..(tt + 1) * kk];
            for w in idx.windows(2) {
                assert!(w[0] < w[1], "indices not ascending: {idx:?}");
            }
        }
    }
}
