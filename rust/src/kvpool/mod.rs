//! Paged compressed-KV pool: one global byte budget for every byte of
//! compressed-KV state the serving layer holds.
//!
//! The bitmap format (`sparse::bitmap`) makes a sequence's KV footprint
//! small but *irregular* — per-tile value segments grow with whatever
//! survives pruning — so the pool allocates fixed-size **pages** and
//! keeps a per-owner page table plus an exact live-byte count:
//!
//!  * **pages** are the reservation granularity (budget enforcement,
//!    fragmentation bound, and the unit a device allocator would map);
//!  * **live bytes** are the exact `size_of_val`-style footprint of the
//!    owner's buffers, so occupancy numbers are measurements rather than
//!    an estimate model.
//!
//! Owners are sequences (their private compressed regions + dense
//! tails) and prefix-cache entries (`prefix::PrefixCache`, which charges
//! shared prefill pages exactly once no matter how many sequences
//! reference them). The pressure ladder that runs when a reservation
//! fails (re-prune → preempt → reject) lives in `pressure` and is
//! orchestrated by `coordinator::engine`.

pub mod prefix;
pub mod pressure;

pub use prefix::{PrefixCache, PrefixHit};
pub use pressure::{next_reprune_tier, pick_preempt_victim, pick_reprune_victim, ReclaimCandidate};

use std::collections::HashMap;

/// Default page size: 16 KiB — small enough that a short sequence's
/// rounding waste stays low, large enough that page-table churn is
/// negligible next to the 64-token compression-group granularity.
pub const DEFAULT_PAGE_BYTES: usize = 16 * 1024;

/// Pool-wide configuration. The pressure-ladder knobs (re-prune tiers,
/// prefix-cache enable) live in `config::EngineConfig` with their
/// consumers — the pool itself only allocates and accounts.
#[derive(Clone, Debug)]
pub struct PoolConfig {
    /// Global byte budget across all owners; 0 = unbounded (accounting
    /// still runs, reservations never fail).
    pub budget_bytes: usize,
    /// Fixed page size in bytes.
    pub page_bytes: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        PoolConfig { budget_bytes: 0, page_bytes: DEFAULT_PAGE_BYTES }
    }
}

/// Handle to one pool occupant (a sequence or a prefix-cache entry).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct OwnerId(u64);

/// A failed reservation: the *total* extra bytes the grow needs
/// (page-granular — `grow_pages * page_bytes`, not merely the missing
/// headroom). The caller runs the pressure ladder until
/// `fits_extra(bytes)` holds, which is exactly the condition for the
/// retried reservation to succeed; reporting only the missing delta
/// would let a reclaim "succeed" against space the retry still cannot
/// use, spinning the retry loop forever.
#[derive(Clone, Copy, Debug)]
pub struct Shortfall {
    pub bytes: usize,
}

/// Per-owner page table: the frames backing this owner's buffers plus
/// the exact number of bytes actually live inside them.
#[derive(Clone, Debug, Default)]
pub struct PageTable {
    pages: Vec<u32>,
    live_bytes: usize,
}

impl PageTable {
    pub fn page_count(&self) -> usize {
        self.pages.len()
    }

    pub fn live_bytes(&self) -> usize {
        self.live_bytes
    }

    pub fn pages(&self) -> &[u32] {
        &self.pages
    }
}

/// Aggregate pool occupancy snapshot (served by the TCP stats endpoint
/// and asserted exactly in tests).
#[derive(Clone, Copy, Debug, Default)]
pub struct PoolStats {
    pub budget_bytes: usize,
    pub page_bytes: usize,
    /// Pages currently allocated to owners.
    pub used_pages: usize,
    /// `used_pages * page_bytes` — the reservation-granular footprint.
    pub reserved_bytes: usize,
    /// Exact bytes live inside those pages.
    pub live_bytes: usize,
    pub owners: usize,
    pub peak_live_bytes: usize,
    pub peak_used_pages: usize,
}

/// Slab/page allocator owning all compressed-KV storage reservations
/// under one byte budget.
#[derive(Debug)]
pub struct KvPool {
    cfg: PoolConfig,
    /// Total frames under the budget; `usize::MAX` when unbounded.
    total_pages: usize,
    /// Recycled frame ids (LIFO, so freed pages are reused first).
    free: Vec<u32>,
    /// High-water mark for never-used frame ids.
    next_page: u32,
    used_pages: usize,
    owners: HashMap<u64, PageTable>,
    next_owner: u64,
    live_bytes: usize,
    peak_live_bytes: usize,
    peak_used_pages: usize,
    /// Fault injection (`kvpool.alloc` / `kvpool.release` points).
    /// Disabled by default; `Engine::set_fault_injector` shares the
    /// engine's injector here.
    faults: crate::faults::Injector,
    /// Pages whose release was deferred by a `kvpool.release` fault:
    /// they hold no live bytes but still count against the budget until
    /// the next pool mutation flushes them — modelling a device
    /// allocator that frees asynchronously. Live-byte accounting stays
    /// exact throughout; only *reservation* headroom lags.
    quarantine: Vec<u32>,
    /// Optional telemetry registry: every successful occupancy mutation
    /// records the new `live_bytes` into the pool-occupancy histogram
    /// (one relaxed atomic record; `None` or a disabled registry costs
    /// one branch).
    telemetry: Option<std::sync::Arc<crate::telemetry::Telemetry>>,
}

impl KvPool {
    pub fn new(cfg: PoolConfig) -> KvPool {
        let page = cfg.page_bytes.max(1);
        let total_pages = if cfg.budget_bytes == 0 {
            usize::MAX
        } else {
            // a budget smaller than one page still grants one page
            cfg.budget_bytes.div_ceil(page).max(1)
        };
        KvPool {
            cfg: PoolConfig { page_bytes: page, ..cfg },
            total_pages,
            free: Vec::new(),
            next_page: 0,
            used_pages: 0,
            owners: HashMap::new(),
            next_owner: 0,
            live_bytes: 0,
            peak_live_bytes: 0,
            peak_used_pages: 0,
            faults: crate::faults::Injector::disabled(),
            quarantine: Vec::new(),
            telemetry: None,
        }
    }

    pub fn config(&self) -> &PoolConfig {
        &self.cfg
    }

    /// Arm the pool's fault points with a (usually engine-shared)
    /// injector.
    pub fn set_fault_injector(&mut self, inj: crate::faults::Injector) {
        self.faults = inj;
    }

    /// Share the engine's telemetry registry: occupancy mutations start
    /// recording into `pool_occupancy_bytes`. A disabled registry is
    /// dropped here so the hot path pays only an `Option` check.
    pub fn set_telemetry(&mut self, tel: std::sync::Arc<crate::telemetry::Telemetry>) {
        self.telemetry = tel.on().then_some(tel);
    }

    fn note_occupancy(&self) {
        if let Some(tel) = &self.telemetry {
            tel.pool_occupancy_bytes.record(self.live_bytes as u64);
        }
    }

    /// Return quarantined (fault-deferred) pages to the free list.
    fn flush_quarantine(&mut self) {
        if self.quarantine.is_empty() {
            return;
        }
        self.used_pages -= self.quarantine.len();
        self.free.append(&mut self.quarantine);
    }

    /// Register a new (empty) owner.
    pub fn register(&mut self) -> OwnerId {
        let id = self.next_owner;
        self.next_owner += 1;
        self.owners.insert(id, PageTable::default());
        OwnerId(id)
    }

    /// Set `owner`'s live footprint to exactly `bytes`, growing or
    /// shrinking its page table to `ceil(bytes / page_bytes)` frames.
    /// On insufficient free pages nothing changes and the missing
    /// headroom comes back as a `Shortfall`. Shrinks never fail.
    pub fn set_live_bytes(
        &mut self,
        owner: OwnerId,
        bytes: usize,
    ) -> std::result::Result<(), Shortfall> {
        self.flush_quarantine();
        let page = self.cfg.page_bytes;
        let need = bytes.div_ceil(page);
        let table = self.owners.get_mut(&owner.0).expect("unknown pool owner");
        let cur = table.pages.len();
        if need > cur {
            let grow = need - cur;
            let avail = self.total_pages - self.used_pages;
            if grow > avail {
                return Err(Shortfall { bytes: grow * page });
            }
            // Injected allocation failure: surfaces as an ordinary
            // shortfall so callers exercise the same pressure ladder a
            // genuine out-of-pages condition would.
            if self.faults.fire("kvpool.alloc") {
                return Err(Shortfall { bytes: grow * page });
            }
            for _ in 0..grow {
                let frame = match self.free.pop() {
                    Some(f) => f,
                    None => {
                        let f = self.next_page;
                        self.next_page += 1;
                        f
                    }
                };
                table.pages.push(frame);
            }
            self.used_pages += grow;
        } else if need < cur {
            for _ in 0..cur - need {
                self.free.push(table.pages.pop().expect("page table underflow"));
            }
            self.used_pages -= cur - need;
        }
        self.live_bytes = self.live_bytes - table.live_bytes + bytes;
        table.live_bytes = bytes;
        self.peak_live_bytes = self.peak_live_bytes.max(self.live_bytes);
        self.peak_used_pages = self.peak_used_pages.max(self.used_pages);
        self.note_occupancy();
        Ok(())
    }

    /// Release an owner, returning all of its pages to the free list.
    /// Returns the owner's live bytes at release time (0 for an unknown
    /// owner) — the cancellation path reports this as memory handed
    /// back to the pool instead of being reclaimed from live requests.
    pub fn release(&mut self, owner: OwnerId) -> usize {
        self.flush_quarantine();
        match self.owners.remove(&owner.0) {
            Some(table) => {
                self.live_bytes -= table.live_bytes;
                self.note_occupancy();
                if self.faults.fire("kvpool.release") {
                    // Injected deferred free: the pages stay reserved
                    // (budget pressure) until the next mutation flushes
                    // them, but the owner and its live bytes are gone —
                    // exactly-once accounting is unaffected.
                    self.quarantine.extend(table.pages);
                } else {
                    self.used_pages -= table.pages.len();
                    self.free.extend(table.pages);
                }
                table.live_bytes
            }
            None => 0,
        }
    }

    /// Would a *new* reservation of `bytes` fit without reclaim?
    pub fn fits_extra(&self, bytes: usize) -> bool {
        bytes.div_ceil(self.cfg.page_bytes) <= self.total_pages - self.used_pages
    }

    /// Free headroom in bytes (page-granular; `usize::MAX` if unbounded).
    pub fn free_bytes(&self) -> usize {
        (self.total_pages - self.used_pages).saturating_mul(self.cfg.page_bytes)
    }

    pub fn owner_live_bytes(&self, owner: OwnerId) -> usize {
        self.owners.get(&owner.0).map_or(0, |t| t.live_bytes)
    }

    pub fn owner_pages(&self, owner: OwnerId) -> usize {
        self.owners.get(&owner.0).map_or(0, |t| t.pages.len())
    }

    pub fn stats(&self) -> PoolStats {
        PoolStats {
            budget_bytes: self.cfg.budget_bytes,
            page_bytes: self.cfg.page_bytes,
            used_pages: self.used_pages,
            reserved_bytes: self.used_pages * self.cfg.page_bytes,
            live_bytes: self.live_bytes,
            owners: self.owners.len(),
            peak_live_bytes: self.peak_live_bytes,
            peak_used_pages: self.peak_used_pages,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pool(budget: usize, page: usize) -> KvPool {
        KvPool::new(PoolConfig { budget_bytes: budget, page_bytes: page })
    }

    #[test]
    fn pages_track_exact_live_bytes() {
        let mut p = pool(1 << 20, 1024);
        let a = p.register();
        p.set_live_bytes(a, 2500).unwrap();
        assert_eq!(p.owner_pages(a), 3); // ceil(2500/1024)
        assert_eq!(p.owner_live_bytes(a), 2500);
        let s = p.stats();
        assert_eq!(s.live_bytes, 2500);
        assert_eq!(s.reserved_bytes, 3 * 1024);

        // shrink releases pages but keeps exact bytes
        p.set_live_bytes(a, 900).unwrap();
        assert_eq!(p.owner_pages(a), 1);
        assert_eq!(p.stats().live_bytes, 900);
        assert_eq!(p.release(a), 900, "release reports the freed live bytes");
        assert_eq!(p.stats().used_pages, 0);
        assert_eq!(p.stats().live_bytes, 0);
        assert_eq!(p.release(a), 0, "double release is a no-op");
    }

    #[test]
    fn budget_is_enforced_with_shortfall() {
        let mut p = pool(4 * 1024, 1024); // 4 pages total
        let a = p.register();
        let b = p.register();
        p.set_live_bytes(a, 3 * 1024).unwrap();
        // b wants 3 pages with only 1 free: the shortfall reports the
        // full grow (3 pages) — once fits_extra(err.bytes) holds, the
        // retried reservation is guaranteed to succeed
        let err = p.set_live_bytes(b, 3 * 1024).unwrap_err();
        assert_eq!(err.bytes, 3 * 1024);
        assert!(!p.fits_extra(err.bytes));
        // failed reservation changed nothing
        assert_eq!(p.owner_pages(b), 0);
        assert_eq!(p.stats().used_pages, 3);
        // after a shrinks, b fits
        p.set_live_bytes(a, 1024).unwrap();
        p.set_live_bytes(b, 3 * 1024).unwrap();
        assert!(!p.fits_extra(1));
        assert_eq!(p.free_bytes(), 0);
    }

    #[test]
    fn freed_pages_are_reused_in_place() {
        let mut p = pool(16 * 1024, 1024);
        let a = p.register();
        p.set_live_bytes(a, 4 * 1024).unwrap();
        let frames_a: Vec<u32> = p.owners.get(&0).unwrap().pages().to_vec();
        p.release(a);
        // the next owner's pages come from the free list, not fresh ids
        let b = p.register();
        p.set_live_bytes(b, 4 * 1024).unwrap();
        let frames_b: Vec<u32> = p.owners.get(&1).unwrap().pages().to_vec();
        let mut sa = frames_a.clone();
        let mut sb = frames_b.clone();
        sa.sort_unstable();
        sb.sort_unstable();
        assert_eq!(sa, sb, "recycled frames expected");
        assert_eq!(p.next_page, 4, "no fresh frames minted");
    }

    #[test]
    fn unbounded_pool_never_fails_but_still_accounts() {
        let mut p = pool(0, 4096);
        let a = p.register();
        p.set_live_bytes(a, 100 << 20).unwrap();
        assert!(p.fits_extra(usize::MAX / 2));
        assert_eq!(p.stats().live_bytes, 100 << 20);
        assert_eq!(p.free_bytes(), usize::MAX);
    }

    #[test]
    fn injected_alloc_fault_is_an_ordinary_shortfall() {
        let mut p = pool(1 << 20, 1024);
        p.set_fault_injector(crate::faults::Injector::parse("kvpool.alloc:after=1", 3).unwrap());
        let a = p.register();
        p.set_live_bytes(a, 1024).unwrap(); // hit 1 passes
        let err = p.set_live_bytes(a, 4096).unwrap_err();
        assert_eq!(err.bytes, 3 * 1024, "full grow reported, like a real shortfall");
        // nothing changed on the faulted reservation
        assert_eq!(p.owner_pages(a), 1);
        assert_eq!(p.stats().live_bytes, 1024);
        // shrinks never consult the alloc point
        p.set_live_bytes(a, 100).unwrap();
        assert_eq!(p.stats().live_bytes, 100);
    }

    #[test]
    fn injected_release_fault_quarantines_pages_but_keeps_bytes_exact() {
        let mut p = pool(4 * 1024, 1024); // 4 pages
        p.set_fault_injector(crate::faults::Injector::parse("kvpool.release:after=0", 3).unwrap());
        let a = p.register();
        p.set_live_bytes(a, 3 * 1024).unwrap();
        assert_eq!(p.release(a), 3 * 1024, "released bytes reported exactly");
        let s = p.stats();
        assert_eq!(s.live_bytes, 0, "live-byte accounting is exact despite the fault");
        assert_eq!(s.used_pages, 3, "quarantined pages still pressure the budget");
        assert!(!p.fits_extra(2 * 1024));
        // the next mutation flushes the quarantine and the space returns
        let b = p.register();
        p.set_live_bytes(b, 4 * 1024).unwrap();
        assert_eq!(p.stats().used_pages, 4);
    }

    #[test]
    fn telemetry_sees_every_occupancy_mutation() {
        let mut p = pool(1 << 20, 1024);
        let tel = std::sync::Arc::new(crate::telemetry::Telemetry::new(true));
        p.set_telemetry(std::sync::Arc::clone(&tel));
        let a = p.register();
        p.set_live_bytes(a, 3000).unwrap();
        p.set_live_bytes(a, 500).unwrap();
        p.release(a);
        let h = tel.pool_occupancy_bytes.snapshot();
        assert_eq!(h.count(), 3, "grow, shrink, release each recorded");
        assert_eq!(h.max(), 3000);
        assert_eq!(h.min(), 0, "release records the post-release occupancy");

        // a disabled registry is dropped at set_telemetry
        let mut q = pool(1 << 20, 1024);
        let off = std::sync::Arc::new(crate::telemetry::Telemetry::new(false));
        q.set_telemetry(std::sync::Arc::clone(&off));
        let b = q.register();
        q.set_live_bytes(b, 100).unwrap();
        assert!(off.pool_occupancy_bytes.snapshot().is_empty());
    }

    #[test]
    fn peaks_are_monotone() {
        let mut p = pool(1 << 20, 1024);
        let a = p.register();
        p.set_live_bytes(a, 10_000).unwrap();
        p.set_live_bytes(a, 100).unwrap();
        let s = p.stats();
        assert_eq!(s.peak_live_bytes, 10_000);
        assert_eq!(s.peak_used_pages, 10);
        assert_eq!(s.live_bytes, 100);
    }
}
