//! Pressure-controller policy: who gives memory back, and how, when a
//! pool reservation cannot be satisfied.
//!
//! The ladder (orchestrated by `coordinator::engine::Engine::reclaim`):
//!
//!  1. **evict** idle prefix-cache entries (LRU; only entries no live
//!     sequence references — `prefix::PrefixCache::evict_lru`);
//!  2. **re-prune** a resident sequence's compressed regions to the next
//!     sparsity tier (decompress → magnitude-prune → recompress, pages
//!     shrink in place) — the response unstructured sparsity uniquely
//!     enables: the cache *degrades* instead of dying;
//!  3. **preempt** the youngest resident sequence back onto the
//!     admission queue (recompute-style preemption, FIFO re-entry);
//!  4. only then reject.
//!
//! This module holds the pure victim-selection policy so it can be
//! tested without an engine.

/// One resident sequence as the pressure controller sees it.
#[derive(Clone, Copy, Debug)]
pub struct ReclaimCandidate {
    /// Monotone admission stamp: lower = admitted earlier ("colder" —
    /// an older sequence has the largest compressed region and the most
    /// pruning headroom, so it is both the cheapest and the highest-yield
    /// re-prune target).
    pub admitted_seq: u64,
    /// Next re-prune tier index (== tiers.len() when exhausted).
    pub tier: usize,
    /// Private compressed-region bytes (excludes shared prefix pages).
    pub compressed_bytes: usize,
    /// False for sequences whose state cannot be re-pruned (dense
    /// policies, PJRT-backed device caches).
    pub reprunable: bool,
}

/// Next sparsity tier for a sequence currently at `tier`, skipping tiers
/// that would not actually raise sparsity above `current` (a K0.8 cache
/// gains nothing from a 0.75 tier). Returns `(new_tier_index, sparsity)`.
pub fn next_reprune_tier(tiers: &[f64], tier: usize, current: f64) -> Option<(usize, f64)> {
    for (i, &s) in tiers.iter().enumerate().skip(tier) {
        if s > current {
            return Some((i + 1, s));
        }
    }
    None
}

/// Pick the sequence to re-prune: the coldest (earliest-admitted)
/// candidate that still has tiers left and a non-empty compressed
/// region. Returns an index into `cands`.
pub fn pick_reprune_victim(cands: &[ReclaimCandidate], n_tiers: usize) -> Option<usize> {
    cands
        .iter()
        .enumerate()
        .filter(|(_, c)| c.reprunable && c.tier < n_tiers && c.compressed_bytes > 0)
        .min_by_key(|(_, c)| c.admitted_seq)
        .map(|(i, _)| i)
}

/// Pick the sequence to preempt: the youngest (latest-admitted)
/// candidate, excluding `protect` (the sequence whose reservation is
/// being satisfied must not be its own victim). Returns an index into
/// `cands`.
pub fn pick_preempt_victim(cands: &[ReclaimCandidate], protect: Option<u64>) -> Option<usize> {
    cands
        .iter()
        .enumerate()
        .filter(|(_, c)| Some(c.admitted_seq) != protect)
        .max_by_key(|(_, c)| c.admitted_seq)
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cand(stamp: u64, tier: usize, bytes: usize) -> ReclaimCandidate {
        ReclaimCandidate { admitted_seq: stamp, tier, compressed_bytes: bytes, reprunable: true }
    }

    #[test]
    fn tier_ladder_skips_non_raising_steps() {
        let tiers = [0.75, 0.9];
        assert_eq!(next_reprune_tier(&tiers, 0, 0.5), Some((1, 0.75)));
        // already sparser than tier 0: jump straight to 0.9
        assert_eq!(next_reprune_tier(&tiers, 0, 0.8), Some((2, 0.9)));
        assert_eq!(next_reprune_tier(&tiers, 2, 0.5), None);
        assert_eq!(next_reprune_tier(&tiers, 0, 0.95), None);
    }

    #[test]
    fn reprune_picks_coldest_with_headroom() {
        let cands = [cand(5, 0, 1000), cand(2, 0, 500), cand(1, 2, 900), cand(3, 1, 0)];
        // stamp 1 is exhausted (tier 2 of 2), stamp 3 has nothing
        // compressed; stamp 2 is the coldest remaining.
        assert_eq!(pick_reprune_victim(&cands, 2), Some(1));
        // nothing eligible
        assert_eq!(pick_reprune_victim(&cands[2..], 2), None);
    }

    #[test]
    fn non_reprunable_states_are_skipped() {
        let mut c = cand(1, 0, 1000);
        c.reprunable = false;
        assert_eq!(pick_reprune_victim(&[c], 2), None);
    }

    #[test]
    fn preempt_picks_youngest_and_respects_protect() {
        let cands = [cand(5, 0, 0), cand(9, 0, 0), cand(2, 0, 0)];
        assert_eq!(pick_preempt_victim(&cands, None), Some(1));
        assert_eq!(pick_preempt_victim(&cands, Some(9)), Some(0));
        assert_eq!(pick_preempt_victim(&cands[..1], Some(5)), None);
    }
}
