//! Prefill prefix cache: compressed prompt prefixes shared across
//! requests as refcounted immutable pages.
//!
//! Keys are a **hash chain** over prompt tokens: `h_i = mix(h_{i-1},
//! tok_i)`, so one left-to-right pass yields a key for every 64-token
//! group boundary plus the full prompt. Two entry kinds:
//!
//!  * **full** — keyed by the whole prompt's chain hash; stores the
//!    shared compressed prefix, this prompt's binary16 dense tails, and
//!    the first greedy token. A hit reconstructs the exact post-prefill
//!    state (`SequenceKV::restore_full`), so decode is token-identical
//!    to the cold path and the entire prefill is skipped.
//!  * **partial** — keyed by the chain hash at the prefix's group
//!    boundary; stores only the shared compressed prefix. A hit reuses
//!    the prefix pages and rebuilds just the prompt suffix through the
//!    decode path (chunked prefill over the compressed prefix).
//!
//! Sharing is sound because token-local pruning (per-token magnitude)
//! plus causal attention make the compressed form of a prompt prefix
//! byte-identical under every prompt extending it
//! (`KvPolicy::prefix_shareable`); candidate hits are verified against
//! the stored tokens, so hash collisions degrade to misses. Entries
//! charge their exact byte footprint to the `KvPool`; shared prefix
//! pages are charged once regardless of how many sequences reference
//! them. Eviction is LRU and refcount-safe: a prefix still referenced
//! by a live sequence is never dropped (its pages would not actually be
//! freed), which doubles as the copy-on-write guarantee — shared pages
//! outlive the cache entry while anyone still reads them.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use crate::kvcache::SharedPrefix;
use crate::kvpool::{KvPool, OwnerId};
use crate::sparse::TILE;

/// Chain-hash seed (FNV-1a offset basis).
pub const CHAIN_SEED: u64 = 0xcbf29ce484222325;

/// One chain step: fold the next token into the running hash.
#[inline]
pub fn chain_push(h: u64, tok: u16) -> u64 {
    let mut x = (h ^ tok as u64).wrapping_mul(0x100000001b3);
    x ^= x >> 29;
    x.wrapping_mul(0xbf58476d1ce4e5b9)
}

/// Chain hash of a whole token slice.
pub fn chain_hash(tokens: &[u16]) -> u64 {
    tokens.iter().fold(CHAIN_SEED, |h, &t| chain_push(h, t))
}

/// Successful lookup.
pub enum PrefixHit {
    /// Exact prompt match: full post-prefill state, token-identical to
    /// the cold path.
    Full {
        prefix: Arc<SharedPrefix>,
        tail_k: Vec<Vec<u16>>,
        tail_v: Vec<Vec<u16>>,
        first_token: u16,
    },
    /// Shared compressed prefix covering `prefix.tokens` prompt tokens;
    /// the caller rebuilds the suffix through the decode path.
    Partial { prefix: Arc<SharedPrefix> },
}

struct FullEntry {
    prompt: Vec<u16>,
    prefix: Arc<SharedPrefix>,
    tail_k: Vec<Vec<u16>>,
    tail_v: Vec<Vec<u16>>,
    first_token: u16,
    owner: OwnerId,
    last_used: u64,
    /// Wall-clock of the last insert/refresh/hit, for TTL decay.
    last_touch: Instant,
}

impl FullEntry {
    /// Exact private footprint (the shared prefix is charged by its
    /// partial entry): tails + prompt bookkeeping.
    fn bytes(&self) -> usize {
        let tails: usize = self
            .tail_k
            .iter()
            .chain(self.tail_v.iter())
            .map(|t| std::mem::size_of_val(t.as_slice()))
            .sum();
        tails + std::mem::size_of_val(self.prompt.as_slice())
    }
}

struct PartialEntry {
    /// The covered prompt tokens (hit verification).
    tokens: Vec<u16>,
    prefix: Arc<SharedPrefix>,
    owner: OwnerId,
    last_used: u64,
    /// Wall-clock of the last insert/refresh/hit, for TTL decay.
    last_touch: Instant,
}

impl PartialEntry {
    fn bytes(&self) -> usize {
        self.prefix.bytes() + std::mem::size_of_val(self.tokens.as_slice())
    }
}

/// The cache proper. All mutation goes through the engine thread, so no
/// interior locking; the shared payloads are `Arc<SharedPrefix>`.
pub struct PrefixCache {
    enabled: bool,
    full: HashMap<u64, FullEntry>,
    partial: HashMap<u64, PartialEntry>,
    clock: u64,
    /// Cache-private byte cap, separate from the pool budget
    /// (0 = bounded only by the pool). Enforced by `make_room`.
    capacity_bytes: usize,
    /// Idle-entry TTL in milliseconds (0 = entries never expire).
    /// Enforced by `expire_idle`, which the engine calls on its step
    /// path.
    ttl_ms: u64,
    /// Entries dropped under pressure or to make room for newer ones.
    pub evictions: usize,
    /// Entries dropped by TTL decay (`expire_idle`), counted apart from
    /// pressure `evictions` so callers watching eviction deltas for
    /// capacity pressure are not confused by idle decay.
    pub ttl_evictions: usize,
}

impl PrefixCache {
    /// Unlimited cache (no byte cap beyond the pool, no TTL).
    pub fn new(enabled: bool) -> PrefixCache {
        PrefixCache::with_limits(enabled, 0, 0)
    }

    /// Cache with its own byte capacity (0 = bounded only by the pool)
    /// and an idle-entry TTL in milliseconds (0 = no TTL).
    pub fn with_limits(enabled: bool, capacity_bytes: usize, ttl_ms: u64) -> PrefixCache {
        PrefixCache {
            enabled,
            full: HashMap::new(),
            partial: HashMap::new(),
            clock: 0,
            capacity_bytes,
            ttl_ms,
            evictions: 0,
            ttl_evictions: 0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.enabled
    }

    pub fn len(&self) -> usize {
        self.full.len() + self.partial.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn tick(&mut self) -> u64 {
        self.clock += 1;
        self.clock
    }

    /// Non-mutating probe: is there an exact full-prompt entry? Used by
    /// admission gating to avoid reclaiming (evicting / re-pruning) for
    /// a whole-prompt estimate when the hit will only charge tails.
    pub fn has_full(&self, prompt: &[u16]) -> bool {
        self.enabled
            && self.full.get(&chain_hash(prompt)).is_some_and(|e| e.prompt == prompt)
    }

    /// Longest usable cached state for `prompt`: an exact full-prompt
    /// entry, else the longest verified group-boundary prefix no longer
    /// than `prompt.len() - 1` (at least one suffix token must remain to
    /// produce the first logits) and within what prefill would compress
    /// (`prompt.len() - local_window`, rounded down to a group). Partial
    /// hits must cover at least half the prompt: rebuilding the suffix
    /// runs token-by-token through the decode path, so a short shared
    /// prefix on a long prompt would cost more than the batched cold
    /// prefill it replaces.
    pub fn lookup(&mut self, prompt: &[u16], local_window: usize) -> Option<PrefixHit> {
        if !self.enabled || prompt.is_empty() {
            return None;
        }
        // one pass: boundary hashes + full hash
        let mut boundary = Vec::with_capacity(prompt.len() / TILE);
        let mut h = CHAIN_SEED;
        for (i, &t) in prompt.iter().enumerate() {
            h = chain_push(h, t);
            if (i + 1) % TILE == 0 {
                boundary.push(h); // hash of prompt[..i+1]
            }
        }
        let now = self.tick();
        let wall = Instant::now();

        if let Some(e) = self.full.get_mut(&h) {
            if e.prompt == prompt {
                e.last_used = now;
                e.last_touch = wall;
                if let Some(p) = self.partial.get_mut(&chain_hash(&prompt[..e.prefix.tokens])) {
                    p.last_used = now; // keep the backing prefix warm too
                    p.last_touch = wall;
                }
                return Some(PrefixHit::Full {
                    prefix: Arc::clone(&e.prefix),
                    tail_k: e.tail_k.clone(),
                    tail_v: e.tail_v.clone(),
                    first_token: e.first_token,
                });
            }
        }

        let b_max = prompt.len().saturating_sub(local_window).min(prompt.len() - 1) / TILE * TILE;
        // minimum-coverage gate (see doc comment): suffix ≤ prefix
        let b_min = TILE.max(prompt.len().div_ceil(2));
        let mut b = b_max;
        while b >= b_min {
            let key = boundary[b / TILE - 1];
            if let Some(e) = self.partial.get_mut(&key) {
                if e.tokens.len() == b && e.tokens[..] == prompt[..b] {
                    e.last_used = now;
                    e.last_touch = wall;
                    return Some(PrefixHit::Partial { prefix: Arc::clone(&e.prefix) });
                }
            }
            b -= TILE;
        }
        None
    }

    /// Cache a cold prefill: the shared compressed prefix under its
    /// group-boundary key, and the full post-prefill state under the
    /// whole-prompt key. Charges exact bytes to the pool, evicting idle
    /// LRU entries to make room.
    ///
    /// Returns the *canonical* pool-charged prefix `Arc` the caller's
    /// sequence must reference — when an identical partial entry already
    /// exists (e.g. a prior prompt shared the prefix but the coverage
    /// gate blocked a partial hit), that existing allocation is returned
    /// and the freshly built duplicate is dropped, so no unaccounted
    /// prefix copy outlives this call. `None` means the pool could not
    /// host the prefix: nothing was cached and the caller must keep its
    /// state fully private (every byte needs exactly one owner).
    pub fn insert(
        &mut self,
        prompt: &[u16],
        prefix: Arc<SharedPrefix>,
        tail_k: &[Vec<u16>],
        tail_v: &[Vec<u16>],
        first_token: u16,
        pool: &mut KvPool,
    ) -> Option<Arc<SharedPrefix>> {
        if !self.enabled {
            return None;
        }
        let now = self.tick();
        let wall = Instant::now();
        let b = prefix.tokens;
        debug_assert!(b <= prompt.len());
        let mut prefix = prefix;

        if b > 0 {
            let key = chain_hash(&prompt[..b]);
            let exists = self
                .partial
                .get(&key)
                .is_some_and(|e| e.tokens[..] == prompt[..b]);
            if exists {
                let e = self.partial.get_mut(&key).unwrap();
                e.last_used = now;
                e.last_touch = wall;
                // dedup: reuse the charged allocation, drop the duplicate
                prefix = Arc::clone(&e.prefix);
            } else {
                if let Some(old) = self.partial.get(&key) {
                    // chain-hash collision (different tokens, same key).
                    // Replaceable only if nothing references the old
                    // prefix — releasing its charge while a full entry
                    // or live sequence still pins the Arc would leave
                    // resident pages accounted to no owner.
                    if Arc::strong_count(&old.prefix) != 1 {
                        return None;
                    }
                    let old = self.partial.remove(&key).unwrap();
                    pool.release(old.owner);
                    self.evictions += 1;
                }
                let entry = PartialEntry {
                    tokens: prompt[..b].to_vec(),
                    prefix: Arc::clone(&prefix),
                    owner: pool.register(),
                    last_used: now,
                    last_touch: wall,
                };
                let bytes = entry.bytes();
                if !self.make_room(pool, bytes) || pool.set_live_bytes(entry.owner, bytes).is_err()
                {
                    pool.release(entry.owner);
                    return None;
                }
                self.partial.insert(key, entry);
            }
        }

        let key = chain_hash(prompt);
        if let Some(e) = self.full.get_mut(&key) {
            if e.prompt == prompt {
                e.last_used = now;
                e.last_touch = wall;
                return Some(prefix);
            }
            let old = self.full.remove(&key).unwrap();
            pool.release(old.owner);
            self.evictions += 1;
        }
        let entry = FullEntry {
            prompt: prompt.to_vec(),
            prefix: Arc::clone(&prefix),
            tail_k: tail_k.to_vec(),
            tail_v: tail_v.to_vec(),
            first_token,
            owner: pool.register(),
            last_used: now,
            last_touch: wall,
        };
        let bytes = entry.bytes();
        if !self.make_room(pool, bytes) || pool.set_live_bytes(entry.owner, bytes).is_err() {
            pool.release(entry.owner);
            // the charged partial (if any) stays and is still the
            // canonical prefix for the caller's sequence — only for
            // prefix-less prompts (b == 0) is there nothing cached
            return if b > 0 { Some(prefix) } else { None };
        }
        self.full.insert(key, entry);
        Some(prefix)
    }

    /// True when `bytes` more cache bytes would exceed the cache's own
    /// capacity cap. Recomputed from `measured_bytes` so the check can
    /// never drift from the real footprint.
    fn over_capacity(&self, bytes: usize) -> bool {
        self.capacity_bytes > 0 && self.measured_bytes() + bytes > self.capacity_bytes
    }

    fn make_room(&mut self, pool: &mut KvPool, bytes: usize) -> bool {
        while !pool.fits_extra(bytes) || self.over_capacity(bytes) {
            if !self.evict_lru(pool) {
                return false;
            }
        }
        true
    }

    /// TTL sweep: drop every entry idle longer than `ttl_ms` and free
    /// its pages, returning how many entries were evicted. Expired full
    /// entries go first — they are always droppable and may be the sole
    /// pin keeping a sibling partial's `Arc` count above one — then
    /// expired partials whose prefix nothing else references (a partial
    /// still pinned by a live sequence or a fresh full entry stays; it
    /// will expire on a later sweep once unpinned, exactly like LRU
    /// eviction). No-op when `ttl_ms` is 0.
    pub fn expire_idle(&mut self, pool: &mut KvPool) -> usize {
        if self.ttl_ms == 0 || self.is_empty() {
            return 0;
        }
        let now = Instant::now();
        let ttl = self.ttl_ms;
        let expired = move |touch: Instant| now.duration_since(touch).as_millis() as u64 > ttl;
        let mut dropped = 0;

        let stale: Vec<u64> = self
            .full
            .iter()
            .filter(|(_, e)| expired(e.last_touch))
            .map(|(&k, _)| k)
            .collect();
        for k in stale {
            let e = self.full.remove(&k).unwrap();
            pool.release(e.owner);
            dropped += 1;
        }
        let stale: Vec<u64> = self
            .partial
            .iter()
            .filter(|(_, e)| expired(e.last_touch) && Arc::strong_count(&e.prefix) == 1)
            .map(|(&k, _)| k)
            .collect();
        for k in stale {
            let e = self.partial.remove(&k).unwrap();
            pool.release(e.owner);
            dropped += 1;
        }
        self.ttl_evictions += dropped;
        dropped
    }

    /// Drop the least-recently-used *idle* entry and free its pages.
    /// Full entries are always droppable (their tails are private);
    /// a partial entry is droppable only when no live sequence and no
    /// full entry still references its prefix — evicting it earlier
    /// would free nothing (the `Arc` keeps the pages alive) and would
    /// break the pool's exact accounting. Returns false when nothing
    /// is reclaimable.
    pub fn evict_lru(&mut self, pool: &mut KvPool) -> bool {
        enum Kind {
            Full(u64),
            Partial(u64),
        }
        let mut best: Option<(u64, Kind)> = None;
        for (&k, e) in &self.full {
            if best.as_ref().is_none_or(|(t, _)| e.last_used < *t) {
                best = Some((e.last_used, Kind::Full(k)));
            }
        }
        for (&k, e) in &self.partial {
            // droppable only when this entry holds the sole reference:
            // a live sequence or a sibling full entry would keep the
            // pages alive, so "freeing" them would only corrupt the
            // accounting (the full entry unblocks it once evicted).
            if Arc::strong_count(&e.prefix) != 1 {
                continue;
            }
            if best.as_ref().is_none_or(|(t, _)| e.last_used < *t) {
                best = Some((e.last_used, Kind::Partial(k)));
            }
        }
        match best {
            Some((_, Kind::Full(k))) => {
                let e = self.full.remove(&k).unwrap();
                pool.release(e.owner);
                self.evictions += 1;
                true
            }
            Some((_, Kind::Partial(k))) => {
                let e = self.partial.remove(&k).unwrap();
                pool.release(e.owner);
                self.evictions += 1;
                true
            }
            None => false,
        }
    }

    /// Recompute the cache's exact byte footprint from its actual
    /// buffers (the figure its pool charges must equal — asserted by
    /// the accounting tests).
    pub fn measured_bytes(&self) -> usize {
        self.full.values().map(|e| e.bytes()).sum::<usize>()
            + self.partial.values().map(|e| e.bytes()).sum::<usize>()
    }

    /// Partial entries currently pinned from *outside* the cache (live
    /// sequences holding the `Arc`; references from sibling full
    /// entries are internal and excluded). A cancelled or finished
    /// sequence must *decref* its shared prefix — dropping its
    /// `SequenceKV` — without freeing the cache-charged pages; this
    /// probe lets the cancellation tests assert exactly that: the entry
    /// count and pool charge are unchanged while the pin count falls
    /// back to zero.
    pub fn pinned_partial_entries(&self) -> usize {
        self.partial
            .values()
            .filter(|e| {
                let internal =
                    1 + self.full.values().filter(|f| Arc::ptr_eq(&f.prefix, &e.prefix)).count();
                Arc::strong_count(&e.prefix) > internal
            })
            .count()
    }

    /// Sum of this cache's live-byte charges in the pool.
    pub fn charged_bytes(&self, pool: &KvPool) -> usize {
        self.full.values().map(|e| pool.owner_live_bytes(e.owner)).sum::<usize>()
            + self.partial.values().map(|e| pool.owner_live_bytes(e.owner)).sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::{build_shared_prefill, KvPolicy};
    use crate::kvpool::PoolConfig;
    use crate::util::Pcg32;

    fn heads(n: usize, t: usize, hd: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| (0..t * hd).map(|_| rng.normal_f32()).collect()).collect()
    }

    fn built(
        prompt_len: usize,
        seed: u64,
    ) -> (Vec<u16>, Arc<SharedPrefix>, Vec<Vec<u16>>, Vec<Vec<u16>>) {
        let policy = KvPolicy::mustafar(0.5, 0.5);
        let (l, kv, hd) = (2, 1, 32);
        let k = heads(l * kv, prompt_len, hd, seed);
        let v = heads(l * kv, prompt_len, hd, seed + 1);
        let (p, tk, tv) = build_shared_prefill(&policy, l, kv, hd, &k, &v, prompt_len).unwrap();
        let prompt: Vec<u16> =
            (0..prompt_len).map(|i| ((seed as usize + i * 7) % 400 + 16) as u16).collect();
        (prompt, Arc::new(p), tk, tv)
    }

    fn pool() -> KvPool {
        KvPool::new(PoolConfig { budget_bytes: 0, page_bytes: 1024 })
    }

    #[test]
    fn chain_hash_is_prefix_consistent() {
        let a = [1u16, 2, 3, 4];
        let h2 = chain_hash(&a[..2]);
        assert_eq!(chain_push(chain_push(h2, 3), 4), chain_hash(&a));
        assert_ne!(chain_hash(&[1, 2]), chain_hash(&[2, 1]));
    }

    #[test]
    fn full_hit_roundtrip_and_partial_probe() {
        let mut c = PrefixCache::new(true);
        let mut p = pool();
        let (prompt, prefix, tk, tv) = built(160, 7);
        assert_eq!(prefix.tokens, 128);
        assert!(c.insert(&prompt, Arc::clone(&prefix), &tk, &tv, 42, &mut p).is_some());
        assert_eq!(c.len(), 2); // full + partial

        // exact prompt: full hit with the stored first token
        match c.lookup(&prompt, 32) {
            Some(PrefixHit::Full { first_token, prefix: fp, .. }) => {
                assert_eq!(first_token, 42);
                assert!(Arc::ptr_eq(&fp, &prefix));
            }
            _ => panic!("expected full hit"),
        }

        // an extending prompt: partial hit on the 128-token boundary
        let mut longer = prompt.clone();
        longer.extend((0..96).map(|i| (i % 100 + 20) as u16));
        match c.lookup(&longer, 32) {
            Some(PrefixHit::Partial { prefix: pp }) => {
                assert_eq!(pp.tokens, 128);
                assert!(Arc::ptr_eq(&pp, &prefix));
            }
            _ => panic!("expected partial hit"),
        }

        // a diverging prompt: miss (verification beats hash luck)
        let mut diverged = prompt.clone();
        diverged[10] ^= 1;
        assert!(c.lookup(&diverged, 32).is_none());
    }

    #[test]
    fn pool_charge_matches_measured_bytes() {
        let mut c = PrefixCache::new(true);
        let mut p = pool();
        for seed in 0..4 {
            let (prompt, prefix, tk, tv) = built(96 + 64 * seed as usize, 100 + seed);
            c.insert(&prompt, prefix, &tk, &tv, 1, &mut p);
        }
        assert_eq!(p.stats().live_bytes, c.measured_bytes());
        assert_eq!(c.charged_bytes(&p), c.measured_bytes());
        // evict everything; the pool must drain to zero
        while c.evict_lru(&mut p) {}
        assert_eq!(c.len(), 0);
        assert_eq!(p.stats().live_bytes, 0);
        assert_eq!(p.stats().used_pages, 0);
    }

    #[test]
    fn eviction_is_lru_and_refcount_safe() {
        let mut c = PrefixCache::new(true);
        let mut p = pool();
        let (prompt_a, prefix_a, tka, tva) = built(160, 11);
        let (prompt_b, prefix_b, tkb, tvb) = built(160, 23);
        let b_key = chain_hash(&prompt_b[..prefix_b.tokens]);
        c.insert(&prompt_a, prefix_a, &tka, &tva, 1, &mut p);
        c.insert(&prompt_b, prefix_b, &tkb, &tvb, 2, &mut p);

        // hold a "live sequence" reference to B's prefix, as the engine
        // would after a hit
        let held = match c.lookup(&prompt_b, 32) {
            Some(PrefixHit::Full { prefix, .. }) => prefix,
            _ => panic!("expected full hit"),
        };
        // touch A so B's entries are the LRU
        c.lookup(&prompt_a, 32);

        let before = c.len();
        assert!(c.evict_lru(&mut p)); // B full (tails are private) goes
        assert_eq!(c.len(), before - 1);
        // B partial is pinned by `held`: the next LRU eviction must pick
        // one of A's entries instead of freeing pages someone still
        // reads.
        assert!(c.evict_lru(&mut p));
        assert!(c.partial.contains_key(&b_key), "pinned prefix was evicted");
        drop(held);
        // now everything drains and the pool empties exactly
        while c.evict_lru(&mut p) {}
        assert_eq!(c.len(), 0);
        assert_eq!(p.stats().live_bytes, 0);
    }


    #[test]
    fn insert_dedups_prefix_against_existing_partial_entry() {
        // Two 144-token prompts share their first 64 tokens. The
        // coverage gate (b_min = 72 > 64) blocks a partial hit for the
        // second, so its cold prefill builds a duplicate prefix; insert
        // must hand back the *charged* allocation and drop the
        // duplicate, or real memory silently exceeds the accounting.
        let mut c = PrefixCache::new(true);
        let mut p = pool();
        let policy = KvPolicy::mustafar(0.5, 0.5);
        let (l, kv, hd, t) = (1, 1, 32, 144);
        let shared: Vec<u16> = (0..64).map(|i| (i * 5 % 300 + 16) as u16).collect();
        let mk_prompt = |salt: u16| {
            let mut v = shared.clone();
            v.extend((0..t as u16 - 64).map(|i| (i * 7 + salt) % 300 + 16));
            v
        };
        let build = |seed: u64| {
            let ka = heads(l * kv, t, hd, seed);
            let va = heads(l * kv, t, hd, seed + 1);
            build_shared_prefill(&policy, l, kv, hd, &ka, &va, t).unwrap()
        };

        let prompt_a = mk_prompt(1);
        let (pa, tka, tva) = build(500);
        assert_eq!(pa.tokens, 64);
        let arc_a = Arc::new(pa);
        let got_a = c.insert(&prompt_a, Arc::clone(&arc_a), &tka, &tva, 1, &mut p).unwrap();
        assert!(Arc::ptr_eq(&got_a, &arc_a));

        let prompt_b = mk_prompt(2);
        assert!(c.lookup(&prompt_b, 32).is_none(), "coverage gate should block this hit");
        let (pb, tkb, tvb) = build(600);
        let arc_b = Arc::new(pb);
        let got_b = c.insert(&prompt_b, Arc::clone(&arc_b), &tkb, &tvb, 2, &mut p).unwrap();
        assert!(Arc::ptr_eq(&got_b, &arc_a), "canonical charged prefix expected");
        assert!(!Arc::ptr_eq(&got_b, &arc_b), "duplicate prefix must be dropped");

        // exactly one partial entry charged; accounting stays exact
        assert_eq!(c.len(), 3); // 2 full + 1 shared partial
        assert_eq!(p.stats().live_bytes, c.measured_bytes());
    }

    #[test]
    fn partial_hit_lineage_gets_longer_hits() {
        // Satellite acceptance: once partial-hit sequences re-insert
        // their extended state (as the engine now does after the suffix
        // rebuild), the *second* partial hit down a lineage of
        // ever-longer prompts covers the extended boundary instead of
        // re-prefilling the tail against the original one.
        let mut c = PrefixCache::new(true);
        let mut p = pool();
        // cold insert: 160-token prompt, prefix boundary 128
        let (prompt1, prefix1, tk1, tv1) = built(160, 33);
        assert_eq!(prefix1.tokens, 128);
        assert!(c.insert(&prompt1, Arc::clone(&prefix1), &tk1, &tv1, 1, &mut p).is_some());

        // extended prompt: the first partial hit covers only 128
        let (prompt2, prefix2, tk2, tv2) = built(224, 33);
        assert_eq!(&prompt2[..160], &prompt1[..]);
        match c.lookup(&prompt2, 32) {
            Some(PrefixHit::Partial { prefix }) => assert_eq!(prefix.tokens, 128),
            _ => panic!("expected partial hit"),
        }
        // ... after which the engine rebuilds the suffix and inserts the
        // extended coverage ((224 - 32) rounded down to a group = 192)
        assert_eq!(prefix2.tokens, 192);
        assert!(c.insert(&prompt2, Arc::clone(&prefix2), &tk2, &tv2, 2, &mut p).is_some());

        // a further-extended prompt now gets the *longer* prefix
        let (prompt3, _, _, _) = built(288, 33);
        assert_eq!(&prompt3[..224], &prompt2[..]);
        match c.lookup(&prompt3, 32) {
            Some(PrefixHit::Partial { prefix }) => {
                assert_eq!(prefix.tokens, 192, "second partial hit should be longer");
                assert!(Arc::ptr_eq(&prefix, &prefix2));
            }
            _ => panic!("expected partial hit"),
        }
        // and an exact repeat of the partial-hit prompt is a full hit
        assert!(matches!(c.lookup(&prompt2, 32), Some(PrefixHit::Full { .. })));
        // accounting stays exact with the lineage entries in place
        assert_eq!(p.stats().live_bytes, c.measured_bytes());
    }

    #[test]
    fn dropping_a_holder_unpins_without_freeing_pages() {
        // The cancellation contract at the cache level: a sequence that
        // goes away (cancel, finish) drops its Arc — the partial entry
        // stays resident and charged, only its external pin count falls,
        // so the pages become reclaimable by LRU eviction instead of
        // leaking or being freed out from under the cache's accounting.
        let mut c = PrefixCache::new(true);
        let mut p = pool();
        let (prompt, prefix, tk, tv) = built(160, 41);
        let canonical = c.insert(&prompt, Arc::clone(&prefix), &tk, &tv, 7, &mut p).unwrap();
        drop(prefix);
        let charged = p.stats().live_bytes;
        // `canonical` plays the live sequence's reference
        assert_eq!(c.pinned_partial_entries(), 1);
        drop(canonical);
        // decref: nothing freed, nothing evicted — just unpinned (the
        // sibling full entry's reference is internal, not a pin)
        assert_eq!(c.pinned_partial_entries(), 0);
        assert_eq!(c.len(), 2);
        assert_eq!(p.stats().live_bytes, charged, "decref must not free pages");
        assert_eq!(p.stats().live_bytes, c.measured_bytes(), "accounting exact throughout");
        // with no outside holder the whole lineage is reclaimable (the
        // full entry first — it blocks the partial while it holds the Arc)
        assert!(c.evict_lru(&mut p));
        assert!(c.evict_lru(&mut p));
        assert_eq!(c.len(), 0);
        assert_eq!(p.stats().live_bytes, 0);
    }

    #[test]
    fn disabled_cache_is_inert() {
        let mut c = PrefixCache::new(false);
        let mut p = pool();
        let (prompt, prefix, tk, tv) = built(160, 5);
        assert!(c.insert(&prompt, prefix, &tk, &tv, 0, &mut p).is_none());
        assert!(c.lookup(&prompt, 32).is_none());
        assert_eq!(p.stats().live_bytes, 0);
    }

    #[test]
    fn capacity_knob_bounds_cache_bytes() {
        // measure one lineage's exact footprint in an unlimited cache
        let mut probe = PrefixCache::new(true);
        let mut p = pool();
        let (prompt_a, prefix_a, tka, tva) = built(160, 51);
        probe.insert(&prompt_a, prefix_a, &tka, &tva, 1, &mut p);
        let one = probe.measured_bytes();
        while probe.evict_lru(&mut p) {}
        assert_eq!(p.stats().live_bytes, 0);

        // capacity for ~1.5 lineages: caching a second prompt must
        // LRU-evict the first to stay under the cache's own cap, even
        // though the pool budget (unlimited here) would happily fit both
        let cap = one + one / 2;
        let mut c = PrefixCache::with_limits(true, cap, 0);
        let (prompt_a, prefix_a, tka, tva) = built(160, 51);
        let (prompt_b, prefix_b, tkb, tvb) = built(160, 52);
        assert!(c.insert(&prompt_a, prefix_a, &tka, &tva, 1, &mut p).is_some());
        assert!(c.insert(&prompt_b, prefix_b, &tkb, &tvb, 2, &mut p).is_some());
        assert!(c.measured_bytes() <= cap, "capacity cap must hold after insert");
        assert!(c.evictions >= 1, "second lineage must evict under the cap");
        assert_eq!(p.stats().live_bytes, c.measured_bytes(), "accounting exact under the cap");
        // the newer lineage is the one that survived
        assert!(matches!(c.lookup(&prompt_b, 32), Some(PrefixHit::Full { .. })));
    }

    #[test]
    fn ttl_decay_expires_idle_entries_and_respects_pins() {
        let mut c = PrefixCache::with_limits(true, 0, 25);
        let mut p = pool();
        let (prompt, prefix, tk, tv) = built(160, 61);
        let canonical = c.insert(&prompt, Arc::clone(&prefix), &tk, &tv, 1, &mut p).unwrap();
        drop(prefix);
        assert_eq!(c.expire_idle(&mut p), 0, "fresh entries must not expire");

        std::thread::sleep(std::time::Duration::from_millis(60));
        // the partial is pinned by `canonical` (a live sequence's
        // reference): only the full entry may expire on this sweep
        assert_eq!(c.expire_idle(&mut p), 1);
        assert_eq!(c.len(), 1);
        assert_eq!(p.stats().live_bytes, c.measured_bytes(), "accounting exact after sweep");
        drop(canonical);
        // unpinned now: the next sweep drains the partial and the pool
        assert_eq!(c.expire_idle(&mut p), 1);
        assert_eq!(c.ttl_evictions, 2);
        assert_eq!(c.evictions, 0, "TTL decay must not count as pressure eviction");
        assert_eq!(c.len(), 0);
        assert_eq!(p.stats().live_bytes, 0);
        assert_eq!(p.stats().used_pages, 0);
    }

    #[test]
    fn short_prompt_full_entry_without_prefix() {
        // prompts too short to compress still cache their full state
        let mut c = PrefixCache::new(true);
        let mut p = pool();
        let (prompt, prefix, tk, tv) = built(48, 9);
        assert_eq!(prefix.tokens, 0);
        assert!(c.insert(&prompt, prefix, &tk, &tv, 3, &mut p).is_some());
        assert_eq!(c.len(), 1); // no partial entry
        assert!(matches!(c.lookup(&prompt, 32), Some(PrefixHit::Full { .. })));
    }
}
