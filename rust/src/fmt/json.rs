//! Minimal JSON parser/serializer (serde is not available offline).
//!
//! Supports the full JSON grammar minus exotic number forms; numbers are
//! held as f64 (adequate for our manifests and reports). Used for weight
//! manifests, artifact indexes, experiment reports, and config files.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.i)));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).ok_or_else(|| Error::Json(format!("missing key '{key}'"))),
            _ => Err(Error::Json(format!("not an object (key '{key}')"))),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => Err(Error::Json("not a number".into())),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            return Err(Error::Json(format!("not a usize: {x}")));
        }
        Ok(x as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => Err(Error::Json("not a string".into())),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => Err(Error::Json("not a bool".into())),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => Err(Error::Json("not an array".into())),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => Err(Error::Json("not an object".into())),
        }
    }

    /// usize vector from a numeric array.
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // -- construction helpers ----------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn arr(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 1-space indent (matches python json.dump).
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().ok_or_else(|| Error::Json("unexpected end".into()))
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            return Err(Error::Json(format!(
                "expected '{}' at byte {}, found '{}'",
                c as char, self.i, self.b[self.i] as char
            )));
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.i)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                c => return Err(Error::Json(format!("expected , or }} found '{}'", c as char))),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                c => return Err(Error::Json(format!("expected , or ] found '{}'", c as char))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(Error::Json("bad \\u escape".into()));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| Error::Json("bad \\u escape".into()))?;
                            self.i += 4;
                            // Surrogate pairs are not needed by our manifests;
                            // map lone surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(Error::Json("bad escape".into())),
                    }
                }
                c => {
                    // continue multi-byte utf8 sequences verbatim
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.i - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.b.len() {
                            return Err(Error::Json("bad utf8".into()));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| Error::Json("bad utf8".into()))?;
                        s.push_str(chunk);
                        self.i = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number '{text}' at byte {start}")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str().unwrap(),
            "x\ny"
        );
        assert_eq!(v.get("c").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_python_manifest_style() {
        let text = r#"{
 "name": "tiny",
 "params": [
  {"name": "tok_emb", "shape": [512, 64], "offset": 0, "nbytes": 131072}
 ],
 "rope_theta": 10000.0
}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("name").unwrap().as_str().unwrap(), "tiny");
        let p0 = &v.get("params").unwrap().as_arr().unwrap()[0];
        assert_eq!(p0.get("shape").unwrap().as_usize_vec().unwrap(), vec![512, 64]);
        assert_eq!(v.get("rope_theta").unwrap().as_f64().unwrap(), 10000.0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("a\"b\\c\nd\te\u{1}".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo → 世界\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn pretty_output_reparses() {
        let v = Json::obj(vec![
            ("x", Json::num(1.0)),
            ("y", Json::arr(vec![Json::str("a"), Json::Bool(true)])),
        ]);
        assert_eq!(Json::parse(&v.to_pretty()).unwrap(), v);
    }
}
