//! Serialization helpers: minimal JSON and markdown table rendering.

pub mod json;
pub mod table;

pub use json::Json;
pub use table::Table;
