//! Markdown/ASCII table rendering for experiment reports — every paper
//! table/figure regenerator prints through this.

/// Simple column-aligned table builder.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells);
        self
    }

    /// Render as a markdown table with aligned columns.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = width[i].max(h.chars().count());
        }
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                width[i] = width[i].max(c.chars().count());
            }
        }
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("### {}\n\n", self.title));
        }
        let fmt_row = |cells: &[String]| -> String {
            let mut line = String::from("|");
            for (i, c) in cells.iter().enumerate() {
                line.push_str(&format!(" {:<w$} |", c, w = width[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row));
        }
        out
    }
}

/// Format an f64 with fixed decimals, for table cells.
pub fn fnum(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["task", "score"]);
        t.row(vec!["retrieval".into(), "43.19".into()]);
        t.row(vec!["qa".into(), "5.0".into()]);
        let out = t.render();
        assert!(out.contains("### Demo"));
        assert!(out.contains("| retrieval | 43.19 |"));
        // all rows same width
        let lines: Vec<&str> = out.lines().filter(|l| l.starts_with('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(1.23456, 2), "1.23");
        assert_eq!(fnum(2.0, 1), "2.0");
    }
}
