//! Deterministic fault injection for robustness testing.
//!
//! A fault *point* is a named site in the engine (`"kvpool.alloc"`,
//! `"seq.decode"`, ...) that asks its [`Injector`] whether to fail this
//! time. Points are armed from a spec string — usually the
//! `MUSTAFAR_FAULTS` environment variable — of comma-separated
//! `name:trigger` pairs, where a trigger is either a probability
//! (`kvpool.alloc:0.05` → fail ~5% of hits) or a counter
//! (`worker.task:after=200` → the first 200 hits pass, every later hit
//! fails). `MUSTAFAR_FAULT_SEED` fixes the probability draws.
//!
//! Two properties the chaos tests rely on:
//!
//! - **Zero-cost when disabled.** An injector built without a spec holds
//!   no state and `fire` returns `false` without taking a lock, so
//!   production binaries and fault-free tests behave byte-identically to
//!   a build without the subsystem.
//! - **Interleaving-independent determinism.** Each point owns its own
//!   PCG stream seeded from `seed ^ fnv1a(name)`, so whether a given hit
//!   of `seq.decode` fails depends only on the seed and that point's hit
//!   index — not on how many times other points fired in between, nor on
//!   worker-thread scheduling (each decision is taken under the lock).
//!
//! Injectors are handles: cloning shares the underlying counters, which
//! is what lets the engine and its kvpool draw from one stream and lets
//! a test read back `fired()` tallies after a run. Tests install
//! injectors programmatically via `Engine::set_fault_injector` rather
//! than through the environment, so parallel tests never interfere.

use std::sync::{Arc, Mutex};

use crate::error::{Error, Result};

/// How a fault point decides whether a given hit fails.
#[derive(Clone, Copy, Debug, PartialEq)]
enum Trigger {
    /// Fail each hit independently with this probability.
    Prob(f32),
    /// Hits `1..=n` pass; every hit after the first `n` fails.
    After(u64),
}

#[derive(Clone, Debug)]
struct FaultPoint {
    name: String,
    trigger: Trigger,
    /// Times this point was consulted.
    hits: u64,
    /// Times it answered "fail".
    fires: u64,
    rng: crate::util::Pcg32,
}

#[derive(Debug)]
struct Inner {
    points: Vec<FaultPoint>,
}

/// Tally of one fault point after a run: `(name, hits, fires)`.
pub type FaultReport = (String, u64, u64);

/// A handle to a set of armed fault points. Cheap to clone (shared
/// state); a default/disabled injector carries no allocation at all.
#[derive(Clone, Debug, Default)]
pub struct Injector {
    inner: Option<Arc<Mutex<Inner>>>,
}

/// FNV-1a, used to give each point a name-derived PCG stream.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

impl Injector {
    /// An injector with no armed points: every `fire` is `false`.
    pub fn disabled() -> Self {
        Injector { inner: None }
    }

    /// Parse a spec string (`"kvpool.alloc:0.05,worker.task:after=200"`)
    /// into an armed injector. An empty spec yields a disabled injector.
    pub fn parse(spec: &str, seed: u64) -> Result<Self> {
        let mut points = Vec::new();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let Some((name, trig)) = part.split_once(':') else {
                return Err(Error::Config(format!(
                    "fault spec entry '{part}' is not name:trigger"
                )));
            };
            let trigger = if let Some(n) = trig.strip_prefix("after=") {
                let n: u64 = n
                    .parse()
                    .map_err(|_| Error::Config(format!("fault spec '{part}': bad counter")))?;
                Trigger::After(n)
            } else {
                let p: f32 = trig
                    .parse()
                    .map_err(|_| Error::Config(format!("fault spec '{part}': bad probability")))?;
                if !(0.0..=1.0).contains(&p) {
                    return Err(Error::Config(format!(
                        "fault spec '{part}': probability outside [0, 1]"
                    )));
                }
                Trigger::Prob(p)
            };
            points.push(FaultPoint {
                name: name.to_string(),
                trigger,
                hits: 0,
                fires: 0,
                rng: crate::util::Pcg32::new(seed ^ fnv1a(name), 54),
            });
        }
        if points.is_empty() {
            return Ok(Self::disabled());
        }
        Ok(Injector { inner: Some(Arc::new(Mutex::new(Inner { points }))) })
    }

    /// Build from `MUSTAFAR_FAULTS` / `MUSTAFAR_FAULT_SEED`. Unset (or
    /// unparseable — a server should not die to a typo'd chaos knob)
    /// yields a disabled injector.
    pub fn from_env() -> Self {
        let Ok(spec) = std::env::var("MUSTAFAR_FAULTS") else {
            return Self::disabled();
        };
        let seed = std::env::var("MUSTAFAR_FAULT_SEED")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(0x5eed);
        Self::parse(&spec, seed).unwrap_or_else(|_| Self::disabled())
    }

    /// Whether any point is armed. Lets hot paths skip building fault
    /// payloads entirely when injection is off.
    pub fn enabled(&self) -> bool {
        self.inner.is_some()
    }

    /// Consult the point called `name`: returns `true` when the caller
    /// should fail this time. Unarmed names (and a disabled injector)
    /// always return `false`.
    pub fn fire(&self, name: &str) -> bool {
        let Some(inner) = &self.inner else {
            return false;
        };
        let mut inner = inner.lock().unwrap_or_else(|p| p.into_inner());
        let Some(p) = inner.points.iter_mut().find(|p| p.name == name) else {
            return false;
        };
        p.hits += 1;
        let fired = match p.trigger {
            Trigger::Prob(prob) => p.rng.unit_f32() < prob,
            Trigger::After(n) => p.hits > n,
        };
        if fired {
            p.fires += 1;
        }
        fired
    }

    /// Per-point `(name, hits, fires)` tallies, in spec order. Empty for
    /// a disabled injector. The chaos harness turns this into the
    /// EXPERIMENTS.md fault-matrix table.
    pub fn fired(&self) -> Vec<FaultReport> {
        let Some(inner) = &self.inner else {
            return Vec::new();
        };
        let inner = inner.lock().unwrap_or_else(|p| p.into_inner());
        inner.points.iter().map(|p| (p.name.clone(), p.hits, p.fires)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_injector_never_fires_and_reports_nothing() {
        let inj = Injector::disabled();
        assert!(!inj.enabled());
        for _ in 0..100 {
            assert!(!inj.fire("kvpool.alloc"));
        }
        assert!(inj.fired().is_empty());
        // Default is disabled too.
        assert!(!Injector::default().enabled());
    }

    #[test]
    fn empty_spec_is_disabled() {
        assert!(!Injector::parse("", 1).unwrap().enabled());
        assert!(!Injector::parse(" , ", 1).unwrap().enabled());
    }

    #[test]
    fn bad_specs_are_config_errors() {
        assert!(Injector::parse("noseparator", 1).is_err());
        assert!(Injector::parse("a:notanumber", 1).is_err());
        assert!(Injector::parse("a:1.5", 1).is_err());
        assert!(Injector::parse("a:after=x", 1).is_err());
    }

    #[test]
    fn after_counter_passes_then_always_fires() {
        let inj = Injector::parse("p:after=3", 9).unwrap();
        let fires: Vec<bool> = (0..6).map(|_| inj.fire("p")).collect();
        assert_eq!(fires, [false, false, false, true, true, true]);
        assert_eq!(inj.fired(), vec![("p".to_string(), 6, 3)]);
    }

    #[test]
    fn probability_extremes() {
        let always = Injector::parse("p:1.0", 4).unwrap();
        let never = Injector::parse("p:0.0", 4).unwrap();
        for _ in 0..50 {
            assert!(always.fire("p"));
            assert!(!never.fire("p"));
        }
    }

    #[test]
    fn unarmed_point_names_never_fire() {
        let inj = Injector::parse("p:1.0", 4).unwrap();
        assert!(!inj.fire("other.point"));
        // the unarmed consult is not tallied
        assert_eq!(inj.fired(), vec![("p".to_string(), 0, 0)]);
    }

    #[test]
    fn decisions_are_deterministic_and_interleaving_independent() {
        // Same seed → same per-point decision sequence, regardless of
        // how hits to *other* points interleave.
        let a = Injector::parse("x:0.4,y:0.4", 77).unwrap();
        let b = Injector::parse("x:0.4,y:0.4", 77).unwrap();
        let seq_a: Vec<bool> = (0..64).map(|_| a.fire("x")).collect();
        let seq_b: Vec<bool> = (0..64)
            .map(|_| {
                b.fire("y"); // extra traffic on another point
                b.fire("x")
            })
            .collect();
        assert_eq!(seq_a, seq_b);
        assert!(seq_a.iter().any(|&f| f) && seq_a.iter().any(|&f| !f));
        // And clones share state: counters accumulate across handles.
        let c = a.clone();
        c.fire("x");
        assert_eq!(a.fired()[0].1, 65);
    }
}
