//! Typed configuration: model hyperparameters (loaded from the weight
//! manifests the python exporter writes), sparsity/compression settings,
//! and engine settings. CLI parsing lives in `main.rs` (clap is not
//! available offline); this module only holds the typed structs.

use crate::error::{Error, Result};
use crate::fmt::Json;
use crate::prune::Method;

/// Model hyperparameters — mirrors `python/compile/model.py::ModelCfg`.
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub head_dim: usize,
    pub ff: usize,
    pub vocab: usize,
    pub rope_theta: f64,
    pub max_seq: usize,
    pub norm_eps: f64,
}

impl ModelConfig {
    pub fn q_dim(&self) -> usize {
        self.n_heads * self.head_dim
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.head_dim
    }

    /// Queries per KV head (GQA group size).
    pub fn group(&self) -> usize {
        self.n_heads / self.n_kv_heads
    }

    pub fn from_json(v: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: v.get("name")?.as_str()?.to_string(),
            d_model: v.get("d_model")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            n_heads: v.get("n_heads")?.as_usize()?,
            n_kv_heads: v.get("n_kv_heads")?.as_usize()?,
            head_dim: v.get("head_dim")?.as_usize()?,
            ff: v.get("ff")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            rope_theta: v.get("rope_theta")?.as_f64()?,
            max_seq: v.get("max_seq")?.as_usize()?,
            norm_eps: v.get("norm_eps")?.as_f64()?,
        })
    }

    pub fn validate(&self) -> Result<()> {
        if self.n_heads % self.n_kv_heads != 0 {
            return Err(Error::Config(format!(
                "n_heads {} not divisible by n_kv_heads {}",
                self.n_heads, self.n_kv_heads
            )));
        }
        if self.head_dim % 2 != 0 {
            return Err(Error::Config("head_dim must be even (RoPE)".into()));
        }
        if self.q_dim() != self.d_model && self.q_dim() == 0 {
            return Err(Error::Config("bad head geometry".into()));
        }
        Ok(())
    }
}

/// Mustafar sparsity configuration for one serving session / experiment.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SparsityConfig {
    pub key_method: Method,
    pub key_sparsity: f64,
    pub value_method: Method,
    pub value_sparsity: f64,
}

impl SparsityConfig {
    pub fn dense() -> SparsityConfig {
        SparsityConfig {
            key_method: Method::None,
            key_sparsity: 0.0,
            value_method: Method::None,
            value_sparsity: 0.0,
        }
    }

    /// The paper's headline configuration: per-token magnitude on both.
    pub fn mustafar(ks: f64, vs: f64) -> SparsityConfig {
        SparsityConfig {
            key_method: if ks > 0.0 { Method::TokenMagnitude } else { Method::None },
            key_sparsity: ks,
            value_method: if vs > 0.0 { Method::TokenMagnitude } else { Method::None },
            value_sparsity: vs,
        }
    }

    /// Table-row label, paper style ("K0.5 V0.7", "Dense", "ThinK0.5").
    pub fn label(&self) -> String {
        if self.key_method == Method::None && self.value_method == Method::None {
            return "Dense".to_string();
        }
        if self.key_method == Method::ThinkStructured && self.value_method == Method::None {
            return format!("ThinK{}", self.key_sparsity);
        }
        format!("K{} V{}", self.key_sparsity, self.value_sparsity)
    }
}

/// Attention/compute backend selector for the engine.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust forward, dense KV (baseline).
    NativeDense,
    /// Pure-Rust forward, bitmap-compressed KV + SpMV attention (Mustafar).
    NativeSparse,
    /// XLA/PJRT monolithic dense decode artifact.
    PjrtDense,
    /// XLA/PJRT sparse decode artifact (L1 Pallas kernel inside).
    PjrtSparse,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        Some(match s {
            "native-dense" => Backend::NativeDense,
            "native-sparse" => Backend::NativeSparse,
            "pjrt-dense" => Backend::PjrtDense,
            "pjrt-sparse" => Backend::PjrtSparse,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::NativeDense => "native-dense",
            Backend::NativeSparse => "native-sparse",
            Backend::PjrtDense => "pjrt-dense",
            Backend::PjrtSparse => "pjrt-sparse",
        }
    }
}

/// Engine (coordinator) settings.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    pub backend: Backend,
    pub sparsity: SparsityConfig,
    /// Maximum sequences decoded together (continuous batching cap).
    pub max_batch: usize,
    /// Admission queue capacity.
    pub queue_cap: usize,
    /// Max generated tokens per request (safety cap). Enforced at
    /// `Engine::submit`: a request asking for more is *clamped* to this
    /// cap rather than rejected (it finishes `Length` at the cap).
    pub max_new_tokens: usize,
    /// Queued-request TTL in milliseconds: a request still waiting for
    /// admission after this long self-cancels with a `Timeout` finish
    /// instead of occupying a queue slot nobody is waiting on.
    /// 0 disables the TTL (the default).
    pub max_queue_ms: u64,
    /// KV pool budget in bytes (0 = unlimited). All compressed-KV
    /// storage — sequence regions, dense tails, shared prefix-cache
    /// pages — reserves fixed-size pages from one `kvpool::KvPool`
    /// under this budget; admission and decode growth are gated on real
    /// pool occupancy, which is how Mustafar's compression buys larger
    /// batches (Fig 7).
    pub kv_budget_bytes: usize,
    /// Page size for the KV pool.
    pub kv_page_bytes: usize,
    /// Enable the prefill prefix cache (shared immutable compressed
    /// pages keyed by a hash chain over prompt tokens).
    pub prefix_cache: bool,
    /// Prefix-cache capacity in bytes, *separate* from the pool byte
    /// budget (0 = bounded only by the pool): the cache evicts LRU
    /// entries to stay under this before an insert, so cached prefixes
    /// cannot crowd live sequences out of a shared budget.
    pub prefix_cache_bytes: usize,
    /// TTL for idle prefix-cache entries in milliseconds (0 = no TTL):
    /// an entry not used for this long is evicted by a sweep on the
    /// engine step path, returning its pool pages.
    pub prefix_ttl_ms: u64,
    /// Pressure-controller re-prune ladder: sparsity tiers the coldest
    /// resident sequences are moved through before anything is
    /// preempted or rejected.
    pub reprune_tiers: Vec<f64>,
    /// Worker threads for per-head attention parallelism.
    pub threads: usize,
    /// Master switch for the telemetry registry (histograms + trace
    /// spans). `--no-telemetry` turns it off; the flight recorder stays
    /// on regardless (it is the post-mortem black box and its cost is
    /// per lifecycle event, not per token).
    pub telemetry: bool,
    /// Trace-span ring capacity (spans retained for `{"trace": n}`).
    pub trace_ring: usize,
    /// Flight-recorder ring capacity (events retained for `{"dump"}`).
    pub recorder_ring: usize,
    /// Chunked-prefill (Sarathi-style) chunk size in prompt tokens: a
    /// native-backend prefill runs through the decode path at most this
    /// many tokens at a time, so cancellation, deadlines, preemption,
    /// and fault isolation all get chunk-boundary cut points instead of
    /// waiting out a monster prompt. 0 disables fixed chunking (the
    /// whole remaining prompt is one chunk — the run-to-completion
    /// baseline when `round_token_budget` is also 0). The default is
    /// one 64-token compression group.
    pub prefill_chunk_tokens: usize,
    /// Per-round token budget for the engine's round planner: each
    /// step, every decodable sequence's token is charged first, and
    /// only the leftover budget is granted to prefill chunks
    /// (round-robin over mid-prefill sequences, oldest first). Decoders
    /// are never skipped — the budget bounds prefill interference, so a
    /// 1M-token prompt cannot head-of-line-block decoding users — and
    /// prefill always makes at least one chunk of progress per round so
    /// neither side starves. 0 (the default) disables the budget:
    /// admitted prompts prefill to completion within the admitting
    /// step, preserving single-step admission semantics.
    pub round_token_budget: usize,
    /// Deferred group compression (the default): decoding sequences only
    /// append fp16 to their dense ring tail on the hot path, and exited
    /// 64-token groups are pruned + bitmap-packed asynchronously on the
    /// worker pool, settled before the next round's attention reads —
    /// token-identical to the synchronous path. `false` restores
    /// compress-inside-`commit_token` (the comparison baseline the
    /// `deferred_compress` bench gate measures against). Prefill always
    /// compresses synchronously either way: its per-chunk token loop
    /// reads attention between commits, so there is no overlap window.
    pub deferred_compress: bool,
    /// Max exited groups a sequence's ring tail may buffer awaiting
    /// deferred compression before `commit_token` stalls (compresses the
    /// oldest group synchronously in place). In engine operation the
    /// settle-every-round schedule keeps the queue depth at 1; the
    /// budget is the graceful-degradation bound when the compressor
    /// falls behind.
    pub compress_inflight_groups: usize,
    /// Dense local attention window in tokens (the paper's recency
    /// region, kept unpruned). Larger windows trade KV bytes for
    /// accuracy at high sparsity tiers — see the EXPERIMENTS.md §13
    /// NLL-vs-window sweep.
    pub local_window: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            backend: Backend::NativeDense,
            sparsity: SparsityConfig::dense(),
            max_batch: 8,
            queue_cap: 256,
            max_new_tokens: 64,
            max_queue_ms: 0,
            kv_budget_bytes: 0,
            kv_page_bytes: crate::kvpool::DEFAULT_PAGE_BYTES,
            prefix_cache: true,
            prefix_cache_bytes: 0,
            prefix_ttl_ms: 0,
            reprune_tiers: vec![0.75, 0.9],
            threads: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
            telemetry: true,
            trace_ring: 4096,
            recorder_ring: 1024,
            prefill_chunk_tokens: 64,
            round_token_budget: 0,
            deferred_compress: true,
            compress_inflight_groups: 2,
            local_window: crate::prune::LOCAL_WINDOW,
        }
    }
}

/// TCP front-end (reactor) settings — every per-connection resource
/// bound the server enforces. See `server`'s module docs for how each
/// limit behaves when hit.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// Fixed reactor thread count; every connection is multiplexed
    /// onto one of these (total server threads = reactors + 1 engine
    /// thread + the engine's worker pool, independent of connection
    /// count).
    pub reactor_threads: usize,
    /// Global connection cap: accepts beyond it are answered with one
    /// `{"error", "retry_after_ms"}` line and closed.
    pub max_conns: usize,
    /// Longest request line accepted; beyond it the line is dropped
    /// with one `error` reply and the connection survives.
    pub max_line_bytes: usize,
    /// Per-connection userspace write-queue high-water mark: a reader
    /// stalled past it is declared dead and torn down.
    pub write_hwm_bytes: usize,
    /// Close connections with nothing in flight after this long
    /// without traffic (0 = never).
    pub idle_timeout_ms: u64,
    /// A partial request line must complete within this window,
    /// measured from its first byte — dribbled bytes do not reset it
    /// (slowloris defense; 0 = no deadline).
    pub read_deadline_ms: u64,
    /// Graceful-drain window: on shutdown every in-flight request's
    /// deadline is clamped to this, so the server exits once all work
    /// finishes or times out (plus a small flush grace).
    pub drain_deadline_ms: u64,
    /// Pin accepted sockets' kernel send buffer (0 = kernel default);
    /// test hook for deterministic write backpressure.
    pub sock_sndbuf_bytes: usize,
    /// Optional `HOST:PORT` for a plain-HTTP Prometheus scrape
    /// listener serving the same exposition as the `{"metrics"}` line
    /// (`None` = line protocol only).
    pub metrics_addr: Option<String>,
    /// Optional path: at engine-loop exit the full retained span ring
    /// is written here as chrome://tracing JSON.
    pub trace_out: Option<String>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            reactor_threads: 2,
            max_conns: 1024,
            max_line_bytes: 1 << 20,
            write_hwm_bytes: 1 << 20,
            idle_timeout_ms: 300_000,
            read_deadline_ms: 30_000,
            drain_deadline_ms: 5_000,
            sock_sndbuf_bytes: 0,
            metrics_addr: None,
            trace_out: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_config_from_json() {
        let text = r#"{"name":"tiny","d_model":64,"n_layers":2,"n_heads":2,
            "n_kv_heads":1,"head_dim":32,"ff":128,"vocab":512,
            "rope_theta":10000.0,"max_seq":256,"norm_eps":1e-5}"#;
        let cfg = ModelConfig::from_json(&Json::parse(text).unwrap()).unwrap();
        assert_eq!(cfg.group(), 2);
        assert_eq!(cfg.q_dim(), 64);
        assert_eq!(cfg.kv_dim(), 32);
        cfg.validate().unwrap();
    }

    #[test]
    fn bad_geometry_rejected() {
        let cfg = ModelConfig {
            name: "x".into(),
            d_model: 64,
            n_layers: 1,
            n_heads: 3,
            n_kv_heads: 2,
            head_dim: 32,
            ff: 64,
            vocab: 512,
            rope_theta: 1e4,
            max_seq: 128,
            norm_eps: 1e-5,
        };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn sparsity_labels() {
        assert_eq!(SparsityConfig::dense().label(), "Dense");
        assert_eq!(SparsityConfig::mustafar(0.5, 0.7).label(), "K0.5 V0.7");
        let think = SparsityConfig {
            key_method: Method::ThinkStructured,
            key_sparsity: 0.5,
            value_method: Method::None,
            value_sparsity: 0.0,
        };
        assert_eq!(think.label(), "ThinK0.5");
    }

    #[test]
    fn backend_parse() {
        assert_eq!(Backend::parse("native-sparse"), Some(Backend::NativeSparse));
        assert_eq!(Backend::parse("nope"), None);
        assert_eq!(Backend::PjrtDense.name(), "pjrt-dense");
    }
}
