//! Attention primitives: RoPE, softmax, dense prefill attention, and the
//! two decode-phase paths — dense MV (baseline) and the Mustafar sparse
//! path (bitmap SpMV over the compressed region + dense MV over the local
//! window, Fig 5a).

use crate::sparse::dispatch::{kernels, KernelTable};
use crate::sparse::{
    dense_key, dense_key_multi_with, dense_key_with, dense_value, dense_value_multi_with,
    dense_value_with, spmv_key, spmv_key_multi_with, spmv_value, spmv_value_multi_with,
    BitmapMatrix, KvElem, MAX_GROUP,
};

/// Precomputed RoPE table for one position: (cos, sin) of length hd/2.
pub fn rope_cos_sin(pos: usize, head_dim: usize, theta: f64) -> (Vec<f32>, Vec<f32>) {
    let mut cos = Vec::new();
    let mut sin = Vec::new();
    rope_cos_sin_into(pos, head_dim, theta, &mut cos, &mut sin);
    (cos, sin)
}

/// Allocation-free variant of `rope_cos_sin`: fills caller-owned buffers
/// (cleared and resized in place; no heap traffic once capacity exists).
pub fn rope_cos_sin_into(
    pos: usize,
    head_dim: usize,
    theta: f64,
    cos: &mut Vec<f32>,
    sin: &mut Vec<f32>,
) {
    let half = head_dim / 2;
    cos.clear();
    sin.clear();
    cos.reserve(half);
    sin.reserve(half);
    for i in 0..half {
        let freq = theta.powf(-(i as f64) / half as f64);
        let ang = pos as f64 * freq;
        cos.push(ang.cos() as f32);
        sin.push(ang.sin() as f32);
    }
}

/// Apply RoPE in place (llama rotate-half convention, matching
/// python/compile/model.py::apply_rope).
pub fn apply_rope(x: &mut [f32], cos: &[f32], sin: &[f32]) {
    let half = x.len() / 2;
    debug_assert_eq!(cos.len(), half);
    for i in 0..half {
        let a = x[i];
        let b = x[half + i];
        x[i] = a * cos[i] - b * sin[i];
        x[half + i] = b * cos[i] + a * sin[i];
    }
}

/// Numerically-stable in-place softmax.
pub fn softmax(xs: &mut [f32]) {
    if xs.is_empty() {
        return;
    }
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let mut denom = 0.0f32;
    for x in xs.iter_mut() {
        *x = (*x - m).exp();
        denom += *x;
    }
    let inv = 1.0 / denom;
    for x in xs.iter_mut() {
        *x *= inv;
    }
}

/// Joint softmax over two concatenated score segments (compressed region
/// and dense tail) without materializing the concatenation.
pub fn two_part_softmax(a: &mut [f32], b: &mut [f32]) {
    let ma = a.iter().fold(f32::NEG_INFINITY, |x, &y| x.max(y));
    let mb = b.iter().fold(f32::NEG_INFINITY, |x, &y| x.max(y));
    let m = ma.max(mb);
    if !m.is_finite() {
        return;
    }
    let mut denom = 0.0f32;
    for x in a.iter_mut() {
        *x = (*x - m).exp();
        denom += *x;
    }
    for x in b.iter_mut() {
        *x = (*x - m).exp();
        denom += *x;
    }
    let inv = 1.0 / denom;
    for x in a.iter_mut() {
        *x *= inv;
    }
    for x in b.iter_mut() {
        *x *= inv;
    }
}

/// Dense single-query decode attention: out[hd] over K/V `[t x hd]`.
pub fn decode_dense(q: &[f32], k: &[f32], v: &[f32], t: usize, scale: f32, out: &mut [f32]) {
    let hd = q.len();
    debug_assert_eq!(k.len(), t * hd);
    debug_assert_eq!(v.len(), t * hd);
    let mut scores = vec![0.0f32; t];
    dense_key(k, t, hd, q, &mut scores);
    for s in scores.iter_mut() {
        *s *= scale;
    }
    softmax(&mut scores);
    out.iter_mut().for_each(|x| *x = 0.0);
    dense_value(v, t, hd, &scores, out);
}

/// Mustafar sparse decode attention for one KV head (Fig 5a):
/// SpMV over the bitmap-compressed region, dense MV over the local-window
/// tail, joint softmax, then SpMV + dense MV on the value side.
///
/// `tail_k`/`tail_v` are `[tail_len x hd]` row-major (the local window,
/// which always includes the current token's K/V — callers append before
/// calling), stored as f32 or binary16 (`KvElem`; the KV manager's tail
/// is `u16`). Returns the attention output in `out` and, if `att_out` is
/// given, writes the post-softmax attention over `[compressed | tail]`
/// (used by the H2O tracker).
#[allow(clippy::too_many_arguments)]
pub fn decode_sparse<E: KvElem>(
    q: &[f32],
    k_comp: &BitmapMatrix,
    v_comp: &BitmapMatrix,
    tail_k: &[E],
    tail_v: &[E],
    tail_len: usize,
    scale: f32,
    out: &mut [f32],
    mut att_out: Option<&mut Vec<f32>>,
) {
    let hd = q.len();
    let nc = k_comp.tokens;
    debug_assert_eq!(v_comp.tokens, nc);
    debug_assert_eq!(tail_k.len(), tail_len * hd);

    let mut s_comp = vec![0.0f32; nc];
    spmv_key(k_comp, q, &mut s_comp);
    let mut s_tail = vec![0.0f32; tail_len];
    dense_key(tail_k, tail_len, hd, q, &mut s_tail);
    for s in s_comp.iter_mut() {
        *s *= scale;
    }
    for s in s_tail.iter_mut() {
        *s *= scale;
    }

    two_part_softmax(&mut s_comp, &mut s_tail);

    out.iter_mut().for_each(|x| *x = 0.0);
    spmv_value(v_comp, &s_comp, out);
    dense_value(tail_v, tail_len, hd, &s_tail, out);

    if let Some(att) = att_out.take() {
        att.clear();
        att.extend_from_slice(&s_comp);
        att.extend_from_slice(&s_tail);
    }
}

/// Fused GQA sparse decode attention for one KV head and its whole query
/// group: `g` query lanes attend over the same compressed region + dense
/// tail, with every compressed tile decoded exactly once (the multi-query
/// kernels in `sparse::spmv`).
///
/// `qs` is `[g x hd]` flat; `out` is `[g x hd]` flat (overwritten).
/// `s_comp`/`s_tail` are caller-owned score workspaces (`[g x nc]` and
/// `[g x tail_len]` after the call) — reusing them across tokens keeps
/// the decode hot path allocation-free.
///
/// Per lane, results are bit-exact against `decode_sparse`.
#[allow(clippy::too_many_arguments)]
pub fn decode_sparse_group<E: KvElem>(
    qs: &[f32],
    g: usize,
    k_comp: &BitmapMatrix,
    v_comp: &BitmapMatrix,
    tail_k: &[E],
    tail_v: &[E],
    tail_len: usize,
    scale: f32,
    out: &mut [f32],
    s_comp: &mut Vec<f32>,
    s_tail: &mut Vec<f32>,
) {
    decode_sparse_group_segments(
        qs,
        g,
        &[(k_comp, v_comp)],
        tail_k,
        tail_v,
        tail_len,
        scale,
        out,
        s_comp,
        s_tail,
    );
}

/// `decode_sparse_group` through an explicit dispatch table (benches pin
/// the scalar oracle to report the stable-dispatch speedup).
#[allow(clippy::too_many_arguments)]
pub fn decode_sparse_group_with<E: KvElem>(
    kt: &KernelTable,
    qs: &[f32],
    g: usize,
    k_comp: &BitmapMatrix,
    v_comp: &BitmapMatrix,
    tail_k: &[E],
    tail_v: &[E],
    tail_len: usize,
    scale: f32,
    out: &mut [f32],
    s_comp: &mut Vec<f32>,
    s_tail: &mut Vec<f32>,
) {
    decode_sparse_group_segments_with(
        kt,
        qs,
        g,
        &[(k_comp, v_comp)],
        tail_k,
        tail_v,
        tail_len,
        scale,
        out,
        s_comp,
        s_tail,
    );
}

/// Multi-segment fused GQA sparse decode: `decode_sparse_group` where
/// the compressed region is a *sequence of segments in token order* —
/// e.g. a shared prefill prefix (`kvcache::SharedPrefix`) followed by
/// the sequence's own compressed groups. Every segment's bitmap stream
/// is walked exactly once for the whole query group, and the joint
/// softmax runs per lane across all segments plus the dense tail.
///
/// `s_comp` is laid out segment-major: segment `s` of `nc_s` tokens
/// occupies `g * nc_s` entries (`[lane][token]` within the segment) at
/// the running offset. Because segments concatenate at 64-token group
/// boundaries, walking them in order reproduces the exact tile stream —
/// and the exact floating-point operation order — of one merged
/// `BitmapMatrix`, so results are bit-identical to a single-segment call
/// on the concatenation (and, with one segment, to `decode_sparse`).
#[allow(clippy::too_many_arguments)]
pub fn decode_sparse_group_segments<E: KvElem>(
    qs: &[f32],
    g: usize,
    segs: &[(&BitmapMatrix, &BitmapMatrix)],
    tail_k: &[E],
    tail_v: &[E],
    tail_len: usize,
    scale: f32,
    out: &mut [f32],
    s_comp: &mut Vec<f32>,
    s_tail: &mut Vec<f32>,
) {
    decode_sparse_group_segments_with(
        kernels(),
        qs,
        g,
        segs,
        tail_k,
        tail_v,
        tail_len,
        scale,
        out,
        s_comp,
        s_tail,
    );
}

/// `decode_sparse_group_segments` through an explicit dispatch table;
/// one table serves the entire call so a single decode never mixes
/// kernel tiers.
#[allow(clippy::too_many_arguments)]
pub fn decode_sparse_group_segments_with<E: KvElem>(
    kt: &KernelTable,
    qs: &[f32],
    g: usize,
    segs: &[(&BitmapMatrix, &BitmapMatrix)],
    tail_k: &[E],
    tail_v: &[E],
    tail_len: usize,
    scale: f32,
    out: &mut [f32],
    s_comp: &mut Vec<f32>,
    s_tail: &mut Vec<f32>,
) {
    assert!(g >= 1, "empty query group");
    assert!(g <= MAX_GROUP, "query group {g} exceeds MAX_GROUP {MAX_GROUP}");
    let hd = qs.len() / g;
    debug_assert_eq!(qs.len(), g * hd);
    debug_assert_eq!(out.len(), g * hd);
    debug_assert_eq!(tail_k.len(), tail_len * hd);
    let total: usize = segs.iter().map(|(k, _)| k.tokens).sum();

    s_comp.clear();
    s_comp.resize(g * total, 0.0);
    s_tail.clear();
    s_tail.resize(g * tail_len, 0.0);

    let mut off = 0;
    for (k, v) in segs {
        let nc = k.tokens;
        debug_assert_eq!(v.tokens, nc);
        if nc == 0 {
            continue;
        }
        spmv_key_multi_with(kt, k, qs, g, &mut s_comp[off..off + g * nc]);
        off += g * nc;
    }
    dense_key_multi_with(kt, tail_k, tail_len, hd, qs, g, s_tail);
    for s in s_comp.iter_mut() {
        *s *= scale;
    }
    for s in s_tail.iter_mut() {
        *s *= scale;
    }

    // Joint softmax per lane over [seg_0 | seg_1 | ... | tail] without
    // materializing the concatenation (the N-segment generalization of
    // `two_part_softmax`, same pass order per lane).
    let mut m = [f32::NEG_INFINITY; MAX_GROUP];
    let mut off = 0;
    for (k, _) in segs {
        let nc = k.tokens;
        if nc == 0 {
            continue;
        }
        for (l, ml) in m.iter_mut().enumerate().take(g) {
            for &x in &s_comp[off + l * nc..off + (l + 1) * nc] {
                *ml = ml.max(x);
            }
        }
        off += g * nc;
    }
    for (l, ml) in m.iter_mut().enumerate().take(g) {
        for &x in &s_tail[l * tail_len..(l + 1) * tail_len] {
            *ml = ml.max(x);
        }
    }

    let mut denom = [0.0f32; MAX_GROUP];
    let mut off = 0;
    for (k, _) in segs {
        let nc = k.tokens;
        if nc == 0 {
            continue;
        }
        for l in 0..g {
            if !m[l].is_finite() {
                continue;
            }
            for x in &mut s_comp[off + l * nc..off + (l + 1) * nc] {
                *x = (*x - m[l]).exp();
                denom[l] += *x;
            }
        }
        off += g * nc;
    }
    for l in 0..g {
        if !m[l].is_finite() {
            continue;
        }
        for x in &mut s_tail[l * tail_len..(l + 1) * tail_len] {
            *x = (*x - m[l]).exp();
            denom[l] += *x;
        }
    }

    let mut off = 0;
    for (k, _) in segs {
        let nc = k.tokens;
        if nc == 0 {
            continue;
        }
        for l in 0..g {
            if !m[l].is_finite() {
                continue;
            }
            let inv = 1.0 / denom[l];
            for x in &mut s_comp[off + l * nc..off + (l + 1) * nc] {
                *x *= inv;
            }
        }
        off += g * nc;
    }
    for l in 0..g {
        if !m[l].is_finite() {
            continue;
        }
        let inv = 1.0 / denom[l];
        for x in &mut s_tail[l * tail_len..(l + 1) * tail_len] {
            *x *= inv;
        }
    }

    out.iter_mut().for_each(|x| *x = 0.0);
    let mut off = 0;
    for (_, v) in segs {
        let nc = v.tokens;
        if nc == 0 {
            continue;
        }
        spmv_value_multi_with(kt, v, &s_comp[off..off + g * nc], g, out);
        off += g * nc;
    }
    dense_value_multi_with(kt, tail_v, tail_len, hd, s_tail, g, out);
}

/// Full causal self-attention for prefill, one head.
///
/// q/k/v `[t x hd]`; writes out `[t x hd]`. If `att_probs` is provided it
/// receives the full `[t x t]` post-softmax matrix (row = query position)
/// for output-aware scoring and H2O initialization.
pub fn causal_prefill(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    t: usize,
    hd: usize,
    scale: f32,
    out: &mut [f32],
    mut att_probs: Option<&mut Vec<f32>>,
) {
    debug_assert_eq!(q.len(), t * hd);
    let kt = kernels();
    let probs: Option<&mut [f32]> = match att_probs.take() {
        Some(p) => {
            p.clear();
            p.resize(t * t, 0.0);
            Some(&mut p[..])
        }
        None => None,
    };

    // Row blocks are independent (each query row attends over its own
    // causal span), so long prompts fan out across threads — previously
    // this loop was single-pass even for multi-thousand-token prefills.
    // The threshold is deliberately high: prefill calls this once per
    // (layer, query head), each call spawning scoped OS threads, so only
    // prompts where the per-call work dwarfs the spawn cost fan out.
    // Blocks stay smallish (~threads x 2) because row cost grows with
    // the row index; per-row math is identical either way, so threading
    // never changes a bit of output.
    let flops = t * (t + 1) * hd * 2; // two MVs per row, ~2*n*hd each
    let threads = crate::util::threads();
    if flops < 16_000_000 || threads <= 1 {
        causal_prefill_rows(kt, q, k, v, t, hd, scale, 0, out, probs);
        return;
    }
    let rows_per = t.div_ceil(threads * 2).max(16);
    std::thread::scope(|scope| {
        let mut out_rest = &mut out[..];
        let mut probs_rest = probs;
        let mut r0 = 0usize;
        while r0 < t {
            let rows = rows_per.min(t - r0);
            let (chunk, rest) = out_rest.split_at_mut(rows * hd);
            out_rest = rest;
            let pchunk = match probs_rest.take() {
                Some(p) => {
                    let (c, rest) = p.split_at_mut(rows * t);
                    probs_rest = Some(rest);
                    Some(c)
                }
                None => None,
            };
            scope.spawn(move || {
                causal_prefill_rows(kt, q, k, v, t, hd, scale, r0, chunk, pchunk);
            });
            r0 += rows;
        }
    });
}

/// One block of causal-prefill rows `[r0, r0 + out_rows.len()/hd)`:
/// `out_rows` holds those rows of the output, `probs_rows` (if given)
/// the matching rows of the `[t x t]` post-softmax matrix.
#[allow(clippy::too_many_arguments)]
fn causal_prefill_rows(
    kt: &KernelTable,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    t: usize,
    hd: usize,
    scale: f32,
    r0: usize,
    out_rows: &mut [f32],
    mut probs_rows: Option<&mut [f32]>,
) {
    let rr = out_rows.len() / hd;
    let mut scores = vec![0.0f32; r0 + rr];
    for j in 0..rr {
        let i = r0 + j;
        let qi = &q[i * hd..(i + 1) * hd];
        let n = i + 1;
        scores[..n].iter_mut().for_each(|s| *s = 0.0);
        dense_key_with(kt, &k[..n * hd], n, hd, qi, &mut scores[..n]);
        for s in scores[..n].iter_mut() {
            *s *= scale;
        }
        softmax(&mut scores[..n]);
        let oi = &mut out_rows[j * hd..(j + 1) * hd];
        oi.iter_mut().for_each(|x| *x = 0.0);
        dense_value_with(kt, &v[..n * hd], n, hd, &scores[..n], oi);
        if let Some(p) = probs_rows.as_deref_mut() {
            p[j * t..j * t + n].copy_from_slice(&scores[..n]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prune::per_token_magnitude;
    use crate::sparse::f16::{f16_round_vec as f16_ref, to_f16_vec};
    use crate::sparse::PackAxis;
    use crate::util::Pcg32;

    fn randv(n: usize, rng: &mut Pcg32) -> Vec<f32> {
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn softmax_sums_to_one() {
        let mut xs = vec![1.0, 2.0, 3.0, -5.0];
        softmax(&mut xs);
        assert!((xs.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(xs[2] > xs[1] && xs[1] > xs[0] && xs[0] > xs[3]);
    }

    #[test]
    fn two_part_matches_joint() {
        let mut rng = Pcg32::seeded(13);
        let mut a = randv(10, &mut rng);
        let mut b = randv(7, &mut rng);
        let mut joint = [a.clone(), b.clone()].concat();
        softmax(&mut joint);
        two_part_softmax(&mut a, &mut b);
        for (x, y) in a.iter().chain(b.iter()).zip(&joint) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn rope_preserves_norm() {
        let mut rng = Pcg32::seeded(14);
        let mut x = randv(64, &mut rng);
        let norm0: f32 = x.iter().map(|v| v * v).sum();
        let (cos, sin) = rope_cos_sin(17, 64, 10000.0);
        apply_rope(&mut x, &cos, &sin);
        let norm1: f32 = x.iter().map(|v| v * v).sum();
        assert!((norm0 - norm1).abs() / norm0 < 1e-5);
    }

    #[test]
    fn rope_position_zero_is_identity() {
        let mut rng = Pcg32::seeded(15);
        let x0 = randv(32, &mut rng);
        let mut x = x0.clone();
        let (cos, sin) = rope_cos_sin(0, 32, 10000.0);
        apply_rope(&mut x, &cos, &sin);
        for (a, b) in x.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn sparse_decode_matches_dense_when_unpruned() {
        // With no pruning (compressed region holds the exact stored
        // values), the sparse path must reproduce dense attention over
        // the f16-rounded matrices — same values, different op order.
        let mut rng = Pcg32::seeded(16);
        let (t_comp, tail, hd) = (128, 16, 64);
        let t = t_comp + tail;
        let k = randv(t * hd, &mut rng);
        let v = randv(t * hd, &mut rng);
        let q = randv(hd, &mut rng);
        let scale = 1.0 / (hd as f32).sqrt();

        let k_comp =
            BitmapMatrix::compress(&k[..t_comp * hd], t_comp, hd, PackAxis::Token).unwrap();
        let v_comp =
            BitmapMatrix::compress(&v[..t_comp * hd], t_comp, hd, PackAxis::Channel).unwrap();
        let tail_k = to_f16_vec(&k[t_comp * hd..]);
        let tail_v = to_f16_vec(&v[t_comp * hd..]);

        let mut out_sparse = vec![0.0f32; hd];
        decode_sparse(
            &q, &k_comp, &v_comp,
            &tail_k, &tail_v, tail,
            scale, &mut out_sparse, None,
        );

        let mut out_dense = vec![0.0f32; hd];
        decode_dense(&q, &f16_ref(&k), &f16_ref(&v), t, scale, &mut out_dense);

        for (a, b) in out_sparse.iter().zip(&out_dense) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn sparse_decode_matches_masked_dense_when_pruned() {
        let mut rng = Pcg32::seeded(17);
        let (t_comp, tail, hd, kk) = (64, 8, 64, 20);
        let k = randv((t_comp + tail) * hd, &mut rng);
        let v = randv((t_comp + tail) * hd, &mut rng);
        let q = randv(hd, &mut rng);
        let scale = 0.125;

        let kp = per_token_magnitude(&k[..t_comp * hd], t_comp, hd, kk);
        let vp = per_token_magnitude(&v[..t_comp * hd], t_comp, hd, kk);
        let k_comp = BitmapMatrix::compress(&kp, t_comp, hd, PackAxis::Token).unwrap();
        let v_comp = BitmapMatrix::compress(&vp, t_comp, hd, PackAxis::Channel).unwrap();

        let mut out_sparse = vec![0.0f32; hd];
        decode_sparse(
            &q, &k_comp, &v_comp,
            &to_f16_vec(&k[t_comp * hd..]), &to_f16_vec(&v[t_comp * hd..]), tail,
            scale, &mut out_sparse, None,
        );

        // dense equivalent over the masked, f16-rounded matrices
        let kfull = f16_ref(&[kp, k[t_comp * hd..].to_vec()].concat());
        let vfull = f16_ref(&[vp, v[t_comp * hd..].to_vec()].concat());
        let mut out_dense = vec![0.0f32; hd];
        decode_dense(&q, &kfull, &vfull, t_comp + tail, scale, &mut out_dense);

        for (a, b) in out_sparse.iter().zip(&out_dense) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn decode_sparse_group_bitexact_vs_per_head() {
        // The fused GQA path must reproduce G independent single-lane
        // decode_sparse calls bit-for-bit (the refactor invariant).
        for seed in 0..8 {
            let mut rng = Pcg32::seeded(seed + 700);
            let g = [1, 2, 4, 8][rng.below(4) as usize];
            let (t_comp, tail) = (64 * (1 + rng.below(3) as usize), 1 + rng.below(40) as usize);
            let hd = 64;
            let kk = 16 + rng.below(40) as usize;
            let k = randv((t_comp + tail) * hd, &mut rng);
            let v = randv((t_comp + tail) * hd, &mut rng);
            let qs = randv(g * hd, &mut rng);
            let scale = 1.0 / (hd as f32).sqrt();

            let kp = per_token_magnitude(&k[..t_comp * hd], t_comp, hd, kk);
            let vp = per_token_magnitude(&v[..t_comp * hd], t_comp, hd, kk);
            let k_comp = BitmapMatrix::compress(&kp, t_comp, hd, PackAxis::Token).unwrap();
            let v_comp = BitmapMatrix::compress(&vp, t_comp, hd, PackAxis::Channel).unwrap();
            let (tail_k, tail_v) =
                (to_f16_vec(&k[t_comp * hd..]), to_f16_vec(&v[t_comp * hd..]));

            let mut fused = vec![0.0f32; g * hd];
            let (mut sc, mut st) = (Vec::new(), Vec::new());
            decode_sparse_group(
                &qs, g, &k_comp, &v_comp, &tail_k, &tail_v, tail,
                scale, &mut fused, &mut sc, &mut st,
            );

            for l in 0..g {
                let mut lane = vec![0.0f32; hd];
                decode_sparse(
                    &qs[l * hd..(l + 1) * hd], &k_comp, &v_comp,
                    &tail_k, &tail_v, tail, scale, &mut lane, None,
                );
                assert_eq!(&fused[l * hd..(l + 1) * hd], &lane[..], "seed {seed} lane {l}");
            }
        }
    }

    #[test]
    fn segmented_decode_bitexact_vs_concatenated() {
        // Splitting the compressed region at a 64-token group boundary
        // (shared prefix | private groups) must not change a single bit:
        // the segment walk reproduces the merged tile stream exactly.
        for seed in 0..6 {
            let mut rng = Pcg32::seeded(seed + 900);
            let g = [1, 2, 4][rng.below(3) as usize];
            let hd = [32usize, 64][rng.below(2) as usize];
            let (t_a, t_b) = (64 * (1 + rng.below(3) as usize), 64 * (1 + rng.below(2) as usize));
            let t_comp = t_a + t_b;
            let tail = 1 + rng.below(40) as usize;
            let kk = 8 + rng.below((hd / 2) as u32) as usize;
            let k = randv((t_comp + tail) * hd, &mut rng);
            let v = randv((t_comp + tail) * hd, &mut rng);
            let qs = randv(g * hd, &mut rng);
            let scale = 1.0 / (hd as f32).sqrt();

            let kp = per_token_magnitude(&k[..t_comp * hd], t_comp, hd, kk);
            let vp = per_token_magnitude(&v[..t_comp * hd], t_comp, hd, kk);
            let k_full = BitmapMatrix::compress(&kp, t_comp, hd, PackAxis::Token).unwrap();
            let v_full = BitmapMatrix::compress(&vp, t_comp, hd, PackAxis::Channel).unwrap();
            let k_a = BitmapMatrix::compress(&kp[..t_a * hd], t_a, hd, PackAxis::Token).unwrap();
            let v_a = BitmapMatrix::compress(&vp[..t_a * hd], t_a, hd, PackAxis::Channel).unwrap();
            let k_b = BitmapMatrix::compress(&kp[t_a * hd..], t_b, hd, PackAxis::Token).unwrap();
            let v_b = BitmapMatrix::compress(&vp[t_a * hd..], t_b, hd, PackAxis::Channel).unwrap();
            let (tail_k, tail_v) =
                (to_f16_vec(&k[t_comp * hd..]), to_f16_vec(&v[t_comp * hd..]));

            let mut one = vec![0.0f32; g * hd];
            let (mut sc, mut st) = (Vec::new(), Vec::new());
            decode_sparse_group(
                &qs, g, &k_full, &v_full, &tail_k, &tail_v, tail,
                scale, &mut one, &mut sc, &mut st,
            );

            let mut two = vec![0.0f32; g * hd];
            let segs = [(&k_a, &v_a), (&k_b, &v_b)];
            decode_sparse_group_segments(
                &qs, g, &segs, &tail_k, &tail_v, tail,
                scale, &mut two, &mut sc, &mut st,
            );
            assert_eq!(one, two, "seed {seed} g={g} hd={hd} split {t_a}+{t_b}");

            // an interposed empty segment must be a no-op
            let k_e = BitmapMatrix::empty(hd, PackAxis::Token);
            let v_e = BitmapMatrix::empty(hd, PackAxis::Channel);
            let mut three = vec![0.0f32; g * hd];
            let segs3 = [(&k_a, &v_a), (&k_e, &v_e), (&k_b, &v_b)];
            decode_sparse_group_segments(
                &qs, g, &segs3, &tail_k, &tail_v, tail,
                scale, &mut three, &mut sc, &mut st,
            );
            assert_eq!(one, three, "empty segment changed the result");
        }
    }

    #[test]
    fn decode_sparse_group_empty_compressed_region() {
        // Before any group has been compressed the whole history lives in
        // the tail; the fused path must handle nc == 0.
        let mut rng = Pcg32::seeded(31);
        let (g, tail, hd) = (4, 12, 32);
        let k = randv(tail * hd, &mut rng);
        let v = randv(tail * hd, &mut rng);
        let qs = randv(g * hd, &mut rng);
        let k_comp = BitmapMatrix::empty(hd, PackAxis::Token);
        let v_comp = BitmapMatrix::empty(hd, PackAxis::Channel);
        let mut fused = vec![0.0f32; g * hd];
        let (mut sc, mut st) = (Vec::new(), Vec::new());
        decode_sparse_group(
            &qs, g, &k_comp, &v_comp, &to_f16_vec(&k), &to_f16_vec(&v), tail, 0.2,
            &mut fused, &mut sc, &mut st,
        );
        for l in 0..g {
            let mut lane = vec![0.0f32; hd];
            let ql = &qs[l * hd..(l + 1) * hd];
            decode_dense(ql, &f16_ref(&k), &f16_ref(&v), tail, 0.2, &mut lane);
            for (a, b) in fused[l * hd..(l + 1) * hd].iter().zip(&lane) {
                assert!((a - b).abs() < 1e-5, "lane {l}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn causal_prefill_threaded_matches_row_blocks() {
        // t is large enough to trigger the threaded row fan-out on
        // multi-core machines; the result (and the captured prob matrix)
        // must be bit-identical to one serial row walk.
        let mut rng = Pcg32::seeded(27);
        let (t, hd) = (384, 64); // past the flop threshold -> threaded
        let q = randv(t * hd, &mut rng);
        let k = randv(t * hd, &mut rng);
        let v = randv(t * hd, &mut rng);
        let scale = 1.0 / (hd as f32).sqrt();

        let mut out = vec![0.0f32; t * hd];
        let mut probs = Vec::new();
        causal_prefill(&q, &k, &v, t, hd, scale, &mut out, Some(&mut probs));

        let mut out2 = vec![0.0f32; t * hd];
        let mut probs2 = vec![0.0f32; t * t];
        causal_prefill_rows(
            crate::sparse::kernels(),
            &q, &k, &v, t, hd, scale, 0,
            &mut out2,
            Some(&mut probs2[..]),
        );
        assert_eq!(out, out2);
        assert_eq!(probs, probs2);
    }

    #[test]
    fn causal_prefill_last_row_matches_decode() {
        let mut rng = Pcg32::seeded(18);
        let (t, hd) = (48, 32);
        let q = randv(t * hd, &mut rng);
        let k = randv(t * hd, &mut rng);
        let v = randv(t * hd, &mut rng);
        let scale = 1.0 / (hd as f32).sqrt();
        let mut out = vec![0.0f32; t * hd];
        causal_prefill(&q, &k, &v, t, hd, scale, &mut out, None);

        let mut last = vec![0.0f32; hd];
        decode_dense(&q[(t - 1) * hd..], &k, &v, t, scale, &mut last);
        for (a, b) in out[(t - 1) * hd..].iter().zip(&last) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn att_probs_rows_causal_and_normalized() {
        let mut rng = Pcg32::seeded(19);
        let (t, hd) = (16, 8);
        let q = randv(t * hd, &mut rng);
        let k = randv(t * hd, &mut rng);
        let v = randv(t * hd, &mut rng);
        let mut out = vec![0.0f32; t * hd];
        let mut probs = Vec::new();
        causal_prefill(&q, &k, &v, t, hd, 0.35, &mut out, Some(&mut probs));
        for i in 0..t {
            let row = &probs[i * t..(i + 1) * t];
            let sum: f32 = row[..=i].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
            assert!(row[i + 1..].iter().all(|&x| x == 0.0), "causality violated");
        }
    }
}
