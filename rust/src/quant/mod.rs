//! KIVI-style KV-cache quantization, numerically simulated (§4.2.2).
//!
//! KIVI [27] quantizes the Key cache per-channel and the Value cache
//! per-token with asymmetric uniform b-bit quantization over small groups.
//! The paper evaluates Mustafar+KIVI for *accuracy only* (its kernel does
//! not support low-bit either), so we reproduce the numerics: quantize →
//! dequantize and measure the accuracy impact. Following Harma et al.
//! [13] (as the paper does), pruning is applied *before* quantization;
//! zeros introduced by pruning are excluded from the quantization range so
//! the joint error model matches a real sparse-quantized store.

/// Quantization group length (KIVI uses small per-group scales).
pub const GROUP: usize = 32;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Axis {
    /// Groups run down each channel (Key cache — per-channel quant).
    PerChannel,
    /// Groups run along each token's vector (Value cache — per-token quant).
    PerToken,
}

/// Asymmetric uniform quantize→dequantize of one group of values,
/// ignoring exact zeros (pruned slots) when `skip_zeros` is set.
fn fake_quant_group(vals: &mut [f32], bits: u32, skip_zeros: bool) {
    let levels = (1u32 << bits) - 1;
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &v in vals.iter() {
        if skip_zeros && v == 0.0 {
            continue;
        }
        lo = lo.min(v);
        hi = hi.max(v);
    }
    if !lo.is_finite() || hi <= lo {
        return; // all-zero or constant group: exact representation
    }
    let scale = (hi - lo) / levels as f32;
    for v in vals.iter_mut() {
        if skip_zeros && *v == 0.0 {
            continue;
        }
        let q = ((*v - lo) / scale).round().clamp(0.0, levels as f32);
        *v = lo + q * scale;
    }
}

/// Fake-quantize a `[tokens x channels]` cache matrix in place.
pub fn kivi_fake_quant(
    x: &mut [f32],
    tokens: usize,
    channels: usize,
    bits: u32,
    axis: Axis,
    skip_zeros: bool,
) {
    assert_eq!(x.len(), tokens * channels);
    assert!(bits >= 1 && bits <= 8);
    match axis {
        Axis::PerChannel => {
            // groups of GROUP tokens down each channel
            let mut buf = vec![0.0f32; GROUP];
            let mut g0 = 0usize;
            while g0 < tokens {
                let glen = GROUP.min(tokens - g0);
                for c in 0..channels {
                    for r in 0..glen {
                        buf[r] = x[(g0 + r) * channels + c];
                    }
                    fake_quant_group(&mut buf[..glen], bits, skip_zeros);
                    for r in 0..glen {
                        x[(g0 + r) * channels + c] = buf[r];
                    }
                }
                g0 += glen;
            }
        }
        Axis::PerToken => {
            for t in 0..tokens {
                let row = &mut x[t * channels..(t + 1) * channels];
                let mut c0 = 0usize;
                while c0 < channels {
                    let glen = GROUP.min(channels - c0);
                    fake_quant_group(&mut row[c0..c0 + glen], bits, skip_zeros);
                    c0 += glen;
                }
            }
        }
    }
}

/// KIVI joint memory accounting: b bits per kept element + one (scale,
/// zero-point) f16 pair per group. Returns bytes.
pub fn kivi_bytes(kept_elems: usize, bits: u32) -> usize {
    let groups = kept_elems.div_ceil(GROUP);
    (kept_elems * bits as usize).div_ceil(8) + groups * 4
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn randmat(t: usize, d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Pcg32::seeded(seed);
        (0..t * d).map(|_| rng.normal_f32()).collect()
    }

    fn rms(a: &[f32], b: &[f32]) -> f32 {
        let s: f32 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum();
        (s / a.len() as f32).sqrt()
    }

    #[test]
    fn error_shrinks_with_bits() {
        let (t, d) = (64, 64);
        let x = randmat(t, d, 20);
        let mut e = Vec::new();
        for bits in [2u32, 4, 8] {
            let mut y = x.clone();
            kivi_fake_quant(&mut y, t, d, bits, Axis::PerToken, false);
            e.push(rms(&x, &y));
        }
        assert!(e[0] > e[1] && e[1] > e[2], "errors {e:?}");
        assert!(e[2] < 0.02, "8-bit error too big: {}", e[2]);
    }

    #[test]
    fn preserves_zeros_when_skipping() {
        let (t, d) = (32, 64);
        let mut x = randmat(t, d, 21);
        for i in (0..x.len()).step_by(3) {
            x[i] = 0.0;
        }
        let mut y = x.clone();
        kivi_fake_quant(&mut y, t, d, 2, Axis::PerChannel, true);
        for (orig, q) in x.iter().zip(&y) {
            if *orig == 0.0 {
                assert_eq!(*q, 0.0);
            }
        }
    }

    #[test]
    fn range_endpoints_exact() {
        // group min/max are representable exactly by asymmetric quant
        let mut x = vec![0.5f32, 1.0, 2.0, 4.0];
        kivi_fake_quant(&mut x, 1, 4, 2, Axis::PerToken, false);
        assert_eq!(x[0], 0.5);
        assert_eq!(x[3], 4.0);
    }

    #[test]
    fn constant_group_unchanged() {
        let mut x = vec![3.0f32; 64];
        kivi_fake_quant(&mut x, 1, 64, 2, Axis::PerToken, false);
        assert!(x.iter().all(|&v| v == 3.0));
    }

    #[test]
    fn per_channel_groups_independent() {
        // Token groups quantize independently: an outlier in group 2 must
        // not affect group 1's values.
        let (t, d) = (64, 1);
        let mut a: Vec<f32> = (0..t).map(|i| (i % 7) as f32 * 0.1).collect();
        let mut b = a.clone();
        b[40] = 1000.0; // outlier in second group of 32
        kivi_fake_quant(&mut a, t, d, 2, Axis::PerChannel, false);
        kivi_fake_quant(&mut b, t, d, 2, Axis::PerChannel, false);
        assert_eq!(&a[..32], &b[..32]);
    }

    #[test]
    fn byte_accounting() {
        assert_eq!(kivi_bytes(64, 4), 32 + 2 * 4);
        assert_eq!(kivi_bytes(64, 2), 16 + 2 * 4);
    }
}
