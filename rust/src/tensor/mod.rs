//! Minimal host tensor: row-major f32 with shape metadata.
//!
//! Deliberately small — the heavy math lives in `attention`, `model`, and
//! the XLA runtime; this type carries data between them.

use crate::error::{Error, Result};

/// Row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            return Err(Error::Shape(format!(
                "shape {:?} wants {} elems, got {}",
                shape,
                n,
                data.len()
            )));
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn filled(shape: Vec<usize>, v: f32) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![v; n] }
    }

    /// Random-normal tensor (deterministic; used for synthetic workloads).
    pub fn randn(shape: Vec<usize>, rng: &mut crate::util::Pcg32) -> Tensor {
        let n: usize = shape.iter().product();
        let data = (0..n).map(|_| rng.normal_f32()).collect();
        Tensor { shape, data }
    }

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Number of rows / row width for a 2-D tensor.
    pub fn dims2(&self) -> Result<(usize, usize)> {
        if self.shape.len() != 2 {
            return Err(Error::Shape(format!("expected 2-D, got {:?}", self.shape)));
        }
        Ok((self.shape[0], self.shape[1]))
    }

    /// Borrow row `r` of a 2-D tensor.
    pub fn row(&self, r: usize) -> &[f32] {
        let (n, d) = self.dims2().expect("row() on non-2D tensor");
        assert!(r < n, "row {r} out of {n}");
        &self.data[r * d..(r + 1) * d]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let (n, d) = self.dims2().expect("row_mut() on non-2D tensor");
        assert!(r < n, "row {r} out of {n}");
        &mut self.data[r * d..(r + 1) * d]
    }

    /// Reshape in place (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            return Err(Error::Shape(format!(
                "reshape {:?} -> {:?} mismatch",
                self.shape, shape
            )));
        }
        self.shape = shape;
        Ok(self)
    }

    /// Max |a - b| across two same-shaped tensors.
    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

/// f32 -> bf16 -> f32 round-trip (truncation with round-to-nearest-even),
/// used to model the 2-byte storage the paper's format assumes.
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7fff + lsb) & 0xffff_0000;
    f32::from_bits(rounded)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construct_and_rows() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(t.row(0), &[1., 2., 3.]);
        assert_eq!(t.row(1), &[4., 5., 6.]);
        assert!(Tensor::new(vec![2, 2], vec![0.0; 3]).is_err());
    }

    #[test]
    fn reshape_checks() {
        let t = Tensor::zeros(vec![4, 2]);
        let t = t.reshape(vec![2, 4]).unwrap();
        assert_eq!(t.shape(), &[2, 4]);
        assert!(Tensor::zeros(vec![4]).reshape(vec![5]).is_err());
    }

    #[test]
    fn bf16_roundtrip_error_small() {
        for &x in &[0.0f32, 1.0, -1.0, 3.14159, 1e-3, 123.456, -0.25] {
            let r = bf16_round(x);
            if x != 0.0 {
                assert!(((r - x) / x).abs() < 0.01, "{x} -> {r}");
            } else {
                assert_eq!(r, 0.0);
            }
        }
    }

    #[test]
    fn bf16_exact_for_representable() {
        // powers of two are exactly representable in bf16
        for &x in &[0.5f32, 2.0, 4.0, -8.0] {
            assert_eq!(bf16_round(x), x);
        }
    }

    #[test]
    fn randn_deterministic() {
        let mut r1 = crate::util::Pcg32::seeded(5);
        let mut r2 = crate::util::Pcg32::seeded(5);
        assert_eq!(Tensor::randn(vec![8], &mut r1), Tensor::randn(vec![8], &mut r2));
    }
}
