//! Accuracy-evaluation pipeline: runs LongBench-sim samples through the
//! native model under a grid of compression configurations and scores
//! them. One prefill is shared across every configuration of a sample
//! (prefill is dense in the paper too — pruning happens afterwards), so
//! full-grid sweeps cost one prefill + cheap decodes per config.

pub mod distribution;
pub mod experiments;
pub mod harness;
pub mod pipeline;
pub mod ppl;

pub use harness::{run_sweep, SweepResult};
pub use pipeline::{eval_sample, EvalConfig, H2oConfig};
