//! Sweep harness: evaluates a config grid over the task suite with
//! sample-level parallelism, aggregates per-task / per-category / average
//! scores — the machinery behind every accuracy table in the paper.

use std::sync::Mutex;

use crate::eval::pipeline::{eval_sample, EvalConfig};
use crate::model::NativeModel;
use crate::workload::tasks::{self, Category, TASKS};

/// Scores from one sweep: `scores[cfg][task]` in paper units (0-100).
#[derive(Clone, Debug)]
pub struct SweepResult {
    pub config_labels: Vec<String>,
    pub task_ids: Vec<String>,
    pub scores: Vec<Vec<f64>>,
}

impl SweepResult {
    /// Mean over all tasks for config `c`.
    pub fn average(&self, c: usize) -> f64 {
        crate::util::stats::mean(&self.scores[c])
    }

    /// Mean over the tasks of one category.
    pub fn category_avg(&self, c: usize, cat: Category) -> f64 {
        let vals: Vec<f64> = self
            .task_ids
            .iter()
            .zip(&self.scores[c])
            .filter(|(id, _)| tasks::spec(id).map(|s| s.category) == Some(cat))
            .map(|(_, &s)| s)
            .collect();
        crate::util::stats::mean(&vals)
    }

    pub fn cfg_index(&self, label: &str) -> Option<usize> {
        self.config_labels.iter().position(|l| l == label)
    }
}

/// Run `n_samples` of every task (or `task_subset` if given) under the
/// config grid. Parallelizes over samples; the model must outlive the
/// call. Returns scores ×100 (paper units).
pub fn run_sweep(
    model: &NativeModel,
    cfgs: &[EvalConfig],
    task_subset: Option<&[&str]>,
    n_samples: usize,
    ctx_len: usize,
) -> SweepResult {
    let task_ids: Vec<String> = match task_subset {
        Some(sub) => sub.iter().map(|s| s.to_string()).collect(),
        None => TASKS.iter().map(|t| t.id.to_string()).collect(),
    };

    // (task_idx, sample_idx) work items
    let work: Vec<(usize, u64)> = task_ids
        .iter()
        .enumerate()
        .flat_map(|(ti, _)| (0..n_samples as u64).map(move |s| (ti, s)))
        .collect();

    // accumulate per (cfg, task)
    let acc = Mutex::new(vec![vec![0.0f64; task_ids.len()]; cfgs.len()]);
    let next = std::sync::atomic::AtomicUsize::new(0);
    let workers = crate::util::threads().min(work.len().max(1));

    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                if i >= work.len() {
                    break;
                }
                let (ti, sidx) = work[i];
                let sample = tasks::generate(&task_ids[ti], sidx, ctx_len);
                let scores = eval_sample(model, &sample, cfgs);
                let mut a = acc.lock().unwrap();
                for (c, s) in scores.iter().enumerate() {
                    a[c][ti] += s;
                }
            });
        }
    });

    let mut scores = acc.into_inner().unwrap();
    for row in scores.iter_mut() {
        for s in row.iter_mut() {
            *s = *s / n_samples as f64 * 100.0;
        }
    }
    SweepResult {
        config_labels: cfgs.iter().map(|c| c.label.clone()).collect(),
        task_ids,
        scores,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::Weights;

    #[test]
    fn sweep_aggregates_shapes() {
        let cfg = ModelConfig {
            name: "tiny".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 32,
            ff: 128,
            vocab: 512,
            rope_theta: 10000.0,
            max_seq: 512,
            norm_eps: 1e-5,
        };
        let model = NativeModel::new(Weights::random_for_tests(cfg, 3));
        let cfgs = vec![EvalConfig::dense(), EvalConfig::mustafar(0.7, 0.7)];
        let r = run_sweep(&model, &cfgs, Some(&["syn-passkey", "sum-recap8"]), 2, 192);
        assert_eq!(r.scores.len(), 2);
        assert_eq!(r.scores[0].len(), 2);
        for row in &r.scores {
            for &s in row {
                assert!((0.0..=100.0).contains(&s));
            }
        }
        let avg = r.average(0);
        assert!((0.0..=100.0).contains(&avg));
        // category average over subset picks only matching tasks
        let syn = r.category_avg(0, Category::Synthetic);
        assert!((0.0..=100.0).contains(&syn));
    }
}
