//! KV-cache magnitude-distribution analysis (paper Fig 2): quantifies the
//! Key cache's channel-wise outlier structure versus the Value cache's
//! uniformity — the observation the whole pruning-direction study builds
//! on. We verify our trained models exhibit the same structure before
//! relying on it (DESIGN.md §2 substitution).

use crate::model::NativeModel;

/// Per-cache distribution statistics for one (layer, kv-head).
#[derive(Clone, Debug)]
pub struct CacheStats {
    /// Mean |x| per channel.
    pub channel_mean_abs: Vec<f32>,
    /// Max/mean ratio of channel means — the "outlier-ness" score.
    /// Large for the Key cache (outlier channels), near 1 for uniform.
    pub channel_outlier_ratio: f32,
    /// Coefficient of variation across channel means.
    pub channel_cv: f32,
}

pub fn cache_stats(cache: &[f32], t: usize, hd: usize) -> CacheStats {
    let mut mean = vec![0.0f32; hd];
    for row in 0..t {
        for c in 0..hd {
            mean[c] += cache[row * hd + c].abs();
        }
    }
    for m in mean.iter_mut() {
        *m /= t as f32;
    }
    let avg: f32 = mean.iter().sum::<f32>() / hd as f32;
    let mx = mean.iter().fold(0.0f32, |a, &b| a.max(b));
    let var: f32 = mean.iter().map(|&m| (m - avg) * (m - avg)).sum::<f32>() / hd as f32;
    CacheStats {
        channel_outlier_ratio: if avg > 0.0 { mx / avg } else { 0.0 },
        channel_cv: if avg > 0.0 { var.sqrt() / avg } else { 0.0 },
        channel_mean_abs: mean,
    }
}

/// Aggregated Fig-2 analysis over a prompt: per layer/head stats for both
/// caches plus cache-wide averages of the outlier ratio.
pub struct Fig2Result {
    pub key_stats: Vec<CacheStats>,
    pub value_stats: Vec<CacheStats>,
    pub key_outlier_mean: f64,
    pub value_outlier_mean: f64,
}

pub fn analyze_model(model: &NativeModel, prompt: &[u16]) -> Fig2Result {
    let pre = model.prefill(prompt, false);
    let hd = model.cfg().head_dim;
    let t = pre.t;
    let key_stats: Vec<CacheStats> = pre.k.iter().map(|k| cache_stats(k, t, hd)).collect();
    let value_stats: Vec<CacheStats> = pre.v.iter().map(|v| cache_stats(v, t, hd)).collect();
    let key_outlier_mean = crate::util::stats::mean(
        &key_stats.iter().map(|s| s.channel_outlier_ratio as f64).collect::<Vec<_>>(),
    );
    let value_outlier_mean = crate::util::stats::mean(
        &value_stats.iter().map(|s| s.channel_outlier_ratio as f64).collect::<Vec<_>>(),
    );
    Fig2Result { key_stats, value_stats, key_outlier_mean, value_outlier_mean }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn outlier_ratio_detects_structure() {
        // uniform matrix -> ratio ~1
        let t = 100;
        let hd = 16;
        let uniform = vec![1.0f32; t * hd];
        let s = cache_stats(&uniform, t, hd);
        assert!((s.channel_outlier_ratio - 1.0).abs() < 1e-6);
        assert!(s.channel_cv < 1e-6);

        // one hot channel -> large ratio
        let mut outlier = vec![0.1f32; t * hd];
        for row in 0..t {
            outlier[row * hd + 3] = 5.0;
        }
        let s = cache_stats(&outlier, t, hd);
        assert!(s.channel_outlier_ratio > 5.0, "{}", s.channel_outlier_ratio);
    }
}
