//! Perplexity-based pruning evaluation (supplementary experiment).
//!
//! Task scores require a model that has mastered the task; perplexity
//! degradation under KV pruning is measurable at *any* model quality,
//! so it gives a floor-free signal for the paper's central comparison
//! (unstructured per-token magnitude vs structured channel pruning vs
//! 2:4) even with the CPU-budget models. Method: prefill the first half
//! of a held-out document dense, apply each compression config, then
//! teacher-force the second half and accumulate token NLL — decode-time
//! attention runs over the pruned cache, exactly like serving.

use crate::eval::pipeline::EvalConfig;
use crate::kvcache::{KvPolicy, SequenceKV};
use crate::model::NativeModel;
use crate::prune::LOCAL_WINDOW;
use crate::util::Pcg32;
use crate::workload::lang;

/// Mean NLL (nats/token) of the continuation under each config, with
/// the paper's default local window.
pub fn doc_nll(model: &NativeModel, doc: &[u16], split: usize, cfgs: &[EvalConfig]) -> Vec<f64> {
    doc_nll_window(model, doc, split, cfgs, LOCAL_WINDOW)
}

/// [`doc_nll`] with an explicit dense local-window size — the §13
/// window-vs-quality sweep varies it against the sparsity tier (a
/// larger window keeps more recent tokens dense, trading ring-tail
/// bytes for NLL).
pub fn doc_nll_window(
    model: &NativeModel,
    doc: &[u16],
    split: usize,
    cfgs: &[EvalConfig],
    window: usize,
) -> Vec<f64> {
    assert!(split > 0 && split < doc.len());
    assert!(window > 0, "local window must be at least one token");
    let pre = model.prefill(&doc[..split], cfgs.iter().any(|c| needs_aux(c)));
    let mcfg = model.cfg();

    cfgs.iter()
        .map(|cfg| {
            let policy = KvPolicy {
                sparsity: cfg.sparsity,
                quant: cfg.quant,
                compress: cfg.sparsity.key_method != crate::prune::Method::None
                    || cfg.sparsity.value_method != crate::prune::Method::None
                    || cfg.quant.is_some(),
                local_window: window,
            };
            let mut kv = SequenceKV::new(policy, mcfg.n_layers, mcfg.n_kv_heads, mcfg.head_dim)
                .expect("kv geometry");
            let aux = if needs_aux(cfg) { Some(&pre.aux) } else { None };
            kv.ingest_prefill(&pre.k, &pre.v, split, aux).expect("ingest");

            let mut nll = 0.0f64;
            let mut logits = pre.logits_last.clone();
            for (i, &gold) in doc[split..].iter().enumerate() {
                nll += token_nll(&logits, gold);
                logits = model.decode(gold, split + i, &mut kv).expect("decode");
            }
            nll / (doc.len() - split) as f64
        })
        .collect()
}

fn needs_aux(cfg: &EvalConfig) -> bool {
    use crate::prune::Method;
    matches!(cfg.sparsity.key_method, Method::TokenOutputAware | Method::ThinkStructured)
        || matches!(cfg.sparsity.value_method, Method::ChannelOutputAware)
}

fn token_nll(logits: &[f32], gold: u16) -> f64 {
    let m = logits.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let denom: f64 = logits.iter().map(|&x| ((x - m) as f64).exp()).sum();
    -((logits[gold as usize] - m) as f64 - denom.ln())
}

/// Average doc_nll over `n_docs` held-out documents of length `len`,
/// with the paper's default local window.
pub fn sweep_nll(
    model: &NativeModel,
    cfgs: &[EvalConfig],
    n_docs: usize,
    len: usize,
) -> Vec<f64> {
    sweep_nll_window(model, cfgs, n_docs, len, LOCAL_WINDOW)
}

/// [`sweep_nll`] with an explicit dense local-window size.
pub fn sweep_nll_window(
    model: &NativeModel,
    cfgs: &[EvalConfig],
    n_docs: usize,
    len: usize,
    window: usize,
) -> Vec<f64> {
    let mut totals = vec![0.0f64; cfgs.len()];
    let work: Vec<u64> = (0..n_docs as u64).collect();
    let results: Vec<Vec<f64>> = std::thread::scope(|scope| {
        let handles: Vec<_> = work
            .iter()
            .map(|&i| {
                scope.spawn(move || {
                    // held-out stream: seeds far from the training stream
                    let mut rng = Pcg32::new(9_000_000 + i, 54);
                    let doc = lang::gen_document(&mut rng, len);
                    doc_nll_window(model, &doc, len / 2, cfgs, window)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    for r in results {
        for (t, x) in totals.iter_mut().zip(&r) {
            *t += x;
        }
    }
    for t in totals.iter_mut() {
        *t /= n_docs as f64;
    }
    totals
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::Weights;

    fn tiny() -> NativeModel {
        let cfg = ModelConfig {
            name: "tiny".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 32,
            ff: 128,
            vocab: 512,
            rope_theta: 10000.0,
            max_seq: 512,
            norm_eps: 1e-5,
        };
        NativeModel::new(Weights::random_for_tests(cfg, 11))
    }

    #[test]
    fn nll_finite_and_dense_leq_heavily_pruned() {
        let model = tiny();
        let cfgs = vec![
            EvalConfig::dense(),
            EvalConfig::mustafar(0.5, 0.5),
            EvalConfig::mustafar(0.95, 0.95),
        ];
        let nll = sweep_nll(&model, &cfgs, 3, 160);
        for &x in &nll {
            assert!(x.is_finite() && x > 0.0, "{nll:?}");
        }
        // even a random model: destroying 95% of the cache must not
        // *improve* held-out NLL relative to dense (sanity direction)
        assert!(nll[2] >= nll[0] - 0.05, "{nll:?}");
    }

    #[test]
    fn window_sweep_is_finite_and_default_window_matches() {
        let model = tiny();
        let cfgs = vec![EvalConfig::mustafar(0.7, 0.7)];
        let a = sweep_nll(&model, &cfgs, 2, 160);
        let b = sweep_nll_window(&model, &cfgs, 2, 160, LOCAL_WINDOW);
        assert_eq!(a, b, "default-window delegate must be exact");
        for w in [8usize, 64] {
            let n = sweep_nll_window(&model, &cfgs, 2, 160, w);
            assert!(n[0].is_finite() && n[0] > 0.0, "window {w}: {n:?}");
        }
    }

    #[test]
    fn token_nll_matches_uniform() {
        let logits = vec![0.0f32; 4];
        let nll = token_nll(&logits, 2);
        assert!((nll - (4.0f64).ln()).abs() < 1e-9);
    }
}
