//! Single-sample evaluation under a grid of compression configs.

use crate::config::SparsityConfig;
use crate::evict::h2o_select;
use crate::kvcache::{KvPolicy, PruneAux, QuantConfig, SequenceKV};
use crate::model::{argmax, NativeModel, PrefillResult};
use crate::prune::{Method, LOCAL_WINDOW};
use crate::workload::TaskSample;

/// H2O joint-application settings (paper §4.2.1: 10% + 10%).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct H2oConfig {
    pub recent_frac: f64,
    pub hh_frac: f64,
}

/// One column of an accuracy table.
#[derive(Clone, Debug)]
pub struct EvalConfig {
    pub label: String,
    pub sparsity: SparsityConfig,
    pub quant: Option<QuantConfig>,
    pub h2o: Option<H2oConfig>,
}

impl EvalConfig {
    pub fn dense() -> EvalConfig {
        EvalConfig {
            label: "Dense".into(),
            sparsity: SparsityConfig::dense(),
            quant: None,
            h2o: None,
        }
    }

    pub fn mustafar(ks: f64, vs: f64) -> EvalConfig {
        let sp = SparsityConfig::mustafar(ks, vs);
        EvalConfig { label: sp.label(), sparsity: sp, quant: None, h2o: None }
    }

    pub fn think(ks: f64) -> EvalConfig {
        let sp = SparsityConfig {
            key_method: Method::ThinkStructured,
            key_sparsity: ks,
            value_method: Method::None,
            value_sparsity: 0.0,
        };
        EvalConfig { label: format!("ThinK{ks}"), sparsity: sp, quant: None, h2o: None }
    }

    /// Custom per-cache methods (the §2 method studies).
    pub fn methods(label: &str, km: Method, ks: f64, vm: Method, vs: f64) -> EvalConfig {
        EvalConfig {
            label: label.to_string(),
            sparsity: SparsityConfig {
                key_method: km,
                key_sparsity: ks,
                value_method: vm,
                value_sparsity: vs,
            },
            quant: None,
            h2o: None,
        }
    }

    fn needs_aux(&self) -> bool {
        self.h2o.is_some()
            || matches!(
                self.sparsity.key_method,
                Method::TokenOutputAware | Method::ThinkStructured
            )
            || matches!(self.sparsity.value_method, Method::ChannelOutputAware)
    }

    fn compresses(&self) -> bool {
        self.sparsity.key_method != Method::None
            || self.sparsity.value_method != Method::None
            || self.quant.is_some()
            || self.h2o.is_some()
    }
}

/// Whether any config in the grid needs the (expensive) attention-matrix
/// capture during prefill.
pub fn grid_needs_aux(cfgs: &[EvalConfig]) -> bool {
    cfgs.iter().any(|c| c.needs_aux())
}

/// Evaluate one sample under every config; returns scores in [0, 1].
///
/// The context minus its trailing `query_len` tokens is prefilled once
/// (dense, as in the paper); per config, the cache is pruned/quantized/
/// evicted + compressed, the query tokens are decoded teacher-forced, and
/// the answer is scored (greedy generation or teacher-forced accuracy).
pub fn eval_sample(model: &NativeModel, sample: &TaskSample, cfgs: &[EvalConfig]) -> Vec<f64> {
    let ctx = &sample.context;
    let qlen = sample.query_len.max(1).min(ctx.len() - 1);
    let t_pre = ctx.len() - qlen;
    let pre = model.prefill(&ctx[..t_pre], grid_needs_aux(cfgs));

    cfgs.iter().map(|cfg| eval_one(model, sample, &pre, cfg, t_pre)).collect()
}

fn eval_one(
    model: &NativeModel,
    sample: &TaskSample,
    pre: &PrefillResult,
    cfg: &EvalConfig,
    t_pre: usize,
) -> f64 {
    let mcfg = model.cfg();
    let policy = KvPolicy {
        sparsity: cfg.sparsity,
        quant: cfg.quant,
        compress: cfg.compresses(),
        local_window: LOCAL_WINDOW,
    };
    let mut kv = SequenceKV::new(policy, mcfg.n_layers, mcfg.n_kv_heads, mcfg.head_dim)
        .expect("kv geometry");

    // H2O eviction first (paper §4.2.1: Mustafar prunes the *retained*
    // tokens), per head — budgets are uniform so head token counts agree.
    let (k_rows, v_rows, t_kept, aux) = if let Some(h2o) = cfg.h2o {
        let hd = mcfg.head_dim;
        let (rb, hb) = crate::evict::budgets_from_fraction(t_pre, h2o.recent_frac, h2o.hh_frac);
        let mut k_f = Vec::with_capacity(pre.k.len());
        let mut v_f = Vec::with_capacity(pre.v.len());
        let mut aux_f = PruneAux::default();
        let mut kept_len = 0;
        for idx in 0..pre.k.len() {
            let att: Vec<f64> = pre.att_total[idx].iter().map(|&x| x as f64).collect();
            let sel = h2o_select(&att, t_pre, rb, hb);
            kept_len = sel.kept.len();
            let mut km = Vec::with_capacity(sel.kept.len() * hd);
            let mut vm = Vec::with_capacity(sel.kept.len() * hd);
            let mut aw = Vec::with_capacity(sel.kept.len());
            for &t in &sel.kept {
                km.extend_from_slice(&pre.k[idx][t * hd..(t + 1) * hd]);
                vm.extend_from_slice(&pre.v[idx][t * hd..(t + 1) * hd]);
                aw.push(pre.aux.att_win[idx].get(t).copied().unwrap_or(0.0));
            }
            k_f.push(km);
            v_f.push(vm);
            aux_f.q_abs_win.push(pre.aux.q_abs_win.get(idx).cloned().unwrap_or_default());
            aux_f.att_win.push(aw);
        }
        (k_f, v_f, kept_len, Some(aux_f))
    } else {
        (pre.k.clone(), pre.v.clone(), t_pre, None)
    };

    let aux_ref = if cfg.needs_aux() {
        if aux.is_some() {
            aux.as_ref()
        } else {
            Some(&pre.aux)
        }
    } else {
        None
    };
    kv.ingest_prefill(&k_rows, &v_rows, t_kept, aux_ref).expect("ingest");

    // Feed the query through decode steps (positions continue from the
    // *original* sequence, eviction notwithstanding — keys keep their
    // RoPE positions).
    let ctx = &sample.context;
    let mut logits = Vec::new();
    for (i, &tok) in ctx[t_pre..].iter().enumerate() {
        logits = model.decode(tok, t_pre + i, &mut kv).expect("decode");
    }
    let mut pos = ctx.len();

    // Score the answer.
    let ans = &sample.answer;
    let mut correct = 0usize;
    for (j, &gold) in ans.iter().enumerate() {
        let pred = argmax(&logits);
        if pred == gold {
            correct += 1;
        }
        if j + 1 < ans.len() {
            // forced: feed gold; gen: feed the model's own token
            let next = if sample.forced { gold } else { pred };
            logits = model.decode(next, pos, &mut kv).expect("decode");
            pos += 1;
        }
    }
    correct as f64 / ans.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ModelConfig;
    use crate::model::Weights;
    use crate::workload::tasks;

    fn tiny_model() -> NativeModel {
        let cfg = ModelConfig {
            name: "tiny".into(),
            d_model: 64,
            n_layers: 2,
            n_heads: 2,
            n_kv_heads: 1,
            head_dim: 32,
            ff: 128,
            vocab: 512,
            rope_theta: 10000.0,
            max_seq: 512,
            norm_eps: 1e-5,
        };
        NativeModel::new(Weights::random_for_tests(cfg, 7))
    }

    #[test]
    fn grid_eval_runs_all_config_kinds() {
        let model = tiny_model();
        let sample = tasks::generate("sqa-easy", 0, 256);
        let cfgs = vec![
            EvalConfig::dense(),
            EvalConfig::mustafar(0.5, 0.5),
            EvalConfig::think(0.5),
            EvalConfig::methods(
                "oa",
                Method::TokenOutputAware,
                0.5,
                Method::ChannelOutputAware,
                0.5,
            ),
            EvalConfig {
                label: "kivi".into(),
                sparsity: SparsityConfig::mustafar(0.5, 0.5),
                quant: Some(QuantConfig { key_bits: 4, value_bits: 4 }),
                h2o: None,
            },
            EvalConfig {
                label: "h2o".into(),
                sparsity: SparsityConfig::mustafar(0.5, 0.5),
                quant: None,
                h2o: Some(H2oConfig { recent_frac: 0.1, hh_frac: 0.1 }),
            },
        ];
        let scores = eval_sample(&model, &sample, &cfgs);
        assert_eq!(scores.len(), cfgs.len());
        for s in scores {
            assert!((0.0..=1.0).contains(&s));
        }
    }

    #[test]
    fn forced_scoring_counts_positions() {
        let model = tiny_model();
        let sample = tasks::generate("sum-recap8", 1, 256);
        assert!(sample.forced);
        assert_eq!(sample.answer.len(), 8);
        let scores = eval_sample(&model, &sample, &[EvalConfig::dense()]);
        // untrained random model: score is a multiple of 1/8 in [0,1]
        let q = (scores[0] * 8.0).round() / 8.0;
        assert!((scores[0] - q).abs() < 1e-9);
    }

    #[test]
    fn dense_config_is_deterministic() {
        let model = tiny_model();
        let sample = tasks::generate("syn-passkey", 2, 256);
        let a = eval_sample(&model, &sample, &[EvalConfig::dense()]);
        let b = eval_sample(&model, &sample, &[EvalConfig::dense()]);
        assert_eq!(a, b);
    }
}
