//! Paper-experiment regenerators: one function per table/figure of the
//! evaluation section (DESIGN.md §7 maps experiment ids to modules).
//! Each prints a paper-style table and writes `reports/<id>.md`.

use std::path::PathBuf;

use crate::error::{Error, Result};
use crate::eval::distribution;
use crate::eval::harness::{run_sweep, SweepResult};
use crate::eval::pipeline::{EvalConfig, H2oConfig};
use crate::fmt::table::{fnum, Table};
use crate::kvcache::{KvPolicy, QuantConfig, SequenceKV};
use crate::model::{NativeModel, Weights};
use crate::prune::Method;
use crate::util::Pcg32;
use crate::workload::tasks::Category;
use crate::workload::lang;

/// Shared experiment context (artifact + report dirs, sample budget).
pub struct ExpCtx {
    pub artifacts: PathBuf,
    pub reports: PathBuf,
    pub n_samples: usize,
    pub ctx_len: usize,
}

impl ExpCtx {
    pub fn new(artifacts: PathBuf, reports: PathBuf) -> ExpCtx {
        ExpCtx { artifacts, reports, n_samples: 20, ctx_len: 448 }
    }

    fn model(&self, name: &str) -> Result<NativeModel> {
        Ok(NativeModel::new(Weights::load(&self.artifacts, name)?))
    }

    fn write_report(&self, id: &str, content: &str) -> Result<()> {
        std::fs::create_dir_all(&self.reports)?;
        let path = self.reports.join(format!("{id}.md"));
        std::fs::write(&path, content)?;
        crate::info!("wrote {}", path.display());
        Ok(())
    }
}

/// All known experiment ids, in run order.
pub const ALL_EXPERIMENTS: [&str; 16] = [
    "fig2", "table1", "table2", "table3", "table4", "table5", "table6",
    "table7", "table8", "table9", "table10", "table11", "table12", "fig6b",
    "ppl", "window",
];

/// Dispatch one experiment by id ("all" runs everything).
pub fn run(id: &str, ctx: &ExpCtx) -> Result<()> {
    match id {
        "all" => {
            for e in ALL_EXPERIMENTS {
                if let Err(err) = run(e, ctx) {
                    // keep going — a missing model (e.g. gqa-medium not yet
                    // trained) should not block the remaining experiments
                    eprintln!("[exp] {e} failed: {err}");
                }
            }
            Ok(())
        }
        "fig2" => fig2(ctx),
        "table1" => key_method_study(ctx, "gqa-small", "table1"),
        "table2" => value_method_study(ctx, "gqa-small", "table2"),
        "table3" => table3(ctx),
        "table4" => table4(ctx),
        "table5" => table5(ctx),
        "table6" => table6(ctx),
        "table7" => key_method_study(ctx, "mha-small", "table7"),
        "table8" => value_method_study(ctx, "mha-small", "table8"),
        "table9" => table9(ctx),
        "table10" => table10(ctx),
        "table11" => table11(ctx),
        "table12" => table12(ctx),
        "fig6b" => fig6b(ctx),
        "ppl" => ppl_study(ctx),
        "window" => window_study(ctx),
        other => Err(Error::Invalid(format!("unknown experiment '{other}'"))),
    }
}

// ---------------------------------------------------------------------------
// rendering helpers
// ---------------------------------------------------------------------------

/// Category-rows table (paper Tables 1/2/3/7/8/9 layout).
fn render_category_table(title: &str, sweep: &SweepResult) -> String {
    let mut header = vec!["Task"];
    let labels: Vec<&str> = sweep.config_labels.iter().map(|s| s.as_str()).collect();
    header.extend(labels.iter());
    let mut t = Table::new(title, &header);
    let mut avg_row = vec!["Average".to_string()];
    for c in 0..sweep.config_labels.len() {
        avg_row.push(fnum(sweep.average(c), 2));
    }
    t.row(avg_row);
    for cat in Category::all() {
        let mut row = vec![cat.name().to_string()];
        for c in 0..sweep.config_labels.len() {
            row.push(fnum(sweep.category_avg(c, cat), 2));
        }
        t.row(row);
    }
    let out = t.render();
    println!("{out}");
    out
}

/// Config-rows × task-columns table (paper Table 4 layout).
fn render_grid_table(title: &str, sweep: &SweepResult) -> String {
    let mut header = vec!["Config".to_string()];
    header.extend(sweep.task_ids.iter().cloned());
    header.push("Avg.".to_string());
    let hrefs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(title, &hrefs);
    for c in 0..sweep.config_labels.len() {
        let mut row = vec![sweep.config_labels[c].clone()];
        for &s in &sweep.scores[c] {
            row.push(fnum(s, 2));
        }
        row.push(fnum(sweep.average(c), 2));
        t.row(row);
    }
    let out = t.render();
    println!("{out}");
    out
}

fn six_task_subset() -> Vec<&'static str> {
    // one representative task per category (paper Tables 5/6 use
    // NtrvQA/HotpotQA/GovReport/TREC/PCount/Lcc)
    vec!["sqa-easy", "mqa-2doc", "sum-recap8", "few-map", "syn-count", "code-ident"]
}

// ---------------------------------------------------------------------------
// Fig 2 — KV magnitude distributions
// ---------------------------------------------------------------------------

fn fig2(ctx: &ExpCtx) -> Result<()> {
    let mut out = String::from("# Fig 2 — KV cache magnitude distribution\n\n");
    out.push_str(
        "Paper: Key cache has distinct channel-wise outliers; Value cache is \
         uniform. Metric: max/mean ratio of per-channel mean |x| (1.0 = \
         perfectly uniform).\n\n",
    );
    let cols = ["model", "Key cache", "Value cache", "K/V ratio"];
    let mut t = Table::new("Channel outlier ratios", &cols);
    for name in ["gqa-small", "mha-small", "gqa-medium"] {
        let Ok(model) = ctx.model(name) else {
            crate::info!("fig2: skipping {name} (weights missing)");
            continue;
        };
        let prompt = lang::gen_document(&mut Pcg32::seeded(1234), ctx.ctx_len);
        let r = distribution::analyze_model(&model, &prompt);
        t.row(vec![
            name.to_string(),
            fnum(r.key_outlier_mean, 2),
            fnum(r.value_outlier_mean, 2),
            fnum(r.key_outlier_mean / r.value_outlier_mean.max(1e-9), 2),
        ]);
    }
    let body = t.render();
    println!("{body}");
    out.push_str(&body);
    ctx.write_report("fig2", &out)
}

// ---------------------------------------------------------------------------
// Tables 1/7 — Key-cache pruning method study
// ---------------------------------------------------------------------------

fn key_method_study(ctx: &ExpCtx, model_name: &str, id: &str) -> Result<()> {
    let model = ctx.model(model_name)?;
    let mut cfgs = vec![EvalConfig::dense()];
    for s in [0.5, 0.7] {
        cfgs.push(EvalConfig::think(s));
        cfgs.push(EvalConfig::methods(
            &format!("OA-Unstr K{s}"),
            Method::TokenOutputAware,
            s,
            Method::None,
            0.0,
        ));
        cfgs.push(EvalConfig::methods(
            &format!("Mag K{s}"),
            Method::TokenMagnitude,
            s,
            Method::None,
            0.0,
        ));
    }
    let sweep = run_sweep(&model, &cfgs, None, ctx.n_samples, ctx.ctx_len);
    let body = render_category_table(
        &format!("{id} — Key-cache pruning methods ({model_name})"),
        &sweep,
    );
    ctx.write_report(id, &body)
}

// ---------------------------------------------------------------------------
// Tables 2/8 — Value-cache pruning method study
// ---------------------------------------------------------------------------

fn value_method_study(ctx: &ExpCtx, model_name: &str, id: &str) -> Result<()> {
    let model = ctx.model(model_name)?;
    let mut cfgs = vec![EvalConfig::dense()];
    for s in [0.5, 0.7] {
        cfgs.push(EvalConfig::methods(
            &format!("ThinK V{s}"),
            Method::None,
            0.0,
            Method::ThinkStructured,
            s,
        ));
        cfgs.push(EvalConfig::methods(
            &format!("ChMag V{s}"),
            Method::None,
            0.0,
            Method::ChannelMagnitude,
            s,
        ));
        cfgs.push(EvalConfig::methods(
            &format!("ChOA V{s}"),
            Method::None,
            0.0,
            Method::ChannelOutputAware,
            s,
        ));
        cfgs.push(EvalConfig::methods(
            &format!("TokMag V{s}"),
            Method::None,
            0.0,
            Method::TokenMagnitude,
            s,
        ));
    }
    let sweep = run_sweep(&model, &cfgs, None, ctx.n_samples, ctx.ctx_len);
    let body = render_category_table(
        &format!("{id} — Value-cache pruning methods ({model_name})"),
        &sweep,
    );
    ctx.write_report(id, &body)
}

// ---------------------------------------------------------------------------
// Table 3 — K+V per-token magnitude on both small models
// ---------------------------------------------------------------------------

fn table3(ctx: &ExpCtx) -> Result<()> {
    let cfgs = vec![
        EvalConfig::dense(),
        EvalConfig::mustafar(0.5, 0.5),
        EvalConfig::mustafar(0.7, 0.7),
    ];
    let mut out = String::new();
    for name in ["gqa-small", "mha-small"] {
        let model = ctx.model(name)?;
        let sweep = run_sweep(&model, &cfgs, None, ctx.n_samples, ctx.ctx_len);
        out.push_str(&render_category_table(
            &format!("table3 — K+V per-token magnitude ({name})"),
            &sweep,
        ));
        out.push('\n');
    }
    ctx.write_report("table3", &out)
}

// ---------------------------------------------------------------------------
// Table 4 — full sparsity grid × 16 tasks × 3 models
// ---------------------------------------------------------------------------

fn grid_configs() -> Vec<EvalConfig> {
    vec![
        EvalConfig::dense(),
        EvalConfig::think(0.5),
        EvalConfig::mustafar(0.5, 0.0),
        EvalConfig::think(0.7),
        EvalConfig::mustafar(0.7, 0.0),
        EvalConfig::mustafar(0.0, 0.5),
        EvalConfig::mustafar(0.0, 0.7),
        EvalConfig::mustafar(0.5, 0.5),
        EvalConfig::mustafar(0.7, 0.7),
    ]
}

fn table4(ctx: &ExpCtx) -> Result<()> {
    let cfgs = grid_configs();
    let mut out = String::new();
    for name in ["gqa-small", "mha-small", "gqa-medium"] {
        let model = ctx.model(name)?;
        let sweep = run_sweep(&model, &cfgs, None, ctx.n_samples, ctx.ctx_len);
        out.push_str(&render_grid_table(&format!("table4 — full grid ({name})"), &sweep));
        out.push('\n');
    }
    ctx.write_report("table4", &out)
}

// ---------------------------------------------------------------------------
// Table 5 — joint with H2O token eviction (20% KV budget)
// ---------------------------------------------------------------------------

fn table5(ctx: &ExpCtx) -> Result<()> {
    let model = ctx.model("mha-small")?;
    let h2o = Some(H2oConfig { recent_frac: 0.1, hh_frac: 0.1 });
    let with_h2o = |mut c: EvalConfig, label: &str| {
        c.h2o = h2o;
        c.label = label.to_string();
        c
    };
    let cfgs = vec![
        EvalConfig::dense(), // "Full KV cache" row
        with_h2o(EvalConfig::dense(), "H2O Dense"),
        with_h2o(EvalConfig::mustafar(0.5, 0.0), "H2O K0.5"),
        with_h2o(EvalConfig::mustafar(0.7, 0.0), "H2O K0.7"),
        with_h2o(EvalConfig::mustafar(0.0, 0.5), "H2O V0.5"),
        with_h2o(EvalConfig::mustafar(0.0, 0.7), "H2O V0.7"),
        with_h2o(EvalConfig::mustafar(0.5, 0.5), "H2O K0.5 V0.5"),
        with_h2o(EvalConfig::mustafar(0.7, 0.7), "H2O K0.7 V0.7"),
    ];
    let subset = six_task_subset();
    let model_sweep = run_sweep(&model, &cfgs, Some(&subset), ctx.n_samples, ctx.ctx_len);
    let body = render_grid_table("table5 — Mustafar + H2O (mha-small, 20% budget)", &model_sweep);
    ctx.write_report("table5", &body)
}

// ---------------------------------------------------------------------------
// Table 6 — joint with KIVI quantization
// ---------------------------------------------------------------------------

fn table6(ctx: &ExpCtx) -> Result<()> {
    let model = ctx.model("gqa-small")?;
    let mut cfgs = vec![EvalConfig::dense()];
    for bits in [4u32, 2] {
        let q = Some(QuantConfig { key_bits: bits, value_bits: bits });
        let mk = |mut c: EvalConfig, label: String| {
            c.quant = q;
            c.label = label;
            c
        };
        cfgs.push(mk(EvalConfig::dense(), format!("KIVI{bits} Dense")));
        cfgs.push(mk(EvalConfig::mustafar(0.5, 0.0), format!("KIVI{bits} K0.5")));
        cfgs.push(mk(EvalConfig::mustafar(0.7, 0.0), format!("KIVI{bits} K0.7")));
        cfgs.push(mk(EvalConfig::mustafar(0.0, 0.5), format!("KIVI{bits} V0.5")));
        cfgs.push(mk(EvalConfig::mustafar(0.0, 0.7), format!("KIVI{bits} V0.7")));
        cfgs.push(mk(EvalConfig::mustafar(0.5, 0.5), format!("KIVI{bits} K0.5 V0.5")));
        cfgs.push(mk(EvalConfig::mustafar(0.7, 0.7), format!("KIVI{bits} K0.7 V0.7")));
    }
    let subset = six_task_subset();
    let sweep = run_sweep(&model, &cfgs, Some(&subset), ctx.n_samples, ctx.ctx_len);
    let body = render_grid_table("table6 — Mustafar + KIVI (gqa-small)", &sweep);
    ctx.write_report("table6", &body)
}

// ---------------------------------------------------------------------------
// Table 9 — K+V magnitude on mha-small (App. A.1)
// ---------------------------------------------------------------------------

fn table9(ctx: &ExpCtx) -> Result<()> {
    let model = ctx.model("mha-small")?;
    let cfgs = vec![
        EvalConfig::dense(),
        EvalConfig::mustafar(0.5, 0.5),
        EvalConfig::mustafar(0.7, 0.7),
    ];
    let sweep = run_sweep(&model, &cfgs, None, ctx.n_samples, ctx.ctx_len);
    let body = render_category_table("table9 — K+V per-token magnitude (mha-small)", &sweep);
    ctx.write_report("table9", &body)
}

// ---------------------------------------------------------------------------
// Table 10 — larger model incl. mixed sparsity (App. A.2)
// ---------------------------------------------------------------------------

fn table10(ctx: &ExpCtx) -> Result<()> {
    let model = ctx.model("gqa-medium")?;
    let mut cfgs = grid_configs();
    cfgs.push(EvalConfig::mustafar(0.5, 0.7)); // the paper's mixed pick
    let sweep = run_sweep(&model, &cfgs, None, ctx.n_samples, ctx.ctx_len);
    let body = render_grid_table("table10 — larger model, incl. K0.5 V0.7 (gqa-medium)", &sweep);
    ctx.write_report("table10", &body)
}

// ---------------------------------------------------------------------------
// Table 11 — higher sparsity (App. A.3)
// ---------------------------------------------------------------------------

fn table11(ctx: &ExpCtx) -> Result<()> {
    let model = ctx.model("gqa-small")?;
    let cfgs = vec![
        EvalConfig::dense(),
        EvalConfig::mustafar(0.8, 0.0),
        EvalConfig::mustafar(0.9, 0.0),
        EvalConfig::mustafar(0.0, 0.8),
        EvalConfig::mustafar(0.0, 0.9),
        EvalConfig::mustafar(0.8, 0.8),
        EvalConfig::mustafar(0.9, 0.9),
    ];
    let sweep = run_sweep(&model, &cfgs, None, ctx.n_samples, ctx.ctx_len);
    let body = render_grid_table("table11 — higher sparsity (gqa-small)", &sweep);
    ctx.write_report("table11", &body)
}

// ---------------------------------------------------------------------------
// Table 12 — 2:4 semi-structured vs unstructured (App. B)
// ---------------------------------------------------------------------------

fn table12(ctx: &ExpCtx) -> Result<()> {
    let model = ctx.model("gqa-small")?;
    let cfgs = vec![
        EvalConfig::dense(),
        EvalConfig::methods("K0.5 (2:4)", Method::Semi24, 0.5, Method::None, 0.0),
        EvalConfig::methods("K0.5 (Unstr)", Method::TokenMagnitude, 0.5, Method::None, 0.0),
        EvalConfig::methods("V0.5 (2:4)", Method::None, 0.0, Method::Semi24, 0.5),
        EvalConfig::methods("V0.5 (Unstr)", Method::None, 0.0, Method::TokenMagnitude, 0.5),
        EvalConfig::methods("KV0.5 (2:4)", Method::Semi24, 0.5, Method::Semi24, 0.5),
        EvalConfig::methods(
            "KV0.5 (Unstr)",
            Method::TokenMagnitude,
            0.5,
            Method::TokenMagnitude,
            0.5,
        ),
    ];
    let sweep = run_sweep(&model, &cfgs, None, ctx.n_samples, ctx.ctx_len);
    let body = render_grid_table("table12 — 2:4 vs unstructured (gqa-small)", &sweep);
    ctx.write_report("table12", &body)
}

// ---------------------------------------------------------------------------
// Supplementary: perplexity degradation under pruning (floor-free signal)
// ---------------------------------------------------------------------------

/// Held-out NLL under the §2 method grid — the model-quality-independent
/// version of Tables 1/2: the *ordering* of methods is the reproduction
/// target (dense < unstructured magnitude/OA < 2:4 < structured).
fn ppl_study(ctx: &ExpCtx) -> Result<()> {
    let mut out = String::from(
        "# Supplementary — held-out NLL (nats/token) under KV pruning\n\n         \
         Lower is better; Dense is the floor. This signal does not depend\n         \
         on task mastery, so it is meaningful at any training budget.\n\n",
    );
    for name in ["gqa-small", "mha-small"] {
        let Ok(model) = ctx.model(name) else { continue };
        let cfgs = vec![
            EvalConfig::dense(),
            EvalConfig::mustafar(0.5, 0.5),
            EvalConfig::methods(
                "OA-K0.5 V0.5",
                Method::TokenOutputAware,
                0.5,
                Method::TokenMagnitude,
                0.5,
            ),
            EvalConfig::methods("2:4 KV", Method::Semi24, 0.5, Method::Semi24, 0.5),
            EvalConfig::methods("ChMag V0.5", Method::None, 0.0, Method::ChannelMagnitude, 0.5),
            EvalConfig::think(0.5),
            EvalConfig::mustafar(0.7, 0.7),
            EvalConfig::think(0.7),
            EvalConfig::mustafar(0.9, 0.9),
        ];
        let (ns, cl) = (ctx.n_samples.min(12), ctx.ctx_len.min(384));
        let nll = crate::eval::ppl::sweep_nll(&model, &cfgs, ns, cl);
        let cols = ["config", "NLL (nats/tok)", "Δ vs dense"];
        let mut t = Table::new(&format!("ppl — {name}"), &cols);
        for (c, cfg) in cfgs.iter().enumerate() {
            t.row(vec![
                cfg.label.clone(),
                fnum(nll[c], 4),
                fnum(nll[c] - nll[0], 4),
            ]);
        }
        let body = t.render();
        println!("{body}");
        out.push_str(&body);
        out.push('\n');
    }
    ctx.write_report("ppl", &out)
}

/// §13 — held-out NLL vs dense local-window size, per sparsity tier.
/// The local window is the deferred pipeline's ring-tail floor (the
/// most recent `window` tokens always stay dense), so this table is
/// the quality side of the window knob: how much NLL each tier buys
/// back as the dense window grows from 8 to 64 tokens.
fn window_study(ctx: &ExpCtx) -> Result<()> {
    let windows = [8usize, 16, 32, 64];
    let tiers = [0.5f64, 0.7, 0.9];
    let mut out = String::from(
        "# §13 — held-out NLL (nats/token) vs local window size\n\n         \
         Rows sweep the dense local window (the ring-tail floor of the\n         \
         deferred compression pipeline); columns sweep the Mustafar\n         \
         sparsity tier. Dense NLL is the shared floor.\n\n",
    );
    for name in ["gqa-small", "mha-small"] {
        let Ok(model) = ctx.model(name) else { continue };
        let (ns, cl) = (ctx.n_samples.min(12), ctx.ctx_len.min(384));
        let dense = crate::eval::ppl::sweep_nll(&model, &[EvalConfig::dense()], ns, cl)[0];
        let mut header = vec!["window".to_string()];
        header.extend(tiers.iter().map(|t| format!("K{t} V{t}")));
        let cols: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
        let mut t = Table::new(&format!("window — {name} (dense NLL {})", fnum(dense, 4)), &cols);
        for &w in &windows {
            let cfgs: Vec<EvalConfig> =
                tiers.iter().map(|&s| EvalConfig::mustafar(s, s)).collect();
            let nll = crate::eval::ppl::sweep_nll_window(&model, &cfgs, ns, cl, w);
            let mut row = vec![w.to_string()];
            row.extend(nll.iter().map(|&x| fnum(x, 4)));
            t.row(row);
        }
        let body = t.render();
        println!("{body}");
        out.push_str(&body);
        out.push('\n');
    }
    ctx.write_report("window", &out)
}

// ---------------------------------------------------------------------------
// Fig 6b — compression rate vs accuracy
// ---------------------------------------------------------------------------

fn fig6b(ctx: &ExpCtx) -> Result<()> {
    let mut out = String::from("# Fig 6b — compression rate vs LongBench-sim average\n\n");
    for name in ["gqa-small", "mha-small"] {
        let model = ctx.model(name)?;
        // measured compression rate on a real prompt through the KV manager
        let rate_of = |cfg: &EvalConfig| -> f64 {
            let mcfg = model.cfg();
            let prompt = lang::gen_document(&mut Pcg32::seeded(5), ctx.ctx_len);
            let pre = model.prefill(&prompt, false);
            let policy = KvPolicy {
                sparsity: cfg.sparsity,
                quant: None,
                compress: cfg.sparsity.key_method != Method::None
                    || cfg.sparsity.value_method != Method::None,
                local_window: crate::prune::LOCAL_WINDOW,
            };
            let mut kv = SequenceKV::new(policy, mcfg.n_layers, mcfg.n_kv_heads, mcfg.head_dim)
                .expect("kv geometry");
            kv.ingest_prefill(&pre.k, &pre.v, pre.t, None).unwrap();
            if cfg.sparsity.key_method == Method::ThinkStructured {
                // ThinK keeps kept channels dense: kept fraction of K + dense V
                let ks = 1.0 - cfg.sparsity.key_sparsity;
                return (ks + 1.0) / 2.0;
            }
            kv.compression_rate()
        };

        let points = vec![
            EvalConfig::dense(),
            EvalConfig::think(0.5),
            EvalConfig::think(0.7),
            EvalConfig::mustafar(0.5, 0.0),
            EvalConfig::mustafar(0.7, 0.0),
            EvalConfig::mustafar(0.5, 0.5),
            EvalConfig::mustafar(0.7, 0.7),
        ];
        let sweep = run_sweep(&model, &points, None, ctx.n_samples, ctx.ctx_len);
        let mut t = Table::new(
            &format!("fig6b — {name}"),
            &["config", "compression rate (% of dense)", "LongBench-sim avg"],
        );
        for (i, cfg) in points.iter().enumerate() {
            t.row(vec![
                cfg.label.clone(),
                fnum(rate_of(cfg) * 100.0, 1),
                fnum(sweep.average(i), 2),
            ]);
        }
        let body = t.render();
        println!("{body}");
        out.push_str(&body);
        out.push('\n');
    }
    ctx.write_report("fig6b", &out)
}
