//! Request traces for the throughput experiments (Fig 7): batches of
//! prompts with configurable input/generation lengths, built from the
//! synthetic language so prompts look like training data.

use super::lang;
use crate::util::Pcg32;

/// One serving request in a trace.
#[derive(Clone, Debug)]
pub struct TraceRequest {
    pub id: u64,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    /// Client disconnect model: cancel this request once it has
    /// generated this many tokens (`Some(0)` = the client hangs up
    /// while the request is still queued or being prefilled). `None` =
    /// the client stays until completion. Drivers poll
    /// `Engine::progress` against this and call `Engine::cancel` when
    /// the threshold is reached; ignoring the field replays the same
    /// trace without cancellation (the before/after baseline).
    pub cancel_after: Option<usize>,
}

/// A batch-throughput trace: `n` requests of `input_len` prompt tokens,
/// each asking for `gen_len` generated tokens (the paper's Fig 7 uses
/// in 2048 / gen 2048 for Llama-2 and in 4096 / gen 4096 for Llama-3,
/// scaled in our harness to the trained context).
pub fn uniform_trace(seed: u64, n: usize, input_len: usize, gen_len: usize) -> Vec<TraceRequest> {
    (0..n)
        .map(|i| {
            let mut rng = Pcg32::new(seed.wrapping_mul(7919).wrapping_add(i as u64), 54);
            TraceRequest {
                id: i as u64,
                prompt: lang::gen_document(&mut rng, input_len),
                max_new_tokens: gen_len,
                cancel_after: None,
            }
        })
        .collect()
}

/// A shared-prefix trace (EXPERIMENTS §6): every request's prompt opens
/// with the same `prefix_len`-token document (a shared system prompt /
/// few-shot header) followed by a per-request `suffix_len`-token
/// continuation — the workload the kvpool prefix cache is built for.
pub fn shared_prefix_trace(
    seed: u64,
    n: usize,
    prefix_len: usize,
    suffix_len: usize,
    gen_len: usize,
) -> Vec<TraceRequest> {
    let mut prng = Pcg32::new(seed.wrapping_mul(6151).wrapping_add(13), 77);
    let prefix = lang::gen_document(&mut prng, prefix_len);
    (0..n)
        .map(|i| {
            let mut rng = Pcg32::new(seed.wrapping_mul(389).wrapping_add(i as u64), 55);
            let mut prompt = prefix.clone();
            prompt.extend(lang::gen_document(&mut rng, suffix_len));
            TraceRequest { id: i as u64, prompt, max_new_tokens: gen_len, cancel_after: None }
        })
        .collect()
}

/// A disconnect-heavy trace (EXPERIMENTS §8): three out of every four
/// clients hang up before their request completes — one while still
/// queued/prefilling (`cancel_after = 0`), one early in decode
/// (`gen_len / 8`), one mid-decode (`gen_len / 2`) — and one stays to
/// the end. Replayed twice (honoring vs ignoring `cancel_after`) it
/// measures how much pressure-ladder damage (re-prunes of, and
/// preemptions against, *live* requests) first-class cancellation
/// avoids by releasing dead requests' pages immediately.
pub fn disconnect_trace(
    seed: u64,
    n: usize,
    input_len: usize,
    gen_len: usize,
) -> Vec<TraceRequest> {
    uniform_trace(seed, n, input_len, gen_len)
        .into_iter()
        .map(|mut r| {
            r.cancel_after = match r.id % 4 {
                1 => Some(0),
                2 => Some((gen_len / 8).max(1)),
                3 => Some((gen_len / 2).max(1)),
                _ => None,
            };
            r
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shapes() {
        let tr = uniform_trace(1, 4, 128, 32);
        assert_eq!(tr.len(), 4);
        for r in &tr {
            assert_eq!(r.prompt.len(), 128);
            assert_eq!(r.max_new_tokens, 32);
        }
        // distinct prompts
        assert_ne!(tr[0].prompt, tr[1].prompt);
    }

    #[test]
    fn trace_deterministic() {
        assert_eq!(uniform_trace(2, 2, 64, 8)[1].prompt, uniform_trace(2, 2, 64, 8)[1].prompt);
    }

    #[test]
    fn disconnect_trace_is_disconnect_heavy_and_deterministic() {
        let tr = disconnect_trace(5, 8, 96, 64);
        assert_eq!(tr.len(), 8);
        let cancels: Vec<Option<usize>> = tr.iter().map(|r| r.cancel_after).collect();
        assert_eq!(cancels.iter().filter(|c| c.is_none()).count(), 2, "1 in 4 survives");
        assert!(cancels.contains(&Some(0)), "some clients hang up before prefill");
        assert!(cancels.contains(&Some(8)) && cancels.contains(&Some(32)));
        // prompts match the uniform trace (same seed): only the
        // disconnect schedule differs between the two replays
        let base = uniform_trace(5, 8, 96, 64);
        for (a, b) in tr.iter().zip(&base) {
            assert_eq!(a.prompt, b.prompt);
        }
        assert_eq!(disconnect_trace(5, 8, 96, 64)[3].cancel_after, tr[3].cancel_after);
    }

    #[test]
    fn shared_prefix_trace_shares_exactly_the_prefix() {
        let tr = shared_prefix_trace(3, 4, 192, 64, 16);
        assert_eq!(tr.len(), 4);
        for r in &tr {
            assert_eq!(r.prompt.len(), 256);
            assert_eq!(r.prompt[..192], tr[0].prompt[..192], "prefix diverged");
        }
        // suffixes differ between requests
        assert_ne!(tr[0].prompt[192..], tr[1].prompt[192..]);
        // deterministic
        assert_eq!(shared_prefix_trace(3, 4, 192, 64, 16)[2].prompt, tr[2].prompt);
    }
}
