//! Request traces for the throughput experiments (Fig 7): batches of
//! prompts with configurable input/generation lengths, built from the
//! synthetic language so prompts look like training data.

use super::lang;
use crate::util::Pcg32;

/// One serving request in a trace.
#[derive(Clone, Debug)]
pub struct TraceRequest {
    pub id: u64,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
    /// Client disconnect model: cancel this request once it has
    /// generated this many tokens (`Some(0)` = the client hangs up
    /// while the request is still queued or being prefilled). `None` =
    /// the client stays until completion. Drivers poll
    /// `Engine::progress` against this and call `Engine::cancel` when
    /// the threshold is reached; ignoring the field replays the same
    /// trace without cancellation (the before/after baseline).
    pub cancel_after: Option<usize>,
}

/// A batch-throughput trace: `n` requests of `input_len` prompt tokens,
/// each asking for `gen_len` generated tokens (the paper's Fig 7 uses
/// in 2048 / gen 2048 for Llama-2 and in 4096 / gen 4096 for Llama-3,
/// scaled in our harness to the trained context).
pub fn uniform_trace(seed: u64, n: usize, input_len: usize, gen_len: usize) -> Vec<TraceRequest> {
    (0..n)
        .map(|i| {
            let mut rng = Pcg32::new(seed.wrapping_mul(7919).wrapping_add(i as u64), 54);
            TraceRequest {
                id: i as u64,
                prompt: lang::gen_document(&mut rng, input_len),
                max_new_tokens: gen_len,
                cancel_after: None,
            }
        })
        .collect()
}

/// A shared-prefix trace (EXPERIMENTS §6): every request's prompt opens
/// with the same `prefix_len`-token document (a shared system prompt /
/// few-shot header) followed by a per-request `suffix_len`-token
/// continuation — the workload the kvpool prefix cache is built for.
pub fn shared_prefix_trace(
    seed: u64,
    n: usize,
    prefix_len: usize,
    suffix_len: usize,
    gen_len: usize,
) -> Vec<TraceRequest> {
    let mut prng = Pcg32::new(seed.wrapping_mul(6151).wrapping_add(13), 77);
    let prefix = lang::gen_document(&mut prng, prefix_len);
    (0..n)
        .map(|i| {
            let mut rng = Pcg32::new(seed.wrapping_mul(389).wrapping_add(i as u64), 55);
            let mut prompt = prefix.clone();
            prompt.extend(lang::gen_document(&mut rng, suffix_len));
            TraceRequest { id: i as u64, prompt, max_new_tokens: gen_len, cancel_after: None }
        })
        .collect()
}

/// A disconnect-heavy trace (EXPERIMENTS §8): three out of every four
/// clients hang up before their request completes — one while still
/// queued/prefilling (`cancel_after = 0`), one early in decode
/// (`gen_len / 8`), one mid-decode (`gen_len / 2`) — and one stays to
/// the end. Replayed twice (honoring vs ignoring `cancel_after`) it
/// measures how much pressure-ladder damage (re-prunes of, and
/// preemptions against, *live* requests) first-class cancellation
/// avoids by releasing dead requests' pages immediately.
pub fn disconnect_trace(
    seed: u64,
    n: usize,
    input_len: usize,
    gen_len: usize,
) -> Vec<TraceRequest> {
    uniform_trace(seed, n, input_len, gen_len)
        .into_iter()
        .map(|mut r| {
            r.cancel_after = match r.id % 4 {
                1 => Some(0),
                2 => Some((gen_len / 8).max(1)),
                3 => Some((gen_len / 2).max(1)),
                _ => None,
            };
            r
        })
        .collect()
}

/// A chaos trace for the fault-injection acceptance test (EXPERIMENTS
/// §9): varied prompt and generation lengths (so admission, prefill,
/// decode growth, and completion all interleave under pressure) plus a
/// mix of clients that hang up while queued (`Some(0)`), mid-decode
/// (`gen/2`), or stay to the end. Fully deterministic in `seed` — the
/// chaos comes from the fault injector layered on top by the driver,
/// not from the trace itself, so a failing seed replays exactly.
pub fn chaos_trace(seed: u64, n: usize, input_len: usize, gen_len: usize) -> Vec<TraceRequest> {
    let mut shape = Pcg32::new(seed.wrapping_mul(4241).wrapping_add(17), 91);
    (0..n)
        .map(|i| {
            let mut rng = Pcg32::new(seed.wrapping_mul(9173).wrapping_add(i as u64), 33);
            // 1/4 .. 5/4 of the nominal lengths, never zero
            let ilen = (input_len / 4 + shape.below(input_len.max(1) as u32) as usize).max(1);
            let glen = (gen_len / 4 + shape.below(gen_len.max(1) as u32) as usize).max(1);
            let cancel_after = match shape.below(5) {
                0 => Some(0),
                1 => Some((glen / 2).max(1)),
                _ => None,
            };
            TraceRequest {
                id: i as u64,
                prompt: lang::gen_document(&mut rng, ilen),
                max_new_tokens: glen,
                cancel_after,
            }
        })
        .collect()
}

/// A bursty monster-prompt trace (EXPERIMENTS §12): one request with a
/// `monster_len`-token prompt (id 0, arriving first) followed by
/// `n_short` short interactive decoders (`short_len` prompt tokens,
/// `gen_len` generated each). Under run-to-completion admission the
/// monster's prefill head-of-line-blocks every decoder for its whole
/// duration; under chunked prefill with a round token budget the
/// decoders' inter-token latency stays bounded — the trace the
/// chunked-prefill SLO gate and the scheduler-fairness tests replay.
pub fn bursty_monster_trace(
    seed: u64,
    monster_len: usize,
    n_short: usize,
    short_len: usize,
    gen_len: usize,
) -> Vec<TraceRequest> {
    let mut out = Vec::with_capacity(n_short + 1);
    let mut mrng = Pcg32::new(seed.wrapping_mul(3571).wrapping_add(29), 83);
    out.push(TraceRequest {
        id: 0,
        prompt: lang::gen_document(&mut mrng, monster_len),
        max_new_tokens: gen_len,
        cancel_after: None,
    });
    for i in 0..n_short {
        let mut rng = Pcg32::new(seed.wrapping_mul(1471).wrapping_add(i as u64), 47);
        out.push(TraceRequest {
            id: i as u64 + 1,
            prompt: lang::gen_document(&mut rng, short_len),
            max_new_tokens: gen_len,
            cancel_after: None,
        });
    }
    out
}

/// A connection-storm trace (EXPERIMENTS §10): `conns` client
/// connections each pipelining `per_conn` small requests at the server
/// at once. Flat request list in connection-major order — request `k`
/// of connection `c` has id `c * per_conn + k`, so a driver can slice
/// per-connection workloads out of one deterministic trace and every
/// (connection, pipeline-slot) pair maps to a unique id for
/// exactly-once accounting across hundreds of sockets.
pub fn storm_trace(
    seed: u64,
    conns: usize,
    per_conn: usize,
    input_len: usize,
    gen_len: usize,
) -> Vec<TraceRequest> {
    (0..conns * per_conn)
        .map(|i| {
            let mut rng = Pcg32::new(seed.wrapping_mul(2887).wrapping_add(i as u64), 61);
            TraceRequest {
                id: i as u64,
                prompt: lang::gen_document(&mut rng, input_len),
                max_new_tokens: gen_len,
                cancel_after: None,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shapes() {
        let tr = uniform_trace(1, 4, 128, 32);
        assert_eq!(tr.len(), 4);
        for r in &tr {
            assert_eq!(r.prompt.len(), 128);
            assert_eq!(r.max_new_tokens, 32);
        }
        // distinct prompts
        assert_ne!(tr[0].prompt, tr[1].prompt);
    }

    #[test]
    fn trace_deterministic() {
        assert_eq!(uniform_trace(2, 2, 64, 8)[1].prompt, uniform_trace(2, 2, 64, 8)[1].prompt);
    }

    #[test]
    fn disconnect_trace_is_disconnect_heavy_and_deterministic() {
        let tr = disconnect_trace(5, 8, 96, 64);
        assert_eq!(tr.len(), 8);
        let cancels: Vec<Option<usize>> = tr.iter().map(|r| r.cancel_after).collect();
        assert_eq!(cancels.iter().filter(|c| c.is_none()).count(), 2, "1 in 4 survives");
        assert!(cancels.contains(&Some(0)), "some clients hang up before prefill");
        assert!(cancels.contains(&Some(8)) && cancels.contains(&Some(32)));
        // prompts match the uniform trace (same seed): only the
        // disconnect schedule differs between the two replays
        let base = uniform_trace(5, 8, 96, 64);
        for (a, b) in tr.iter().zip(&base) {
            assert_eq!(a.prompt, b.prompt);
        }
        assert_eq!(disconnect_trace(5, 8, 96, 64)[3].cancel_after, tr[3].cancel_after);
    }

    #[test]
    fn chaos_trace_is_varied_and_deterministic() {
        let tr = chaos_trace(11, 24, 64, 16);
        assert_eq!(tr.len(), 24);
        for r in &tr {
            assert!(!r.prompt.is_empty());
            assert!(r.max_new_tokens >= 1);
            assert!(r.prompt.len() <= 64 / 4 + 64, "input stays within 5/4 of nominal");
        }
        // lengths actually vary
        let lens: std::collections::HashSet<usize> = tr.iter().map(|r| r.prompt.len()).collect();
        assert!(lens.len() > 4, "prompt lengths should vary, got {lens:?}");
        // a mix of stay-to-the-end and hang-up clients
        assert!(tr.iter().any(|r| r.cancel_after.is_none()));
        assert!(tr.iter().any(|r| r.cancel_after.is_some()));
        // deterministic replay
        let again = chaos_trace(11, 24, 64, 16);
        for (a, b) in tr.iter().zip(&again) {
            assert_eq!(a.prompt, b.prompt);
            assert_eq!(a.max_new_tokens, b.max_new_tokens);
            assert_eq!(a.cancel_after, b.cancel_after);
        }
        // different seeds diverge
        assert_ne!(chaos_trace(12, 24, 64, 16)[0].prompt, tr[0].prompt);
    }

    #[test]
    fn storm_trace_is_connection_major_and_deterministic() {
        let tr = storm_trace(9, 4, 3, 48, 8);
        assert_eq!(tr.len(), 12);
        for (i, r) in tr.iter().enumerate() {
            assert_eq!(r.id, i as u64, "ids are connection-major");
            assert_eq!(r.prompt.len(), 48);
            assert_eq!(r.max_new_tokens, 8);
            assert!(r.cancel_after.is_none());
        }
        // connection 2's slice is [6, 9) and its prompts are distinct
        assert_ne!(tr[6].prompt, tr[7].prompt);
        assert_eq!(storm_trace(9, 4, 3, 48, 8)[7].prompt, tr[7].prompt);
        assert_ne!(storm_trace(10, 4, 3, 48, 8)[7].prompt, tr[7].prompt);
    }

    #[test]
    fn bursty_monster_trace_shape_and_determinism() {
        let tr = bursty_monster_trace(7, 2048, 16, 24, 8);
        assert_eq!(tr.len(), 17);
        assert_eq!(tr[0].id, 0);
        assert_eq!(tr[0].prompt.len(), 2048, "the monster arrives first");
        for (i, r) in tr.iter().enumerate().skip(1) {
            assert_eq!(r.id, i as u64);
            assert_eq!(r.prompt.len(), 24);
            assert_eq!(r.max_new_tokens, 8);
            assert!(r.cancel_after.is_none());
        }
        assert_ne!(tr[1].prompt, tr[2].prompt, "short prompts are distinct");
        assert_eq!(bursty_monster_trace(7, 2048, 16, 24, 8)[3].prompt, tr[3].prompt);
        assert_ne!(bursty_monster_trace(8, 2048, 16, 24, 8)[0].prompt, tr[0].prompt);
    }

    #[test]
    fn shared_prefix_trace_shares_exactly_the_prefix() {
        let tr = shared_prefix_trace(3, 4, 192, 64, 16);
        assert_eq!(tr.len(), 4);
        for r in &tr {
            assert_eq!(r.prompt.len(), 256);
            assert_eq!(r.prompt[..192], tr[0].prompt[..192], "prefix diverged");
        }
        // suffixes differ between requests
        assert_ne!(tr[0].prompt[192..], tr[1].prompt[192..]);
        // deterministic
        assert_eq!(shared_prefix_trace(3, 4, 192, 64, 16)[2].prompt, tr[2].prompt);
    }
}
