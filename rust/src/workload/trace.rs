//! Request traces for the throughput experiments (Fig 7): batches of
//! prompts with configurable input/generation lengths, built from the
//! synthetic language so prompts look like training data.

use super::lang;
use crate::util::Pcg32;

/// One serving request in a trace.
#[derive(Clone, Debug)]
pub struct TraceRequest {
    pub id: u64,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
}

/// A batch-throughput trace: `n` requests of `input_len` prompt tokens,
/// each asking for `gen_len` generated tokens (the paper's Fig 7 uses
/// in 2048 / gen 2048 for Llama-2 and in 4096 / gen 4096 for Llama-3,
/// scaled in our harness to the trained context).
pub fn uniform_trace(seed: u64, n: usize, input_len: usize, gen_len: usize) -> Vec<TraceRequest> {
    (0..n)
        .map(|i| {
            let mut rng = Pcg32::new(seed.wrapping_mul(7919).wrapping_add(i as u64), 54);
            TraceRequest {
                id: i as u64,
                prompt: lang::gen_document(&mut rng, input_len),
                max_new_tokens: gen_len,
            }
        })
        .collect()
}

/// A shared-prefix trace (EXPERIMENTS §6): every request's prompt opens
/// with the same `prefix_len`-token document (a shared system prompt /
/// few-shot header) followed by a per-request `suffix_len`-token
/// continuation — the workload the kvpool prefix cache is built for.
pub fn shared_prefix_trace(
    seed: u64,
    n: usize,
    prefix_len: usize,
    suffix_len: usize,
    gen_len: usize,
) -> Vec<TraceRequest> {
    let mut prng = Pcg32::new(seed.wrapping_mul(6151).wrapping_add(13), 77);
    let prefix = lang::gen_document(&mut prng, prefix_len);
    (0..n)
        .map(|i| {
            let mut rng = Pcg32::new(seed.wrapping_mul(389).wrapping_add(i as u64), 55);
            let mut prompt = prefix.clone();
            prompt.extend(lang::gen_document(&mut rng, suffix_len));
            TraceRequest { id: i as u64, prompt, max_new_tokens: gen_len }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shapes() {
        let tr = uniform_trace(1, 4, 128, 32);
        assert_eq!(tr.len(), 4);
        for r in &tr {
            assert_eq!(r.prompt.len(), 128);
            assert_eq!(r.max_new_tokens, 32);
        }
        // distinct prompts
        assert_ne!(tr[0].prompt, tr[1].prompt);
    }

    #[test]
    fn trace_deterministic() {
        assert_eq!(uniform_trace(2, 2, 64, 8)[1].prompt, uniform_trace(2, 2, 64, 8)[1].prompt);
    }

    #[test]
    fn shared_prefix_trace_shares_exactly_the_prefix() {
        let tr = shared_prefix_trace(3, 4, 192, 64, 16);
        assert_eq!(tr.len(), 4);
        for r in &tr {
            assert_eq!(r.prompt.len(), 256);
            assert_eq!(r.prompt[..192], tr[0].prompt[..192], "prefix diverged");
        }
        // suffixes differ between requests
        assert_ne!(tr[0].prompt[192..], tr[1].prompt[192..]);
        // deterministic
        assert_eq!(shared_prefix_trace(3, 4, 192, 64, 16)[2].prompt, tr[2].prompt);
    }
}
