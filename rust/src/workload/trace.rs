//! Request traces for the throughput experiments (Fig 7): batches of
//! prompts with configurable input/generation lengths, built from the
//! synthetic language so prompts look like training data.

use super::lang;
use crate::util::Pcg32;

/// One serving request in a trace.
#[derive(Clone, Debug)]
pub struct TraceRequest {
    pub id: u64,
    pub prompt: Vec<u16>,
    pub max_new_tokens: usize,
}

/// A batch-throughput trace: `n` requests of `input_len` prompt tokens,
/// each asking for `gen_len` generated tokens (the paper's Fig 7 uses
/// in 2048 / gen 2048 for Llama-2 and in 4096 / gen 4096 for Llama-3,
/// scaled in our harness to the trained context).
pub fn uniform_trace(seed: u64, n: usize, input_len: usize, gen_len: usize) -> Vec<TraceRequest> {
    (0..n)
        .map(|i| {
            let mut rng = Pcg32::new(seed.wrapping_mul(7919).wrapping_add(i as u64), 54);
            TraceRequest {
                id: i as u64,
                prompt: lang::gen_document(&mut rng, input_len),
                max_new_tokens: gen_len,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_shapes() {
        let tr = uniform_trace(1, 4, 128, 32);
        assert_eq!(tr.len(), 4);
        for r in &tr {
            assert_eq!(r.prompt.len(), 128);
            assert_eq!(r.max_new_tokens, 32);
        }
        // distinct prompts
        assert_ne!(tr[0].prompt, tr[1].prompt);
    }

    #[test]
    fn trace_deterministic() {
        assert_eq!(uniform_trace(2, 2, 64, 8)[1].prompt, uniform_trace(2, 2, 64, 8)[1].prompt);
    }
}
