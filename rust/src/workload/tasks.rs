//! LongBench-sim: 16 synthetic long-context tasks in the paper's six
//! LongBench categories (Table 4 column layout). Each task plants the
//! answer-bearing tokens far from the query so that damaging distant KV
//! entries damages the score — the mechanism KV-cache pruning quality is
//! measured by. Substitution rationale: DESIGN.md §2.

use super::lang::{self, LangRng};
use crate::util::Pcg32;

/// Task category, mirroring LongBench's six groups.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Category {
    SingleDoc,
    MultiDoc,
    Summarization,
    FewShot,
    Synthetic,
    Code,
}

impl Category {
    pub fn name(&self) -> &'static str {
        match self {
            Category::SingleDoc => "SingleDoc QA",
            Category::MultiDoc => "MultiDoc QA",
            Category::Summarization => "Summarization",
            Category::FewShot => "Few-shot",
            Category::Synthetic => "Synthetic",
            Category::Code => "Code",
        }
    }

    pub fn all() -> [Category; 6] {
        [
            Category::SingleDoc,
            Category::MultiDoc,
            Category::Summarization,
            Category::FewShot,
            Category::Synthetic,
            Category::Code,
        ]
    }
}

/// One evaluation sample. `context` already ends with the query tokens;
/// the model must continue with `answer`.
#[derive(Clone, Debug)]
pub struct TaskSample {
    pub context: Vec<u16>,
    pub answer: Vec<u16>,
    /// Teacher-forced scoring (per-position argmax accuracy) instead of
    /// greedy generation + match.
    pub forced: bool,
    /// Number of trailing context tokens fed through *decode* steps
    /// (teacher-forced) instead of prefill. Prefill is dense (as in the
    /// paper — pruning happens after it), so the answer-predicting step
    /// must be a decode over the pruned cache for pruning to matter.
    pub query_len: usize,
}

/// Static description of one task.
#[derive(Clone, Copy, Debug)]
pub struct TaskSpec {
    pub id: &'static str,
    pub category: Category,
    /// The LongBench task this column stands in for.
    pub paper_analog: &'static str,
}

/// The 16 tasks, in the paper's Table 4 column order.
pub const TASKS: [TaskSpec; 16] = [
    TaskSpec { id: "sqa-easy", category: Category::SingleDoc, paper_analog: "NrtvQA" },
    TaskSpec { id: "sqa-med", category: Category::SingleDoc, paper_analog: "Qasper" },
    TaskSpec { id: "sqa-hard", category: Category::SingleDoc, paper_analog: "MF-en" },
    TaskSpec { id: "mqa-2doc", category: Category::MultiDoc, paper_analog: "HotpotQA" },
    TaskSpec { id: "mqa-4doc", category: Category::MultiDoc, paper_analog: "2WikiMQA" },
    TaskSpec { id: "mqa-8doc", category: Category::MultiDoc, paper_analog: "Musique" },
    TaskSpec { id: "sum-recap8", category: Category::Summarization, paper_analog: "GovReport" },
    TaskSpec { id: "sum-recap16", category: Category::Summarization, paper_analog: "QMSum" },
    TaskSpec { id: "sum-far", category: Category::Summarization, paper_analog: "MultiNews" },
    TaskSpec { id: "few-map", category: Category::FewShot, paper_analog: "TREC" },
    TaskSpec { id: "few-map-long", category: Category::FewShot, paper_analog: "TriviaQA" },
    TaskSpec { id: "few-count", category: Category::FewShot, paper_analog: "SAMSum" },
    TaskSpec { id: "syn-count", category: Category::Synthetic, paper_analog: "PCount" },
    TaskSpec { id: "syn-passkey", category: Category::Synthetic, paper_analog: "PRe" },
    TaskSpec { id: "code-ident", category: Category::Code, paper_analog: "Lcc" },
    TaskSpec { id: "code-balance", category: Category::Code, paper_analog: "RB-P" },
];

pub fn spec(id: &str) -> Option<&'static TaskSpec> {
    TASKS.iter().find(|t| t.id == id)
}

fn task_seed(id: &str) -> u64 {
    // FNV-1a over the task id, so each task has its own sample stream.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in id.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Deterministically generate sample `idx` of `task` with a target
/// context length (the query tokens are included in the budget).
pub fn generate(task: &str, idx: u64, ctx_len: usize) -> TaskSample {
    let mut rng = Pcg32::new(task_seed(task).wrapping_add(idx), 54);
    match task {
        "sqa-easy" => single_doc(&mut rng, ctx_len, 4),
        "sqa-med" => single_doc(&mut rng, ctx_len, 8),
        "sqa-hard" => single_doc(&mut rng, ctx_len, 16),
        "mqa-2doc" => multi_doc(&mut rng, ctx_len, 2),
        "mqa-4doc" => multi_doc(&mut rng, ctx_len, 4),
        "mqa-8doc" => multi_doc(&mut rng, ctx_len, 8),
        "sum-recap8" => recap(&mut rng, ctx_len, 8, false),
        "sum-recap16" => recap(&mut rng, ctx_len, 16, false),
        "sum-far" => recap(&mut rng, ctx_len, 8, true),
        "few-map" => few_map(&mut rng, ctx_len, 6),
        "few-map-long" => few_map(&mut rng, ctx_len, 8),
        "few-count" => spread_count(&mut rng, ctx_len, 1),
        "syn-count" => spread_count(&mut rng, ctx_len, 2),
        "syn-passkey" => passkey(&mut rng, ctx_len),
        "code-ident" => code_ident(&mut rng, ctx_len),
        "code-balance" => code_balance(&mut rng, ctx_len),
        other => panic!("unknown task '{other}'"),
    }
}

// ---------------------------------------------------------------------------
// builders
// ---------------------------------------------------------------------------

/// Append filler word-chain tokens until `out` reaches `target` length.
fn fill_to(rng: &mut Pcg32, out: &mut Vec<u16>, target: usize) {
    while out.len() < target {
        out.extend(lang::seg_filler(rng));
    }
    out.truncate(target);
}

/// Fresh names, distinct from each other and from `taken`.
fn fresh_names(rng: &mut Pcg32, n: usize, taken: &[u16]) -> Vec<u16> {
    let mut out: Vec<u16> = Vec::with_capacity(n);
    while out.len() < n {
        let nm = rng.name();
        if !taken.contains(&nm) && !out.contains(&nm) {
            out.push(nm);
        }
    }
    out
}

/// Place `blocks` into a context of `body_len` tokens with filler between,
/// block b at approximately `fracs[b]` of the body.
fn weave(rng: &mut Pcg32, blocks: &[(f64, Vec<u16>)], body_len: usize) -> Vec<u16> {
    let mut out = vec![lang::BOS];
    let mut blocks: Vec<&(f64, Vec<u16>)> = blocks.iter().collect();
    blocks.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (frac, toks) in blocks {
        let at = (((body_len as f64) * frac) as usize).max(out.len());
        fill_to(rng, &mut out, at);
        out.extend_from_slice(toks);
    }
    fill_to(rng, &mut out, body_len);
    out
}

fn single_doc(rng: &mut Pcg32, ctx_len: usize, distractors: usize) -> TaskSample {
    let names = fresh_names(rng, distractors + 1, &[]);
    let gold_name = names[0];
    let gold_val = rng.value();

    let mut blocks: Vec<(f64, Vec<u16>)> = Vec::new();
    let gold_frac = 0.08 + 0.55 * rng.unit_f32() as f64;
    blocks.push((gold_frac, vec![lang::KEY, gold_name, gold_val, lang::SEP]));
    for nm in &names[1..] {
        let v = rng.value();
        let frac = 0.05 + 0.85 * rng.unit_f32() as f64;
        blocks.push((frac, vec![lang::KEY, *nm, v, lang::SEP]));
    }

    let mut context = weave(rng, &blocks, ctx_len - 2);
    context.extend_from_slice(&[lang::QUERY, gold_name]);
    TaskSample { context, answer: vec![gold_val], forced: false, query_len: 2 }
}

fn multi_doc(rng: &mut Pcg32, ctx_len: usize, ndocs: usize) -> TaskSample {
    let facts_per_doc = 2usize;
    let names = fresh_names(rng, ndocs * facts_per_doc, &[]);
    let vals: Vec<u16> = (0..names.len()).map(|_| rng.value()).collect();
    let gold = rng.below((names.len()) as u32) as usize;

    let mut blocks: Vec<(f64, Vec<u16>)> = Vec::new();
    for d in 0..ndocs {
        let mut doc = vec![lang::DOC, rng.name()];
        for f in 0..facts_per_doc {
            let i = d * facts_per_doc + f;
            doc.extend_from_slice(&[lang::ARROW, names[i], vals[i], lang::SEP]);
        }
        doc.push(lang::ENDDOC);
        let frac = 0.05 + 0.8 * (d as f64 + rng.unit_f32() as f64 * 0.8) / ndocs as f64;
        blocks.push((frac, doc));
    }

    let mut context = weave(rng, &blocks, ctx_len - 2);
    context.extend_from_slice(&[lang::QUERY, names[gold]]);
    TaskSample { context, answer: vec![vals[gold]], forced: false, query_len: 2 }
}

fn recap(rng: &mut Pcg32, ctx_len: usize, nback: usize, far: bool) -> TaskSample {
    let m = 24;
    let words: Vec<u16> = (0..m).map(|_| rng.word()).collect();
    let mut seg = vec![lang::SUM];
    seg.extend_from_slice(&words);
    let frac = if far { 0.0 } else { 0.05 + 0.4 * rng.unit_f32() as f64 };

    let mut context = weave(rng, &[(frac, seg)], ctx_len - 1);
    context.push(lang::RECAP);
    TaskSample { context, answer: words[..nback].to_vec(), forced: true, query_len: 1 }
}

fn few_map(rng: &mut Pcg32, ctx_len: usize, nshots: usize) -> TaskSample {
    let offset = 1 + rng.below(31) as u16;
    let names = fresh_names(rng, nshots + 1, &[]);

    let mut blocks: Vec<(f64, Vec<u16>)> = Vec::new();
    for (i, nm) in names[..nshots].iter().enumerate() {
        let frac = 0.05 + 0.85 * (i as f64 + rng.unit_f32() as f64) / nshots as f64;
        blocks.push((frac, vec![lang::MAP, *nm, lang::fewshot_map(*nm, offset), lang::SEP]));
    }
    let q = names[nshots];
    let mut context = weave(rng, &blocks, ctx_len - 2);
    context.extend_from_slice(&[lang::QUERY, q]);
    TaskSample { context, answer: vec![lang::fewshot_map(q, offset)], forced: false, query_len: 2 }
}

fn spread_count(rng: &mut Pcg32, ctx_len: usize, ntypes: usize) -> TaskSample {
    let items = fresh_names(rng, ntypes, &[]);
    let counts: Vec<usize> = (0..ntypes).map(|_| 2 + rng.below(9) as usize).collect();
    let ask = rng.below(ntypes as u32) as usize;

    let mut blocks: Vec<(f64, Vec<u16>)> = Vec::new();
    for (ty, &item) in items.iter().enumerate() {
        for _ in 0..counts[ty] {
            let frac = 0.05 + 0.85 * rng.unit_f32() as f64;
            blocks.push((frac, vec![lang::ITEM, item]));
        }
    }
    let mut context = weave(rng, &blocks, ctx_len - 3);
    context.extend_from_slice(&[lang::CNT, items[ask], lang::ANS]);
    let answer = vec![lang::VAL0 + counts[ask] as u16];
    TaskSample { context, answer, forced: false, query_len: 3 }
}

fn passkey(rng: &mut Pcg32, ctx_len: usize) -> TaskSample {
    let nm = rng.name();
    let v = rng.value();
    let frac = 0.05 + 0.45 * rng.unit_f32() as f64;
    let mut context = weave(
        rng,
        &[(frac, vec![lang::KEY, nm, v, lang::SEP])],
        ctx_len - 2,
    );
    context.extend_from_slice(&[lang::QUERY, nm]);
    TaskSample { context, answer: vec![v], forced: false, query_len: 2 }
}

fn code_ident(rng: &mut Pcg32, ctx_len: usize) -> TaskSample {
    // A fixed 6-ident motif repeated throughout the context ("API usage
    // pattern"); the model completes the final, truncated occurrence.
    let motif: Vec<u16> =
        (0..6).map(|_| lang::IDENT0 + rng.below(lang::N_IDENTS as u32) as u16).collect();
    let mut blocks: Vec<(f64, Vec<u16>)> = Vec::new();
    for r in 0..4 {
        let mut b = motif.clone();
        b.push(lang::SEP);
        let frac = 0.05 + 0.8 * (r as f64 + rng.unit_f32() as f64 * 0.6) / 4.0;
        blocks.push((frac, b));
    }
    let cut = 3usize;
    let mut context = weave(rng, &blocks, ctx_len - cut);
    context.extend_from_slice(&motif[..cut]);
    TaskSample { context, answer: motif[cut..].to_vec(), forced: true, query_len: 3 }
}

fn code_balance(rng: &mut Pcg32, ctx_len: usize) -> TaskSample {
    // Long code region whose open brackets must be closed in order at the
    // end — structural prediction over long range.
    let mut stack: Vec<u16> = Vec::new();
    let mut code: Vec<u16> = Vec::new();
    let body = 80usize;
    for i in 0..body {
        let r = rng.below(4);
        // keep a few brackets open near the end so the answer is non-empty
        let want_open = stack.len() < 3 && i > body - 30;
        if (r == 0 || want_open) && stack.len() < 6 {
            let b = rng.below(3) as usize;
            code.push(lang::OPENERS[b]);
            stack.push(lang::CLOSERS[b]);
        } else if r == 1 && stack.len() > 3 {
            code.push(stack.pop().unwrap());
        } else {
            code.push(lang::IDENT0 + rng.below(lang::N_IDENTS as u32) as u16);
        }
    }
    stack.reverse();
    let answer = stack;

    let mut context = vec![lang::BOS];
    fill_to(rng, &mut context, ctx_len.saturating_sub(code.len()));
    context.extend_from_slice(&code);
    TaskSample { context, answer, forced: true, query_len: 8 }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_tasks_generate() {
        for t in TASKS.iter() {
            for idx in 0..3 {
                let s = generate(t.id, idx, 448);
                assert!(!s.answer.is_empty(), "{} empty answer", t.id);
                assert!(
                    s.context.len() <= 448 + 8 && s.context.len() > 300,
                    "{}: context len {}",
                    t.id,
                    s.context.len()
                );
                assert_eq!(s.context[0], lang::BOS, "{}", t.id);
            }
        }
    }

    #[test]
    fn deterministic_generation() {
        for t in TASKS.iter() {
            let a = generate(t.id, 5, 448);
            let b = generate(t.id, 5, 448);
            assert_eq!(a.context, b.context);
            assert_eq!(a.answer, b.answer);
        }
    }

    #[test]
    fn sqa_answer_is_planted() {
        for idx in 0..10 {
            let s = generate("sqa-hard", idx, 448);
            let n = s.context.len();
            let qname = s.context[n - 1];
            // find KEY qname v in the context
            let mut found = None;
            for i in 0..n - 3 {
                if s.context[i] == lang::KEY && s.context[i + 1] == qname {
                    found = Some(s.context[i + 2]);
                }
            }
            assert_eq!(found, Some(s.answer[0]), "idx {idx}");
        }
    }

    #[test]
    fn gold_outside_local_window() {
        // the answer-bearing tokens must sit outside the recent-32 window,
        // otherwise pruning could never affect the task
        for t in ["sqa-easy", "syn-passkey", "mqa-4doc"] {
            for idx in 0..10 {
                let s = generate(t, idx, 448);
                let n = s.context.len();
                let qname = s.context[n - 1];
                let mut last_pos = 0;
                for i in 0..n - 1 {
                    if s.context[i] == qname {
                        last_pos = last_pos.max(i);
                    }
                }
                assert!(last_pos > 0, "{t}/{idx}: gold never planted");
                assert!(
                    n - last_pos > 32,
                    "{t}/{idx}: gold at {last_pos} inside local window (n={n})"
                );
            }
        }
    }

    #[test]
    fn count_tasks_answer_matches_occurrences() {
        for idx in 0..10 {
            let s = generate("few-count", idx, 448);
            let item = s.context[s.context.len() - 2];
            let occurrences = (0..s.context.len() - 3)
                .filter(|&i| s.context[i] == lang::ITEM && s.context[i + 1] == item)
                .count();
            assert_eq!(s.answer[0], lang::VAL0 + occurrences as u16, "idx {idx}");
        }
    }

    #[test]
    fn code_balance_answer_closes_stack() {
        for idx in 0..10 {
            let s = generate("code-balance", idx, 448);
            let mut stack = Vec::new();
            for &t in &s.context {
                if lang::OPENERS.contains(&t) {
                    stack.push(t);
                } else if let Some(p) = lang::CLOSERS.iter().position(|&c| c == t) {
                    assert_eq!(stack.pop(), Some(lang::OPENERS[p]));
                }
            }
            let want: Vec<u16> = stack
                .iter()
                .rev()
                .map(|&o| {
                    let p = lang::OPENERS.iter().position(|&x| x == o).unwrap();
                    lang::CLOSERS[p]
                })
                .collect();
            assert_eq!(s.answer, want, "idx {idx}");
        }
    }
}
