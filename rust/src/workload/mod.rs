//! Serving/eval workloads: the synthetic language (python-mirrored), the
//! LongBench-sim task suite, and request traces for throughput benches.

pub mod lang;
pub mod tasks;
pub mod trace;

pub use tasks::{Category, TaskSample, TaskSpec, TASKS};
