//! Synthetic language — token-for-token mirror of
//! `python/compile/data.py`. The evaluation models are trained (in JAX)
//! on exactly this stream; the Rust side regenerates it for serving
//! workloads and builds the LongBench-sim tasks from the same segment
//! vocabulary. Locked against drift by `tests/lang_golden.rs` (rust) and
//! `python/tests/test_lang_golden.py` (python) over a shared golden file.

use crate::util::Pcg32;

// -- vocabulary layout (mirror of data.py) ---------------------------------

pub const PAD: u16 = 0;
pub const BOS: u16 = 1;
pub const EOS: u16 = 2;
pub const SEP: u16 = 3;
pub const KEY: u16 = 4;
pub const VAL: u16 = 5;
pub const QUERY: u16 = 6;
pub const ANS: u16 = 7;
pub const DOC: u16 = 8;
pub const ENDDOC: u16 = 9;
pub const SUM: u16 = 10;
pub const MAP: u16 = 11;
pub const ARROW: u16 = 12;
pub const CNT: u16 = 13;
pub const ITEM: u16 = 14;
pub const RECAP: u16 = 15;

pub const NAME0: u16 = 16;
pub const N_NAMES: u16 = 128;
pub const VAL0: u16 = 144;
pub const N_VALS: u16 = 128;
pub const WORD0: u16 = 272;
pub const N_WORDS: u16 = 192;
pub const CODE0: u16 = 464;
pub const OPEN_PAREN: u16 = 464;
pub const CLOSE_PAREN: u16 = 465;
pub const OPEN_BRACK: u16 = 466;
pub const CLOSE_BRACK: u16 = 467;
pub const OPEN_BRACE: u16 = 468;
pub const CLOSE_BRACE: u16 = 469;
pub const IDENT0: u16 = 470;
pub const N_IDENTS: u16 = 42;
pub const VOCAB: usize = 512;

pub const OPENERS: [u16; 3] = [OPEN_PAREN, OPEN_BRACK, OPEN_BRACE];
pub const CLOSERS: [u16; 3] = [CLOSE_PAREN, CLOSE_BRACK, CLOSE_BRACE];

/// rng helpers matching the python draw order exactly.
pub trait LangRng {
    fn name(&mut self) -> u16;
    fn value(&mut self) -> u16;
    fn word(&mut self) -> u16;
}

impl LangRng for Pcg32 {
    fn name(&mut self) -> u16 {
        NAME0 + self.below(N_NAMES as u32) as u16
    }
    fn value(&mut self) -> u16 {
        VAL0 + self.below(N_VALS as u32) as u16
    }
    fn word(&mut self) -> u16 {
        WORD0 + self.below(N_WORDS as u32) as u16
    }
}

pub fn is_name(tok: u16) -> bool {
    (NAME0..NAME0 + N_NAMES).contains(&tok)
}

pub fn is_value(tok: u16) -> bool {
    (VAL0..VAL0 + N_VALS).contains(&tok)
}

// -- segment generators (draw order is the spec) ----------------------------

/// `[KEY name val SEP]*n` then two queries over the stated pairs.
/// Values directly follow names (adjacency): retrieval is the canonical
/// induction-head task, learnable within a CPU token budget.
pub fn seg_kv_facts(rng: &mut Pcg32) -> Vec<u16> {
    let n = 4 + rng.below(5) as usize;
    let mut names: Vec<u16> = Vec::with_capacity(n);
    let mut vals: Vec<u16> = Vec::with_capacity(n);
    let mut out = Vec::new();
    for _ in 0..n {
        let mut nm = rng.name();
        while names.contains(&nm) {
            nm = rng.name();
        }
        let v = rng.value();
        names.push(nm);
        vals.push(v);
        out.extend_from_slice(&[KEY, nm, v, SEP]);
    }
    for _ in 0..2 {
        let i = rng.below(n as u32) as usize;
        out.extend_from_slice(&[QUERY, names[i], vals[i], SEP]);
    }
    out
}

/// Documents holding ARROW facts, then queries across documents.
pub fn seg_doc_facts(rng: &mut Pcg32) -> Vec<u16> {
    let ndocs = 2 + rng.below(3) as usize;
    let mut names: Vec<u16> = Vec::new();
    let mut vals: Vec<u16> = Vec::new();
    let mut out = Vec::new();
    for _ in 0..ndocs {
        let doc_name = rng.name();
        out.extend_from_slice(&[DOC, doc_name]);
        for _ in 0..2 {
            let mut nm = rng.name();
            while names.contains(&nm) {
                nm = rng.name();
            }
            let v = rng.value();
            names.push(nm);
            vals.push(v);
            out.extend_from_slice(&[ARROW, nm, v, SEP]);
        }
        out.push(ENDDOC);
    }
    for _ in 0..2 {
        let i = rng.below(names.len() as u32) as usize;
        out.extend_from_slice(&[QUERY, names[i], vals[i], SEP]);
    }
    out
}

/// `[SUM] w1..wm [RECAP] w1..w8` — long-range copy/summary.
pub fn seg_recap(rng: &mut Pcg32) -> Vec<u16> {
    let m = 12 + rng.below(9) as usize;
    let words: Vec<u16> = (0..m).map(|_| rng.word()).collect();
    let mut out = vec![SUM];
    out.extend_from_slice(&words);
    out.push(RECAP);
    out.extend_from_slice(&words[..8]);
    out.push(SEP);
    out
}

/// In-context mapping f(name_i) = val_{(i+offset) mod N}.
pub fn fewshot_map(name_tok: u16, offset: u16) -> u16 {
    VAL0 + ((name_tok - NAME0) + offset) % N_VALS
}

pub fn seg_fewshot(rng: &mut Pcg32) -> Vec<u16> {
    let offset = 1 + rng.below(31) as u16;
    let k = 3 + rng.below(3) as usize;
    let mut out = Vec::new();
    let mut seen: Vec<u16> = Vec::new();
    for _ in 0..k {
        let mut nm = rng.name();
        while seen.contains(&nm) {
            nm = rng.name();
        }
        seen.push(nm);
        out.extend_from_slice(&[MAP, nm, fewshot_map(nm, offset), SEP]);
    }
    let mut nm = rng.name();
    while seen.contains(&nm) {
        nm = rng.name();
    }
    out.extend_from_slice(&[QUERY, nm, fewshot_map(nm, offset), SEP]);
    out
}

/// ITEM x repeated k times, then `CNT x ANS <k>`.
pub fn seg_count(rng: &mut Pcg32) -> Vec<u16> {
    let k = 2 + rng.below(9) as usize;
    let item = rng.name();
    let mut out = Vec::new();
    for _ in 0..k {
        out.extend_from_slice(&[ITEM, item]);
    }
    out.extend_from_slice(&[CNT, item, ANS, VAL0 + k as u16, SEP]);
    out
}

/// Balanced bracket sequence with identifiers, closed in order at the end.
pub fn seg_code(rng: &mut Pcg32) -> Vec<u16> {
    let mut out = Vec::new();
    let mut stack: Vec<u16> = Vec::new();
    let steps = 10 + rng.below(13) as usize;
    for _ in 0..steps {
        let r = rng.below(4);
        if r == 0 && stack.len() < 6 {
            let b = rng.below(3) as usize;
            out.push(OPENERS[b]);
            stack.push(CLOSERS[b]);
        } else if r == 1 && !stack.is_empty() {
            out.push(stack.pop().unwrap());
        } else {
            out.push(IDENT0 + rng.below(N_IDENTS as u32) as u16);
        }
    }
    while let Some(c) = stack.pop() {
        out.push(c);
    }
    out.push(SEP);
    out
}

/// Deterministic bigram chain over filler words.
pub fn seg_filler(rng: &mut Pcg32) -> Vec<u16> {
    let m = 8 + rng.below(17) as usize;
    let mut cur = rng.below(N_WORDS as u32) as u16;
    let mut out = vec![WORD0 + cur];
    for _ in 0..m - 1 {
        cur = ((cur as u32 * 17 + 7 + rng.below(8)) % N_WORDS as u32) as u16;
        out.push(WORD0 + cur);
    }
    out.push(SEP);
    out
}

/// Segment mixture weights (out of 16) — mirror of data.py.
pub const SEGMENT_WEIGHTS: [u32; 7] = [4, 3, 2, 2, 1, 2, 2];

pub fn next_segment(rng: &mut Pcg32) -> Vec<u16> {
    let total: u32 = SEGMENT_WEIGHTS.iter().sum();
    let r = rng.below(total);
    let mut acc = 0;
    for (i, &w) in SEGMENT_WEIGHTS.iter().enumerate() {
        acc += w;
        if r < acc {
            return match i {
                0 => seg_kv_facts(rng),
                1 => seg_doc_facts(rng),
                2 => seg_recap(rng),
                3 => seg_fewshot(rng),
                4 => seg_count(rng),
                5 => seg_code(rng),
                _ => seg_filler(rng),
            };
        }
    }
    unreachable!()
}

/// Collect (name, value) facts stated anywhere in a token stream: any
/// name token directly followed by a value token (the adjacency grammar
/// of KEY/ARROW/MAP/QUERY statements). Later statements win. Mirror of
/// data.py::scan_facts (python dict preserves insertion order).
pub fn scan_facts(tokens: &[u16]) -> Vec<(u16, u16)> {
    let mut order: Vec<u16> = Vec::new();
    let mut map: std::collections::HashMap<u16, u16> = std::collections::HashMap::new();
    for i in 0..tokens.len().saturating_sub(1) {
        let (nm, v) = (tokens[i], tokens[i + 1]);
        if is_name(nm) && is_value(v) {
            if !map.contains_key(&nm) {
                order.push(nm);
            }
            map.insert(nm, v);
        }
    }
    order.into_iter().map(|n| (n, map[&n])).collect()
}

/// One training document: BOS + segments + long-range queries over facts
/// stated anywhere in the document. Mirror of data.py::gen_document.
pub fn gen_document(rng: &mut Pcg32, seq_len: usize) -> Vec<u16> {
    let mut out = vec![BOS];
    while out.len() < seq_len.saturating_sub(28) {
        out.extend(next_segment(rng));
    }
    let facts = scan_facts(&out);
    if !facts.is_empty() {
        for _ in 0..3 {
            let (name, val) = facts[rng.below(facts.len() as u32) as usize];
            out.extend_from_slice(&[QUERY, name, val, SEP]);
        }
    }
    while out.len() < seq_len {
        out.extend(next_segment(rng));
    }
    out.truncate(seq_len);
    out
}

/// Per-document rng seeding used by the training corpus
/// (data.py::corpus_batches): document `i` of stream `seed`.
pub fn doc_rng(seed: u64, doc_idx: u64) -> Pcg32 {
    Pcg32::new(seed.wrapping_mul(1_000_003).wrapping_add(doc_idx), 54)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn segments_are_deterministic() {
        let a = seg_kv_facts(&mut Pcg32::seeded(1));
        let b = seg_kv_facts(&mut Pcg32::seeded(1));
        assert_eq!(a, b);
    }

    #[test]
    fn kv_facts_shape() {
        let toks = seg_kv_facts(&mut Pcg32::seeded(2));
        assert_eq!(toks[0], KEY);
        // n pairs of 4 + 2 queries of 4
        assert_eq!(toks.len() % 4, 0);
        let pairs = scan_facts(&toks);
        assert!(pairs.len() >= 4);
        // queries restate known facts (value adjacent to name)
        let qpos: Vec<usize> = (0..toks.len()).filter(|&i| toks[i] == QUERY).collect();
        assert_eq!(qpos.len(), 2);
        for i in qpos {
            let nm = toks[i + 1];
            let ans = toks[i + 2];
            assert_eq!(pairs.iter().find(|(n, _)| *n == nm).unwrap().1, ans);
        }
    }

    #[test]
    fn code_segment_balanced() {
        for seed in 0..20 {
            let toks = seg_code(&mut Pcg32::seeded(seed));
            let mut stack = Vec::new();
            for &t in &toks {
                if OPENERS.contains(&t) {
                    stack.push(t);
                } else if let Some(pos) = CLOSERS.iter().position(|&c| c == t) {
                    assert_eq!(stack.pop(), Some(OPENERS[pos]), "seed {seed}");
                }
            }
            assert!(stack.is_empty(), "seed {seed}: unclosed brackets");
        }
    }

    #[test]
    fn fewshot_mapping_consistent() {
        let toks = seg_fewshot(&mut Pcg32::seeded(3));
        // every MAP fact and the query share one offset
        let mut offsets = Vec::new();
        for i in 0..toks.len() {
            if toks[i] == MAP || toks[i] == QUERY {
                let nm = toks[i + 1];
                let v = toks[i + 2];
                let off = (v - VAL0 + N_VALS - (nm - NAME0)) % N_VALS;
                offsets.push(off);
            }
        }
        assert!(offsets.len() >= 4);
        assert!(offsets.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn document_has_longrange_queries() {
        let doc = gen_document(&mut Pcg32::seeded(42), 512);
        assert_eq!(doc.len(), 512);
        assert_eq!(doc[0], BOS);
        let nq = doc.iter().filter(|&&t| t == QUERY).count();
        assert!(nq >= 3, "documents should contain queries, got {nq}");
    }

    #[test]
    fn count_segment_counts() {
        let toks = seg_count(&mut Pcg32::seeded(9));
        let items = toks.iter().filter(|&&t| t == ITEM).count();
        let cnt_pos = toks.iter().position(|&t| t == CNT).unwrap();
        assert_eq!(toks[cnt_pos + 3], VAL0 + items as u16);
    }

    #[test]
    fn scan_facts_recency_wins() {
        let toks = vec![KEY, NAME0, VAL0, SEP, KEY, NAME0, VAL0 + 1, SEP];
        let facts = scan_facts(&toks);
        assert_eq!(facts, vec![(NAME0, VAL0 + 1)]);
    }
}
