//! H2O token eviction (heavy-hitter oracle) [44], used for the joint
//! Mustafar+H2O study (§4.2.1, Table 5).
//!
//! H2O retains a fixed budget of *recent* tokens plus *heavy-hitter*
//! tokens ranked by accumulated attention mass; everything else is
//! evicted. The paper configures 10% of the KV budget for each class.
//! Jointly with Mustafar, retained tokens that have exited the local
//! window are additionally pruned + compressed.

/// Which tokens survive an H2O pass.
#[derive(Clone, Debug, PartialEq)]
pub struct H2oSelection {
    /// Sorted kept token positions.
    pub kept: Vec<usize>,
    /// kept[i] is a recent token (true) or a heavy hitter (false).
    pub is_recent: Vec<bool>,
}

/// Accumulated-attention tracker for one KV head.
#[derive(Clone, Debug, Default)]
pub struct HeavyHitterTracker {
    /// acc[t] = Σ over decode steps of attention mass on token t.
    acc: Vec<f64>,
}

impl HeavyHitterTracker {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fold one attention distribution (length = current token count).
    pub fn observe(&mut self, att: &[f32]) {
        if att.len() > self.acc.len() {
            self.acc.resize(att.len(), 0.0);
        }
        for (a, x) in self.acc.iter_mut().zip(att) {
            *a += *x as f64;
        }
    }

    pub fn scores(&self) -> &[f64] {
        &self.acc
    }

    pub fn len(&self) -> usize {
        self.acc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.acc.is_empty()
    }
}

/// Select surviving tokens for a sequence of length `n`:
/// the `recent_budget` most recent tokens plus the `hh_budget` highest
/// accumulated-attention tokens among the rest (ties -> more recent wins,
/// matching H2O's greedy oracle on streaming ties).
pub fn h2o_select(
    scores: &[f64],
    n: usize,
    recent_budget: usize,
    hh_budget: usize,
) -> H2oSelection {
    assert!(scores.len() >= n || scores.is_empty() || scores.len() == n);
    let recent_start = n.saturating_sub(recent_budget);
    let mut candidates: Vec<usize> = (0..recent_start).collect();
    candidates.sort_by(|&a, &b| {
        let sa = scores.get(a).copied().unwrap_or(0.0);
        let sb = scores.get(b).copied().unwrap_or(0.0);
        sb.partial_cmp(&sa).unwrap().then(b.cmp(&a))
    });
    let mut kept: Vec<(usize, bool)> = candidates
        .into_iter()
        .take(hh_budget)
        .map(|t| (t, false))
        .collect();
    kept.extend((recent_start..n).map(|t| (t, true)));
    kept.sort_by_key(|(t, _)| *t);
    H2oSelection {
        is_recent: kept.iter().map(|(_, r)| *r).collect(),
        kept: kept.into_iter().map(|(t, _)| t).collect(),
    }
}

/// Budgets from a fraction of sequence length (paper: 10% + 10%).
pub fn budgets_from_fraction(n: usize, recent_frac: f64, hh_frac: f64) -> (usize, usize) {
    let r = ((n as f64 * recent_frac).round() as usize).max(1);
    let h = ((n as f64 * hh_frac).round() as usize).max(1);
    (r, h)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keeps_recents_and_heavy_hitters() {
        let n = 100;
        let mut scores = vec![0.0f64; n];
        scores[5] = 10.0;
        scores[17] = 8.0;
        scores[33] = 6.0;
        let sel = h2o_select(&scores, n, 10, 3);
        assert_eq!(sel.kept.len(), 13);
        assert!(sel.kept.contains(&5));
        assert!(sel.kept.contains(&17));
        assert!(sel.kept.contains(&33));
        for t in 90..100 {
            assert!(sel.kept.contains(&t));
        }
    }

    #[test]
    fn kept_sorted_and_flagged() {
        let scores = vec![1.0f64; 50];
        let sel = h2o_select(&scores, 50, 5, 5);
        for w in sel.kept.windows(2) {
            assert!(w[0] < w[1]);
        }
        let recents = sel.is_recent.iter().filter(|r| **r).count();
        assert_eq!(recents, 5);
    }

    #[test]
    fn tracker_accumulates() {
        let mut tr = HeavyHitterTracker::new();
        tr.observe(&[0.5, 0.5]);
        tr.observe(&[0.1, 0.2, 0.7]);
        assert_eq!(tr.len(), 3);
        assert!((tr.scores()[0] - 0.6).abs() < 1e-6);
        assert!((tr.scores()[2] - 0.7).abs() < 1e-6);
    }

    #[test]
    fn budget_fractions() {
        assert_eq!(budgets_from_fraction(500, 0.1, 0.1), (50, 50));
        assert_eq!(budgets_from_fraction(3, 0.1, 0.1), (1, 1));
    }

    #[test]
    fn short_sequences_keep_everything_recent() {
        let sel = h2o_select(&[], 5, 10, 10);
        assert_eq!(sel.kept, vec![0, 1, 2, 3, 4]);
        assert!(sel.is_recent.iter().all(|r| *r));
    }
}
