//! Compressed KV-cache management (the red half of Fig 1).
//!
//! Per sequence, per (layer, kv-head): a bitmap-compressed region (tokens
//! that exited the local window, pruned + compressed) and a dense tail
//! (the local window plus the 64-token compression group in flight).
//! Both regions store real IEEE binary16 (`sparse::f16`) — the paper's
//! storage type — so `mem_usage`/`memory_bytes` report *actually stored*
//! bytes, not an accounting model.
//!
//! Lifecycle, following §3 and App. C:
//!  * prefill KV is pruned + compressed before decode starts (everything
//!    but the most recent `local_window` tokens);
//!  * decode KV stays dense while inside the local window; once a full
//!    64-token group has exited the window it is pruned (per-token
//!    magnitude — the runtime method) and *appended* to the compressed
//!    region (tile ordering makes this an O(group) append);
//!  * optional KIVI-style fake quantization after pruning (§4.2.2).
//!
//! The serving engine's *chunked* prefill drives prompt tokens through
//! the same per-token decode path (`commit_token` via
//! `model::decode_into`) regardless of chunk size, resuming from a
//! cursor between engine rounds: a cold start begins from [`new`],
//! a prefix-cache partial hit from [`with_prefix`] (the suffix rebuild
//! is the same resumable chunk API, not a separate code path), and a
//! full hit skips prompt compute entirely via [`restore_full`]. Batched
//! `ingest_prefill`/`build_shared_prefill` remain for offline/eval
//! paths that build a whole sequence in one call.
//!
//! [`new`]: SequenceKV::new
//! [`with_prefix`]: SequenceKV::with_prefix
//! [`restore_full`]: SequenceKV::restore_full

use std::cell::RefCell;
use std::sync::Arc;

use crate::config::SparsityConfig;
use crate::error::{Error, Result};
use crate::prune::{self, Method, OutputAwareCtx};
use crate::quant;
use crate::sparse::f16;
use crate::sparse::{BitmapMatrix, PackAxis, TILE};

/// Dense-tail capacity: one compression group in flight + local window.
pub const TAIL_CAP: usize = TILE + prune::LOCAL_WINDOW;

thread_local! {
    /// Reusable widen/prune scratch for group compression: one (K, V)
    /// pair of `[TILE * hd]` f32 buffers per thread, shared by the
    /// synchronous `commit_token` path (engine thread) and the deferred
    /// compression jobs (worker threads). Replaces the two fresh
    /// `vec![0.0; TILE * hd]` allocations every group exit used to pay —
    /// once a thread's pair has grown to the largest head_dim it
    /// compresses, steady-state group compression allocates nothing.
    static COMPRESS_SCRATCH: RefCell<(Vec<f32>, Vec<f32>)> =
        const { RefCell::new((Vec::new(), Vec::new())) };
}

/// Run `f` with this thread's reusable `[elems]` widen/prune scratch
/// pair (grown on demand, never shrunk). Not reentrant.
pub fn with_compress_scratch<R>(elems: usize, f: impl FnOnce(&mut [f32], &mut [f32]) -> R) -> R {
    COMPRESS_SCRATCH.with(|cell| {
        let mut pair = cell.borrow_mut();
        let (kg, vg) = &mut *pair;
        if kg.len() < elems {
            kg.resize(elems, 0.0);
            vg.resize(elems, 0.0);
        }
        f(&mut kg[..elems], &mut vg[..elems])
    })
}

/// Widen one exited 64-token group from binary16 into the provided
/// scratch and apply the runtime policy in place: per-token magnitude
/// prune (the paper's kernel method; output-aware scores are a
/// prefill-time notion) + optional fake quantization. A pure per-group
/// function of the policy and the rows, shared verbatim by the
/// synchronous `commit_token` path and the deferred worker jobs — which
/// is what keeps the two pipelines bit-identical.
pub fn prune_group_into(
    policy: &KvPolicy,
    hd: usize,
    k_rows: &[u16],
    v_rows: &[u16],
    kg: &mut [f32],
    vg: &mut [f32],
) {
    debug_assert_eq!(k_rows.len(), TILE * hd);
    let sp = policy.sparsity;
    f16::widen_into(kg, k_rows);
    f16::widen_into(vg, v_rows);
    if sp.key_method != Method::None {
        prune::per_token_magnitude_inplace(kg, TILE, hd, prune::keep_count(hd, sp.key_sparsity));
    }
    if sp.value_method != Method::None {
        prune::per_token_magnitude_inplace(vg, TILE, hd, prune::keep_count(hd, sp.value_sparsity));
    }
    if let Some(q) = policy.quant {
        quant::kivi_fake_quant(kg, TILE, hd, q.key_bits, quant::Axis::PerChannel, true);
        quant::kivi_fake_quant(vg, TILE, hd, q.value_bits, quant::Axis::PerToken, true);
    }
}

/// Prune + bitmap-pack one exited group from its dense binary16 rows:
/// the body of a deferred compression job, runnable on any worker
/// thread. Returns the compressed (K, V) pair; `SequenceKV::settle_group`
/// appends it byte-identically to what the synchronous path's
/// `append_groups` would have produced (the
/// `BitmapMatrix::append_compressed` byte-identity contract).
pub fn compress_group(
    policy: &KvPolicy,
    hd: usize,
    k_rows: &[u16],
    v_rows: &[u16],
) -> Result<(BitmapMatrix, BitmapMatrix)> {
    with_compress_scratch(TILE * hd, |kg, vg| {
        prune_group_into(policy, hd, k_rows, v_rows, kg, vg);
        let km = BitmapMatrix::compress(kg, TILE, hd, PackAxis::Token)?;
        let vm = BitmapMatrix::compress(vg, TILE, hd, PackAxis::Channel)?;
        Ok((km, vm))
    })
}

/// Re-prune one head's compressed regions in place to the given keep
/// counts — the per-head body of [`SequenceKV::reprune`], exposed so
/// the engine can fan a pressure re-prune's heads out across the worker
/// pool as deferred jobs instead of blocking its own thread on the
/// whole sequence.
pub fn reprune_head_inplace(
    h: &mut HeadKV,
    hd: usize,
    raise_k: bool,
    raise_v: bool,
    kk_k: usize,
    kk_v: usize,
) -> Result<()> {
    if raise_k && h.k_comp.tokens > 0 {
        let t = h.k_comp.tokens;
        let pruned = prune::per_token_magnitude(&h.k_comp.decompress(), t, hd, kk_k);
        h.k_comp = BitmapMatrix::compress(&pruned, t, hd, PackAxis::Token)?;
    }
    if raise_v && h.v_comp.tokens > 0 {
        let t = h.v_comp.tokens;
        let pruned = prune::per_token_magnitude(&h.v_comp.decompress(), t, hd, kk_v);
        h.v_comp = BitmapMatrix::compress(&pruned, t, hd, PackAxis::Channel)?;
    }
    Ok(())
}

/// Optional KIVI-sim quantization applied to the compressed region.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantConfig {
    pub key_bits: u32,
    pub value_bits: u32,
}

/// Per-sequence KV policy.
#[derive(Clone, Copy, Debug)]
pub struct KvPolicy {
    pub sparsity: SparsityConfig,
    pub quant: Option<QuantConfig>,
    /// When false (dense baseline) nothing is ever pruned/compressed and
    /// the tail holds the entire history.
    pub compress: bool,
    pub local_window: usize,
}

impl KvPolicy {
    pub fn dense() -> KvPolicy {
        KvPolicy {
            sparsity: SparsityConfig::dense(),
            quant: None,
            compress: false,
            local_window: prune::LOCAL_WINDOW,
        }
    }

    pub fn mustafar(ks: f64, vs: f64) -> KvPolicy {
        KvPolicy {
            sparsity: SparsityConfig::mustafar(ks, vs),
            quant: None,
            compress: true,
            local_window: prune::LOCAL_WINDOW,
        }
    }

    /// True when prefill compression under this policy is a pure
    /// per-token function of each token's own K/V row. Causal attention
    /// makes a token's K/V depend only on the tokens before it, so under
    /// a token-local policy the compressed form of a shared prompt
    /// prefix is *byte-identical* across every prompt extending it —
    /// the property the prefix cache relies on to share pages. Output-
    /// aware / channel-wise methods and span-wise fake quantization mix
    /// information across tokens and are not shareable.
    pub fn prefix_shareable(&self) -> bool {
        self.compress
            && self.quant.is_none()
            && matches!(self.sparsity.key_method, Method::None | Method::TokenMagnitude)
            && matches!(self.sparsity.value_method, Method::None | Method::TokenMagnitude)
    }
}

/// Immutable compressed prefill prefix, shared across sequences through
/// the `kvpool` prefix cache (refcounted via `Arc`). Covers `tokens`
/// prompt tokens (a multiple of the 64-token group), one (K, V)
/// compressed pair per (layer, kv-head), in the same bitmap format as a
/// sequence's private region. Never mutated after construction: sharers
/// append their own private groups *after* it (copy-on-write at the
/// divergence point — the shared pages stay untouched, divergence lives
/// entirely in per-sequence storage).
#[derive(Clone, Debug)]
pub struct SharedPrefix {
    pub n_layers: usize,
    pub n_kv: usize,
    pub hd: usize,
    /// Prompt tokens covered (multiple of `TILE`).
    pub tokens: usize,
    k: Vec<BitmapMatrix>,
    v: Vec<BitmapMatrix>,
}

impl SharedPrefix {
    /// Compressed (K, V) pair of one (layer, kv-head).
    #[inline]
    pub fn head(&self, layer: usize, kv: usize) -> (&BitmapMatrix, &BitmapMatrix) {
        let idx = layer * self.n_kv + kv;
        (&self.k[idx], &self.v[idx])
    }

    /// Actually-stored bytes across all heads (the pool-charged figure).
    pub fn bytes(&self) -> usize {
        self.k.iter().map(|m| m.compressed_bytes()).sum::<usize>()
            + self.v.iter().map(|m| m.compressed_bytes()).sum::<usize>()
    }
}

/// Split a prefill's dense K/V into a shareable compressed prefix plus
/// per-head binary16 dense tails — the cacheable decomposition of
/// `ingest_prefill`. Caller must have checked `policy.prefix_shareable()`
/// (token-local pruning), which is what makes the produced prefix
/// byte-identical for every prompt sharing those tokens.
///
/// Returns `(prefix, tail_k, tail_v)` with `tail_k[layer * n_kv + kv]`
/// holding the `[tail_tokens x hd]` rows not covered by the prefix.
pub fn build_shared_prefill(
    policy: &KvPolicy,
    n_layers: usize,
    n_kv: usize,
    hd: usize,
    k_dense: &[Vec<f32>],
    v_dense: &[Vec<f32>],
    t: usize,
) -> Result<(SharedPrefix, Vec<Vec<u16>>, Vec<Vec<u16>>)> {
    let heads = n_layers * n_kv;
    assert_eq!(k_dense.len(), heads);
    let w = policy.local_window;
    let n_comp = if policy.compress && t > w { ((t - w) / TILE) * TILE } else { 0 };

    let mut k_comp = Vec::with_capacity(heads);
    let mut v_comp = Vec::with_capacity(heads);
    let mut tail_k = Vec::with_capacity(heads);
    let mut tail_v = Vec::with_capacity(heads);
    for idx in 0..heads {
        let k = &k_dense[idx];
        let v = &v_dense[idx];
        assert_eq!(k.len(), t * hd);
        let mut km = BitmapMatrix::empty(hd, PackAxis::Token);
        let mut vm = BitmapMatrix::empty(hd, PackAxis::Channel);
        if n_comp > 0 {
            let (kp, vp) =
                prune_span(policy, hd, &k[..n_comp * hd], &v[..n_comp * hd], n_comp, idx, None);
            km.append_groups(&kp, n_comp)?;
            vm.append_groups(&vp, n_comp)?;
        }
        k_comp.push(km);
        v_comp.push(vm);
        tail_k.push(f16::to_f16_vec(&k[n_comp * hd..]));
        tail_v.push(f16::to_f16_vec(&v[n_comp * hd..]));
    }
    let prefix = SharedPrefix { n_layers, n_kv, hd, tokens: n_comp, k: k_comp, v: v_comp };
    Ok((prefix, tail_k, tail_v))
}

/// How many dead 64-token groups may accumulate ahead of the tail cursor
/// before the buffers are compacted. Larger values amortize the memmove
/// further at the cost of transient buffer growth: up to
/// `TAIL_COMPACT_GROUPS * TILE * hd` dead elements in each of the k and v
/// buffers per head.
const TAIL_COMPACT_GROUPS: usize = 4;

/// KV state of one (layer, kv-head).
#[derive(Clone, Debug)]
pub struct HeadKV {
    /// Compressed region: Key packed along tokens, Value along channels.
    pub k_comp: BitmapMatrix,
    pub v_comp: BitmapMatrix,
    /// Dense tail storage in binary16; the live window is
    /// `tail_k_buf[tail_start..]`, `[tail_len x hd]` row-major, post-RoPE
    /// keys. Compressed-away groups advance the cursor instead of
    /// memmoving the window every group; the dead prefix is compacted
    /// lazily (`advance_tail`).
    tail_k_buf: Vec<u16>,
    tail_v_buf: Vec<u16>,
    /// Element offset of the live tail within both buffers.
    tail_start: usize,
}

impl HeadKV {
    /// Build the per-head state, guarding against geometries the bitmap
    /// format cannot represent. With partial channel tiles any
    /// `hd >= 1` is storable (including `hd < 64` and `hd % 64 != 0`);
    /// a zero-width head has no tiles at all and is rejected loudly
    /// instead of producing a silently-empty compressed region.
    pub fn new(hd: usize) -> Result<HeadKV> {
        if hd == 0 {
            return Err(Error::Shape(
                "HeadKV: head_dim must be >= 1 — the bitmap format has no tiles for \
                 zero-width heads"
                    .into(),
            ));
        }
        Ok(HeadKV {
            k_comp: BitmapMatrix::empty(hd, PackAxis::Token),
            v_comp: BitmapMatrix::empty(hd, PackAxis::Channel),
            tail_k_buf: Vec::new(),
            tail_v_buf: Vec::new(),
            tail_start: 0,
        })
    }

    /// Live dense-tail keys `[tail_len x hd]` (binary16).
    #[inline]
    pub fn tail_k(&self) -> &[u16] {
        &self.tail_k_buf[self.tail_start..]
    }

    /// Live dense-tail values `[tail_len x hd]` (binary16).
    #[inline]
    pub fn tail_v(&self) -> &[u16] {
        &self.tail_v_buf[self.tail_start..]
    }

    pub fn tail_len(&self, hd: usize) -> usize {
        (self.tail_k_buf.len() - self.tail_start) / hd
    }

    fn push_tail(&mut self, k: &[f32], v: &[f32]) {
        f16::extend_f16(&mut self.tail_k_buf, k);
        f16::extend_f16(&mut self.tail_v_buf, v);
    }

    /// Consume `elems` elements (one compressed-away group) from the
    /// front of the live tail. O(1) cursor bump; the buffers are
    /// compacted only once `TAIL_COMPACT_GROUPS` dead groups have
    /// accumulated, so the per-group memmove of the seed's
    /// `Vec::drain` is amortized away.
    fn advance_tail(&mut self, elems: usize) {
        self.tail_start += elems;
        if self.tail_start >= TAIL_COMPACT_GROUPS * elems {
            let live = self.tail_k_buf.len() - self.tail_start;
            self.tail_k_buf.copy_within(self.tail_start.., 0);
            self.tail_k_buf.truncate(live);
            self.tail_v_buf.copy_within(self.tail_start.., 0);
            self.tail_v_buf.truncate(live);
            self.tail_start = 0;
        }
    }

    /// Actually-stored bytes of this head's *live* KV state: both
    /// compressed regions (f16 values incl. padding + u64 bitmaps + u32
    /// offsets) plus the live f16 dense tail. Every term is the
    /// in-memory size of real data — values occupy 2 bytes each, not 4.
    /// Transient allocator slack is excluded: the lazily-compacted dead
    /// tail prefix (bounded by `TAIL_COMPACT_GROUPS` groups) and `Vec`
    /// spare capacity are not live state.
    pub fn mem_usage(&self) -> usize {
        self.k_comp.compressed_bytes()
            + self.v_comp.compressed_bytes()
            + std::mem::size_of_val(self.tail_k())
            + std::mem::size_of_val(self.tail_v())
    }
}

/// Prune-time side information for output-aware / structured methods
/// (captured by the prefill pass; None for plain magnitude).
#[derive(Clone, Debug, Default)]
pub struct PruneAux {
    /// Σ|Q| over the query window, per (layer*kv_head), length hd.
    pub q_abs_win: Vec<Vec<f32>>,
    /// Attention mass per token over the query window, per (layer*kv_head).
    pub att_win: Vec<Vec<f32>>,
}

/// Apply `policy`'s pruning (+ optional quantization) to a span of K and
/// V rows for head index `idx` (shared by `ingest_prefill` and
/// `build_shared_prefill`).
fn prune_span(
    policy: &KvPolicy,
    hd: usize,
    k: &[f32],
    v: &[f32],
    t: usize,
    idx: usize,
    aux: Option<&PruneAux>,
) -> (Vec<f32>, Vec<f32>) {
    let sp = &policy.sparsity;

    let kctx = OutputAwareCtx {
        q_abs_sum: aux.map(|a| a.q_abs_win[idx].as_slice()),
        att_sum: None,
    };
    let mut kp = prune::apply(sp.key_method, k, t, hd, sp.key_sparsity, &kctx);

    let vctx = OutputAwareCtx {
        q_abs_sum: None,
        // only the rows being pruned (the compressed span) are scored
        att_sum: aux.map(|a| &a.att_win[idx][..t]),
    };
    let mut vp = prune::apply(sp.value_method, v, t, hd, sp.value_sparsity, &vctx);

    if let Some(q) = policy.quant {
        // Harma et al. ordering (as the paper follows): prune first,
        // then quantize the survivors.
        quant::kivi_fake_quant(&mut kp, t, hd, q.key_bits, quant::Axis::PerChannel, true);
        quant::kivi_fake_quant(&mut vp, t, hd, q.value_bits, quant::Axis::PerToken, true);
    }
    (kp, vp)
}

/// Full per-sequence KV cache across layers and kv-heads.
#[derive(Clone, Debug)]
pub struct SequenceKV {
    pub policy: KvPolicy,
    pub n_layers: usize,
    pub n_kv: usize,
    pub hd: usize,
    heads: Vec<HeadKV>,
    /// Shared immutable compressed prefill prefix (prefix-cache hit);
    /// covers tokens `[0, prefix.tokens)`. Private state holds
    /// everything after it.
    prefix: Option<Arc<SharedPrefix>>,
    /// Total tokens represented (prefix + compressed + tail); uniform
    /// across heads.
    pub tokens: usize,
    /// Deferred-compression mode (engine-driven; see [`set_deferred`]).
    /// When on, `commit_token` only bumps `pending` — exited groups stay
    /// dense at the front of the ring tail until harvested into worker
    /// jobs (`pending` → `inflight`) and settled (`settle_group`) in
    /// exit order. Both are zero in synchronous mode.
    ///
    /// [`set_deferred`]: SequenceKV::set_deferred
    deferred: bool,
    /// Max exited groups the ring tail may buffer before `commit_token`
    /// stalls (compresses synchronously in place).
    inflight_budget: usize,
    pending: usize,
    inflight: usize,
    stalls: u64,
}

impl SequenceKV {
    pub fn new(policy: KvPolicy, n_layers: usize, n_kv: usize, hd: usize) -> Result<SequenceKV> {
        let heads =
            (0..n_layers * n_kv).map(|_| HeadKV::new(hd)).collect::<Result<Vec<HeadKV>>>()?;
        Ok(SequenceKV {
            policy,
            n_layers,
            n_kv,
            hd,
            heads,
            prefix: None,
            tokens: 0,
            deferred: false,
            inflight_budget: 0,
            pending: 0,
            inflight: 0,
            stalls: 0,
        })
    }

    /// Build a sequence on top of a shared compressed prefix (partial
    /// prefix-cache hit): the prefix supplies tokens `[0, prefix.tokens)`
    /// and the caller drives the remaining prompt through the decode
    /// path to fill the dense tail. An empty prefix degrades to `new`.
    pub fn with_prefix(policy: KvPolicy, prefix: Arc<SharedPrefix>) -> Result<SequenceKV> {
        if !policy.compress {
            return Err(Error::Invalid(
                "with_prefix: shared compressed prefixes require a compressing policy".into(),
            ));
        }
        let (n_layers, n_kv, hd) = (prefix.n_layers, prefix.n_kv, prefix.hd);
        let mut seq = SequenceKV::new(policy, n_layers, n_kv, hd)?;
        if prefix.tokens > 0 {
            seq.tokens = prefix.tokens;
            seq.prefix = Some(prefix);
        }
        Ok(seq)
    }

    /// Reconstruct a full post-prefill sequence from a prefix-cache
    /// *full* hit: shared compressed prefix + this prompt's own binary16
    /// dense tails (`tail_k[layer * n_kv + kv]`, `[tail_tokens x hd]`).
    /// The result is bit-identical to the state `ingest_prefill` would
    /// have produced for the same prompt, so subsequent decode is
    /// token-identical to the cold path.
    pub fn restore_full(
        policy: KvPolicy,
        prefix: Arc<SharedPrefix>,
        tail_k: Vec<Vec<u16>>,
        tail_v: Vec<Vec<u16>>,
        total_tokens: usize,
    ) -> Result<SequenceKV> {
        let mut seq = SequenceKV::with_prefix(policy, prefix)?;
        let hd = seq.hd;
        if tail_k.len() != seq.heads.len() || tail_v.len() != seq.heads.len() {
            return Err(Error::Shape("restore_full: per-head tail count mismatch".into()));
        }
        if total_tokens < seq.tokens {
            return Err(Error::Shape(format!(
                "restore_full: total tokens {total_tokens} < prefix tokens {}",
                seq.tokens
            )));
        }
        let tail_tokens = total_tokens - seq.tokens;
        // tails move in (no copy): the caller either owns a fresh clone
        // from the cache entry or built them for this sequence anyway
        let pairs = tail_k.into_iter().zip(tail_v);
        for (idx, (h, (tk, tv))) in seq.heads.iter_mut().zip(pairs).enumerate() {
            if tk.len() != tail_tokens * hd || tv.len() != tail_tokens * hd {
                return Err(Error::Shape(format!(
                    "restore_full: head {idx} tail len {} != {} tokens x {hd}",
                    tk.len(),
                    tail_tokens
                )));
            }
            h.tail_k_buf = tk;
            h.tail_v_buf = tv;
            h.tail_start = 0;
        }
        seq.tokens = total_tokens;
        Ok(seq)
    }

    /// Shared prefix, if this sequence rides on one.
    #[inline]
    pub fn prefix(&self) -> Option<&Arc<SharedPrefix>> {
        self.prefix.as_ref()
    }

    /// Snapshot this sequence's cacheable decomposition: a
    /// `SharedPrefix` covering every compressed token — the current
    /// shared prefix (if any) structurally concatenated with the private
    /// compressed groups — plus clones of the per-head dense tails.
    /// Under a token-local policy (`KvPolicy::prefix_shareable`) this is
    /// byte-identical to what `build_shared_prefill` would produce for
    /// the same tokens, which is what lets the engine insert *partial-
    /// hit* sequences back into the prefix cache after their suffix
    /// rebuild (previously only cold misses populated it). When no new
    /// groups were compressed the existing prefix `Arc` is returned
    /// as-is (no copy).
    pub fn shareable_snapshot(&self) -> Result<(Arc<SharedPrefix>, Vec<Vec<u16>>, Vec<Vec<u16>>)> {
        if self.pending + self.inflight > 0 {
            // A snapshot with exited-but-uncompressed groups in its tail
            // would restore to a layout the cold path never produces
            // (dense attention over rows the cold path pruned), breaking
            // the restore-is-bit-identical contract. The engine only
            // snapshots synchronous-mode sequences.
            return Err(Error::Invalid(
                "shareable_snapshot: deferred groups queued; settle and flush first".into(),
            ));
        }
        let tail_k: Vec<Vec<u16>> = self.heads.iter().map(|h| h.tail_k().to_vec()).collect();
        let tail_v: Vec<Vec<u16>> = self.heads.iter().map(|h| h.tail_v().to_vec()).collect();
        let comp_tokens = self.heads.first().map_or(0, |h| h.k_comp.tokens);
        let prefix = match (&self.prefix, comp_tokens) {
            (Some(p), 0) => Arc::clone(p),
            (pfx, _) => {
                let hd = self.hd;
                let base = pfx.as_ref().map_or(0, |p| p.tokens);
                let mut k = Vec::with_capacity(self.heads.len());
                let mut v = Vec::with_capacity(self.heads.len());
                for (idx, h) in self.heads.iter().enumerate() {
                    let (mut km, mut vm) = match pfx {
                        Some(p) => (p.k[idx].clone(), p.v[idx].clone()),
                        None => (
                            BitmapMatrix::empty(hd, PackAxis::Token),
                            BitmapMatrix::empty(hd, PackAxis::Channel),
                        ),
                    };
                    km.append_compressed(&h.k_comp)?;
                    vm.append_compressed(&h.v_comp)?;
                    k.push(km);
                    v.push(vm);
                }
                Arc::new(SharedPrefix {
                    n_layers: self.n_layers,
                    n_kv: self.n_kv,
                    hd,
                    tokens: base + comp_tokens,
                    k,
                    v,
                })
            }
        };
        Ok((prefix, tail_k, tail_v))
    }

    /// Swap this sequence onto a shared prefix covering exactly its
    /// current prefix plus all private compressed groups, dropping the
    /// now-redundant private copies (the canonical pages are charged to
    /// the prefix cache; see `shareable_snapshot`). Decode is
    /// bit-identical before and after: the segmented attention walk over
    /// `[prefix | private]` reproduces the merged tile stream exactly.
    pub fn promote_prefix(&mut self, p: Arc<SharedPrefix>) -> Result<()> {
        let comp_tokens = self.heads.first().map_or(0, |h| h.k_comp.tokens);
        let covered = self.prefix.as_ref().map_or(0, |x| x.tokens) + comp_tokens;
        let same_geom = p.n_layers == self.n_layers && p.n_kv == self.n_kv && p.hd == self.hd;
        if p.tokens != covered || !same_geom {
            return Err(Error::Shape(format!(
                "promote_prefix: prefix covers {} tokens / geometry ({},{},{}), sequence has \
                 {covered} compressed tokens / ({},{},{})",
                p.tokens, p.n_layers, p.n_kv, p.hd, self.n_layers, self.n_kv, self.hd
            )));
        }
        if p.tokens == 0 {
            return Ok(());
        }
        for h in &mut self.heads {
            h.k_comp = BitmapMatrix::empty(self.hd, PackAxis::Token);
            h.v_comp = BitmapMatrix::empty(self.hd, PackAxis::Channel);
        }
        self.prefix = Some(p);
        Ok(())
    }

    #[inline]
    pub fn head(&self, layer: usize, kv: usize) -> &HeadKV {
        &self.heads[layer * self.n_kv + kv]
    }

    #[inline]
    pub fn head_mut(&mut self, layer: usize, kv: usize) -> &mut HeadKV {
        &mut self.heads[layer * self.n_kv + kv]
    }

    /// Ingest prefill caches: `k_dense[l*n_kv+h]` is `[t x hd]` row-major
    /// (post-RoPE keys). Prunes + compresses everything except the local
    /// window per the policy; `aux` supplies output-aware scores.
    pub fn ingest_prefill(
        &mut self,
        k_dense: &[Vec<f32>],
        v_dense: &[Vec<f32>],
        t: usize,
        aux: Option<&PruneAux>,
    ) -> Result<()> {
        assert_eq!(k_dense.len(), self.n_layers * self.n_kv);
        assert_eq!(self.tokens, 0, "ingest_prefill on non-empty cache");
        let hd = self.hd;
        let w = self.policy.local_window;

        // Compress whole 64-token groups that are fully outside the window.
        let n_comp = if self.policy.compress && t > w { ((t - w) / TILE) * TILE } else { 0 };

        for idx in 0..self.heads.len() {
            let k = &k_dense[idx];
            let v = &v_dense[idx];
            assert_eq!(k.len(), t * hd);

            if n_comp > 0 {
                let policy = self.policy;
                let (kp, vp) =
                    prune_span(&policy, hd, &k[..n_comp * hd], &v[..n_comp * hd], n_comp, idx, aux);
                let h = &mut self.heads[idx];
                h.k_comp.append_groups(&kp, n_comp)?;
                h.v_comp.append_groups(&vp, n_comp)?;
            }
            let h = &mut self.heads[idx];
            h.push_tail(&k[n_comp * hd..], &v[n_comp * hd..]);
        }
        self.tokens = t;
        Ok(())
    }

    /// Append one decoded token's K/V for (layer, kv) — narrowed to
    /// binary16 at the push. Call for every (layer, kv) exactly once per
    /// generated token, then `commit_token`.
    pub fn append(&mut self, layer: usize, kv: usize, k: &[f32], v: &[f32]) {
        debug_assert_eq!(k.len(), self.hd);
        self.head_mut(layer, kv).push_tail(k, v);
    }

    /// Account the token appended to all heads and run the compression
    /// trigger: once the tail holds a full group beyond the local window
    /// (plus any groups already queued for deferred compression), the
    /// oldest 64-token group exits. Synchronous mode prunes + packs it
    /// here, on the calling thread; deferred mode only bumps the
    /// pending-group count — an O(1), allocation-free bookkeeping step —
    /// leaving the prune/pack work to harvested worker jobs
    /// ([`pending_group_rows`] → [`settle_group`]).
    ///
    /// [`pending_group_rows`]: SequenceKV::pending_group_rows
    /// [`settle_group`]: SequenceKV::settle_group
    pub fn commit_token(&mut self) -> Result<()> {
        self.tokens += 1;
        if !self.policy.compress {
            return Ok(());
        }
        let cap = TILE + self.policy.local_window + (self.pending + self.inflight) * TILE;
        // decide based on head 0 (all heads have identical tail lengths)
        if self.heads[0].tail_len(self.hd) < cap {
            return Ok(());
        }
        if !self.deferred {
            return self.compress_front_group();
        }
        self.pending += 1;
        // Backpressure: the ring tail may buffer at most
        // `inflight_budget` exited groups. Degrade gracefully by
        // compressing the oldest pending group synchronously in place —
        // order-preserving and bit-identical to the deferred job — the
        // "stall" the `compress_stalls` counter reports. In engine
        // operation the budget is never exceeded (decode adds one token
        // per round and every round settles first), so this is the
        // slow-compressor escape hatch; with jobs still in flight ahead
        // of the pending group the ring grows instead (the front cannot
        // be retired past unsettled groups).
        while self.pending + self.inflight > self.inflight_budget.max(1) && self.inflight == 0 {
            self.compress_front_group()?;
            self.pending -= 1;
            self.stalls += 1;
        }
        Ok(())
    }

    /// Prune + pack the group at the front of the dense tail into the
    /// compressed region — the synchronous compression step. Widen,
    /// prune, and (optional) quantize run *in place* in the thread's
    /// reusable scratch pair, so a commit performs no allocations beyond
    /// the compressed region itself.
    fn compress_front_group(&mut self) -> Result<()> {
        let hd = self.hd;
        let policy = self.policy;
        with_compress_scratch(TILE * hd, |kg, vg| {
            for idx in 0..self.heads.len() {
                {
                    let h = &self.heads[idx];
                    prune_group_into(
                        &policy,
                        hd,
                        &h.tail_k()[..TILE * hd],
                        &h.tail_v()[..TILE * hd],
                        kg,
                        vg,
                    );
                }
                let h = &mut self.heads[idx];
                h.k_comp.append_groups(kg, TILE)?;
                h.v_comp.append_groups(vg, TILE)?;
                h.advance_tail(TILE * hd);
            }
            Ok(())
        })
    }

    /// Switch deferred-compression mode. The engine flips this on when a
    /// sequence becomes decodable; direct users (batched prefill
    /// ingestion, eval) stay synchronous. Turning it off flushes any
    /// pending groups synchronously so the layout returns to the
    /// canonical synchronous one. `budget` bounds how many exited groups
    /// the ring tail may buffer before `commit_token` stalls.
    pub fn set_deferred(&mut self, on: bool, budget: usize) -> Result<()> {
        if !on {
            self.flush_queued()?;
        }
        self.deferred = on;
        self.inflight_budget = budget;
        Ok(())
    }

    /// Exited groups not yet harvested into compression jobs.
    #[inline]
    pub fn pending_groups(&self) -> usize {
        self.pending
    }

    /// Harvested groups whose compression jobs have not settled yet.
    #[inline]
    pub fn inflight_groups(&self) -> usize {
        self.inflight
    }

    /// Exited groups still dense in the ring tail (pending + in flight).
    #[inline]
    pub fn queued_groups(&self) -> usize {
        self.pending + self.inflight
    }

    /// Drain the backpressure-stall count (commits forced to compress
    /// synchronously because the ring was full).
    pub fn take_stalls(&mut self) -> u64 {
        std::mem::take(&mut self.stalls)
    }

    /// Dense binary16 rows of the `slot`-th *pending* group (0 = oldest
    /// unharvested) for head `idx` — the input a deferred compression
    /// job copies out before [`mark_harvested`] moves the slot in
    /// flight.
    ///
    /// [`mark_harvested`]: SequenceKV::mark_harvested
    pub fn pending_group_rows(&self, idx: usize, slot: usize) -> (&[u16], &[u16]) {
        debug_assert!(slot < self.pending, "pending_group_rows: slot {slot} >= {}", self.pending);
        let elems = TILE * self.hd;
        let off = (self.inflight + slot) * elems;
        let h = &self.heads[idx];
        (&h.tail_k()[off..off + elems], &h.tail_v()[off..off + elems])
    }

    /// Mark the oldest `n` pending groups as harvested into worker jobs;
    /// their results must come back through [`settle_group`] in exit
    /// order.
    ///
    /// [`settle_group`]: SequenceKV::settle_group
    pub fn mark_harvested(&mut self, n: usize) {
        debug_assert!(n <= self.pending);
        self.pending -= n;
        self.inflight += n;
    }

    /// Settle one completed compression wave: append each head's
    /// compressed (K, V) pair — produced by [`compress_group`] from the
    /// rows this call now retires — and advance the ring tail past the
    /// group. Byte-identical to the synchronous path per
    /// `BitmapMatrix::append_compressed`. Waves must arrive in exit
    /// order (the engine's compressor sorts by wave id), `parts` in
    /// `layer * n_kv + kv` head order.
    pub fn settle_group(&mut self, parts: Vec<(BitmapMatrix, BitmapMatrix)>) -> Result<()> {
        if self.inflight == 0 {
            return Err(Error::Invalid("settle_group: no compression wave in flight".into()));
        }
        if parts.len() != self.heads.len() {
            return Err(Error::Shape(format!(
                "settle_group: {} head results for {} heads",
                parts.len(),
                self.heads.len()
            )));
        }
        let elems = TILE * self.hd;
        for (h, (km, vm)) in self.heads.iter_mut().zip(parts) {
            h.k_comp.append_compressed(&km)?;
            h.v_comp.append_compressed(&vm)?;
            h.advance_tail(elems);
        }
        self.inflight -= 1;
        Ok(())
    }

    /// Synchronously compress every pending group (leaving deferred
    /// mode, or preparing a canonical-layout snapshot). Requires nothing
    /// in flight — the engine settles before flushing.
    pub fn flush_queued(&mut self) -> Result<()> {
        if self.inflight > 0 {
            return Err(Error::Invalid(
                "flush_queued: compression jobs still in flight; settle first".into(),
            ));
        }
        while self.pending > 0 {
            self.compress_front_group()?;
            self.pending -= 1;
        }
        Ok(())
    }

    /// Mutable access to the per-(layer, kv-head) states, in
    /// `layer * n_kv + kv` order — the engine's worker-parallel re-prune
    /// fans these out with [`reprune_head_inplace`].
    pub fn heads_mut(&mut self) -> &mut [HeadKV] {
        &mut self.heads
    }

    /// Which sides a re-prune to (ks, vs) raises, plus the per-side keep
    /// counts (shared by the inline and worker-parallel re-prune paths).
    pub fn reprune_plan(&self, ks: f64, vs: f64) -> (bool, bool, usize, usize) {
        let raise_k = self.policy.compress && ks > self.policy.sparsity.key_sparsity;
        let raise_v = self.policy.compress && vs > self.policy.sparsity.value_sparsity;
        (raise_k, raise_v, prune::keep_count(self.hd, ks), prune::keep_count(self.hd, vs))
    }

    /// Record a completed re-prune's policy side effects, so groups
    /// compressed from now on (including still-pending deferred groups)
    /// match the new tier.
    pub fn apply_reprune_policy(&mut self, ks: f64, vs: f64) {
        if self.policy.compress && ks > self.policy.sparsity.key_sparsity {
            self.policy.sparsity.key_sparsity = ks;
            self.policy.sparsity.key_method = Method::TokenMagnitude;
        }
        if self.policy.compress && vs > self.policy.sparsity.value_sparsity {
            self.policy.sparsity.value_sparsity = vs;
            self.policy.sparsity.value_method = Method::TokenMagnitude;
        }
    }

    /// (compressed_bytes, dense_equivalent_bytes) — the Fig 6b metric,
    /// aggregated over layers and heads. Since the cache stores real
    /// binary16, the compressed figure is the sum of actually-stored
    /// bytes (`HeadKV::mem_usage`, plus the shared prefix this sequence
    /// logically includes); the dense equivalent counts the same token
    /// count at dense fp16.
    pub fn memory_bytes(&self) -> (usize, usize) {
        let hd = self.hd;
        let mut comp = self.prefix.as_ref().map_or(0, |p| p.bytes());
        let mut dense = 0usize;
        for h in &self.heads {
            comp += h.mem_usage();
            dense += 2 * self.tokens * hd * crate::sparse::bitmap::VALUE_BYTES;
        }
        (comp, dense)
    }

    /// Bytes privately owned by this sequence: compressed regions + live
    /// dense tails, *excluding* any shared prefix (the prefix cache
    /// charges those pages to the pool exactly once for all sharers).
    /// This is the figure the engine reserves against the kvpool.
    pub fn private_bytes(&self) -> usize {
        self.heads.iter().map(|h| h.mem_usage()).sum()
    }

    /// Bytes of the private *compressed regions* only — the part a
    /// re-prune can shrink (dense tails and shared prefixes are not
    /// re-prunable). The pressure controller ranks victims by this.
    pub fn compressed_region_bytes(&self) -> usize {
        self.heads
            .iter()
            .map(|h| h.k_comp.compressed_bytes() + h.v_comp.compressed_bytes())
            .sum()
    }

    /// Pressure-adaptive re-prune (the kvpool pressure controller's
    /// step 2): raise the *private* compressed regions to `ks`/`vs`
    /// sparsity by decompress → per-token magnitude → recompress, pages
    /// shrinking in place. The dense tail (local window) and any shared
    /// prefix stay untouched, and the policy is updated so groups
    /// compressed from now on match the new tier. Sides whose sparsity
    /// would not increase are left alone. Returns the bytes freed.
    pub fn reprune(&mut self, ks: f64, vs: f64) -> Result<usize> {
        let before = self.private_bytes();
        let hd = self.hd;
        let (raise_k, raise_v, kk_k, kk_v) = self.reprune_plan(ks, vs);
        for h in &mut self.heads {
            reprune_head_inplace(h, hd, raise_k, raise_v, kk_k, kk_v)?;
        }
        self.apply_reprune_policy(ks, vs);
        Ok(before.saturating_sub(self.private_bytes()))
    }

    /// Fig 6b compression rate for this sequence (1.0 = dense).
    pub fn compression_rate(&self) -> f64 {
        let (c, d) = self.memory_bytes();
        if d == 0 {
            0.0
        } else {
            c as f64 / d as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Pcg32;

    fn rand_heads(n: usize, t: usize, hd: usize, seed: u64) -> Vec<Vec<f32>> {
        let mut rng = Pcg32::seeded(seed);
        (0..n).map(|_| (0..t * hd).map(|_| rng.normal_f32()).collect()).collect()
    }

    #[test]
    fn prefill_ingest_splits_comp_and_tail() {
        let (l, kv, hd, t) = (2, 2, 64, 448);
        let mut seq = SequenceKV::new(KvPolicy::mustafar(0.5, 0.5), l, kv, hd).unwrap();
        let k = rand_heads(l * kv, t, hd, 1);
        let v = rand_heads(l * kv, t, hd, 2);
        seq.ingest_prefill(&k, &v, t, None).unwrap();
        // (448-32)/64 = 6 groups -> 384 compressed, 64 tail
        assert_eq!(seq.tokens, 448);
        let h = seq.head(0, 0);
        assert_eq!(h.k_comp.tokens, 384);
        assert_eq!(h.tail_len(hd), 64);
        // ~50% sparsity in compressed K
        let rate = h.k_comp.nnz() as f64 / (384.0 * hd as f64);
        assert!((rate - 0.5).abs() < 0.02, "{rate}");
    }

    #[test]
    fn small_head_dim_populates_value_cache() {
        // Seed-bug regression: hd = 32 < 64 channel-packed V produced
        // zero tiles (channels / TILE == 0) and silently contributed
        // nothing; partial channel tiles must carry the real values.
        let (l, kv, hd, t) = (1, 1, 32, 448);
        let mut seq = SequenceKV::new(KvPolicy::mustafar(0.5, 0.5), l, kv, hd).unwrap();
        let k = rand_heads(l * kv, t, hd, 21);
        let v = rand_heads(l * kv, t, hd, 22);
        seq.ingest_prefill(&k, &v, t, None).unwrap();
        let h = seq.head(0, 0);
        assert_eq!(h.v_comp.tokens, 384);
        assert_eq!(h.v_comp.bitmaps.len(), 384, "one partial tile per token");
        let rate = h.v_comp.nnz() as f64 / (384.0 * hd as f64);
        assert!((rate - 0.5).abs() < 0.05, "value cache holds ~50%: {rate}");
        // and the decompressed region matches the pruned reference
        let want =
            f16::f16_round_vec(&crate::prune::per_token_magnitude(&v[0][..384 * hd], 384, hd, 16));
        assert_eq!(h.v_comp.decompress(), want);
    }

    #[test]
    fn zero_head_dim_is_rejected() {
        let err = SequenceKV::new(KvPolicy::dense(), 1, 1, 0);
        assert!(err.is_err(), "hd = 0 must fail construction, not silently store nothing");
    }

    #[test]
    fn dense_policy_keeps_everything_in_tail() {
        let (l, kv, hd, t) = (1, 1, 32, 200);
        let mut seq = SequenceKV::new(KvPolicy::dense(), l, kv, hd).unwrap();
        let k = rand_heads(1, t, hd, 3);
        let v = rand_heads(1, t, hd, 4);
        seq.ingest_prefill(&k, &v, t, None).unwrap();
        assert_eq!(seq.head(0, 0).k_comp.tokens, 0);
        assert_eq!(seq.head(0, 0).tail_len(hd), 200);
        assert!((seq.compression_rate() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn decode_appends_trigger_group_compression() {
        let (l, kv, hd) = (1, 1, 64);
        let mut seq = SequenceKV::new(KvPolicy::mustafar(0.7, 0.7), l, kv, hd).unwrap();
        let mut rng = Pcg32::seeded(5);
        // grow token by token past the trigger point
        for i in 0..TAIL_CAP + 10 {
            let k: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
            let v: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
            seq.append(0, 0, &k, &v);
            seq.commit_token().unwrap();
            let h = seq.head(0, 0);
            assert_eq!(h.k_comp.tokens + h.tail_len(hd), i + 1, "token {i}");
            assert!(h.tail_len(hd) >= 32.min(i + 1), "local window violated at {i}");
            assert!(h.tail_len(hd) < TAIL_CAP + 1);
        }
        let h = seq.head(0, 0);
        assert_eq!(h.k_comp.tokens, TILE); // exactly one group compressed
    }

    #[test]
    fn lazy_tail_compaction_preserves_contents() {
        // Drive enough tokens through the decode path to cross several
        // compaction cycles; the live tail must always hold exactly the
        // most recent `tail_len` rows (as their f16 narrowings — storage
        // is binary16), and the dead prefix stays bounded.
        let hd = 16;
        let mut seq = SequenceKV::new(KvPolicy::mustafar(0.5, 0.5), 1, 1, hd).unwrap();
        let row = |i: usize, c: usize| (i * 31 + c) as f32 + 0.25;
        for i in 0..1000 {
            let k: Vec<f32> = (0..hd).map(|c| row(i, c)).collect();
            let v: Vec<f32> = (0..hd).map(|c| -row(i, c)).collect();
            seq.append(0, 0, &k, &v);
            seq.commit_token().unwrap();

            let h = seq.head(0, 0);
            let tl = h.tail_len(hd);
            assert_eq!(h.k_comp.tokens + tl, i + 1);
            let tail = h.tail_k();
            assert_eq!(tail.len(), tl * hd);
            for r in 0..tl {
                let tok = i + 1 - tl + r;
                for c in 0..hd {
                    assert_eq!(
                        tail[r * hd + c],
                        crate::sparse::f32_to_f16(row(tok, c)),
                        "token {i} row {r} ch {c}"
                    );
                }
                assert_eq!(h.tail_v()[r * hd], crate::sparse::f32_to_f16(-row(i + 1 - tl + r, 0)));
            }
            // dead prefix bounded by the compaction threshold
            assert!(
                h.tail_k_buf.len() - h.tail_k().len() < TAIL_COMPACT_GROUPS * TILE * hd,
                "dead prefix unbounded at token {i}"
            );
        }
    }

    #[test]
    fn compression_rate_improves_with_sparsity() {
        let (l, kv, hd, t) = (1, 1, 64, 448);
        let k = rand_heads(1, t, hd, 6);
        let v = rand_heads(1, t, hd, 7);
        let mut rates = Vec::new();
        for s in [0.5, 0.7] {
            let mut seq = SequenceKV::new(KvPolicy::mustafar(s, s), l, kv, hd).unwrap();
            seq.ingest_prefill(&k, &v, t, None).unwrap();
            rates.push(seq.compression_rate());
        }
        assert!(rates[0] > rates[1], "{rates:?}");
        assert!(rates[0] < 1.0);
    }

    #[test]
    fn mem_usage_equals_actually_stored_bytes() {
        // Acceptance: the compressed-bytes figure must equal the summed
        // in-memory size of every buffer actually held — f16 values are
        // 2 bytes in memory, not 4.
        let (l, kv, hd, t) = (2, 1, 64, 448);
        let mut seq = SequenceKV::new(KvPolicy::mustafar(0.5, 0.5), l, kv, hd).unwrap();
        let k = rand_heads(l * kv, t, hd, 30);
        let v = rand_heads(l * kv, t, hd, 31);
        seq.ingest_prefill(&k, &v, t, None).unwrap();

        let mut expect = 0usize;
        for layer in 0..l {
            let h = seq.head(layer, 0);
            for m in [&h.k_comp, &h.v_comp] {
                expect += std::mem::size_of_val(m.values.as_slice())
                    + std::mem::size_of_val(m.bitmaps.as_slice())
                    + std::mem::size_of_val(&m.offsets.as_slice()[..m.offsets.len() - 1]);
            }
            expect += std::mem::size_of_val(h.tail_k()) + std::mem::size_of_val(h.tail_v());
            assert_eq!(std::mem::size_of_val(&h.k_comp.values[0]), 2, "values are f16");
        }
        let (comp, dense) = seq.memory_bytes();
        assert_eq!(comp, expect);
        assert!(comp < dense);
    }

    #[test]
    fn quantization_is_applied_to_compressed_region() {
        let (l, kv, hd, t) = (1, 1, 64, 128);
        let k = rand_heads(1, t, hd, 8);
        let v = rand_heads(1, t, hd, 9);
        let mut pol = KvPolicy::mustafar(0.5, 0.5);
        pol.quant = Some(QuantConfig { key_bits: 2, value_bits: 2 });
        let mut seq = SequenceKV::new(pol, l, kv, hd).unwrap();
        seq.ingest_prefill(&k, &v, t, None).unwrap();
        // quantized values differ from originals (2-bit is coarse)
        let dec = seq.head(0, 0).k_comp.decompress();
        let mut diffs = 0;
        for (a, b) in dec.iter().zip(&k[0][..dec.len()]) {
            if *a != 0.0 && (a - b).abs() > 1e-6 {
                diffs += 1;
            }
        }
        assert!(diffs > 100, "quant had no effect ({diffs})");
    }

    #[test]
    fn restore_full_is_bit_identical_to_ingest() {
        // A prefix-cache full hit reconstructs exactly the state the
        // cold path builds: same compressed tiles, same f16 tails.
        let (l, kv, hd, t) = (2, 2, 64, 448);
        let policy = KvPolicy::mustafar(0.5, 0.5);
        let k = rand_heads(l * kv, t, hd, 40);
        let v = rand_heads(l * kv, t, hd, 41);

        let mut cold = SequenceKV::new(policy, l, kv, hd).unwrap();
        cold.ingest_prefill(&k, &v, t, None).unwrap();

        let (prefix, tk, tv) = build_shared_prefill(&policy, l, kv, hd, &k, &v, t).unwrap();
        assert_eq!(prefix.tokens, 384);
        let prefix = std::sync::Arc::new(prefix);
        let hit = SequenceKV::restore_full(policy, prefix, tk, tv, t).unwrap();

        assert_eq!(hit.tokens, cold.tokens);
        for layer in 0..l {
            for h in 0..kv {
                let (pk, pv) = hit.prefix().unwrap().head(layer, h);
                assert_eq!(pk, &cold.head(layer, h).k_comp);
                assert_eq!(pv, &cold.head(layer, h).v_comp);
                assert_eq!(hit.head(layer, h).tail_k(), cold.head(layer, h).tail_k());
                assert_eq!(hit.head(layer, h).tail_v(), cold.head(layer, h).tail_v());
                // the hit sequence's private compressed region is empty
                assert_eq!(hit.head(layer, h).k_comp.tokens, 0);
            }
        }
        // logical footprint identical; private footprint excludes prefix
        assert_eq!(hit.memory_bytes(), cold.memory_bytes());
        assert!(hit.private_bytes() < cold.private_bytes());
    }

    #[test]
    fn shared_prefix_is_byte_identical_across_extending_prompts() {
        // Token-local pruning makes the compressed form of a shared
        // prompt prefix independent of what follows it — the invariant
        // the prefix cache relies on.
        let (l, kv, hd) = (1, 1, 64);
        let policy = KvPolicy::mustafar(0.6, 0.6);
        let long_k = rand_heads(1, 512, hd, 50);
        let long_v = rand_heads(1, 512, hd, 51);
        let short_k = vec![long_k[0][..448 * hd].to_vec()];
        let short_v = vec![long_v[0][..448 * hd].to_vec()];

        let (pa, _, _) = build_shared_prefill(&policy, l, kv, hd, &short_k, &short_v, 448).unwrap();
        let (pb, _, _) = build_shared_prefill(&policy, l, kv, hd, &long_k, &long_v, 512).unwrap();
        assert_eq!(pa.tokens, 384);
        assert_eq!(pb.tokens, 448);
        let da = pa.head(0, 0).0.decompress();
        let db = pb.head(0, 0).0.decompress();
        assert_eq!(da[..], db[..384 * hd], "shared K prefix diverged");
        let va = pa.head(0, 0).1.decompress();
        let vb = pb.head(0, 0).1.decompress();
        assert_eq!(va[..], vb[..384 * hd], "shared V prefix diverged");
    }

    #[test]
    fn with_prefix_supports_decode_appends() {
        let (l, kv, hd, t) = (1, 1, 32, 448);
        let policy = KvPolicy::mustafar(0.5, 0.5);
        let k = rand_heads(1, t, hd, 60);
        let v = rand_heads(1, t, hd, 61);
        let (prefix, _, _) = build_shared_prefill(&policy, l, kv, hd, &k, &v, t).unwrap();
        let b = prefix.tokens;
        let mut seq = SequenceKV::with_prefix(policy, std::sync::Arc::new(prefix)).unwrap();
        assert_eq!(seq.tokens, b);
        let mut rng = Pcg32::seeded(62);
        for i in 0..TAIL_CAP + 5 {
            let kr: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
            let vr: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
            seq.append(0, 0, &kr, &vr);
            seq.commit_token().unwrap();
            assert_eq!(seq.tokens, b + i + 1);
        }
        // one group exited the window into the *private* compressed region
        assert_eq!(seq.head(0, 0).k_comp.tokens, TILE);
        assert!(seq.private_bytes() > 0);
        let (comp, _) = seq.memory_bytes();
        assert!(comp > seq.private_bytes(), "logical bytes include the shared prefix");
    }

    #[test]
    fn reprune_raises_sparsity_and_frees_bytes() {
        let (l, kv, hd, t) = (2, 1, 64, 448);
        let mut seq = SequenceKV::new(KvPolicy::mustafar(0.5, 0.5), l, kv, hd).unwrap();
        let k = rand_heads(l * kv, t, hd, 70);
        let v = rand_heads(l * kv, t, hd, 71);
        seq.ingest_prefill(&k, &v, t, None).unwrap();

        let before = seq.private_bytes();
        let old_dec = seq.head(0, 0).k_comp.decompress();
        let freed = seq.reprune(0.75, 0.75).unwrap();
        assert!(freed > 0);
        assert_eq!(seq.private_bytes(), before - freed);

        // survivors are exactly the magnitude top-k of the old contents
        let kk = prune::keep_count(hd, 0.75);
        let want = crate::prune::per_token_magnitude(&old_dec, 384, hd, kk);
        assert_eq!(seq.head(0, 0).k_comp.decompress(), f16::f16_round_vec(&want));
        let rate = seq.head(0, 0).k_comp.nnz() as f64 / (384.0 * hd as f64);
        assert!((rate - 0.25).abs() < 0.03, "{rate}");

        // policy follows the tier, so future groups compress at 0.75
        assert_eq!(seq.policy.sparsity.key_sparsity, 0.75);

        // re-pruning at a lower sparsity is a no-op
        let freed2 = seq.reprune(0.6, 0.6).unwrap();
        assert_eq!(freed2, 0);
        assert_eq!(seq.policy.sparsity.key_sparsity, 0.75);
    }

    #[test]
    fn shareable_snapshot_merges_prefix_and_private_groups_bitexact() {
        // A sequence that started from a shared prefix and compressed
        // more groups through the decode path must snapshot to *exactly*
        // the state a cold sequence over the same token stream holds —
        // the identity the engine's partial-hit cache insert relies on.
        let (l, kv, hd, t1) = (2, 1, 32, 160);
        let policy = KvPolicy::mustafar(0.5, 0.5);
        let k = rand_heads(l * kv, t1, hd, 80);
        let v = rand_heads(l * kv, t1, hd, 81);

        let mut cold = SequenceKV::new(policy, l, kv, hd).unwrap();
        cold.ingest_prefill(&k, &v, t1, None).unwrap();

        let (prefix, tk, tv) = build_shared_prefill(&policy, l, kv, hd, &k, &v, t1).unwrap();
        assert!(prefix.tokens > 0);
        let mut hot =
            SequenceKV::restore_full(policy, std::sync::Arc::new(prefix), tk, tv, t1).unwrap();

        // identical decode-path appends on both (enough to push private
        // groups through compression on the hot sequence)
        let mut rng = Pcg32::seeded(82);
        for _ in 0..TAIL_CAP + 8 {
            let rows: Vec<(Vec<f32>, Vec<f32>)> = (0..l * kv)
                .map(|_| {
                    let kr: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
                    let vr: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
                    (kr, vr)
                })
                .collect();
            for seq_ref in [&mut cold, &mut hot] {
                for layer in 0..l {
                    for h in 0..kv {
                        let (kr, vr) = &rows[layer * kv + h];
                        seq_ref.append(layer, h, kr, vr);
                    }
                }
                seq_ref.commit_token().unwrap();
            }
        }
        assert!(hot.head(0, 0).k_comp.tokens > 0, "no private groups compressed");

        let (pa, tka, tva) = cold.shareable_snapshot().unwrap();
        let (pb, tkb, tvb) = hot.shareable_snapshot().unwrap();
        assert_eq!(pa.tokens, pb.tokens);
        assert_eq!((tka, tva), (tkb, tvb), "tails diverged");
        for idx in 0..l * kv {
            assert_eq!(pa.k[idx], pb.k[idx], "merged K head {idx} diverged");
            assert_eq!(pa.v[idx], pb.v[idx], "merged V head {idx} diverged");
        }

        // Promotion drops the private copies without changing the
        // logical state, and shrinks the private footprint.
        let before = hot.memory_bytes();
        let private_before = hot.private_bytes();
        let tokens_before = hot.tokens;
        hot.promote_prefix(std::sync::Arc::clone(&pb)).unwrap();
        assert_eq!(hot.tokens, tokens_before);
        assert_eq!(hot.head(0, 0).k_comp.tokens, 0);
        assert_eq!(hot.prefix().unwrap().tokens, pb.tokens);
        assert_eq!(hot.memory_bytes(), before, "logical bytes must not change");
        assert!(hot.private_bytes() < private_before);

        // a stale (wrong-coverage) prefix is rejected loudly
        let (short, _, _) = build_shared_prefill(&policy, l, kv, hd, &k, &v, t1).unwrap();
        assert!(hot.promote_prefix(std::sync::Arc::new(short)).is_err());
    }

    #[test]
    fn roundtrip_contents_match_prune_reference() {
        let (l, kv, hd, t) = (1, 1, 64, 96);
        let k = rand_heads(1, t, hd, 10);
        let v = rand_heads(1, t, hd, 11);
        let mut seq = SequenceKV::new(KvPolicy::mustafar(0.5, 0.0), l, kv, hd).unwrap();
        seq.ingest_prefill(&k, &v, t, None).unwrap();
        let h = seq.head(0, 0);
        // first 64 tokens compressed, pruned to kk=32, stored as f16
        let want =
            f16::f16_round_vec(&crate::prune::per_token_magnitude(&k[0][..64 * hd], 64, hd, 32));
        assert_eq!(h.k_comp.decompress(), want);
        // value method None -> v stored exactly (up to the f16 narrowing)
        assert_eq!(h.v_comp.decompress(), f16::f16_round_vec(&v[0][..64 * hd]));
    }

    /// Drive two identical sequences — one synchronous, one deferred —
    /// through the same append stream, harvesting + settling the
    /// deferred one's exited groups with `compress_group` (the worker-
    /// job body). Every head's compressed region and live tail must be
    /// byte-identical at every step: the bit-exactness the engine's
    /// settle-before-read schedule relies on.
    #[test]
    fn deferred_harvest_and_settle_is_bit_identical_to_sync() {
        let (l, kv, hd) = (2, 2, 32);
        let policy = KvPolicy::mustafar(0.6, 0.4);
        let mut sync = SequenceKV::new(policy, l, kv, hd).unwrap();
        let mut def = SequenceKV::new(policy, l, kv, hd).unwrap();
        def.set_deferred(true, 2).unwrap();

        let mut rng = Pcg32::seeded(90);
        for step in 0..3 * TAIL_CAP {
            for layer in 0..l {
                for h in 0..kv {
                    let kr: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
                    let vr: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
                    sync.append(layer, h, &kr, &vr);
                    def.append(layer, h, &kr, &vr);
                }
            }
            sync.commit_token().unwrap();
            def.commit_token().unwrap();

            // harvest + settle like the engine does between rounds
            while def.pending_groups() > 0 {
                let parts: Vec<(BitmapMatrix, BitmapMatrix)> = (0..l * kv)
                    .map(|idx| {
                        let (kr, vr) = def.pending_group_rows(idx, 0);
                        compress_group(&policy, hd, kr, vr).unwrap()
                    })
                    .collect();
                def.mark_harvested(1);
                def.settle_group(parts).unwrap();
            }

            assert_eq!(def.tokens, sync.tokens, "step {step}");
            assert_eq!(def.private_bytes(), sync.private_bytes(), "step {step}");
            for idx in 0..l * kv {
                let (a, b) = (&def.heads[idx], &sync.heads[idx]);
                assert_eq!(a.k_comp, b.k_comp, "K head {idx} step {step}");
                assert_eq!(a.v_comp, b.v_comp, "V head {idx} step {step}");
                assert_eq!(a.tail_k(), b.tail_k(), "tail K head {idx} step {step}");
                assert_eq!(a.tail_v(), b.tail_v(), "tail V head {idx} step {step}");
            }
        }
        assert!(sync.head(0, 0).k_comp.tokens >= 2 * TILE, "too few groups exercised");
        assert_eq!(def.take_stalls(), 0, "budget 2 with per-step settle must never stall");
    }

    /// With a full ring (budget exhausted, nothing harvested) the tail
    /// stalls: the oldest pending group is compressed synchronously in
    /// place. Order is preserved, the stall is counted, and after a
    /// final flush the layout equals the all-synchronous one exactly.
    #[test]
    fn deferred_ring_full_stalls_bit_exact_and_counts() {
        let (l, kv, hd) = (1, 1, 48);
        let policy = KvPolicy::mustafar(0.5, 0.5);
        let mut sync = SequenceKV::new(policy, l, kv, hd).unwrap();
        let mut def = SequenceKV::new(policy, l, kv, hd).unwrap();
        def.set_deferred(true, 1).unwrap();

        let mut rng = Pcg32::seeded(91);
        let steps = TAIL_CAP + 4 * TILE; // several group exits, never harvested
        for _ in 0..steps {
            let kr: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
            let vr: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
            sync.append(0, 0, &kr, &vr);
            def.append(0, 0, &kr, &vr);
            sync.commit_token().unwrap();
            def.commit_token().unwrap();
            // ring may buffer at most one exited group
            assert!(def.queued_groups() <= 1);
        }
        let stalls = def.take_stalls();
        assert!(stalls >= 3, "expected repeated ring-full stalls, got {stalls}");

        def.flush_queued().unwrap();
        assert_eq!(def.pending_groups(), 0);
        assert_eq!(def.head(0, 0).k_comp, sync.head(0, 0).k_comp);
        assert_eq!(def.head(0, 0).v_comp, sync.head(0, 0).v_comp);
        assert_eq!(def.head(0, 0).tail_k(), sync.head(0, 0).tail_k());
        assert_eq!(def.head(0, 0).tail_v(), sync.head(0, 0).tail_v());
    }

    /// Deferred commits are pure bookkeeping (no prune/pack work), a
    /// snapshot with queued groups is refused (it would restore to a
    /// layout the cold path never produces), and leaving deferred mode
    /// flushes back to the canonical synchronous layout.
    #[test]
    fn deferred_commit_is_bookkeeping_and_mode_exit_flushes() {
        let (l, kv, hd) = (1, 1, 32);
        let mut seq = SequenceKV::new(KvPolicy::mustafar(0.5, 0.5), l, kv, hd).unwrap();
        seq.set_deferred(true, 4).unwrap();
        let mut rng = Pcg32::seeded(92);
        for _ in 0..TAIL_CAP + TILE {
            let kr: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
            let vr: Vec<f32> = (0..hd).map(|_| rng.normal_f32()).collect();
            seq.append(0, 0, &kr, &vr);
            seq.commit_token().unwrap();
        }
        // two groups exited (TAIL_CAP + TILE appends) and stayed dense
        assert_eq!(seq.pending_groups(), 2);
        assert_eq!(seq.head(0, 0).k_comp.tokens, 0, "deferred commit must not compress");
        assert!(seq.shareable_snapshot().is_err(), "queued groups must refuse snapshot");

        seq.set_deferred(false, 0).unwrap();
        assert_eq!(seq.pending_groups(), 0);
        assert_eq!(seq.head(0, 0).k_comp.tokens, 2 * TILE);
        assert!(seq.shareable_snapshot().is_ok());
    }

    /// The thread-local widen/prune scratch is grown once and reused:
    /// repeated group compressions on one thread must hand back the same
    /// buffers (pointer-stable), which is the structural form of the
    /// "steady-state decode is allocation-free" guarantee.
    #[test]
    fn compress_scratch_is_reused_across_groups() {
        let elems = TILE * 64;
        let first = with_compress_scratch(elems, |kg, vg| (kg.as_ptr(), vg.as_ptr()));
        for _ in 0..8 {
            let again = with_compress_scratch(elems, |kg, vg| (kg.as_ptr(), vg.as_ptr()));
            assert_eq!(again, first, "scratch must be reused, not reallocated");
        }
        // smaller requests share the same allocation
        let small = with_compress_scratch(elems / 2, |kg, vg| (kg.as_ptr(), vg.as_ptr()));
        assert_eq!(small, first);
    }
}
