//! Prometheus text-format exposition (version 0.0.4) of the telemetry
//! registry plus the engine's scalar stats.
//!
//! Scalars render as single `mustafar_<name> <value>` samples — the
//! same name/value pairs the `{"stats"}` line reports, so the two
//! surfaces cannot drift (server_e2e asserts the containment). Each
//! histogram renders the classic `_bucket{le="..."}` cumulative series
//! plus `_sum`/`_count`, and — because log₂ buckets make client-side
//! quantile math lossy — explicit `_p50`/`_p99`/`_p999` gauge samples
//! computed server-side from the exact same buckets.

use std::fmt::Write as _;

use super::hist::{bucket_le, Hist, BUCKETS};

/// Every metric name is prefixed with this.
pub const PREFIX: &str = "mustafar_";

/// Format a sample value the way Prometheus expects: integers without
/// a decimal point, everything else as shortest-roundtrip f64.
fn fmt_num(out: &mut String, x: f64) {
    if x.fract() == 0.0 && x.abs() < 9.0e15 {
        let _ = write!(out, "{}", x as i64);
    } else {
        let _ = write!(out, "{x}");
    }
}

/// Render scalars (counters/gauges) and histograms into one exposition
/// body. Iteration order is the caller's, so output is deterministic.
pub fn render(scalars: &[(&str, f64)], hists: &[(&str, Hist)]) -> String {
    let mut out = String::new();
    for (name, v) in scalars {
        let _ = write!(out, "{PREFIX}{name} ");
        fmt_num(&mut out, *v);
        out.push('\n');
    }
    for (name, h) in hists {
        let _ = writeln!(out, "# TYPE {PREFIX}{name} histogram");
        // collapse trailing empty buckets: emit up to the last nonempty
        // bucket, then +Inf
        let last = (0..BUCKETS).rev().find(|&i| h.buckets()[i] > 0);
        let mut cum = 0u64;
        if let Some(last) = last {
            for i in 0..=last {
                cum += h.buckets()[i];
                let _ = writeln!(out, "{PREFIX}{name}_bucket{{le=\"{}\"}} {cum}", bucket_le(i));
            }
        }
        let _ = writeln!(out, "{PREFIX}{name}_bucket{{le=\"+Inf\"}} {}", h.count());
        let _ = write!(out, "{PREFIX}{name}_sum ");
        fmt_num(&mut out, h.sum());
        out.push('\n');
        let _ = writeln!(out, "{PREFIX}{name}_count {}", h.count());
        for (suffix, q) in [("p50", 0.50), ("p99", 0.99), ("p999", 0.999)] {
            let _ = write!(out, "{PREFIX}{name}_{suffix} ");
            fmt_num(&mut out, h.quantile(q));
            out.push('\n');
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars_render_one_line_each() {
        let text = render(&[("completions", 3.0), ("tokens_per_sec", 12.5)], &[]);
        assert!(text.contains("mustafar_completions 3\n"));
        assert!(text.contains("mustafar_tokens_per_sec 12.5\n"));
    }

    #[test]
    fn histogram_series_is_cumulative_and_closed() {
        let mut h = Hist::new();
        for v in [1u64, 2, 3, 100] {
            h.record(v);
        }
        let text = render(&[], &[("ttft_us", h)]);
        assert!(text.contains("# TYPE mustafar_ttft_us histogram"));
        assert!(text.contains("mustafar_ttft_us_bucket{le=\"+Inf\"} 4\n"));
        assert!(text.contains("mustafar_ttft_us_count 4\n"));
        assert!(text.contains("mustafar_ttft_us_sum 106\n"));
        assert!(text.contains("mustafar_ttft_us_p50 "));
        assert!(text.contains("mustafar_ttft_us_p999 "));
        // cumulative counts never decrease along the le series
        let mut prev = 0u64;
        for line in text.lines().filter(|l| l.contains("_bucket{le=")) {
            let n: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(n >= prev, "non-monotone bucket series: {line}");
            prev = n;
        }
        assert_eq!(prev, 4);
    }

    #[test]
    fn empty_histogram_still_renders_closed_series() {
        let text = render(&[], &[("queue_wait_us", Hist::new())]);
        assert!(text.contains("mustafar_queue_wait_us_bucket{le=\"+Inf\"} 0\n"));
        assert!(text.contains("mustafar_queue_wait_us_count 0\n"));
        assert!(text.contains("mustafar_queue_wait_us_sum 0\n"));
    }
}
