//! Fixed-bucket log₂ histograms: a plain single-writer variant for
//! engine-thread metrics and a sharded atomic variant for the
//! cross-thread registry.
//!
//! Values are non-negative integers (microseconds, bytes, counts).
//! Bucket 0 holds exact zeros; bucket `i ≥ 1` holds `[2^(i-1), 2^i)`.
//! 48 buckets cover everything up to 2^47 (~140 TB as bytes, ~4.5 years
//! as microseconds); the last bucket absorbs any larger tail. Recording
//! is O(1) and allocation-free; quantiles interpolate linearly inside
//! the containing bucket and clamp to the exact observed min/max, so
//! p50/p99 are never wrong by more than one power of two and the
//! extremes are exact.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use crate::util::Summary;

/// Number of log₂ buckets; index 0 is the exact-zero bucket.
pub const BUCKETS: usize = 48;

/// Bucket index for a value: 0 for 0, else `64 - leading_zeros`,
/// clamped so the largest bucket absorbs the tail.
#[inline]
pub fn bucket_of(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        ((64 - v.leading_zeros()) as usize).min(BUCKETS - 1)
    }
}

/// Inclusive upper bound of bucket `i` (the Prometheus `le` label):
/// bucket 0 → 0, bucket `i` → 2^i − 1.
pub fn bucket_le(i: usize) -> u64 {
    if i == 0 {
        0
    } else if i >= 63 {
        u64::MAX
    } else {
        (1u64 << i) - 1
    }
}

/// Exclusive lower / upper value bounds of bucket `i`, as f64, for
/// interpolation and midpoint estimates.
fn bucket_span(i: usize) -> (f64, f64) {
    if i == 0 {
        (0.0, 0.0)
    } else {
        ((1u64 << (i - 1)) as f64, (1u64 << (i - 1)) as f64 * 2.0)
    }
}

/// Single-writer histogram. Lives inside engine-thread state
/// ([`crate::coordinator::Metrics`]) and as the merged snapshot form of
/// [`AtomicHist`].
#[derive(Clone, Debug)]
pub struct Hist {
    buckets: [u64; BUCKETS],
    count: u64,
    sum: f64,
    sumsq: f64,
    min: u64,
    max: u64,
}

impl Default for Hist {
    fn default() -> Self {
        Self::new()
    }
}

impl Hist {
    pub fn new() -> Self {
        Hist { buckets: [0; BUCKETS], count: 0, sum: 0.0, sumsq: 0.0, min: u64::MAX, max: 0 }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[bucket_of(v)] += 1;
        self.count += 1;
        let vf = v as f64;
        self.sum += vf;
        self.sumsq += vf * vf;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact observed minimum (0 when empty).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Exact observed maximum (0 when empty).
    pub fn max(&self) -> u64 {
        self.max
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn buckets(&self) -> &[u64; BUCKETS] {
        &self.buckets
    }

    /// q-quantile (q ∈ [0, 1]) via cumulative bucket walk with linear
    /// interpolation inside the containing bucket, clamped to the exact
    /// observed [min, max]. 0.0 when empty.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        if q <= 0.0 {
            return self.min as f64;
        }
        if q >= 1.0 {
            return self.max as f64;
        }
        let rank = (q * self.count as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if cum + c >= rank {
                let (lo, hi) = bucket_span(i);
                let frac = (rank - cum) as f64 / c as f64;
                let est = lo + (hi - lo) * frac;
                return est.clamp(self.min as f64, self.max as f64);
            }
            cum += c;
        }
        self.max as f64
    }

    pub fn merge(&mut self, other: &Hist) {
        for i in 0..BUCKETS {
            self.buckets[i] += other.buckets[i];
        }
        self.count += other.count;
        self.sum += other.sum;
        self.sumsq += other.sumsq;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    /// Reconstruct a [`Summary`] (exact n/mean/std/min/max, interpolated
    /// percentiles), with values scaled by `scale` — e.g. record µs,
    /// summarize ms with `scale = 1e-3`. `None` when empty.
    pub fn summary(&self, scale: f64) -> Option<Summary> {
        if self.count == 0 {
            return None;
        }
        let n = self.count as f64;
        let mean = self.sum / n;
        let var = (self.sumsq / n - mean * mean).max(0.0);
        Some(Summary {
            n: self.count as usize,
            mean: mean * scale,
            std: var.sqrt() * scale,
            min: self.min as f64 * scale,
            p10: self.quantile(0.10) * scale,
            p50: self.quantile(0.50) * scale,
            p90: self.quantile(0.90) * scale,
            p95: self.quantile(0.95) * scale,
            max: self.max as f64 * scale,
        })
    }
}

// --- sharded atomic histogram -------------------------------------------

/// Shard count for [`AtomicHist`]. Eight shards keep contention
/// negligible for the thread counts we run (workers + reactors ≤ ~16)
/// while a snapshot merge stays trivially cheap.
const SHARDS: usize = 8;

/// One cache-line-aligned shard so two threads recording into adjacent
/// shards never false-share.
#[repr(align(64))]
#[derive(Debug)]
struct Shard {
    buckets: [AtomicU64; BUCKETS],
    count: AtomicU64,
    sum: AtomicU64,
    min: AtomicU64,
    max: AtomicU64,
}

impl Shard {
    fn new() -> Self {
        Shard {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            min: AtomicU64::new(u64::MAX),
            max: AtomicU64::new(0),
        }
    }
}

/// Per-thread shard assignment: threads round-robin onto shards on
/// first record, then stick, so a hot thread always hits the same cache
/// line and never contends with the other shards.
fn shard_index() -> usize {
    static NEXT: AtomicUsize = AtomicUsize::new(0);
    thread_local! {
        static IDX: std::cell::Cell<usize> = const { std::cell::Cell::new(usize::MAX) };
    }
    IDX.with(|c| {
        let mut i = c.get();
        if i == usize::MAX {
            i = NEXT.fetch_add(1, Ordering::Relaxed) % SHARDS;
            c.set(i);
        }
        i
    })
}

/// Sharded multi-writer histogram: `record` is a handful of relaxed
/// atomic ops on the caller's own shard (O(1), no allocation, no lock);
/// `snapshot` merges the shards into a plain [`Hist`] on the reader's
/// side. A snapshot racing concurrent writers can miss records that are
/// mid-flight — fine for monitoring, and each shard's own fields are
/// only ever off by those in-flight records.
#[derive(Debug)]
pub struct AtomicHist {
    shards: Vec<Shard>,
}

impl Default for AtomicHist {
    fn default() -> Self {
        Self::new()
    }
}

impl AtomicHist {
    pub fn new() -> Self {
        AtomicHist { shards: (0..SHARDS).map(|_| Shard::new()).collect() }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        let s = &self.shards[shard_index()];
        s.buckets[bucket_of(v)].fetch_add(1, Ordering::Relaxed);
        s.count.fetch_add(1, Ordering::Relaxed);
        s.sum.fetch_add(v, Ordering::Relaxed);
        s.min.fetch_min(v, Ordering::Relaxed);
        s.max.fetch_max(v, Ordering::Relaxed);
    }

    /// Merge all shards into a plain [`Hist`]. The sum-of-squares (used
    /// only for the std in summaries) is reconstructed from bucket
    /// midpoints since squares of µs-scale sums would overflow a u64
    /// counter.
    pub fn snapshot(&self) -> Hist {
        let mut h = Hist::new();
        for s in &self.shards {
            let c = s.count.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            for i in 0..BUCKETS {
                h.buckets[i] += s.buckets[i].load(Ordering::Relaxed);
            }
            h.count += c;
            h.sum += s.sum.load(Ordering::Relaxed) as f64;
            h.min = h.min.min(s.min.load(Ordering::Relaxed));
            h.max = h.max.max(s.max.load(Ordering::Relaxed));
        }
        for i in 0..BUCKETS {
            let (lo, hi) = bucket_span(i);
            let mid = (lo + hi) * 0.5;
            h.sumsq += h.buckets[i] as f64 * mid * mid;
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_of(0), 0);
        assert_eq!(bucket_of(1), 1);
        assert_eq!(bucket_of(2), 2);
        assert_eq!(bucket_of(3), 2);
        assert_eq!(bucket_of(4), 3);
        assert_eq!(bucket_of(u64::MAX), BUCKETS - 1);
        assert_eq!(bucket_le(0), 0);
        assert_eq!(bucket_le(1), 1);
        assert_eq!(bucket_le(2), 3);
        // every value lands in the bucket whose le covers it
        for v in [0u64, 1, 2, 3, 5, 100, 1 << 20, (1 << 40) + 7] {
            assert!(v <= bucket_le(bucket_of(v)), "v={v}");
        }
    }

    #[test]
    fn record_and_quantiles() {
        let mut h = Hist::new();
        for v in 1..=1000u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 1000);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 1000);
        assert!((h.mean() - 500.5).abs() < 1e-9);
        // log2 buckets: quantiles are right to within one power of two
        let p50 = h.quantile(0.5);
        assert!((250.0..=1000.0).contains(&p50), "p50={p50}");
        let p99 = h.quantile(0.99);
        assert!((500.0..=1000.0).contains(&p99), "p99={p99}");
        // extremes are exact
        assert_eq!(h.quantile(0.0), 1.0);
        assert_eq!(h.quantile(1.0), 1000.0);
        // monotone in q
        let qs = [0.0, 0.1, 0.5, 0.9, 0.99, 0.999, 1.0];
        for w in qs.windows(2) {
            assert!(h.quantile(w[0]) <= h.quantile(w[1]));
        }
    }

    #[test]
    fn empty_hist_is_inert() {
        let h = Hist::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), 0.0);
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), 0);
        assert!(h.summary(1.0).is_none());
    }

    #[test]
    fn merge_matches_sequential() {
        let mut a = Hist::new();
        let mut b = Hist::new();
        let mut all = Hist::new();
        for v in [3u64, 9, 17, 100, 0] {
            a.record(v);
            all.record(v);
        }
        for v in [5u64, 5000, 2] {
            b.record(v);
            all.record(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), all.count());
        assert_eq!(a.min(), all.min());
        assert_eq!(a.max(), all.max());
        assert_eq!(a.buckets(), all.buckets());
        assert!((a.sum() - all.sum()).abs() < 1e-9);
    }

    #[test]
    fn summary_reconstructs_exact_moments() {
        let mut h = Hist::new();
        for v in [10u64, 20, 30, 40] {
            h.record(v);
        }
        let s = h.summary(1.0).unwrap();
        assert_eq!(s.n, 4);
        assert!((s.mean - 25.0).abs() < 1e-9);
        assert!((s.min - 10.0).abs() < 1e-9);
        assert!((s.max - 40.0).abs() < 1e-9);
        // std of {10,20,30,40} (population) = sqrt(125)
        assert!((s.std - 125f64.sqrt()).abs() < 1e-6);
        // scale applies everywhere
        let ms = h.summary(1e-3).unwrap();
        assert!((ms.mean - 0.025).abs() < 1e-12);
        assert!((ms.max - 0.040).abs() < 1e-12);
    }

    #[test]
    fn atomic_hist_merges_across_threads() {
        let h = std::sync::Arc::new(AtomicHist::new());
        let mut handles = Vec::new();
        for t in 0..4u64 {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..1000u64 {
                    h.record(t * 1000 + i);
                }
            }));
        }
        for jh in handles {
            jh.join().unwrap();
        }
        let snap = h.snapshot();
        assert_eq!(snap.count(), 4000);
        assert_eq!(snap.min(), 0);
        assert_eq!(snap.max(), 3999);
        assert_eq!(snap.buckets().iter().sum::<u64>(), 4000);
    }
}
