//! Flight recorder: a bounded ring of recent lifecycle, fault, and
//! pressure-ladder events, owned by the engine thread.
//!
//! Two design rules keep it cheap and reproducible:
//!
//! - **Lock-free by ownership.** Events are recorded only on the engine
//!   thread (worker-side faults are folded in at step end by diffing
//!   the injector's tallies), so there is no lock at all — "lock-cheap"
//!   by construction.
//! - **Deterministic by content.** Events carry a monotone sequence
//!   number, a kind, and two integer payloads — never a wall-clock
//!   timestamp or duration. Two runs of the same pinned-seed chaos
//!   trace therefore dump byte-identical event sequences, which the
//!   telemetry test suite asserts.
//!
//! The ring dumps automatically (once, to stderr) the first time a
//! panic is isolated or a chaos fault fires, and on demand via the
//! server's `{"dump"}` line.

use std::collections::VecDeque;

use crate::fmt::Json;

/// One recorded event. `a`/`b` are kind-specific integer payloads
/// (typically request id / token count / byte count).
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub seq: u64,
    pub kind: String,
    pub a: u64,
    pub b: u64,
}

#[derive(Debug)]
pub struct FlightRecorder {
    ring: VecDeque<Event>,
    cap: usize,
    next_seq: u64,
    dropped: u64,
    /// Auto-dump latch: the first trigger dumps, later ones only count.
    auto_dumped: bool,
    suppressed_dumps: u64,
}

impl FlightRecorder {
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        FlightRecorder {
            ring: VecDeque::with_capacity(cap.min(1024)),
            cap,
            next_seq: 0,
            dropped: 0,
            auto_dumped: false,
            suppressed_dumps: 0,
        }
    }

    /// Record an event with a static kind (the common case).
    pub fn note(&mut self, kind: &str, a: u64, b: u64) {
        self.note_owned(kind.to_string(), a, b);
    }

    /// Record an event with an already-built kind string (fault names).
    pub fn note_owned(&mut self, kind: String, a: u64, b: u64) {
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(Event { seq: self.next_seq, kind, a, b });
        self.next_seq += 1;
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// Retained events, oldest first (for tests and determinism checks).
    pub fn events(&self) -> impl Iterator<Item = &Event> {
        self.ring.iter()
    }

    /// Render the retained ring as one JSON object.
    pub fn dump_json(&self) -> Json {
        let events: Vec<Json> = self
            .ring
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("seq", Json::num(e.seq as f64)),
                    ("kind", Json::str(e.kind.as_str())),
                    ("a", Json::num(e.a as f64)),
                    ("b", Json::num(e.b as f64)),
                ])
            })
            .collect();
        Json::obj(vec![
            ("events", Json::arr(events)),
            ("dropped", Json::num(self.dropped as f64)),
            ("suppressed_dumps", Json::num(self.suppressed_dumps as f64)),
        ])
    }

    /// Auto-dump trigger: the first call writes the whole ring to
    /// stderr tagged with `reason`; every later call is only counted
    /// (`suppressed_dumps`), so a fault storm cannot flood the log.
    pub fn trigger_auto_dump(&mut self, reason: &str) {
        if self.auto_dumped {
            self.suppressed_dumps += 1;
            return;
        }
        self.auto_dumped = true;
        eprintln!("mustafar flight-recorder auto-dump ({reason}): {}", self.dump_json().to_string());
    }

    /// Whether the auto-dump latch has fired (for tests).
    pub fn auto_dumped(&self) -> bool {
        self.auto_dumped
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_bounds_and_seq_monotone() {
        let mut r = FlightRecorder::new(4);
        for i in 0..10u64 {
            r.note("finish", i, 0);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.dropped(), 6);
        let seqs: Vec<u64> = r.events().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![6, 7, 8, 9]);
    }

    #[test]
    fn dump_json_parses_back() {
        let mut r = FlightRecorder::new(8);
        r.note("admit", 3, 128);
        r.note_owned("fault:kvpool.alloc".to_string(), 1, 0);
        let line = r.dump_json().to_string();
        let v = Json::parse(&line).unwrap();
        let ev = v.get("events").unwrap().as_arr().unwrap();
        assert_eq!(ev.len(), 2);
        assert_eq!(ev[0].get("kind").unwrap().as_str().unwrap(), "admit");
        assert_eq!(ev[0].get("a").unwrap().as_usize().unwrap(), 3);
        assert_eq!(ev[1].get("kind").unwrap().as_str().unwrap(), "fault:kvpool.alloc");
        assert_eq!(v.get("dropped").unwrap().as_usize().unwrap(), 0);
    }

    #[test]
    fn auto_dump_latches_once() {
        let mut r = FlightRecorder::new(8);
        r.note("decode_panic", 1, 0);
        assert!(!r.auto_dumped());
        r.trigger_auto_dump("panic isolated");
        assert!(r.auto_dumped());
        r.trigger_auto_dump("fault fired");
        r.trigger_auto_dump("fault fired");
        let v = r.dump_json();
        assert_eq!(v.get("suppressed_dumps").unwrap().as_usize().unwrap(), 2);
    }
}
