//! Telemetry: the observability layer for the serving stack.
//!
//! Four pieces, each in its own submodule:
//!
//! - [`hist`] — fixed-bucket log₂ histograms: a plain single-writer
//!   form for engine-thread metrics and a sharded atomic form
//!   ([`AtomicHist`]) whose `record` is O(1), allocation-free, and
//!   lock-free, merged across worker/reactor threads only on read.
//! - [`spans`] — per-request trace spans (submit → queued → prefill →
//!   decode → finish) kept in a bounded ring and rendered as
//!   chrome://tracing JSON for `{"trace": n}` / `--trace-out`.
//! - [`recorder`] — the flight recorder: a deterministic bounded ring
//!   of lifecycle/fault/pressure events, auto-dumped on the first
//!   isolated panic or chaos-fault fire and on demand via `{"dump"}`.
//! - [`prometheus`] — text exposition of the whole registry for the
//!   `{"metrics"}` line and the optional `--metrics-addr` listener.
//!
//! The [`Telemetry`] registry itself is the shared, thread-safe handle
//! (`Arc<Telemetry>`): the engine, worker pool, kv pool, and reactors
//! all record into it. When built disabled (`--no-telemetry`), every
//! hot-path site skips recording behind one branch on
//! [`Telemetry::on`], which is how the overhead bench measures the
//! instrumentation's cost honestly.

pub mod hist;
pub mod prometheus;
pub mod recorder;
pub mod spans;

pub use hist::{AtomicHist, Hist};
pub use recorder::{Event, FlightRecorder};
pub use spans::{Span, SpanRing};

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

/// Monotonically increasing counter (relaxed atomics; exactness across
/// a concurrent read is not required for monitoring).
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-write-wins gauge.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// The shared metrics registry. Histogram fields are recorded straight
/// into from any thread; call sites gate on [`Telemetry::on`] so a
/// disabled registry costs one predictable branch.
#[derive(Debug)]
pub struct Telemetry {
    enabled: bool,
    /// Engine-creation epoch: trace-span timestamps are µs since this.
    pub epoch: Instant,
    /// Time to first token (queue wait + prefill), µs.
    pub ttft_us: AtomicHist,
    /// Decode-round wall time ÷ 1 per token landed that round, µs.
    pub inter_token_us: AtomicHist,
    /// Submit → admission, µs.
    pub queue_wait_us: AtomicHist,
    /// Prefill wall time per admitted request, µs.
    pub prefill_us: AtomicHist,
    /// One full decode round (batched forward + retire), µs.
    pub decode_round_us: AtomicHist,
    /// One pressure-ladder re-prune (prune + re-compress), µs.
    pub prune_us: AtomicHist,
    /// Live pool bytes sampled once per engine step.
    pub pool_occupancy_bytes: AtomicHist,
    /// Reactor per-connection pending-write depth sampled per reply.
    pub write_queue_depth: AtomicHist,
    /// Worker-pool job wall time, µs (recorded on worker threads —
    /// the cross-thread shard-merge path).
    pub worker_task_us: AtomicHist,
    /// One chunked-prefill chunk (decode-path forward over ≤
    /// `prefill_chunk_tokens` prompt tokens + reservation settle), µs.
    pub prefill_chunk_us: AtomicHist,
    /// One deferred per-head group-compression job (widen → prune →
    /// bitmap-pack of a 64-token group), µs — recorded on the worker
    /// threads, like `worker_task_us`.
    pub compress_us: AtomicHist,
    /// Observability-surface traffic.
    pub trace_queries: Counter,
    pub dump_queries: Counter,
    pub metrics_queries: Counter,
    /// Prefill chunks executed (all sequences; a run-to-completion
    /// prefill counts as one chunk).
    pub prefill_chunks: Counter,
    /// Mid-prefill sequences bounced back to the queue (pool-pressure
    /// requeue or preemption before their first token landed).
    pub prefill_preempted: Counter,
    /// Tokens granted to prefill chunks by the round planner last step
    /// (0 when the budget is disabled or nothing was mid-prefill).
    pub round_budget_tokens: Gauge,
    /// Deferred per-head compression jobs submitted to the worker pool.
    pub compress_jobs: Counter,
    /// Ring-full backpressure stalls: commits forced to compress a
    /// group synchronously because the in-flight-group budget was
    /// exhausted (0 in healthy operation).
    pub compress_stalls: Counter,
    /// Exited groups awaiting compression (pending + in flight) sampled
    /// once per engine step.
    pub compress_backlog: Gauge,
}

impl Telemetry {
    pub fn new(enabled: bool) -> Self {
        Telemetry {
            enabled,
            epoch: Instant::now(),
            ttft_us: AtomicHist::new(),
            inter_token_us: AtomicHist::new(),
            queue_wait_us: AtomicHist::new(),
            prefill_us: AtomicHist::new(),
            decode_round_us: AtomicHist::new(),
            prune_us: AtomicHist::new(),
            pool_occupancy_bytes: AtomicHist::new(),
            write_queue_depth: AtomicHist::new(),
            worker_task_us: AtomicHist::new(),
            prefill_chunk_us: AtomicHist::new(),
            compress_us: AtomicHist::new(),
            trace_queries: Counter::default(),
            dump_queries: Counter::default(),
            metrics_queries: Counter::default(),
            prefill_chunks: Counter::default(),
            prefill_preempted: Counter::default(),
            round_budget_tokens: Gauge::default(),
            compress_jobs: Counter::default(),
            compress_stalls: Counter::default(),
            compress_backlog: Gauge::default(),
        }
    }

    /// Whether recording is on. Hot paths check this once and skip all
    /// timestamping/recording work when it is off.
    #[inline]
    pub fn on(&self) -> bool {
        self.enabled
    }

    /// µs since the engine epoch (span timestamps).
    pub fn now_us(&self) -> u64 {
        self.epoch.elapsed().as_micros() as u64
    }

    /// Snapshots of every histogram, in stable exposition order.
    pub fn hist_snapshots(&self) -> Vec<(&'static str, Hist)> {
        vec![
            ("ttft_us", self.ttft_us.snapshot()),
            ("inter_token_us", self.inter_token_us.snapshot()),
            ("queue_wait_us", self.queue_wait_us.snapshot()),
            ("prefill_us", self.prefill_us.snapshot()),
            ("decode_round_us", self.decode_round_us.snapshot()),
            ("prune_us", self.prune_us.snapshot()),
            ("pool_occupancy_bytes", self.pool_occupancy_bytes.snapshot()),
            ("write_queue_depth", self.write_queue_depth.snapshot()),
            ("worker_task_us", self.worker_task_us.snapshot()),
            ("prefill_chunk_us", self.prefill_chunk_us.snapshot()),
            ("compress_us", self.compress_us.snapshot()),
        ]
    }

    /// The p50/p99/p999 latency quantiles `{"stats"}` reports, in ms.
    /// Always present (0.0 before any sample) so dashboards and the
    /// exposition-containment test see a stable key set.
    pub fn quantile_fields(&self) -> Vec<(&'static str, f64)> {
        let mut out = Vec::with_capacity(9);
        for (name_p50, name_p99, name_p999, h) in [
            ("ttft_ms_p50", "ttft_ms_p99", "ttft_ms_p999", &self.ttft_us),
            (
                "inter_token_ms_p50",
                "inter_token_ms_p99",
                "inter_token_ms_p999",
                &self.inter_token_us,
            ),
            (
                "queue_wait_ms_p50",
                "queue_wait_ms_p99",
                "queue_wait_ms_p999",
                &self.queue_wait_us,
            ),
        ] {
            let snap = h.snapshot();
            out.push((name_p50, snap.quantile(0.50) * 1e-3));
            out.push((name_p99, snap.quantile(0.99) * 1e-3));
            out.push((name_p999, snap.quantile(0.999) * 1e-3));
        }
        out
    }
}

/// Duration → whole microseconds (saturating; 2^64 µs ≫ any run).
#[inline]
pub fn us(d: std::time::Duration) -> u64 {
    d.as_micros() as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disabled_registry_is_just_a_flag() {
        let t = Telemetry::new(false);
        assert!(!t.on());
        // recording is the call site's choice; the registry still works
        t.ttft_us.record(5);
        assert_eq!(t.ttft_us.snapshot().count(), 1);
    }

    #[test]
    fn quantile_fields_cover_the_three_latency_families() {
        let t = Telemetry::new(true);
        let names: Vec<&str> = t.quantile_fields().iter().map(|&(n, _)| n).collect();
        for fam in ["ttft_ms", "inter_token_ms", "queue_wait_ms"] {
            for q in ["p50", "p99", "p999"] {
                assert!(names.contains(&format!("{fam}_{q}").as_str()), "{fam}_{q}");
            }
        }
        // empty hists read 0.0, not NaN
        assert!(t.quantile_fields().iter().all(|&(_, v)| v == 0.0));
        // µs → ms scaling
        for _ in 0..100 {
            t.ttft_us.record(4000);
        }
        let q: Vec<(&str, f64)> = t.quantile_fields();
        let p50 = q.iter().find(|&&(n, _)| n == "ttft_ms_p50").unwrap().1;
        assert!((p50 - 4.0).abs() < 0.01, "p50={p50}");
    }

    #[test]
    fn counters_and_gauges() {
        let c = Counter::default();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::default();
        g.set(7);
        g.set(3);
        assert_eq!(g.get(), 3);
    }
}
