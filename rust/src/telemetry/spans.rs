//! Per-request trace spans, retained in a bounded ring and emitted as
//! chrome://tracing-compatible JSON (load the output of `{"trace": n}`
//! or `--trace-out` straight into `chrome://tracing` / Perfetto).
//!
//! Spans use the "X" (complete) event phase: one record per span with a
//! start timestamp and duration, both in microseconds relative to the
//! engine-creation epoch. The `pid` is always 1 (one engine); the `tid`
//! lane is the request's client route, so every request from one
//! connection renders on one row and the engine-wide decode-round spans
//! render on row 0.

use std::collections::VecDeque;

use crate::fmt::Json;

#[derive(Clone, Debug)]
pub struct Span {
    pub name: &'static str,
    /// chrome://tracing thread lane (we use the client route; 0 for
    /// engine-wide spans).
    pub tid: u64,
    /// Start, µs since the engine-creation epoch.
    pub ts_us: u64,
    pub dur_us: u64,
    /// Extra key/values rendered into the event's `args` object.
    pub args: Vec<(&'static str, u64)>,
}

/// Bounded ring of recent spans. Owned by the engine thread (recording
/// is single-writer and lock-free); readers receive rendered JSON.
#[derive(Debug)]
pub struct SpanRing {
    ring: VecDeque<Span>,
    cap: usize,
    dropped: u64,
}

impl SpanRing {
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        SpanRing { ring: VecDeque::with_capacity(cap.min(1024)), cap, dropped: 0 }
    }

    pub fn push(&mut self, s: Span) {
        if self.ring.len() == self.cap {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(s);
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }

    /// Spans evicted by the ring since startup.
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    /// chrome://tracing JSON object holding the most recent `n` spans
    /// (`n == 0` → everything retained).
    pub fn chrome_json(&self, n: usize) -> Json {
        let take = if n == 0 { self.ring.len() } else { n.min(self.ring.len()) };
        let skip = self.ring.len() - take;
        let events: Vec<Json> = self.ring.iter().skip(skip).map(span_json).collect();
        Json::obj(vec![
            ("traceEvents", Json::arr(events)),
            ("displayTimeUnit", Json::str("ms")),
            ("droppedSpans", Json::num(self.dropped as f64)),
        ])
    }
}

fn span_json(s: &Span) -> Json {
    let args: Vec<(&str, Json)> =
        s.args.iter().map(|&(k, v)| (k, Json::num(v as f64))).collect();
    Json::obj(vec![
        ("name", Json::str(s.name)),
        ("ph", Json::str("X")),
        ("ts", Json::num(s.ts_us as f64)),
        ("dur", Json::num(s.dur_us as f64)),
        ("pid", Json::num(1.0)),
        ("tid", Json::num(s.tid as f64)),
        ("args", Json::obj(args)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_bounds_and_drops() {
        let mut r = SpanRing::new(3);
        for i in 0..5u64 {
            r.push(Span { name: "s", tid: 1, ts_us: i, dur_us: 1, args: vec![] });
        }
        assert_eq!(r.len(), 3);
        assert_eq!(r.dropped(), 2);
        let j = r.chrome_json(0);
        let ev = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(ev.len(), 3);
        // oldest retained span is ts=2
        assert_eq!(ev[0].get("ts").unwrap().as_f64().unwrap(), 2.0);
    }

    #[test]
    fn chrome_json_schema() {
        let mut r = SpanRing::new(8);
        r.push(Span {
            name: "request",
            tid: 7,
            ts_us: 100,
            dur_us: 50,
            args: vec![("id", 3), ("tokens", 8)],
        });
        let line = r.chrome_json(1).to_string();
        let v = Json::parse(&line).unwrap();
        let ev = &v.get("traceEvents").unwrap().as_arr().unwrap()[0];
        assert_eq!(ev.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(ev.get("name").unwrap().as_str().unwrap(), "request");
        assert_eq!(ev.get("pid").unwrap().as_usize().unwrap(), 1);
        assert_eq!(ev.get("tid").unwrap().as_usize().unwrap(), 7);
        assert_eq!(ev.get("ts").unwrap().as_usize().unwrap(), 100);
        assert_eq!(ev.get("dur").unwrap().as_usize().unwrap(), 50);
        assert_eq!(ev.get("args").unwrap().get("id").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn trace_n_takes_most_recent() {
        let mut r = SpanRing::new(16);
        for i in 0..10u64 {
            r.push(Span { name: "s", tid: 0, ts_us: i * 10, dur_us: 1, args: vec![] });
        }
        let ev_all = r.chrome_json(0);
        assert_eq!(ev_all.get("traceEvents").unwrap().as_arr().unwrap().len(), 10);
        let ev2 = r.chrome_json(2);
        let ev2 = ev2.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(ev2.len(), 2);
        assert_eq!(ev2[0].get("ts").unwrap().as_f64().unwrap(), 80.0);
        assert_eq!(ev2[1].get("ts").unwrap().as_f64().unwrap(), 90.0);
    }
}
