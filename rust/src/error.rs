//! Library-wide error type (hand-rolled Display — proc-macro derive
//! crates are not in the offline vendor set).

use std::fmt;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Debug)]
pub enum Error {
    Json(String),
    Config(String),
    Shape(String),
    Io(std::io::Error),
    Xla(String),
    Runtime(String),
    Engine(String),
    Invalid(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Json(s) => write!(f, "json: {s}"),
            Error::Config(s) => write!(f, "config: {s}"),
            Error::Shape(s) => write!(f, "shape: {s}"),
            Error::Io(e) => write!(f, "io: {e}"),
            Error::Xla(s) => write!(f, "xla: {s}"),
            Error::Runtime(s) => write!(f, "runtime: {s}"),
            Error::Engine(s) => write!(f, "engine: {s}"),
            Error::Invalid(s) => write!(f, "invalid argument: {s}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
