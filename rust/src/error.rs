//! Library-wide error type.

use thiserror::Error;

pub type Result<T> = std::result::Result<T, Error>;

#[derive(Error, Debug)]
pub enum Error {
    #[error("json: {0}")]
    Json(String),

    #[error("config: {0}")]
    Config(String),

    #[error("shape: {0}")]
    Shape(String),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    #[error("xla: {0}")]
    Xla(String),

    #[error("runtime: {0}")]
    Runtime(String),

    #[error("engine: {0}")]
    Engine(String),

    #[error("invalid argument: {0}")]
    Invalid(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}
