//! The readiness-polling reactor: every connection's reads, writes,
//! deadlines, and teardown run on a small fixed set of reactor threads
//! (no per-connection threads, no per-connection locks — each
//! connection is owned by exactly one reactor).
//!
//! Layout: reactor 0 runs on the `serve_listener_cfg` caller thread
//! and owns the nonblocking listener; accepted sockets are dealt
//! round-robin to all reactors over each reactor's control channel.
//! The engine thread routes completions back as `Control::Done`
//! messages addressed by `(reactor, token)` and nudges the target
//! reactor's [`Waker`] (a nonblocking socketpair registered in the
//! poll set) so a parked reactor wakes without busy-polling.
//!
//! Every per-connection resource is bounded:
//! - read buffer: at most `max_line_bytes` of an unterminated line is
//!   ever held; beyond that the line is discarded, one `error` line is
//!   answered, and the connection survives,
//! - write queue: completions buffer in userspace only up to
//!   `write_hwm_bytes`; past the high-water mark the connection is
//!   declared dead and torn down through the batched `AbortMany` path
//!   (a slow reader stalls only its own completions),
//! - time: a partial request line must complete within
//!   `read_deadline_ms` (slowloris defense — the clock starts at the
//!   first byte of the line and does *not* reset on later dribbled
//!   bytes), and a connection with nothing in flight closes after
//!   `idle_timeout_ms`,
//! - count: accepts beyond `max_conns` are shed with a
//!   `retry_after_ms` hint before the socket is closed.
//!
//! The `server.io` fault point fires inside the real read and write
//! paths here: a fire is treated exactly like the socket dying
//! (teardown, batched abort), so the chaos suite exercises the same
//! code a broken peer would.

use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

use super::poll::{self, Poller};
use super::{
    cancel_target, error_line, is_dump_json, is_metrics_json, is_stats_json, render_completion,
    request_from_json, trace_request_depth, ConnAddr, Inbound, ShutdownHandle,
};
use crate::config::ServerConfig;
use crate::coordinator::Completion;
use crate::faults::Injector;
use crate::fmt::Json;
use crate::telemetry::Telemetry;

/// Reserved poll tokens (connection tokens count up from zero and are
/// never reused, so the top of the space is safe to reserve).
const WAKE_TOKEN: u64 = u64::MAX;
const LISTEN_TOKEN: u64 = u64::MAX - 1;

/// Retry hint attached to capacity/drain sheds at the accept edge.
const SHED_RETRY_MS: u64 = 250;

/// How many 8 KiB read chunks one readiness event may consume before
/// yielding to the next connection (level-triggered poll re-reports).
const READ_CHUNKS_PER_EVENT: usize = 16;

/// Grace beyond `drain_deadline_ms` before a draining reactor
/// force-closes surviving connections: the engine needs a moment to
/// turn imposed deadlines into `timeout` completions and the reactor
/// a moment to flush them.
const DRAIN_FLUSH_GRACE_MS: u64 = 2_000;

/// Connection-level gauges surfaced through `{"stats": true}`.
#[derive(Default)]
pub(crate) struct Gauges {
    pub open_conns: AtomicUsize,
    pub conns_shed: AtomicU64,
    pub write_backpressure_closes: AtomicU64,
    pub idle_closes: AtomicU64,
    pub read_deadline_closes: AtomicU64,
    pub oversize_lines: AtomicU64,
    pub io_fault_closes: AtomicU64,
    /// 0 = serving, 1 = draining.
    pub drain_state: AtomicU64,
}

/// Cross-thread wakeup for a parked reactor: one byte down a
/// nonblocking socketpair whose read end sits in the poll set.
/// `WouldBlock` on write means a wake is already pending — exactly the
/// coalescing we want.
#[derive(Clone)]
pub(crate) struct Waker {
    tx: Arc<UnixStream>,
}

impl Waker {
    pub fn new(tx: UnixStream) -> Waker {
        Waker { tx: Arc::new(tx) }
    }

    /// Best-effort, amount deliberately ignored: a short/failed write
    /// means a wake is already pending (`WouldBlock`) or the reactor is
    /// gone — both are fine.
    #[allow(clippy::unused_io_amount)]
    pub fn wake(&self) {
        let _ = (&*self.tx).write(&[1u8]);
    }
}

/// Messages addressed to one reactor.
pub(crate) enum Control {
    /// A freshly accepted connection dealt to this reactor.
    Conn(TcpStream),
    /// A completion for `(token, completion)` from the engine thread.
    Done(u64, Completion),
    /// A pre-rendered reply line (stats) for `token`.
    Line(u64, String),
}

#[derive(Clone)]
pub(crate) struct ReactorHandle {
    pub ctl_tx: Sender<Control>,
    pub waker: Waker,
}

/// Reactor-owned per-connection state. No locks: the owning reactor
/// thread is the only reader and writer, which is what retires the old
/// registration-vs-abort race the thread-per-connection server needed
/// a critical section for.
struct Conn {
    stream: TcpStream,
    /// Bytes read but not yet consumed as complete lines (bounded by
    /// `max_line_bytes` + one read chunk).
    rbuf: Vec<u8>,
    /// Scan resume offset into `rbuf` (bytes before it hold no '\n').
    scan_from: usize,
    /// Swallowing the tail of an oversized line until its newline.
    discarding: bool,
    /// Rendered-but-unsent reply bytes (bounded by `write_hwm_bytes`).
    wbuf: Vec<u8>,
    /// Consumed prefix of `wbuf` (compacted opportunistically).
    wpos: usize,
    /// In-flight requests: client id -> engine routing key.
    inflight: HashMap<u64, u64>,
    /// Stats queries sent to the engine but not yet answered.
    pending_stats: usize,
    last_activity: Instant,
    /// Deadline for the current partial request line (slowloris
    /// defense); armed at the first byte of a line, cleared when the
    /// buffer empties, and *not* refreshed by dribbled bytes.
    line_deadline: Option<Instant>,
}

impl Conn {
    fn pending_out(&self) -> usize {
        self.wbuf.len() - self.wpos
    }
}

pub(crate) struct Reactor {
    idx: usize,
    cfg: ServerConfig,
    conns: HashMap<u64, Conn>,
    next_token: u64,
    ctl_rx: Receiver<Control>,
    wake_rx: UnixStream,
    engine_tx: Sender<Inbound>,
    gauges: Arc<Gauges>,
    next_route: Arc<AtomicU64>,
    faults: Injector,
    shutdown: ShutdownHandle,
    /// Engine-shared telemetry registry (per-connection write-queue
    /// depth is recorded here as reply lines queue).
    telemetry: Arc<Telemetry>,
    /// Every reactor's handle (self included) for round-robin dealing.
    handles: Vec<ReactorHandle>,
    /// Reactor 0 owns the listener; dropped when draining begins so
    /// the kernel refuses new connections during drain.
    listener: Option<TcpListener>,
    rr: usize,
    draining: bool,
    drain_started: Option<Instant>,
    poller: Poller,
}

impl Reactor {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        idx: usize,
        cfg: ServerConfig,
        ctl_rx: Receiver<Control>,
        wake_rx: UnixStream,
        engine_tx: Sender<Inbound>,
        gauges: Arc<Gauges>,
        next_route: Arc<AtomicU64>,
        faults: Injector,
        shutdown: ShutdownHandle,
        telemetry: Arc<Telemetry>,
        handles: Vec<ReactorHandle>,
    ) -> Reactor {
        Reactor {
            idx,
            cfg,
            conns: HashMap::new(),
            next_token: 0,
            ctl_rx,
            wake_rx,
            engine_tx,
            gauges,
            next_route,
            faults,
            shutdown,
            telemetry,
            handles,
            listener: None,
            rr: idx,
            draining: false,
            drain_started: None,
            poller: Poller::new(),
        }
    }

    pub fn set_listener(&mut self, l: TcpListener) {
        self.listener = Some(l);
    }

    /// The event loop. Returns once draining is complete (every owned
    /// connection closed); dropping `self` then drops this reactor's
    /// `engine_tx` clone, and the engine thread exits when the last
    /// reactor's clone is gone.
    pub fn run(mut self) {
        loop {
            if !self.draining && self.shutdown.is_shutdown() {
                self.begin_drain();
            }
            loop {
                match self.ctl_rx.try_recv() {
                    Ok(m) => self.handle_control(m),
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
                }
            }
            if self.draining {
                self.close_quiesced();
                if self.conns.is_empty() {
                    return;
                }
            }
            self.poller.clear();
            self.poller.register(self.wake_rx.as_raw_fd(), WAKE_TOKEN, true, false);
            if let Some(l) = &self.listener {
                self.poller.register(l.as_raw_fd(), LISTEN_TOKEN, true, false);
            }
            for (&tok, c) in &self.conns {
                self.poller.register(c.stream.as_raw_fd(), tok, true, c.pending_out() > 0);
            }
            let timeout = self.poll_timeout_ms();
            if self.poller.wait(timeout).is_err() {
                // poll(2) itself failing is unrecoverable for this
                // reactor: tear every connection down so the engine
                // releases their pages, then exit.
                let all: Vec<u64> = self.conns.keys().copied().collect();
                for tok in all {
                    self.teardown(tok);
                }
                return;
            }
            let events: Vec<poll::Event> = self.poller.events().collect();
            for ev in events {
                match ev.token {
                    WAKE_TOKEN => self.drain_wakes(),
                    LISTEN_TOKEN => self.accept_ready(),
                    tok => {
                        if ev.readable {
                            self.conn_readable(tok);
                        }
                        if ev.writable {
                            self.conn_writable(tok);
                        }
                    }
                }
            }
            self.sweep_deadlines();
        }
    }

    fn begin_drain(&mut self) {
        self.draining = true;
        self.drain_started = Some(Instant::now());
        self.gauges.drain_state.store(1, Ordering::Relaxed);
        // Closing the listener fd makes the kernel refuse new
        // connections for the rest of the drain.
        self.listener = None;
        // Idempotent on the engine side; every reactor announces so
        // the signal survives any one of them being wedged.
        let _ = self.engine_tx.send(Inbound::Drain);
    }

    /// During drain, close every connection with nothing left to say:
    /// no requests in flight, no pending stats reply, nothing buffered
    /// to write. Connections still owed an answer stay open until the
    /// engine finishes (or deadline-cancels) their requests and the
    /// reply bytes flush — or until the hard drain deadline.
    fn close_quiesced(&mut self) {
        let victims: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| c.inflight.is_empty() && c.pending_stats == 0 && c.pending_out() == 0)
            .map(|(&t, _)| t)
            .collect();
        for tok in victims {
            self.teardown(tok);
        }
    }

    fn drain_hard_ms(&self) -> u64 {
        self.cfg.drain_deadline_ms + DRAIN_FLUSH_GRACE_MS
    }

    /// Next poll timeout: the soonest per-connection deadline (line
    /// deadline, idle timeout) or the hard drain deadline, clamped to
    /// [0, 500] ms; block indefinitely only when there is truly
    /// nothing timed to watch.
    fn poll_timeout_ms(&self) -> i32 {
        let now = Instant::now();
        let mut next: Option<Instant> = None;
        let mut consider = |t: Instant| {
            next = Some(match next {
                Some(n) if n <= t => n,
                _ => t,
            });
        };
        let idle_ms = self.cfg.idle_timeout_ms;
        for c in self.conns.values() {
            if let Some(d) = c.line_deadline {
                consider(d);
            }
            if idle_ms > 0 && c.inflight.is_empty() && c.pending_stats == 0 {
                consider(c.last_activity + Duration::from_millis(idle_ms));
            }
        }
        if let Some(t0) = self.drain_started {
            consider(t0 + Duration::from_millis(self.drain_hard_ms()));
        }
        match next {
            Some(t) => (t.saturating_duration_since(now).as_millis() as u64).min(500) as i32,
            None if self.conns.is_empty() && !self.draining => -1,
            None => 500,
        }
    }

    fn drain_wakes(&mut self) {
        let mut buf = [0u8; 64];
        loop {
            match (&self.wake_rx).read(&mut buf) {
                Ok(0) => return,
                Ok(_) => {}
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => return,
            }
        }
    }

    fn handle_control(&mut self, m: Control) {
        match m {
            Control::Conn(stream) => self.install(stream),
            Control::Done(tok, c) => {
                let line = {
                    let Some(conn) = self.conns.get_mut(&tok) else { return };
                    // Retire the id before the reply is queued, guarded
                    // on the route so a pipelined same-id reuse racing
                    // this completion can never evict the newer entry.
                    if conn.inflight.get(&c.id) == Some(&c.route) {
                        conn.inflight.remove(&c.id);
                    }
                    render_completion(&c)
                };
                self.push_line(tok, &line);
            }
            Control::Line(tok, s) => {
                match self.conns.get_mut(&tok) {
                    Some(conn) => conn.pending_stats = conn.pending_stats.saturating_sub(1),
                    None => return,
                }
                self.push_line(tok, &s);
            }
        }
    }

    /// Accept everything pending on the listener, shedding beyond the
    /// global connection cap and dealing survivors round-robin.
    fn accept_ready(&mut self) {
        loop {
            let accepted = match &self.listener {
                Some(l) => l.accept(),
                None => return,
            };
            match accepted {
                Ok((stream, _)) => self.dispatch(stream),
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                // Transient accept failure (ECONNABORTED, EMFILE):
                // yield; poll re-reports the listener when ready.
                Err(_) => return,
            }
        }
    }

    fn dispatch(&mut self, stream: TcpStream) {
        if self.draining {
            self.shed(stream, "server draining");
            return;
        }
        // Reserve the slot before handing off so a same-instant burst
        // cannot overshoot the cap.
        let prev = self.gauges.open_conns.fetch_add(1, Ordering::Relaxed);
        if prev >= self.cfg.max_conns {
            self.gauges.open_conns.fetch_sub(1, Ordering::Relaxed);
            self.shed(stream, "server at connection capacity");
            return;
        }
        let target = self.rr % self.handles.len();
        self.rr = self.rr.wrapping_add(1);
        let h = &self.handles[target];
        if h.ctl_tx.send(Control::Conn(stream)).is_ok() {
            h.waker.wake();
        } else {
            self.gauges.open_conns.fetch_sub(1, Ordering::Relaxed);
        }
    }

    /// Refuse a connection at the accept edge: one best-effort
    /// `{"error", "retry_after_ms"}` line, then close. Mirrors the
    /// engine's queue shedding so clients handle both identically.
    fn shed(&mut self, stream: TcpStream, why: &str) {
        self.gauges.conns_shed.fetch_add(1, Ordering::Relaxed);
        let line = Json::obj(vec![
            ("error", Json::str(why)),
            ("retry_after_ms", Json::num(SHED_RETRY_MS as f64)),
        ])
        .to_string();
        let _ = stream.set_nonblocking(false);
        let _ = stream.set_write_timeout(Some(Duration::from_millis(250)));
        let mut s = stream;
        let _ = writeln!(s, "{line}");
    }

    fn install(&mut self, stream: TcpStream) {
        if self.draining {
            // Raced a drain transition between accept and dealing.
            self.gauges.open_conns.fetch_sub(1, Ordering::Relaxed);
            self.shed(stream, "server draining");
            return;
        }
        if stream.set_nonblocking(true).is_err() {
            self.gauges.open_conns.fetch_sub(1, Ordering::Relaxed);
            return;
        }
        let _ = stream.set_nodelay(true);
        if self.cfg.sock_sndbuf_bytes > 0 {
            let snd = Some(self.cfg.sock_sndbuf_bytes);
            let _ = poll::set_sock_buf(stream.as_raw_fd(), snd, None);
        }
        let tok = self.next_token;
        self.next_token += 1;
        self.conns.insert(
            tok,
            Conn {
                stream,
                rbuf: Vec::new(),
                scan_from: 0,
                discarding: false,
                wbuf: Vec::new(),
                wpos: 0,
                inflight: HashMap::new(),
                pending_stats: 0,
                last_activity: Instant::now(),
                line_deadline: None,
            },
        );
    }

    /// Remove a connection and batch-abort everything it had in
    /// flight. One `AbortMany` per teardown: mpsc preserves per-sender
    /// order, so the abort always lands after this connection's own
    /// `Req` sends and never interleaves with other connections'
    /// teardowns.
    fn teardown(&mut self, tok: u64) {
        let Some(c) = self.conns.remove(&tok) else { return };
        self.gauges.open_conns.fetch_sub(1, Ordering::Relaxed);
        let routes: Vec<u64> = c.inflight.values().copied().collect();
        if !routes.is_empty() {
            let _ = self.engine_tx.send(Inbound::AbortMany(routes));
        }
        // dropping `c.stream` closes the fd; any Done/Line still in
        // flight for this token is dropped on arrival (never reused)
    }

    fn conn_readable(&mut self, tok: u64) {
        // `server.io` on the read side simulates the socket dying
        // between reads: identical teardown to a real broken peer.
        if self.conns.contains_key(&tok) && self.faults.fire("server.io") {
            self.gauges.io_fault_closes.fetch_add(1, Ordering::Relaxed);
            self.teardown(tok);
            return;
        }
        let mut chunk = [0u8; 8192];
        for _ in 0..READ_CHUNKS_PER_EVENT {
            let r = match self.conns.get_mut(&tok) {
                Some(c) => (&c.stream).read(&mut chunk),
                None => return,
            };
            match r {
                Ok(0) => {
                    // Reader EOF *is* the disconnect signal (see the
                    // module docs): abort everything still in flight.
                    self.teardown(tok);
                    return;
                }
                Ok(n) => {
                    if !self.ingest(tok, &chunk[..n]) {
                        return;
                    }
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => return,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.teardown(tok);
                    return;
                }
            }
        }
    }

    /// Buffer freshly read bytes, consume complete lines, enforce the
    /// line-length bound, and maintain the line deadline. Returns
    /// false if the connection was torn down.
    fn ingest(&mut self, tok: u64, data: &[u8]) -> bool {
        let now = Instant::now();
        {
            let Some(c) = self.conns.get_mut(&tok) else { return false };
            c.last_activity = now;
            c.rbuf.extend_from_slice(data);
        }
        let mut consumed_line = false;
        loop {
            let (line, discard) = {
                let Some(c) = self.conns.get_mut(&tok) else { return false };
                let Some(rel) = c.rbuf[c.scan_from..].iter().position(|&b| b == b'\n') else {
                    c.scan_from = c.rbuf.len();
                    break;
                };
                let end = c.scan_from + rel;
                let line: Vec<u8> = c.rbuf.drain(..=end).collect();
                c.scan_from = 0;
                (line, std::mem::take(&mut c.discarding))
            };
            consumed_line = true;
            if discard {
                // Tail of an oversized line; the error was already
                // answered when the bound tripped.
                continue;
            }
            if !self.handle_line(tok, &line[..line.len() - 1]) {
                return false;
            }
        }
        let max_line = self.cfg.max_line_bytes;
        let dl_ms = self.cfg.read_deadline_ms;
        let oversize = {
            let Some(c) = self.conns.get_mut(&tok) else { return false };
            let over = !c.discarding && c.rbuf.len() > max_line;
            if over {
                // Drop the partial line but keep the connection: one
                // error reply, then swallow until the next newline.
                c.discarding = true;
                c.rbuf.clear();
                c.scan_from = 0;
            }
            if c.rbuf.is_empty() && !c.discarding {
                c.line_deadline = None;
            } else if consumed_line || c.line_deadline.is_none() {
                // A new partial line just began (or progress was made
                // through a complete line): restart its clock. Dribbled
                // bytes into the *same* partial line do not reset it.
                c.line_deadline = (dl_ms > 0).then(|| now + Duration::from_millis(dl_ms));
            }
            over
        };
        if oversize {
            self.gauges.oversize_lines.fetch_add(1, Ordering::Relaxed);
            let msg = error_line(&format!(
                "request line exceeds max_line_bytes ({max_line}); line dropped"
            ));
            return self.push_line(tok, &msg);
        }
        true
    }

    /// Parse and act on one complete line. Returns false if the
    /// connection was torn down (e.g. the reply tripped the
    /// write high-water mark).
    fn handle_line(&mut self, tok: u64, raw: &[u8]) -> bool {
        let line = match std::str::from_utf8(raw) {
            Ok(s) => s.trim(),
            Err(_) => return self.push_line(tok, &error_line("request line is not valid UTF-8")),
        };
        if line.is_empty() {
            return true;
        }
        // parse each line exactly once; branch on the parsed value
        let parsed = match Json::parse(line) {
            Ok(v) => v,
            Err(e) => return self.push_line(tok, &error_line(&e.to_string())),
        };
        if is_stats_json(&parsed) {
            return self.send_query(tok, Inbound::Stats);
        }
        if let Some(n) = trace_request_depth(&parsed) {
            return self.send_query(tok, |addr| Inbound::Trace(addr, n));
        }
        if is_dump_json(&parsed) {
            return self.send_query(tok, Inbound::Dump);
        }
        if is_metrics_json(&parsed) {
            return self.send_query(tok, Inbound::MetricsQ);
        }
        // A cancel message is an object carrying "cancel" and no
        // request body — a request with a stray "cancel" field must
        // still be submitted (and answered), not silently swallowed.
        if parsed.opt("cancel").is_some() && parsed.opt("prompt").is_none() {
            match cancel_target(&parsed) {
                Some(id) => {
                    // Fire-and-forget (module docs): in flight → the
                    // engine answers with a "cancelled" finish; unknown
                    // id → silently ignored.
                    let route = self.conns.get(&tok).and_then(|c| c.inflight.get(&id).copied());
                    if let Some(r) = route {
                        let _ = self.engine_tx.send(Inbound::Abort(r));
                    }
                }
                None => {
                    let msg = "malformed cancel: \"cancel\" must be a numeric request id";
                    return self.push_line(tok, &error_line(msg));
                }
            }
            return true;
        }
        let mut req = match request_from_json(&parsed) {
            Ok(r) => r,
            Err(e) => return self.push_line(tok, &error_line(&e.to_string())),
        };
        let dup = match self.conns.get(&tok) {
            Some(c) => c.inflight.contains_key(&req.id),
            None => return false,
        };
        if dup {
            let msg = error_line(&format!("duplicate in-flight request id {}", req.id));
            return self.push_line(tok, &msg);
        }
        req.route = self.next_route.fetch_add(1, Ordering::Relaxed);
        if let Some(c) = self.conns.get_mut(&tok) {
            c.inflight.insert(req.id, req.route);
        }
        let addr = ConnAddr { reactor: self.idx, token: tok };
        let _ = self.engine_tx.send(Inbound::Req(req, addr));
        true
    }

    /// Forward one engine-answered query line (stats, trace, dump,
    /// metrics) to the engine thread. All four share the
    /// `pending_stats` accounting so drain-time quiescence waits for
    /// their replies too.
    fn send_query<F: FnOnce(ConnAddr) -> Inbound>(&mut self, tok: u64, make: F) -> bool {
        if let Some(c) = self.conns.get_mut(&tok) {
            c.pending_stats += 1;
        }
        let addr = ConnAddr { reactor: self.idx, token: tok };
        let _ = self.engine_tx.send(make(addr));
        true
    }

    /// Queue one reply line, enforcing the write high-water mark, and
    /// opportunistically flush. Returns false if the connection was
    /// torn down.
    fn push_line(&mut self, tok: u64, line: &str) -> bool {
        let hwm = self.cfg.write_hwm_bytes;
        let over = {
            let Some(c) = self.conns.get_mut(&tok) else { return false };
            if c.pending_out() + line.len() + 1 > hwm {
                true
            } else {
                c.wbuf.extend_from_slice(line.as_bytes());
                c.wbuf.push(b'\n');
                if self.telemetry.on() {
                    self.telemetry.write_queue_depth.record(c.pending_out() as u64);
                }
                false
            }
        };
        if over {
            // The client stopped reading long enough to back the
            // socket *and* the userspace queue up past the high-water
            // mark: declare it dead rather than buffer unboundedly.
            self.gauges.write_backpressure_closes.fetch_add(1, Ordering::Relaxed);
            self.teardown(tok);
            return false;
        }
        self.flush(tok)
    }

    /// Write as much buffered output as the socket accepts. Returns
    /// false if the connection was torn down.
    fn flush(&mut self, tok: u64) -> bool {
        {
            let Some(c) = self.conns.get(&tok) else { return false };
            if c.pending_out() == 0 {
                return true;
            }
        }
        // `server.io` on the write side simulates the socket dying
        // mid-response; same teardown as a real write failure.
        if self.faults.fire("server.io") {
            self.gauges.io_fault_closes.fetch_add(1, Ordering::Relaxed);
            self.teardown(tok);
            return false;
        }
        loop {
            let Some(c) = self.conns.get_mut(&tok) else { return false };
            if c.wpos == c.wbuf.len() {
                c.wbuf.clear();
                c.wpos = 0;
                return true;
            }
            match (&c.stream).write(&c.wbuf[c.wpos..]) {
                Ok(0) => {
                    self.teardown(tok);
                    return false;
                }
                Ok(n) => c.wpos += n,
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    // Compact a large consumed prefix so a slowly
                    // draining buffer does not pin memory.
                    if c.wpos > 64 * 1024 {
                        c.wbuf.drain(..c.wpos);
                        c.wpos = 0;
                    }
                    return true;
                }
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(_) => {
                    self.teardown(tok);
                    return false;
                }
            }
        }
    }

    fn conn_writable(&mut self, tok: u64) {
        self.flush(tok);
    }

    /// Enforce line deadlines, idle timeouts, and the hard drain
    /// deadline.
    fn sweep_deadlines(&mut self) {
        let now = Instant::now();
        let idle_ms = self.cfg.idle_timeout_ms;
        let mut line_expired: Vec<u64> = Vec::new();
        let mut idle_expired: Vec<u64> = Vec::new();
        for (&tok, c) in &self.conns {
            if c.line_deadline.map(|d| now >= d).unwrap_or(false) {
                line_expired.push(tok);
            } else if idle_ms > 0
                && c.inflight.is_empty()
                && c.pending_stats == 0
                && c.rbuf.is_empty()
                && !c.discarding
                && c.pending_out() == 0
                && now.duration_since(c.last_activity).as_millis() as u64 > idle_ms
            {
                idle_expired.push(tok);
            }
        }
        for tok in line_expired {
            self.gauges.read_deadline_closes.fetch_add(1, Ordering::Relaxed);
            self.teardown(tok);
        }
        for tok in idle_expired {
            self.gauges.idle_closes.fetch_add(1, Ordering::Relaxed);
            self.teardown(tok);
        }
        if let Some(t0) = self.drain_started {
            if now.duration_since(t0).as_millis() as u64 > self.drain_hard_ms() {
                // Bounded quiescence: whatever could not finish and
                // flush inside the drain window is cut off now.
                let all: Vec<u64> = self.conns.keys().copied().collect();
                for tok in all {
                    self.teardown(tok);
                }
            }
        }
    }
}
